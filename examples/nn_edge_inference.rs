//! End-to-end driver (the paper's motivating workload, §I): quantised
//! NN inference on an edge-style datapath where the 4x4-bit multiplier
//! is approximated by each ALS method, trading multiplier area against
//! classification accuracy. This run is recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --offline --example nn_edge_inference [STORE_DIR]
//!
//! With a STORE_DIR argument (a store written by `sxpat sweep --store`),
//! the multiplier is *not* re-synthesised: each error budget becomes a
//! QoS tier in a `serve::Registry` — the same tiered resolution the
//! serving layer uses — which resolves it to the cheapest stored 4x4
//! multiplier within budget (re-verified against the exhaustive
//! oracle) and hands back a ready `MultLut`. Budgets the library
//! cannot serve resolve to the exact-multiplier fallback, and for
//! those this example synthesises with MUSCAT/MECALS instead, exactly
//! as the store-less mode does for every row.

use sxpat::baselines::{mecals, muscat};
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::nn::{synthetic_digits, MultLut, QuantMlp};
use sxpat::serve::{Registry, TierSource, TierSpec};
use sxpat::synth::synthesize_area;

const ETS: [u64; 6] = [1, 2, 4, 8, 16, 32];

fn tier_name(et: u64) -> String {
    format!("et{et}")
}

fn main() {
    let bench = benchmark_by_name("mult_i8").unwrap();
    let nl = bench.netlist();
    let exact_area = synthesize_area(&nl);

    // Train once on the synthetic digits workload; inference is pure
    // integer and swaps only the multiplier LUT.
    let train = synthetic_digits(300, 11);
    let test = synthetic_digits(200, 77);
    let mlp = QuantMlp::train(&train, 12, 15, 5);
    let exact_acc = mlp.accuracy(&test, &MultLut::exact());
    println!("exact 4x4 multiplier: area {exact_area:.2} µm², accuracy {exact_acc:.3}\n");

    let registry = std::env::args().nth(1).map(|dir| {
        let tiers: Vec<TierSpec> = ETS
            .iter()
            .map(|&et| TierSpec { name: tier_name(et), et })
            .collect();
        // Same model the accuracy rows use, so the registry's compiled
        // kernels are interchangeable with mlp.accuracy.
        let reg = Registry::open(
            "mult_i8",
            tiers,
            Some(std::path::Path::new(&dir)),
            std::sync::Arc::new(mlp.clone()),
            true,
        )
        .unwrap_or_else(|e| panic!("cannot open operator registry on {dir}: {e:#}"));
        let served = reg
            .snapshot()
            .values()
            .filter(|t| matches!(t.source, TierSource::OpLib { .. }))
            .count();
        println!(
            "operator registry over {dir}: {served}/{} tiers resolved from the library\n",
            ETS.len()
        );
        reg
    });

    println!(
        "{:<8} {:>4} {:>9} {:>8} {:>8} {:>9}  {}",
        "method", "ET", "area", "saving%", "max|err|", "accuracy", "source"
    );

    for et in ETS {
        // Registry hit: serve the stored operator instead of searching.
        let tier = registry.as_ref().and_then(|r| r.resolve(&tier_name(et)));
        if let Some(tier) = tier {
            if let TierSource::OpLib { method, fingerprint } = &tier.source {
                // Compiled batch kernel when the operator fits i16
                // product rows — byte-identical to the scalar path.
                let acc = match &tier.kernel {
                    Some(kernel) => kernel.accuracy(&test),
                    None => mlp.accuracy(&test, &tier.lut),
                };
                println!(
                    "{:<8} {et:>4} {:>9.3} {:>8.1} {:>8} {acc:>9.3}  oplib {}",
                    method,
                    tier.area,
                    100.0 * (1.0 - tier.area / exact_area),
                    tier.lut.max_error(),
                    fingerprint,
                );
                continue;
            }
            // ExactFallback = nothing stored within budget: synthesise
            // below, as the store-less mode does.
        }
        for (label, res) in [
            ("MUSCAT", muscat(&nl, et)),
            ("MECALS", mecals(&nl, et)),
        ] {
            let lut = match MultLut::try_from_netlist(&res.netlist) {
                Ok(lut) => lut,
                Err(e) => {
                    println!("{label:<8} {et:>4} synthesis produced a malformed multiplier: {e}");
                    continue;
                }
            };
            let acc = mlp.accuracy(&test, &lut);
            println!(
                "{label:<8} {et:>4} {:>9.3} {:>8.1} {:>8} {acc:>9.3}  synthesised",
                res.area,
                100.0 * (1.0 - res.area / exact_area),
                lut.max_error(),
            );
        }
    }
    println!("\ntake-away: small ET buys large multiplier-area savings at \
              negligible accuracy loss — the edge-inference tradeoff the \
              paper targets.");
}

//! End-to-end driver (the paper's motivating workload, §I): quantised
//! NN inference on an edge-style datapath where the 4x4-bit multiplier
//! is approximated by each ALS method, trading multiplier area against
//! classification accuracy. This run is recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --offline --example nn_edge_inference [STORE_DIR]
//!
//! With a STORE_DIR argument (a store written by `sxpat sweep --store`),
//! the multiplier is *not* re-synthesised: for each error budget the
//! example asks the operator library for the cheapest stored 4x4
//! multiplier within budget (`OpLib::best`), re-verifies it against the
//! exhaustive oracle, and drops its truth table straight into the
//! datapath via `MultLut::from_values` — the deployment-time flow where
//! search and serving are decoupled. Budgets with no stored operator
//! fall back to synthesising with MUSCAT, exactly as the store-less
//! mode does for every row.

use sxpat::baselines::{mecals, muscat};
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::nn::{synthetic_digits, MultLut, QuantMlp};
use sxpat::store::{OpLib, Store};
use sxpat::synth::synthesize_area;

fn main() {
    let bench = benchmark_by_name("mult_i8").unwrap();
    let nl = bench.netlist();
    let exact_area = synthesize_area(&nl);

    // Train once on the synthetic digits workload; inference is pure
    // integer and swaps only the multiplier LUT.
    let train = synthetic_digits(300, 11);
    let test = synthetic_digits(200, 77);
    let mlp = QuantMlp::train(&train, 12, 15, 5);
    let exact_acc = mlp.accuracy(&test, &MultLut::exact());
    println!("exact 4x4 multiplier: area {exact_area:.2} µm², accuracy {exact_acc:.3}\n");

    let lib = std::env::args().nth(1).map(|dir| {
        let store = Store::open(std::path::Path::new(&dir))
            .unwrap_or_else(|e| panic!("cannot open store {dir}: {e:#}"));
        let lib = OpLib::from_store(&store);
        println!(
            "operator library {dir}: {} stored operators for mult_i8\n",
            lib.frontier("mult_i8").len()
        );
        lib
    });

    println!(
        "{:<8} {:>4} {:>9} {:>8} {:>8} {:>9}  {}",
        "method", "ET", "area", "saving%", "max|err|", "accuracy", "source"
    );

    for et in [1u64, 2, 4, 8, 16, 32] {
        // Library hit: serve the stored operator instead of searching.
        if let Some(entry) = lib.as_ref().and_then(|l| l.best("mult_i8", et)) {
            OpLib::verify(entry).expect("stored operator failed re-verification");
            let lut = MultLut::from_values(&entry.values);
            let acc = mlp.accuracy(&test, &lut);
            println!(
                "{:<8} {et:>4} {:>9.3} {:>8.1} {:>8} {acc:>9.3}  oplib {}",
                entry.method.name(),
                entry.area,
                100.0 * (1.0 - entry.area / exact_area),
                lut.max_error(),
                entry.fingerprint,
            );
            continue;
        }
        for (label, res) in [
            ("MUSCAT", muscat(&nl, et)),
            ("MECALS", mecals(&nl, et)),
        ] {
            let lut = MultLut::from_netlist(&res.netlist);
            let acc = mlp.accuracy(&test, &lut);
            println!(
                "{label:<8} {et:>4} {:>9.3} {:>8.1} {:>8} {acc:>9.3}  synthesised",
                res.area,
                100.0 * (1.0 - res.area / exact_area),
                lut.max_error(),
            );
        }
    }
    println!("\ntake-away: small ET buys large multiplier-area savings at \
              negligible accuracy loss — the edge-inference tradeoff the \
              paper targets.");
}

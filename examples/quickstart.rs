//! Quickstart: approximate one adder with the SHARED template and
//! inspect the result.
//!
//!     cargo run --offline --example quickstart

use sxpat::circuit::generators::benchmark_by_name;
use sxpat::circuit::sim::{error_stats, TruthTables};
use sxpat::circuit::verilog::write_verilog;
use sxpat::search::{search_shared, SearchConfig};
use sxpat::synth::synthesize_area;

fn main() {
    // 1. Pick a benchmark (a 2+2-bit adder) and an error threshold.
    let bench = benchmark_by_name("adder_i4").unwrap();
    let nl = bench.netlist();
    let et = 1;
    let exact_area = synthesize_area(&nl);
    println!("exact {}: area {exact_area:.3} µm²", bench.name);

    // 2. Run the SHARED-template search (paper §II-C / §III).
    let cfg = SearchConfig { pool: 8, ..Default::default() };
    let outcome = search_shared(&nl, et, &cfg);
    println!(
        "search: {} cells tried, {} SAT, {} solutions, {} ms",
        outcome.cells_tried,
        outcome.cells_sat,
        outcome.solutions.len(),
        outcome.elapsed_ms
    );

    // 3. The best solution: proxies, area, and a soundness re-check.
    let best = outcome.best().expect("search found no solution");
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    let (max_err, mean_err) = error_stats(&exact, &best.params.output_values());
    println!(
        "best: PIT={} ITS={} -> area {:.3} µm² ({:.1}% saving), max|err|={max_err} (ET {et}), mean {mean_err:.3}",
        best.proxy.0,
        best.proxy.1,
        best.area,
        100.0 * (1.0 - best.area / exact_area)
    );
    assert!(max_err <= et, "sound by construction");

    // 4. Export the approximate circuit as Verilog.
    let approx = best.params.to_netlist("adder_i4_approx");
    println!("\n{}", write_verilog(&approx));
}

//! Bring-your-own-circuit: parse a Verilog spec, approximate it with the
//! SHARED template, and write the approximation back out — the workflow
//! a downstream user of the open-source tool follows.
//!
//!     cargo run --offline --example custom_circuit [file.v] [ET]

use sxpat::circuit::sim::{error_stats, TruthTables};
use sxpat::circuit::verilog::{parse_verilog, write_verilog};
use sxpat::search::{search_shared, SearchConfig};
use sxpat::synth::synthesize_area;

/// A 3-input majority-plus-parity unit, as a user might hand-write it.
const DEMO: &str = "
module majpar (in0, in1, in2, out0, out1);
  input in0, in1, in2;
  output out0, out1;
  wire ab, ac, bc, mj;
  and g1 (ab, in0, in1);
  and g2 (ac, in0, in2);
  and g3 (bc, in1, in2);
  or  g4 (mj, ab, ac, bc);
  wire px;
  xor g5 (px, in0, in1, in2);
  assign out0 = mj;
  assign out1 = px;
endmodule";

fn main() {
    let (src, et) = match std::env::args().nth(1) {
        Some(path) => (
            std::fs::read_to_string(&path).expect("reading verilog file"),
            std::env::args()
                .nth(2)
                .and_then(|s| s.parse().ok())
                .unwrap_or(1),
        ),
        None => (DEMO.to_string(), 1),
    };

    let nl = parse_verilog(&src).expect("parse failed");
    println!(
        "parsed `{}`: {} inputs, {} outputs, {} gates, exact area {:.3} µm²",
        nl.name,
        nl.n_inputs(),
        nl.n_outputs(),
        nl.n_logic_gates(),
        synthesize_area(&nl)
    );

    let cfg = SearchConfig { pool: 8, ..Default::default() };
    let outcome = search_shared(&nl, et, &cfg);
    match outcome.best() {
        None => println!("no approximation found within budget at ET={et}"),
        Some(best) => {
            let exact = TruthTables::simulate(&nl).output_values(&nl);
            let (mx, mean) = error_stats(&exact, &best.params.output_values());
            println!(
                "SHARED @ ET={et}: area {:.3} µm², PIT={}, ITS={}, max|err|={mx}, mean {mean:.3}",
                best.area, best.proxy.0, best.proxy.1
            );
            let out = best.params.to_netlist(&format!("{}_approx", nl.name));
            println!("\n{}", write_verilog(&out));
        }
    }
}

//! Pareto sweep (Fig. 5 in miniature): all four methods across the ET
//! range of one benchmark, on the parallel coordinator.
//!
//!     cargo run --release --offline --example pareto_sweep [bench]

use sxpat::circuit::generators::benchmark_by_name;
use sxpat::coordinator::{run_sweep, Method, SweepPlan};
use sxpat::report::fig5_markdown;
use sxpat::search::SearchConfig;
use sxpat::synth::synthesize_area;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "mult_i4".into());
    let bench = benchmark_by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown benchmark {name}");
        std::process::exit(1);
    });
    let exact_area = synthesize_area(&bench.netlist());
    println!("{name}: exact area {exact_area:.3} µm²; sweeping ET ∈ {:?}", bench.et_sweep());

    let plan = SweepPlan {
        benches: vec![bench],
        methods: Method::all_compared().to_vec(),
        ets: None, // paper sweep for this benchmark
        search: SearchConfig { pool: 8, ..Default::default() },
        workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };
    let records = run_sweep(&plan);
    println!("{}", fig5_markdown(&records));

    // Pareto frontier (ET, area) for SHARED.
    println!("SHARED Pareto frontier:");
    let mut frontier: Vec<(u64, f64)> = records
        .iter()
        .filter(|r| r.method == Method::Shared && r.area.is_finite())
        .map(|r| (r.et, r.area))
        .collect();
    frontier.sort_by_key(|&(et, _)| et);
    let mut best = f64::INFINITY;
    for (et, area) in frontier {
        if area < best {
            best = area;
            println!("  ET {et:>3}: {area:.3} µm² ({:.1}% of exact)", 100.0 * area / exact_area);
        }
    }
}

//! Distributed-fabric throughput: jobs/sec and speedup over a 1/2/4
//! worker grid, real loopback TCP, in-process workers, plus the local
//! single-process sweep as the zero-overhead reference. Written to
//! `BENCH_dist.json`.
//!
//!     cargo bench --bench dist

use std::time::Instant;

use sxpat::bench_support::JsonReport;
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::coordinator::{run_sweep, Method, SweepPlan};
use sxpat::dist::{Coordinator, DistConfig, WorkerConfig};
use sxpat::search::SearchConfig;

/// Enough jobs that a 4-worker fleet stays busy, small enough that the
/// grid finishes in seconds: 2 benches × 2 methods × 4 ETs = 16 jobs.
fn bench_plan() -> SweepPlan {
    SweepPlan {
        benches: vec![
            benchmark_by_name("adder_i4").unwrap(),
            benchmark_by_name("mult_i4").unwrap(),
        ],
        methods: vec![Method::Shared, Method::Muscat],
        ets: Some(vec![1, 2, 3, 4]),
        search: SearchConfig {
            pool: 5,
            solutions_per_cell: 1,
            max_sat_cells: 1,
            conflict_budget: Some(20_000),
            time_budget_ms: 20_000,
            ..Default::default()
        },
        workers: 1,
    }
}

/// One distributed run (no store: measuring the fabric, not the cache);
/// returns wall seconds.
fn run_distributed(plan: &SweepPlan, workers: usize) -> f64 {
    let cfg = DistConfig {
        addr: "127.0.0.1:0".to_string(),
        lease_ms: 120_000,
        wait_ms: 10,
        ..Default::default()
    };
    let t = Instant::now();
    let records = std::thread::scope(|s| {
        let coord = Coordinator::bind(plan, None, &cfg).unwrap();
        let addr = coord.addr();
        let run = s.spawn(move || coord.run().unwrap());
        for i in 0..workers {
            s.spawn(move || {
                sxpat::dist::run_worker(&WorkerConfig {
                    addr: addr.to_string(),
                    name: format!("bench-w{i}"),
                    cell_workers: None,
                    max_jobs: None,
                    ..Default::default()
                })
                .unwrap()
            });
        }
        run.join().unwrap()
    });
    assert_eq!(records.len(), plan.n_jobs());
    assert!(records.iter().all(|r| r.error.is_none()));
    t.elapsed().as_secs_f64()
}

fn main() {
    let mut report = JsonReport::new();
    let plan = bench_plan();
    let n_jobs = plan.n_jobs() as f64;
    report.push("jobs", n_jobs);

    // Local single-process reference (the fabric's overhead floor).
    let t = Instant::now();
    let local = run_sweep(&plan);
    let local_s = t.elapsed().as_secs_f64();
    assert_eq!(local.len(), plan.n_jobs());
    println!(
        "bench dist/local_w1        {:>8.2} jobs/s ({:.3} s)",
        n_jobs / local_s,
        local_s
    );
    report.push("local_w1.jobs_per_sec", n_jobs / local_s);

    let mut one_worker_s = f64::NAN;
    for workers in [1usize, 2, 4] {
        let secs = run_distributed(&plan, workers);
        let jps = n_jobs / secs;
        if workers == 1 {
            one_worker_s = secs;
        }
        let speedup = one_worker_s / secs;
        println!(
            "bench dist/dist_w{workers}         {jps:>8.2} jobs/s ({secs:.3} s, \
             speedup x{speedup:.2})"
        );
        report.push(&format!("dist_w{workers}.jobs_per_sec"), jps);
        report.push(&format!("dist_w{workers}.speedup_over_w1"), speedup);
    }
    // Fabric tax: 1 distributed worker vs the same sweep in-process.
    report.push("dist_w1.overhead_vs_local", one_worker_s / local_s);

    report.write("dist");
}

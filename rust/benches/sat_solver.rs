//! SAT-substrate microbenchmarks: propagation rate on miter CNFs and on
//! pigeonhole instances, the arena headline — prototype *clone* versus
//! fresh *build* cost per miter — and the heuristics A/B: the legacy
//! policies (Luby restarts, activity-only reduce, no preprocessing)
//! against the Glucose-class defaults (EMA restarts, LBD-tiered reduce,
//! prototype preprocessing) on the same miter corpus, reporting
//! conflicts/sec plus the restart/LBD/preprocessing counters so
//! `BENCH_sat.json` records *why* solve time moved. Feeds EXPERIMENTS.md
//! §Perf (L3 targets).
//!
//!     cargo bench --bench sat_solver

use sxpat::bench_support::{bench, bench_clone_vs_build, JsonReport};
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::circuit::sim::TruthTables;
use sxpat::sat::{Heuristics, Lit, SatResult, Solver, Stats};
use sxpat::template::SharedMiter;

fn php(pigeons: usize, holes: usize) -> Solver {
    let mut s = Solver::new();
    let mut v = vec![vec![Lit(0); holes]; pigeons];
    for p in 0..pigeons {
        for h in 0..holes {
            v[p][h] = Lit::pos(s.new_var());
        }
    }
    for p in 0..pigeons {
        s.add_clause(&v[p]);
    }
    for h in 0..holes {
        for p1 in 0..pigeons {
            for p2 in p1 + 1..pigeons {
                s.add_clause(&[!v[p1][h], !v[p2][h]]);
            }
        }
    }
    s
}

fn main() {
    let mut report = JsonReport::new();

    // Pigeonhole: conflict-analysis stress.
    for n in [7usize, 8] {
        let mut props = 0u64;
        let mut reclaimed = 0u64;
        let stats = bench(&format!("sat/php_{}_{n}", n + 1), 1, 3, || {
            let mut s = php(n + 1, n);
            assert_eq!(s.solve(&[]), SatResult::Unsat);
            props = s.stats.propagations;
            reclaimed = s.stats.arena_reclaimed_words;
        });
        let rate = props as f64 / (stats.mean_ms / 1e3) / 1e6;
        println!(
            "  {rate:.1} M props/s ({props} propagations, {reclaimed} arena words reclaimed)"
        );
        report.push_stats(&format!("php_{}_{n}", n + 1), &stats);
        report.push(&format!("php_{}_{n}.props_per_sec", n + 1), rate * 1e6);
        report.push(&format!("php_{}_{n}.arena_reclaimed_words", n + 1), reclaimed as f64);
    }

    // Miter solving: the workload the search actually runs, A/B'd
    // between the legacy and Glucose-class policies on an identical
    // corpus. Each iteration clones the (optionally preprocessed)
    // prototype and solves a cold lattice prefix — exactly the per-cell
    // pattern of the canonical scan.
    for (name, et) in [("adder_i4", 1u64), ("mult_i4", 2), ("adder_i6", 8)] {
        let b = benchmark_by_name(name).unwrap();
        let nl = b.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let (n, m) = (nl.n_inputs(), nl.n_outputs());
        // The arena headline: cloning the encoded prototype must be far
        // cheaper than re-running the full encode — this ratio is what
        // the canonical parallel scan saves on every lattice cell.
        bench_clone_vs_build(&mut report, "sat", &format!("miter_{name}"), || {
            SharedMiter::build(n, m, 8, &exact, et)
        });

        for (policy, heur, preprocess) in [
            ("legacy", Heuristics::legacy(), false),
            ("glucose", Heuristics::default(), true),
        ] {
            let mut base = SharedMiter::build(n, m, 8, &exact, et);
            base.b.solver.heuristics = heur;
            if preprocess {
                base.preprocess();
            }
            let mut last = Stats::default();
            let key = format!("miter_solve_{name}_et{et}.{policy}");
            let solve_stats = bench(&format!("sat/{key}"), 1, 3, || {
                let mut miter = base.clone();
                for pit in 1..=4usize {
                    if miter.solve(pit, 3 * pit).is_sat() {
                        break;
                    }
                }
                last = miter.b.solver.stats.clone();
            });
            let secs = solve_stats.mean_ms / 1e3;
            let conflicts_per_sec = last.conflicts as f64 / secs;
            let props_per_sec = last.propagations as f64 / secs;
            println!(
                "  {policy}: {conflicts_per_sec:.0} conflicts/s, \
                 {:.1} M props/s, {} restarts ({} blocked)",
                props_per_sec / 1e6,
                last.restarts,
                last.restarts_blocked
            );
            report.push_stats(&key, &solve_stats);
            report.push(&format!("{key}.conflicts_per_sec"), conflicts_per_sec);
            report.push(&format!("{key}.props_per_sec"), props_per_sec);
            report.push_sat_stats(&key, &last);
        }
    }

    report.write("sat");
}

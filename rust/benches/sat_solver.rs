//! SAT-substrate microbenchmarks: propagation rate on miter CNFs and on
//! pigeonhole instances, plus the arena headline — prototype *clone*
//! versus fresh *build* cost per miter. Feeds EXPERIMENTS.md §Perf (L3
//! targets) and writes machine-readable results to `BENCH_sat.json`.
//!
//!     cargo bench --bench sat_solver

use sxpat::bench_support::{bench, bench_clone_vs_build, JsonReport};
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::circuit::sim::TruthTables;
use sxpat::sat::{Lit, SatResult, Solver};
use sxpat::template::SharedMiter;

fn php(pigeons: usize, holes: usize) -> Solver {
    let mut s = Solver::new();
    let mut v = vec![vec![Lit(0); holes]; pigeons];
    for p in 0..pigeons {
        for h in 0..holes {
            v[p][h] = Lit::pos(s.new_var());
        }
    }
    for p in 0..pigeons {
        s.add_clause(&v[p]);
    }
    for h in 0..holes {
        for p1 in 0..pigeons {
            for p2 in p1 + 1..pigeons {
                s.add_clause(&[!v[p1][h], !v[p2][h]]);
            }
        }
    }
    s
}

fn main() {
    let mut report = JsonReport::new();

    // Pigeonhole: conflict-analysis stress.
    for n in [7usize, 8] {
        let mut props = 0u64;
        let mut reclaimed = 0u64;
        let stats = bench(&format!("sat/php_{}_{n}", n + 1), 1, 3, || {
            let mut s = php(n + 1, n);
            assert_eq!(s.solve(&[]), SatResult::Unsat);
            props = s.stats.propagations;
            reclaimed = s.stats.arena_reclaimed_words;
        });
        let rate = props as f64 / (stats.mean_ms / 1e3) / 1e6;
        println!(
            "  {rate:.1} M props/s ({props} propagations, {reclaimed} arena words reclaimed)"
        );
        report.push_stats(&format!("php_{}_{n}", n + 1), &stats);
        report.push(&format!("php_{}_{n}.props_per_sec", n + 1), rate * 1e6);
        report.push(&format!("php_{}_{n}.arena_reclaimed_words", n + 1), reclaimed as f64);
    }

    // Miter solving: the workload the search actually runs.
    for (name, et) in [("adder_i4", 1u64), ("mult_i4", 2), ("adder_i6", 8)] {
        let b = benchmark_by_name(name).unwrap();
        let nl = b.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let (n, m) = (nl.n_inputs(), nl.n_outputs());
        // The arena headline: cloning the encoded prototype must be far
        // cheaper than re-running the full encode — this ratio is what
        // the canonical parallel scan saves on every lattice cell.
        bench_clone_vs_build(&mut report, "sat", &format!("miter_{name}"), || {
            SharedMiter::build(n, m, 8, &exact, et)
        });

        let mut miter = SharedMiter::build(n, m, 8, &exact, et);
        let solve_stats = bench(&format!("sat/miter_solve_{name}_et{et}"), 1, 3, || {
            // Re-solve the same lattice prefix each iteration: the
            // solver is incremental, so this measures warm solving.
            for pit in 1..=4usize {
                if miter.solve(pit, 3 * pit).is_sat() {
                    break;
                }
            }
        });
        report.push_stats(&format!("miter_solve_{name}_et{et}"), &solve_stats);
        let props = miter.b.solver.stats.propagations;
        report.push(&format!("miter_solve_{name}_et{et}.total_propagations"), props as f64);
    }

    report.write("sat");
}

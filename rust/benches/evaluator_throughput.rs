//! Evaluator throughput: candidates/second through (a) the rust
//! bit-parallel engine and (b) the PJRT artifact (JAX + Pallas L1
//! kernel). Feeds EXPERIMENTS.md §Perf (L1/L2 targets).
//!
//!     cargo bench --bench evaluator_throughput

use sxpat::bench_support::{bench, black_box, throughput};
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::circuit::sim::TruthTables;
use sxpat::evaluator::rust_eval::evaluate_batch;
use sxpat::runtime::{find_artifacts_dir, Runtime};
use sxpat::template::SopParams;
use sxpat::util::Rng;

fn main() {
    let runtime = find_artifacts_dir().and_then(|d| Runtime::load(&d).ok());
    if runtime.is_none() {
        println!("note: artifacts missing — PJRT lane skipped (run `make artifacts`)");
    }

    for name in ["adder_i4", "mult_i6", "mult_i8"] {
        let b = benchmark_by_name(name).unwrap();
        let nl = b.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let (n, m) = (nl.n_inputs(), nl.n_outputs());
        let t = 16;
        let batch_size = 256;
        let mut rng = Rng::seed_from(99);
        let batch: Vec<SopParams> = (0..batch_size)
            .map(|_| SopParams::random(&mut rng, n, m, t, 0.35, 0.3))
            .collect();

        let s = bench(&format!("eval/rust/{name}/b{batch_size}"), 2, 10, || {
            black_box(evaluate_batch(&batch, &exact));
        });
        println!("  rust: {:.0} candidates/s", throughput(&s, batch_size));

        if let Some(rt) = &runtime {
            if rt.geometry(name).is_some() {
                let s = bench(&format!("eval/pjrt/{name}/b{batch_size}"), 2, 10, || {
                    black_box(rt.evaluate_batch(name, &batch, &exact).unwrap());
                });
                println!("  pjrt: {:.0} candidates/s", throughput(&s, batch_size));
            }
        }
    }
}

//! Fig. 4 regeneration: area vs. proxy value at fixed ET for the paper's
//! four proxy-study benchmarks, with the exact star, the random-sound
//! cloud, and all four methods. Prints the same series the figure plots
//! plus the proxy↔area correlation the paper's take-away (1) claims.
//!
//!     cargo bench --bench fig4_proxy

use sxpat::baselines::random_sound_baseline;
use sxpat::bench_support::bench;
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::coordinator::{run_job, Job, Method};
use sxpat::report::fig4_csv;
use sxpat::search::SearchConfig;
use sxpat::synth::synthesize_area;

fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let vx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let vy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    cov / (vx.sqrt() * vy.sqrt()).max(1e-12)
}

fn main() {
    let cfg = SearchConfig {
        pool: 8,
        solutions_per_cell: 4,
        max_sat_cells: 5,
        conflict_budget: Some(120_000),
        time_budget_ms: 30_000,
        ..Default::default()
    };
    let random_count = 150; // paper: 1000; scaled for bench wall-time

    for name in ["adder_i4", "mult_i4", "adder_i6", "mult_i6"] {
        let b = benchmark_by_name(name).unwrap();
        let nl = b.netlist();
        let et = b.fig4_et();
        let exact_area = synthesize_area(&nl);

        let mut records = Vec::new();
        let stats = bench(&format!("fig4/{name}/methods"), 0, 1, || {
            records.clear();
            for method in Method::all_compared() {
                records.push(run_job(&Job { bench: b, method, et, search: cfg.clone() }));
            }
        });
        let _ = stats;
        let mut random = Vec::new();
        bench(&format!("fig4/{name}/random{random_count}"), 0, 1, || {
            random = random_sound_baseline(&nl, et, random_count, 8, 42, None);
        });

        // The figure's series (head of the CSV).
        let csv = fig4_csv(name, et, exact_area, &records, &random);
        println!("--- {name} (ET {et}) ---");
        for line in csv.lines().take(8) {
            println!("  {line}");
        }
        println!("  ... ({} rows total)", csv.lines().count());

        // Take-away (1): PIT+ITS correlates strongly with area.
        let shared = records.iter().find(|r| r.method == Method::Shared).unwrap();
        let mut xs: Vec<f64> =
            shared.all_points.iter().map(|&(a, b, _)| (a + b) as f64).collect();
        let mut ys: Vec<f64> = shared.all_points.iter().map(|&(_, _, ar)| ar).collect();
        for p in &random {
            xs.push((p.pit + p.its) as f64);
            ys.push(p.area);
        }
        let r = pearson(&xs, &ys);
        println!("  proxy↔area correlation (SHARED pts + random cloud): r = {r:.3}");
        // Take-away (2): SHARED has the smallest area of the methods.
        let best_area = |m: Method| {
            records.iter().find(|r| r.method == m).map(|r| r.area).unwrap()
        };
        println!(
            "  best areas: SHARED {:.3} | XPAT {:.3} | MUSCAT {:.3} | MECALS {:.3} | exact {exact_area:.3}",
            best_area(Method::Shared),
            best_area(Method::Xpat),
            best_area(Method::Muscat),
            best_area(Method::Mecals)
        );
    }
}

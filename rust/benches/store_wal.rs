//! Result-store microbenchmarks: WAL append/replay throughput,
//! fingerprint hashing rate, and the headline system number — cold
//! (all-SAT) vs resumed (all-cached) sweep wall time on the same grid.
//! Written to `BENCH_store.json`.
//!
//!     cargo bench --bench store_wal

use std::path::PathBuf;

use sxpat::bench_support::{bench, black_box, throughput, JsonReport};
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::circuit::sim::TruthTables;
use sxpat::coordinator::{run_sweep_stored, Method, RunRecord, SweepPlan};
use sxpat::search::SearchConfig;
use sxpat::store::{job_fingerprint, Fingerprint, Store};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("sxpat_store_bench_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn synthetic_record(i: u64) -> RunRecord {
    RunRecord {
        bench: "mult_i8",
        method: Method::Shared,
        et: i % 17,
        area: 100.0 + i as f64 * 0.25,
        max_err: i % 17,
        mean_err: 0.375,
        proxy: (3, 9),
        elapsed_ms: i,
        cached: false,
        values: (0..256).map(|v| (v * (i + 1)) % 255).collect(),
        all_points: vec![(3, 9, 100.0), (4, 10, 120.0)],
        error: None,
    }
}

fn main() {
    let mut report = JsonReport::new();

    // WAL append throughput: realistic mult_i8-sized records (256-entry
    // truth tables) streamed one commit at a time.
    const N: u64 = 500;
    let dir = tmp_dir("append");
    let store = Store::open(&dir).unwrap();
    let mut next = 0u64;
    let append_stats = bench("store/wal_append_500", 1, 5, || {
        for i in 0..N {
            let fp = Fingerprint(next * N + i);
            store.append(fp, &synthetic_record(i)).unwrap();
        }
        next += 1;
    });
    report.push_stats("wal_append_500", &append_stats);
    report.push(
        "wal_append.records_per_sec",
        throughput(&append_stats, N as usize),
    );

    // Replay (open) throughput over everything appended above.
    let total_lines = store.lines();
    drop(store);
    let open_stats = bench("store/wal_replay_open", 1, 5, || {
        black_box(Store::open(&dir).unwrap());
    });
    report.push_stats("wal_replay_open", &open_stats);
    report.push(
        "wal_replay.lines_per_sec",
        throughput(&open_stats, total_lines),
    );
    std::fs::remove_dir_all(&dir).unwrap();

    // Fingerprint hashing rate on the biggest paper geometry.
    let bench_def = benchmark_by_name("mult_i8").unwrap();
    let nl = bench_def.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    let cfg = SearchConfig::default();
    let fp_stats = bench("store/fingerprint_mult_i8_x1000", 2, 10, || {
        for et in 0..1000u64 {
            black_box(job_fingerprint(
                nl.n_inputs(),
                nl.n_outputs(),
                &exact,
                Method::Shared,
                et,
                &cfg,
            ));
        }
    });
    report.push_stats("fingerprint_x1000", &fp_stats);
    report.push("fingerprint.per_sec", throughput(&fp_stats, 1000));

    // The system number: cold sweep (every job a SAT search) vs resumed
    // sweep (every job a store hit) on the same grid.
    let plan = SweepPlan {
        benches: vec![benchmark_by_name("adder_i4").unwrap()],
        methods: vec![Method::Shared, Method::Xpat, Method::Muscat],
        ets: Some(vec![1, 2]),
        search: SearchConfig {
            pool: 6,
            solutions_per_cell: 2,
            max_sat_cells: 2,
            conflict_budget: Some(50_000),
            time_budget_ms: 30_000,
            ..Default::default()
        },
        workers: 2,
    };
    let dir = tmp_dir("sweep");
    let store = Store::open(&dir).unwrap();
    let cold_stats = bench("store/sweep_cold", 0, 1, || {
        let recs = run_sweep_stored(&plan, Some(&store));
        assert!(recs.iter().all(|r| !r.cached));
    });
    let resumed_stats = bench("store/sweep_resumed", 0, 3, || {
        let recs = run_sweep_stored(&plan, Some(&store));
        assert!(recs.iter().all(|r| r.cached), "warm store must serve 100%");
    });
    report.push_stats("sweep_cold", &cold_stats);
    report.push_stats("sweep_resumed", &resumed_stats);
    report.push(
        "sweep_resumed.speedup_over_cold",
        cold_stats.mean_ms / resumed_stats.mean_ms,
    );
    std::fs::remove_dir_all(&dir).unwrap();

    report.write("store");
}

//! Serving-layer throughput: closed-loop requests/sec vs worker count
//! and batch size over real localhost TCP, plus server-side batch
//! occupancy and per-tier latency percentiles. The (4,16)
//! configuration runs twice — compiled kernels vs `--scalar-path` —
//! and the report carries their end-to-end ratio
//! (`compiled_over_scalar_rps`). Written to `BENCH_serve.json`.
//!
//!     cargo bench --bench serve

use std::path::PathBuf;
use std::sync::Arc;

use sxpat::bench_support::JsonReport;
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::coordinator::{run_sweep_stored, Method, SweepPlan};
use sxpat::search::SearchConfig;
use sxpat::serve::{
    parse_tiers, run_loadgen, serving_mlp, LoadgenConfig, Registry, ServeConfig, Server,
    DEFAULT_TIERS,
};
use sxpat::store::Store;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("sxpat_serve_bench_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn main() {
    let mut report = JsonReport::new();

    // One store of sound mult_i8 operators feeds every configuration.
    let dir = tmp_dir("store");
    {
        let plan = SweepPlan {
            benches: vec![benchmark_by_name("mult_i8").unwrap()],
            methods: vec![Method::Muscat],
            ets: Some(vec![4, 8, 16]),
            search: SearchConfig::default(),
            workers: 2,
        };
        let store = Store::open(&dir).unwrap();
        run_sweep_stored(&plan, Some(&store));
    }
    let mlp = Arc::new(serving_mlp());
    let tier_names: Vec<String> =
        parse_tiers(DEFAULT_TIERS).unwrap().into_iter().map(|t| t.name).collect();

    // The grid: worker count x batch size, fixed closed-loop load; the
    // final configuration also runs with kernels disabled so the
    // report quantifies the compiled path end to end at equal shape.
    const CLIENTS: usize = 8;
    const REQUESTS: usize = 250;
    let mut compiled_rps = 0.0f64;
    let mut scalar_rps = 0.0f64;
    for (workers, batch, kernels) in [
        (1usize, 1usize, true),
        (1, 8, true),
        (2, 8, true),
        (4, 16, true),
        (4, 16, false),
    ] {
        let key = if kernels {
            format!("serve_w{workers}_b{batch}")
        } else {
            format!("serve_w{workers}_b{batch}_scalar")
        };
        let registry = Registry::open(
            "mult_i8",
            parse_tiers(DEFAULT_TIERS).unwrap(),
            Some(dir.as_path()),
            mlp.clone(),
            kernels,
        )
        .unwrap();
        let server = Server::start(
            &ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                workers,
                batch,
                batch_wait_ms: 1,
                queue_cap: 4096,
                ..ServeConfig::default()
            },
            registry,
        )
        .unwrap();

        let stats = run_loadgen(&LoadgenConfig {
            addr: server.addr().to_string(),
            clients: CLIENTS,
            requests_per_client: REQUESTS,
            tiers: tier_names.clone(),
            seed: 42,
            ..LoadgenConfig::default()
        })
        .unwrap();
        assert_eq!(stats.errors, 0, "{key}: load must serve clean");
        println!(
            "bench serve/{key:<16} {:>8.0} req/s (p50 {} µs, p99 {} µs, n={})",
            stats.rps, stats.p50_us, stats.p99_us, stats.sent
        );
        report.push(&format!("{key}.requests_per_sec"), stats.rps);
        report.push(&format!("{key}.p50_us"), stats.p50_us as f64);
        report.push(&format!("{key}.p99_us"), stats.p99_us as f64);
        if workers == 4 && batch == 16 {
            if kernels {
                compiled_rps = stats.rps;
            } else {
                scalar_rps = stats.rps;
            }
        }

        server.shutdown();
        let server_metrics = server.join();
        // Fold the server-side view (batch occupancy, per-tier counts)
        // into the suite under this configuration's prefix.
        for (k, v) in server_metrics.entries() {
            if k == "mean_batch_occupancy"
                || k == "max_batch_occupancy"
                || k == "batches"
            {
                report.push(&format!("{key}.{k}"), *v);
            }
        }
    }

    if scalar_rps > 0.0 {
        let ratio = compiled_rps / scalar_rps;
        println!("bench serve/compiled_over_scalar_rps {ratio:>8.2}x");
        report.push("compiled_over_scalar_rps", ratio);
    }

    std::fs::remove_dir_all(&dir).unwrap();
    report.write("serve");
}

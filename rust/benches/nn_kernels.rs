//! Kernel-level inference throughput: compiled branchless batch
//! kernels (`nn::kernel::CompiledMlp`) vs the scalar
//! `QuantMlp::classify_batch` oracle, on the canonical serving model
//! over exact and approximate LUTs, plus a batch-size sweep. Parity is
//! asserted before anything is timed — a fast wrong kernel must fail
//! the bench, not set a record. Written to `BENCH_kernel.json`.
//!
//!     cargo bench --bench nn_kernels

use sxpat::bench_support::{bench, black_box, throughput, JsonReport};
use sxpat::nn::{synthetic_digits, CompiledMlp, MultLut, LANES};
use sxpat::serve::serving_mlp;

/// Exact products with the low `bits` output bits cleared — the same
/// sound approximation family the serve bench's store is built from.
fn masked_lut(bits: u32) -> MultLut {
    let mask = !((1u64 << bits) - 1);
    let vals: Vec<u64> = (0..256u64).map(|x| ((x & 15) * (x >> 4)) & mask).collect();
    MultLut::from_values(&vals)
}

fn main() {
    let mut report = JsonReport::new();
    let mlp = serving_mlp();
    let data = synthetic_digits(2048, 99);
    let images: Vec<&[u8]> = data.iter().map(|s| s.pixels.as_slice()).collect();

    for (tag, lut) in [("exact", MultLut::exact()), ("masked2", masked_lut(2))] {
        let kernel = CompiledMlp::compile(&mlp, &lut);
        assert_eq!(
            kernel.classify_batch(&images),
            mlp.classify_batch(&images, &lut),
            "{tag}: compiled kernel must be byte-identical before it is timed"
        );

        let scalar = bench(&format!("kernel/{tag}_scalar_batch2048"), 1, 10, || {
            black_box(mlp.classify_batch(black_box(&images), &lut));
        });
        let compiled = bench(&format!("kernel/{tag}_compiled_batch2048"), 1, 10, || {
            black_box(kernel.classify_batch(black_box(&images)));
        });
        let scalar_ips = throughput(&scalar, images.len());
        let compiled_ips = throughput(&compiled, images.len());
        let speedup = compiled_ips / scalar_ips;
        println!(
            "  {tag}: scalar {scalar_ips:>10.0} img/s, compiled {compiled_ips:>10.0} img/s \
             ({speedup:.2}x)"
        );
        report.push_stats(&format!("{tag}_scalar"), &scalar);
        report.push_stats(&format!("{tag}_compiled"), &compiled);
        report.push(&format!("{tag}_scalar.images_per_sec"), scalar_ips);
        report.push(&format!("{tag}_compiled.images_per_sec"), compiled_ips);
        report.push(&format!("{tag}.compiled_over_scalar"), speedup);
    }

    // Batch-size sweep on the exact LUT: where does lane blocking start
    // paying? (Serving micro-batches live at the small end.)
    let kernel = CompiledMlp::compile(&mlp, &MultLut::exact());
    for n in [1usize, LANES - 1, LANES, 4 * LANES, 512] {
        let slice = &images[..n];
        let stats = bench(&format!("kernel/exact_compiled_batch{n}"), 2, 20, || {
            black_box(kernel.classify_batch(black_box(slice)));
        });
        report.push(
            &format!("exact_compiled_batch{n}.images_per_sec"),
            throughput(&stats, n),
        );
    }

    report.write("kernel");
}

//! Ablations over the design choices DESIGN.md calls out:
//!  (a) shared vs. nonshared encoding size (vars/clauses in the miter),
//!  (b) totalizer vs. naive pairwise cardinality,
//!  (c) ∀-expansion cost as n grows,
//!  (d) proxy-ordered lattice vs. naive row-major order (cells tried
//!      until the first SAT answer),
//!  (e) lattice-scan worker scaling (cumulative single-worker vs the
//!      canonical parallel scan).
//!
//!     cargo bench --bench ablations

use sxpat::bench_support::bench;
use sxpat::circuit::generators::benchmark_by_name;
use sxpat::circuit::sim::TruthTables;
use sxpat::sat::Lit;
use sxpat::search::lattice::shared_cells;
use sxpat::smt::cardinality::at_most_k;
use sxpat::smt::cnf::CnfBuilder;
use sxpat::template::{NonsharedMiter, SharedMiter};

fn naive_at_most_k(b: &mut CnfBuilder, xs: &[Lit], k: usize) {
    // Forbid every (k+1)-subset — exponential, fine for tiny k.
    fn rec(b: &mut CnfBuilder, xs: &[Lit], k: usize, start: usize,
           cur: &mut Vec<Lit>) {
        if cur.len() == k + 1 {
            let clause: Vec<Lit> = cur.iter().map(|&l| !l).collect();
            b.add_clause(&clause);
            return;
        }
        for i in start..xs.len() {
            cur.push(xs[i]);
            rec(b, xs, k, i + 1, cur);
            cur.pop();
        }
    }
    rec(b, xs, k, 0, &mut Vec::new());
}

fn main() {
    // (a) encoding size: shared pool T vs. nonshared m*K products.
    for name in ["adder_i4", "mult_i4", "adder_i6"] {
        let b = benchmark_by_name(name).unwrap();
        let nl = b.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let (n, m) = (nl.n_inputs(), nl.n_outputs());
        let sh = SharedMiter::build(n, m, 8, &exact, b.fig4_et());
        let ns = NonsharedMiter::build(n, m, 8, &exact, b.fig4_et());
        println!(
            "ablation(a) {name}: shared miter {} vars / {} clauses, nonshared {} vars / {} clauses",
            sh.b.solver.n_vars(),
            sh.b.solver.n_clauses(),
            ns.b.solver.n_vars(),
            ns.b.solver.n_clauses()
        );
    }

    // (b) totalizer vs naive pairwise cardinality encoding size + time.
    for (n, k) in [(16usize, 4usize), (24, 3), (32, 2)] {
        let mut tot_clauses = 0;
        bench(&format!("ablation_b/totalizer_n{n}_k{k}"), 1, 5, || {
            let mut b = CnfBuilder::new();
            let xs: Vec<Lit> = (0..n).map(|_| b.new_lit()).collect();
            at_most_k(&mut b, &xs, k);
            tot_clauses = b.solver.n_clauses();
        });
        let mut naive_clauses = 0;
        bench(&format!("ablation_b/naive_n{n}_k{k}"), 1, 5, || {
            let mut b = CnfBuilder::new();
            let xs: Vec<Lit> = (0..n).map(|_| b.new_lit()).collect();
            naive_at_most_k(&mut b, &xs, k);
            naive_clauses = b.solver.n_clauses();
        });
        println!("  clauses: totalizer {tot_clauses} vs naive {naive_clauses}");
    }

    // (c) ∀-expansion growth: miter size vs input count.
    println!("ablation(c) ∀-expansion growth (shared miter, T=8):");
    for name in ["adder_i4", "adder_i6", "adder_i8"] {
        let b = benchmark_by_name(name).unwrap();
        let nl = b.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let (n, m) = (nl.n_inputs(), nl.n_outputs());
        let stats = bench(&format!("ablation_c/build_{name}"), 0, 2, || {
            let _ = SharedMiter::build(n, m, 8, &exact, b.fig4_et());
        });
        let sh = SharedMiter::build(n, m, 8, &exact, b.fig4_et());
        println!(
            "  n={n}: {} vars, {} clauses, build {:.1} ms",
            sh.b.solver.n_vars(),
            sh.b.solver.n_clauses(),
            stats.mean_ms
        );
    }

    // (d) lattice order: proxy-estimate order vs row-major until first SAT.
    for name in ["adder_i4", "mult_i4"] {
        let b = benchmark_by_name(name).unwrap();
        let nl = b.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let (n, m) = (nl.n_inputs(), nl.n_outputs());
        let et = b.fig4_et();
        let ordered = shared_cells(8, m);
        let mut row_major: Vec<(usize, usize)> = Vec::new();
        for pit in 0..=8usize {
            for its in pit..=(m * pit.max(1)) {
                row_major.push((pit, its));
            }
        }
        let count_until_sat = |cells: Vec<(usize, usize)>| {
            let mut miter = SharedMiter::build(n, m, 8, &exact, et);
            let mut tried = 0usize;
            let mut area = f64::NAN;
            for (pit, its) in cells {
                tried += 1;
                if let Some(sol) = miter.solve(pit, its).sat() {
                    area = sxpat::synth::synthesize_area(&sol.to_netlist("x"));
                    break;
                }
            }
            (tried, area)
        };
        let (t1, a1) =
            count_until_sat(ordered.iter().map(|c| (c.a, c.b)).collect());
        let (t2, a2) = count_until_sat(row_major);
        println!(
            "ablation(d) {name}: proxy order {t1} cells -> area {a1:.3}; \
             row-major {t2} cells -> area {a2:.3}"
        );
    }

    // (e) lattice-scan worker scaling on the heaviest i4 job.
    {
        use sxpat::search::{search_shared, SearchConfig};
        let b = benchmark_by_name("mult_i4").unwrap();
        let nl = b.netlist();
        let et = b.fig4_et();
        for cell_workers in [1usize, 2, 4] {
            let cfg = SearchConfig {
                pool: 8,
                solutions_per_cell: 1,
                max_sat_cells: 4,
                conflict_budget: Some(150_000),
                time_budget_ms: 60_000,
                cell_workers,
                ..Default::default()
            };
            let mut area = f64::NAN;
            bench(&format!("ablation_e/cell_workers_{cell_workers}"), 1, 3, || {
                area = search_shared(&nl, et, &cfg)
                    .best()
                    .map(|s| s.area)
                    .unwrap_or(f64::NAN);
            });
            println!("  cell_workers={cell_workers}: best area {area:.3}");
        }
    }
}

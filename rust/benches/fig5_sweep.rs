//! Fig. 5 regeneration: best area per method across the ET sweep for the
//! paper's six benchmarks, on the parallel coordinator. i8 multiplier
//! search cells are the heavy tail; the per-cell conflict budget bounds
//! the wall time the same way the paper's 3 h timeout does.
//!
//!     cargo bench --bench fig5_sweep
//!     SXPAT_FULL=1 cargo bench --bench fig5_sweep   # include i8 grid

use sxpat::bench_support::bench;
use sxpat::circuit::generators::{benchmark_by_name, PAPER_BENCHMARKS};
use sxpat::coordinator::{run_job, run_sweep, Job, Method, SweepPlan};
use sxpat::report::{fig5_csv, fig5_markdown};
use sxpat::search::SearchConfig;

fn main() {
    let full = std::env::var("SXPAT_FULL").is_ok();
    let benches: Vec<_> = if full {
        PAPER_BENCHMARKS.iter().collect()
    } else {
        ["adder_i4", "mult_i4", "adder_i6", "mult_i6"]
            .iter()
            .map(|n| benchmark_by_name(n).unwrap())
            .collect()
    };
    let plan = SweepPlan {
        benches,
        methods: Method::all_compared().to_vec(),
        ets: None,
        search: SearchConfig {
            pool: 8,
            solutions_per_cell: 2,
            max_sat_cells: 2,
            conflict_budget: Some(if full { 400_000 } else { 80_000 }),
            time_budget_ms: if full { 120_000 } else { 30_000 },
            ..Default::default()
        },
        workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };

    let mut records = Vec::new();
    bench("fig5/sweep", 0, 1, || {
        records = run_sweep(&plan);
    });
    println!("{}", fig5_markdown(&records));

    // Who wins per (bench, et) — the figure's qualitative content.
    let mut wins = std::collections::BTreeMap::<&str, usize>::new();
    let mut cells = 0usize;
    let mut keys: Vec<(&str, u64)> =
        records.iter().map(|r| (r.bench, r.et)).collect();
    keys.sort_unstable();
    keys.dedup();
    for (bench_name, et) in keys {
        let best = records
            .iter()
            .filter(|r| r.bench == bench_name && r.et == et && r.area.is_finite())
            .min_by(|a, b| a.area.partial_cmp(&b.area).unwrap());
        if let Some(b) = best {
            *wins.entry(b.method.name()).or_default() += 1;
            cells += 1;
        }
    }
    println!("wins per method over {cells} (bench, ET) cells: {wins:?}");
    println!("(paper: SHARED yields the best approximation for most ET values)");
    let csv = fig5_csv(&records);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig5_bench.csv", &csv).ok();
    println!("wrote results/fig5_bench.csv ({} rows)", csv.lines().count());

    // Intra-job parallelism: sequential vs parallel lattice scan on one
    // SHARED mult_i4 job (the acceptance bar: the parallel scan must not
    // be slower, and its best area must match the sequential scan).
    let mult = benchmark_by_name("mult_i4").unwrap();
    let cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut area_by_workers = Vec::new();
    for cell_workers in [1usize, cores.max(2)] {
        let search = SearchConfig {
            pool: 8,
            solutions_per_cell: 1,
            max_sat_cells: 4,
            conflict_budget: Some(200_000),
            time_budget_ms: 60_000,
            cell_workers,
            ..Default::default()
        };
        let mut area = f64::NAN;
        bench(&format!("fig5/cell_scan_mult_i4_w{cell_workers}"), 1, 3, || {
            let rec = run_job(&Job {
                bench: mult,
                method: Method::Shared,
                et: mult.fig4_et(),
                search: search.clone(),
            });
            area = rec.area;
        });
        area_by_workers.push((cell_workers, area));
    }
    for (w, area) in &area_by_workers {
        println!("cell scan mult_i4, {w} worker(s): best area {area:.3}");
    }
}

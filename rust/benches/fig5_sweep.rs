//! Fig. 5 regeneration: best area per method across the ET sweep for the
//! paper's six benchmarks, on the parallel coordinator. i8 multiplier
//! search cells are the heavy tail; the per-cell conflict budget bounds
//! the wall time the same way the paper's 3 h timeout does.
//!
//! Also the engine perf tracker: cell-worker scaling (cells/sec per
//! worker count) and the prototype-clone vs fresh-build per-cell cost on
//! the sweep geometries, written to `BENCH_engine.json`.
//!
//!     cargo bench --bench fig5_sweep
//!     SXPAT_FULL=1 cargo bench --bench fig5_sweep   # include i8 grid

use sxpat::bench_support::{bench, bench_clone_vs_build, JsonReport};
use sxpat::circuit::generators::{benchmark_by_name, PAPER_BENCHMARKS};
use sxpat::circuit::sim::TruthTables;
use sxpat::coordinator::{run_job, run_sweep, Job, Method, SweepPlan};
use sxpat::report::{fig5_csv, fig5_markdown};
use sxpat::search::{search_shared, SearchConfig};
use sxpat::template::SharedMiter;

fn main() {
    let mut report = JsonReport::new();
    let full = std::env::var("SXPAT_FULL").is_ok();
    let benches: Vec<_> = if full {
        PAPER_BENCHMARKS.iter().collect()
    } else {
        ["adder_i4", "mult_i4", "adder_i6", "mult_i6"]
            .iter()
            .map(|n| benchmark_by_name(n).unwrap())
            .collect()
    };
    let plan = SweepPlan {
        benches,
        methods: Method::all_compared().to_vec(),
        ets: None,
        search: SearchConfig {
            pool: 8,
            solutions_per_cell: 2,
            max_sat_cells: 2,
            conflict_budget: Some(if full { 400_000 } else { 80_000 }),
            time_budget_ms: if full { 120_000 } else { 30_000 },
            ..Default::default()
        },
        workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };

    let mut records = Vec::new();
    let sweep_stats = bench("fig5/sweep", 0, 1, || {
        records = run_sweep(&plan);
    });
    report.push_stats("sweep", &sweep_stats);
    report.push("sweep.jobs", records.len() as f64);
    println!("{}", fig5_markdown(&records));

    // Who wins per (bench, et) — the figure's qualitative content.
    let mut wins = std::collections::BTreeMap::<&str, usize>::new();
    let mut cells = 0usize;
    let mut keys: Vec<(&str, u64)> =
        records.iter().map(|r| (r.bench, r.et)).collect();
    keys.sort_unstable();
    keys.dedup();
    for (bench_name, et) in keys {
        let best = records
            .iter()
            .filter(|r| r.bench == bench_name && r.et == et && r.area.is_finite())
            .min_by(|a, b| a.area.partial_cmp(&b.area).unwrap());
        if let Some(b) = best {
            *wins.entry(b.method.name()).or_default() += 1;
            cells += 1;
        }
    }
    println!("wins per method over {cells} (bench, ET) cells: {wins:?}");
    println!("(paper: SHARED yields the best approximation for most ET values)");
    let csv = fig5_csv(&records);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig5_bench.csv", &csv).ok();
    println!("wrote results/fig5_bench.csv ({} rows)", csv.lines().count());

    // Prototype clone vs fresh build on the sweep geometries: the
    // canonical scan pays one clone per cell where it used to pay a full
    // re-encode, so clone must be strictly cheaper than build. Recorded
    // in BENCH_engine.json so the perf trajectory is tracked.
    for (name, pool) in [("adder_i4", 8usize), ("mult_i4", 8), ("adder_i6", 8)] {
        let b = benchmark_by_name(name).unwrap();
        let nl = b.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let (n, m) = (nl.n_inputs(), nl.n_outputs());
        let et = b.fig4_et();
        bench_clone_vs_build(&mut report, "fig5", &format!("proto_{name}"), || {
            SharedMiter::build(n, m, pool, &exact, et)
        });
    }

    // Intra-job parallelism: sequential vs parallel lattice scan on one
    // SHARED mult_i4 job (the acceptance bar: the parallel scan must not
    // be slower, and its best area must match the sequential scan).
    let mult = benchmark_by_name("mult_i4").unwrap();
    let cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut area_by_workers = Vec::new();
    for cell_workers in [1usize, cores.max(2)] {
        let search = SearchConfig {
            pool: 8,
            solutions_per_cell: 1,
            max_sat_cells: 4,
            conflict_budget: Some(200_000),
            time_budget_ms: 60_000,
            cell_workers,
            ..Default::default()
        };
        let mut area = f64::NAN;
        let scan_stats =
            bench(&format!("fig5/cell_scan_mult_i4_w{cell_workers}"), 1, 3, || {
                let rec = run_job(&Job {
                    bench: mult,
                    method: Method::Shared,
                    et: mult.fig4_et(),
                    search: search.clone(),
                });
                area = rec.area;
            });
        // cells/sec needs the search telemetry, not the RunRecord — one
        // untimed run outside the bench loop.
        let out = search_shared(&mult.netlist(), mult.fig4_et(), &search);
        let cells_per_sec =
            out.cells_tried as f64 / (out.elapsed_ms.max(1) as f64 / 1e3);
        area_by_workers.push((cell_workers, area));
        report.push_stats(&format!("cell_scan_mult_i4_w{cell_workers}"), &scan_stats);
        report.push(
            &format!("cell_scan_mult_i4_w{cell_workers}.cells_per_sec"),
            cells_per_sec,
        );
        report.push(&format!("cell_scan_mult_i4_w{cell_workers}.best_area"), area);
    }
    for (w, area) in &area_by_workers {
        println!("cell scan mult_i4, {w} worker(s): best area {area:.3}");
    }
    report.write("engine");
}

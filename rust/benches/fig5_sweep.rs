//! Fig. 5 regeneration: best area per method across the ET sweep for the
//! paper's six benchmarks, on the parallel coordinator. i8 multiplier
//! search cells are the heavy tail; the per-cell conflict budget bounds
//! the wall time the same way the paper's 3 h timeout does.
//!
//!     cargo bench --bench fig5_sweep
//!     SXPAT_FULL=1 cargo bench --bench fig5_sweep   # include i8 grid

use sxpat::bench_support::bench;
use sxpat::circuit::generators::{benchmark_by_name, PAPER_BENCHMARKS};
use sxpat::coordinator::{run_sweep, Method, SweepPlan};
use sxpat::report::{fig5_csv, fig5_markdown};
use sxpat::search::SearchConfig;

fn main() {
    let full = std::env::var("SXPAT_FULL").is_ok();
    let benches: Vec<_> = if full {
        PAPER_BENCHMARKS.iter().collect()
    } else {
        ["adder_i4", "mult_i4", "adder_i6", "mult_i6"]
            .iter()
            .map(|n| benchmark_by_name(n).unwrap())
            .collect()
    };
    let plan = SweepPlan {
        benches,
        methods: Method::all_compared().to_vec(),
        ets: None,
        search: SearchConfig {
            pool: 8,
            solutions_per_cell: 2,
            max_sat_cells: 2,
            conflict_budget: Some(if full { 400_000 } else { 80_000 }),
            time_budget_ms: if full { 120_000 } else { 30_000 },
        },
        workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };

    let mut records = Vec::new();
    bench("fig5/sweep", 0, 1, || {
        records = run_sweep(&plan);
    });
    println!("{}", fig5_markdown(&records));

    // Who wins per (bench, et) — the figure's qualitative content.
    let mut wins = std::collections::BTreeMap::<&str, usize>::new();
    let mut cells = 0usize;
    let mut keys: Vec<(&str, u64)> =
        records.iter().map(|r| (r.bench, r.et)).collect();
    keys.sort_unstable();
    keys.dedup();
    for (bench_name, et) in keys {
        let best = records
            .iter()
            .filter(|r| r.bench == bench_name && r.et == et && r.area.is_finite())
            .min_by(|a, b| a.area.partial_cmp(&b.area).unwrap());
        if let Some(b) = best {
            *wins.entry(b.method.name()).or_default() += 1;
            cells += 1;
        }
    }
    println!("wins per method over {cells} (bench, ET) cells: {wins:?}");
    println!("(paper: SHARED yields the best approximation for most ET values)");
    let csv = fig5_csv(&records);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig5_bench.csv", &csv).ok();
    println!("wrote results/fig5_bench.csv ({} rows)", csv.lines().count());
}

//! MECALS-style baseline: greedy local rewrites, each verified by a
//! maximum-error check.
//!
//! Candidate rewrites on the optimised AIG: replace a node with a
//! constant, with another existing node, or with its complement. Each
//! round evaluates all candidates, applies the one with the best sound
//! area reduction, and repeats until no candidate improves — the greedy
//! descent MECALS performs with its SAT-based max-error oracle (here the
//! exhaustive oracle, exact at these sizes; see baselines::mod).

use crate::aig::graph::{self, Aig, Lit};
use crate::aig::{aig_to_netlist, netlist_to_aig, optimize};
use crate::circuit::sim::error_stats;
use crate::circuit::Netlist;
use crate::synth::synthesize_area;

use super::BaselineResult;

/// Rebuild `aig` with AND node `target` (index) replaced by `repl`
/// (a literal over the *old* graph's variables).
fn substitute(aig: &Aig, target: usize, repl: Lit) -> Aig {
    let mut out = Aig::new(aig.n_inputs);
    let mut map: Vec<Lit> = vec![graph::FALSE; aig.n_vars()];
    for j in 0..aig.n_inputs {
        map[1 + j] = out.input(j);
    }
    let tr = |map: &[Lit], l: Lit| {
        let base = map[graph::var(l) as usize];
        if graph::is_compl(l) {
            graph::not(base)
        } else {
            base
        }
    };
    for (i, nd) in aig.ands.iter().enumerate() {
        let v = 1 + aig.n_inputs + i;
        if i == target {
            // Replacement literal must be over already-mapped variables
            // (enforced by the candidate generator: repl var < target var).
            map[v] = tr(&map, repl);
            continue;
        }
        let a = tr(&map, nd.0);
        let b = tr(&map, nd.1);
        map[v] = out.and(a, b);
    }
    out.outputs = aig.outputs.iter().map(|&l| tr(&map, l)).collect();
    out
}

/// One MECALS round: the best sound candidate, if any improves.
fn best_candidate(aig: &Aig, exact: &[u64], et: u64, cur_count: usize)
                  -> Option<(Aig, usize)> {
    let mut best: Option<(Aig, usize)> = None;
    let n_ands = aig.ands.len();
    // Candidate replacement literals per target: constants, earlier
    // nodes (both phases) and inputs. To keep rounds quadratic-not-cubic
    // we cap the per-target candidate list using truth-table proximity.
    let rows = aig.simulate_all();
    for target in 0..n_ands {
        let tvar = (1 + aig.n_inputs + target) as u32;
        let trow = &rows[tvar as usize];
        let mut cands: Vec<Lit> = vec![graph::FALSE, graph::TRUE];
        for v in 1..tvar {
            let vrow = &rows[v as usize];
            // Quick filter: only consider close functions (<= et bits of
            // difference is a heuristic, not a soundness condition —
            // soundness is checked below).
            let dist: u32 =
                trow.iter().zip(vrow).map(|(a, b)| (a ^ b).count_ones()).sum();
            let inv_dist: u32 = trow
                .iter()
                .zip(vrow)
                .map(|(a, b)| (a ^ !b).count_ones())
                .sum();
            if dist <= 4 + et as u32 * 4 {
                cands.push(graph::lit(v, false));
            }
            if inv_dist <= 4 + et as u32 * 4 {
                cands.push(graph::lit(v, true));
            }
        }
        for repl in cands {
            let candidate = substitute(aig, target, repl);
            let reduced = optimize(&candidate);
            let count = reduced.live_and_count();
            if count >= cur_count {
                continue;
            }
            let (mx, _) = error_stats(exact, &reduced.output_values());
            if mx > et {
                continue;
            }
            match &best {
                Some((_, c)) if *c <= count => {}
                _ => best = Some((reduced, count)),
            }
        }
    }
    best
}

/// Run the MECALS-style greedy descent.
pub fn mecals(nl: &Netlist, et: u64) -> BaselineResult {
    let mut aig = optimize(&netlist_to_aig(nl));
    let exact = aig.output_values();
    let mut applied = 0usize;
    let mut count = aig.live_and_count();
    loop {
        match best_candidate(&aig, &exact, et, count) {
            Some((next, c)) => {
                aig = next;
                count = c;
                applied += 1;
            }
            None => break,
        }
    }
    let vals = aig.output_values();
    let (max_err, mean_err) = error_stats(&exact, &vals);
    debug_assert!(max_err <= et);
    let netlist = aig_to_netlist(&aig, &format!("{}_mecals", nl.name));
    let area = synthesize_area(&netlist);
    BaselineResult { netlist, area, max_err, mean_err, applied }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::{adder, multiplier};
    use crate::circuit::sim::TruthTables;

    #[test]
    fn mecals_is_sound() {
        for (nl, et) in [(adder(2), 1u64), (adder(2), 2), (multiplier(2), 2)] {
            let res = mecals(&nl, et);
            assert!(res.max_err <= et, "{}: {} > {et}", nl.name, res.max_err);
            let tt = TruthTables::simulate(&res.netlist);
            let exact = TruthTables::simulate(&nl).output_values(&nl);
            let (mx, _) = error_stats(&exact, &tt.output_values(&res.netlist));
            assert!(mx <= et);
        }
    }

    #[test]
    fn mecals_et_zero_is_exact() {
        let nl = adder(2);
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let res = mecals(&nl, 0);
        let tt = TruthTables::simulate(&res.netlist);
        assert_eq!(tt.output_values(&res.netlist), exact);
    }

    #[test]
    fn mecals_reduces_area_with_slack() {
        let nl = multiplier(2);
        let exact_area = synthesize_area(&nl);
        let res = mecals(&nl, 4);
        assert!(res.area < exact_area, "area {} !< {exact_area}", res.area);
        assert!(res.applied > 0);
    }

    #[test]
    fn substitution_replaces_function() {
        // Replace the single AND of and2 with TRUE: outputs become 1.
        let mut nl = crate::circuit::Netlist::new("and2");
        let a = nl.add_input();
        let b = nl.add_input();
        let g = nl.push(crate::circuit::GateKind::And, vec![a, b]);
        nl.set_outputs(vec![g]);
        let aig = netlist_to_aig(&nl);
        let sub = substitute(&aig, 0, graph::TRUE);
        assert_eq!(sub.output_values(), vec![1, 1, 1, 1]);
    }
}

//! The comparison methods of §IV: MUSCAT (MUS-guided gate
//! constantisation, DATE'22), MECALS (maximum-error-checked local
//! rewrites, DATE'23) and the 1000-random-sound-approximations baseline
//! that anchors Fig. 4.
//!
//! Both published baselines verify candidate approximations with a
//! maximum-error check. At the paper's benchmark sizes (<= 8 inputs) the
//! exhaustive bit-parallel check is exact and orders of magnitude faster
//! than a SAT query, so it is the default engine; a SAT-based check kept
//! in `muscat::sat_check` is differential-tested against it (DESIGN.md §2).

pub mod mecals;
pub mod muscat;
pub mod random_sound;

pub use mecals::mecals;
pub use muscat::muscat;
pub use random_sound::{random_sound_baseline, RandomPoint};

/// Result shape shared by the baseline methods.
#[derive(Debug, Clone)]
pub struct BaselineResult {
    pub netlist: crate::circuit::Netlist,
    pub area: f64,
    pub max_err: u64,
    pub mean_err: f64,
    /// Method-specific knob count (applied candidates / rewrites).
    pub applied: usize,
}

//! The Fig. 4 red-circle baseline: 1000 random approximations, each
//! sound w.r.t. the ET, with their proxy values and synthesised areas.
//!
//! Candidates are drawn from the SHARED template's parameter space at
//! mixed densities and screened for soundness. Screening runs through
//! the batch evaluator abstraction so the PJRT artifact (L1 Pallas
//! kernel) does the bulk evaluation when available, with the rust
//! bit-parallel engine as fallback — identical semantics either way
//! (differential-tested in rust/tests/integration_runtime.rs).

use crate::circuit::sim::TruthTables;
use crate::circuit::Netlist;
use crate::evaluator::rust_eval::evaluate_batch;
use crate::evaluator::EvalResult;
use crate::synth::synthesize_area;
use crate::template::SopParams;
use crate::util::Rng;

/// One random sound approximation with its Fig. 4 coordinates.
#[derive(Debug, Clone)]
pub struct RandomPoint {
    pub pit: usize,
    pub its: usize,
    pub area: f64,
    pub max_err: u64,
    pub mean_err: f64,
}

/// Batch-evaluation engine hook (lets the coordinator inject the PJRT
/// runtime without this module depending on it).
pub type BatchEval<'a> = dyn Fn(&[SopParams], &[u64]) -> Vec<EvalResult> + 'a;

/// Generate `target` random sound approximations (or give up after
/// `max_draws` candidates). Returns points sorted by area.
pub fn random_sound_baseline(
    nl: &Netlist,
    et: u64,
    target: usize,
    pool: usize,
    seed: u64,
    eval: Option<&BatchEval>,
) -> Vec<RandomPoint> {
    let (n, m) = (nl.n_inputs(), nl.n_outputs());
    let exact = TruthTables::simulate(nl).output_values(nl);
    let mut rng = Rng::seed_from(seed);
    let mut points = Vec::with_capacity(target);
    let max_draws = target * 4000;
    let mut drawn = 0usize;
    let chunk = 256usize;

    while points.len() < target && drawn < max_draws {
        // Mixed densities: sparse instantiations are far likelier to be
        // sound at small ET, dense ones populate the upper proxy range.
        let batch: Vec<SopParams> = (0..chunk)
            .map(|_| {
                let lit_d = 0.15 + 0.5 * rng.f64();
                let sel_d = 0.05 + 0.4 * rng.f64();
                SopParams::random(&mut rng, n, m, pool, lit_d, sel_d)
            })
            .collect();
        drawn += chunk;
        let results = match eval {
            Some(f) => f(&batch, &exact),
            None => evaluate_batch(&batch, &exact),
        };
        for (p, r) in batch.iter().zip(&results) {
            if r.max_err <= et && points.len() < target {
                points.push(RandomPoint {
                    pit: p.pit(),
                    its: p.its(),
                    area: synthesize_area(&p.to_netlist("rand")),
                    max_err: r.max_err,
                    mean_err: r.mean_err,
                });
            }
        }
    }
    points.sort_by(|a, b| a.area.partial_cmp(&b.area).unwrap());
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::{adder, multiplier};

    #[test]
    fn generates_requested_count_for_adder_i4() {
        let nl = adder(2);
        let pts = random_sound_baseline(&nl, 2, 50, 8, 42, None);
        assert_eq!(pts.len(), 50);
        for p in &pts {
            assert!(p.max_err <= 2);
            assert!(p.pit <= 8);
            assert!(p.its <= 3 * 8);
        }
        // Sorted by area.
        for w in pts.windows(2) {
            assert!(w[0].area <= w[1].area);
        }
    }

    #[test]
    fn tighter_et_means_fewer_or_smaller() {
        // With ET=0 random soundness is rare; the generator must still
        // terminate (possibly short) and all returned points are exact.
        let nl = multiplier(2);
        let pts = random_sound_baseline(&nl, 0, 5, 6, 7, None);
        for p in &pts {
            assert_eq!(p.max_err, 0);
        }
    }

    #[test]
    fn custom_eval_hook_is_used() {
        let nl = adder(2);
        let mut called = false;
        {
            let hook: &BatchEval = &|batch, exact| {
                crate::evaluator::rust_eval::evaluate_batch(batch, exact)
            };
            let pts = random_sound_baseline(&nl, 2, 10, 6, 1, Some(hook));
            assert_eq!(pts.len(), 10);
            called = true;
        }
        assert!(called);
    }
}

//! MUSCAT-style baseline: approximate by forcing internal gates to
//! constants, keeping the set of applied "approximation candidates"
//! maximal subject to the ET bound.
//!
//! MUSCAT inserts candidate constantisations, asks a solver whether the
//! error bound can be violated, and uses minimal unsatisfiable subsets to
//! prune candidates. Our engine keeps the same outer loop — candidates
//! ordered by estimated saving, each tentatively applied and kept only if
//! the max-error check still passes — but the check itself is the
//! exhaustive bit-parallel oracle, which is exact at these sizes. A
//! SAT-encoded check ([`sat_check`]) is retained and differential-tested.

use crate::aig::graph::{self, Aig, Lit};
use crate::aig::{aig_to_netlist, netlist_to_aig, optimize};
use crate::circuit::sim::error_stats;
use crate::circuit::Netlist;
use crate::smt::cnf::CnfBuilder;
use crate::synth::synthesize_area;

use super::BaselineResult;

/// Candidate action: force AND node (by index) to a constant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub and_index: usize,
    pub value: bool,
}

/// Output values of `aig` when the given AND nodes are replaced by
/// constants (map from and-index to value).
fn values_with_consts(aig: &Aig, subst: &[(usize, bool)]) -> Vec<u64> {
    let n = aig.n_inputs;
    let words = (1usize << n).div_ceil(64);
    let mask = if n < 6 { (1u64 << (1usize << n)) - 1 } else { !0 };
    let mut rows: Vec<Vec<u64>> = Vec::with_capacity(aig.n_vars());
    rows.push(vec![0u64; words]);
    for j in 0..n {
        rows.push(crate::circuit::sim::input_pattern(j, n, words));
    }
    for (i, nd) in aig.ands.iter().enumerate() {
        if let Some(&(_, v)) = subst.iter().find(|&&(idx, _)| idx == i) {
            rows.push(vec![if v { mask } else { 0 }; words]);
            continue;
        }
        let mut row = vec![0u64; words];
        for w in 0..words {
            let a = rows[graph::var(nd.0) as usize][w]
                ^ if graph::is_compl(nd.0) { !0 } else { 0 };
            let b = rows[graph::var(nd.1) as usize][w]
                ^ if graph::is_compl(nd.1) { !0 } else { 0 };
            row[w] = (a & b) & mask;
        }
        rows.push(row);
    }
    (0..1usize << n)
        .map(|x| {
            aig.outputs.iter().enumerate().fold(0u64, |acc, (i, &l)| {
                let bit = ((rows[graph::var(l) as usize][x / 64] >> (x % 64)) & 1)
                    ^ graph::is_compl(l) as u64;
                acc | (bit << i)
            })
        })
        .collect()
}

/// Build the approximate AIG with the substitutions applied, re-hash and
/// sweep (constant propagation does the actual gate removal).
fn apply_substitutions(aig: &Aig, subst: &[(usize, bool)]) -> Aig {
    let mut out = Aig::new(aig.n_inputs);
    let mut map: Vec<Lit> = vec![graph::FALSE; aig.n_vars()];
    for j in 0..aig.n_inputs {
        map[1 + j] = out.input(j);
    }
    for (i, nd) in aig.ands.iter().enumerate() {
        let v = 1 + aig.n_inputs + i;
        if let Some(&(_, val)) = subst.iter().find(|&&(idx, _)| idx == i) {
            map[v] = if val { graph::TRUE } else { graph::FALSE };
            continue;
        }
        let tr = |l: Lit| {
            let base = map[graph::var(l) as usize];
            if graph::is_compl(l) {
                graph::not(base)
            } else {
                base
            }
        };
        map[v] = out.and(tr(nd.0), tr(nd.1));
    }
    out.outputs = aig
        .outputs
        .iter()
        .map(|&l| {
            let base = map[graph::var(l) as usize];
            if graph::is_compl(l) {
                graph::not(base)
            } else {
                base
            }
        })
        .collect();
    out
}

/// Run the MUSCAT-style search. Candidates are visited in descending
/// estimated saving (fanout-weighted cone size) and greedily retained.
pub fn muscat(nl: &Netlist, et: u64) -> BaselineResult {
    let aig = optimize(&netlist_to_aig(nl));
    let exact = aig.output_values();

    // Estimated saving per node: number of AND nodes in its fanin cone
    // (shared nodes counted once per candidate — an upper bound).
    let mut cone = vec![0usize; aig.ands.len()];
    for i in 0..aig.ands.len() {
        let mut seen = std::collections::HashSet::new();
        let mut stack = vec![1 + aig.n_inputs + i];
        while let Some(v) = stack.pop() {
            if let Some(idx) = aig.and_index(v as u32) {
                if seen.insert(idx) {
                    stack.push(graph::var(aig.ands[idx].0) as usize);
                    stack.push(graph::var(aig.ands[idx].1) as usize);
                }
            }
        }
        cone[i] = seen.len();
    }

    let mut order: Vec<Candidate> = (0..aig.ands.len())
        .flat_map(|i| {
            [Candidate { and_index: i, value: false },
             Candidate { and_index: i, value: true }]
        })
        .collect();
    order.sort_by_key(|c| std::cmp::Reverse(cone[c.and_index]));

    let mut applied: Vec<(usize, bool)> = Vec::new();
    for cand in order {
        if applied.iter().any(|&(i, _)| i == cand.and_index) {
            continue;
        }
        applied.push((cand.and_index, cand.value));
        let vals = values_with_consts(&aig, &applied);
        let (mx, _) = error_stats(&exact, &vals);
        if mx > et {
            applied.pop();
        }
    }

    let approx = optimize(&apply_substitutions(&aig, &applied));
    let vals = approx.output_values();
    let (max_err, mean_err) = error_stats(&exact, &vals);
    debug_assert!(max_err <= et);
    let netlist = aig_to_netlist(&approx, &format!("{}_muscat", nl.name));
    let area = synthesize_area(&netlist);
    BaselineResult { netlist, area, max_err, mean_err, applied: applied.len() }
}

/// SAT-encoded max-error check for a substitution set: UNSAT iff the
/// approximation is sound w.r.t. `et`. Differential-tested against the
/// exhaustive engine; kept as the faithful MUSCAT machinery.
pub fn sat_check(aig: &Aig, subst: &[(usize, bool)], exact: &[u64], et: u64) -> bool {
    use crate::sat::SatResult;
    let n = aig.n_inputs;
    let mut b = CnfBuilder::new();
    let inputs: Vec<_> = (0..n).map(|_| b.new_lit()).collect();
    // Encode the substituted circuit once with free inputs.
    let mut lit_of: Vec<crate::sat::Lit> = vec![b.false_lit(); aig.n_vars()];
    for j in 0..n {
        lit_of[1 + j] = inputs[j];
    }
    for (i, nd) in aig.ands.iter().enumerate() {
        let v = 1 + n + i;
        if let Some(&(_, val)) = subst.iter().find(|&&(idx, _)| idx == i) {
            lit_of[v] = if val { b.true_lit() } else { b.false_lit() };
            continue;
        }
        let tr = |l: Lit, lits: &[crate::sat::Lit]| {
            let base = lits[graph::var(l) as usize];
            if graph::is_compl(l) {
                !base
            } else {
                base
            }
        };
        let a = tr(nd.0, &lit_of);
        let c = tr(nd.1, &lit_of);
        lit_of[v] = b.and(&[a, c]);
    }
    let out_bits: Vec<crate::sat::Lit> = aig
        .outputs
        .iter()
        .map(|&l| {
            let base = lit_of[graph::var(l) as usize];
            if graph::is_compl(l) {
                !base
            } else {
                base
            }
        })
        .collect();

    // Violation indicator per input point: inputs equal x AND value
    // outside [lo, hi]. Encoded as: for each x, a selector s_x that
    // implies inputs == x; requiring OR(s_x out-of-range...) — simpler
    // and still one query: assert inputs free, and forbid nothing;
    // instead encode "distance respected" for every x via implication
    // from the input assignment. UNSAT of (exists x: out of range) is
    // what we want, so we encode the complement: find x with V outside
    // the interval.
    let m = out_bits.len();
    let top = (1u64 << m) - 1;
    let mut any_violation: Vec<crate::sat::Lit> = Vec::new();
    for (x, &e) in exact.iter().enumerate() {
        let lo = e.saturating_sub(et);
        let hi = (e + et).min(top);
        // eq_x <-> inputs == x
        let conj: Vec<crate::sat::Lit> = (0..n)
            .map(|j| if (x >> j) & 1 == 1 { inputs[j] } else { !inputs[j] })
            .collect();
        let eq = b.and(&conj);
        // in-range indicator via two comparator-free bounds: encode
        // "value < lo OR value > hi" with helper bits per x is costly;
        // reuse value_in_range on fresh bits tied by equivalence instead.
        // Cheaper: violation_x = eq AND NOT in_range(out_bits).
        // We encode in_range via an indicator r_x defined by Tseitin over
        // a sub-CNF: r -> range clauses can't be expressed directly with
        // value_in_range (it adds hard clauses). Use conditional copies:
        let copy: Vec<crate::sat::Lit> = (0..m).map(|_| b.new_lit()).collect();
        for i in 0..m {
            // eq -> (copy_i <-> out_i)
            b.add_clause(&[!eq, !copy[i], out_bits[i]]);
            b.add_clause(&[!eq, copy[i], !out_bits[i]]);
        }
        // When eq holds, copies carry the real value; out-of-range copies
        // are forbidden by the range constraint *negated*: we want a
        // violation witness, so assert NOT in [lo, hi] conditionally.
        // Encode: viol_x = eq AND (copy < lo OR copy > hi). Express the
        // two strict comparisons by value_in_range on the complement
        // intervals with selector literals.
        let viol = b.new_lit();
        // viol -> eq
        b.add_clause(&[!viol, eq]);
        // If lo > 0: low violation possible; build lv <-> copy <= lo-1.
        let mut parts: Vec<crate::sat::Lit> = Vec::new();
        if lo > 0 {
            let lv = b.new_lit();
            // lv -> copy <= lo-1 enforced via conditional hard bound on
            // shadow bits: shadow = copy when lv... to keep the encoding
            // small we use the direct MSB-chain comparison.
            encode_le_indicator(&mut b, &copy, lo - 1, lv);
            parts.push(lv);
        }
        if hi < top {
            let hv = b.new_lit();
            encode_ge_indicator(&mut b, &copy, hi + 1, hv);
            parts.push(hv);
        }
        if parts.is_empty() {
            b.add_clause(&[!viol]);
        } else {
            // viol -> OR(parts)
            let mut cl = vec![!viol];
            cl.extend(&parts);
            b.add_clause(&cl);
        }
        any_violation.push(viol);
    }
    b.add_clause(&any_violation.clone());
    b.solver.solve(&[]) == SatResult::Unsat
}

/// ind -> (value(bits) <= c): one-directional comparator.
fn encode_le_indicator(b: &mut CnfBuilder, bits: &[crate::sat::Lit], c: u64,
                       ind: crate::sat::Lit) {
    // value > c happens iff for some k with c_k = 0, bits_k = 1 and all
    // higher bits match c. Forbid each such pattern when ind holds.
    let m = bits.len();
    for k in 0..m {
        if (c >> k) & 1 == 1 {
            continue;
        }
        // ind & (all higher bits == c) -> !bits[k], i.e.
        // !ind ∨ !bits[k] ∨ ⋁_{j>k} (bits_j != c_j).
        let mut clause = vec![!ind, !bits[k]];
        for j in k + 1..m {
            if (c >> j) & 1 == 1 {
                clause.push(!bits[j]); // differs when bits_j = 0
            } else {
                clause.push(bits[j]); // differs when bits_j = 1
            }
        }
        b.add_clause(&clause);
    }
}

/// ind -> (value(bits) >= c).
fn encode_ge_indicator(b: &mut CnfBuilder, bits: &[crate::sat::Lit], c: u64,
                       ind: crate::sat::Lit) {
    let m = bits.len();
    let mask = (1u64 << m) - 1;
    let inv: Vec<crate::sat::Lit> = bits.iter().map(|&l| !l).collect();
    encode_le_indicator(b, &inv, !c & mask, ind);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::{adder, multiplier, PAPER_BENCHMARKS};
    use crate::circuit::sim::TruthTables;

    #[test]
    fn muscat_is_sound_and_saves_area() {
        for b in PAPER_BENCHMARKS.iter().take(4) {
            let nl = b.netlist();
            let exact_area = synthesize_area(&nl);
            let et = b.fig4_et();
            let res = muscat(&nl, et);
            assert!(res.max_err <= et, "{}: err {} > {et}", b.name, res.max_err);
            assert!(res.area <= exact_area + 1e-9, "{}", b.name);
            assert!(res.applied > 0, "{}: nothing applied", b.name);
        }
    }

    #[test]
    fn muscat_et_zero_changes_nothing_functionally() {
        let nl = adder(2);
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let res = muscat(&nl, 0);
        let tt = TruthTables::simulate(&res.netlist);
        assert_eq!(tt.output_values(&res.netlist), exact);
    }

    #[test]
    fn larger_et_never_larger_area() {
        let nl = multiplier(2);
        let a1 = muscat(&nl, 1).area;
        let a4 = muscat(&nl, 4).area;
        assert!(a4 <= a1 + 1e-9, "a4={a4} a1={a1}");
    }

    #[test]
    fn sat_check_agrees_with_exhaustive() {
        let nl = adder(2);
        let aig = optimize(&netlist_to_aig(&nl));
        let exact = aig.output_values();
        for idx in 0..aig.ands.len().min(6) {
            for val in [false, true] {
                let subst = vec![(idx, val)];
                let vals = values_with_consts(&aig, &subst);
                for et in [0u64, 1, 2] {
                    let (mx, _) = error_stats(&exact, &vals);
                    let want_sound = mx <= et;
                    assert_eq!(
                        sat_check(&aig, &subst, &exact, et),
                        want_sound,
                        "idx={idx} val={val} et={et} mx={mx}"
                    );
                }
            }
        }
    }
}

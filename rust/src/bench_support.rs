//! Minimal bench harness (criterion is not vendored in this offline
//! environment): warmup + timed iterations with mean / stddev / min /
//! quantile reporting, and a black_box to defeat const-folding.
//! Per-iteration samples go into a fixed-size log2-bucketed histogram
//! ([`obs::hist`](crate::obs::hist)) plus exact running sums, so the
//! harness holds no per-iteration `Vec` however many iterations run.

use std::hint::black_box as std_black_box;
use std::time::Instant;

use crate::obs::Histogram;

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Statistics over per-iteration wall times (milliseconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub stddev_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    /// Median per-iteration time (histogram quantile, µs resolution).
    pub p50_ms: f64,
    /// Tail per-iteration time (histogram quantile, µs resolution).
    pub p99_ms: f64,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "bench {:<44} {:>8.3} ms/iter (±{:.3}, min {:.3}, p50 {:.3}, \
             p99 {:.3}, max {:.3}, n={})",
            self.name,
            self.mean_ms,
            self.stddev_ms,
            self.min_ms,
            self.p50_ms,
            self.p99_ms,
            self.max_ms,
            self.iters
        );
    }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    // Exact running sums for mean/stddev/min/max; the histogram serves
    // the quantiles. Both are O(1) in the iteration count.
    let hist = Histogram::new();
    let (mut sum, mut sum_sq) = (0.0f64, 0.0f64);
    let (mut min_ms, mut max_ms) = (f64::INFINITY, 0.0f64);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let us = t.elapsed().as_micros() as u64;
        let ms = us as f64 / 1e3;
        hist.record(us);
        sum += ms;
        sum_sq += ms * ms;
        min_ms = min_ms.min(ms);
        max_ms = max_ms.max(ms);
    }
    let n = iters.max(1) as f64;
    let mean = sum / n;
    let var = (sum_sq / n - mean * mean).max(0.0);
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        stddev_ms: var.sqrt(),
        min_ms: if min_ms.is_finite() { min_ms } else { 0.0 },
        max_ms,
        p50_ms: hist.quantile(0.50) as f64 / 1e3,
        p99_ms: hist.quantile(0.99) as f64 / 1e3,
    };
    stats.report();
    stats
}

/// Throughput helper: items/second given per-iteration item count.
pub fn throughput(stats: &BenchStats, items_per_iter: usize) -> f64 {
    items_per_iter as f64 / (stats.mean_ms / 1e3)
}

/// Machine-readable bench results: a flat `name -> number` JSON object
/// written as `BENCH_<suite>.json`, so the perf trajectory can be diffed
/// across commits instead of scraped from stdout. Non-finite values
/// serialize as `null` (JSON has no NaN/inf).
#[derive(Debug, Default)]
pub struct JsonReport {
    entries: Vec<(String, f64)>,
}

impl JsonReport {
    pub fn new() -> Self {
        JsonReport::default()
    }

    /// Record one metric. Keys are kept in insertion order.
    pub fn push(&mut self, key: &str, value: f64) {
        self.entries.push((key.to_string(), value));
    }

    /// The recorded metrics, in insertion order — for callers that
    /// merge one report into another (e.g. the serve bench folding
    /// server-side metrics into its own suite).
    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    /// Record the standard fields of a [`BenchStats`] under `prefix`.
    pub fn push_stats(&mut self, prefix: &str, stats: &BenchStats) {
        self.push(&format!("{prefix}.mean_ms"), stats.mean_ms);
        self.push(&format!("{prefix}.min_ms"), stats.min_ms);
        self.push(&format!("{prefix}.p50_ms"), stats.p50_ms);
        self.push(&format!("{prefix}.p99_ms"), stats.p99_ms);
        self.push(&format!("{prefix}.iters"), stats.iters as f64);
    }

    /// Record the restart/LBD/preprocessing counters of a solver run
    /// under `prefix` — the "why did solve time move" half of the sat
    /// suite (`BENCH_sat.json`), next to the wall-clock numbers.
    pub fn push_sat_stats(&mut self, prefix: &str, stats: &crate::sat::Stats) {
        self.push(&format!("{prefix}.conflicts"), stats.conflicts as f64);
        self.push(&format!("{prefix}.restarts"), stats.restarts as f64);
        self.push(
            &format!("{prefix}.restarts_blocked"),
            stats.restarts_blocked as f64,
        );
        let mean_lbd = if stats.conflicts > 0 {
            stats.lbd_sum as f64 / stats.conflicts as f64
        } else {
            0.0
        };
        self.push(&format!("{prefix}.mean_lbd"), mean_lbd);
        self.push(
            &format!("{prefix}.deleted_clauses"),
            stats.deleted_clauses as f64,
        );
        self.push(
            &format!("{prefix}.preprocess_probes"),
            stats.preprocess_probes as f64,
        );
        self.push(
            &format!("{prefix}.preprocess_subsumed"),
            stats.preprocess_subsumed as f64,
        );
    }

    pub fn render(&self) -> String {
        let mut out = String::from("{\n");
        for (i, (k, v)) in self.entries.iter().enumerate() {
            let comma = if i + 1 < self.entries.len() { "," } else { "" };
            let key = k.replace('\\', "\\\\").replace('"', "\\\"");
            if v.is_finite() {
                out.push_str(&format!("  \"{key}\": {v}{comma}\n"));
            } else {
                out.push_str(&format!("  \"{key}\": null{comma}\n"));
            }
        }
        out.push_str("}\n");
        out
    }

    /// Write `BENCH_<suite>.json` into the working directory (the crate
    /// root under `cargo bench`).
    pub fn write(&self, suite: &str) {
        let path = format!("BENCH_{suite}.json");
        match std::fs::write(&path, self.render()) {
            Ok(()) => println!("wrote {path} ({} metrics)", self.entries.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }

    /// Parse a rendered report back (the `perfgate` input path): a
    /// flat JSON object of numbers, entries sorted by key. `null`
    /// entries (non-finite at write time) load as NaN so comparisons
    /// can skip them explicitly.
    pub fn parse(text: &str) -> anyhow::Result<JsonReport> {
        let j = crate::util::Json::parse(text)?;
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("bench report is not a JSON object"))?;
        let mut report = JsonReport::new();
        for (k, v) in obj {
            match v {
                crate::util::Json::Null => report.push(k, f64::NAN),
                other => report.push(
                    k,
                    other.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("bench report key {k:?} is not a number")
                    })?,
                ),
            }
        }
        Ok(report)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<JsonReport> {
        use anyhow::Context;
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read bench report {}", path.display()))?;
        JsonReport::parse(&text).with_context(|| format!("parse {}", path.display()))
    }
}

/// Shared fresh-build vs prototype-clone harness: times `build()` (3
/// iters) against `.clone()` of one built value (10 iters), prints the
/// ratio and records `<key>_build.*`, `<key>_clone.*`,
/// `<key>_clone.clone_over_build` and `<key>_clone.clone_strictly_faster`
/// on the report. Used by the sat and engine bench suites so the two
/// `BENCH_*.json` files cannot drift apart in methodology.
pub fn bench_clone_vs_build<T: Clone>(
    report: &mut JsonReport,
    group: &str,
    key: &str,
    mut build: impl FnMut() -> T,
) {
    let build_stats = bench(&format!("{group}/{key}_build"), 1, 3, || {
        black_box(build());
    });
    let proto = build();
    let clone_stats = bench(&format!("{group}/{key}_clone"), 1, 10, || {
        black_box(proto.clone());
    });
    let faster = clone_stats.mean_ms < build_stats.mean_ms;
    println!(
        "  {key}: clone {:.3} ms vs fresh build {:.3} ms — clone {}",
        clone_stats.mean_ms,
        build_stats.mean_ms,
        if faster { "strictly faster" } else { "NOT faster (regression!)" }
    );
    report.push_stats(&format!("{key}_build"), &build_stats);
    report.push_stats(&format!("{key}_clone"), &clone_stats);
    report.push(
        &format!("{key}_clone.clone_over_build"),
        clone_stats.mean_ms / build_stats.mean_ms,
    );
    report.push(
        &format!("{key}_clone.clone_strictly_faster"),
        if faster { 1.0 } else { 0.0 },
    );
}

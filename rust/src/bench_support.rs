//! Minimal bench harness (criterion is not vendored in this offline
//! environment): warmup + timed iterations with mean / stddev / min
//! reporting, and a black_box to defeat const-folding.

use std::hint::black_box as std_black_box;
use std::time::Instant;

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Statistics over per-iteration wall times (milliseconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub stddev_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "bench {:<44} {:>8.3} ms/iter (±{:.3}, min {:.3}, max {:.3}, n={})",
            self.name, self.mean_ms, self.stddev_ms, self.min_ms, self.max_ms, self.iters
        );
    }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>()
        / times.len().max(1) as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        stddev_ms: var.sqrt(),
        min_ms: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_ms: times.iter().cloned().fold(0.0, f64::max),
    };
    stats.report();
    stats
}

/// Throughput helper: items/second given per-iteration item count.
pub fn throughput(stats: &BenchStats, items_per_iter: usize) -> f64 {
    items_per_iter as f64 / (stats.mean_ms / 1e3)
}

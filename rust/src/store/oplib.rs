//! The operator library: a Pareto view over the persistent store.
//!
//! The store accumulates solved jobs; the deployment-time question is
//! the inverse lookup — *given a benchmark and an error budget, which
//! stored operator should the accelerator instantiate?* This is the
//! per-layer operator-selection primitive of QoS-Nets-style NN
//! deployment (see PAPERS.md): the NN layer asks for "the cheapest 4x4
//! multiplier whose worst-case error is within my budget" and gets a
//! truth table it can drop into `MultLut::from_values`.
//!
//! [`OpLib::from_store`] folds every usable record (finite area, a
//! non-empty exported truth table, no error) into per-benchmark entry
//! lists; [`OpLib::frontier`] reduces one benchmark to its Pareto
//! frontier (area vs. achieved max error — an entry is kept iff no
//! stored operator has both a smaller-or-equal error and a smaller
//! area); [`OpLib::best`] answers the budget query by *achieved*
//! `max_err`, not the ET the job was run at, so an ET=4 search that
//! happened to land a max-error-2 operator serves ET≥2 budgets too.
//!
//! Every export path re-verifies the operator against the exhaustive
//! oracle ([`OpLib::verify`]) — records come from disk and disks/hands
//! are not part of the soundness argument.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

use crate::circuit::generators::benchmark_by_name;
use crate::circuit::sim::TruthTables;
use crate::coordinator::Method;

use super::fingerprint::Fingerprint;
use super::wal::Store;

/// One stored operator, ready to serve.
#[derive(Debug, Clone)]
pub struct OpEntry {
    pub bench: &'static str,
    pub method: Method,
    /// The ET the producing job was run at.
    pub et: u64,
    /// The operator's *achieved* worst-case error (≤ `et`) — the field
    /// budget queries match against.
    pub max_err: u64,
    pub mean_err: f64,
    pub area: f64,
    /// Exhaustive output table (`2^n` entries), LSB-first input
    /// indexing — `MultLut::from_values` shape.
    pub values: Vec<u64>,
    pub fingerprint: Fingerprint,
}

/// In-memory library view; rebuild cheaply from the store after sweeps.
pub struct OpLib {
    /// bench -> entries sorted by (max_err, area, method name, fp) —
    /// a deterministic order regardless of WAL history.
    per_bench: BTreeMap<&'static str, Vec<OpEntry>>,
}

impl OpLib {
    pub fn from_store(store: &Store) -> OpLib {
        let mut per_bench: BTreeMap<&'static str, Vec<OpEntry>> = BTreeMap::new();
        for (fp, rec) in store.records() {
            if rec.error.is_some() || !rec.area.is_finite() || rec.values.is_empty() {
                continue;
            }
            per_bench.entry(rec.bench).or_default().push(OpEntry {
                bench: rec.bench,
                method: rec.method,
                et: rec.et,
                max_err: rec.max_err,
                mean_err: rec.mean_err,
                area: rec.area,
                values: rec.values,
                fingerprint: fp,
            });
        }
        for entries in per_bench.values_mut() {
            entries.sort_by(|a, b| {
                (a.max_err, a.area, a.method.name(), a.fingerprint).partial_cmp(&(
                    b.max_err,
                    b.area,
                    b.method.name(),
                    b.fingerprint,
                ))
                .expect("areas are finite here")
            });
        }
        OpLib { per_bench }
    }

    /// Benchmarks with at least one stored operator.
    pub fn benches(&self) -> Vec<&'static str> {
        self.per_bench.keys().copied().collect()
    }

    /// Total operators across all benchmarks.
    pub fn len(&self) -> usize {
        self.per_bench.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The benchmark's Pareto frontier in ascending `max_err` order:
    /// each kept entry strictly improves area over everything with
    /// smaller-or-equal error. Dominated operators (bigger AND no more
    /// accurate than a kept one) are folded away.
    pub fn frontier(&self, bench: &str) -> Vec<&OpEntry> {
        let mut out: Vec<&OpEntry> = Vec::new();
        let mut best_area = f64::INFINITY;
        for e in self.per_bench.get(bench).map(Vec::as_slice).unwrap_or(&[]) {
            // Entries arrive sorted by (max_err, area): within one
            // max_err the first is the cheapest, and a later entry only
            // earns a slot by beating every lower-error area.
            if e.area < best_area {
                best_area = e.area;
                out.push(e);
            }
        }
        out
    }

    /// The cheapest stored operator sound for error budget `et`:
    /// minimum area among entries with `max_err <= et`, ties broken
    /// deterministically by (max_err, method name, fingerprint).
    pub fn best(&self, bench: &str, et: u64) -> Option<&OpEntry> {
        self.per_bench
            .get(bench)?
            .iter()
            .filter(|e| e.max_err <= et)
            .min_by(|a, b| {
                (a.area, a.max_err, a.method.name(), a.fingerprint)
                    .partial_cmp(&(b.area, b.max_err, b.method.name(), b.fingerprint))
                    .expect("areas are finite here")
            })
    }

    /// Tier resolution for serving paths: [`OpLib::best`] plus the
    /// mandatory oracle re-verification in one call, so no caller can
    /// forget the verify step. `Ok(None)` means the library has nothing
    /// within budget (the caller picks its fallback); `Err` means the
    /// best stored operator failed re-verification and must not be
    /// served.
    pub fn best_verified(&self, bench: &str, et: u64) -> Result<Option<&OpEntry>> {
        match self.best(bench, et) {
            None => Ok(None),
            Some(e) => {
                Self::verify(e)?;
                Ok(Some(e))
            }
        }
    }

    /// Re-verify a stored operator against the exhaustive oracle: the
    /// benchmark must be known, the table exhaustive, and every output
    /// within the entry's recorded `max_err` of the exact value.
    pub fn verify(entry: &OpEntry) -> Result<()> {
        let bench = benchmark_by_name(entry.bench).ok_or_else(|| {
            anyhow!("{}: not a known benchmark, cannot re-verify", entry.bench)
        })?;
        let nl = bench.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        if entry.values.len() != exact.len() {
            bail!(
                "{}: stored table has {} entries, oracle has {}",
                entry.bench,
                entry.values.len(),
                exact.len()
            );
        }
        for (i, (&e, &a)) in exact.iter().zip(&entry.values).enumerate() {
            if e.abs_diff(a) > entry.max_err {
                bail!(
                    "{}: point {i}: |{e} - {a}| > recorded max_err {}",
                    entry.bench,
                    entry.max_err
                );
            }
        }
        Ok(())
    }

    /// Render one operator as a portable truth-table file: comment
    /// header, then one output value per line in input-index order.
    pub fn export_tt(entry: &OpEntry) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "# sxpat operator bench={} method={} et={} max_err={} area={:.4} fp={}",
            entry.bench,
            entry.method.name(),
            entry.et,
            entry.max_err,
            entry.area,
            entry.fingerprint,
        );
        let _ = writeln!(
            s,
            "# {} output values, input index = sum_i x_i << i (LSB-first)",
            entry.values.len()
        );
        for v in &entry.values {
            let _ = writeln!(s, "{v}");
        }
        s
    }

    /// Parse [`export_tt`](Self::export_tt)'s format back to values.
    pub fn parse_tt(src: &str) -> Result<Vec<u64>> {
        src.lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .map(|l| l.parse::<u64>().map_err(|_| anyhow!("bad value line {l:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunRecord;
    use std::path::PathBuf;

    fn entry_rec(
        bench: &'static str,
        method: Method,
        et: u64,
        max_err: u64,
        area: f64,
        values: Vec<u64>,
    ) -> RunRecord {
        RunRecord {
            bench,
            method,
            et,
            area,
            max_err,
            mean_err: 0.1,
            proxy: (0, 0),
            elapsed_ms: 1,
            cached: false,
            values,
            all_points: Vec::new(),
            error: None,
        }
    }

    fn tmp_store(tag: &str) -> (PathBuf, Store) {
        let d = std::env::temp_dir()
            .join(format!("sxpat_oplib_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        let st = Store::open(&d).unwrap();
        (d, st)
    }

    #[test]
    fn fold_best_and_frontier() {
        let (dir, st) = tmp_store("fold");
        let vals = vec![0u64; 16];
        st.append(
            Fingerprint(1),
            &entry_rec("adder_i4", Method::Shared, 1, 1, 8.0, vals.clone()),
        )
        .unwrap();
        st.append(
            Fingerprint(2),
            &entry_rec("adder_i4", Method::Xpat, 1, 1, 10.0, vals.clone()),
        )
        .unwrap();
        st.append(
            Fingerprint(3),
            &entry_rec("adder_i4", Method::Shared, 2, 2, 5.0, vals.clone()),
        )
        .unwrap();
        // Dominated: same error as fp=3 but bigger.
        st.append(
            Fingerprint(4),
            &entry_rec("adder_i4", Method::Muscat, 2, 2, 9.0, vals.clone()),
        )
        .unwrap();
        // Unusable records never enter the library.
        st.append(
            Fingerprint(5),
            &entry_rec("adder_i4", Method::Shared, 4, u64::MAX, f64::INFINITY, vec![]),
        )
        .unwrap();
        let lib = OpLib::from_store(&st);
        assert_eq!(lib.len(), 4);
        assert_eq!(lib.benches(), vec!["adder_i4"]);

        // Budget queries go by achieved error, minimum area wins.
        assert_eq!(lib.best("adder_i4", 0).map(|e| e.fingerprint), None);
        assert_eq!(lib.best("adder_i4", 1).unwrap().fingerprint, Fingerprint(1));
        assert_eq!(lib.best("adder_i4", 2).unwrap().fingerprint, Fingerprint(3));
        assert_eq!(lib.best("adder_i4", 99).unwrap().fingerprint, Fingerprint(3));
        assert!(lib.best("mult_i4", 1).is_none());

        // Frontier: (err 1, area 8.0) then (err 2, area 5.0).
        let front: Vec<Fingerprint> =
            lib.frontier("adder_i4").iter().map(|e| e.fingerprint).collect();
        assert_eq!(front, vec![Fingerprint(1), Fingerprint(3)]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn verify_checks_against_oracle() {
        let bench = benchmark_by_name("adder_i4").unwrap();
        let nl = bench.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let good = OpEntry {
            bench: "adder_i4",
            method: Method::Exact,
            et: 0,
            max_err: 0,
            mean_err: 0.0,
            area: 1.0,
            values: exact.clone(),
            fingerprint: Fingerprint(1),
        };
        assert!(OpLib::verify(&good).is_ok());

        let mut bad = good.clone();
        bad.values[3] += 5; // err 5 > recorded max_err 0
        assert!(OpLib::verify(&bad).is_err());

        let mut short = good.clone();
        short.values.pop();
        assert!(OpLib::verify(&short).is_err());

        let mut unknown = good;
        unknown.bench = "divider_i4";
        assert!(OpLib::verify(&unknown).is_err());
    }

    #[test]
    fn export_parse_round_trip() {
        let e = OpEntry {
            bench: "mult_i4",
            method: Method::Shared,
            et: 2,
            max_err: 2,
            mean_err: 0.4,
            area: 12.25,
            values: vec![0, 1, 2, 3, 4, 5, 6, 9],
            fingerprint: Fingerprint(0xFEED),
        };
        let text = OpLib::export_tt(&e);
        assert!(text.starts_with("# sxpat operator bench=mult_i4"));
        assert_eq!(OpLib::parse_tt(&text).unwrap(), e.values);
    }
}

//! Persistent result store: content-addressed caching of solved jobs
//! and the operator library that serves deployment-time lookups.
//!
//! * [`fingerprint`] — stable (FNV-1a/64) job identity over the
//!   benchmark truth table, method, ET and the search-relevant config
//!   fields; worker counts are excluded (determinism-neutral).
//! * [`wal`] — append-only JSONL log of [`RunRecord`]s keyed by
//!   fingerprint, with torn-tail recovery, last-writer-wins replay and
//!   an advisory single-writer lock (`Store::open` writes, with
//!   cross-process exclusion; `Store::open_read_only` queries alongside
//!   a live writer).
//! * [`oplib`] — Pareto-frontier view (area vs. error) over the store,
//!   exporting operators as truth tables the NN layer consumes.
//!
//! `coordinator::sweep::run_sweep_stored` is the producer seam: jobs
//! already fingerprinted in the store are served from disk (marked
//! `cached`), fresh results are appended as each job commits — a sweep
//! killed at any point resumes where it stopped.
//!
//! [`RunRecord`]: crate::coordinator::RunRecord

pub mod fingerprint;
pub mod oplib;
pub mod wal;

pub use fingerprint::{job_fingerprint, Fingerprint};
pub use oplib::{OpEntry, OpLib};
pub use wal::Store;

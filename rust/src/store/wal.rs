//! Append-only JSONL write-ahead log of completed [`RunRecord`]s keyed
//! by job fingerprint — the durable half of the result store.
//!
//! On-disk layout (`<dir>/wal.jsonl`): one line per committed record,
//!
//! ```text
//! {"fp":"<16 hex digits>","record":{...RunRecord::to_json()...}}
//! ```
//!
//! Crash-safety model (process crashes, not power loss): each append
//! hands one whole line to the kernel in a single `write_all` before
//! the in-memory index is updated, so a *process* killed mid-append
//! leaves at most one torn final line. `Store::open` detects a final
//! line that does not parse (or lacks its newline), drops it, and
//! truncates the file back to the last good line so the next append
//! starts clean — a torn tail never corrupts the record after it. A
//! malformed line *before* the tail is real corruption and fails the
//! open loudly rather than silently dropping solved work. Surviving
//! power loss / kernel crashes would need an `fsync` per append; the
//! store deliberately does not pay that — every record is recomputable,
//! so the worst case is re-solving the tail of one sweep.
//!
//! Duplicate fingerprints are legal (back-to-back sweeps over
//! overlapping grids, a record re-solved after failing oracle
//! re-verification) and resolve last-writer-wins: the in-memory index
//! keeps the latest occurrence, matching what a full replay of the log
//! would produce.
//!
//! Writer model: **one writing process at a time**. Within a process a
//! `Store` is freely shared across sweep workers (appends are
//! mutex-serialized); a second *process* appending to the same
//! directory concurrently is not supported — the open-time tail repair
//! and the append-failure rollback both truncate against this
//! process's view of the file and would cut another writer's committed
//! lines. Readers of a store no process is writing are always safe.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::RunRecord;
use crate::util::Json;

use super::fingerprint::Fingerprint;

const WAL_FILE: &str = "wal.jsonl";

struct Inner {
    /// fp -> latest record (last-writer-wins).
    map: HashMap<Fingerprint, RunRecord>,
    /// Append handle, positioned at end-of-log.
    file: File,
    /// Total lines appended over the store's life, including
    /// overwritten duplicates (telemetry; `len()` is the deduped size).
    lines: usize,
    /// Byte length of the WAL after the last good line — the rollback
    /// point when an append fails partway (see [`Store::append`]).
    end: u64,
}

/// The persistent result store: an in-memory fingerprint index over an
/// append-only JSONL WAL. Shareable across sweep workers (`&Store` is
/// `Sync`; all mutation is behind one mutex — appends are rare relative
/// to SAT solving, so contention is irrelevant).
pub struct Store {
    dir: PathBuf,
    inner: Mutex<Inner>,
}

impl Store {
    /// Open (creating if needed) the store in `dir`, replaying the WAL.
    pub fn open(dir: &Path) -> Result<Store> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        let wal_path = dir.join(WAL_FILE);
        let mut map = HashMap::new();
        let mut lines = 0usize;
        let mut keep_bytes = 0u64;
        if wal_path.exists() {
            let text = std::fs::read_to_string(&wal_path)
                .with_context(|| format!("reading {}", wal_path.display()))?;
            let mut offset = 0u64;
            for (i, raw) in text.split_inclusive('\n').enumerate() {
                offset += raw.len() as u64;
                if !raw.ends_with('\n') {
                    // Only the final piece can lack its newline, and
                    // under the single-`write_all` append model a
                    // cut-off append is exactly this shape (even if the
                    // prefix happens to parse): a torn tail. Drop it;
                    // the truncate below repairs the file so the next
                    // append starts on a clean line.
                    break;
                }
                let line = raw.trim_end_matches('\n').trim_end_matches('\r');
                if line.is_empty() {
                    keep_bytes = offset;
                    continue;
                }
                match parse_wal_line(line) {
                    Ok((fp, rec)) => {
                        map.insert(fp, rec);
                        lines += 1;
                        keep_bytes = offset;
                    }
                    // A newline-terminated line that fails to parse is
                    // NOT a crash artefact — appends are whole lines —
                    // so even in tail position it is real corruption
                    // and must fail loudly, not be silently truncated
                    // away with a solved record inside it.
                    Err(e) => {
                        bail!(
                            "{}: corrupt WAL line {}: {e:#}",
                            wal_path.display(),
                            i + 1
                        );
                    }
                }
            }
            if keep_bytes < text.len() as u64 {
                let f = OpenOptions::new()
                    .write(true)
                    .open(&wal_path)
                    .with_context(|| format!("repairing {}", wal_path.display()))?;
                f.set_len(keep_bytes).context("truncating torn WAL tail")?;
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&wal_path)
            .with_context(|| format!("opening {} for append", wal_path.display()))?;
        Ok(Store {
            dir: dir.to_path_buf(),
            inner: Mutex::new(Inner { map, file, lines, end: keep_bytes }),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of distinct fingerprints stored.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total WAL lines ever appended (≥ `len()`; the excess is
    /// last-writer-wins overwrites).
    pub fn lines(&self) -> usize {
        self.inner.lock().unwrap().lines
    }

    /// Look a completed job up by fingerprint.
    pub fn get(&self, fp: Fingerprint) -> Option<RunRecord> {
        self.inner.lock().unwrap().map.get(&fp).cloned()
    }

    pub fn contains(&self, fp: Fingerprint) -> bool {
        self.inner.lock().unwrap().map.contains_key(&fp)
    }

    /// Commit one record: append one whole line to the WAL (a single
    /// `write_all`, so the kernel sees it before the index does — see
    /// the module docs for the exact crash model) and insert into the
    /// in-memory map.
    ///
    /// A *failed* append (disk full, I/O error) rolls the file back to
    /// the last good line before returning the error: a partial line
    /// left in place would otherwise glue onto the next append and turn
    /// into mid-log corruption that `open` refuses to load.
    pub fn append(&self, fp: Fingerprint, rec: &RunRecord) -> Result<()> {
        let mut line = wal_line(fp, rec);
        line.push('\n');
        let mut inner = self.inner.lock().unwrap();
        if let Err(e) = inner.file.write_all(line.as_bytes()) {
            let end = inner.end;
            // Best effort: if the truncate also fails the torn bytes
            // stay, and the next open's tail repair handles them as
            // long as nothing else is appended after.
            let _ = inner.file.set_len(end);
            return Err(e).context("appending WAL line");
        }
        inner.end += line.len() as u64;
        inner.map.insert(fp, rec.clone());
        inner.lines += 1;
        Ok(())
    }

    /// Snapshot of every stored (fingerprint, record) pair, in
    /// deterministic fingerprint order — the oplib fold input.
    pub fn records(&self) -> Vec<(Fingerprint, RunRecord)> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<(Fingerprint, RunRecord)> =
            inner.map.iter().map(|(k, v)| (*k, v.clone())).collect();
        out.sort_by_key(|(fp, _)| *fp);
        out
    }
}

/// Render one WAL line (without the trailing newline). Deterministic:
/// `Json::render` sorts keys and escapes to ASCII.
fn wal_line(fp: Fingerprint, rec: &RunRecord) -> String {
    let mut m = std::collections::BTreeMap::new();
    m.insert("fp".to_string(), Json::Str(fp.to_string()));
    m.insert("record".to_string(), rec.to_json());
    Json::Obj(m).render()
}

fn parse_wal_line(line: &str) -> Result<(Fingerprint, RunRecord)> {
    let j = Json::parse(line)?;
    let fp_str = j
        .get("fp")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing \"fp\""))?;
    let fp = Fingerprint::parse(fp_str)
        .ok_or_else(|| anyhow!("bad fingerprint {fp_str:?}"))?;
    let rec = RunRecord::from_json(
        j.get("record").ok_or_else(|| anyhow!("missing \"record\""))?,
    )?;
    Ok((fp, rec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Method;

    fn rec(et: u64, area: f64) -> RunRecord {
        RunRecord {
            bench: "adder_i4",
            method: Method::Shared,
            et,
            area,
            max_err: et,
            mean_err: 0.5,
            proxy: (1, 2),
            elapsed_ms: 9,
            cached: false,
            values: vec![0, 1, 2, 3],
            all_points: vec![(1, 2, area)],
            error: None,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("sxpat_wal_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn append_get_reopen() {
        let dir = tmp_dir("basic");
        let fp = Fingerprint(0xABCD);
        {
            let st = Store::open(&dir).unwrap();
            assert!(st.is_empty());
            st.append(fp, &rec(2, 10.0)).unwrap();
            assert_eq!(st.get(fp).unwrap().area, 10.0);
            assert_eq!(st.len(), 1);
        }
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 1);
        assert_eq!(st.get(fp).unwrap(), rec(2, 10.0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn duplicate_keys_resolve_last_writer_wins() {
        let dir = tmp_dir("lww");
        let fp = Fingerprint(7);
        {
            let st = Store::open(&dir).unwrap();
            st.append(fp, &rec(2, 10.0)).unwrap();
            st.append(fp, &rec(2, 8.5)).unwrap();
            assert_eq!(st.len(), 1, "one key");
            assert_eq!(st.lines(), 2, "two physical lines");
            assert_eq!(st.get(fp).unwrap().area, 8.5);
        }
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.get(fp).unwrap().area, 8.5, "replay keeps the last");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_dropped_and_repaired() {
        let dir = tmp_dir("torn");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &rec(1, 5.0)).unwrap();
            st.append(Fingerprint(2), &rec(2, 6.0)).unwrap();
        }
        // Simulate a crash mid-append: half a line, no newline.
        let wal = dir.join(WAL_FILE);
        let mut f = OpenOptions::new().append(true).open(&wal).unwrap();
        f.write_all(b"{\"fp\":\"00000000000000").unwrap();
        drop(f);
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 2, "torn tail dropped, good lines kept");
        // The repair truncated the torn bytes: a fresh append and reopen
        // must see 3 clean records.
        st.append(Fingerprint(3), &rec(4, 7.0)).unwrap();
        drop(st);
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 3);
        assert_eq!(st.get(Fingerprint(3)).unwrap().area, 7.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn complete_parsable_tail_without_newline_is_torn() {
        // Even a tail that parses is torn if its newline is missing —
        // keeping it would glue the next append onto it.
        let dir = tmp_dir("noeol");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &rec(1, 5.0)).unwrap();
            st.append(Fingerprint(2), &rec(2, 6.0)).unwrap();
        }
        let wal = dir.join(WAL_FILE);
        let text = std::fs::read_to_string(&wal).unwrap();
        std::fs::write(&wal, text.trim_end_matches('\n')).unwrap();
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 1, "newline-less tail treated as torn");
        assert!(st.get(Fingerprint(1)).is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_middle_line_fails_loudly() {
        let dir = tmp_dir("midcorrupt");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &rec(1, 5.0)).unwrap();
        }
        let wal = dir.join(WAL_FILE);
        let mut text = std::fs::read_to_string(&wal).unwrap();
        text = format!("garbage not json\n{text}");
        std::fs::write(&wal, text).unwrap();
        assert!(Store::open(&dir).is_err(), "mid-log corruption must not be silent");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn error_strings_survive_the_wal() {
        let dir = tmp_dir("err");
        let mut r = rec(2, f64::INFINITY);
        r.error = Some("worker panicked: \"boom\"\n\tat cell (3, 4)".into());
        r.values = Vec::new();
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(9), &r).unwrap();
        }
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.get(Fingerprint(9)).unwrap(), r);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

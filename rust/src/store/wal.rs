//! Append-only JSONL write-ahead log of completed [`RunRecord`]s keyed
//! by job fingerprint — the durable half of the result store.
//!
//! On-disk layout (`<dir>/wal.jsonl`): one line per committed record,
//!
//! ```text
//! {"fp":"<16 hex digits>","record":{...RunRecord::to_json()...}}
//! ```
//!
//! Crash-safety model (process crashes, not power loss): each append
//! hands one whole line to the kernel in a single `write_all` before
//! the in-memory index is updated, so a *process* killed mid-append
//! leaves at most one torn final line. `Store::open` detects a final
//! line that does not parse (or lacks its newline), drops it, and
//! truncates the file back to the last good line so the next append
//! starts clean — a torn tail never corrupts the record after it. A
//! malformed line *before* the tail is real corruption and fails the
//! open loudly rather than silently dropping solved work. Surviving
//! power loss / kernel crashes would need an `fsync` per append; the
//! store deliberately does not pay that — every record is recomputable,
//! so the worst case is re-solving the tail of one sweep.
//!
//! Duplicate fingerprints are legal (back-to-back sweeps over
//! overlapping grids, a record re-solved after failing oracle
//! re-verification) and resolve last-writer-wins: the in-memory index
//! keeps the latest occurrence, matching what a full replay of the log
//! would produce.
//!
//! Writer model: **one writing `Store` at a time**, now *enforced* by
//! an advisory lock file (`<dir>/LOCK`, containing the holder's pid)
//! acquired by [`Store::open`] and released on drop. Within a process
//! a `Store` is freely shared across sweep workers (appends are
//! mutex-serialized); a second writer on the same directory — another
//! process, or a second `Store::open` in this one — fails loudly at
//! open instead of interleaving WAL appends: the open-time tail repair
//! and the append-failure rollback both truncate against one writer's
//! view of the file and would cut another writer's committed lines. A
//! lock left behind by a *dead* process (crash before drop) is
//! detected on Linux via `/proc/<pid>` and reclaimed; elsewhere it
//! must be removed by hand (the error message names the file).
//! Pure readers use [`Store::open_read_only`], which takes no lock,
//! never repairs the file, and refuses appends — safe alongside a live
//! writer up to WAL-tail staleness.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::RunRecord;
use crate::obs::{log as obs_log, metrics};
use crate::util::Json;

use super::fingerprint::Fingerprint;

const WAL_FILE: &str = "wal.jsonl";
const LOCK_FILE: &str = "LOCK";

/// Registry handles cached once per process (registration takes a
/// lock; the per-append path is then a single relaxed atomic add).
struct WalMetrics {
    appends: metrics::Counter,
    repairs: metrics::Counter,
    truncated_bytes: metrics::Counter,
}

fn wal_metrics() -> &'static WalMetrics {
    static M: std::sync::OnceLock<WalMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| WalMetrics {
        appends: metrics::counter("pallas_wal_appends_total"),
        repairs: metrics::counter("pallas_wal_repairs_total"),
        truncated_bytes: metrics::counter("pallas_wal_truncated_bytes_total"),
    })
}

/// RAII half of the advisory single-writer guard: the lock file is
/// removed when the owning [`Store`] drops (or when `open` fails after
/// acquisition, e.g. on a corrupt WAL).
struct LockGuard {
    path: PathBuf,
}

impl Drop for LockGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Is the pid recorded in a lock file still alive? Only Linux can
/// answer cheaply without libc (`/proc/<pid>` existence); elsewhere
/// every holder is presumed alive, so stale locks need manual removal.
fn lock_holder_alive(pid: u32) -> bool {
    if cfg!(target_os = "linux") {
        Path::new(&format!("/proc/{pid}")).exists()
    } else {
        true
    }
}

/// Acquire `<dir>/LOCK` with `create_new` (the atomic arbiter), writing
/// our pid into it. One reclaim attempt is made when the recorded
/// holder is provably dead.
fn acquire_lock(dir: &Path) -> Result<LockGuard> {
    let path = dir.join(LOCK_FILE);
    for attempt in 0..2 {
        match OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(mut f) => {
                let _ = write!(f, "{}", std::process::id());
                return Ok(LockGuard { path });
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                let holder = std::fs::read_to_string(&path)
                    .ok()
                    .and_then(|s| s.trim().parse::<u32>().ok());
                let stale = match holder {
                    // Our own pid means a second live writer in this
                    // very process — just as unsafe, never stale.
                    Some(pid) => pid != std::process::id() && !lock_holder_alive(pid),
                    // Unreadable/empty: a writer between create_new and
                    // the pid write. Treat as held.
                    None => false,
                };
                if stale && attempt == 0 {
                    reclaim_stale_lock(&path, holder.unwrap())?;
                    continue;
                }
                bail!(
                    "store {} is already locked by a writer (pid {}, lock file {}); \
                     a second concurrent writer would interleave WAL appends — \
                     wait for it, or remove the lock file if that process is dead",
                    dir.display(),
                    holder.map_or("unknown".to_string(), |p| p.to_string()),
                    path.display()
                );
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("creating lock file {}", path.display()))
            }
        }
    }
    unreachable!("second attempt either locks or bails");
}

/// Remove a lock whose recorded pid is provably dead. A bare
/// read-then-unlink would race: two openers could both judge the lock
/// stale, one reclaims it and *re-creates* it live, and the other's
/// unlink then deletes the fresh lock — two live writers. So removal
/// itself is arbitrated by a second `create_new` file (`LOCK.reclaim`)
/// and the dead pid is re-verified under it immediately before the
/// unlink: a lock that changed hands since we judged it stale is left
/// alone (the caller's retry then sees the live holder and bails). A
/// reclaim guard orphaned by a crash *during this tiny window* is not
/// auto-reclaimed — reclaiming reclaim locks would recurse — so it
/// fails loudly here and is removed by hand.
fn reclaim_stale_lock(path: &Path, dead_pid: u32) -> Result<()> {
    let guard_path = path.with_extension("reclaim");
    let mut f = match OpenOptions::new().write(true).create_new(true).open(&guard_path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
            bail!(
                "stale lock {} is being reclaimed by another process (guard {}); \
                 retry shortly, or remove the guard if its owner crashed",
                path.display(),
                guard_path.display()
            );
        }
        Err(e) => {
            return Err(e)
                .with_context(|| format!("creating reclaim guard {}", guard_path.display()))
        }
    };
    let _ = write!(f, "{}", std::process::id());
    // RAII: every exit below releases the guard file.
    let _guard = LockGuard { path: guard_path };
    let still_dead = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| s.trim().parse::<u32>().ok())
        .is_some_and(|pid| pid == dead_pid && !lock_holder_alive(pid));
    if still_dead {
        let _ = std::fs::remove_file(path);
    }
    Ok(())
}

struct Inner {
    /// fp -> latest record (last-writer-wins).
    map: HashMap<Fingerprint, RunRecord>,
    /// Append handle, positioned at end-of-log. `None` in read-only
    /// stores, whose appends fail instead.
    file: Option<File>,
    /// Total lines appended over the store's life, including
    /// overwritten duplicates (telemetry; `len()` is the deduped size).
    lines: usize,
    /// Byte length of the WAL after the last good line — the rollback
    /// point when an append fails partway (see [`Store::append`]).
    end: u64,
}

/// The persistent result store: an in-memory fingerprint index over an
/// append-only JSONL WAL. Shareable across sweep workers (`&Store` is
/// `Sync`; all mutation is behind one mutex — appends are rare relative
/// to SAT solving, so contention is irrelevant).
pub struct Store {
    dir: PathBuf,
    inner: Mutex<Inner>,
    /// Held for the store's lifetime by writers; `None` when read-only.
    _lock: Option<LockGuard>,
}

impl Store {
    /// Open (creating if needed) the store in `dir` for writing,
    /// replaying the WAL. Acquires the advisory single-writer lock —
    /// a concurrent writer on the same directory fails here, loudly,
    /// instead of interleaving WAL appends.
    pub fn open(dir: &Path) -> Result<Store> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        let lock = acquire_lock(dir)?;
        Store::open_inner(dir, Some(lock))
    }

    /// Open the store without the writer lock: no lock file, no
    /// torn-tail *repair* (a torn tail is still dropped from the
    /// in-memory view, just not truncated on disk), and appends fail.
    /// Safe to use while a writer is live (the operator-library and
    /// `oplib` query paths); a missing directory or WAL is an empty
    /// store, exactly as for writers.
    pub fn open_read_only(dir: &Path) -> Result<Store> {
        Store::open_inner(dir, None)
    }

    fn open_inner(dir: &Path, lock: Option<LockGuard>) -> Result<Store> {
        let writable = lock.is_some();
        let wal_path = dir.join(WAL_FILE);
        let mut map = HashMap::new();
        let mut lines = 0usize;
        let mut keep_bytes = 0u64;
        if wal_path.exists() {
            let text = std::fs::read_to_string(&wal_path)
                .with_context(|| format!("reading {}", wal_path.display()))?;
            let mut offset = 0u64;
            for (i, raw) in text.split_inclusive('\n').enumerate() {
                offset += raw.len() as u64;
                if !raw.ends_with('\n') {
                    // Only the final piece can lack its newline, and
                    // under the single-`write_all` append model a
                    // cut-off append is exactly this shape (even if the
                    // prefix happens to parse): a torn tail. Drop it;
                    // the truncate below repairs the file so the next
                    // append starts on a clean line.
                    break;
                }
                let line = raw.trim_end_matches('\n').trim_end_matches('\r');
                if line.is_empty() {
                    keep_bytes = offset;
                    continue;
                }
                match parse_wal_line(line) {
                    Ok((fp, rec)) => {
                        map.insert(fp, rec);
                        lines += 1;
                        keep_bytes = offset;
                    }
                    // A newline-terminated line that fails to parse is
                    // NOT a crash artefact — appends are whole lines —
                    // so even in tail position it is real corruption
                    // and must fail loudly, not be silently truncated
                    // away with a solved record inside it.
                    Err(e) => {
                        bail!(
                            "{}: corrupt WAL line {}: {e:#}",
                            wal_path.display(),
                            i + 1
                        );
                    }
                }
            }
            if writable && keep_bytes < text.len() as u64 {
                let torn = text.len() as u64 - keep_bytes;
                let f = OpenOptions::new()
                    .write(true)
                    .open(&wal_path)
                    .with_context(|| format!("repairing {}", wal_path.display()))?;
                f.set_len(keep_bytes).context("truncating torn WAL tail")?;
                // Recovery used to be silent; operators watching
                // corruption trends need the byte count (satellite of
                // the observability fabric — see DESIGN.md §13).
                wal_metrics().repairs.inc();
                wal_metrics().truncated_bytes.add(torn);
                obs_log::warn(
                    "store.wal",
                    "repaired torn WAL tail",
                    &[
                        ("path", Json::Str(wal_path.display().to_string())),
                        ("truncated_bytes", Json::Num(torn as f64)),
                    ],
                );
            }
        }
        let file = if writable {
            Some(
                OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(&wal_path)
                    .with_context(|| {
                        format!("opening {} for append", wal_path.display())
                    })?,
            )
        } else {
            None
        };
        Ok(Store {
            dir: dir.to_path_buf(),
            inner: Mutex::new(Inner { map, file, lines, end: keep_bytes }),
            _lock: lock,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of distinct fingerprints stored.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total WAL lines ever appended (≥ `len()`; the excess is
    /// last-writer-wins overwrites).
    pub fn lines(&self) -> usize {
        self.inner.lock().unwrap().lines
    }

    /// Look a completed job up by fingerprint.
    pub fn get(&self, fp: Fingerprint) -> Option<RunRecord> {
        self.inner.lock().unwrap().map.get(&fp).cloned()
    }

    pub fn contains(&self, fp: Fingerprint) -> bool {
        self.inner.lock().unwrap().map.contains_key(&fp)
    }

    /// Commit one record: append one whole line to the WAL (a single
    /// `write_all`, so the kernel sees it before the index does — see
    /// the module docs for the exact crash model) and insert into the
    /// in-memory map.
    ///
    /// A *failed* append (disk full, I/O error) rolls the file back to
    /// the last good line before returning the error: a partial line
    /// left in place would otherwise glue onto the next append and turn
    /// into mid-log corruption that `open` refuses to load.
    pub fn append(&self, fp: Fingerprint, rec: &RunRecord) -> Result<()> {
        self.append_inner(fp, rec, false).map(|_| ())
    }

    /// Commit `rec` only if `fp` is not already stored; returns whether
    /// a line was appended (checked and appended under one lock hold).
    /// The fingerprint-keyed dedup for paths that can legitimately
    /// produce duplicate completions of one job (the distributed
    /// sweep's lease-expiry requeue: first committed wins, a late
    /// duplicate must not grow the WAL). Callers that *want* the
    /// last-writer-wins overwrite (oracle-failure healing) use
    /// [`Store::append`].
    pub fn append_if_absent(&self, fp: Fingerprint, rec: &RunRecord) -> Result<bool> {
        self.append_inner(fp, rec, true)
    }

    fn append_inner(&self, fp: Fingerprint, rec: &RunRecord, only_absent: bool) -> Result<bool> {
        let mut line = wal_line(fp, rec);
        line.push('\n');
        let mut inner = self.inner.lock().unwrap();
        if only_absent && inner.map.contains_key(&fp) {
            return Ok(false);
        }
        let Some(file) = inner.file.as_mut() else {
            bail!("store {} was opened read-only; appends are refused", self.dir.display());
        };
        if let Err(e) = file.write_all(line.as_bytes()) {
            let end = inner.end;
            // Best effort: if the truncate also fails the torn bytes
            // stay, and the next open's tail repair handles them as
            // long as nothing else is appended after.
            if let Some(file) = inner.file.as_ref() {
                let _ = file.set_len(end);
            }
            return Err(e).context("appending WAL line");
        }
        inner.end += line.len() as u64;
        inner.map.insert(fp, rec.clone());
        inner.lines += 1;
        wal_metrics().appends.inc();
        Ok(true)
    }

    /// Snapshot of every stored (fingerprint, record) pair, in
    /// deterministic fingerprint order — the oplib fold input.
    pub fn records(&self) -> Vec<(Fingerprint, RunRecord)> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<(Fingerprint, RunRecord)> =
            inner.map.iter().map(|(k, v)| (*k, v.clone())).collect();
        out.sort_by_key(|(fp, _)| *fp);
        out
    }
}

/// Render one WAL line (without the trailing newline). Deterministic:
/// `Json::render` sorts keys and escapes to ASCII.
fn wal_line(fp: Fingerprint, rec: &RunRecord) -> String {
    let mut m = std::collections::BTreeMap::new();
    m.insert("fp".to_string(), Json::Str(fp.to_string()));
    m.insert("record".to_string(), rec.to_json());
    Json::Obj(m).render()
}

fn parse_wal_line(line: &str) -> Result<(Fingerprint, RunRecord)> {
    let j = Json::parse(line)?;
    let fp_str = j
        .get("fp")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing \"fp\""))?;
    let fp = Fingerprint::parse(fp_str)
        .ok_or_else(|| anyhow!("bad fingerprint {fp_str:?}"))?;
    let rec = RunRecord::from_json(
        j.get("record").ok_or_else(|| anyhow!("missing \"record\""))?,
    )?;
    Ok((fp, rec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Method;

    fn rec(et: u64, area: f64) -> RunRecord {
        RunRecord {
            bench: "adder_i4",
            method: Method::Shared,
            et,
            area,
            max_err: et,
            mean_err: 0.5,
            proxy: (1, 2),
            elapsed_ms: 9,
            cached: false,
            values: vec![0, 1, 2, 3],
            all_points: vec![(1, 2, area)],
            error: None,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("sxpat_wal_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn append_get_reopen() {
        let dir = tmp_dir("basic");
        let fp = Fingerprint(0xABCD);
        {
            let st = Store::open(&dir).unwrap();
            assert!(st.is_empty());
            st.append(fp, &rec(2, 10.0)).unwrap();
            assert_eq!(st.get(fp).unwrap().area, 10.0);
            assert_eq!(st.len(), 1);
        }
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 1);
        assert_eq!(st.get(fp).unwrap(), rec(2, 10.0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn duplicate_keys_resolve_last_writer_wins() {
        let dir = tmp_dir("lww");
        let fp = Fingerprint(7);
        {
            let st = Store::open(&dir).unwrap();
            st.append(fp, &rec(2, 10.0)).unwrap();
            st.append(fp, &rec(2, 8.5)).unwrap();
            assert_eq!(st.len(), 1, "one key");
            assert_eq!(st.lines(), 2, "two physical lines");
            assert_eq!(st.get(fp).unwrap().area, 8.5);
        }
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.get(fp).unwrap().area, 8.5, "replay keeps the last");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_dropped_and_repaired() {
        let dir = tmp_dir("torn");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &rec(1, 5.0)).unwrap();
            st.append(Fingerprint(2), &rec(2, 6.0)).unwrap();
        }
        // Simulate a crash mid-append: half a line, no newline.
        let wal = dir.join(WAL_FILE);
        let mut f = OpenOptions::new().append(true).open(&wal).unwrap();
        f.write_all(b"{\"fp\":\"00000000000000").unwrap();
        drop(f);
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 2, "torn tail dropped, good lines kept");
        // The repair truncated the torn bytes: a fresh append and reopen
        // must see 3 clean records.
        st.append(Fingerprint(3), &rec(4, 7.0)).unwrap();
        drop(st);
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 3);
        assert_eq!(st.get(Fingerprint(3)).unwrap().area, 7.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn complete_parsable_tail_without_newline_is_torn() {
        // Even a tail that parses is torn if its newline is missing —
        // keeping it would glue the next append onto it.
        let dir = tmp_dir("noeol");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &rec(1, 5.0)).unwrap();
            st.append(Fingerprint(2), &rec(2, 6.0)).unwrap();
        }
        let wal = dir.join(WAL_FILE);
        let text = std::fs::read_to_string(&wal).unwrap();
        std::fs::write(&wal, text.trim_end_matches('\n')).unwrap();
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 1, "newline-less tail treated as torn");
        assert!(st.get(Fingerprint(1)).is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_middle_line_fails_loudly() {
        let dir = tmp_dir("midcorrupt");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &rec(1, 5.0)).unwrap();
        }
        let wal = dir.join(WAL_FILE);
        let mut text = std::fs::read_to_string(&wal).unwrap();
        text = format!("garbage not json\n{text}");
        std::fs::write(&wal, text).unwrap();
        assert!(Store::open(&dir).is_err(), "mid-log corruption must not be silent");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn second_writer_is_locked_out() {
        let dir = tmp_dir("lock");
        let st = Store::open(&dir).unwrap();
        let err = Store::open(&dir).unwrap_err().to_string();
        assert!(err.contains("locked"), "{err}");
        assert!(err.contains("LOCK"), "must name the lock file: {err}");
        // Readers are not locked out while the writer is live.
        st.append(Fingerprint(1), &rec(1, 5.0)).unwrap();
        let ro = Store::open_read_only(&dir).unwrap();
        assert_eq!(ro.get(Fingerprint(1)).unwrap().area, 5.0);
        assert!(ro.append(Fingerprint(2), &rec(2, 6.0)).is_err(), "read-only refuses appends");
        // Dropping the writer releases the lock.
        drop(st);
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn stale_lock_from_dead_process_is_reclaimed() {
        let dir = tmp_dir("stale");
        std::fs::create_dir_all(&dir).unwrap();
        // A pid far above any default pid_max: provably not alive.
        std::fs::write(dir.join(LOCK_FILE), "999999999").unwrap();
        let st = Store::open(&dir).unwrap();
        st.append(Fingerprint(1), &rec(1, 5.0)).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn orphaned_reclaim_guard_blocks_stale_reclaim() {
        let dir = tmp_dir("reguard");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(LOCK_FILE), "999999999").unwrap();
        std::fs::write(dir.join("LOCK.reclaim"), "999999998").unwrap();
        let err = Store::open(&dir).unwrap_err().to_string();
        assert!(err.contains("reclaim"), "{err}");
        // Removing the orphaned guard unblocks the reclaim.
        std::fs::remove_file(dir.join("LOCK.reclaim")).unwrap();
        let st = Store::open(&dir).unwrap();
        drop(st);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unreadable_lock_is_treated_as_held() {
        let dir = tmp_dir("badlock");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(LOCK_FILE), "not a pid").unwrap();
        assert!(Store::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn read_only_store_leaves_torn_tail_on_disk() {
        let dir = tmp_dir("rotorn");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &rec(1, 5.0)).unwrap();
        }
        let wal = dir.join(WAL_FILE);
        let mut f = OpenOptions::new().append(true).open(&wal).unwrap();
        f.write_all(b"{\"fp\":\"torn").unwrap();
        drop(f);
        let before = std::fs::metadata(&wal).unwrap().len();
        let ro = Store::open_read_only(&dir).unwrap();
        assert_eq!(ro.len(), 1, "torn tail dropped from the view");
        assert_eq!(
            std::fs::metadata(&wal).unwrap().len(),
            before,
            "read-only open must not repair the file"
        );
        // Missing directories are empty stores, not errors.
        let missing = tmp_dir("romissing");
        let empty = Store::open_read_only(&missing).unwrap();
        assert!(empty.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_if_absent_keeps_first_committed() {
        let dir = tmp_dir("dedup");
        let st = Store::open(&dir).unwrap();
        let fp = Fingerprint(5);
        assert!(st.append_if_absent(fp, &rec(2, 10.0)).unwrap());
        assert!(!st.append_if_absent(fp, &rec(2, 99.0)).unwrap(), "duplicate skipped");
        assert_eq!(st.lines(), 1, "no WAL growth on the duplicate");
        assert_eq!(st.get(fp).unwrap().area, 10.0, "first committed wins");
        // Plain append still overwrites last-writer-wins (healing).
        st.append(fp, &rec(2, 8.0)).unwrap();
        assert_eq!(st.get(fp).unwrap().area, 8.0);
        assert_eq!(st.lines(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn error_strings_survive_the_wal() {
        let dir = tmp_dir("err");
        let mut r = rec(2, f64::INFINITY);
        r.error = Some("worker panicked: \"boom\"\n\tat cell (3, 4)".into());
        r.values = Vec::new();
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(9), &r).unwrap();
        }
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.get(Fingerprint(9)).unwrap(), r);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

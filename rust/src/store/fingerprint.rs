//! Stable job fingerprints for the persistent result store.
//!
//! A fingerprint identifies a solved job by *what was computed*, not
//! where or how fast: the benchmark's exhaustive truth table (function
//! identity — names are caller-supplied and untrustworthy), the method,
//! the error threshold, and — for the template methods only — every
//! [`SearchConfig`] field that can change the search result (pool /
//! lattice bounds / budget knobs). MUSCAT/MECALS/EXACT never read the
//! search config, so hashing it for them would only manufacture cache
//! misses when a user tweaks `--time-ms` between sweeps.
//!
//! `cell_workers` is deliberately excluded (per the store design): the
//! canonical scan is deterministic across worker counts, and the
//! sequential scan agrees with it on the committed best area on the
//! paper benchmarks (pinned by the engine's determinism tests), so the
//! same job at any worker count hits the same store slot. The residual
//! caveat is documented: the *scatter* (`all_points`) of a cumulative
//! 1-worker scan can differ from a canonical scan's, so a store written
//! at one mode serves the other mode's scatter — the figure-critical
//! best area is the invariant, not the enumeration order.
//! `share_blocked_models` IS included — it can change which models are
//! enumerated.
//!
//! The hash is a hand-rolled FNV-1a/64 over a tagged little-endian byte
//! serialization. `std::hash` is not used because `DefaultHasher` is
//! explicitly unstable across releases, and fingerprints live on disk
//! across toolchains and machines.

use std::fmt;

use crate::coordinator::Method;
use crate::search::SearchConfig;

/// A 64-bit content fingerprint, displayed as 16 lowercase hex digits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u64);

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

impl Fingerprint {
    /// Parse the 16-hex-digit form produced by `Display`.
    pub fn parse(s: &str) -> Option<Fingerprint> {
        if s.len() != 16 {
            return None;
        }
        u64::from_str_radix(s, 16).ok().map(Fingerprint)
    }
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x00000100000001b3;

/// Incremental FNV-1a/64 with per-field domain tags, so adjacent fields
/// cannot alias (e.g. `pool=1, et=2` vs `pool=12, et=<empty>`).
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(FNV_OFFSET)
    }

    fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(FNV_PRIME);
    }

    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    /// Start a new field: tag byte + implicit separator.
    fn field(&mut self, tag: u8) {
        self.byte(0xFE);
        self.byte(tag);
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        for b in s.as_bytes() {
            self.byte(*b);
        }
    }
}

/// Fingerprint of one (function, method, ET, search-config) job.
///
/// `exact` is the exhaustive output table (`2^n` entries) of the
/// benchmark netlist; `n`/`m` are its input/output counts (included
/// explicitly so two functions whose tables happen to agree on a prefix
/// cannot alias).
pub fn job_fingerprint(
    n: usize,
    m: usize,
    exact: &[u64],
    method: Method,
    et: u64,
    cfg: &SearchConfig,
) -> Fingerprint {
    let mut h = Fnv::new();
    h.field(0x01);
    h.u64(n as u64);
    h.field(0x02);
    h.u64(m as u64);
    h.field(0x03);
    h.u64(exact.len() as u64);
    for &v in exact {
        h.u64(v);
    }
    h.field(0x04);
    h.str(method.name());
    h.field(0x05);
    h.u64(et);
    // Search-relevant config: pool / lattice bounds / budget knobs.
    // NOT cell_workers (determinism-neutral, see module docs), and not
    // at all for the baseline/exact methods, which never read the
    // config — their results must serve across config changes.
    if matches!(method, Method::Shared | Method::Xpat) {
        h.field(0x06);
        h.u64(cfg.pool as u64);
        h.field(0x07);
        h.u64(cfg.solutions_per_cell as u64);
        h.field(0x08);
        h.u64(cfg.max_sat_cells as u64);
        h.field(0x09);
        match cfg.conflict_budget {
            None => h.byte(0),
            Some(b) => {
                h.byte(1);
                h.u64(b);
            }
        }
        h.field(0x0A);
        h.u64(cfg.time_budget_ms);
        h.field(0x0B);
        h.byte(cfg.share_blocked_models as u8);
    }
    Fingerprint(h.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SearchConfig {
        SearchConfig::default()
    }

    fn fp(et: u64, c: &SearchConfig) -> Fingerprint {
        job_fingerprint(4, 3, &[0, 1, 2, 3], Method::Shared, et, c)
    }

    #[test]
    fn stable_across_worker_counts() {
        let mut a = cfg();
        a.cell_workers = 1;
        let mut b = cfg();
        b.cell_workers = 8;
        assert_eq!(fp(2, &a), fp(2, &b), "cell_workers must not key the store");
    }

    #[test]
    fn sensitive_to_search_relevant_fields() {
        let base = fp(2, &cfg());
        assert_ne!(base, fp(3, &cfg()), "et");
        let mut c = cfg();
        c.pool += 1;
        assert_ne!(base, fp(2, &c), "pool");
        let mut c = cfg();
        c.solutions_per_cell += 1;
        assert_ne!(base, fp(2, &c), "solutions_per_cell");
        let mut c = cfg();
        c.max_sat_cells += 1;
        assert_ne!(base, fp(2, &c), "max_sat_cells");
        let mut c = cfg();
        c.conflict_budget = None;
        assert_ne!(base, fp(2, &c), "conflict_budget");
        let mut c = cfg();
        c.time_budget_ms += 1;
        assert_ne!(base, fp(2, &c), "time_budget_ms");
        let mut c = cfg();
        c.share_blocked_models = true;
        assert_ne!(base, fp(2, &c), "share_blocked_models");
    }

    #[test]
    fn sensitive_to_function_and_method() {
        let base = fp(2, &cfg());
        let other_tt =
            job_fingerprint(4, 3, &[0, 1, 2, 4], Method::Shared, 2, &cfg());
        assert_ne!(base, other_tt, "truth table");
        let other_m = job_fingerprint(4, 3, &[0, 1, 2, 3], Method::Xpat, 2, &cfg());
        assert_ne!(base, other_m, "method");
    }

    #[test]
    fn baseline_methods_ignore_search_config() {
        // MUSCAT/MECALS/EXACT never read SearchConfig, so their store
        // slots must survive config tweaks between sweeps.
        let mut other = cfg();
        other.pool += 3;
        other.time_budget_ms /= 2;
        other.conflict_budget = None;
        for m in [Method::Muscat, Method::Mecals, Method::Exact] {
            let a = job_fingerprint(4, 3, &[0, 1, 2, 3], m, 2, &cfg());
            let b = job_fingerprint(4, 3, &[0, 1, 2, 3], m, 2, &other);
            assert_eq!(a, b, "{}", m.name());
        }
        // ...while the template methods stay config-sensitive.
        let a = job_fingerprint(4, 3, &[0, 1, 2, 3], Method::Shared, 2, &cfg());
        let b = job_fingerprint(4, 3, &[0, 1, 2, 3], Method::Shared, 2, &other);
        assert_ne!(a, b);
    }

    #[test]
    fn display_parse_round_trip() {
        let f = fp(2, &cfg());
        assert_eq!(Fingerprint::parse(&f.to_string()), Some(f));
        assert_eq!(f.to_string().len(), 16);
        assert!(Fingerprint::parse("xyz").is_none());
        assert!(Fingerprint::parse("0123").is_none());
    }

    #[test]
    fn known_value_pins_cross_version_stability() {
        // FNV-1a over a fixed input must never change across releases:
        // this value is what an existing on-disk store was keyed with.
        let f = job_fingerprint(
            1,
            1,
            &[0, 1],
            Method::Shared,
            0,
            &SearchConfig {
                pool: 2,
                solutions_per_cell: 1,
                max_sat_cells: 1,
                conflict_budget: Some(10),
                time_budget_ms: 1000,
                cell_workers: 1,
                share_blocked_models: false,
            },
        );
        // Computed independently (reference FNV-1a implementation) at
        // introduction time; a mismatch means the serialization changed
        // and every existing store on disk silently misses.
        assert_eq!(f, Fingerprint(0xda9fb58d1e40d6a3));
        assert_eq!(f.to_string(), "da9fb58d1e40d6a3");
    }
}

//! Generators for the paper's benchmark circuits (§IV): ripple-carry
//! adders and array multipliers with 2-, 3- and 4-bit operands, named
//! `adder_i4/i6/i8` and `mult_i4/i6/i8` after their *total input* count,
//! exactly as in the paper.
//!
//! Input bus convention (shared with the python evaluator and the
//! template layer): inputs `0..bits` are operand A (LSB first), inputs
//! `bits..2*bits` are operand B; outputs are LSB first.

use super::netlist::{GateKind, Netlist, NodeId};

/// A named benchmark with its paper-conventional error-threshold sweep.
#[derive(Debug, Clone)]
pub struct Benchmark {
    pub name: &'static str,
    pub bits: usize,
    pub is_adder: bool,
}

impl Benchmark {
    pub fn netlist(&self) -> Netlist {
        if self.is_adder {
            adder(self.bits)
        } else {
            multiplier(self.bits)
        }
    }

    pub fn n_inputs(&self) -> usize {
        2 * self.bits
    }

    pub fn n_outputs(&self) -> usize {
        if self.is_adder {
            self.bits + 1
        } else {
            2 * self.bits
        }
    }

    /// ET values swept in Fig. 5: powers of two up to half the output range
    /// (the paper sweeps "varying ET values" over this scale).
    pub fn et_sweep(&self) -> Vec<u64> {
        let m = self.n_outputs();
        (0..m as u32 - 1).map(|k| 1u64 << k).collect()
    }

    /// The fixed ET used for this benchmark's Fig. 4 proxy study.
    pub fn fig4_et(&self) -> u64 {
        match self.n_inputs() {
            4 => 2,
            6 => 8,
            _ => 16,
        }
    }
}

/// The six benchmarks of the paper's evaluation.
pub const PAPER_BENCHMARKS: [Benchmark; 6] = [
    Benchmark { name: "adder_i4", bits: 2, is_adder: true },
    Benchmark { name: "mult_i4", bits: 2, is_adder: false },
    Benchmark { name: "adder_i6", bits: 3, is_adder: true },
    Benchmark { name: "mult_i6", bits: 3, is_adder: false },
    Benchmark { name: "adder_i8", bits: 4, is_adder: true },
    Benchmark { name: "mult_i8", bits: 4, is_adder: false },
];

/// Look a benchmark up by its paper name (e.g. `"mult_i6"`).
pub fn benchmark_by_name(name: &str) -> Option<&'static Benchmark> {
    PAPER_BENCHMARKS.iter().find(|b| b.name == name)
}

fn full_adder(nl: &mut Netlist, a: NodeId, b: NodeId, cin: NodeId) -> (NodeId, NodeId) {
    let axb = nl.push(GateKind::Xor, vec![a, b]);
    let sum = nl.push(GateKind::Xor, vec![axb, cin]);
    let ab = nl.push(GateKind::And, vec![a, b]);
    let c_axb = nl.push(GateKind::And, vec![axb, cin]);
    let cout = nl.push(GateKind::Or, vec![ab, c_axb]);
    (sum, cout)
}

fn half_adder(nl: &mut Netlist, a: NodeId, b: NodeId) -> (NodeId, NodeId) {
    let sum = nl.push(GateKind::Xor, vec![a, b]);
    let cout = nl.push(GateKind::And, vec![a, b]);
    (sum, cout)
}

/// `bits`-bit + `bits`-bit ripple-carry adder (2*bits inputs, bits+1 outputs).
pub fn adder(bits: usize) -> Netlist {
    assert!(bits >= 1);
    let mut nl = Netlist::new(format!("adder_i{}", 2 * bits));
    let a: Vec<_> = (0..bits).map(|_| nl.add_input()).collect();
    let b: Vec<_> = (0..bits).map(|_| nl.add_input()).collect();

    let mut outs = Vec::with_capacity(bits + 1);
    let (s0, mut carry) = half_adder(&mut nl, a[0], b[0]);
    outs.push(s0);
    for k in 1..bits {
        let (s, c) = full_adder(&mut nl, a[k], b[k], carry);
        outs.push(s);
        carry = c;
    }
    outs.push(carry);
    nl.set_outputs(outs);
    nl
}

/// `bits` x `bits` unsigned array multiplier (2*bits inputs, 2*bits outputs).
///
/// Classic carry-save array: partial products `a_i AND b_j` reduced with
/// half/full adders row by row.
pub fn multiplier(bits: usize) -> Netlist {
    assert!(bits >= 1);
    let mut nl = Netlist::new(format!("mult_i{}", 2 * bits));
    let a: Vec<_> = (0..bits).map(|_| nl.add_input()).collect();
    let b: Vec<_> = (0..bits).map(|_| nl.add_input()).collect();

    // columns[k] = list of 1-bit signals of weight 2^k awaiting reduction.
    let mut columns: Vec<Vec<NodeId>> = vec![Vec::new(); 2 * bits];
    for i in 0..bits {
        for j in 0..bits {
            let pp = nl.push(GateKind::And, vec![a[i], b[j]]);
            columns[i + j].push(pp);
        }
    }

    // Column-compression: reduce each column to one bit, pushing carries
    // rightward. Deterministic order keeps the netlist reproducible.
    let mut outs = Vec::with_capacity(2 * bits);
    for k in 0..2 * bits {
        while columns[k].len() > 1 {
            if columns[k].len() >= 3 {
                let z = columns[k].pop().unwrap();
                let y = columns[k].pop().unwrap();
                let x = columns[k].pop().unwrap();
                let (s, c) = full_adder(&mut nl, x, y, z);
                columns[k].insert(0, s);
                columns[k + 1].push(c);
            } else {
                let y = columns[k].pop().unwrap();
                let x = columns[k].pop().unwrap();
                let (s, c) = half_adder(&mut nl, x, y);
                columns[k].insert(0, s);
                columns[k + 1].push(c);
            }
        }
        outs.push(match columns[k].first() {
            Some(&bit) => bit,
            None => nl.push(GateKind::Const0, vec![]),
        });
    }
    nl.set_outputs(outs);
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::sim::TruthTables;

    #[test]
    fn paper_benchmark_shapes() {
        for b in &PAPER_BENCHMARKS {
            let nl = b.netlist();
            assert!(nl.validate().is_ok(), "{}: {:?}", b.name, nl.validate());
            assert_eq!(nl.n_inputs(), b.n_inputs(), "{}", b.name);
            assert_eq!(nl.n_outputs(), b.n_outputs(), "{}", b.name);
            assert_eq!(nl.name, b.name);
        }
    }

    #[test]
    fn benchmark_lookup() {
        assert_eq!(benchmark_by_name("adder_i6").unwrap().bits, 3);
        assert!(benchmark_by_name("divider_i4").is_none());
    }

    #[test]
    fn et_sweep_covers_powers_of_two() {
        let b = benchmark_by_name("mult_i8").unwrap();
        assert_eq!(b.et_sweep(), vec![1, 2, 4, 8, 16, 32, 64]);
        let a = benchmark_by_name("adder_i4").unwrap();
        assert_eq!(a.et_sweep(), vec![1, 2]);
    }

    #[test]
    fn one_bit_multiplier_is_an_and() {
        let nl = multiplier(1);
        let tt = TruthTables::simulate(&nl);
        let vals = tt.output_values(&nl);
        assert_eq!(vals, vec![0, 0, 0, 1]);
    }

    // Full arithmetic equivalence for all bit widths is covered in sim.rs.
}

//! Structural-Verilog subset reader/writer.
//!
//! The paper consumes Verilog specifications of the benchmark circuits;
//! we generate them (`benchmarks/*.v`), write approximate results back
//! out, and can re-read both. The subset is primitive-gate structural
//! Verilog: `and/or/nand/nor/xor/xnor/not/buf` instantiations plus
//! `assign` of an identifier or a `1'b0`/`1'b1` constant. Gate
//! instantiations may appear in any order; the reader topologically
//! sorts while building the netlist.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use super::netlist::{GateKind, Netlist, NodeId};

/// Render `nl` as structural Verilog. Inputs are `in0..`, outputs
/// `out0..`, internal wires `w<id>`.
pub fn write_verilog(nl: &Netlist) -> String {
    let mut s = String::new();
    let ins: Vec<String> = (0..nl.n_inputs()).map(|i| format!("in{i}")).collect();
    let outs: Vec<String> = (0..nl.n_outputs()).map(|i| format!("out{i}")).collect();
    s.push_str(&format!(
        "module {} ({});\n",
        nl.name,
        ins.iter().chain(outs.iter()).cloned().collect::<Vec<_>>().join(", ")
    ));
    if !ins.is_empty() {
        s.push_str(&format!("  input {};\n", ins.join(", ")));
    }
    if !outs.is_empty() {
        s.push_str(&format!("  output {};\n", outs.join(", ")));
    }

    // Wire name per node: inputs map to their bus name, logic to w<id>.
    let mut name: HashMap<NodeId, String> = HashMap::new();
    for (i, &id) in nl.inputs.iter().enumerate() {
        name.insert(id, format!("in{i}"));
    }
    let live = nl.live_cone();
    let mut wires = Vec::new();
    for (id, g) in nl.gates.iter().enumerate() {
        if g.kind == GateKind::Input || !live[id] {
            continue;
        }
        let w = format!("w{id}");
        name.insert(id as NodeId, w.clone());
        wires.push(w);
    }
    if !wires.is_empty() {
        s.push_str(&format!("  wire {};\n", wires.join(", ")));
    }

    for (id, g) in nl.gates.iter().enumerate() {
        if !live[id] {
            continue;
        }
        match g.kind {
            GateKind::Input => {}
            GateKind::Const0 => {
                s.push_str(&format!("  assign w{id} = 1'b0;\n"));
            }
            GateKind::Const1 => {
                s.push_str(&format!("  assign w{id} = 1'b1;\n"));
            }
            _ => {
                let fanins: Vec<&str> =
                    g.fanins.iter().map(|f| name[f].as_str()).collect();
                s.push_str(&format!(
                    "  {} g{id} (w{id}, {});\n",
                    g.kind.verilog_name(),
                    fanins.join(", ")
                ));
            }
        }
    }
    for (i, &o) in nl.outputs.iter().enumerate() {
        s.push_str(&format!("  assign out{i} = {};\n", name[&o]));
    }
    s.push_str("endmodule\n");
    s
}

#[derive(Debug)]
enum Stmt {
    Gate { kind: GateKind, out: String, ins: Vec<String> },
    AssignWire { out: String, rhs: String },
    AssignConst { out: String, one: bool },
}

fn gate_kind(name: &str) -> Option<GateKind> {
    Some(match name {
        "and" => GateKind::And,
        "or" => GateKind::Or,
        "nand" => GateKind::Nand,
        "nor" => GateKind::Nor,
        "xor" => GateKind::Xor,
        "xnor" => GateKind::Xnor,
        "not" => GateKind::Not,
        "buf" => GateKind::Buf,
        _ => return None,
    })
}

/// Parse the structural subset back into a [`Netlist`].
pub fn parse_verilog(src: &str) -> Result<Netlist> {
    // Strip comments, split into ';'-terminated statements.
    let mut clean = String::with_capacity(src.len());
    for line in src.lines() {
        let line = match line.find("//") {
            Some(p) => &line[..p],
            None => line,
        };
        clean.push_str(line);
        clean.push(' ');
    }

    let mut module_name = String::from("top");
    let mut input_order: Vec<String> = Vec::new();
    let mut output_order: Vec<String> = Vec::new();
    let mut stmts: Vec<Stmt> = Vec::new();

    for raw in clean.split(';') {
        let stmt = raw.trim().trim_end_matches("endmodule").trim();
        if stmt.is_empty() {
            continue;
        }
        let (head, rest) = match stmt.split_once(char::is_whitespace) {
            Some(p) => p,
            None => continue,
        };
        let rest = rest.trim();
        match head {
            "module" => {
                module_name = rest
                    .split(['(', ' '])
                    .next()
                    .ok_or_else(|| anyhow!("bad module header"))?
                    .to_string();
            }
            "input" => {
                input_order.extend(idents(rest));
            }
            "output" => {
                output_order.extend(idents(rest));
            }
            "wire" => {}
            "assign" => {
                let (lhs, rhs) = rest
                    .split_once('=')
                    .ok_or_else(|| anyhow!("bad assign: {stmt}"))?;
                let out = lhs.trim().to_string();
                let rhs = rhs.trim();
                match rhs {
                    "1'b0" => stmts.push(Stmt::AssignConst { out, one: false }),
                    "1'b1" => stmts.push(Stmt::AssignConst { out, one: true }),
                    ident => stmts.push(Stmt::AssignWire { out, rhs: ident.to_string() }),
                }
            }
            prim => {
                let kind = gate_kind(prim)
                    .ok_or_else(|| anyhow!("unsupported construct: {head}"))?;
                // "name (out, in...)": instance name is optional.
                let open = stmt.find('(').ok_or_else(|| anyhow!("bad gate: {stmt}"))?;
                let close =
                    stmt.rfind(')').ok_or_else(|| anyhow!("bad gate: {stmt}"))?;
                let ports: Vec<String> = idents(&stmt[open + 1..close]);
                if ports.len() < 2 {
                    bail!("gate with <2 ports: {stmt}");
                }
                stmts.push(Stmt::Gate {
                    kind,
                    out: ports[0].clone(),
                    ins: ports[1..].to_vec(),
                });
            }
        }
    }

    // Build: inputs first, then Kahn-style resolution of the statements.
    let mut nl = Netlist::new(module_name);
    let mut node_of: HashMap<String, NodeId> = HashMap::new();
    for name in &input_order {
        let id = nl.add_input();
        node_of.insert(name.clone(), id);
    }

    let mut pending: Vec<Stmt> = stmts;
    loop {
        let before = pending.len();
        pending.retain(|stmt| {
            let (out, resolved): (&str, Option<(GateKind, Vec<NodeId>)>) = match stmt {
                Stmt::Gate { kind, out, ins } => {
                    let fanins: Option<Vec<NodeId>> =
                        ins.iter().map(|i| node_of.get(i).copied()).collect();
                    (out, fanins.map(|f| (*kind, f)))
                }
                Stmt::AssignWire { out, rhs } => (
                    out,
                    node_of.get(rhs).copied().map(|id| (GateKind::Buf, vec![id])),
                ),
                Stmt::AssignConst { out, one } => (
                    out,
                    Some((if *one { GateKind::Const1 } else { GateKind::Const0 }, vec![])),
                ),
            };
            match resolved {
                Some((kind, fanins)) => {
                    let id = nl.push(kind, fanins);
                    node_of.insert(out.to_string(), id);
                    false
                }
                None => true,
            }
        });
        if pending.is_empty() {
            break;
        }
        if pending.len() == before {
            bail!("combinational cycle or undriven wires: {pending:?}");
        }
    }

    let outputs: Result<Vec<NodeId>> = output_order
        .iter()
        .map(|o| node_of.get(o).copied().ok_or_else(|| anyhow!("undriven output {o}")))
        .collect();
    nl.set_outputs(outputs?);
    nl.validate().map_err(|e| anyhow!(e))?;
    Ok(nl)
}

fn idents(s: &str) -> Vec<String> {
    s.split([',', ' ', '\t'])
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(str::to_string)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::PAPER_BENCHMARKS;
    use crate::circuit::sim::TruthTables;

    #[test]
    fn round_trip_all_benchmarks() {
        for b in &PAPER_BENCHMARKS {
            let nl = b.netlist();
            let v = write_verilog(&nl);
            let back = parse_verilog(&v).unwrap_or_else(|e| panic!("{}: {e}", b.name));
            assert_eq!(back.n_inputs(), nl.n_inputs());
            assert_eq!(back.n_outputs(), nl.n_outputs());
            let tt_a = TruthTables::simulate(&nl);
            let tt_b = TruthTables::simulate(&back);
            assert_eq!(
                tt_a.output_values(&nl),
                tt_b.output_values(&back),
                "functional mismatch after round-trip for {}",
                b.name
            );
        }
    }

    #[test]
    fn parses_out_of_order_gates() {
        let src = "
            module weird (in0, in1, out0);
              input in0, in1;
              output out0;
              wire a, b;
              // b depends on a but is declared first
              not g2 (b, a);
              and g1 (a, in0, in1);
              assign out0 = b;
            endmodule";
        let nl = parse_verilog(src).unwrap();
        let tt = TruthTables::simulate(&nl);
        assert_eq!(tt.output_values(&nl), vec![1, 1, 1, 0]); // NAND
    }

    #[test]
    fn parses_constants_and_buf() {
        let src = "
            module c (in0, out0, out1);
              input in0;
              output out0, out1;
              wire k;
              assign k = 1'b1;
              assign out0 = k;
              assign out1 = in0;
            endmodule";
        let nl = parse_verilog(src).unwrap();
        let tt = TruthTables::simulate(&nl);
        assert_eq!(tt.output_values(&nl), vec![1, 3]);
    }

    #[test]
    fn rejects_cycles() {
        let src = "
            module cyc (in0, out0);
              input in0; output out0;
              wire a, b;
              and g1 (a, b, in0);
              and g2 (b, a, in0);
              assign out0 = a;
            endmodule";
        assert!(parse_verilog(src).is_err());
    }

    #[test]
    fn rejects_undriven_output() {
        let src = "module u (in0, out0); input in0; output out0; endmodule";
        assert!(parse_verilog(src).is_err());
    }

    #[test]
    fn rejects_unknown_primitive() {
        let src = "module u (in0, out0); input in0; output out0; frob g (out0, in0); endmodule";
        assert!(parse_verilog(src).is_err());
    }
}

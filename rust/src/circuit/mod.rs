//! Gate-level circuit substrate: netlist representation, bit-parallel
//! exhaustive simulation, a Verilog-subset reader/writer, and generators
//! for the paper's benchmark set (ripple-carry adders and array
//! multipliers at bitwidths 2/3/4 — `adder_i4..mult_i8`, §IV).

pub mod generators;
pub mod netlist;
pub mod sim;
pub mod verilog;

pub use generators::{adder, benchmark_by_name, multiplier, Benchmark, PAPER_BENCHMARKS};
pub use netlist::{Gate, GateKind, Netlist, NodeId};
pub use sim::TruthTables;

//! Gate-level netlist in topological order.
//!
//! Nodes are appended after their fanins, so a single forward pass is a
//! valid evaluation order. This is the interchange representation between
//! the Verilog reader, the template extractor, the AIG optimiser and the
//! exhaustive simulator.

/// Index of a gate inside a [`Netlist`].
pub type NodeId = u32;

/// Primitive gate kinds. `Input` gates carry no fanins; constants carry
/// none either. Everything else is a standard boolean function of its
/// fanin list (`Not`/`Buf` are unary, the rest n-ary with n >= 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GateKind {
    Input,
    Const0,
    Const1,
    Buf,
    Not,
    And,
    Or,
    Nand,
    Nor,
    Xor,
    Xnor,
}

impl GateKind {
    /// Evaluate the gate over bit-parallel words (one bit per input point).
    pub fn eval_words(self, fanins: &[u64]) -> u64 {
        match self {
            GateKind::Input => unreachable!("inputs are simulated directly"),
            GateKind::Const0 => 0,
            GateKind::Const1 => !0,
            GateKind::Buf => fanins[0],
            GateKind::Not => !fanins[0],
            GateKind::And => fanins.iter().fold(!0u64, |a, &b| a & b),
            GateKind::Or => fanins.iter().fold(0u64, |a, &b| a | b),
            GateKind::Nand => !fanins.iter().fold(!0u64, |a, &b| a & b),
            GateKind::Nor => !fanins.iter().fold(0u64, |a, &b| a | b),
            GateKind::Xor => fanins.iter().fold(0u64, |a, &b| a ^ b),
            GateKind::Xnor => !fanins.iter().fold(0u64, |a, &b| a ^ b),
        }
    }

    /// Verilog operator / primitive name used by the writer.
    pub fn verilog_name(self) -> &'static str {
        match self {
            GateKind::Input => "input",
            GateKind::Const0 => "const0",
            GateKind::Const1 => "const1",
            GateKind::Buf => "buf",
            GateKind::Not => "not",
            GateKind::And => "and",
            GateKind::Or => "or",
            GateKind::Nand => "nand",
            GateKind::Nor => "nor",
            GateKind::Xor => "xor",
            GateKind::Xnor => "xnor",
        }
    }
}

/// One gate: a kind plus fanin node ids (empty for inputs/constants).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Gate {
    pub kind: GateKind,
    pub fanins: Vec<NodeId>,
}

/// A combinational netlist. Invariants (checked by [`Netlist::validate`]):
/// gates are in topological order; `inputs` lists every `Input` gate in
/// bus order (LSB first, operand A before operand B); `outputs` lists the
/// output bus LSB-first and may reference any node.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    pub name: String,
    pub gates: Vec<Gate>,
    pub inputs: Vec<NodeId>,
    pub outputs: Vec<NodeId>,
}

impl Netlist {
    pub fn new(name: impl Into<String>) -> Self {
        Netlist { name: name.into(), ..Default::default() }
    }

    pub fn n_inputs(&self) -> usize {
        self.inputs.len()
    }

    pub fn n_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// Number of non-input, non-constant gates (a crude size metric; the
    /// synthesised-area metric lives in [`crate::synth`]).
    pub fn n_logic_gates(&self) -> usize {
        self.gates
            .iter()
            .filter(|g| {
                !matches!(g.kind, GateKind::Input | GateKind::Const0 | GateKind::Const1)
            })
            .count()
    }

    pub fn add_input(&mut self) -> NodeId {
        let id = self.push(GateKind::Input, vec![]);
        self.inputs.push(id);
        id
    }

    pub fn push(&mut self, kind: GateKind, fanins: Vec<NodeId>) -> NodeId {
        debug_assert!(fanins.iter().all(|&f| (f as usize) < self.gates.len()));
        let id = self.gates.len() as NodeId;
        self.gates.push(Gate { kind, fanins });
        id
    }

    pub fn set_outputs(&mut self, outputs: Vec<NodeId>) {
        self.outputs = outputs;
    }

    /// Check the structural invariants; returns a description of the first
    /// violation, if any.
    pub fn validate(&self) -> Result<(), String> {
        for (i, g) in self.gates.iter().enumerate() {
            for &f in &g.fanins {
                if f as usize >= i {
                    return Err(format!("gate {i} has non-topological fanin {f}"));
                }
            }
            let arity_ok = match g.kind {
                GateKind::Input | GateKind::Const0 | GateKind::Const1 => g.fanins.is_empty(),
                GateKind::Buf | GateKind::Not => g.fanins.len() == 1,
                _ => !g.fanins.is_empty(),
            };
            if !arity_ok {
                return Err(format!("gate {i} ({:?}) has bad arity {}", g.kind, g.fanins.len()));
            }
        }
        for &o in &self.outputs {
            if o as usize >= self.gates.len() {
                return Err(format!("dangling output {o}"));
            }
        }
        for &i in &self.inputs {
            if self.gates[i as usize].kind != GateKind::Input {
                return Err(format!("input list entry {i} is not an Input gate"));
            }
        }
        let declared = self.inputs.len();
        let actual = self.gates.iter().filter(|g| g.kind == GateKind::Input).count();
        if declared != actual {
            return Err(format!("{actual} Input gates but {declared} declared inputs"));
        }
        Ok(())
    }

    /// Ids of gates reachable from the outputs (the "live" cone).
    pub fn live_cone(&self) -> Vec<bool> {
        let mut live = vec![false; self.gates.len()];
        let mut stack: Vec<NodeId> = self.outputs.clone();
        while let Some(id) = stack.pop() {
            if std::mem::replace(&mut live[id as usize], true) {
                continue;
            }
            stack.extend_from_slice(&self.gates[id as usize].fanins);
        }
        live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor2() -> Netlist {
        let mut nl = Netlist::new("xor2");
        let a = nl.add_input();
        let b = nl.add_input();
        let x = nl.push(GateKind::Xor, vec![a, b]);
        nl.set_outputs(vec![x]);
        nl
    }

    #[test]
    fn build_and_validate() {
        let nl = xor2();
        assert!(nl.validate().is_ok());
        assert_eq!(nl.n_inputs(), 2);
        assert_eq!(nl.n_outputs(), 1);
        assert_eq!(nl.n_logic_gates(), 1);
    }

    #[test]
    fn validate_rejects_non_topological() {
        let mut nl = xor2();
        nl.gates[0].fanins = vec![2]; // input gains a forward fanin
        assert!(nl.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_arity() {
        let mut nl = xor2();
        nl.gates[2].kind = GateKind::Not; // Not with two fanins
        assert!(nl.validate().is_err());
    }

    #[test]
    fn validate_rejects_dangling_output() {
        let mut nl = xor2();
        nl.outputs = vec![99];
        assert!(nl.validate().is_err());
    }

    #[test]
    fn live_cone_skips_dead_gates() {
        let mut nl = xor2();
        let a = nl.inputs[0];
        let dead = nl.push(GateKind::Not, vec![a]);
        let live = nl.live_cone();
        assert!(!live[dead as usize]);
        assert!(live[2]); // the xor
    }

    #[test]
    fn gate_eval_words() {
        assert_eq!(GateKind::And.eval_words(&[0b1100, 0b1010]), 0b1000);
        assert_eq!(GateKind::Or.eval_words(&[0b1100, 0b1010]), 0b1110);
        assert_eq!(GateKind::Xor.eval_words(&[0b1100, 0b1010]), 0b0110);
        assert_eq!(GateKind::Nand.eval_words(&[0b1100, 0b1010]), !0b1000u64);
        assert_eq!(GateKind::Nor.eval_words(&[0b1100, 0b1010]), !0b1110u64);
        assert_eq!(GateKind::Xnor.eval_words(&[0b1100, 0b1010]), !0b0110u64);
        assert_eq!(GateKind::Not.eval_words(&[0b1]), !0b1u64);
        assert_eq!(GateKind::Buf.eval_words(&[42]), 42);
        assert_eq!(GateKind::Const0.eval_words(&[]), 0);
        assert_eq!(GateKind::Const1.eval_words(&[]), !0);
    }

    #[test]
    fn nary_gates() {
        // 3-input AND over packed words.
        assert_eq!(GateKind::And.eval_words(&[0b1110, 0b1101, 0b1011]), 0b1000);
        assert_eq!(GateKind::Xor.eval_words(&[0b1, 0b1, 0b1]), 0b1);
    }
}

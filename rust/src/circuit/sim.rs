//! Bit-parallel exhaustive simulation.
//!
//! For a circuit with `n` inputs we evaluate all `2^n` input points at
//! once, packing 64 points per `u64` word. Input point `x` (an integer
//! whose bit `j` is the value of input `j` — LSB-first, matching the
//! python truth table in `compile/kernels/sop_eval.py`) lands in word
//! `x / 64`, bit `x % 64`.
//!
//! This is the sound-and-complete error oracle for every circuit in the
//! paper's benchmark set (n <= 8 means at most 4 words per signal) and the
//! rust-side cross-check of the PJRT evaluator artifact.

use super::netlist::{GateKind, Netlist, NodeId};

/// Truth tables for every gate of a netlist, one `Vec<u64>` row per gate.
#[derive(Debug, Clone)]
pub struct TruthTables {
    pub n_inputs: usize,
    pub words: usize,
    rows: Vec<Vec<u64>>,
}

/// The canonical truth-table row of input variable `j` out of `n`.
pub fn input_pattern(j: usize, n: usize, words: usize) -> Vec<u64> {
    let mut row = vec![0u64; words];
    if j < 6 {
        // Pattern repeats within a word: 2^j zeros then 2^j ones.
        let period = 1u64 << (j + 1);
        let mut w = 0u64;
        for bit in 0..64 {
            if (bit as u64) % period >= period / 2 {
                w |= 1 << bit;
            }
        }
        for r in row.iter_mut() {
            *r = w;
        }
    } else {
        // Whole words alternate.
        let wperiod = 1usize << (j - 6 + 1);
        for (wi, r) in row.iter_mut().enumerate() {
            if wi % wperiod >= wperiod / 2 {
                *r = !0;
            }
        }
    }
    // Mask out points beyond 2^n when n < 6.
    if n < 6 {
        let mask = (1u64 << (1usize << n)) - 1;
        row[0] &= mask;
    }
    row
}

impl TruthTables {
    /// Simulate every gate of `nl` over all `2^n` input points.
    pub fn simulate(nl: &Netlist) -> Self {
        let n = nl.n_inputs();
        assert!(n <= 16, "exhaustive simulation capped at 16 inputs");
        let words = (1usize << n).div_ceil(64);
        let mask = if n < 6 { (1u64 << (1usize << n)) - 1 } else { !0 };

        let mut rows: Vec<Vec<u64>> = Vec::with_capacity(nl.gates.len());
        let mut input_idx = 0usize;
        let mut fanin_buf: Vec<u64> = Vec::new();
        for gate in &nl.gates {
            let row = match gate.kind {
                GateKind::Input => {
                    let r = input_pattern(input_idx, n, words);
                    input_idx += 1;
                    r
                }
                _ => {
                    let mut row = vec![0u64; words];
                    for w in 0..words {
                        fanin_buf.clear();
                        fanin_buf
                            .extend(gate.fanins.iter().map(|&f| rows[f as usize][w]));
                        row[w] = gate.kind.eval_words(&fanin_buf) & mask;
                    }
                    row
                }
            };
            rows.push(row);
        }
        TruthTables { n_inputs: n, words, rows }
    }

    pub fn row(&self, id: NodeId) -> &[u64] {
        &self.rows[id as usize]
    }

    /// Value of gate `id` at input point `x`.
    pub fn bit(&self, id: NodeId, x: usize) -> bool {
        (self.rows[id as usize][x / 64] >> (x % 64)) & 1 == 1
    }

    /// Integer interpretation (LSB-first output bus) at input point `x`.
    pub fn output_value(&self, nl: &Netlist, x: usize) -> u64 {
        nl.outputs
            .iter()
            .enumerate()
            .fold(0u64, |acc, (i, &o)| acc | ((self.bit(o, x) as u64) << i))
    }

    /// All output values, indexed by input point.
    pub fn output_values(&self, nl: &Netlist) -> Vec<u64> {
        (0..1usize << self.n_inputs)
            .map(|x| self.output_value(nl, x))
            .collect()
    }
}

/// Maximum and mean absolute error distance between two same-shape circuits.
pub fn error_stats(exact: &[u64], approx: &[u64]) -> (u64, f64) {
    assert_eq!(exact.len(), approx.len());
    let mut max = 0u64;
    let mut sum = 0u128;
    for (&e, &a) in exact.iter().zip(approx) {
        let d = e.abs_diff(a);
        max = max.max(d);
        sum += d as u128;
    }
    (max, sum as f64 / exact.len() as f64)
}

/// `true` iff `approx` never deviates from `exact` by more than `et`.
pub fn is_sound(exact: &[u64], approx: &[u64], et: u64) -> bool {
    exact.iter().zip(approx).all(|(&e, &a)| e.abs_diff(a) <= et)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::{adder, multiplier};
    use crate::circuit::netlist::{GateKind, Netlist};

    #[test]
    fn input_patterns_are_binary_counting() {
        // For every input point x, bit j of x must equal pattern j at x.
        for n in 1..=8 {
            let words = (1usize << n).div_ceil(64);
            for j in 0..n {
                let row = input_pattern(j, n, words);
                for x in 0..1usize << n {
                    let got = (row[x / 64] >> (x % 64)) & 1;
                    assert_eq!(got, ((x >> j) & 1) as u64, "n={n} j={j} x={x}");
                }
            }
        }
    }

    #[test]
    fn xor_truth_table() {
        let mut nl = Netlist::new("xor2");
        let a = nl.add_input();
        let b = nl.add_input();
        let x = nl.push(GateKind::Xor, vec![a, b]);
        nl.set_outputs(vec![x]);
        let tt = TruthTables::simulate(&nl);
        assert_eq!(tt.row(x)[0], 0b0110);
    }

    #[test]
    fn adder_values_match_arithmetic() {
        for bits in 1..=4 {
            let nl = adder(bits);
            let tt = TruthTables::simulate(&nl);
            let vals = tt.output_values(&nl);
            for x in 0..1usize << (2 * bits) {
                let a = x & ((1 << bits) - 1);
                let b = x >> bits;
                assert_eq!(vals[x], (a + b) as u64, "bits={bits} a={a} b={b}");
            }
        }
    }

    #[test]
    fn multiplier_values_match_arithmetic() {
        for bits in 1..=4 {
            let nl = multiplier(bits);
            let tt = TruthTables::simulate(&nl);
            let vals = tt.output_values(&nl);
            for x in 0..1usize << (2 * bits) {
                let a = x & ((1 << bits) - 1);
                let b = x >> bits;
                assert_eq!(vals[x], (a * b) as u64, "bits={bits} a={a} b={b}");
            }
        }
    }

    #[test]
    fn error_stats_basics() {
        let exact = vec![0, 1, 2, 3];
        let approx = vec![0, 2, 2, 1];
        let (max, mean) = error_stats(&exact, &approx);
        assert_eq!(max, 2);
        assert!((mean - 0.75).abs() < 1e-12);
        assert!(is_sound(&exact, &approx, 2));
        assert!(!is_sound(&exact, &approx, 1));
    }

    #[test]
    fn seven_input_sim_uses_two_words() {
        // Cross-word correctness: 7-input AND fires only at x = 127.
        let mut nl = Netlist::new("and7");
        let ins: Vec<_> = (0..7).map(|_| nl.add_input()).collect();
        let g = nl.push(GateKind::And, ins);
        nl.set_outputs(vec![g]);
        let tt = TruthTables::simulate(&nl);
        assert_eq!(tt.words, 2);
        assert_eq!(tt.row(g)[0], 0);
        assert_eq!(tt.row(g)[1], 1u64 << 63);
    }
}

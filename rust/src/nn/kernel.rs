//! Compiled branchless batch kernels — the serving hot path.
//!
//! [`QuantMlp::classify_batch`] walks one `MultLut::mul` table lookup
//! plus a weight decode and a sign branch per (unit, pixel, image)
//! triple, so the batched path is byte-identical to the sequential one
//! but barely faster. [`CompiledMlp::compile`] instead folds the
//! network's weights *into* the operator, the way approximate
//! multipliers are compiled into an accelerator datapath rather than
//! called through (Armeniakos et al.; QoS-Nets — see PAPERS.md):
//!
//! - For every (unit, input) weight `(mag, neg)` it precomputes a
//!   16-entry signed product row `row[x] = ±lut.mul(mag, x)` as `i16`
//!   (sign baked in), laid out contiguously per unit. At inference
//!   time the weight decode, the two-level LUT index arithmetic and
//!   the sign branch are all gone — the inner loop is a pure
//!   gather-accumulate.
//! - Images are processed in fixed-width lanes of [`LANES`] (tail
//!   blocks zero-padded, padding lanes discarded): each block is
//!   transposed into structure-of-arrays pixel order so the innermost
//!   loop runs the *same* product row over [`LANES`] images with a
//!   compile-time trip count and no bounds checks — the shape LLVM
//!   autovectorises (and, failing a gather ISA, at least unrolls into
//!   branch-free scalar code).
//!
//! Byte-identity with the scalar paths is by construction, not by
//! testing alone: row entries equal the scalar products exactly
//! (`i16 -> i32` sign extension is value-preserving; `compile`
//! *rejects* any LUT whose products overflow `i16` rather than wrap),
//! layer-1 accumulation runs in the same `i = 0..n_in` order, and the
//! per-image ReLU/re-quantise ([`relu_requantise`]) and argmax
//! ([`argmax_i32`]) stages are the very same functions the scalar code
//! calls. `tests/kernel_parity.rs` fuzzes the equivalence across
//! random geometries, LUTs and batch shapes anyway.
//!
//! The serving layer compiles one kernel per QoS tier at registry
//! resolve/reload time (DESIGN.md §12); [`CompiledMlp::emit_rust_source`]
//! additionally renders a kernel as standalone Rust source — the
//! software mirror of the `python/compile/` AOT sketch.

use std::fmt::Write as _;

use super::digits::{Sample, N_CLASSES};
use super::mlp::{argmax_i32, check_batch_shape, relu_requantise, MultLut, QuantMlp};

/// Fixed SIMD-friendly lane width: one structure-of-arrays block holds
/// this many images. 16 × i32 accumulators fit two AVX2 registers (or
/// four NEON ones) and the block transpose stays L1-resident.
pub const LANES: usize = 16;

/// A [`QuantMlp`] with one specific [`MultLut`] folded into signed
/// product tables — immutable once compiled, cheap to share via `Arc`.
/// The serving registry compiles one per QoS tier and recompiles on
/// hot-reload; in-flight batches keep the kernel they resolved.
#[derive(Debug, Clone)]
pub struct CompiledMlp {
    hidden: usize,
    n_in: usize,
    /// Layer-1 product rows: `(hidden * n_in)` rows of 16 `i16`s; row
    /// `(u, i)` starts at `(u * n_in + i) * 16`, entry `x` holds
    /// `±lut.mul(mag, x)` with the weight's sign baked in.
    w1_rows: Vec<i16>,
    /// Layer-2 product rows, same shape over `(N_CLASSES * hidden)`.
    w2_rows: Vec<i16>,
}

impl CompiledMlp {
    /// Fold `lut` into `mlp`'s weights. Thin panicking wrapper over
    /// [`CompiledMlp::try_compile`] for tests, benches and trusted
    /// local operators.
    pub fn compile(mlp: &QuantMlp, lut: &MultLut) -> CompiledMlp {
        Self::try_compile(mlp, lut).expect("operator not compilable to i16 rows")
    }

    /// Fallible [`CompiledMlp::compile`] for serving paths: a stored
    /// table is only bounded by the 16-bit output bus, so a (legal but
    /// extreme) product beyond `i16::MAX` must surface as an error —
    /// the registry then keeps that tier on the scalar path instead of
    /// serving wrapped-around sums.
    pub fn try_compile(mlp: &QuantMlp, lut: &MultLut) -> Result<CompiledMlp, String> {
        Ok(CompiledMlp {
            hidden: mlp.hidden,
            n_in: mlp.n_in(),
            w1_rows: fold_rows(mlp.w1(), lut)?,
            w2_rows: fold_rows(mlp.w2(), lut)?,
        })
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    pub fn n_in(&self) -> usize {
        self.n_in
    }

    /// Classify one image (a one-lane batch; for hot loops prefer
    /// [`CompiledMlp::classify_batch`]).
    pub fn infer(&self, pixels: &[u8]) -> usize {
        self.classify_batch(&[pixels])[0]
    }

    /// Batched classification through the compiled tables —
    /// byte-identical to [`QuantMlp::infer`] per image with the
    /// compiled-in LUT.
    ///
    /// Library path: panics on shape/range errors exactly where
    /// [`QuantMlp::classify_batch`] does; the serving path uses
    /// [`CompiledMlp::try_classify_batch`].
    pub fn classify_batch(&self, images: &[&[u8]]) -> Vec<usize> {
        match self.try_classify_batch(images) {
            Ok(labels) => labels,
            Err(e) => panic!("CompiledMlp::classify_batch: {e}"),
        }
    }

    /// Fallible [`CompiledMlp::classify_batch`]: ragged batches,
    /// wrong-width images and out-of-range pixels are checked errors
    /// (the same [`check_batch_shape`] contract as the scalar path).
    pub fn try_classify_batch(&self, images: &[&[u8]]) -> Result<Vec<usize>, String> {
        check_batch_shape(images, self.n_in)?;
        let mut out = Vec::with_capacity(images.len());
        let mut block = vec![0u8; self.n_in * LANES];
        let mut h = vec![0i32; self.hidden * LANES];
        let mut hrow = vec![0i32; self.hidden];
        for chunk in images.chunks(LANES) {
            // Structure-of-arrays transpose: block[i * LANES + l] =
            // image l's pixel i. Tail blocks zero-pad the unused
            // lanes; their results are computed branchlessly and
            // discarded (an approximate LUT may map pixel 0 to a
            // non-zero product — that only ever lands in a lane we
            // never copy out).
            if chunk.len() < LANES {
                block.fill(0);
            }
            for (l, img) in chunk.iter().enumerate() {
                for (i, &px) in img.iter().enumerate() {
                    block[i * LANES + l] = px;
                }
            }
            self.layer1_block(&block, &mut h);
            for l in 0..chunk.len() {
                for (u, v) in hrow.iter_mut().enumerate() {
                    *v = h[u * LANES + l];
                }
                let hq = relu_requantise(&mut hrow);
                out.push(self.layer2_image(&hq));
            }
        }
        Ok(out)
    }

    /// Classification accuracy over a dataset — the compiled twin of
    /// [`QuantMlp::accuracy`], provably equal for the compiled-in LUT.
    pub fn accuracy(&self, data: &[Sample]) -> f64 {
        let images: Vec<&[u8]> = data.iter().map(|s| s.pixels.as_slice()).collect();
        let correct = self
            .classify_batch(&images)
            .iter()
            .zip(data)
            .filter(|&(&label, s)| label == s.label)
            .count();
        correct as f64 / data.len() as f64
    }

    /// Layer 1 over one SoA block: for every hidden unit, accumulate
    /// the unit's product rows across all [`LANES`] images at once.
    /// `chunks_exact` + the fixed-size accumulator array keep the
    /// innermost loop bounds-check-free with a compile-time trip
    /// count. Accumulation order over `i` matches the scalar paths.
    fn layer1_block(&self, block: &[u8], h: &mut [i32]) {
        debug_assert_eq!(block.len(), self.n_in * LANES);
        for (u, rows) in self.w1_rows.chunks_exact(self.n_in * 16).enumerate() {
            let mut acc = [0i32; LANES];
            for (row, px) in rows.chunks_exact(16).zip(block.chunks_exact(LANES)) {
                for l in 0..LANES {
                    acc[l] += row[px[l] as usize] as i32;
                }
            }
            h[u * LANES..(u + 1) * LANES].copy_from_slice(&acc);
        }
    }

    /// Layer 2 for one image's re-quantised activations (`hq` entries
    /// are 0..=15 by construction). Branchless like layer 1; the
    /// output stage is per-image anyway, so it shares no block state.
    fn layer2_image(&self, hq: &[u8]) -> usize {
        let mut o = [0i32; N_CLASSES];
        for (oc, rows) in o.iter_mut().zip(self.w2_rows.chunks_exact(self.hidden * 16)) {
            let mut acc = 0i32;
            for (row, &q) in rows.chunks_exact(16).zip(hq) {
                acc += row[q as usize] as i32;
            }
            *oc = acc;
        }
        argmax_i32(&o)
    }

    /// Render this kernel as standalone Rust source — a dependency-free
    /// `classify` function over baked-in product tables, the software
    /// mirror of the `python/compile/` AOT sketch (`sxpat synth
    /// --emit-kernel FILE`). The emitted scalar loop reproduces the
    /// library numerics exactly, including the last-maximal-class
    /// argmax tie-break.
    pub fn emit_rust_source(&self, name: &str) -> String {
        let mut src = String::new();
        let _ = writeln!(
            src,
            "//! `{name}`: compiled approximate-MLP kernel, generated by\n\
             //! `sxpat synth --emit-kernel` — do not edit.\n\
             //!\n\
             //! Product rows fold one 4x4 multiplier LUT and the trained\n\
             //! weights (signs baked in); `classify` is byte-identical to\n\
             //! the generating `QuantMlp::infer` with that LUT.\n"
        );
        let _ = writeln!(src, "pub const HIDDEN: usize = {};", self.hidden);
        let _ = writeln!(src, "pub const N_IN: usize = {};", self.n_in);
        let _ = writeln!(src, "pub const N_CLASSES: usize = {N_CLASSES};\n");
        emit_table(&mut src, "W1_ROWS", &self.w1_rows);
        emit_table(&mut src, "W2_ROWS", &self.w2_rows);
        src.push_str(
            "pub fn classify(pixels: &[u8; N_IN]) -> usize {\n\
             \x20   let mut h = [0i32; HIDDEN];\n\
             \x20   for u in 0..HIDDEN {\n\
             \x20       let mut acc = 0i32;\n\
             \x20       for i in 0..N_IN {\n\
             \x20           acc += W1_ROWS[(u * N_IN + i) * 16 + pixels[i] as usize] as i32;\n\
             \x20       }\n\
             \x20       h[u] = acc.max(0);\n\
             \x20   }\n\
             \x20   let mut hmax = 1i32;\n\
             \x20   for &v in &h {\n\
             \x20       hmax = hmax.max(v);\n\
             \x20   }\n\
             \x20   let mut best = 0usize;\n\
             \x20   let mut best_score = i32::MIN;\n\
             \x20   for c in 0..N_CLASSES {\n\
             \x20       let mut acc = 0i32;\n\
             \x20       for u in 0..HIDDEN {\n\
             \x20           let q = ((h[u] * 15) / hmax) as usize;\n\
             \x20           acc += W2_ROWS[(c * HIDDEN + u) * 16 + q] as i32;\n\
             \x20       }\n\
             \x20       // >= : ties resolve to the last maximal class, like the\n\
             \x20       // library's argmax.\n\
             \x20       if acc >= best_score {\n\
             \x20           best_score = acc;\n\
             \x20           best = c;\n\
             \x20       }\n\
             \x20   }\n\
             \x20   best\n\
             }\n",
        );
        src
    }
}

/// Fold one weight matrix into signed product rows: row `(w, x)` =
/// `±lut.mul(mag_w, x)`. Rejects products beyond `i16::MAX` — baking
/// the sign in must never change a value.
fn fold_rows(weights: &[(u8, bool)], lut: &MultLut) -> Result<Vec<i16>, String> {
    let mut rows = Vec::with_capacity(weights.len() * 16);
    for &(mag, neg) in weights {
        for x in 0..16u8 {
            let p = lut.mul(mag, x);
            if p > i16::MAX as u16 {
                return Err(format!(
                    "product {mag}*{x} = {p} exceeds the i16 product-row range; \
                     this operator must stay on the scalar path"
                ));
            }
            let p = p as i16;
            rows.push(if neg { -p } else { p });
        }
    }
    Ok(rows)
}

/// Render one product table as a `static` array, 16 entries per line
/// (one product row), deterministically.
fn emit_table(src: &mut String, name: &str, rows: &[i16]) {
    let _ = writeln!(src, "static {name}: [i16; {}] = [", rows.len());
    for row in rows.chunks(16) {
        src.push_str("    ");
        for v in row {
            let _ = write!(src, "{v}, ");
        }
        src.pop();
        src.push('\n');
    }
    src.push_str("];\n\n");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::digits::synthetic_digits;

    fn masked_lut(bits: u32) -> MultLut {
        let mask = !((1u64 << bits) - 1);
        let vals: Vec<u64> = (0..256u64).map(|x| ((x & 15) * (x >> 4)) & mask).collect();
        MultLut::from_values(&vals)
    }

    #[test]
    fn compiled_matches_scalar_on_the_trained_geometry() {
        let train = synthetic_digits(120, 11);
        let test = synthetic_digits(70, 77);
        let mlp = QuantMlp::train(&train, 9, 6, 5);
        for lut in [MultLut::exact(), masked_lut(2)] {
            let kernel = CompiledMlp::compile(&mlp, &lut);
            assert_eq!(kernel.hidden(), 9);
            assert_eq!(kernel.n_in(), 64);
            let images: Vec<&[u8]> = test.iter().map(|s| s.pixels.as_slice()).collect();
            let want: Vec<usize> =
                test.iter().map(|s| mlp.infer(&s.pixels, &lut)).collect();
            // Full batch (tail block), one lane block, and singles.
            assert_eq!(kernel.classify_batch(&images), want);
            assert_eq!(kernel.classify_batch(&images[..LANES]), want[..LANES]);
            assert_eq!(kernel.infer(&test[3].pixels), want[3]);
            assert!(kernel.classify_batch(&[]).is_empty());
            assert_eq!(kernel.accuracy(&test), mlp.accuracy(&test, &lut));
        }
    }

    #[test]
    fn compile_rejects_products_beyond_i16() {
        let mut vals: Vec<u64> = (0..256u64).map(|x| (x & 15) * (x >> 4)).collect();
        vals[255] = 40_000; // 15*15 slot: legal on the 16-bit bus, not in i16.
        let lut = MultLut::from_values(&vals);
        let mlp = QuantMlp::from_weights(
            1,
            vec![(15, false); 2],
            vec![(1, false); N_CLASSES],
        );
        let err = CompiledMlp::try_compile(&mlp, &lut).unwrap_err();
        assert!(err.contains("i16"), "{err}");
        // A magnitude that never indexes the poisoned slot compiles.
        let mlp = QuantMlp::from_weights(
            1,
            vec![(14, false); 2],
            vec![(1, false); N_CLASSES],
        );
        assert!(CompiledMlp::try_compile(&mlp, &lut).is_ok());
    }

    #[test]
    fn shape_errors_match_the_scalar_contract() {
        let mlp = QuantMlp::from_weights(
            2,
            vec![(3, true); 2 * 5],
            vec![(2, false); N_CLASSES * 2],
        );
        let lut = MultLut::exact();
        let kernel = CompiledMlp::compile(&mlp, &lut);
        let good: Vec<u8> = vec![1, 2, 3, 4, 5];
        let short: Vec<u8> = vec![1, 2];
        let batch = [good.as_slice(), short.as_slice()];
        assert_eq!(
            kernel.try_classify_batch(&batch).unwrap_err(),
            mlp.try_classify_batch(&batch, &lut).unwrap_err()
        );
        let hot: Vec<u8> = vec![1, 2, 3, 4, 99];
        assert_eq!(
            kernel.try_classify_batch(&[hot.as_slice()]).unwrap_err(),
            mlp.try_classify_batch(&[hot.as_slice()], &lut).unwrap_err()
        );
    }

    #[test]
    fn emitted_source_is_deterministic_and_complete() {
        let mlp = QuantMlp::from_weights(
            2,
            vec![(1, false), (2, true), (3, false), (0, true)],
            vec![(1, false); N_CLASSES * 2],
        );
        let kernel = CompiledMlp::compile(&mlp, &MultLut::exact());
        let src = kernel.emit_rust_source("demo");
        assert_eq!(src, kernel.emit_rust_source("demo"));
        assert!(src.contains("pub const HIDDEN: usize = 2;"), "{src}");
        assert!(src.contains("pub const N_IN: usize = 2;"), "{src}");
        assert!(src.contains(&format!("static W1_ROWS: [i16; {}]", 4 * 16)));
        assert!(src.contains(&format!("static W2_ROWS: [i16; {}]", N_CLASSES * 2 * 16)));
        assert!(src.contains("pub fn classify(pixels: &[u8; N_IN]) -> usize"));
        // Sign baking is visible in the table: (2, true) row of exact
        // products starts 0, -2, -4, ...
        assert!(src.contains("0, -2, -4"), "{src}");
    }
}

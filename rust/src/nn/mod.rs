//! The paper's motivating application (§I): quantised neural-network
//! inference on edge devices with approximate multipliers. A small MLP
//! with 4-bit weights/activations runs inference where every multiply is
//! a 16x16 lookup table — either the exact 4x4 multiplier or an
//! approximate one produced by any of the ALS methods — so classification
//! accuracy vs. multiplier area can be traded off exactly as in [1].

pub mod digits;
pub mod kernel;
pub mod mlp;

pub use digits::synthetic_digits;
pub use kernel::{CompiledMlp, LANES};
pub use mlp::{MultLut, QuantMlp};

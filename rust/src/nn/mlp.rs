//! Quantised two-layer MLP whose every multiplication goes through a
//! 4x4-bit multiplier lookup table — exact or approximate.
//!
//! Training is a tiny perceptron-style fit on the synthetic digits (all
//! integer arithmetic in the forward pass, so swapping the multiplier
//! LUT is the *only* difference between exact and approximate
//! inference). This mirrors how approximate multipliers are dropped into
//! edge NN accelerators [1].

use crate::circuit::sim::TruthTables;
use crate::circuit::Netlist;
use crate::util::Rng;

use super::digits::{Sample, IMG, N_CLASSES};

/// 16x16 unsigned multiplier lookup table (4-bit operands).
#[derive(Debug, Clone)]
pub struct MultLut {
    table: Vec<u16>, // 256 entries, index = a | (b << 4)
}

impl MultLut {
    pub fn exact() -> Self {
        let mut table = vec![0u16; 256];
        for a in 0..16u16 {
            for b in 0..16u16 {
                table[(a | (b << 4)) as usize] = a * b;
            }
        }
        MultLut { table }
    }

    /// Build from any 8-input circuit with the mult_i8 bus convention
    /// (inputs 0..4 = operand A LSB-first, 4..8 = operand B). Thin
    /// panicking wrapper over [`MultLut::try_from_netlist`] for tests
    /// and trusted local synthesis results.
    pub fn from_netlist(nl: &Netlist) -> Self {
        Self::try_from_netlist(nl).expect("malformed multiplier netlist")
    }

    /// Fallible [`MultLut::from_netlist`] for library-serving paths: a
    /// malformed store entry or circuit must degrade to an error
    /// response, not kill a serving worker.
    pub fn try_from_netlist(nl: &Netlist) -> Result<Self, String> {
        if nl.n_inputs() != 8 {
            return Err(format!(
                "expected a 4x4 multiplier (8 inputs), got {} inputs",
                nl.n_inputs()
            ));
        }
        let vals = TruthTables::simulate(nl).output_values(nl);
        Self::try_from_values(&vals)
    }

    /// Build directly from precomputed output values (e.g. the PJRT
    /// evaluator's `values` vector for a template instantiation). Thin
    /// panicking wrapper over [`MultLut::try_from_values`].
    pub fn from_values(vals: &[u64]) -> Self {
        Self::try_from_values(vals).expect("malformed multiplier table")
    }

    /// Fallible [`MultLut::from_values`]: the table must be exhaustive
    /// over 8 inputs and every entry must fit the 16-bit output bus —
    /// the silent-truncation hazard of `as u16` on a hand-edited or
    /// bit-rotted store entry.
    pub fn try_from_values(vals: &[u64]) -> Result<Self, String> {
        if vals.len() != 256 {
            return Err(format!("expected 256 table entries, got {}", vals.len()));
        }
        if let Some((i, &v)) =
            vals.iter().enumerate().find(|&(_, &v)| v > u64::from(u16::MAX))
        {
            return Err(format!("table entry {i} = {v} exceeds the 16-bit output bus"));
        }
        Ok(MultLut { table: vals.iter().map(|&v| v as u16).collect() })
    }

    #[inline]
    pub fn mul(&self, a: u8, b: u8) -> u16 {
        debug_assert!(a < 16 && b < 16);
        self.table[(a as usize) | ((b as usize) << 4)]
    }

    /// Worst-case absolute error against the exact product, computed
    /// inline — this sits on the `best_verified` serving/verify path,
    /// which calls it per resolution, so it must not allocate and
    /// rebuild the exact table every time.
    pub fn max_error(&self) -> u16 {
        let mut worst = 0u16;
        for a in 0..16u16 {
            for b in 0..16u16 {
                worst = worst.max(self.table[(a | (b << 4)) as usize].abs_diff(a * b));
            }
        }
        worst
    }
}

/// Two-layer quantised MLP: 64 -> hidden -> 10. Weights are 4-bit signed
/// magnitudes (sign handled outside the LUT, as in unsigned-multiplier
/// accelerator datapaths).
#[derive(Debug, Clone)]
pub struct QuantMlp {
    pub hidden: usize,
    /// [hidden][64]: (magnitude 0..=15, negative?).
    w1: Vec<(u8, bool)>,
    /// [10][hidden].
    w2: Vec<(u8, bool)>,
}

impl QuantMlp {
    /// Train with a simple sign-based perceptron rule, then quantise.
    pub fn train(data: &[Sample], hidden: usize, epochs: usize, seed: u64) -> Self {
        let n_in = IMG * IMG;
        let mut rng = Rng::seed_from(seed);
        // Float shadow weights for training only.
        let mut f1: Vec<f64> = (0..hidden * n_in)
            .map(|_| rng.f64() * 2.0 - 1.0)
            .collect();
        let mut f2: Vec<f64> = (0..N_CLASSES * hidden)
            .map(|_| rng.f64() * 2.0 - 1.0)
            .collect();
        let lr = 0.01;
        for _ in 0..epochs {
            for s in data {
                // Forward (float, for training signal).
                let h: Vec<f64> = (0..hidden)
                    .map(|u| {
                        let dot: f64 = (0..n_in)
                            .map(|i| f1[u * n_in + i] * s.pixels[i] as f64 / 15.0)
                            .sum();
                        dot.max(0.0)
                    })
                    .collect();
                let o: Vec<f64> = (0..N_CLASSES)
                    .map(|c| (0..hidden).map(|u| f2[c * hidden + u] * h[u]).sum())
                    .collect();
                let pred = argmax(&o);
                if pred == s.label {
                    continue;
                }
                // Perceptron update toward the true class, away from pred.
                for u in 0..hidden {
                    f2[s.label * hidden + u] += lr * h[u];
                    f2[pred * hidden + u] -= lr * h[u];
                    let backdelta = f2[s.label * hidden + u] - f2[pred * hidden + u];
                    if h[u] > 0.0 {
                        for i in 0..n_in {
                            f1[u * n_in + i] +=
                                lr * backdelta.signum() * s.pixels[i] as f64 / 15.0 * 0.1;
                        }
                    }
                }
            }
        }
        QuantMlp {
            hidden,
            w1: quantise(&f1),
            w2: quantise(&f2),
        }
    }

    /// Build directly from quantised weights — the constructor the
    /// differential-fuzz tests use to cover geometries `train` never
    /// produces. Panics unless `w1` is `hidden` rows of one fixed
    /// input width, `w2` is `N_CLASSES x hidden`, and every magnitude
    /// fits the 4-bit LUT operand range.
    pub fn from_weights(hidden: usize, w1: Vec<(u8, bool)>, w2: Vec<(u8, bool)>) -> QuantMlp {
        assert!(hidden > 0, "at least one hidden unit required");
        assert!(
            !w1.is_empty() && w1.len() % hidden == 0,
            "w1 must be hidden x n_in weights"
        );
        assert_eq!(w2.len(), N_CLASSES * hidden, "w2 must be N_CLASSES x hidden");
        assert!(
            w1.iter().chain(&w2).all(|&(mag, _)| mag < 16),
            "weight magnitudes must fit the 4-bit LUT operand"
        );
        QuantMlp { hidden, w1, w2 }
    }

    /// The trained input width (pixels per image).
    pub fn n_in(&self) -> usize {
        self.w1.len() / self.hidden
    }

    /// Layer-1 weights, `[hidden][n_in]` — read by the kernel compiler.
    pub(crate) fn w1(&self) -> &[(u8, bool)] {
        &self.w1
    }

    /// Layer-2 weights, `[N_CLASSES][hidden]` — read by the kernel compiler.
    pub(crate) fn w2(&self) -> &[(u8, bool)] {
        &self.w2
    }

    /// Integer forward pass; every product goes through `lut`.
    ///
    /// Library path: panics when `pixels` does not match the trained
    /// input width (the serving path validates shapes up front via
    /// [`QuantMlp::try_classify_batch`] instead).
    pub fn infer(&self, pixels: &[u8], lut: &MultLut) -> usize {
        let n_in = self.n_in();
        assert_eq!(pixels.len(), n_in, "image width != trained input width");
        let mut h: Vec<i32> = (0..self.hidden)
            .map(|u| {
                let mut acc = 0i32;
                for i in 0..n_in {
                    let (mag, neg) = self.w1[u * n_in + i];
                    let p = lut.mul(mag, pixels[i]) as i32;
                    acc += if neg { -p } else { p };
                }
                acc
            })
            .collect();
        let hq = relu_requantise(&mut h);
        self.layer2(&hq, lut)
    }

    /// Second LUT layer + argmax for one image's requantised
    /// activations — shared by [`QuantMlp::infer`] and
    /// [`QuantMlp::classify_batch`] (and mirrored product-for-product
    /// by the compiled kernel's folded rows), so the paths cannot
    /// drift numerically.
    fn layer2(&self, hq: &[u8], lut: &MultLut) -> usize {
        let o: Vec<i32> = (0..N_CLASSES)
            .map(|c| {
                let mut acc = 0i32;
                for u in 0..self.hidden {
                    let (mag, neg) = self.w2[c * self.hidden + u];
                    let p = lut.mul(mag, hq[u]) as i32;
                    acc += if neg { -p } else { p };
                }
                acc
            })
            .collect();
        argmax_i32(&o)
    }

    /// Batched forward pass: one weight decode + LUT dispatch serves
    /// the whole micro-batch. The result is byte-identical to calling
    /// [`QuantMlp::infer`] per image: for each (image, unit) pair the
    /// products are accumulated in the same `i = 0..n_in` order, and
    /// the per-image re-quantise / output stages are the exact scalar
    /// code, so the integer numerics cannot drift between the batched
    /// and sequential paths.
    ///
    /// Library path: panics on a ragged batch, images that do not
    /// match the trained input width, or pixels outside the 4-bit
    /// operand range; the serving path uses
    /// [`QuantMlp::try_classify_batch`] and degrades to a structured
    /// error instead.
    pub fn classify_batch(&self, images: &[&[u8]], lut: &MultLut) -> Vec<usize> {
        match self.try_classify_batch(images, lut) {
            Ok(labels) => labels,
            Err(e) => panic!("classify_batch: {e}"),
        }
    }

    /// Fallible [`QuantMlp::classify_batch`] for serving paths: a
    /// ragged batch (or one whose images do not match the trained
    /// input width) is a checked error — the old `debug_assert` would
    /// have silently mis-indexed weights or panicked mid-batch in a
    /// release-build serving worker.
    pub fn try_classify_batch(
        &self,
        images: &[&[u8]],
        lut: &MultLut,
    ) -> Result<Vec<usize>, String> {
        check_batch_shape(images, self.n_in())?;
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let n_in = self.n_in();
        let nb = images.len();
        let mut h = vec![0i32; nb * self.hidden];
        for u in 0..self.hidden {
            for i in 0..n_in {
                let (mag, neg) = self.w1[u * n_in + i];
                for (b, img) in images.iter().enumerate() {
                    let p = lut.mul(mag, img[i]) as i32;
                    h[b * self.hidden + u] += if neg { -p } else { p };
                }
            }
        }
        Ok((0..nb)
            .map(|b| {
                let hrow = &mut h[b * self.hidden..(b + 1) * self.hidden];
                let hq = relu_requantise(hrow);
                self.layer2(&hq, lut)
            })
            .collect())
    }

    /// Classification accuracy over a dataset with the given
    /// multiplier. Routed through the batched path (provably
    /// byte-identical to per-image [`QuantMlp::infer`]), so sweeps,
    /// examples and tests exercise `classify_batch` constantly.
    pub fn accuracy(&self, data: &[Sample], lut: &MultLut) -> f64 {
        let images: Vec<&[u8]> = data.iter().map(|s| s.pixels.as_slice()).collect();
        let correct = self
            .classify_batch(&images, lut)
            .iter()
            .zip(data)
            .filter(|&(&label, s)| label == s.label)
            .count();
        correct as f64 / data.len() as f64
    }
}

/// Shape/range validation shared by the scalar and compiled batch
/// paths, so both report the same checked errors for the same inputs.
pub(crate) fn check_batch_shape(images: &[&[u8]], n_in: usize) -> Result<(), String> {
    for (b, img) in images.iter().enumerate() {
        if img.len() != n_in {
            return Err(format!(
                "batch image {b} has {} pixels, expected {n_in}",
                img.len()
            ));
        }
        if let Some((i, &px)) = img.iter().enumerate().find(|&(_, &px)| px > 15) {
            return Err(format!(
                "batch image {b} pixel {i} = {px} outside the 4-bit operand range"
            ));
        }
    }
    Ok(())
}

/// ReLU + 4-bit re-quantisation of one image's hidden accumulators,
/// in place. Shared by every forward path — scalar, batched, and the
/// compiled kernel — so the integer numerics cannot drift.
pub(crate) fn relu_requantise(h: &mut [i32]) -> Vec<u8> {
    for v in h.iter_mut() {
        *v = (*v).max(0);
    }
    let hmax = h.iter().copied().max().unwrap_or(1).max(1);
    h.iter().map(|&v| ((v * 15) / hmax) as u8).collect()
}

fn quantise(w: &[f64]) -> Vec<(u8, bool)> {
    let wmax = w.iter().fold(0.0f64, |a, &b| a.max(b.abs())).max(1e-9);
    w.iter()
        .map(|&v| (((v.abs() / wmax) * 15.0).round() as u8, v < 0.0))
        .collect()
}

fn argmax(xs: &[f64]) -> usize {
    // total_cmp, not partial_cmp().unwrap(): a NaN training score must
    // not panic (same fix PR 2 applied to the arena's activity sort).
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap()
}

/// Ties resolve to the *last* maximal class (`max_by_key` semantics);
/// the compiled kernel and emitted standalone source replicate exactly
/// this tie-break.
pub(crate) fn argmax_i32(xs: &[i32]) -> usize {
    xs.iter().enumerate().max_by_key(|&(_, &v)| v).map(|(i, _)| i).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::multiplier;
    use crate::nn::digits::synthetic_digits;

    #[test]
    fn exact_lut_is_multiplication() {
        let lut = MultLut::exact();
        for a in 0..16u8 {
            for b in 0..16u8 {
                assert_eq!(lut.mul(a, b), a as u16 * b as u16);
            }
        }
        assert_eq!(lut.max_error(), 0);
    }

    #[test]
    fn netlist_lut_matches_exact_for_exact_multiplier() {
        let lut = MultLut::from_netlist(&multiplier(4));
        assert_eq!(lut.max_error(), 0);
    }

    #[test]
    fn training_beats_chance_with_exact_multiplier() {
        let train = synthetic_digits(200, 11);
        let test = synthetic_digits(100, 77);
        let mlp = QuantMlp::train(&train, 12, 12, 5);
        let acc = mlp.accuracy(&test, &MultLut::exact());
        assert!(acc > 0.5, "accuracy {acc} not above chance (0.1)");
    }

    #[test]
    fn try_constructors_reject_malformed_inputs() {
        // Wrong operand width: a 3x3 multiplier has 6 inputs.
        let err = MultLut::try_from_netlist(&multiplier(3)).unwrap_err();
        assert!(err.contains("8 inputs"), "{err}");
        // Wrong table size.
        assert!(MultLut::try_from_values(&[0u64; 255]).is_err());
        // Entry that `as u16` would silently truncate.
        let mut vals = vec![0u64; 256];
        vals[7] = u64::from(u16::MAX) + 1;
        let err = MultLut::try_from_values(&vals).unwrap_err();
        assert!(err.contains("entry 7"), "{err}");
        // The happy path still round-trips.
        let vals: Vec<u64> = (0..256u64).map(|x| (x & 15) * (x >> 4)).collect();
        assert_eq!(MultLut::try_from_values(&vals).unwrap().max_error(), 0);
    }

    #[test]
    fn classify_batch_matches_sequential_inference() {
        let train = synthetic_digits(200, 11);
        let test = synthetic_digits(60, 77);
        let mlp = QuantMlp::train(&train, 12, 12, 5);
        let approx: Vec<u64> = (0..256u64)
            .map(|x| ((x & 15) * (x >> 4)) & !3)
            .collect();
        for lut in [MultLut::exact(), MultLut::from_values(&approx)] {
            for chunk in [1usize, 2, 7, 60] {
                for batch in test.chunks(chunk) {
                    let images: Vec<&[u8]> =
                        batch.iter().map(|s| s.pixels.as_slice()).collect();
                    let got = mlp.classify_batch(&images, &lut);
                    let want: Vec<usize> =
                        batch.iter().map(|s| mlp.infer(&s.pixels, &lut)).collect();
                    assert_eq!(got, want, "chunk={chunk}");
                }
            }
        }
        assert!(mlp.classify_batch(&[], &MultLut::exact()).is_empty());
    }

    #[test]
    fn ragged_batches_are_checked_errors_not_silent_misindexing() {
        let mlp = QuantMlp::from_weights(
            2,
            vec![(1, false); 2 * 4],
            vec![(1, true); N_CLASSES * 2],
        );
        assert_eq!(mlp.n_in(), 4);
        let lut = MultLut::exact();
        let good: Vec<u8> = vec![1, 2, 3, 4];
        let short: Vec<u8> = vec![1, 2];
        let err = mlp
            .try_classify_batch(&[good.as_slice(), short.as_slice()], &lut)
            .unwrap_err();
        assert!(err.contains("image 1"), "{err}");
        assert!(err.contains("expected 4"), "{err}");
        // Out-of-range pixels are checked too, not just lengths.
        let hot: Vec<u8> = vec![1, 2, 99, 4];
        let err = mlp.try_classify_batch(&[hot.as_slice()], &lut).unwrap_err();
        assert!(err.contains("4-bit"), "{err}");
        // The library wrapper turns the same condition into a panic.
        assert!(std::panic::catch_unwind(|| {
            mlp.classify_batch(&[short.as_slice()], &lut)
        })
        .is_err());
        // An empty batch is fine either way.
        assert!(mlp.try_classify_batch(&[], &lut).unwrap().is_empty());
    }

    #[test]
    fn mild_approximation_degrades_gracefully() {
        let train = synthetic_digits(200, 11);
        let test = synthetic_digits(100, 77);
        let mlp = QuantMlp::train(&train, 12, 12, 5);
        let exact_acc = mlp.accuracy(&test, &MultLut::exact());
        // ET=4 approximate multiplier: truncate the low two output bits.
        let vals: Vec<u64> = (0..256u64)
            .map(|x| ((x & 15) * (x >> 4)) & !3)
            .collect();
        let lut = MultLut::from_values(&vals);
        assert!(lut.max_error() <= 4);
        let approx_acc = mlp.accuracy(&test, &lut);
        assert!(
            approx_acc >= exact_acc - 0.25,
            "approx {approx_acc} vs exact {exact_acc}"
        );
    }
}

//! Synthetic 8x8 "digits" workload: ten prototype glyphs rendered as
//! 4-bit grayscale images, perturbed with seeded noise — a deterministic
//! stand-in for the UCI digits set that exercises the same code path
//! (DESIGN.md §2 substitution table).

use crate::util::Rng;

pub const IMG: usize = 8;
pub const N_CLASSES: usize = 10;

/// Prototype strokes per digit class, on an 8x8 grid ('#' = bright).
#[rustfmt::skip]
const GLYPHS: [[&str; 8]; 10] = [
    [" ####   ", "##  ##  ", "##  ##  ", "##  ##  ", "##  ##  ", "##  ##  ", " ####   ", "        "],
    ["  ##    ", " ###    ", "  ##    ", "  ##    ", "  ##    ", "  ##    ", " ####   ", "        "],
    [" ####   ", "##  ##  ", "    ##  ", "   ##   ", "  ##    ", " ##     ", "######  ", "        "],
    [" ####   ", "##  ##  ", "    ##  ", "  ###   ", "    ##  ", "##  ##  ", " ####   ", "        "],
    ["   ###  ", "  ####  ", " ## ##  ", "##  ##  ", "######  ", "    ##  ", "    ##  ", "        "],
    ["######  ", "##      ", "#####   ", "    ##  ", "    ##  ", "##  ##  ", " ####   ", "        "],
    [" ####   ", "##      ", "#####   ", "##  ##  ", "##  ##  ", "##  ##  ", " ####   ", "        "],
    ["######  ", "    ##  ", "   ##   ", "  ##    ", " ##     ", " ##     ", " ##     ", "        "],
    [" ####   ", "##  ##  ", " ####   ", "##  ##  ", "##  ##  ", "##  ##  ", " ####   ", "        "],
    [" ####   ", "##  ##  ", "##  ##  ", " #####  ", "    ##  ", "    ##  ", " ####   ", "        "],
];

/// One labelled image: 64 pixels quantised to 4 bits (0..=15).
#[derive(Debug, Clone)]
pub struct Sample {
    pub pixels: Vec<u8>,
    pub label: usize,
}

/// Render `count` noisy samples (balanced across classes).
pub fn synthetic_digits(count: usize, seed: u64) -> Vec<Sample> {
    let mut rng = Rng::seed_from(seed);
    let mut out = Vec::with_capacity(count);
    for idx in 0..count {
        let label = idx % N_CLASSES;
        let glyph = &GLYPHS[label];
        let mut pixels = Vec::with_capacity(IMG * IMG);
        for row in glyph {
            for ch in row.chars() {
                let base = if ch == '#' { 13u8 } else { 1u8 };
                // ±2 noise, clamped to the 4-bit range.
                let noise = rng.below(5) as i16 - 2;
                pixels.push((base as i16 + noise).clamp(0, 15) as u8);
            }
        }
        // Occasional pixel dropouts make the task non-trivial.
        for _ in 0..3 {
            let p = rng.usize_below(IMG * IMG);
            pixels[p] = rng.below(16) as u8;
        }
        out.push(Sample { pixels, label });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let data = synthetic_digits(40, 7);
        assert_eq!(data.len(), 40);
        for s in &data {
            assert_eq!(s.pixels.len(), 64);
            assert!(s.pixels.iter().all(|&p| p <= 15));
            assert!(s.label < N_CLASSES);
        }
    }

    #[test]
    fn deterministic_and_balanced() {
        let a = synthetic_digits(30, 1);
        let b = synthetic_digits(30, 1);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.pixels, y.pixels);
        }
        let count0 = a.iter().filter(|s| s.label == 0).count();
        assert_eq!(count0, 3);
    }

    #[test]
    fn glyphs_are_distinguishable() {
        // Prototype images of different classes differ in many pixels.
        let protos = synthetic_digits(10, 99);
        for i in 0..10 {
            for j in i + 1..10 {
                let d: usize = protos[i]
                    .pixels
                    .iter()
                    .zip(&protos[j].pixels)
                    .filter(|(a, b)| a.abs_diff(**b) > 6)
                    .count();
                assert!(d >= 4, "classes {i} and {j} too similar ({d})");
            }
        }
    }
}

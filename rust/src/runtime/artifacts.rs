//! Artifact manifest: the shape contract between `python/compile/aot.py`
//! and the rust runtime (one entry per AOT-lowered geometry).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// One artifact's geometry — mirrors `compile.model.Geometry`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Geometry {
    pub name: String,
    pub file: String,
    pub n: usize,
    pub m: usize,
    pub t: usize,
    pub b: usize,
    pub npoints: usize,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub geometries: BTreeMap<String, Geometry>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&src)?;
        let obj = json.as_obj().ok_or_else(|| anyhow!("manifest is not an object"))?;
        let mut geometries = BTreeMap::new();
        for (name, entry) in obj {
            let get = |k: &str| -> Result<u64> {
                entry
                    .get(k)
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("manifest entry {name} missing {k}"))
            };
            let g = Geometry {
                name: name.clone(),
                file: entry
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("manifest entry {name} missing file"))?
                    .to_string(),
                n: get("n")? as usize,
                m: get("m")? as usize,
                t: get("t")? as usize,
                b: get("b")? as usize,
                npoints: get("npoints")? as usize,
            };
            if g.npoints != 1usize << g.n {
                bail!("{name}: npoints {} != 2^{}", g.npoints, g.n);
            }
            geometries.insert(name.clone(), g);
        }
        Ok(Manifest { geometries, dir: dir.to_path_buf() })
    }

    pub fn hlo_path(&self, name: &str) -> Option<PathBuf> {
        self.geometries.get(name).map(|g| self.dir.join(&g.file))
    }
}

/// Locate the artifacts directory: `$SXPAT_ARTIFACTS`, else `artifacts/`
/// relative to the working directory, else relative to the manifest dir
/// of the crate (useful under `cargo test`).
pub fn find_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("SXPAT_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    for base in [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ] {
        if base.join("manifest.json").exists() {
            return Some(base);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_well_formed_manifest() {
        let dir = std::env::temp_dir().join("sxpat_manifest_ok");
        write_manifest(
            &dir,
            r#"{"adder_i4": {"file": "a.hlo.txt", "n": 4, "m": 3, "t": 16,
                             "b": 256, "npoints": 16}}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let g = &m.geometries["adder_i4"];
        assert_eq!((g.n, g.m, g.t, g.b), (4, 3, 16, 256));
        assert_eq!(m.hlo_path("adder_i4").unwrap(), dir.join("a.hlo.txt"));
        assert!(m.hlo_path("nope").is_none());
    }

    #[test]
    fn rejects_inconsistent_npoints() {
        let dir = std::env::temp_dir().join("sxpat_manifest_bad");
        write_manifest(
            &dir,
            r#"{"x": {"file": "x", "n": 4, "m": 3, "t": 16, "b": 256,
                      "npoints": 17}}"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn real_artifacts_manifest_parses_when_present() {
        if let Some(dir) = find_artifacts_dir() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.geometries.len(), 6);
            for (name, g) in &m.geometries {
                assert!(dir.join(&g.file).exists(), "{name} artifact missing");
            }
        }
    }
}

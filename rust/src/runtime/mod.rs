//! PJRT runtime: load the AOT-compiled JAX/Pallas evaluator artifacts
//! (HLO text, see `python/compile/aot.py`) and execute them from the
//! rust hot path. Python never runs here — the artifacts are compiled
//! once by `make artifacts` and the binary is self-contained afterwards.

pub mod artifacts;
pub mod client;

pub use artifacts::{find_artifacts_dir, Geometry, Manifest};
pub use client::Runtime;

//! The PJRT client wrapper: compile each HLO-text artifact once, then
//! execute batches from the coordinator hot path.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::evaluator::pack::pack_batch;
use crate::evaluator::EvalResult;
use crate::template::SopParams;

use super::artifacts::{Geometry, Manifest};

pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: Manifest,
}

impl Runtime {
    /// Load every artifact in `dir` and compile it on the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let mut exes = HashMap::new();
        for (name, _g) in manifest.geometries.iter() {
            let path = manifest.hlo_path(name).unwrap();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Runtime { client, exes, manifest })
    }

    pub fn geometry(&self, name: &str) -> Option<&Geometry> {
        self.manifest.geometries.get(name)
    }

    pub fn geometries(&self) -> impl Iterator<Item = &Geometry> {
        self.manifest.geometries.values()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Evaluate instantiations under geometry `name`, chunking into the
    /// artifact's fixed batch size. Semantics match
    /// [`crate::evaluator::rust_eval::evaluate_batch`] exactly.
    pub fn evaluate_batch(
        &self,
        name: &str,
        params: &[SopParams],
        exact: &[u64],
    ) -> Result<Vec<EvalResult>> {
        let g = self
            .geometry(name)
            .ok_or_else(|| anyhow!("unknown geometry {name}"))?
            .clone();
        let exe = &self.exes[name];
        anyhow::ensure!(exact.len() == g.npoints, "exact length mismatch");
        let exact_f32: Vec<f32> = exact.iter().map(|&v| v as f32).collect();

        let mut out = Vec::with_capacity(params.len());
        for chunk in params.chunks(g.b) {
            let packed = pack_batch(chunk, g.n, g.m, g.t, g.b);
            let lits = [
                lit3(&packed.use_mask, g.b, g.t, g.n)?,
                lit3(&packed.neg_mask, g.b, g.t, g.n)?,
                lit3(&packed.out_sel, g.b, g.m, g.t)?,
                lit2(&packed.out_const, g.b, g.m)?,
                xla::Literal::vec1(&exact_f32),
            ];
            let result = exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
            let (max_l, mean_l, vals_l) = result
                .to_tuple3()
                .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
            let maxs: Vec<f32> =
                max_l.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let means: Vec<f32> =
                mean_l.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let vals: Vec<f32> =
                vals_l.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            for bi in 0..chunk.len() {
                out.push(EvalResult {
                    max_err: maxs[bi].round() as u64,
                    mean_err: means[bi] as f64,
                    values: vals[bi * g.npoints..(bi + 1) * g.npoints]
                        .iter()
                        .map(|&v| v.round() as u64)
                        .collect(),
                });
            }
        }
        Ok(out)
    }
}

fn lit3(data: &[f32], d0: usize, d1: usize, d2: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(&[d0 as i64, d1 as i64, d2 as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn lit2(data: &[f32], d0: usize, d1: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(&[d0 as i64, d1 as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

// Integration coverage for the full PJRT path (needs built artifacts)
// lives in rust/tests/integration_runtime.rs.

//! Restriction lattices.
//!
//! A cell is one restriction the miter is solved under. The paper starts
//! from a strong restriction and progressively weakens it; because the
//! proxies correlate with synthesised area (§III / Fig. 4), visiting
//! cells in ascending *estimated-area* order makes the first few SAT
//! answers the low-area ones.

/// One restriction cell with its proxy-based area estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cell {
    /// (PIT, ITS) for SHARED; (LPP, PPO) for XPAT.
    pub a: usize,
    pub b: usize,
    pub estimate: f64,
}

/// SHARED lattice: PIT ∈ [0, t], ITS ∈ [pit, min(m*pit, its_cap)].
///
/// The estimate mirrors the proxy study: each included product costs
/// roughly one AND tree, each extra sum connection one OR input. The
/// exact weights only fix the visiting order, not correctness.
pub fn shared_cells(t: usize, m: usize) -> Vec<Cell> {
    let mut cells = Vec::new();
    for pit in 0..=t {
        let its_hi = (m * pit.max(1)).min(m * t);
        for its in pit..=its_hi {
            cells.push(Cell {
                a: pit,
                b: its,
                estimate: 2.0 * pit as f64 + 0.8 * its as f64,
            });
        }
    }
    sort_cells(&mut cells);
    cells
}

/// XPAT lattice: LPP ∈ [0, n], PPO ∈ [1, k]. The nonshared template
/// replicates products per output, so the estimate scales with m.
pub fn xpat_cells(n: usize, k: usize, m: usize) -> Vec<Cell> {
    let mut cells = Vec::new();
    for lpp in 0..=n {
        for ppo in 1..=k {
            cells.push(Cell {
                a: lpp,
                b: ppo,
                estimate: m as f64 * ppo as f64 * (1.0 + 0.9 * lpp as f64),
            });
        }
    }
    sort_cells(&mut cells);
    cells
}

fn sort_cells(cells: &mut [Cell]) {
    cells.sort_by(|x, y| {
        x.estimate
            .partial_cmp(&y.estimate)
            .unwrap()
            .then(x.a.cmp(&y.a))
            .then(x.b.cmp(&y.b))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_cells_sorted_and_bounded() {
        let cells = shared_cells(4, 3);
        assert!(!cells.is_empty());
        for w in cells.windows(2) {
            assert!(w[0].estimate <= w[1].estimate);
        }
        for c in &cells {
            assert!(c.a <= 4);
            assert!(c.b <= 12);
            assert!(c.b >= c.a || c.a == 0);
        }
    }

    #[test]
    fn xpat_cells_cover_grid() {
        let cells = xpat_cells(4, 3, 2);
        assert_eq!(cells.len(), 5 * 3);
        assert!(cells.iter().any(|c| c.a == 0 && c.b == 1));
        assert!(cells.iter().any(|c| c.a == 4 && c.b == 3));
    }

    #[test]
    fn strongest_cell_first() {
        let cells = shared_cells(6, 3);
        assert_eq!((cells[0].a, cells[0].b), (0, 0));
        let xc = xpat_cells(4, 4, 3);
        assert_eq!((xc[0].a, xc[0].b), (0, 1));
    }
}

//! The generic lattice-scan engine.
//!
//! One engine serves every template family: a [`Template`] implementation
//! supplies miter construction, restricted solving, blocking, lattice
//! generation, proxy extraction and the achieved-estimate formula, and
//! [`run_search`] owns everything the two former copy-pasted loops did —
//! weakest-cell probe, proxy-ordered scan, per-cell model enumeration,
//! deadline / conflict-budget / max-SAT-cells enforcement, and telemetry.
//!
//! ## Parallel scan and determinism
//!
//! The scan runs on `SearchConfig::cell_workers` threads that claim cells
//! from an atomic cursor over the proxy-ordered candidate list. Two
//! scan modes keep the results reproducible:
//!
//! * **Cumulative** (`cell_workers == 1`): the probe miter is reused for
//!   the whole scan and every found model is blocked into it — the
//!   historical sequential algorithm (the XPAT path additionally gained
//!   first-model proxy minimisation, which the old `search_xpat`
//!   lacked). Deterministic across runs; exact traces can differ from
//!   pre-arena builds (clause activities are f32 now), but results are
//!   reproducible within any build.
//! * **Canonical** (`cell_workers > 1`): every cell is solved on a
//!   *clone of the search's prototype miter* — the base CNF is encoded
//!   exactly once per geometry (with the probe model blocked), and each
//!   cell gets a byte-identical snapshot, so a cell's result is a pure
//!   function of the cell — independent of scheduling, worker count and
//!   which cells ran before it. Cloning a flat-arena solver costs buffer
//!   copies instead of the full products/outputs/distance/gate-proxy
//!   re-encode the former fresh-build-per-cell scan paid. Workers race
//!   ahead speculatively; a deterministic in-order commit pass then
//!   replays the sequential stopping rules (max SAT cells, perfect-area
//!   early exit) over the per-cell results and discards any speculative
//!   overshoot, so the outcome is identical across runs and thread
//!   counts — provided the wall-clock budget does not bind (a deadline
//!   that fires mid-scan truncates the claimed prefix at a load-
//!   dependent point, exactly as it truncates the sequential scan).
//!
//! Cross-worker model exchange (`share_blocked_models`) additionally
//! blocks every model already found anywhere into each fresh miter. That
//! reduces duplicate models but makes the constraint set timing-
//! dependent, so it is off by default; duplicates are instead removed
//! deterministically at commit time.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use crate::circuit::sim::{error_stats, is_sound, TruthTables};
use crate::circuit::Netlist;
use crate::obs::Obs;
use crate::synth::synthesize_area;
use crate::template::{NonsharedMiter, SharedMiter, SolveOutcome, SopParams};
use crate::util::Json;

use super::lattice::{shared_cells, xpat_cells, Cell};
use super::runner::{SearchConfig, SearchOutcome, Solution};

/// Everything the lattice-scan engine needs from a template family.
///
/// `a` / `b` are the two restriction axes — (PIT, ITS) for the SHARED
/// template, (LPP, PPO) for the nonshared XPAT template. New template
/// families plug into the whole search/coordinator stack by implementing
/// this trait.
///
/// `Clone` is load-bearing: `build` runs once per search (or once per
/// geometry, when the coordinator shares prototypes across jobs) and the
/// canonical parallel scan clones the encoded prototype per lattice
/// cell. A clone must be a snapshot — byte-identical solver state, so
/// solving a clone replays exactly what a fresh build would do.
/// (`Sync` because canonical-mode workers clone the shared prototype
/// from inside scoped threads.)
pub trait Template: Sized + Clone + Sync {
    /// Method name for diagnostics.
    const NAME: &'static str;

    /// Encode the miter for a function with `n` inputs, `m` outputs and
    /// the given product pool, against `exact` output values (`2^n`
    /// entries) and error threshold `et`.
    fn build(n: usize, m: usize, pool: usize, exact: &[u64], et: u64) -> Self;

    /// Per-solve conflict budget (None = run to completion).
    fn set_conflict_budget(&mut self, budget: Option<u64>);

    /// Once-per-prototype simplification, run by the engine after build
    /// (or on a cache-provided prototype) and *before* any solve or
    /// clone, so the cost is amortised across every cell of the lattice.
    /// Must be idempotent and deterministic: preprocessing twice is a
    /// no-op, and a clone of a preprocessed prototype is byte-identical
    /// to a fresh build-then-preprocess. Default: nothing to simplify.
    fn preprocess(&mut self) {}

    /// Solve under the `(a, b)` restriction.
    fn solve(&mut self, a: usize, b: usize) -> SolveOutcome;

    /// Solve, then greedily minimise the area-driving proxies within the
    /// cell, stopping the descent (but keeping the incumbent) once the
    /// deadline passes.
    fn solve_minimized_deadline(
        &mut self,
        a: usize,
        b: usize,
        deadline: Option<Instant>,
    ) -> SolveOutcome;

    /// Permanently exclude a model from future solves.
    fn block(&mut self, p: &SopParams);

    /// The restriction lattice in ascending estimated-area order.
    fn cells(n: usize, m: usize, pool: usize) -> Vec<Cell>;

    /// The unrestricted probe cell solved before the scan.
    fn weakest_cell(n: usize, m: usize, pool: usize) -> Cell;

    /// Achieved proxy pair of a model.
    fn proxy(p: &SopParams) -> (usize, usize);

    /// Area estimate of achieved proxies — the same formula the lattice
    /// ordering uses, so the probe's result prunes dominated cells.
    fn achieved_estimate(proxy: (usize, usize), m: usize) -> f64;

    /// Cumulative statistics of the underlying solver, snapshotted
    /// before and after a cell so trace spans can fold in the effort
    /// delta. Observe-only: MUST NOT mutate or perturb the solve.
    /// Families without a CDCL core report empty stats.
    fn stats(&self) -> crate::sat::Stats {
        crate::sat::Stats::default()
    }
}

impl Template for SharedMiter {
    const NAME: &'static str = "SHARED";

    fn build(n: usize, m: usize, pool: usize, exact: &[u64], et: u64) -> Self {
        SharedMiter::build(n, m, pool, exact, et)
    }

    fn set_conflict_budget(&mut self, budget: Option<u64>) {
        SharedMiter::set_conflict_budget(self, budget);
    }

    fn preprocess(&mut self) {
        SharedMiter::preprocess(self);
    }

    fn solve(&mut self, a: usize, b: usize) -> SolveOutcome {
        SharedMiter::solve(self, a, b)
    }

    fn solve_minimized_deadline(
        &mut self,
        a: usize,
        b: usize,
        deadline: Option<Instant>,
    ) -> SolveOutcome {
        SharedMiter::solve_minimized_deadline(self, a, b, deadline)
    }

    fn block(&mut self, p: &SopParams) {
        SharedMiter::block(self, p);
    }

    fn cells(_n: usize, m: usize, pool: usize) -> Vec<Cell> {
        shared_cells(pool, m)
    }

    fn weakest_cell(_n: usize, m: usize, pool: usize) -> Cell {
        Cell { a: pool, b: pool * m, estimate: f64::INFINITY }
    }

    fn proxy(p: &SopParams) -> (usize, usize) {
        (p.pit(), p.its())
    }

    fn achieved_estimate(proxy: (usize, usize), _m: usize) -> f64 {
        2.0 * proxy.0 as f64 + 0.8 * proxy.1 as f64
    }

    fn stats(&self) -> crate::sat::Stats {
        SharedMiter::stats(self)
    }
}

impl Template for NonsharedMiter {
    const NAME: &'static str = "XPAT";

    fn build(n: usize, m: usize, pool: usize, exact: &[u64], et: u64) -> Self {
        NonsharedMiter::build(n, m, pool, exact, et)
    }

    fn set_conflict_budget(&mut self, budget: Option<u64>) {
        NonsharedMiter::set_conflict_budget(self, budget);
    }

    fn preprocess(&mut self) {
        NonsharedMiter::preprocess(self);
    }

    fn solve(&mut self, a: usize, b: usize) -> SolveOutcome {
        NonsharedMiter::solve(self, a, b)
    }

    fn solve_minimized_deadline(
        &mut self,
        a: usize,
        b: usize,
        deadline: Option<Instant>,
    ) -> SolveOutcome {
        NonsharedMiter::solve_minimized_deadline(self, a, b, deadline)
    }

    fn block(&mut self, p: &SopParams) {
        NonsharedMiter::block(self, p);
    }

    fn cells(n: usize, m: usize, pool: usize) -> Vec<Cell> {
        xpat_cells(n, pool, m)
    }

    fn weakest_cell(n: usize, _m: usize, pool: usize) -> Cell {
        Cell { a: n, b: pool, estimate: f64::INFINITY }
    }

    fn proxy(p: &SopParams) -> (usize, usize) {
        (p.lpp(), p.ppo())
    }

    fn achieved_estimate(proxy: (usize, usize), m: usize) -> f64 {
        m as f64 * proxy.1 as f64 * (1.0 + 0.9 * proxy.0 as f64)
    }

    fn stats(&self) -> crate::sat::Stats {
        NonsharedMiter::stats(self)
    }
}

/// Result of scanning one cell, as produced by a worker.
enum CellStatus {
    Sat(Vec<Solution>),
    Unsat,
    /// The first solve of the cell ran out of conflict budget.
    Budget,
    /// No worker claimed the cell before the scan stopped.
    NotReached,
}

/// Shared scan coordination state (all monotone, so `Relaxed` suffices:
/// the claim cursor only hands out each index once, and the stop flags
/// only ever tighten — a stale read merely delays a worker one cell).
struct ScanState {
    next: AtomicUsize,
    sat_cells: AtomicUsize,
    cancel: AtomicBool,
}

/// Read-only context shared by all scan workers.
struct ScanCtx<'a, T: Template> {
    et: u64,
    exact: &'a [u64],
    name: &'a str,
    cfg: &'a SearchConfig,
    cells: &'a [Cell],
    deadline: Instant,
    state: &'a ScanState,
    /// The encoded-once prototype (probe model already blocked, conflict
    /// budget already set) that canonical-mode workers clone per cell.
    /// `None` in cumulative mode, where the prototype itself is the
    /// persistent scan miter and cannot be shared immutably.
    proto: Option<&'a T>,
    /// Cross-worker model exchange (only with `share_blocked_models`).
    journal: Option<&'a Mutex<Vec<SopParams>>>,
    /// Trace handle. Observe-only: spans record around the solves, and
    /// clock reads live in the span guard, never in a solver or commit
    /// decision — tracing on/off cannot change any outcome.
    obs: &'a Obs,
}

/// Post-process one model into a [`Solution`].
fn finish<T: Template>(
    params: SopParams,
    cell: &Cell,
    exact: &[u64],
    name: &str,
) -> Solution {
    let approx = params.output_values();
    let (max_err, mean_err) = error_stats(exact, &approx);
    let area = synthesize_area(&params.to_netlist(name));
    let proxy = T::proxy(&params);
    Solution { params, proxy, cell: (cell.a, cell.b), area, max_err, mean_err }
}

fn status_name(status: &CellStatus) -> &'static str {
    match status {
        CellStatus::Sat(_) => "sat",
        CellStatus::Unsat => "unsat",
        CellStatus::Budget => "budget",
        CellStatus::NotReached => "not_reached",
    }
}

/// Enumerate up to `solutions_per_cell` models of one cell, wrapped in a
/// `sweep.cell` span that folds in the solver-effort delta. The span is
/// pure observation — the solve itself is [`scan_cell_inner`], which
/// never sees the trace handle.
fn scan_cell<T: Template>(miter: &mut T, cell: &Cell, ctx: &ScanCtx<'_, T>) -> CellStatus {
    if !ctx.obs.enabled() {
        return scan_cell_inner(miter, cell, ctx);
    }
    let before = miter.stats();
    let mut span = ctx.obs.span(
        "sweep.cell",
        &[
            ("bench", Json::Str(ctx.name.to_string())),
            ("method", Json::Str(T::NAME.to_string())),
            ("et", Json::Num(ctx.et as f64)),
            ("cell_a", Json::Num(cell.a as f64)),
            ("cell_b", Json::Num(cell.b as f64)),
        ],
    );
    let status = scan_cell_inner(miter, cell, ctx);
    let d = miter.stats().delta_since(&before);
    span.field("conflicts", Json::Num(d.conflicts as f64));
    span.field("decisions", Json::Num(d.decisions as f64));
    span.field("propagations", Json::Num(d.propagations as f64));
    span.field("restarts", Json::Num(d.restarts as f64));
    span.field("lbd_sum", Json::Num(d.lbd_sum as f64));
    span.field("preprocess_probes", Json::Num(d.preprocess_probes as f64));
    span.field("preprocess_subsumed", Json::Num(d.preprocess_subsumed as f64));
    span.field("status", Json::Str(status_name(&status).to_string()));
    span.finish();
    status
}

/// The first model is proxy-minimised (drives to the cell's low-area
/// corner); further models are plain enumeration for the Fig. 4 scatter.
fn scan_cell_inner<T: Template>(
    miter: &mut T,
    cell: &Cell,
    ctx: &ScanCtx<'_, T>,
) -> CellStatus {
    let mut sols: Vec<Solution> = Vec::new();
    for sol_idx in 0..ctx.cfg.solutions_per_cell {
        let solved = if sol_idx == 0 {
            miter.solve_minimized_deadline(cell.a, cell.b, Some(ctx.deadline))
        } else {
            miter.solve(cell.a, cell.b)
        };
        match solved {
            SolveOutcome::Sat(params) => {
                debug_assert!(is_sound(ctx.exact, &params.output_values(), ctx.et));
                miter.block(&params);
                sols.push(finish::<T>(params, cell, ctx.exact, ctx.name));
            }
            SolveOutcome::Unsat => break,
            SolveOutcome::Budget => {
                if sols.is_empty() {
                    return CellStatus::Budget;
                }
                break;
            }
        }
    }
    if sols.is_empty() {
        CellStatus::Unsat
    } else {
        CellStatus::Sat(sols)
    }
}

/// One scan worker: claim cells in lattice order until a stop condition
/// fires. `persistent` is the cumulative-mode miter; canonical mode
/// (`None`) clones the prototype per cell instead.
fn scan_worker<T: Template>(
    mut persistent: Option<&mut T>,
    ctx: &ScanCtx<'_, T>,
    tx: &mpsc::Sender<(usize, CellStatus)>,
) {
    loop {
        if ctx.state.cancel.load(Ordering::Relaxed)
            || ctx.state.sat_cells.load(Ordering::Relaxed) >= ctx.cfg.max_sat_cells
            || Instant::now() > ctx.deadline
        {
            return;
        }
        let idx = ctx.state.next.fetch_add(1, Ordering::Relaxed);
        if idx >= ctx.cells.len() {
            return;
        }
        let cell = &ctx.cells[idx];
        let status = match persistent.as_deref_mut() {
            Some(miter) => scan_cell(miter, cell, ctx),
            None => {
                // Canonical mode: snapshot the prototype — the base CNF,
                // probe block and conflict budget come along for the
                // price of a few flat-buffer copies, no re-encoding.
                let mut miter = ctx
                    .proto
                    .expect("canonical scan carries a prototype")
                    .clone();
                if let Some(j) = ctx.journal {
                    // Snapshot under the lock, encode outside it — the
                    // block() encodes would otherwise serialize workers.
                    let snapshot = j.lock().unwrap().clone();
                    for p in &snapshot {
                        miter.block(p);
                    }
                }
                scan_cell(&mut miter, cell, ctx)
            }
        };
        if let CellStatus::Sat(sols) = &status {
            ctx.state.sat_cells.fetch_add(1, Ordering::Relaxed);
            if sols.iter().any(|s| s.area == 0.0) {
                ctx.state.cancel.store(true, Ordering::Relaxed);
            }
            if let Some(j) = ctx.journal {
                j.lock()
                    .unwrap()
                    .extend(sols.iter().map(|s| s.params.clone()));
            }
        }
        if tx.send((idx, status)).is_err() {
            return;
        }
    }
}

/// Run the full lattice search for one template family.
pub fn run_search<T: Template>(nl: &Netlist, et: u64, cfg: &SearchConfig) -> SearchOutcome {
    run_search_from(nl, et, cfg, None)
}

/// As [`run_search`], optionally starting from a pre-encoded *pristine*
/// prototype (never solved, nothing blocked) for the same geometry —
/// the seam `search::runner::MiterCache` uses to share one encode across
/// same-geometry jobs of a sweep. The prototype MUST have been built
/// with this `(nl, et, cfg.pool)` triple; a `None` builds it here. Only
/// one `T::build` runs per search either way: cumulative mode probes and
/// scans on the prototype itself, canonical mode probes on a throwaway
/// clone and clones the pristine prototype once per cell.
pub fn run_search_from<T: Template>(
    nl: &Netlist,
    et: u64,
    cfg: &SearchConfig,
    prototype: Option<T>,
) -> SearchOutcome {
    let exact = TruthTables::simulate(nl).output_values(nl);
    run_search_exact(nl, et, cfg, prototype, &exact)
}

/// As [`run_search_from`], with the exhaustive truth table supplied by
/// the caller instead of re-simulated here. The coordinator computes
/// `exact` once per job (it is also the store fingerprint input and the
/// final soundness oracle) and threads it through `MiterCache` and this
/// function, so the `2^n`-point simulation runs once instead of three
/// times per job. `exact` MUST be `nl`'s exhaustive output table.
pub fn run_search_exact<T: Template>(
    nl: &Netlist,
    et: u64,
    cfg: &SearchConfig,
    prototype: Option<T>,
    exact: &[u64],
) -> SearchOutcome {
    run_search_exact_obs(nl, et, cfg, prototype, exact, &Obs::off())
}

/// As [`run_search_exact`], tracing the probe and every cell into `obs`.
/// Instrumentation is observe-only: spans wrap the solves without
/// entering them, so a traced search commits byte-identical results.
pub fn run_search_exact_obs<T: Template>(
    nl: &Netlist,
    et: u64,
    cfg: &SearchConfig,
    prototype: Option<T>,
    exact: &[u64],
    obs: &Obs,
) -> SearchOutcome {
    let (n, m) = (nl.n_inputs(), nl.n_outputs());
    debug_assert_eq!(exact.len(), 1usize << n, "exact table must be exhaustive");
    let start = Instant::now();
    let deadline = start + Duration::from_millis(cfg.time_budget_ms);

    let mut out = SearchOutcome {
        solutions: Vec::new(),
        cells_tried: 0,
        cells_sat: 0,
        cells_unsat: 0,
        cells_timeout: 0,
        elapsed_ms: 0,
    };

    // The prototype: the single `T::build` of this search. In cumulative
    // mode it doubles as the probe-and-scan miter (no snapshot, one miter
    // alive — only canonical-mode cells clone); in canonical mode the
    // probe runs on a throwaway clone so the prototype stays pristine for
    // the per-cell clones.
    let canonical = cfg.cell_workers > 1;
    let mut proto =
        prototype.unwrap_or_else(|| T::build(n, m, cfg.pool, exact, et));
    // Idempotent: cold builds get simplified here, cache-provided
    // prototypes were already preprocessed at insert time and skip out.
    proto.preprocess();
    proto.set_conflict_budget(cfg.conflict_budget);
    let mut probe_clone: Option<T> = if canonical { Some(proto.clone()) } else { None };

    // Weakest-cell probe: solve the unrestricted template first. It
    // yields (a) an immediate finite upper bound (no `inf` rows when the
    // strong cells are all hard-UNSAT, as on the bigger multipliers) and
    // (b) with proxy minimisation, achieved values that tell the lattice
    // scan which strictly-stronger cells are worth trying.
    let weakest = T::weakest_cell(n, m, cfg.pool);
    let mut achieved = f64::INFINITY;
    out.cells_tried += 1;
    let probe_outcome = {
        let probe_target: &mut T = match probe_clone.as_mut() {
            Some(pm) => pm,
            None => &mut proto,
        };
        let before = probe_target.stats();
        let mut span = obs.span(
            "sweep.probe",
            &[
                ("bench", Json::Str(nl.name.clone())),
                ("method", Json::Str(T::NAME.to_string())),
                ("et", Json::Num(et as f64)),
            ],
        );
        let outcome =
            probe_target.solve_minimized_deadline(weakest.a, weakest.b, Some(deadline));
        if let SolveOutcome::Sat(params) = &outcome {
            probe_target.block(params);
        }
        let d = probe_target.stats().delta_since(&before);
        span.field("conflicts", Json::Num(d.conflicts as f64));
        span.field("restarts", Json::Num(d.restarts as f64));
        span.field("lbd_sum", Json::Num(d.lbd_sum as f64));
        span.field(
            "status",
            Json::Str(
                match &outcome {
                    SolveOutcome::Sat(_) => "sat",
                    SolveOutcome::Unsat => "unsat",
                    SolveOutcome::Budget => "budget",
                }
                .to_string(),
            ),
        );
        span.finish();
        outcome
    };
    match probe_outcome {
        SolveOutcome::Sat(params) => {
            if canonical {
                // Bake the probe block into the prototype too, so the
                // per-cell clones inherit it for free.
                proto.block(&params);
            }
            let sol = finish::<T>(params, &weakest, exact, &nl.name);
            achieved = T::achieved_estimate(sol.proxy, m);
            out.solutions.push(sol);
            out.cells_sat += 1;
        }
        SolveOutcome::Unsat => out.cells_unsat += 1,
        SolveOutcome::Budget => out.cells_timeout += 1,
    }
    // The canonical-mode probe clone has served its purpose.
    drop(probe_clone);
    // Exactly one of these owns the miter from here on: the cumulative
    // scan mutates it in place, the canonical scan shares it read-only
    // so workers can clone it per cell. (Two variables, so the borrow
    // checker can see the mutable and shared paths never coexist.)
    let (mut cumulative_miter, shared_proto): (Option<T>, Option<T>) =
        if canonical { (None, Some(proto)) } else { (Some(proto), None) };

    // Cells that could still beat the probe's achieved proxies, in
    // ascending estimated-area order.
    let cells: Vec<Cell> = T::cells(n, m, cfg.pool)
        .into_iter()
        .filter(|c| c.estimate < achieved)
        .collect();

    let state = ScanState {
        next: AtomicUsize::new(0),
        sat_cells: AtomicUsize::new(out.cells_sat),
        cancel: AtomicBool::new(out.solutions.iter().any(|s| s.area == 0.0)),
    };
    let journal: Option<Mutex<Vec<SopParams>>> =
        if canonical && cfg.share_blocked_models {
            Some(Mutex::new(Vec::new()))
        } else {
            None
        };
    let ctx = ScanCtx {
        et,
        exact,
        name: &nl.name,
        cfg,
        cells: &cells,
        deadline,
        state: &state,
        proto: shared_proto.as_ref(),
        journal: journal.as_ref(),
        obs,
    };

    let (tx, rx) = mpsc::channel::<(usize, CellStatus)>();
    if !cells.is_empty() {
        if !canonical {
            scan_worker(cumulative_miter.as_mut(), &ctx, &tx);
        } else {
            let threads = cfg.cell_workers.min(cells.len());
            let ctx_ref = &ctx;
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    let tx = tx.clone();
                    scope.spawn(move || scan_worker::<T>(None, ctx_ref, &tx));
                }
            });
        }
    }
    drop(tx);

    let mut statuses: Vec<CellStatus> =
        (0..cells.len()).map(|_| CellStatus::NotReached).collect();
    for (idx, status) in rx {
        statuses[idx] = status;
    }

    // Deterministic in-order commit: replay the sequential stopping rules
    // over the per-cell results. In canonical mode this discards any
    // speculative overshoot past the stop point and removes duplicate
    // models a later cell re-found.
    let mut zero_found = out.solutions.iter().any(|s| s.area == 0.0);
    for status in statuses {
        if out.cells_sat >= cfg.max_sat_cells || zero_found {
            break;
        }
        match status {
            CellStatus::NotReached => break,
            CellStatus::Unsat => {
                out.cells_tried += 1;
                out.cells_unsat += 1;
            }
            CellStatus::Budget => {
                out.cells_tried += 1;
                out.cells_timeout += 1;
            }
            CellStatus::Sat(sols) => {
                out.cells_tried += 1;
                out.cells_sat += 1;
                for s in sols {
                    if canonical
                        && out.solutions.iter().any(|q| q.params == s.params)
                    {
                        continue;
                    }
                    if s.area == 0.0 {
                        zero_found = true;
                    }
                    out.solutions.push(s);
                }
            }
        }
    }
    out.elapsed_ms = start.elapsed().as_millis() as u64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::adder;
    use crate::circuit::netlist::GateKind;

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            pool: 5,
            solutions_per_cell: 1,
            max_sat_cells: 2,
            conflict_budget: Some(50_000),
            time_budget_ms: 30_000,
            ..Default::default()
        }
    }

    #[test]
    fn generic_engine_runs_both_template_impls() {
        let nl = adder(2);
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let sh = run_search::<SharedMiter>(&nl, 2, &quick_cfg());
        let xp = run_search::<NonsharedMiter>(&nl, 2, &quick_cfg());
        for (name, out) in [("SHARED", &sh), ("XPAT", &xp)] {
            let best = out.best().unwrap_or_else(|| panic!("{name}: no solution"));
            assert!(
                is_sound(&exact, &best.params.output_values(), 2),
                "{name} unsound"
            );
            assert_eq!(
                out.cells_tried,
                out.cells_sat + out.cells_unsat + out.cells_timeout,
                "{name} telemetry"
            );
        }
    }

    // ---- scripted mock template: deterministic engine-logic tests ----

    /// A template whose solve outcomes are scripted by the cell's `a`
    /// coordinate: 99 (the probe) and 2 are SAT, 1 exhausts the budget,
    /// everything else is UNSAT. Models invert the single input, so they
    /// are sound for the NOT-gate netlist below at ET = 0.
    #[derive(Clone)]
    struct MockTemplate {
        pool: usize,
    }

    fn mock_netlist() -> Netlist {
        let mut nl = Netlist::new("mock");
        let a = nl.add_input();
        let inv = nl.push(GateKind::Not, vec![a]);
        nl.set_outputs(vec![inv]);
        nl
    }

    fn mock_model(pool: usize, tag: usize) -> SopParams {
        let mut p = SopParams::empty(1, 1, pool);
        p.use_mask[0] = true; // product 0: in0 ...
        p.neg_mask[0] = true; // ... negated
        p.out_sel[0] = true; // out0 <- product 0
        // Distinguish models per cell via don't-care bits of unused
        // products (they never reach the output or the netlist).
        for k in 1..pool {
            p.use_mask[k] = (tag >> (k - 1)) & 1 == 1;
        }
        p
    }

    impl Template for MockTemplate {
        const NAME: &'static str = "MOCK";

        fn build(_n: usize, _m: usize, pool: usize, _exact: &[u64], _et: u64) -> Self {
            MockTemplate { pool }
        }

        fn set_conflict_budget(&mut self, _budget: Option<u64>) {}

        fn solve(&mut self, a: usize, _b: usize) -> SolveOutcome {
            match a {
                99 | 2 => SolveOutcome::Sat(mock_model(self.pool, a)),
                1 => SolveOutcome::Budget,
                _ => SolveOutcome::Unsat,
            }
        }

        fn solve_minimized_deadline(
            &mut self,
            a: usize,
            b: usize,
            _deadline: Option<Instant>,
        ) -> SolveOutcome {
            self.solve(a, b)
        }

        fn block(&mut self, _p: &SopParams) {}

        fn cells(_n: usize, _m: usize, _pool: usize) -> Vec<Cell> {
            (0..4)
                .map(|a| Cell { a, b: 0, estimate: 1.0 + a as f64 })
                .collect()
        }

        fn weakest_cell(_n: usize, _m: usize, _pool: usize) -> Cell {
            Cell { a: 99, b: 0, estimate: f64::INFINITY }
        }

        fn proxy(p: &SopParams) -> (usize, usize) {
            (p.pit(), p.its())
        }

        fn achieved_estimate(_proxy: (usize, usize), _m: usize) -> f64 {
            f64::INFINITY
        }
    }

    fn mock_cfg(cell_workers: usize) -> SearchConfig {
        SearchConfig {
            pool: 4,
            solutions_per_cell: 1,
            max_sat_cells: 3,
            conflict_budget: None,
            time_budget_ms: 60_000,
            cell_workers,
            ..Default::default()
        }
    }

    #[test]
    fn telemetry_distinguishes_budget_timeouts_from_unsat() {
        // Scripted cells: a=0 UNSAT, a=1 budget-abort, a=2 SAT, a=3 UNSAT.
        let nl = mock_netlist();
        let out = run_search::<MockTemplate>(&nl, 0, &mock_cfg(1));
        assert_eq!(out.cells_tried, 5); // probe + 4 cells
        assert_eq!(out.cells_sat, 2); // probe + a=2
        assert_eq!(out.cells_unsat, 2);
        assert_eq!(out.cells_timeout, 1, "budget abort must not count as UNSAT");
        assert_eq!(
            out.cells_tried,
            out.cells_sat + out.cells_unsat + out.cells_timeout
        );
        assert_eq!(out.solutions.len(), 2);
    }

    #[test]
    fn engine_commit_is_identical_across_worker_counts() {
        let nl = mock_netlist();
        let base = run_search::<MockTemplate>(&nl, 0, &mock_cfg(1));
        for workers in [2, 4, 8] {
            let par = run_search::<MockTemplate>(&nl, 0, &mock_cfg(workers));
            assert_eq!(par.cells_tried, base.cells_tried, "workers={workers}");
            assert_eq!(par.cells_sat, base.cells_sat, "workers={workers}");
            assert_eq!(par.cells_unsat, base.cells_unsat, "workers={workers}");
            assert_eq!(par.cells_timeout, base.cells_timeout, "workers={workers}");
            let key = |o: &SearchOutcome| -> Vec<((usize, usize), (usize, usize), f64)> {
                o.solutions.iter().map(|s| (s.cell, s.proxy, s.area)).collect()
            };
            assert_eq!(key(&par), key(&base), "workers={workers}");
        }
    }
}

//! The design-space exploration of §III: progressive weakening of the
//! template restrictions until satisfiable, then multi-solution
//! enumeration — XPAT's grid over (LPP, PPO) and SHARED's grid over
//! (PIT, ITS), each ordered by the proxy's area estimate.
//!
//! * [`lattice`] — restriction cells and their area estimates.
//! * [`engine`] — the generic, optionally parallel lattice-scan engine
//!   over any [`Template`](engine::Template) implementation.
//! * [`runner`] — configuration/outcome types and the two paper methods
//!   (`search_shared`, `search_xpat`) as thin engine instantiations.

pub mod engine;
pub mod lattice;
pub mod runner;

pub use engine::{
    run_search, run_search_exact, run_search_exact_obs, run_search_from, Template,
};
pub use lattice::{shared_cells, xpat_cells, Cell};
pub use runner::{
    search_shared, search_xpat, MiterCache, SearchConfig, SearchOutcome, Solution,
};

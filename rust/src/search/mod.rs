//! The design-space exploration of §III: progressive weakening of the
//! template restrictions until satisfiable, then multi-solution
//! enumeration — XPAT's grid over (LPP, PPO) and SHARED's grid over
//! (PIT, ITS), each ordered by the proxy's area estimate.

pub mod lattice;
pub mod runner;

pub use lattice::{shared_cells, xpat_cells, Cell};
pub use runner::{search_shared, search_xpat, SearchConfig, SearchOutcome, Solution};

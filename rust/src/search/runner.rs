//! Lattice search driver: solve cells in ascending estimated-area order,
//! enumerate several models per SAT cell (Fig. 4 plots several points per
//! template method), verify every model against the exhaustive oracle,
//! synthesise, and keep the area-best solution.

use std::time::Instant;

use crate::circuit::sim::{error_stats, is_sound, TruthTables};
use crate::circuit::Netlist;
use crate::synth::synthesize_area;
use crate::template::{NonsharedMiter, SharedMiter, SopParams};

use super::lattice::{shared_cells, xpat_cells, Cell};

#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Product-pool size (SHARED) / per-output slots (XPAT).
    pub pool: usize,
    /// Models to enumerate per SAT cell.
    pub solutions_per_cell: usize,
    /// SAT cells to accept before stopping (weakening continues until
    /// this many cells answered SAT).
    pub max_sat_cells: usize,
    /// Per-solve conflict budget (None = run to completion).
    pub conflict_budget: Option<u64>,
    /// Overall wall-clock budget in milliseconds.
    pub time_budget_ms: u64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            pool: 10,
            solutions_per_cell: 3,
            max_sat_cells: 10,
            conflict_budget: Some(200_000),
            time_budget_ms: 60_000,
        }
    }
}

/// One satisfying assignment, fully post-processed.
#[derive(Debug, Clone)]
pub struct Solution {
    pub params: SopParams,
    /// (PIT, ITS) for SHARED, (LPP, PPO) for XPAT — the *achieved* proxy
    /// values of the model, not the cell bounds.
    pub proxy: (usize, usize),
    pub cell: (usize, usize),
    pub area: f64,
    pub max_err: u64,
    pub mean_err: f64,
}

/// Search telemetry + all solutions found.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub solutions: Vec<Solution>,
    pub cells_tried: usize,
    pub cells_sat: usize,
    pub cells_unsat: usize,
    pub cells_timeout: usize,
    pub elapsed_ms: u64,
}

impl SearchOutcome {
    /// The headline result: smallest synthesised area (Fig. 5 reports one
    /// best point per method).
    pub fn best(&self) -> Option<&Solution> {
        self.solutions
            .iter()
            .min_by(|a, b| a.area.partial_cmp(&b.area).unwrap())
    }
}

fn exact_values(nl: &Netlist) -> Vec<u64> {
    TruthTables::simulate(nl).output_values(nl)
}

fn finish(params: SopParams, cell: &Cell, exact: &[u64], shared: bool, name: &str)
          -> Solution {
    let approx = params.output_values();
    let (max_err, mean_err) = error_stats(exact, &approx);
    let area = synthesize_area(&params.to_netlist(name));
    let proxy = if shared {
        (params.pit(), params.its())
    } else {
        (params.lpp(), params.ppo())
    };
    Solution { params, proxy, cell: (cell.a, cell.b), area, max_err, mean_err }
}

/// SHARED search (the paper's contribution).
pub fn search_shared(nl: &Netlist, et: u64, cfg: &SearchConfig) -> SearchOutcome {
    let (n, m) = (nl.n_inputs(), nl.n_outputs());
    let exact = exact_values(nl);
    let mut miter = SharedMiter::build(n, m, cfg.pool, &exact, et);
    miter.set_conflict_budget(cfg.conflict_budget);

    let start = Instant::now();
    let mut out = SearchOutcome {
        solutions: Vec::new(),
        cells_tried: 0,
        cells_sat: 0,
        cells_unsat: 0,
        cells_timeout: 0,
        elapsed_ms: 0,
    };

    // Weakest-cell probe: solve the unrestricted template first. It
    // yields (a) an immediate finite upper bound (no `inf` rows when the
    // strong cells are all hard-UNSAT, as on the bigger multipliers) and
    // (b) with literal/negation minimisation, achieved proxies that tell
    // the lattice scan which strictly-stronger cells are worth trying.
    let weakest = Cell {
        a: cfg.pool,
        b: cfg.pool * m,
        estimate: f64::INFINITY,
    };
    let mut achieved_estimate = f64::INFINITY;
    out.cells_tried += 1;
    let deadline = start + std::time::Duration::from_millis(cfg.time_budget_ms);
    if let Some(params) =
        miter.solve_minimized_deadline(weakest.a, weakest.b, Some(deadline))
    {
        miter.block(&params);
        let sol = finish(params, &weakest, &exact, true, &nl.name);
        achieved_estimate = 2.0 * sol.proxy.0 as f64 + 0.8 * sol.proxy.1 as f64;
        out.solutions.push(sol);
        out.cells_sat += 1;
    } else {
        out.cells_unsat += 1;
    }

    for cell in shared_cells(cfg.pool, m) {
        if cell.estimate >= achieved_estimate {
            continue; // cannot beat the probe's achieved proxies
        }
        if out.cells_sat >= cfg.max_sat_cells
            || start.elapsed().as_millis() as u64 > cfg.time_budget_ms
            || out.best().map(|s| s.area == 0.0).unwrap_or(false)
        {
            break;
        }
        out.cells_tried += 1;
        let mut got_any = false;
        for sol_idx in 0..cfg.solutions_per_cell {
            // First model per cell: minimise the literal-count proxy
            // (drives to the cell's low-area corner). Further models:
            // plain enumeration for the Fig. 4 scatter.
            let solved = if sol_idx == 0 {
                miter.solve_minimized_deadline(cell.a, cell.b, Some(deadline))
            } else {
                miter.solve(cell.a, cell.b)
            };
            match solved {
                Some(params) => {
                    debug_assert!(is_sound(&exact, &params.output_values(), et));
                    miter.block(&params);
                    out.solutions
                        .push(finish(params, &cell, &exact, true, &nl.name));
                    got_any = true;
                }
                None => break,
            }
        }
        if got_any {
            out.cells_sat += 1;
        } else {
            out.cells_unsat += 1;
        }
    }
    out.elapsed_ms = start.elapsed().as_millis() as u64;
    out
}

/// Original-XPAT search over the nonshared template.
pub fn search_xpat(nl: &Netlist, et: u64, cfg: &SearchConfig) -> SearchOutcome {
    let (n, m) = (nl.n_inputs(), nl.n_outputs());
    let exact = exact_values(nl);
    let mut miter = NonsharedMiter::build(n, m, cfg.pool, &exact, et);
    miter.set_conflict_budget(cfg.conflict_budget);

    let start = Instant::now();
    let mut out = SearchOutcome {
        solutions: Vec::new(),
        cells_tried: 0,
        cells_sat: 0,
        cells_unsat: 0,
        cells_timeout: 0,
        elapsed_ms: 0,
    };

    // Weakest-cell probe (see search_shared).
    let weakest = Cell { a: n, b: cfg.pool, estimate: f64::INFINITY };
    let mut achieved_estimate = f64::INFINITY;
    out.cells_tried += 1;
    if let Some(params) = miter.solve(weakest.a, weakest.b) {
        miter.block(&params);
        let sol = finish(params, &weakest, &exact, false, &nl.name);
        achieved_estimate =
            m as f64 * sol.proxy.1 as f64 * (1.0 + 0.9 * sol.proxy.0 as f64);
        out.solutions.push(sol);
        out.cells_sat += 1;
    } else {
        out.cells_unsat += 1;
    }

    for cell in xpat_cells(n, cfg.pool, m) {
        if cell.estimate >= achieved_estimate {
            continue;
        }
        if out.cells_sat >= cfg.max_sat_cells
            || start.elapsed().as_millis() as u64 > cfg.time_budget_ms
            || out.best().map(|s| s.area == 0.0).unwrap_or(false)
        {
            break;
        }
        out.cells_tried += 1;
        let mut got_any = false;
        for _ in 0..cfg.solutions_per_cell {
            match miter.solve(cell.a, cell.b) {
                Some(params) => {
                    debug_assert!(is_sound(&exact, &params.output_values(), et));
                    miter.block(&params);
                    out.solutions
                        .push(finish(params, &cell, &exact, false, &nl.name));
                    got_any = true;
                }
                None => break,
            }
        }
        if got_any {
            out.cells_sat += 1;
        } else {
            out.cells_unsat += 1;
        }
    }
    out.elapsed_ms = start.elapsed().as_millis() as u64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::{adder, multiplier};

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            pool: 6,
            solutions_per_cell: 2,
            max_sat_cells: 2,
            conflict_budget: Some(50_000),
            time_budget_ms: 30_000,
        }
    }

    #[test]
    fn shared_search_finds_sound_low_area_adder() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let out = search_shared(&nl, 2, &quick_cfg());
        let best = out.best().expect("solutions expected");
        assert!(is_sound(&exact, &best.params.output_values(), 2));
        let exact_area = synthesize_area(&nl);
        assert!(
            best.area < exact_area,
            "approximation ({}) should beat exact ({exact_area})",
            best.area
        );
    }

    #[test]
    fn xpat_search_finds_sound_solution() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let out = search_xpat(&nl, 2, &quick_cfg());
        let best = out.best().expect("solutions expected");
        assert!(is_sound(&exact, &best.params.output_values(), 2));
    }

    #[test]
    fn shared_beats_or_matches_xpat_on_mult_i4() {
        // The paper's headline: SHARED >= XPAT in area for the same ET.
        let nl = multiplier(2);
        let mut cfg = quick_cfg();
        cfg.max_sat_cells = 6;
        cfg.solutions_per_cell = 4;
        let sh = search_shared(&nl, 2, &cfg);
        let xp = search_xpat(&nl, 2, &cfg);
        let (sa, xa) = (sh.best().unwrap().area, xp.best().unwrap().area);
        assert!(sa <= xa + 1e-9, "shared {sa} worse than xpat {xa}");
    }

    #[test]
    fn telemetry_counts_are_consistent() {
        let nl = adder(2);
        let out = search_shared(&nl, 1, &quick_cfg());
        assert_eq!(out.cells_tried, out.cells_sat + out.cells_unsat + out.cells_timeout);
        assert!(out.cells_sat > 0);
        assert!(!out.solutions.is_empty());
    }

    #[test]
    fn solutions_respect_cell_bounds() {
        let nl = adder(2);
        let out = search_shared(&nl, 1, &quick_cfg());
        for s in &out.solutions {
            assert!(s.proxy.0 <= s.cell.0, "pit {} > cell {}", s.proxy.0, s.cell.0);
            assert!(s.proxy.1 <= s.cell.1);
            assert!(s.max_err <= 1);
        }
    }
}

//! Public search API: configuration, outcome types and the two paper
//! methods as thin instantiations of the generic lattice engine
//! ([`super::engine::run_search`]) — SHARED and XPAT differ only in the
//! [`Template`](super::engine::Template) implementation they plug in.
//!
//! [`MiterCache`] is the build-once/clone-cheap store for miter
//! *prototypes*: a sweep running several jobs over the same geometry
//! (benchmark × ET × pool) encodes the base CNF once and hands every job
//! a clone. Prototypes are pristine (never solved) and preprocessed once
//! at insert time; preprocessing is deterministic and idempotent, so a
//! cache hit is byte-identical to a fresh build-and-preprocess and
//! results cannot depend on whether the cache was warm.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::circuit::sim::TruthTables;
use crate::circuit::Netlist;
use crate::obs::Obs;
use crate::template::{NonsharedMiter, SharedMiter, SopParams};

use super::engine::{run_search, run_search_exact_obs};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SearchConfig {
    /// Product-pool size (SHARED) / per-output slots (XPAT).
    pub pool: usize,
    /// Models to enumerate per SAT cell.
    pub solutions_per_cell: usize,
    /// SAT cells to accept before stopping (weakening continues until
    /// this many cells answered SAT).
    pub max_sat_cells: usize,
    /// Per-solve conflict budget (None = run to completion).
    pub conflict_budget: Option<u64>,
    /// Overall wall-clock budget in milliseconds.
    pub time_budget_ms: u64,
    /// Threads scanning lattice cells within one search. `1` (the
    /// default) is the historical sequential scan; `> 1` switches to the
    /// canonical per-cell scan, which is deterministic across runs and
    /// thread counts as long as the wall-clock budget does not bind
    /// (see `search::engine`).
    pub cell_workers: usize,
    /// With `cell_workers > 1`, block every model found by any worker
    /// into each fresh per-cell miter. Avoids duplicate models at the
    /// cost of scheduling-dependent (non-deterministic) model choice;
    /// off by default — duplicates are removed at commit time instead.
    pub share_blocked_models: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            pool: 10,
            solutions_per_cell: 3,
            max_sat_cells: 10,
            conflict_budget: Some(200_000),
            time_budget_ms: 60_000,
            cell_workers: 1,
            share_blocked_models: false,
        }
    }
}

impl SearchConfig {
    /// Serialize for the distributed-sweep wire (`dist::protocol`):
    /// every field travels, including the determinism-neutral ones
    /// (`cell_workers`, `share_blocked_models`) — a worker may override
    /// those locally, but the coordinator's values are the defaults.
    /// Deterministic rendering via `Json::render` (sorted keys, ASCII).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("pool".to_string(), Json::Num(self.pool as f64));
        m.insert(
            "solutions_per_cell".to_string(),
            Json::Num(self.solutions_per_cell as f64),
        );
        m.insert("max_sat_cells".to_string(), Json::Num(self.max_sat_cells as f64));
        m.insert(
            "conflict_budget".to_string(),
            match self.conflict_budget {
                Some(b) => Json::Num(b as f64),
                None => Json::Null,
            },
        );
        m.insert("time_budget_ms".to_string(), Json::Num(self.time_budget_ms as f64));
        m.insert("cell_workers".to_string(), Json::Num(self.cell_workers as f64));
        m.insert(
            "share_blocked_models".to_string(),
            Json::Bool(self.share_blocked_models),
        );
        Json::Obj(m)
    }

    /// Inverse of [`SearchConfig::to_json`].
    pub fn from_json(j: &crate::util::Json) -> anyhow::Result<SearchConfig> {
        use anyhow::anyhow;
        use crate::util::Json;
        let num = |key: &str| {
            j.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("search config: missing/invalid {key:?}"))
        };
        let conflict_budget = match j.get("conflict_budget") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_u64()
                    .ok_or_else(|| anyhow!("search config: bad conflict_budget"))?,
            ),
        };
        Ok(SearchConfig {
            pool: num("pool")? as usize,
            solutions_per_cell: num("solutions_per_cell")? as usize,
            max_sat_cells: num("max_sat_cells")? as usize,
            conflict_budget,
            time_budget_ms: num("time_budget_ms")?,
            cell_workers: num("cell_workers")?.max(1) as usize,
            share_blocked_models: j
                .get("share_blocked_models")
                .and_then(Json::as_bool)
                .unwrap_or(false),
        })
    }
}

/// One satisfying assignment, fully post-processed.
#[derive(Debug, Clone)]
pub struct Solution {
    pub params: SopParams,
    /// (PIT, ITS) for SHARED, (LPP, PPO) for XPAT — the *achieved* proxy
    /// values of the model, not the cell bounds.
    pub proxy: (usize, usize),
    pub cell: (usize, usize),
    pub area: f64,
    pub max_err: u64,
    pub mean_err: f64,
}

/// Search telemetry + all solutions found.
///
/// `cells_tried == cells_sat + cells_unsat + cells_timeout`: a cell whose
/// first solve ran out of conflict budget counts as a timeout, not as
/// UNSAT — the two mean different things for the figures.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub solutions: Vec<Solution>,
    pub cells_tried: usize,
    pub cells_sat: usize,
    pub cells_unsat: usize,
    pub cells_timeout: usize,
    pub elapsed_ms: u64,
}

impl SearchOutcome {
    /// The headline result: smallest synthesised area (Fig. 5 reports one
    /// best point per method).
    pub fn best(&self) -> Option<&Solution> {
        self.solutions
            .iter()
            .min_by(|a, b| a.area.partial_cmp(&b.area).unwrap())
    }
}

/// SHARED search (the paper's contribution).
pub fn search_shared(nl: &Netlist, et: u64, cfg: &SearchConfig) -> SearchOutcome {
    run_search::<SharedMiter>(nl, et, cfg)
}

/// Original-XPAT search over the nonshared template.
pub fn search_xpat(nl: &Netlist, et: u64, cfg: &SearchConfig) -> SearchOutcome {
    run_search::<NonsharedMiter>(nl, et, cfg)
}

/// Geometry key: everything the base miter CNF depends on — input and
/// output counts, pool, ET and the exhaustive truth table itself, so two
/// different functions can never alias a prototype (netlist names are
/// caller-supplied and not trustworthy as identity).
type GeometryKey = (usize, usize, usize, u64, Vec<u64>);

/// Cross-job store of pristine miter prototypes, keyed by geometry.
///
/// `coordinator::sweep` keeps one cache per sweep: the first job of a
/// geometry pays the encode (and the one-time solver preprocessing),
/// every later same-geometry job clones it. Because a prototype is never
/// solved and never blocked, and preprocessing is deterministic and
/// idempotent, a clone from the cache is byte-identical to a fresh
/// build-and-preprocess — cache warmth cannot change any result, only
/// the time to first solve.
#[derive(Default)]
pub struct MiterCache {
    shared: Mutex<HashMap<GeometryKey, Arc<SharedMiter>>>,
    xpat: Mutex<HashMap<GeometryKey, Arc<NonsharedMiter>>>,
}

impl MiterCache {
    pub fn new() -> Self {
        MiterCache::default()
    }

    /// Number of distinct geometries encoded so far (both templates).
    pub fn len(&self) -> usize {
        self.shared.lock().unwrap().len() + self.xpat.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn geometry_key(
        nl: &Netlist,
        et: u64,
        cfg: &SearchConfig,
        exact: &[u64],
    ) -> GeometryKey {
        (nl.n_inputs(), nl.n_outputs(), cfg.pool, et, exact.to_vec())
    }

    /// Shared cache protocol. Only an `Arc` handle is touched under the
    /// lock: a cold build can be expensive (2^n expansion) and even the
    /// deep per-job clone is a multi-buffer copy, so both happen outside
    /// it — workers on other geometries never stall. Two workers racing
    /// on the same cold key both build byte-identical prototypes (the
    /// encode is deterministic), so whichever insert wins is
    /// indistinguishable.
    fn proto_from<T: Clone>(
        map: &Mutex<HashMap<GeometryKey, Arc<T>>>,
        key: GeometryKey,
        build: impl FnOnce(usize, usize, usize, &[u64], u64) -> T,
    ) -> T {
        let cached = map.lock().unwrap().get(&key).cloned();
        let handle = match cached {
            Some(p) => p,
            None => {
                let built = Arc::new(build(key.0, key.1, key.2, &key.4, key.3));
                map.lock().unwrap().entry(key).or_insert(built).clone()
            }
        };
        (*handle).clone()
    }

    /// As [`search_shared`], sourcing the prototype from this cache.
    pub fn search_shared(
        &self,
        nl: &Netlist,
        et: u64,
        cfg: &SearchConfig,
    ) -> SearchOutcome {
        let exact = TruthTables::simulate(nl).output_values(nl);
        self.search_shared_with(nl, et, cfg, &exact)
    }

    /// As [`search_shared`] with the exhaustive truth table supplied by
    /// the caller — the coordinator simulates it once per job (it is
    /// also the soundness oracle and the store fingerprint input) and
    /// threads it through here, so neither the key computation nor the
    /// engine re-simulates. `exact` MUST be `nl`'s exhaustive table.
    pub fn search_shared_with(
        &self,
        nl: &Netlist,
        et: u64,
        cfg: &SearchConfig,
        exact: &[u64],
    ) -> SearchOutcome {
        self.search_shared_obs(nl, et, cfg, exact, &Obs::off())
    }

    /// As [`MiterCache::search_shared_with`], tracing the probe and
    /// per-cell spans into `obs` (observe-only — see `run_search_exact_obs`).
    pub fn search_shared_obs(
        &self,
        nl: &Netlist,
        et: u64,
        cfg: &SearchConfig,
        exact: &[u64],
        obs: &Obs,
    ) -> SearchOutcome {
        let key = Self::geometry_key(nl, et, cfg, exact);
        // Preprocess at insert time: every later same-geometry job clones
        // the already-simplified CNF (idempotent, so the engine's own
        // `preprocess` call on the clone is a no-op).
        let proto = Self::proto_from(&self.shared, key, |n, m, p, e, et| {
            let mut t = SharedMiter::build(n, m, p, e, et);
            t.preprocess();
            t
        });
        run_search_exact_obs::<SharedMiter>(nl, et, cfg, Some(proto), exact, obs)
    }

    /// As [`search_xpat`], sourcing the prototype from this cache.
    pub fn search_xpat(
        &self,
        nl: &Netlist,
        et: u64,
        cfg: &SearchConfig,
    ) -> SearchOutcome {
        let exact = TruthTables::simulate(nl).output_values(nl);
        self.search_xpat_with(nl, et, cfg, &exact)
    }

    /// As [`search_shared_with`], for the nonshared template.
    pub fn search_xpat_with(
        &self,
        nl: &Netlist,
        et: u64,
        cfg: &SearchConfig,
        exact: &[u64],
    ) -> SearchOutcome {
        self.search_xpat_obs(nl, et, cfg, exact, &Obs::off())
    }

    /// As [`MiterCache::search_shared_obs`], for the nonshared template.
    pub fn search_xpat_obs(
        &self,
        nl: &Netlist,
        et: u64,
        cfg: &SearchConfig,
        exact: &[u64],
        obs: &Obs,
    ) -> SearchOutcome {
        let key = Self::geometry_key(nl, et, cfg, exact);
        let proto = Self::proto_from(&self.xpat, key, |n, m, p, e, et| {
            let mut t = NonsharedMiter::build(n, m, p, e, et);
            t.preprocess();
            t
        });
        run_search_exact_obs::<NonsharedMiter>(nl, et, cfg, Some(proto), exact, obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::{adder, benchmark_by_name, multiplier};
    use crate::circuit::sim::{is_sound, TruthTables};
    use crate::synth::synthesize_area;

    fn exact_values(nl: &Netlist) -> Vec<u64> {
        TruthTables::simulate(nl).output_values(nl)
    }

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            pool: 6,
            solutions_per_cell: 2,
            max_sat_cells: 2,
            conflict_budget: Some(50_000),
            time_budget_ms: 30_000,
            ..Default::default()
        }
    }

    #[test]
    fn search_config_json_round_trip() {
        let mut cfg = quick_cfg();
        cfg.cell_workers = 4;
        cfg.share_blocked_models = true;
        let back = SearchConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // None conflict budget survives as JSON null.
        cfg.conflict_budget = None;
        let text = cfg.to_json().render();
        assert!(text.contains("\"conflict_budget\":null"), "{text}");
        let back = SearchConfig::from_json(&crate::util::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.conflict_budget, None);
        // Missing fields fail loudly, not with defaults.
        assert!(SearchConfig::from_json(&crate::util::Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn shared_search_finds_sound_low_area_adder() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let out = search_shared(&nl, 2, &quick_cfg());
        let best = out.best().expect("solutions expected");
        assert!(is_sound(&exact, &best.params.output_values(), 2));
        let exact_area = synthesize_area(&nl);
        assert!(
            best.area < exact_area,
            "approximation ({}) should beat exact ({exact_area})",
            best.area
        );
    }

    #[test]
    fn xpat_search_finds_sound_solution() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let out = search_xpat(&nl, 2, &quick_cfg());
        let best = out.best().expect("solutions expected");
        assert!(is_sound(&exact, &best.params.output_values(), 2));
    }

    #[test]
    fn shared_beats_or_matches_xpat_on_mult_i4() {
        // The paper's headline: SHARED >= XPAT in area for the same ET.
        let nl = multiplier(2);
        let mut cfg = quick_cfg();
        cfg.max_sat_cells = 6;
        cfg.solutions_per_cell = 4;
        let sh = search_shared(&nl, 2, &cfg);
        let xp = search_xpat(&nl, 2, &cfg);
        let (sa, xa) = (sh.best().unwrap().area, xp.best().unwrap().area);
        assert!(sa <= xa + 1e-9, "shared {sa} worse than xpat {xa}");
    }

    #[test]
    fn telemetry_counts_are_consistent() {
        let nl = adder(2);
        let out = search_shared(&nl, 1, &quick_cfg());
        assert_eq!(out.cells_tried, out.cells_sat + out.cells_unsat + out.cells_timeout);
        assert!(out.cells_sat > 0);
        assert!(!out.solutions.is_empty());

        // Forced-timeout case: a zero conflict budget on a hard query
        // aborts most solves; budget aborts must land in cells_timeout
        // (never in cells_unsat) and the counts must still add up.
        // (search::engine has a scripted-template test pinning the exact
        // timeout classification deterministically.)
        let mut starved = quick_cfg();
        starved.conflict_budget = Some(0);
        let out = search_shared(&multiplier(2), 0, &starved);
        assert_eq!(out.cells_tried, out.cells_sat + out.cells_unsat + out.cells_timeout);
    }

    #[test]
    fn solutions_respect_cell_bounds() {
        let nl = adder(2);
        let out = search_shared(&nl, 1, &quick_cfg());
        for s in &out.solutions {
            assert!(s.proxy.0 <= s.cell.0, "pit {} > cell {}", s.proxy.0, s.cell.0);
            assert!(s.proxy.1 <= s.cell.1);
            assert!(s.max_err <= 1);
        }
    }

    #[test]
    fn cached_prototype_search_matches_direct_search() {
        // A MiterCache hit must be invisible in the results: same full
        // outcome as the uncached path, for both templates and in both
        // scan modes, on repeated same-geometry runs.
        let nl = adder(2);
        let key = |o: &SearchOutcome| -> (usize, usize, Vec<((usize, usize), f64)>) {
            (
                o.cells_tried,
                o.cells_sat,
                o.solutions.iter().map(|s| (s.cell, s.area)).collect(),
            )
        };
        for workers in [1usize, 4] {
            let mut cfg = quick_cfg();
            cfg.cell_workers = workers;
            cfg.conflict_budget = None;
            let cache = MiterCache::new();
            let direct_sh = search_shared(&nl, 2, &cfg);
            let direct_xp = search_xpat(&nl, 2, &cfg);
            // Twice through the cache: cold (build) then warm (clone).
            for round in 0..2 {
                let sh = cache.search_shared(&nl, 2, &cfg);
                let xp = cache.search_xpat(&nl, 2, &cfg);
                assert_eq!(key(&sh), key(&direct_sh), "shared w={workers} r={round}");
                assert_eq!(key(&xp), key(&direct_xp), "xpat w={workers} r={round}");
            }
            assert_eq!(cache.len(), 2, "one prototype per (template, geometry)");
        }
    }

    #[test]
    fn parallel_cell_scan_matches_single_worker_best_area() {
        // The acceptance bar for the parallel engine: same best area as
        // the sequential scan on the paper's i4 benchmarks.
        for name in ["adder_i4", "mult_i4"] {
            let bench = benchmark_by_name(name).unwrap();
            let nl = bench.netlist();
            let et = bench.fig4_et();
            // No conflict budget: a budget that aborts the minimisation
            // descent at different depths in the two scan modes would be
            // a spurious source of area divergence.
            let mut cfg = SearchConfig {
                pool: 5,
                solutions_per_cell: 1,
                max_sat_cells: 2,
                conflict_budget: None,
                time_budget_ms: 120_000,
                ..Default::default()
            };
            let seq = search_shared(&nl, et, &cfg);
            cfg.cell_workers = 4;
            let par = search_shared(&nl, et, &cfg);
            let a = seq.best().expect("sequential found no solution").area;
            let b = par.best().expect("parallel found no solution").area;
            assert!(
                (a - b).abs() < 1e-9,
                "{name}: sequential best {a} vs parallel best {b}"
            );
        }
    }

    #[test]
    fn parallel_scan_is_deterministic_across_runs_and_worker_counts() {
        // Canonical mode: identical full outcomes for any worker count
        // > 1 and across repeated runs.
        let nl = multiplier(2);
        let cfg = |w: usize| SearchConfig {
            pool: 5,
            solutions_per_cell: 2,
            max_sat_cells: 3,
            conflict_budget: Some(100_000),
            time_budget_ms: 60_000,
            cell_workers: w,
            ..Default::default()
        };
        let key = |o: &SearchOutcome| -> (usize, usize, usize, usize, Vec<((usize, usize), f64)>) {
            (
                o.cells_tried,
                o.cells_sat,
                o.cells_unsat,
                o.cells_timeout,
                o.solutions.iter().map(|s| (s.cell, s.area)).collect(),
            )
        };
        let base = search_shared(&nl, 2, &cfg(2));
        for w in [2, 2, 4, 8] {
            let out = search_shared(&nl, 2, &cfg(w));
            assert_eq!(key(&out), key(&base), "workers={w}");
        }
    }
}

//! # sxpat — product-sharing templates for approximate logic synthesis
//!
//! A full reproduction of *"An Improved Template for Approximate
//! Computing"* (Rezaalipour et al., 2025): SMT-style template-based
//! approximate logic synthesis with the paper's SHARED product-sharing
//! template, the original XPAT nonshared template, and the MUSCAT /
//! MECALS baselines, over from-scratch substrates (CDCL SAT solver,
//! AIG optimiser, technology mapper / area model, Verilog subset I/O).
//!
//! Architecture (see DESIGN.md): a rust L3 coordinator owns the search
//! and experiment orchestration; the bulk-evaluation hot path is a JAX +
//! Pallas program AOT-lowered to HLO text and executed via PJRT
//! (`runtime`), with a bit-parallel rust evaluator (`evaluator`) as the
//! oracle and fallback.

pub mod aig;
pub mod baselines;
pub mod bench_support;
pub mod circuit;
pub mod coordinator;
pub mod dist;
pub mod evaluator;
pub mod monitor;
pub mod nn;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod sat;
pub mod search;
pub mod serve;
pub mod smt;
pub mod store;
pub mod synth;
pub mod template;
pub mod util;

//! `sxpat` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   bench-gen                      write benchmark Verilog into benchmarks/
//!   synth      --bench B --method M --et E     one synthesis job
//!   sweep      [--out DIR] [--store DIR]  Fig. 5: all benches x methods x ETs
//!   proxy-study [--out DIR]        Fig. 4: scatter + random baseline
//!   random-baseline --bench B --et E --count N
//!   verify     --bench B --et E    re-verify SHARED result exhaustively
//!   nn-eval    [--et-list 0,1,2,4] NN accuracy vs multiplier area
//!   oplib      list|best|export    query/export the persistent operator store
//!   serve      [--store DIR]       QoS-tiered batched inference server (TCP)
//!   loadgen    [--addr A]          closed-loop load generator for `serve`
//!   worker     --connect ADDR      distributed-sweep worker node
//!   trace      FILE... [--top N] [--check|--tree|--critical-path|--flame]
//!                                  inspect --trace JSONL dumps
//!   monitor    --serve A,B --coord C   live cluster telemetry view
//!   perfgate   OLD NEW | --reduce FILE perf regression gate
//!
//! `sweep --store DIR` opens the persistent result store in DIR: jobs
//! already fingerprinted there are served from disk (no SAT search,
//! `cached=true` in the CSVs), fresh results are appended as they
//! commit — so an interrupted sweep resumes where it stopped. The
//! `--resume` flag is the explicit spelling of that default (it errors
//! without `--store`, as a guard against expecting resumption with no
//! store configured).
//!
//! `sweep --distributed ADDR` (alias `--listen ADDR`) runs the sweep
//! as a *coordinator*: it binds ADDR, serves store cache hits locally,
//! and leases the remaining jobs to `worker` nodes over TCP
//! (line-delimited JSON; see `dist::protocol` and DESIGN.md §11). The
//! coordinator is the single WAL writer; leases that expire
//! (`--lease-ms`, default 2×time budget + 30s) or belong to a dead
//! connection are requeued, and the record set is byte-identical to a
//! local sweep regardless of worker count. `worker --connect ADDR
//! [--name N] [--cell-workers K] [--max-jobs N]` runs one worker node;
//! its search config comes from each lease, with only the
//! determinism-neutral `cell_workers` overridable per node.
//!
//! `oplib` reads a store and serves the deployment-time lookup:
//!   oplib list   --store DIR              per-benchmark Pareto frontiers
//!   oplib best   --store DIR --bench B --et N   cheapest operator within budget
//!   oplib export --store DIR [--out DIR]  frontier operators as .tt files
//!
//! Flags: --pool, --workers (parallel jobs), --cell-workers (parallel
//! lattice cells within one job; `sweep` shrinks the outer job pool so
//! jobs × cells stays near the core count), --share-models (exchange
//! blocked models across cell workers; faster dedup, non-deterministic),
//! --budget (SAT conflicts), --pjrt (use the AOT artifact for bulk
//! evaluation), --artifacts DIR.
//!
//! `serve` binds a line-delimited-JSON TCP endpoint (see
//! `serve::protocol`) and answers digit-classification requests at
//! named QoS tiers (`--tiers gold=0,silver=4,bronze=16`): each tier is
//! resolved at startup to the min-area operator on the store's Pareto
//! frontier within the tier's error budget (re-verified against the
//! exhaustive oracle, falling back to the exact multiplier when the
//! library has nothing within budget), and a `reload` request
//! atomically re-resolves after new sweeps land in the store. Each
//! resolved operator is folded into a compiled branchless batch kernel
//! (`nn::kernel`, DESIGN.md §12) at resolve/reload time; `--scalar-path`
//! keeps every tier on the scalar `classify_batch` oracle instead, and
//! `stats` reports the per-tier path. Requests
//! are micro-batched (`--batch`, `--batch-wait-ms`) across
//! `--serve-workers` worker threads; `--dump-metrics` writes
//! `BENCH_serve.json` on shutdown. `loadgen` drives a running server
//! closed-loop (`--clients`, `--requests` per client, `--tier-names`)
//! and prints throughput/latency; `--stats` also fetches the server's
//! metrics, `--shutdown` stops the server afterwards.
//!
//! `synth --dump-cnf DIR [--cell-a A --cell-b B]` skips the search and
//! instead exports the cell's miter (base CNF + the cell's restriction
//! assumptions as units) as DIMACS, for cross-checking against a
//! reference SAT solver offline. Cell bounds default to the weakest
//! (unrestricted) cell. The inverse, `synth --solve-dimacs FILE`, replays
//! such a dump through this repo's own solver (preprocessing + the
//! Glucose-class heuristics) and prints a DIMACS-style `s` answer line
//! plus `c` statistics lines — the standalone surface for solver A/B
//! debugging, also exercised by the CI smoke job.
//!
//! `synth --emit-kernel FILE` additionally renders the synthesised 4x4
//! multiplier, folded into the canonical serving MLP, as standalone
//! dependency-free Rust source (`nn::kernel::CompiledMlp::emit_rust_source`).
//!
//! Observability: `sweep --trace FILE`, `worker --trace FILE`,
//! `serve --trace FILE` and `loadgen --trace FILE` dump structured
//! JSONL events (spans around every cell/probe solve with folded
//! SAT-effort deltas, request/batch/compute spans in the server, dist
//! lease/commit events) to FILE without perturbing results — the
//! record set stays byte-identical (see `obs` and DESIGN.md §13).
//! Spans carry optional `parent` references (within and across
//! nodes), so merged coordinator + worker dumps form one causal tree
//! per job. `trace FILE...` renders per-phase timelines, the top-N
//! slowest spans, and per-node counts and commit accounting; `trace
//! --tree` renders the causal waterfall with self time,
//! `--critical-path` the slowest causal chain, `--flame` folded
//! stacks for inferno/`flamegraph.pl`; `trace --check FILE...`
//! validates schema, span balance and parent resolution, exiting
//! non-zero on a malformed trace (the CI contract). `PALLAS_LOG`
//! filters the leveled stderr logging (e.g. `PALLAS_LOG=debug`,
//! default `warn`).
//!
//! Live telemetry (DESIGN.md §14): `serve` answers `watch`
//! subscriptions (one cumulative registry sample every `--sample-ms`
//! per subscriber), workers piggyback compact telemetry on each lease
//! request, and the coordinator answers a pre-`hello` `status` poll
//! with an aggregate sample. `monitor --serve A,B --coord C
//! [--interval-ms N] [--iterations N] [--out TS.jsonl] [--slo FILE]`
//! subscribes to any mix of endpoints, renders the aggregated
//! per-tier / per-worker cluster table (exact histogram merges) and
//! appends the time-series log. `loadgen --rate RPS` switches the
//! load generator to an open-loop arrival schedule (latency charged
//! from intended send times — no coordinated omission);
//! `--spike-after K --spike-ms MS` injects a sender stall, and
//! `--slo FILE` judges the client-observed series as fast/slow burn
//! rates, emitting `slo.breach` events into the trace. `perfgate OLD
//! NEW [--tolerance F] [--min-delta F]` compares two perf artifacts
//! (`BENCH_*.json` reports or time-series logs) under noise
//! thresholds and exits non-zero on a regression — the CI gate;
//! `perfgate --reduce FILE` prints the flat reduced metric map.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use sxpat::baselines::random_sound_baseline;
use sxpat::circuit::generators::{benchmark_by_name, PAPER_BENCHMARKS};
use sxpat::circuit::sim::TruthTables;
use sxpat::circuit::verilog::write_verilog;
use sxpat::coordinator::{run_job, run_sweep_obs, Job, Method, SweepPlan};
use sxpat::dist::{run_worker, Coordinator, DistConfig, WorkerConfig};
use sxpat::obs::Obs;
use sxpat::evaluator::rust_eval::evaluate_batch;
use sxpat::report::{fig4_csv, fig5_csv, fig5_markdown, records_csv};
use sxpat::runtime::{find_artifacts_dir, Runtime};
use sxpat::sat::dimacs::{solve_dimacs, to_dimacs};
use sxpat::sat::SatResult;
use sxpat::search::SearchConfig;
use sxpat::store::{OpLib, Store};
use sxpat::synth::synthesize_area;
use sxpat::template::{NonsharedMiter, SharedMiter, SopParams};
use sxpat::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("bench-gen") => bench_gen(args),
        Some("synth") => synth(args),
        Some("sweep") => sweep(args),
        Some("proxy-study") => proxy_study(args),
        Some("random-baseline") => random_baseline(args),
        Some("verify") => verify(args),
        Some("nn-eval") => nn_eval(args),
        Some("oplib") => oplib(args),
        Some("serve") => serve(args),
        Some("loadgen") => loadgen(args),
        Some("worker") => worker(args),
        Some("trace") => trace_cmd(args),
        Some("monitor") => monitor(args),
        Some("perfgate") => perfgate(args),
        _ => {
            eprintln!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "usage: sxpat <bench-gen|synth|sweep|proxy-study|random-baseline|verify|nn-eval|oplib|serve|loadgen|worker|trace|monitor|perfgate> [--flags]
see rust/src/main.rs header or README.md for details";

fn search_config(args: &Args) -> Result<SearchConfig> {
    Ok(SearchConfig {
        pool: args.get_usize_or("pool", 10)?,
        solutions_per_cell: args.get_usize_or("solutions", 3)?,
        max_sat_cells: args.get_usize_or("sat-cells", 4)?,
        conflict_budget: Some(args.get_u64("budget")?.unwrap_or(200_000)),
        time_budget_ms: args.get_u64("time-ms")?.unwrap_or(120_000),
        cell_workers: args.get_usize_or("cell-workers", 1)?.max(1),
        share_blocked_models: args.has_flag("share-models"),
    })
}

fn out_dir(args: &Args) -> Result<PathBuf> {
    let dir = PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

fn the_bench(args: &Args) -> Result<&'static sxpat::circuit::Benchmark> {
    let name = args
        .get("bench")
        .ok_or_else(|| anyhow!("--bench <name> required (e.g. adder_i4)"))?;
    benchmark_by_name(name).ok_or_else(|| {
        anyhow!(
            "unknown benchmark {name}; have: {}",
            PAPER_BENCHMARKS.iter().map(|b| b.name).collect::<Vec<_>>().join(", ")
        )
    })
}

fn bench_gen(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("out", "benchmarks"));
    std::fs::create_dir_all(&dir)?;
    for b in &PAPER_BENCHMARKS {
        let path = dir.join(format!("{}.v", b.name));
        std::fs::write(&path, write_verilog(&b.netlist()))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn synth(args: &Args) -> Result<()> {
    // Standalone replay of a dumped instance: no --bench needed, the
    // formula is fully described by the file.
    if let Some(path) = args.get("solve-dimacs") {
        return solve_dimacs_file(Path::new(path));
    }
    let bench = the_bench(args)?;
    let et = args.get_u64("et")?.unwrap_or(bench.fig4_et());
    let method = match args.get_or("method", "shared").as_str() {
        "shared" => Method::Shared,
        "xpat" => Method::Xpat,
        "muscat" => Method::Muscat,
        "mecals" => Method::Mecals,
        m => bail!("unknown method {m}"),
    };
    if let Some(dir) = args.get("dump-cnf") {
        return dump_cnf(args, bench, method, et, &PathBuf::from(dir));
    }
    let rec = run_job(&Job { bench, method, et, search: search_config(args)? });
    println!(
        "{} {} et={} -> area {:.3} µm², max_err {}, mean_err {:.3}, {} ms",
        rec.bench,
        rec.method.name(),
        rec.et,
        rec.area,
        rec.max_err,
        rec.mean_err,
        rec.elapsed_ms
    );
    if method == Method::Shared || method == Method::Xpat {
        println!("proxy: ({}, {})", rec.proxy.0, rec.proxy.1);
    }
    let exact_area = synthesize_area(&bench.netlist());
    println!("exact area {:.3} µm² -> saving {:.1}%", exact_area,
             100.0 * (1.0 - rec.area / exact_area));
    if let Some(path) = args.get("emit-kernel") {
        emit_kernel(&rec, Path::new(path))?;
    }
    Ok(())
}

/// `synth --emit-kernel FILE`: render the synthesised multiplier,
/// folded into the canonical serving MLP, as standalone Rust source —
/// the AOT mirror of what `serve` compiles at registry resolve time
/// (see `nn::kernel`). 4x4 multipliers only (the serving datapath).
fn emit_kernel(rec: &sxpat::coordinator::RunRecord, path: &Path) -> Result<()> {
    use sxpat::nn::{CompiledMlp, MultLut};
    let lut = MultLut::try_from_values(&rec.values)
        .map_err(|m| anyhow!("--emit-kernel needs a 4x4 multiplier operator: {m}"))?;
    let mlp = sxpat::serve::serving_mlp();
    let kernel = CompiledMlp::try_compile(&mlp, &lut)
        .map_err(|m| anyhow!("operator not compilable to i16 product rows: {m}"))?;
    let name = format!("{}_{}_et{}", rec.bench, rec.method.name().to_lowercase(), rec.et);
    std::fs::write(path, kernel.emit_rust_source(&name))?;
    println!(
        "wrote {} (hidden {}, {} inputs, {} product-table bytes)",
        path.display(),
        kernel.hidden(),
        kernel.n_in(),
        2 * 16 * (kernel.hidden() * kernel.n_in() + 10 * kernel.hidden())
    );
    Ok(())
}

/// Export one lattice cell's miter instance as DIMACS CNF: the encoded
/// base formula plus the cell's restriction assumptions appended as unit
/// clauses, via the existing `sat::dimacs` writer. An external solver
/// run on the file must agree with `miter.solve(a, b)` on SAT/UNSAT.
fn dump_cnf(
    args: &Args,
    bench: &'static sxpat::circuit::Benchmark,
    method: Method,
    et: u64,
    dir: &PathBuf,
) -> Result<()> {
    let nl = bench.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    let (n, m) = (nl.n_inputs(), nl.n_outputs());
    let pool = args.get_usize_or("pool", 10)?;
    let (clauses, n_vars, cell) = match method {
        Method::Shared => {
            let miter = SharedMiter::build(n, m, pool, &exact, et);
            let a = args.get_usize_or("cell-a", pool)?;
            let b = args.get_usize_or("cell-b", pool * m)?;
            let mut cl = miter.b.solver.export_clauses();
            cl.extend(miter.restrict(a, b).into_iter().map(|l| vec![l]));
            (cl, miter.b.solver.n_vars(), (a, b))
        }
        Method::Xpat => {
            let miter = NonsharedMiter::build(n, m, pool, &exact, et);
            let a = args.get_usize_or("cell-a", n)?;
            let b = args.get_usize_or("cell-b", pool)?;
            let mut cl = miter.b.solver.export_clauses();
            cl.extend(miter.restrict(a, b).into_iter().map(|l| vec![l]));
            (cl, miter.b.solver.n_vars(), (a, b))
        }
        _ => bail!("--dump-cnf supports only the shared/xpat template methods"),
    };
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!(
        "{}_{}_et{}_cell{}x{}.cnf",
        bench.name,
        method.name().to_lowercase(),
        et,
        cell.0,
        cell.1
    ));
    std::fs::write(&path, to_dimacs(n_vars, &clauses))?;
    println!(
        "wrote {} ({} vars, {} clauses, cell ({}, {}))",
        path.display(),
        n_vars,
        clauses.len(),
        cell.0,
        cell.1
    );
    Ok(())
}

/// Replay a dumped DIMACS miter (the inverse of `--dump-cnf`): load the
/// file, run the solver's one-time preprocessing plus the Glucose-class
/// search, and print `c` statistics lines followed by a DIMACS-style
/// answer line (`s SATISFIABLE` / `s UNSATISFIABLE`) that scripts and
/// the CI smoke job can grep.
fn solve_dimacs_file(path: &Path) -> Result<()> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    let (result, stats) = solve_dimacs(&src)?;
    let mean_lbd = if stats.conflicts > 0 {
        stats.lbd_sum as f64 / stats.conflicts as f64
    } else {
        0.0
    };
    println!("c file {}", path.display());
    println!(
        "c conflicts {} propagations {} decisions {}",
        stats.conflicts, stats.propagations, stats.decisions
    );
    println!(
        "c restarts {} blocked {} mean_lbd {mean_lbd:.2}",
        stats.restarts, stats.restarts_blocked
    );
    println!(
        "c preprocess probes {} subsumed {}",
        stats.preprocess_probes, stats.preprocess_subsumed
    );
    println!(
        "s {}",
        match result {
            SatResult::Sat => "SATISFIABLE",
            SatResult::Unsat => "UNSATISFIABLE",
        }
    );
    Ok(())
}

fn sweep(args: &Args) -> Result<()> {
    let dir = out_dir(args)?;
    let mut plan = SweepPlan { search: search_config(args)?, ..Default::default() };
    if let Some(b) = args.get("bench") {
        plan.benches = vec![benchmark_by_name(b).ok_or_else(|| anyhow!("unknown bench"))?];
    }
    if let Some(w) = args.get_u64("workers")? {
        plan.workers = w as usize;
    } else if plan.search.cell_workers > 1 {
        // One thread budget for the nested jobs × cells parallelism:
        // shrink the outer job pool so the product stays near the
        // machine's core count.
        plan.workers = (plan.workers / plan.search.cell_workers).max(1);
    }
    let store = match args.get("store") {
        Some(d) => Some(Store::open(Path::new(d))?),
        None if args.has_flag("store") => {
            // `--store` immediately followed by another flag parses as
            // a bare flag; running a long sweep silently without
            // persistence would be a nasty surprise.
            bail!("--store requires a directory argument");
        }
        None => None,
    };
    // `--resume DIR` parses as an *option*, silently skipping the flag
    // guard below — the classic misuse is `sweep --resume results/store`
    // by a user who thinks --resume names the store. Reject both shapes
    // loudly: a "resumable" sweep with no store would re-solve the world.
    if let Some(v) = args.get("resume") {
        bail!(
            "--resume takes no value (got {v:?}); spell it `--store {v} --resume`"
        );
    }
    if args.has_flag("resume") && store.is_none() {
        bail!("--resume requires --store DIR (nothing to resume from)");
    }
    if let Some(st) = &store {
        println!(
            "store {}: {} completed jobs on disk",
            st.dir().display(),
            st.len()
        );
    }
    if args.has_flag("distributed") || args.has_flag("listen") {
        bail!("--distributed/--listen require a bind address (e.g. 127.0.0.1:7979)");
    }
    let distributed = args.get("distributed").or_else(|| args.get("listen"));
    // --trace FILE: observe-only JSONL event dump (spans around every
    // cell/probe solve; lease/commit events when distributed). Guard
    // the bare-flag shape like --store: silently tracing nowhere would
    // defeat the point.
    let obs = match args.get("trace") {
        Some(p) => {
            let node = if distributed.is_some() { "coord" } else { "sweep" };
            Obs::to_file(Path::new(p), node)
        }
        None if args.has_flag("trace") => {
            bail!("--trace requires a file argument");
        }
        None => Obs::off(),
    };
    let records = match distributed {
        Some(addr) => {
            let cfg = DistConfig {
                addr: addr.to_string(),
                lease_ms: args.get_u64("lease-ms")?.unwrap_or(0),
                wait_ms: args.get_u64("wait-ms")?.unwrap_or(500),
                obs: obs.clone(),
            };
            let coord = Coordinator::bind(&plan, store.as_ref(), &cfg)?;
            println!(
                "coordinator listening on {} ({} jobs); start workers with \
                 `sxpat worker --connect {}`",
                coord.addr(),
                plan.n_jobs(),
                coord.addr()
            );
            coord.run()?
        }
        None => {
            println!(
                "running {} jobs on {} workers × {} cell workers...",
                plan.n_jobs(),
                plan.workers,
                plan.search.cell_workers
            );
            let records = run_sweep_obs(&plan, store.as_ref(), &obs);
            obs.flush()?;
            records
        }
    };
    if store.is_some() {
        let hits = records.iter().filter(|r| r.cached).count();
        println!(
            "{hits}/{} jobs served from the store, {} solved fresh",
            records.len(),
            records.len() - hits
        );
    }
    std::fs::write(dir.join("records.csv"), records_csv(&records))?;
    std::fs::write(dir.join("fig5.csv"), fig5_csv(&records))?;
    std::fs::write(dir.join("fig5.md"), fig5_markdown(&records))?;
    println!("{}", fig5_markdown(&records));
    println!("wrote {}/records.csv, fig5.csv, fig5.md", dir.display());
    Ok(())
}

/// The `oplib` subcommand: query/export the persistent operator store.
fn oplib(args: &Args) -> Result<()> {
    let store_dir = args
        .get("store")
        .ok_or_else(|| anyhow!("--store DIR required (a dir written by sweep --store)"))?;
    // Queries never write: a read-only open works alongside a live
    // sweep holding the writer lock.
    let store = Store::open_read_only(Path::new(store_dir))?;
    let lib = OpLib::from_store(&store);
    match args.positional.get(1).map(String::as_str) {
        Some("list") => {
            println!(
                "store {}: {} usable operators over {} benchmarks ({} WAL lines)",
                store.dir().display(),
                lib.len(),
                lib.benches().len(),
                store.lines()
            );
            for bench in lib.benches() {
                println!("\n{bench} Pareto frontier (area vs. achieved max err):");
                println!(
                    "{:>8} {:>8} {:>10}  {:<8} {}",
                    "max_err", "job_et", "area", "method", "fingerprint"
                );
                for e in lib.frontier(bench) {
                    println!(
                        "{:>8} {:>8} {:>10.3}  {:<8} {}",
                        e.max_err,
                        e.et,
                        e.area,
                        e.method.name(),
                        e.fingerprint
                    );
                }
            }
            Ok(())
        }
        Some("best") => {
            let bench = the_bench(args)?;
            let et = args
                .get_u64("et")?
                .ok_or_else(|| anyhow!("--et <budget> required"))?;
            let entry = lib.best(bench.name, et).ok_or_else(|| {
                anyhow!("no stored operator for {} within error budget {et}", bench.name)
            })?;
            OpLib::verify(entry)?;
            // Summary on stderr: stdout carries only the .tt payload,
            // so `oplib best ... > op.tt` yields a parse_tt-clean file.
            eprintln!(
                "{} et≤{et}: {} area {:.3} µm², max_err {} (job et {}), fp {} — re-verified sound",
                bench.name,
                entry.method.name(),
                entry.area,
                entry.max_err,
                entry.et,
                entry.fingerprint
            );
            print!("{}", OpLib::export_tt(entry));
            Ok(())
        }
        Some("export") => {
            let dir = out_dir(args)?;
            let mut written = 0usize;
            let mut skipped = 0usize;
            for bench in lib.benches() {
                for e in lib.frontier(bench) {
                    // One unverifiable entry (e.g. a record for a
                    // custom benchmark this binary cannot re-simulate)
                    // must not abort the rest of the export.
                    if let Err(err) = OpLib::verify(e) {
                        eprintln!(
                            "warning: skipping {} fp {}: {err:#}",
                            e.bench, e.fingerprint
                        );
                        skipped += 1;
                        continue;
                    }
                    let path = dir.join(format!(
                        "{}_err{}_{}.tt",
                        e.bench,
                        e.max_err,
                        e.method.name().to_lowercase()
                    ));
                    std::fs::write(&path, OpLib::export_tt(e))?;
                    written += 1;
                }
            }
            println!(
                "exported {written} re-verified frontier operators to {} \
                 ({skipped} skipped)",
                dir.display()
            );
            Ok(())
        }
        other => bail!("oplib <list|best|export>, got {other:?}"),
    }
}

/// The `worker` subcommand: one distributed-sweep worker node.
fn worker(args: &Args) -> Result<()> {
    let name = args.get_or("name", &format!("worker-{}", std::process::id()));
    let obs = match args.get("trace") {
        Some(p) => Obs::to_file(Path::new(p), &name),
        None if args.has_flag("trace") => {
            bail!("--trace requires a file argument");
        }
        None => Obs::off(),
    };
    let cfg = WorkerConfig {
        addr: args.get_or("connect", "127.0.0.1:7979"),
        name,
        cell_workers: args.get_u64("cell-workers")?.map(|x| x as usize),
        max_jobs: args.get_u64("max-jobs")?.map(|x| x as usize),
        obs,
    };
    println!("worker {} connecting to {}...", cfg.name, cfg.addr);
    let stats = run_worker(&cfg)?;
    println!(
        "worker {} done: {} jobs completed ({} stale duplicates, {} leases \
         rejected, {} idle waits)",
        cfg.name, stats.completed, stats.stale, stats.rejected, stats.waits
    );
    Ok(())
}

/// The `trace` subcommand: load one or more `--trace` JSONL dumps
/// (several files merge into one multi-node view — e.g. a coordinator
/// dump plus each worker's), then either validate (`--check`: schema,
/// span balance and parent-reference resolution, non-zero exit on
/// failure) or render one of the views: the default report (per-phase
/// timelines, `--top N` slowest spans, per-node counts and commit
/// accounting), `--tree` (causal waterfall with per-span self time),
/// `--critical-path` (the slowest root-to-leaf causal chain), or
/// `--flame` (folded stacks of self time for
/// inferno/`flamegraph.pl`).
fn trace_cmd(args: &Args) -> Result<()> {
    use sxpat::obs::trace;
    let files = &args.positional[1..];
    if files.is_empty() {
        bail!("trace FILE... [--top N] [--check|--tree|--critical-path|--flame]");
    }
    let mut events = Vec::new();
    for f in files {
        events.extend(trace::load(Path::new(f))?);
    }
    if args.has_flag("check") {
        let r = trace::check(&events)?;
        for w in &r.warnings {
            eprintln!("warning: {w}");
        }
        println!(
            "ok: {} event(s), {} span(s), {} parented, {} node(s) [{}]{}",
            r.events,
            r.spans,
            r.parented,
            r.nodes.len(),
            r.nodes.join(", "),
            if r.dropped > 0 {
                format!(", {} event(s) dropped to ring overflow", r.dropped)
            } else {
                String::new()
            }
        );
        return Ok(());
    }
    let top = args.get_usize_or("top", 10)?;
    if args.has_flag("tree") {
        print!("{}", trace::render_tree(&events, top));
    } else if args.has_flag("critical-path") {
        print!("{}", trace::render_critical_path(&events, top));
    } else if args.has_flag("flame") {
        print!("{}", trace::render_flame(&events));
    } else {
        print!("{}", trace::render_report(&events, top));
    }
    Ok(())
}

/// The `serve` subcommand: QoS-tiered batched inference over TCP.
fn serve(args: &Args) -> Result<()> {
    use sxpat::serve::{parse_tiers, Registry, ServeConfig, Server, DEFAULT_TIERS};

    let bench_name = args.get_or("bench", "mult_i8");
    let bench = benchmark_by_name(&bench_name)
        .ok_or_else(|| anyhow!("unknown benchmark {bench_name}"))?;
    let tiers = parse_tiers(&args.get_or("tiers", DEFAULT_TIERS))?;
    let store_dir = args.get("store").map(Path::new);
    if store_dir.is_none() {
        println!(
            "note: no --store DIR given — every tier serves the exact multiplier"
        );
    }
    println!("training the serving MLP on the synthetic digits workload...");
    let mlp = std::sync::Arc::new(sxpat::serve::serving_mlp());
    // --scalar-path: skip kernel compilation, serve every tier through
    // the scalar classify_batch oracle (differential testing).
    let compile_kernels = !args.has_flag("scalar-path");
    let registry = Registry::open(bench.name, tiers, store_dir, mlp, compile_kernels)?;
    println!("tier resolution for {}:", bench.name);
    for (name, t) in registry.snapshot().iter() {
        println!(
            "  {:<12} et<={:<4} max_err {:<4} area {:>8.3} µm²  {:<9} {}",
            name,
            t.et,
            t.max_err,
            t.area,
            t.path_str(),
            t.source_str()
        );
    }
    let obs = match args.get("trace") {
        Some(p) => Obs::to_file(Path::new(p), "serve"),
        None if args.has_flag("trace") => {
            bail!("--trace requires a file argument");
        }
        None => Obs::off(),
    };
    let cfg = ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:7878"),
        workers: args.get_usize_or("serve-workers", 4)?,
        batch: args.get_usize_or("batch", 8)?,
        batch_wait_ms: args.get_u64("batch-wait-ms")?.unwrap_or(2),
        queue_cap: args.get_usize_or("queue-cap", 1024)?,
        sample_ms: args.get_u64("sample-ms")?.unwrap_or(1000),
        obs,
    };
    let server = Server::start(&cfg, registry)?;
    println!(
        "serving {} on {} ({} workers, batch {} / {} ms); \
         send {{\"type\":\"shutdown\"}} to stop",
        bench.name,
        server.addr(),
        cfg.workers,
        cfg.batch,
        cfg.batch_wait_ms
    );
    let report = server.join();
    println!("server stopped");
    if args.has_flag("dump-metrics") {
        report.write("serve");
    }
    Ok(())
}

/// The `loadgen` subcommand: closed-loop client workload for `serve`.
fn loadgen(args: &Args) -> Result<()> {
    use sxpat::serve::protocol;
    use sxpat::serve::{parse_tiers, run_loadgen, LoadgenConfig, DEFAULT_TIERS};
    use std::io::{BufRead, BufReader, Write};

    let tiers: Vec<String> = match args.get("tier-names") {
        Some(list) => list.split(',').map(str::trim).map(str::to_string).collect(),
        None => parse_tiers(DEFAULT_TIERS)?.into_iter().map(|t| t.name).collect(),
    };
    let obs = match args.get("trace") {
        Some(p) => Obs::to_file(Path::new(p), "loadgen"),
        None if args.has_flag("trace") => {
            bail!("--trace requires a file argument");
        }
        None => Obs::off(),
    };
    // --rate RPS: total open-loop arrival rate across all clients.
    let rate = match args.get("rate") {
        Some(r) => Some(
            r.parse::<f64>()
                .map_err(|_| anyhow!("--rate must be a number (requests/sec), got {r}"))?,
        ),
        None if args.has_flag("rate") => bail!("--rate requires a requests/sec argument"),
        None => None,
    };
    // --slo FILE: judge the run's own (client-observed) registry
    // mirror, so the spec's prefix is forced to the loadgen metrics.
    let slo = match args.get("slo") {
        Some(p) => {
            let mut spec = sxpat::obs::SloSpec::load(Path::new(p))?;
            spec.prefix = "pallas_loadgen".to_string();
            Some(spec)
        }
        None if args.has_flag("slo") => bail!("--slo requires a file argument"),
        None => None,
    };
    let cfg = LoadgenConfig {
        addr: args.get_or("addr", "127.0.0.1:7878"),
        clients: args.get_usize_or("clients", 4)?,
        requests_per_client: args.get_usize_or("requests", 200)?,
        tiers,
        seed: args.get_u64("seed")?.unwrap_or(7),
        rate,
        spike_after: args.get_u64("spike-after")?.map(|x| x as usize),
        spike_ms: args.get_u64("spike-ms")?.unwrap_or(0),
        slo,
        sample_ms: args.get_u64("sample-ms")?.unwrap_or(200),
        obs,
    };
    println!(
        "loadgen: {} clients x {} requests against {} (tiers {}, {})",
        cfg.clients,
        cfg.requests_per_client,
        cfg.addr,
        cfg.tiers.join(","),
        match cfg.rate {
            Some(r) => format!("open loop at {r} req/s total"),
            None => "closed loop".to_string(),
        }
    );
    let stats = run_loadgen(&cfg)?;
    stats.report();

    if args.has_flag("stats") || args.has_flag("shutdown") {
        let stream = std::net::TcpStream::connect(&cfg.addr)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        if args.has_flag("stats") {
            writer.write_all(protocol::render_control_request("stats", 1).as_bytes())?;
            writer.write_all(b"\n")?;
            reader.read_line(&mut line)?;
            println!("server stats: {}", line.trim());
        }
        if args.has_flag("shutdown") {
            writer
                .write_all(protocol::render_control_request("shutdown", 2).as_bytes())?;
            writer.write_all(b"\n")?;
            line.clear();
            reader.read_line(&mut line)?;
            println!("server acknowledged shutdown");
        }
    }
    Ok(())
}

/// The `monitor` subcommand: live aggregated telemetry over any mix
/// of serve (`watch` subscription) and coordinator (`status` poll)
/// endpoints. Endpoint lists are comma-separated because repeated
/// `--serve` flags collapse in the option map.
fn monitor(args: &Args) -> Result<()> {
    use sxpat::monitor::{run_monitor, MonitorConfig};

    fn split_list(v: Option<&str>) -> Vec<String> {
        v.map(|s| {
            s.split(',')
                .map(str::trim)
                .filter(|a| !a.is_empty())
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default()
    }

    let obs = match args.get("trace") {
        Some(p) => Obs::to_file(Path::new(p), "monitor"),
        None if args.has_flag("trace") => {
            bail!("--trace requires a file argument");
        }
        None => Obs::off(),
    };
    let slo = match args.get("slo") {
        Some(p) => Some(sxpat::obs::SloSpec::load(Path::new(p))?),
        None if args.has_flag("slo") => bail!("--slo requires a file argument"),
        None => None,
    };
    let cfg = MonitorConfig {
        serve: split_list(args.get("serve")),
        coord: split_list(args.get("coord")),
        interval_ms: args.get_u64("interval-ms")?.unwrap_or(1000).max(1),
        iterations: args.get_u64("iterations")?,
        out: args.get("out").map(PathBuf::from),
        slo,
        obs,
    };
    let summary = run_monitor(&cfg)?;
    if summary.endpoints_live == 0 {
        bail!(
            "no endpoint delivered a sample ({} configured)",
            summary.endpoints
        );
    }
    Ok(())
}

/// The `perfgate` subcommand: compare two perf artifacts
/// (`BENCH_*.json` or time-series JSONL) under noise thresholds,
/// exiting non-zero on a regression. `--reduce FILE` instead prints
/// one artifact's flat reduced metric map as a bench-report JSON
/// object (one key per line — greppable, and itself valid `perfgate`
/// input).
fn perfgate(args: &Args) -> Result<()> {
    use sxpat::obs::perfgate::{compare, load_flat, GateConfig};

    if let Some(path) = args.get("reduce") {
        let flat = load_flat(Path::new(path))?;
        let mut report = sxpat::bench_support::JsonReport::new();
        for (k, v) in &flat {
            report.push(k, *v);
        }
        print!("{}", report.render());
        return Ok(());
    }
    let (old, new) = match (args.positional.get(1), args.positional.get(2)) {
        (Some(o), Some(n)) => (PathBuf::from(o), PathBuf::from(n)),
        _ => bail!("usage: perfgate OLD NEW [--tolerance F] [--min-delta F] | perfgate --reduce FILE"),
    };
    let parse_f64 = |key: &str, default: f64| -> Result<f64> {
        match args.get(key) {
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| anyhow!("--{key} must be a number, got {v}")),
            None if args.has_flag(key) => bail!("--{key} requires a number"),
            None => Ok(default),
        }
    };
    let cfg = GateConfig {
        rel_tolerance: parse_f64("tolerance", 0.10)?,
        min_delta: parse_f64("min-delta", 0.0)?,
    };
    let report = compare(&load_flat(&old)?, &load_flat(&new)?, &cfg);
    print!("{}", report.render());
    if !report.passed() {
        bail!(
            "{} regression(s) against {}",
            report.regressions.len(),
            old.display()
        );
    }
    Ok(())
}

fn proxy_study(args: &Args) -> Result<()> {
    let dir = out_dir(args)?;
    let count = args.get_usize_or("count", 1000)?;
    let pool = args.get_usize_or("pool", 10)?;
    let runtime = if args.has_flag("pjrt") { Some(load_runtime(args)?) } else { None };

    // The paper's Fig. 4 grid: two adders and two multipliers.
    for name in ["adder_i4", "mult_i4", "adder_i6", "mult_i6"] {
        let bench = benchmark_by_name(name).unwrap();
        let et = args.get_u64("et")?.unwrap_or(bench.fig4_et());
        let nl = bench.netlist();
        let exact_area = synthesize_area(&nl);
        let mut records = Vec::new();
        for method in Method::all_compared() {
            records.push(run_job(&Job {
                bench,
                method,
                et,
                search: search_config(args)?,
            }));
        }
        let random = match &runtime {
            Some(rt) if rt.geometry(name).map(|g| g.t >= pool).unwrap_or(false) => {
                let g = rt.geometry(name).unwrap().clone();
                let hook = |batch: &[SopParams], exact: &[u64]| {
                    let widened: Vec<SopParams> = batch
                        .iter()
                        .map(|p| sxpat::evaluator::pack::widen_to_pool(p, g.t))
                        .collect();
                    rt.evaluate_batch(name, &widened, exact)
                        .unwrap_or_else(|_| evaluate_batch(batch, exact))
                };
                random_sound_baseline(&nl, et, count, pool, 42, Some(&hook))
            }
            _ => random_sound_baseline(&nl, et, count, pool, 42, None),
        };
        let csv = fig4_csv(name, et, exact_area, &records, &random);
        let path = dir.join(format!("fig4_{name}.csv"));
        std::fs::write(&path, &csv)?;
        let best_shared = records
            .iter()
            .find(|r| r.method == Method::Shared)
            .map(|r| r.area)
            .unwrap_or(f64::NAN);
        println!(
            "{name} et={et}: exact {exact_area:.2}, SHARED best {best_shared:.2}, \
             {} random sound pts -> {}",
            random.len(),
            path.display()
        );
    }
    Ok(())
}

fn load_runtime(args: &Args) -> Result<Runtime> {
    let dir = match args.get("artifacts") {
        Some(d) => PathBuf::from(d),
        None => find_artifacts_dir()
            .ok_or_else(|| anyhow!("no artifacts/ found; run `make artifacts`"))?,
    };
    let rt = Runtime::load(&dir)?;
    println!("PJRT runtime up: platform {}", rt.platform());
    Ok(rt)
}

fn random_baseline(args: &Args) -> Result<()> {
    let bench = the_bench(args)?;
    let et = args.get_u64("et")?.unwrap_or(bench.fig4_et());
    let count = args.get_usize_or("count", 1000)?;
    let pool = args.get_usize_or("pool", 10)?;
    let nl = bench.netlist();
    let pts = if args.has_flag("pjrt") {
        let rt = load_runtime(args)?;
        let name = bench.name;
        let g = rt
            .geometry(name)
            .ok_or_else(|| anyhow!("no artifact for {name}"))?
            .clone();
        let hook = |batch: &[SopParams], exact: &[u64]| {
            let widened: Vec<SopParams> = batch
                .iter()
                .map(|p| sxpat::evaluator::pack::widen_to_pool(p, g.t))
                .collect();
            rt.evaluate_batch(name, &widened, exact)
                .unwrap_or_else(|_| evaluate_batch(batch, exact))
        };
        random_sound_baseline(&nl, et, count, pool, 42, Some(&hook))
    } else {
        random_sound_baseline(&nl, et, count, pool, 42, None)
    };
    println!("{} sound random approximations (target {count})", pts.len());
    if let (Some(first), Some(last)) = (pts.first(), pts.last()) {
        println!("area range [{:.3}, {:.3}] µm²", first.area, last.area);
    }
    Ok(())
}

fn verify(args: &Args) -> Result<()> {
    let bench = the_bench(args)?;
    let et = args.get_u64("et")?.unwrap_or(bench.fig4_et());
    let nl = bench.netlist();
    let rec = run_job(&Job {
        bench,
        method: Method::Shared,
        et,
        search: search_config(args)?,
    });
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    println!(
        "SHARED on {} et={}: area {:.3}, max_err {} (bound {}) over {} points — {}",
        bench.name,
        et,
        rec.area,
        rec.max_err,
        et,
        exact.len(),
        if rec.max_err <= et { "SOUND" } else { "VIOLATION" }
    );
    if rec.max_err > et {
        bail!("verification failed");
    }
    Ok(())
}

fn nn_eval(args: &Args) -> Result<()> {
    use sxpat::nn::{synthetic_digits, MultLut, QuantMlp};
    let ets: Vec<u64> = args
        .get_or("et-list", "0,2,4,8,16")
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| anyhow!("bad --et-list")))
        .collect::<Result<_>>()?;
    let bench = benchmark_by_name("mult_i8").unwrap();
    let train = synthetic_digits(300, 11);
    let test = synthetic_digits(200, 77);
    let mlp = QuantMlp::train(&train, 12, 15, 5);
    let exact_area = synthesize_area(&bench.netlist());
    let exact_acc = mlp.accuracy(&test, &MultLut::exact());
    println!("bench=mult_i8 exact: area {exact_area:.2} µm², accuracy {exact_acc:.3}");
    println!("et,area,area_saving_pct,max_err,accuracy");
    for et in ets {
        if et == 0 {
            println!("0,{exact_area:.3},0.0,0,{exact_acc:.3}");
            continue;
        }
        // MUSCAT is the fast sound method at i8 scale.
        let res = sxpat::baselines::muscat(&bench.netlist(), et);
        let lut = MultLut::try_from_netlist(&res.netlist)
            .map_err(|e| anyhow!("et={et}: {e}"))?;
        let acc = mlp.accuracy(&test, &lut);
        println!(
            "{et},{:.3},{:.1},{},{acc:.3}",
            res.area,
            100.0 * (1.0 - res.area / exact_area),
            lut.max_error()
        );
    }
    Ok(())
}

//! The perf regression gate: compare two performance artifacts — flat
//! `BENCH_*.json` reports or time-series logs — with noise thresholds,
//! and fail (non-zero exit from the `perfgate` subcommand) when a
//! metric regressed.
//!
//! Both inputs reduce to a flat `key -> f64` map first. A BENCH report
//! is already flat; a time-series log reduces per node to counter
//! totals (summed deltas), final gauge values, and merged-histogram
//! `p50`/`p99`/`mean`/`count` derived from the last cumulative
//! snapshot of each histogram.
//!
//! The gate only judges keys whose *direction* it understands from the
//! name (`latency`/`_us`/`error`/... are lower-is-better,
//! `per_sec`/`throughput`/... higher-is-better); everything else is
//! compared for information but never fails the gate, so adding a new
//! neutral metric can't break CI. A judged key regresses when it moves
//! the wrong way by more than `rel_tolerance` relative *and* more than
//! `min_delta` absolute — the absolute floor keeps micro-benchmarks
//! with tiny magnitudes from tripping on scheduler noise.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use super::timeseries;
use crate::obs::Histogram;

/// Noise thresholds for [`compare`].
#[derive(Debug, Clone, Copy)]
pub struct GateConfig {
    /// Relative move (fraction of the old value) tolerated before a
    /// key counts as changed. Default 0.10.
    pub rel_tolerance: f64,
    /// Absolute move tolerated regardless of the relative one.
    /// Default 0 (identical inputs always pass: a zero move is never a
    /// regression).
    pub min_delta: f64,
}

impl Default for GateConfig {
    fn default() -> Self {
        GateConfig { rel_tolerance: 0.10, min_delta: 0.0 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Direction {
    Lower,
    Higher,
}

/// Infer whether a metric is lower- or higher-is-better from its
/// name; `None` means "informational only".
fn direction(key: &str) -> Option<Direction> {
    let k = key.to_ascii_lowercase();
    const HIGHER: &[&str] =
        &["per_sec", "throughput", "speedup", "_rps", "images_per", "jobs_per"];
    const LOWER: &[&str] = &[
        "latency", "_us", "_ms", "error", "rejected", "unsound", "dropped", "stale", "expired",
        "conflicts",
    ];
    if HIGHER.iter().any(|p| k.contains(p)) {
        Some(Direction::Higher)
    } else if LOWER.iter().any(|p| k.contains(p)) {
        Some(Direction::Lower)
    } else {
        None
    }
}

/// One judged key that moved the wrong way past both thresholds.
#[derive(Debug, Clone)]
pub struct Regression {
    pub key: String,
    pub old: f64,
    pub new: f64,
    /// Signed relative move in the *bad* direction (0.25 = 25% worse).
    pub worse_by: f64,
}

/// The gate's verdict over two flat metric maps.
#[derive(Debug, Default)]
pub struct GateReport {
    pub regressions: Vec<Regression>,
    /// Judged keys that moved the *good* way past the tolerance.
    pub improvements: Vec<(String, f64, f64)>,
    /// Keys compared under a known direction.
    pub judged: usize,
    /// Keys compared for information only (unknown direction or
    /// non-finite values).
    pub informational: usize,
    /// Keys present in only one input.
    pub unmatched: usize,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.regressions {
            let _ = writeln!(
                out,
                "REGRESSION {}: {} -> {} ({:+.1}% worse)",
                r.key,
                r.old,
                r.new,
                r.worse_by * 100.0
            );
        }
        for (key, old, new) in &self.improvements {
            let _ = writeln!(out, "improved   {key}: {old} -> {new}");
        }
        let _ = writeln!(
            out,
            "perfgate: {} judged, {} informational, {} unmatched -> {}",
            self.judged,
            self.informational,
            self.unmatched,
            if self.passed() { "PASS" } else { "FAIL" }
        );
        out
    }
}

/// Compare `new` against the `old` baseline under `cfg`.
pub fn compare(
    old: &BTreeMap<String, f64>,
    new: &BTreeMap<String, f64>,
    cfg: &GateConfig,
) -> GateReport {
    let mut report = GateReport::default();
    for (key, &old_v) in old {
        let Some(&new_v) = new.get(key) else {
            report.unmatched += 1;
            continue;
        };
        let dir = direction(key);
        if dir.is_none() || !old_v.is_finite() || !new_v.is_finite() {
            report.informational += 1;
            continue;
        }
        report.judged += 1;
        let bad_move = match dir {
            Some(Direction::Lower) => new_v - old_v,
            Some(Direction::Higher) => old_v - new_v,
            None => unreachable!(),
        };
        // Relative to the baseline magnitude; a zero baseline judges
        // purely on the absolute floor.
        let rel = if old_v != 0.0 {
            bad_move / old_v.abs()
        } else if bad_move > 0.0 {
            f64::INFINITY
        } else {
            0.0
        };
        if rel > cfg.rel_tolerance && bad_move.abs() > cfg.min_delta {
            report.regressions.push(Regression {
                key: key.clone(),
                old: old_v,
                new: new_v,
                worse_by: if rel.is_finite() { rel } else { 1.0 },
            });
        } else if rel < -cfg.rel_tolerance && bad_move.abs() > cfg.min_delta {
            report.improvements.push((key.clone(), old_v, new_v));
        }
    }
    report.unmatched += new.keys().filter(|k| !old.contains_key(*k)).count();
    report
}

/// Reduce parsed time-series samples to flat derived metrics, keyed
/// `{node}.{metric}[.{stat}]`.
pub fn reduce_samples(samples: &[timeseries::Sample]) -> BTreeMap<String, f64> {
    let mut counters: BTreeMap<String, u64> = BTreeMap::new();
    let mut gauges: BTreeMap<String, u64> = BTreeMap::new();
    let mut hists: BTreeMap<String, crate::obs::hist::HistSnapshot> = BTreeMap::new();
    for s in samples {
        for (name, d) in &s.counters {
            *counters.entry(format!("{}.{name}", s.node)).or_default() += d;
        }
        for (name, v) in &s.gauges {
            gauges.insert(format!("{}.{name}", s.node), *v);
        }
        for (name, snap) in &s.hists {
            // Cumulative snapshots: the biggest count is the latest
            // total, whatever order segments were appended in.
            let key = format!("{}.{name}", s.node);
            let keep = hists.get(&key).map_or(true, |prev| snap.count >= prev.count);
            if keep {
                hists.insert(key, snap.clone());
            }
        }
    }
    let mut flat = BTreeMap::new();
    for (key, v) in counters {
        flat.insert(key, v as f64);
    }
    for (key, v) in gauges {
        flat.insert(key, v as f64);
    }
    for (key, snap) in hists {
        let h = Histogram::new();
        h.absorb(&snap);
        flat.insert(format!("{key}.count"), h.count() as f64);
        flat.insert(format!("{key}.mean"), h.mean());
        flat.insert(format!("{key}.p50"), h.quantile(0.50) as f64);
        flat.insert(format!("{key}.p99"), h.quantile(0.99) as f64);
    }
    flat
}

/// Load either input kind as a flat metric map: a `BENCH_*.json`
/// report (single JSON object) or a time-series JSONL log (reduced via
/// [`reduce_samples`]).
pub fn load_flat(path: &Path) -> Result<BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read perf artifact {}", path.display()))?;
    if let Ok(report) = crate::bench_support::JsonReport::parse(&text) {
        return Ok(report
            .entries()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect());
    }
    let (samples, _footer) = timeseries::parse(&text)
        .with_context(|| format!("{} is neither a bench report nor a time-series log", path.display()))?;
    Ok(reduce_samples(&samples))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map(kvs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        kvs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn identical_inputs_always_pass() {
        let m = map(&[("serve.lat_us.p99", 1200.0), ("dist.jobs_per_sec", 8.0)]);
        let r = compare(&m, &m, &GateConfig::default());
        assert!(r.passed());
        assert_eq!(r.judged, 2);
        assert!(r.improvements.is_empty());
    }

    #[test]
    fn regressions_respect_direction_and_thresholds() {
        let old = map(&[
            ("serve.lat_us.p99", 1000.0),
            ("dist.jobs_per_sec", 10.0),
            ("neutral.knob", 5.0),
        ]);
        // p99 +50% (bad), throughput -50% (bad), neutral x10 (ignored).
        let new = map(&[
            ("serve.lat_us.p99", 1500.0),
            ("dist.jobs_per_sec", 5.0),
            ("neutral.knob", 50.0),
        ]);
        let r = compare(&old, &new, &GateConfig::default());
        assert!(!r.passed());
        let keys: Vec<&str> = r.regressions.iter().map(|x| x.key.as_str()).collect();
        assert_eq!(keys, vec!["dist.jobs_per_sec", "serve.lat_us.p99"]);
        assert_eq!(r.informational, 1, "unknown direction never fails the gate");

        // Within tolerance: 5% move on a 10% gate passes.
        let close = map(&[("serve.lat_us.p99", 1050.0), ("dist.jobs_per_sec", 10.0)]);
        assert!(compare(&old, &close, &GateConfig::default()).passed());

        // The absolute floor suppresses big-relative/small-absolute noise.
        let tiny_old = map(&[("a.lat_us.p50", 2.0)]);
        let tiny_new = map(&[("a.lat_us.p50", 3.0)]);
        let cfg = GateConfig { rel_tolerance: 0.10, min_delta: 5.0 };
        assert!(compare(&tiny_old, &tiny_new, &cfg).passed());
        assert!(!compare(&tiny_old, &tiny_new, &GateConfig::default()).passed());
    }

    #[test]
    fn improvements_and_unmatched_are_reported_not_failed() {
        let old = map(&[("serve.lat_us.p99", 1000.0), ("gone.lat_us", 1.0)]);
        let new = map(&[("serve.lat_us.p99", 500.0), ("added.lat_us", 1.0)]);
        let r = compare(&old, &new, &GateConfig::default());
        assert!(r.passed());
        assert_eq!(r.improvements.len(), 1);
        assert_eq!(r.unmatched, 2);
        assert!(r.render().contains("PASS"));
    }

    #[test]
    fn timeseries_reduction_produces_judgeable_keys() {
        use crate::obs::Histogram;
        use std::collections::BTreeMap as Map;

        let h = Histogram::new();
        h.record(500);
        let early = h.snapshot();
        h.record(90_000);
        let late = h.snapshot();
        let mk = |seq: u64, c: u64, snap| timeseries::Sample {
            node: "serve".to_string(),
            seq,
            ts_us: seq * 1000,
            counters: [("pallas_serve_requests_total".to_string(), c)].into_iter().collect(),
            gauges: [("pallas_serve_depth".to_string(), seq)].into_iter().collect(),
            hists: {
                let mut m: Map<String, _> = Map::new();
                m.insert("pallas_serve_latency_us".to_string(), snap);
                m
            },
        };
        let flat = reduce_samples(&[mk(0, 3, early), mk(1, 4, late)]);
        assert_eq!(flat["serve.pallas_serve_requests_total"], 7.0);
        assert_eq!(flat["serve.pallas_serve_depth"], 1.0, "gauges keep the last point");
        assert_eq!(flat["serve.pallas_serve_latency_us.count"], 2.0);
        assert!(flat["serve.pallas_serve_latency_us.p99"] > 10_000.0);
        assert_eq!(
            direction("serve.pallas_serve_latency_us.p99"),
            Some(Direction::Lower)
        );
    }
}

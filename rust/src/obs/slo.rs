//! Declarative per-tier SLOs evaluated as burn rates over a
//! [`TimeSeries`], in the SRE multi-window style: a target is
//! *breaching* when both a fast (reactive) and a slow (sustained)
//! trailing window burn at or above the threshold, which keeps a
//! single slow request from paging while still firing within a few
//! samples of a real incident.
//!
//! Two dimensions per tier:
//!
//! * **latency** — target `p99_us`. Window burn = (fraction of the
//!   window's requests above the target, via the conservative
//!   [`HistSnapshot::count_above`]) / 0.01, i.e. burn 1.0 means
//!   exactly the tolerated 1% of requests were slow.
//! * **error_rate** — target fraction. Window burn =
//!   (errors/requests) / target. Windows with zero requests burn 0
//!   (no traffic is not an outage).
//!
//! Breaches are **edge-triggered events, level-held gauges**: entering
//! breach emits one structured `slo.breach` counter event into the
//! trace stream (`trace --check` passes counters through, so checked
//! traces account for them) and sets
//! `pallas_slo_breach{tier="..",slo=".."}` to 1; recovery clears the
//! gauge to 0 without an event. Evaluation only *reads* the series —
//! like the rest of `obs`, it cannot perturb the run it watches.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::event::Obs;
use super::metrics;
use super::timeseries::TimeSeries;
use crate::util::Json;

/// Targets for one tier; a missing dimension is simply not evaluated.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TierSlo {
    pub p99_us: Option<u64>,
    pub error_rate: Option<f64>,
}

/// A parsed `--slo FILE` spec.
#[derive(Debug, Clone, PartialEq)]
pub struct SloSpec {
    pub tiers: BTreeMap<String, TierSlo>,
    /// Fast window length, in samples.
    pub fast_window: usize,
    /// Slow window length, in samples.
    pub slow_window: usize,
    /// Breach when both window burns are `>=` this (exact threshold
    /// breaches).
    pub burn_threshold: f64,
    /// Metric-name prefix the targets refer to: `pallas_serve`
    /// (server-side) or `pallas_loadgen` (client-observed).
    pub prefix: String,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec {
            tiers: BTreeMap::new(),
            fast_window: 6,
            slow_window: 30,
            burn_threshold: 1.0,
            prefix: "pallas_serve".to_string(),
        }
    }
}

impl SloSpec {
    /// Parse the JSON spec:
    /// `{"tiers":{"gold":{"p99_us":5000,"error_rate":0.01}},
    ///   "fast_window":6,"slow_window":30,"burn_threshold":1.0,
    ///   "prefix":"pallas_serve"}` — every key except `tiers` optional.
    pub fn parse(text: &str) -> Result<SloSpec> {
        let j = Json::parse(text).context("SLO spec is not valid JSON")?;
        let mut spec = SloSpec::default();
        let tiers = j
            .get("tiers")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("SLO spec needs a \"tiers\" object"))?;
        for (tier, t) in tiers {
            let slo = TierSlo {
                p99_us: t.get("p99_us").and_then(Json::as_u64),
                error_rate: t.get("error_rate").and_then(Json::as_f64),
            };
            if slo.p99_us.is_none() && slo.error_rate.is_none() {
                return Err(anyhow!(
                    "tier {tier:?} sets neither p99_us nor error_rate"
                ));
            }
            if slo.error_rate.is_some_and(|r| !(r > 0.0)) {
                return Err(anyhow!("tier {tier:?}: error_rate must be > 0"));
            }
            spec.tiers.insert(tier.clone(), slo);
        }
        if let Some(v) = j.get("fast_window").and_then(Json::as_u64) {
            spec.fast_window = v.max(1) as usize;
        }
        if let Some(v) = j.get("slow_window").and_then(Json::as_u64) {
            spec.slow_window = v.max(1) as usize;
        }
        if let Some(v) = j.get("burn_threshold").and_then(Json::as_f64) {
            spec.burn_threshold = v;
        }
        if let Some(v) = j.get("prefix").and_then(Json::as_str) {
            spec.prefix = v.to_string();
        }
        if spec.fast_window > spec.slow_window {
            return Err(anyhow!("fast_window must be <= slow_window"));
        }
        Ok(spec)
    }

    pub fn load(path: &Path) -> Result<SloSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read SLO spec {}", path.display()))?;
        SloSpec::parse(&text)
    }
}

/// A breach *transition* reported by one evaluation pass.
#[derive(Debug, Clone, PartialEq)]
pub struct Breach {
    pub tier: String,
    /// `"latency"` or `"error_rate"`.
    pub dimension: &'static str,
    pub burn_fast: f64,
    pub burn_slow: f64,
}

/// Stateful evaluator: tracks which (tier, dimension) pairs are
/// currently breaching so events fire on entry and gauges clear on
/// recovery.
pub struct SloEvaluator {
    spec: SloSpec,
    breached: BTreeMap<(String, &'static str), bool>,
}

impl SloEvaluator {
    pub fn new(spec: SloSpec) -> SloEvaluator {
        SloEvaluator { spec, breached: BTreeMap::new() }
    }

    pub fn spec(&self) -> &SloSpec {
        &self.spec
    }

    fn latency_burn(&self, ts: &TimeSeries, tier: &str, target_us: u64, window: usize) -> f64 {
        let name = format!("{}_latency_us{{tier=\"{tier}\"}}", self.spec.prefix);
        let Some(w) = ts.window_hist(&name, window) else {
            return 0.0;
        };
        if w.count == 0 {
            return 0.0;
        }
        let frac = w.count_above(target_us) as f64 / w.count as f64;
        frac / 0.01
    }

    fn error_burn(&self, ts: &TimeSeries, tier: &str, target: f64, window: usize) -> f64 {
        let req = ts.window_counter(
            &format!("{}_requests_total{{tier=\"{tier}\"}}", self.spec.prefix),
            window,
        );
        if req == 0 {
            return 0.0;
        }
        let err = ts.window_counter(
            &format!("{}_request_errors_total{{tier=\"{tier}\"}}", self.spec.prefix),
            window,
        );
        (err as f64 / req as f64) / target
    }

    /// Evaluate every target against the series' trailing windows.
    /// Returns the breaches *entered* by this pass; emits their
    /// `slo.breach` events on `obs` and maintains the breach gauges.
    pub fn evaluate(&mut self, ts: &TimeSeries, obs: &Obs) -> Vec<Breach> {
        let mut entered = Vec::new();
        let tiers: Vec<(String, TierSlo)> =
            self.spec.tiers.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        for (tier, slo) in tiers {
            if let Some(p99) = slo.p99_us {
                let fast = self.latency_burn(ts, &tier, p99, self.spec.fast_window);
                let slow = self.latency_burn(ts, &tier, p99, self.spec.slow_window);
                self.transition(&tier, "latency", fast, slow, obs, &mut entered);
            }
            if let Some(rate) = slo.error_rate {
                let fast = self.error_burn(ts, &tier, rate, self.spec.fast_window);
                let slow = self.error_burn(ts, &tier, rate, self.spec.slow_window);
                self.transition(&tier, "error_rate", fast, slow, obs, &mut entered);
            }
        }
        entered
    }

    fn transition(
        &mut self,
        tier: &str,
        dimension: &'static str,
        burn_fast: f64,
        burn_slow: f64,
        obs: &Obs,
        entered: &mut Vec<Breach>,
    ) {
        let breaching =
            burn_fast >= self.spec.burn_threshold && burn_slow >= self.spec.burn_threshold;
        let was = self
            .breached
            .insert((tier.to_string(), dimension), breaching)
            .unwrap_or(false);
        metrics::gauge(&format!("pallas_slo_breach{{tier=\"{tier}\",slo=\"{dimension}\"}}"))
            .set(breaching as u64);
        if breaching && !was {
            obs.counter(
                "slo.breach",
                1,
                &[
                    ("tier", Json::Str(tier.to_string())),
                    ("slo", Json::Str(dimension.to_string())),
                    ("burn_fast", Json::Num(burn_fast)),
                    ("burn_slow", Json::Num(burn_slow)),
                ],
            );
            entered.push(Breach {
                tier: tier.to_string(),
                dimension,
                burn_fast,
                burn_slow,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;
    use crate::obs::timeseries::Sample;
    use crate::obs::Histogram;

    fn spec(tier: &str) -> SloSpec {
        SloSpec::parse(&format!(
            "{{\"tiers\":{{\"{tier}\":{{\"p99_us\":1000,\"error_rate\":0.1}}}},\
             \"fast_window\":2,\"slow_window\":4,\"burn_threshold\":1.0,\
             \"prefix\":\"pallas_serve\"}}"
        ))
        .unwrap()
    }

    /// Push one synthetic ring-form sample: `req` requests, `err`
    /// errors, latencies appended to a cumulative histogram.
    fn push(ts: &mut TimeSeries, hist: &Histogram, tier: &str, req: u64, err: u64, lats: &[u64]) {
        for &v in lats {
            hist.record(v);
        }
        let mut counters = BTreeMap::new();
        if req > 0 {
            counters.insert(format!("pallas_serve_requests_total{{tier=\"{tier}\"}}"), req);
        }
        if err > 0 {
            counters.insert(format!("pallas_serve_request_errors_total{{tier=\"{tier}\"}}"), err);
        }
        let mut hists = BTreeMap::new();
        hists.insert(format!("pallas_serve_latency_us{{tier=\"{tier}\"}}"), hist.snapshot());
        ts.push(Sample {
            node: "t".to_string(),
            seq: 0,
            ts_us: 0,
            counters,
            gauges: BTreeMap::new(),
            hists,
        });
    }

    fn breach_gauge(tier: &str, dim: &str) -> u64 {
        metrics::gauge(&format!("pallas_slo_breach{{tier=\"{tier}\",slo=\"{dim}\"}}")).get()
    }

    #[test]
    fn spec_parsing_validates_and_defaults() {
        let s = SloSpec::parse("{\"tiers\":{\"gold\":{\"p99_us\":5000}}}").unwrap();
        assert_eq!(s.tiers["gold"].p99_us, Some(5000));
        assert_eq!(s.tiers["gold"].error_rate, None);
        assert_eq!((s.fast_window, s.slow_window), (6, 30));
        assert_eq!(s.prefix, "pallas_serve");
        assert!(SloSpec::parse("{}").is_err(), "tiers required");
        assert!(SloSpec::parse("{\"tiers\":{\"g\":{}}}").is_err(), "empty tier rejected");
        assert!(
            SloSpec::parse("{\"tiers\":{\"g\":{\"error_rate\":0}}}").is_err(),
            "zero error_rate rejected"
        );
        assert!(
            SloSpec::parse(
                "{\"tiers\":{\"g\":{\"p99_us\":1}},\"fast_window\":9,\"slow_window\":3}"
            )
            .is_err(),
            "fast window must fit in slow"
        );
    }

    #[test]
    fn empty_window_never_breaches() {
        let mut ev = SloEvaluator::new(spec("slo_empty"));
        let ts = TimeSeries::new("t", 8);
        assert!(ev.evaluate(&ts, &Obs::off()).is_empty());
        assert_eq!(breach_gauge("slo_empty", "latency"), 0);
        assert_eq!(breach_gauge("slo_empty", "error_rate"), 0);
    }

    #[test]
    fn exact_threshold_counts_as_breach() {
        // error_rate target 0.1, threshold 1.0: 10 errors in 100
        // requests burns exactly 1.0 in every window => breach.
        let mut ev = SloEvaluator::new(spec("slo_exact"));
        let mut ts = TimeSeries::new("t", 8);
        let h = Histogram::new();
        for _ in 0..4 {
            push(&mut ts, &h, "slo_exact", 100, 10, &[100]);
        }
        let breaches = ev.evaluate(&ts, &Obs::off());
        assert_eq!(breaches.len(), 1);
        assert_eq!(breaches[0].dimension, "error_rate");
        assert_eq!(breaches[0].burn_fast, 1.0);
        assert_eq!(breach_gauge("slo_exact", "error_rate"), 1);
        // Still breaching on the next pass: gauge holds, no new event.
        push(&mut ts, &h, "slo_exact", 100, 10, &[100]);
        assert!(ev.evaluate(&ts, &Obs::off()).is_empty(), "edge-triggered");
        assert_eq!(breach_gauge("slo_exact", "error_rate"), 1);
    }

    #[test]
    fn latency_breach_fires_and_recovery_clears_gauge() {
        let mut ev = SloEvaluator::new(spec("slo_rec"));
        let mut ts = TimeSeries::new("t", 16);
        let h = Histogram::new();
        // Healthy traffic: everything far below the 1000µs target.
        for _ in 0..4 {
            push(&mut ts, &h, "slo_rec", 50, 0, &[100, 200, 300]);
        }
        assert!(ev.evaluate(&ts, &Obs::off()).is_empty());
        // Spike: half the window's requests land above the target —
        // burn 50x in both windows.
        for _ in 0..4 {
            push(&mut ts, &h, "slo_rec", 50, 0, &[100, 50_000, 60_000]);
        }
        let breaches = ev.evaluate(&ts, &Obs::off());
        assert_eq!(breaches.len(), 1);
        assert_eq!(breaches[0].dimension, "latency");
        assert!(breaches[0].burn_fast >= 1.0 && breaches[0].burn_slow >= 1.0);
        assert_eq!(breach_gauge("slo_rec", "latency"), 1);
        // Recovery: fast traffic pushes the spike out of both windows.
        for _ in 0..5 {
            push(&mut ts, &h, "slo_rec", 50, 0, &[100, 110, 120]);
        }
        assert!(ev.evaluate(&ts, &Obs::off()).is_empty());
        assert_eq!(breach_gauge("slo_rec", "latency"), 0, "recovery clears the gauge");
        // Re-entering breach fires a fresh event.
        for _ in 0..4 {
            push(&mut ts, &h, "slo_rec", 50, 0, &[70_000, 80_000, 90_000]);
        }
        assert_eq!(ev.evaluate(&ts, &Obs::off()).len(), 1);
    }

    #[test]
    fn breach_event_lands_in_trace_as_counter() {
        use crate::obs::Event;

        let dir = std::env::temp_dir().join(format!("pallas_slo_evt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let _ = std::fs::remove_file(&path);

        let obs = Obs::to_file(&path, "slo-test");
        let mut ev = SloEvaluator::new(spec("slo_evt"));
        let mut ts = TimeSeries::new("t", 8);
        let h = Histogram::new();
        for _ in 0..4 {
            // 100% errors, all latencies healthy: exactly one breach.
            push(&mut ts, &h, "slo_evt", 100, 100, &[100]);
        }
        assert_eq!(ev.evaluate(&ts, &obs).len(), 1);
        obs.flush().unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let events: Vec<Event> = text
            .lines()
            .map(|l| Event::from_json_line(l).unwrap())
            .collect();
        let breach: Vec<_> = events.iter().filter(|e| e.name == "slo.breach").collect();
        assert_eq!(breach.len(), 1);
        assert_eq!(breach[0].kind, "counter");
        assert_eq!(breach[0].fields.get("tier").and_then(Json::as_str), Some("slo_evt"));
        assert_eq!(breach[0].fields.get("slo").and_then(Json::as_str), Some("error_rate"));
        std::fs::remove_file(&path).ok();
    }
}

//! Fixed-size log2-bucketed latency histograms over `AtomicU64`
//! arrays: lock-free recording, bounded-error quantile estimation,
//! exact merging — the bounded replacement for the unbounded
//! stored-sample `Vec<u64>` percentile paths that used to live in
//! `serve::server`, `serve::loadgen` and `bench_support`.
//!
//! **Bucket layout** (HdrHistogram-style log-linear, [`SUB_BITS`] = 5):
//! values below `2 * 2^SUB_BITS = 64` get one bucket each (exact);
//! above that, every power-of-two octave is split into `2^SUB_BITS =
//! 32` linear sub-buckets, so a bucket spanning `[lo, lo + w)` always
//! has `w / lo <= 1/32`. With [`N_BUCKETS`] = 1024 the top bucket
//! starts at `2^35 + 31 * 2^30`; anything at or above `2^36` (~19
//! hours in microseconds) saturates into it. A histogram is a flat 8
//! KiB of counters plus `count`/`sum`/`min`/`max` cells — fixed size
//! no matter how many samples land in it.
//!
//! **Error bound.** [`Histogram::quantile`] walks the counters to the
//! nearest-rank bucket (the same rank convention the old sort-based
//! `percentile` used) and answers the bucket's midpoint, clamped into
//! the recorded `[min, max]`. The midpoint is within half a bucket
//! width of every sample in the bucket, so the estimate's relative
//! error is at most `1/64` — exact below 64, unbounded only in the
//! saturated top bucket (tested in this module and pinned by property
//! tests against exact sorted-sample percentiles).
//!
//! **Merging** is exact: bucket counts, `count` and `sum` add;
//! `min`/`max` take the extreme — merging per-client histograms
//! yields byte-identical quantiles to recording every sample into one
//! histogram, in any merge order (associative and commutative).
//!
//! Recording is one `fetch_add` on the bucket plus four more relaxed
//! atomic ops, so handles can be shared across serving workers and
//! loadgen clients without locks; snapshots ([`Histogram::to_json`],
//! [`render_prometheus_summary`]) are point-in-time like the metrics
//! registry's.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Result};

use crate::util::Json;

/// Sub-bucket resolution: each octave splits into `2^SUB_BITS` linear
/// buckets, bounding quantile relative error by `2^-(SUB_BITS + 1)`.
pub const SUB_BITS: u32 = 5;
const SUBS: u64 = 1 << SUB_BITS; // 32 sub-buckets per octave

/// Total buckets: indices 0..2*SUBS are exact unit buckets, then 32
/// per octave up to the saturation bound.
pub const N_BUCKETS: usize = 1024;

/// Values at or above this saturate into the top bucket. Derivation:
/// the last index maps back to exponent `N_BUCKETS/SUBS + SUB_BITS - 2
/// = 35`, so the first unrepresentable value is `2^36`.
pub const SATURATION: u64 = 1 << 36;

/// Bucket index for a value. Exact (`idx == v`) below `2 * SUBS`;
/// log-linear above; clamped to the top bucket at [`SATURATION`].
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < 2 * SUBS {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros() as u64; // 2^exp <= v < 2^(exp+1)
    let sub = (v >> (exp - SUB_BITS as u64)) & (SUBS - 1);
    let idx = ((exp - SUB_BITS as u64 + 1) * SUBS + sub) as usize;
    idx.min(N_BUCKETS - 1)
}

/// Inclusive `[lo, hi]` value range of bucket `idx` (the top bucket's
/// `hi` is reported as its nominal upper edge, though saturation means
/// it really extends to `u64::MAX`).
fn bucket_range(idx: usize) -> (u64, u64) {
    let i = idx as u64;
    if i < 2 * SUBS {
        return (i, i);
    }
    let exp = i / SUBS + SUB_BITS as u64 - 1;
    let sub = i % SUBS;
    let width = 1u64 << (exp - SUB_BITS as u64);
    let lo = (1u64 << exp) + sub * width;
    (lo, lo + width - 1)
}

/// The representative value reported for a bucket: its midpoint,
/// within half a bucket width of every member.
fn bucket_mid(idx: usize) -> u64 {
    let (lo, hi) = bucket_range(idx);
    lo + (hi - lo) / 2
}

/// A lock-free fixed-size log2-bucketed histogram. Share one across
/// threads via `Arc` (or through [`metrics::histogram`]); all methods
/// take `&self`.
///
/// [`metrics::histogram`]: super::metrics::histogram
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample. Lock-free: five relaxed atomic ops.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of all recorded samples (wrapping only past u64).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact minimum recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX {
            0
        } else {
            m
        }
    }

    /// Exact maximum recorded sample.
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Mean of recorded samples (exact `sum / count`, 0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Nearest-rank quantile estimate: the same rank convention as the
    /// old sorted-`Vec` `percentile` (`rank = round((count-1) * q)`),
    /// answered as the rank's bucket midpoint clamped into the exact
    /// recorded `[min, max]`. The first and last ranks ARE the tracked
    /// min/max, so the edges are exact; elsewhere relative error is
    /// <= 1/64 outside the saturated top bucket; 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((n - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        if rank == 0 {
            return self.min();
        }
        if rank >= n - 1 {
            return self.max();
        }
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen > rank {
                return bucket_mid(idx).clamp(self.min(), self.max());
            }
        }
        // Counts raced upward mid-walk; the max is the right answer
        // for "the highest rank we know about".
        self.max()
    }

    /// Fold `other` into `self`, exactly: per-bucket counts, `count`
    /// and `sum` add, `min`/`max` take the extreme. Associative and
    /// commutative, so per-thread histograms can merge in any order.
    pub fn merge(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(&other.buckets) {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                a.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
        self.min.fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max(), Ordering::Relaxed);
    }

    /// Non-empty buckets as `(index, count)` pairs — the exact
    /// mergeable state, used by tests and the JSON export.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                if n > 0 {
                    Some((i, n))
                } else {
                    None
                }
            })
            .collect()
    }

    /// Point-in-time JSON summary:
    /// `{"count":..,"max":..,"mean":..,"min":..,"p50":..,"p90":..,
    /// "p99":..,"sum":..}` — what the metrics snapshot embeds per
    /// histogram.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count() as f64));
        m.insert("sum".to_string(), Json::Num(self.sum() as f64));
        m.insert("min".to_string(), Json::Num(self.min() as f64));
        m.insert("max".to_string(), Json::Num(self.max() as f64));
        m.insert("mean".to_string(), Json::Num(self.mean()));
        m.insert("p50".to_string(), Json::Num(self.quantile(0.50) as f64));
        m.insert("p90".to_string(), Json::Num(self.quantile(0.90) as f64));
        m.insert("p99".to_string(), Json::Num(self.quantile(0.99) as f64));
        Json::Obj(m)
    }

    /// Point-in-time copy of the exact mergeable state. The inverse of
    /// [`Histogram::absorb`]: `fresh.absorb(&h.snapshot())` reproduces
    /// `h` exactly, which is what lets the monitor merge per-node
    /// histograms across the wire without losing quantile precision.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.nonzero_buckets(),
            count: self.count(),
            sum: self.sum(),
            min: self.min(),
            max: self.max(),
        }
    }

    /// Fold a snapshot into `self`, exactly — [`Histogram::merge`] for
    /// wire-transported state. An empty snapshot is a no-op (its
    /// `min`/`max` carry no information and must not clobber ours).
    pub fn absorb(&self, snap: &HistSnapshot) {
        for &(idx, n) in &snap.buckets {
            if idx < N_BUCKETS && n > 0 {
                self.buckets[idx].fetch_add(n, Ordering::Relaxed);
            }
        }
        if snap.count == 0 {
            return;
        }
        self.count.fetch_add(snap.count, Ordering::Relaxed);
        self.sum.fetch_add(snap.sum, Ordering::Relaxed);
        self.min.fetch_min(snap.min, Ordering::Relaxed);
        self.max.fetch_max(snap.max, Ordering::Relaxed);
    }
}

/// The exact mergeable state of a [`Histogram`] at one instant:
/// non-empty buckets plus `count`/`sum`/`min`/`max`. Serializable
/// (time-series samples, watch/status wire frames) and foldable back
/// into a live histogram via [`Histogram::absorb`]. Unlike the live
/// histogram, `min` here is already normalised (0 when empty).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Non-empty buckets as `(index, count)` pairs, ascending index.
    pub buckets: Vec<(usize, u64)>,
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
}

impl HistSnapshot {
    /// Samples recorded at or above `threshold`, counted
    /// conservatively: only buckets whose *entire range* sits above the
    /// threshold contribute, so the bucket straddling the threshold is
    /// excluded. Used by the SLO evaluator ("fraction of requests over
    /// the p99 target"), where undercounting by less than one bucket
    /// width (1/64 relative) never fabricates a breach.
    pub fn count_above(&self, threshold: u64) -> u64 {
        self.buckets
            .iter()
            .filter(|&&(idx, _)| bucket_range(idx).0 >= threshold)
            .map(|&(_, n)| n)
            .sum()
    }

    /// Activity between two cumulative snapshots of the same histogram
    /// (`self` later, `prev` earlier): bucket counts, `count` and `sum`
    /// subtract (saturating, so a racy reader never underflows).
    /// `min`/`max` are not recoverable for a window, so the later
    /// snapshot's values are carried — window quantile logic must use
    /// the buckets, not the extremes.
    pub fn delta(&self, prev: &HistSnapshot) -> HistSnapshot {
        let before: BTreeMap<usize, u64> = prev.buckets.iter().copied().collect();
        let buckets: Vec<(usize, u64)> = self
            .buckets
            .iter()
            .filter_map(|&(idx, n)| {
                let d = n.saturating_sub(before.get(&idx).copied().unwrap_or(0));
                if d > 0 {
                    Some((idx, d))
                } else {
                    None
                }
            })
            .collect();
        HistSnapshot {
            buckets,
            count: self.count.saturating_sub(prev.count),
            sum: self.sum.saturating_sub(prev.sum),
            min: self.min,
            max: self.max,
        }
    }

    /// JSON form: `{"buckets":[[idx,count],..],"count":..,"max":..,
    /// "min":..,"sum":..}` — the time-series / wire representation.
    pub fn to_json(&self) -> Json {
        let buckets = self
            .buckets
            .iter()
            .map(|&(idx, n)| {
                Json::Arr(vec![Json::Num(idx as f64), Json::Num(n as f64)])
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("buckets".to_string(), Json::Arr(buckets));
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("sum".to_string(), Json::Num(self.sum as f64));
        m.insert("min".to_string(), Json::Num(self.min as f64));
        m.insert("max".to_string(), Json::Num(self.max as f64));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<HistSnapshot> {
        let field = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("hist snapshot missing u64 field {k:?}"))
        };
        let raw = j
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("hist snapshot missing buckets array"))?;
        let mut buckets = Vec::with_capacity(raw.len());
        for pair in raw {
            let p = pair.as_arr().ok_or_else(|| anyhow!("bucket entry not a pair"))?;
            let (Some(idx), Some(n)) = (
                p.first().and_then(Json::as_u64),
                p.get(1).and_then(Json::as_u64),
            ) else {
                return Err(anyhow!("bucket entry not [index, count]"));
            };
            if idx as usize >= N_BUCKETS {
                return Err(anyhow!("bucket index {idx} out of range"));
            }
            buckets.push((idx as usize, n));
        }
        Ok(HistSnapshot {
            buckets,
            count: field("count")?,
            sum: field("sum")?,
            min: field("min")?,
            max: field("max")?,
        })
    }
}

/// Prometheus summary exposition for one named histogram: quantile
/// samples plus `_sum`/`_count`, honouring a `{label}` suffix in the
/// registered name (quantile labels are appended to existing labels).
/// Pass `emit_type: false` to suppress the `# TYPE` header when the
/// previous histogram shared the same base name.
pub fn render_prometheus_summary(out: &mut String, name: &str, h: &Histogram, emit_type: bool) {
    let (base, labels) = match name.find('{') {
        Some(i) => (&name[..i], name[i..].trim_start_matches('{').trim_end_matches('}')),
        None => (name, ""),
    };
    let q_labels = |q: &str| {
        if labels.is_empty() {
            format!("{{quantile=\"{q}\"}}")
        } else {
            format!("{{{labels},quantile=\"{q}\"}}")
        }
    };
    if emit_type {
        out.push_str(&format!("# TYPE {base} summary\n"));
    }
    for (q, v) in [
        ("0.5", h.quantile(0.50)),
        ("0.9", h.quantile(0.90)),
        ("0.99", h.quantile(0.99)),
    ] {
        out.push_str(&format!("{base}{} {v}\n", q_labels(q)));
    }
    let plain = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
    out.push_str(&format!("{base}_sum{plain} {}\n", h.sum()));
    out.push_str(&format!("{base}_count{plain} {}\n", h.count()));
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift64* — the property-test workload source
    /// (no rand crate in this offline environment).
    struct XorShift(u64);

    impl XorShift {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x.wrapping_mul(0x2545_F491_4F6C_DD1D)
        }
    }

    /// The old sort-based nearest-rank percentile, kept here as the
    /// test oracle for the histogram's quantile estimates.
    fn exact_percentile(sorted: &[u64], q: f64) -> u64 {
        if sorted.is_empty() {
            return 0;
        }
        sorted[((sorted.len() - 1) as f64 * q).round() as usize]
    }

    #[test]
    fn bucket_index_is_monotone_and_exact_below_64() {
        for v in 0..64u64 {
            assert_eq!(bucket_index(v), v as usize);
            let (lo, hi) = bucket_range(v as usize);
            assert_eq!((lo, hi), (v, v), "unit buckets below 2*SUBS");
        }
        let mut last = 0usize;
        for exp in 0..40u32 {
            for v in [1u64 << exp, (1u64 << exp) + 1, (1u64 << (exp + 1)) - 1] {
                let idx = bucket_index(v);
                assert!(idx >= last || idx == N_BUCKETS - 1, "monotone at v={v}");
                last = last.max(idx);
                if v < SATURATION {
                    let (lo, hi) = bucket_range(idx);
                    assert!(lo <= v && v <= hi, "v={v} in its bucket [{lo},{hi}]");
                }
            }
        }
    }

    #[test]
    fn bucket_relative_width_is_bounded() {
        for idx in 2 * SUBS as usize..N_BUCKETS {
            let (lo, hi) = bucket_range(idx);
            let width = hi - lo + 1;
            assert!(
                width * SUBS <= lo,
                "bucket {idx} [{lo},{hi}]: width/lo must be <= 1/{SUBS}"
            );
        }
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    /// The headline property: on random workloads spanning several
    /// orders of magnitude, every quantile estimate is within the
    /// documented 1/64 relative error of the exact sorted-sample
    /// percentile (plus the clamp's exactness at the edges).
    #[test]
    fn quantiles_match_exact_percentiles_within_error_bound() {
        let mut rng = XorShift(0x9E37_79B9_7F4A_7C15);
        for trial in 0..8 {
            let n = 200 + (trial * 137) % 1800;
            let h = Histogram::new();
            let mut samples = Vec::with_capacity(n);
            for _ in 0..n {
                // Mix scales: sub-64 exact range, µs, ms, and seconds.
                let v = match rng.next() % 4 {
                    0 => rng.next() % 64,
                    1 => rng.next() % 10_000,
                    2 => rng.next() % 1_000_000,
                    _ => rng.next() % 60_000_000,
                };
                h.record(v);
                samples.push(v);
            }
            samples.sort_unstable();
            for q in [0.0, 0.10, 0.25, 0.50, 0.75, 0.90, 0.99, 1.0] {
                let exact = exact_percentile(&samples, q);
                let est = h.quantile(q);
                let tol = exact / (2 * SUBS) + 1; // 1/64 relative + unit slack
                assert!(
                    est.abs_diff(exact) <= tol,
                    "trial {trial} q={q}: est {est} vs exact {exact} (tol {tol})"
                );
            }
            // The edges are exact thanks to the min/max clamp.
            assert_eq!(h.quantile(0.0), samples[0]);
            assert_eq!(h.quantile(1.0), *samples.last().unwrap());
            assert_eq!(h.count(), n as u64);
            assert_eq!(h.sum(), samples.iter().sum::<u64>());
        }
    }

    fn state(h: &Histogram) -> (Vec<(usize, u64)>, u64, u64, u64, u64) {
        (h.nonzero_buckets(), h.count(), h.sum(), h.min(), h.max())
    }

    #[test]
    fn merge_is_commutative_and_associative() {
        let mut rng = XorShift(42);
        let parts: Vec<Histogram> = (0..3)
            .map(|_| {
                let h = Histogram::new();
                for _ in 0..500 {
                    h.record(rng.next() % 2_000_000);
                }
                h
            })
            .collect();
        let [a, b, c] = &parts[..] else { unreachable!() };

        // Commutativity: a+b == b+a.
        let ab = Histogram::new();
        ab.merge(a);
        ab.merge(b);
        let ba = Histogram::new();
        ba.merge(b);
        ba.merge(a);
        assert_eq!(state(&ab), state(&ba));

        // Associativity: (a+b)+c == a+(b+c).
        let ab_c = Histogram::new();
        ab_c.merge(&ab);
        ab_c.merge(c);
        let bc = Histogram::new();
        bc.merge(b);
        bc.merge(c);
        let a_bc = Histogram::new();
        a_bc.merge(a);
        a_bc.merge(&bc);
        assert_eq!(state(&ab_c), state(&a_bc));

        // Merging equals recording everything into one histogram.
        let mut rng2 = XorShift(42);
        let direct = Histogram::new();
        for _ in 0..1500 {
            direct.record(rng2.next() % 2_000_000);
        }
        assert_eq!(state(&direct), state(&ab_c));
        for q in [0.5, 0.99] {
            assert_eq!(direct.quantile(q), ab_c.quantile(q));
        }
    }

    #[test]
    fn top_bucket_saturates_instead_of_overflowing() {
        let h = Histogram::new();
        for v in [SATURATION, SATURATION * 2, u64::MAX] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        let buckets = h.nonzero_buckets();
        assert_eq!(buckets, vec![(N_BUCKETS - 1, 3)], "all in the top bucket");
        // Quantiles of saturated samples clamp to the exact max.
        assert_eq!(h.quantile(1.0), u64::MAX);
        // The estimate can't dip below the top bucket's lower edge.
        assert!(h.quantile(0.5) >= bucket_range(N_BUCKETS - 1).0);
        // Mixing a normal sample keeps low quantiles sane.
        h.record(100);
        assert_eq!(h.quantile(0.0), 100);
    }

    /// Snapshot/absorb is the wire-transport form of `merge`:
    /// absorbing per-node snapshots into a fresh histogram must equal
    /// recording every sample into one global histogram — the property
    /// the monitor's cluster aggregation rests on.
    #[test]
    fn absorbing_snapshots_equals_global_histogram() {
        let mut rng = XorShift(7);
        let global = Histogram::new();
        let parts: Vec<Histogram> = (0..3).map(|_| Histogram::new()).collect();
        for i in 0..1800 {
            let v = rng.next() % 3_000_000;
            global.record(v);
            parts[i % 3].record(v);
        }
        let merged = Histogram::new();
        for p in &parts {
            // Round-trip each snapshot through JSON, as the wire does.
            let snap = p.snapshot();
            let back = HistSnapshot::from_json(&Json::parse(&snap.to_json().render()).unwrap())
                .unwrap();
            assert_eq!(back, snap);
            merged.absorb(&back);
        }
        assert_eq!(state(&merged), state(&global));
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(merged.quantile(q), global.quantile(q));
        }
    }

    #[test]
    fn absorbing_empty_snapshot_is_a_no_op() {
        let h = Histogram::new();
        h.record(50);
        let before = state(&h);
        h.absorb(&Histogram::new().snapshot());
        assert_eq!(state(&h), before, "empty min/max must not clobber");
    }

    #[test]
    fn count_above_is_conservative() {
        let h = Histogram::new();
        for v in [10u64, 20, 1000, 2000, 4000] {
            h.record(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count_above(0), 5);
        assert_eq!(snap.count_above(500), 3);
        // 1000's bucket straddles a threshold inside it: excluded.
        let lo1000 = bucket_range(bucket_index(1000)).0;
        assert_eq!(snap.count_above(lo1000 + 1), 2);
        assert_eq!(snap.count_above(u64::MAX), 0);
    }

    #[test]
    fn snapshot_delta_isolates_window_activity() {
        let h = Histogram::new();
        h.record(100);
        h.record(200);
        let early = h.snapshot();
        h.record(5000);
        h.record(5000);
        let late = h.snapshot();
        let d = late.delta(&early);
        assert_eq!(d.count, 2);
        assert_eq!(d.sum, 10_000);
        assert_eq!(d.buckets, vec![(bucket_index(5000), 2)]);
        assert_eq!(d.count_above(1000), 2, "window excludes pre-window samples");
        // Self-delta is empty.
        let z = late.delta(&late);
        assert_eq!((z.count, z.sum, z.buckets.len()), (0, 0, 0));
    }

    #[test]
    fn json_and_prometheus_exports_are_well_formed() {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 40, 1000] {
            h.record(v);
        }
        let j = h.to_json();
        assert_eq!(Json::parse(&j.render()).unwrap(), j, "snapshot is valid Json");
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(5));
        assert_eq!(j.get("min").and_then(Json::as_u64), Some(10));
        assert_eq!(j.get("max").and_then(Json::as_u64), Some(1000));
        assert_eq!(j.get("p50").and_then(Json::as_u64), Some(30));

        let mut out = String::new();
        render_prometheus_summary(
            &mut out,
            "pallas_serve_latency_us{tier=\"gold\"}",
            &h,
            true,
        );
        assert!(out.contains("# TYPE pallas_serve_latency_us summary\n"));
        assert!(out
            .contains("pallas_serve_latency_us{tier=\"gold\",quantile=\"0.5\"} 30\n"));
        assert!(out.contains("pallas_serve_latency_us_sum{tier=\"gold\"} 1100\n"));
        assert!(out.contains("pallas_serve_latency_us_count{tier=\"gold\"} 5\n"));
    }
}

//! Unified observability fabric: structured events, a process-wide
//! metrics registry, and trace-file tooling — zero dependencies, built
//! on [`util::Json`](crate::util::Json) so every emitted line is
//! deterministic, ASCII, and self-describing.
//!
//! Four pillars, deliberately decoupled:
//!
//! * [`event`] — the [`EventSink`] trait and the lock-striped
//!   ring-buffer [`Recorder`] behind the cheap cloneable [`Obs`]
//!   handle. Spans (begin/end pairs with monotonic-clock durations)
//!   form *causal trees*: a handle derived via [`Obs::child_of`] (or
//!   [`Obs::child_of_ctx`] from a wire-carried [`TraceCtx`]) stamps a
//!   `parent` span id — across threads and, for distributed sweeps,
//!   across nodes — while counters and log records accumulate in
//!   memory and are written as line-delimited JSON on `flush`; no
//!   syscalls on the hot path.
//! * [`log`] — leveled, `PALLAS_LOG`-filtered structured logging to
//!   stderr, replacing the ad-hoc `eprintln!` calls. Works without an
//!   [`Obs`] handle (module-level functions) so deep code like the WAL
//!   can warn; an enabled handle additionally mirrors log records into
//!   the trace file.
//! * [`metrics`] — a process-wide registry of named counters, gauges
//!   and histograms. The hot path is one relaxed atomic op on a
//!   cached handle; snapshots render to both JSON (`serve`'s
//!   `metrics` verb) and Prometheus-style text exposition.
//! * [`hist`] — fixed-size log2-bucketed [`Histogram`]s over
//!   `AtomicU64` arrays: lock-free recording, quantile estimates with
//!   bounded relative error, exact merging — the bounded replacement
//!   for every stored-sample percentile vector.
//!
//! On top of the registry sits the live telemetry plane (DESIGN.md
//! §14): [`timeseries`] samples the registry periodically through an
//! injectable [`Clock`], [`slo`] judges the series against declarative
//! per-tier burn-rate targets, and [`perfgate`] compares two runs'
//! artifacts as a CI regression gate.
//!
//! **Determinism contract.** Instrumentation is observe-only: clock
//! reads happen strictly outside solver/commit decision paths, events
//! buffer in memory until an explicit flush, and every integration
//! point is gated on `Obs::enabled()` so the disabled path does no
//! work. `tests/obs_determinism.rs` pins that sweep records, fig5 CSV
//! and WAL bytes are identical with tracing on vs off and across
//! `--cell-workers` counts. See DESIGN.md §13.

pub mod event;
pub mod hist;
pub mod log;
pub mod metrics;
pub mod perfgate;
pub mod slo;
pub mod timeseries;
pub mod trace;

pub use event::{Event, EventSink, Obs, Recorder, Span, TraceCtx};
pub use hist::{HistSnapshot, Histogram};
pub use log::Level;
pub use slo::{SloEvaluator, SloSpec};
pub use timeseries::{Clock, ManualClock, MonotonicClock, Sample, TimeSeries};

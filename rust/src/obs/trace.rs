//! Trace-file tooling behind the `trace` CLI subcommand: load JSONL
//! dumps, validate them (`trace --check`: per-line schema plus span
//! balance), and render human reports — per-phase timelines, top-N
//! slowest spans, and a merged multi-node view over coordinator +
//! worker traces.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Json;

use super::event::Event;

/// Load one trace file, failing on the first malformed line (the
/// `--check` contract: a single bad event fails the build).
pub fn load(path: &Path) -> Result<Vec<Event>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read trace file {}", path.display()))?;
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        events.push(
            Event::from_json_line(line)
                .with_context(|| format!("{}:{}", path.display(), i + 1))?,
        );
    }
    Ok(events)
}

/// What `check` verified, for the CLI's one-line summary.
pub struct CheckReport {
    pub events: usize,
    pub spans: usize,
    pub nodes: Vec<String>,
    pub dropped: u64,
}

fn span_id(ev: &Event) -> Result<u64> {
    ev.fields
        .get("span")
        .and_then(Json::as_u64)
        .ok_or_else(|| {
            anyhow::anyhow!("{} event {:?} (seq {}) missing span id", ev.kind, ev.name, ev.seq)
        })
}

/// Validate span balance over already-parsed events: every
/// `span_begin` has exactly one matching `span_end` (per node — span
/// ids are only unique within a recorder) and vice versa. Also totals
/// the ring-overflow drop counts from flush footers.
pub fn check(events: &[Event]) -> Result<CheckReport> {
    let mut open: BTreeMap<(String, u64), String> = BTreeMap::new();
    let mut spans = 0usize;
    let mut nodes: Vec<String> = Vec::new();
    let mut dropped = 0u64;
    for ev in events {
        if !nodes.contains(&ev.node) {
            nodes.push(ev.node.clone());
        }
        match ev.kind.as_str() {
            "span_begin" => {
                let key = (ev.node.clone(), span_id(ev)?);
                if let Some(prev) = open.insert(key, ev.name.clone()) {
                    bail!("duplicate span_begin for span already open as {prev:?}");
                }
            }
            "span_end" => {
                spans += 1;
                let key = (ev.node.clone(), span_id(ev)?);
                if open.remove(&key).is_none() {
                    bail!(
                        "span_end {:?} (node {:?}, span {}) without begin",
                        ev.name,
                        ev.node,
                        key.1
                    );
                }
            }
            "meta" if ev.name == "obs.flush" => {
                dropped += ev
                    .fields
                    .get("dropped")
                    .and_then(Json::as_u64)
                    .unwrap_or(0);
            }
            _ => {}
        }
    }
    // Ring overflow drops oldest events first, so a dropped begin with
    // a surviving end is legitimate loss, not malformed tracing —
    // unbalanced spans only fail a drop-free trace.
    if !open.is_empty() && dropped == 0 {
        let ((node, id), name) = open.iter().next().unwrap();
        bail!(
            "{} unbalanced span(s), e.g. {name:?} (node {node:?}, span {id}) never ended",
            open.len()
        );
    }
    nodes.sort();
    Ok(CheckReport { events: events.len(), spans, nodes, dropped })
}

/// Per-job commit counts from `dist.commit` counter events — the
/// merged-trace accounting view (`tests/obs_determinism.rs` pins that
/// a distributed run commits every job exactly once).
pub fn commit_counts(events: &[Event]) -> BTreeMap<u64, u64> {
    let mut counts = BTreeMap::new();
    for ev in events {
        if ev.kind == "counter" && ev.name == "dist.commit" {
            if let Some(job) = ev.fields.get("job").and_then(Json::as_u64) {
                *counts.entry(job).or_insert(0) += 1;
            }
        }
    }
    counts
}

fn fmt_us(us: u64) -> String {
    if us >= 1_000_000 {
        format!("{:.2}s", us as f64 / 1e6)
    } else if us >= 1_000 {
        format!("{:.2}ms", us as f64 / 1e3)
    } else {
        format!("{us}us")
    }
}

/// A compact label for a span's identity fields (bench/method/et/cell
/// when present), for the slowest-spans table.
fn span_label(ev: &Event) -> String {
    let mut parts = Vec::new();
    for key in ["bench", "method", "et", "cell_a", "cell_b", "job", "status"] {
        if let Some(v) = ev.fields.get(key) {
            let txt = match v {
                Json::Str(s) => s.clone(),
                other => other.render(),
            };
            parts.push(format!("{key}={txt}"));
        }
    }
    parts.join(" ")
}

/// Render the human report over (possibly multi-node) events:
/// per-phase aggregates, the `top` slowest spans, and — when the trace
/// came from a distributed run — per-node event counts and commit
/// accounting.
pub fn render_report(events: &[Event], top: usize) -> String {
    let mut out = String::new();
    let report = match check(events) {
        Ok(r) => r,
        Err(e) => {
            let _ = writeln!(out, "warning: trace failed validation: {e:#}");
            CheckReport { events: events.len(), spans: 0, nodes: Vec::new(), dropped: 0 }
        }
    };
    let _ = writeln!(
        out,
        "{} event(s), {} span(s), {} node(s){}",
        report.events,
        report.spans,
        report.nodes.len().max(1),
        if report.dropped > 0 {
            format!(" — {} event(s) dropped to ring overflow", report.dropped)
        } else {
            String::new()
        }
    );

    // Per-phase timeline: aggregate span_end durations by span name.
    let mut phases: BTreeMap<&str, (u64, u64, u64)> = BTreeMap::new();
    let mut ends: Vec<&Event> = Vec::new();
    for ev in events {
        if ev.kind == "span_end" {
            let dur = ev.fields.get("dur_us").and_then(Json::as_u64).unwrap_or(0);
            let e = phases.entry(ev.name.as_str()).or_insert((0, 0, 0));
            e.0 += 1;
            e.1 += dur;
            e.2 = e.2.max(dur);
            ends.push(ev);
        }
    }
    if !phases.is_empty() {
        let _ = writeln!(out, "\nphases:");
        let _ = writeln!(
            out,
            "  {:<24} {:>7} {:>12} {:>12} {:>12}",
            "span", "count", "total", "mean", "max"
        );
        for (name, (count, total, max)) in &phases {
            let _ = writeln!(
                out,
                "  {:<24} {:>7} {:>12} {:>12} {:>12}",
                name,
                count,
                fmt_us(*total),
                fmt_us(total / count.max(&1)),
                fmt_us(*max)
            );
        }
    }

    // Top-N slowest spans. Ties break on (node, seq) for determinism.
    ends.sort_by(|a, b| {
        let da = a.fields.get("dur_us").and_then(Json::as_u64).unwrap_or(0);
        let db = b.fields.get("dur_us").and_then(Json::as_u64).unwrap_or(0);
        db.cmp(&da).then_with(|| (&a.node, a.seq).cmp(&(&b.node, b.seq)))
    });
    if !ends.is_empty() {
        let _ = writeln!(out, "\nslowest {} span(s):", top.min(ends.len()));
        for ev in ends.iter().take(top) {
            let dur = ev.fields.get("dur_us").and_then(Json::as_u64).unwrap_or(0);
            let label = span_label(ev);
            let _ = writeln!(
                out,
                "  {:>12}  {:<24} [{}]{}{}",
                fmt_us(dur),
                ev.name,
                ev.node,
                if label.is_empty() { "" } else { " " },
                label
            );
        }
    }

    // Merged multi-node view: per-node event counts, plus commit
    // accounting when coordinator events are present.
    if report.nodes.len() > 1 {
        let _ = writeln!(out, "\nnodes:");
        for node in &report.nodes {
            let n = events.iter().filter(|e| &e.node == node).count();
            let _ = writeln!(out, "  {node:<24} {n:>7} event(s)");
        }
    }
    let commits = commit_counts(events);
    if !commits.is_empty() {
        let dups: Vec<u64> = commits
            .iter()
            .filter(|(_, &c)| c > 1)
            .map(|(&j, _)| j)
            .collect();
        let _ = writeln!(
            out,
            "\ncommits: {} job(s) committed{}",
            commits.len(),
            if dups.is_empty() {
                ", each exactly once".to_string()
            } else {
                format!("; DUPLICATES: {dups:?}")
            }
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::fields;

    fn ev(seq: u64, kind: &str, name: &str, node: &str, kvs: &[(&str, Json)]) -> Event {
        Event {
            seq,
            ts_us: seq * 10,
            kind: kind.to_string(),
            name: name.to_string(),
            node: node.to_string(),
            fields: fields(kvs),
        }
    }

    #[test]
    fn balanced_spans_pass_check() {
        let events = vec![
            ev(0, "span_begin", "sweep.job", "local", &[("span", Json::Num(1.0))]),
            ev(
                1,
                "span_end",
                "sweep.job",
                "local",
                &[("span", Json::Num(1.0)), ("dur_us", Json::Num(500.0))],
            ),
            ev(2, "counter", "dist.commit", "local", &[("job", Json::Num(0.0))]),
        ];
        let r = check(&events).unwrap();
        assert_eq!(r.events, 3);
        assert_eq!(r.spans, 1);
        assert_eq!(r.nodes, vec!["local".to_string()]);
    }

    #[test]
    fn unbalanced_spans_fail_check() {
        let open = vec![ev(
            0,
            "span_begin",
            "sweep.job",
            "local",
            &[("span", Json::Num(1.0))],
        )];
        assert!(check(&open).is_err());
        let stray = vec![ev(
            0,
            "span_end",
            "sweep.job",
            "local",
            &[("span", Json::Num(1.0))],
        )];
        assert!(check(&stray).is_err());
    }

    #[test]
    fn same_span_id_on_different_nodes_is_balanced() {
        let events = vec![
            ev(0, "span_begin", "a", "w1", &[("span", Json::Num(1.0))]),
            ev(0, "span_begin", "b", "w2", &[("span", Json::Num(1.0))]),
            ev(1, "span_end", "a", "w1", &[("span", Json::Num(1.0))]),
            ev(1, "span_end", "b", "w2", &[("span", Json::Num(1.0))]),
        ];
        let r = check(&events).unwrap();
        assert_eq!(r.spans, 2);
        assert_eq!(r.nodes.len(), 2);
    }

    #[test]
    fn commit_accounting_counts_per_job() {
        let events = vec![
            ev(0, "counter", "dist.commit", "coord", &[("job", Json::Num(0.0))]),
            ev(1, "counter", "dist.commit", "coord", &[("job", Json::Num(1.0))]),
            ev(2, "counter", "dist.commit", "coord", &[("job", Json::Num(1.0))]),
        ];
        let counts = commit_counts(&events);
        assert_eq!(counts.get(&0), Some(&1));
        assert_eq!(counts.get(&1), Some(&2));
        let report = render_report(&events, 5);
        assert!(report.contains("DUPLICATES"));
    }

    #[test]
    fn report_renders_phases_and_slowest() {
        let events = vec![
            ev(0, "span_begin", "sweep.cell", "local", &[("span", Json::Num(1.0))]),
            ev(
                1,
                "span_end",
                "sweep.cell",
                "local",
                &[
                    ("span", Json::Num(1.0)),
                    ("dur_us", Json::Num(1500.0)),
                    ("cell_a", Json::Num(2.0)),
                    ("cell_b", Json::Num(3.0)),
                ],
            ),
        ];
        let report = render_report(&events, 3);
        assert!(report.contains("sweep.cell"));
        assert!(report.contains("1.50ms"));
        assert!(report.contains("cell_a=2"));
    }
}

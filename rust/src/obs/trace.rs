//! Trace-file tooling behind the `trace` CLI subcommand: load JSONL
//! dumps, validate them (`trace --check`: per-line schema, span
//! balance, and causal-parent resolution), and render human reports —
//! per-phase timelines, top-N slowest spans, per-request/per-job
//! waterfalls (`--tree`), slowest causal chains (`--critical-path`),
//! folded flamegraph stacks (`--flame`), and a merged multi-node view
//! over coordinator + worker traces.
//!
//! Causality (schema 2): a `span_begin` may carry a `parent` span id
//! (plus `parent_node` when the parent lives on another node). The
//! checker resolves every parent reference whose node is present in
//! the merged trace — and when both coordinator `dist.lease` and
//! worker `dist.job` spans are present, enforces that every `dist.job`
//! parents under a lease span, which is exactly the cross-machine
//! causal contract the distributed sweep promises. Ring-overflow drops
//! relax these failures to warnings (the parent may have been the
//! dropped event).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Json;

use super::event::Event;

/// Load one trace file, failing on the first malformed line (the
/// `--check` contract: a single bad event fails the build).
pub fn load(path: &Path) -> Result<Vec<Event>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read trace file {}", path.display()))?;
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        events.push(
            Event::from_json_line(line)
                .with_context(|| format!("{}:{}", path.display(), i + 1))?,
        );
    }
    Ok(events)
}

/// What `check` verified, for the CLI's one-line summary.
pub struct CheckReport {
    pub events: usize,
    pub spans: usize,
    pub nodes: Vec<String>,
    pub dropped: u64,
    /// Spans carrying a resolved causal parent reference.
    pub parented: usize,
    /// Non-fatal findings (failures relaxed because events dropped).
    pub warnings: Vec<String>,
}

fn span_id(ev: &Event) -> Result<u64> {
    ev.fields
        .get("span")
        .and_then(Json::as_u64)
        .ok_or_else(|| {
            anyhow::anyhow!("{} event {:?} (seq {}) missing span id", ev.kind, ev.name, ev.seq)
        })
}

/// The causal parent reference on a `span_begin`, if any:
/// `(parent_node, parent_span_id)` — `parent_node` defaults to the
/// event's own node when absent (same-recorder nesting).
fn parent_ref(ev: &Event) -> Option<(String, u64)> {
    let id = ev.fields.get("parent").and_then(Json::as_u64)?;
    let node = match ev.fields.get("parent_node") {
        Some(Json::Str(s)) => s.clone(),
        _ => ev.node.clone(),
    };
    Some((node, id))
}

/// Validate span balance over already-parsed events: every
/// `span_begin` has exactly one matching `span_end` (per node — span
/// ids are only unique within a recorder) and vice versa. Also
/// resolves every causal `parent` reference whose target node is
/// present in the merged trace, enforces that `dist.job` worker spans
/// parent under coordinator `dist.lease` spans whenever both sides
/// were traced, and totals the ring-overflow drop counts from flush
/// footers. Drops relax balance and parent failures to
/// [`CheckReport::warnings`] — the missing half may have been the
/// dropped event.
pub fn check(events: &[Event]) -> Result<CheckReport> {
    // Pass 1: every span id seen per node (begins *and* ends, so a
    // dropped begin still lets children resolve their parent), plus
    // span names for the dist.job -> dist.lease enforcement, plus the
    // drop total (known before pass 2 decides fail-vs-warn).
    let mut ids: BTreeMap<&str, BTreeSet<u64>> = BTreeMap::new();
    let mut names: BTreeMap<(&str, u64), &str> = BTreeMap::new();
    let mut dropped = 0u64;
    let mut has_lease = false;
    for ev in events {
        match ev.kind.as_str() {
            "span_begin" | "span_end" => {
                let id = span_id(ev)?;
                ids.entry(&ev.node).or_default().insert(id);
                names.insert((&ev.node, id), &ev.name);
                has_lease |= ev.name == "dist.lease";
            }
            "meta" if ev.name == "obs.flush" => {
                dropped += ev
                    .fields
                    .get("dropped")
                    .and_then(Json::as_u64)
                    .unwrap_or(0);
            }
            _ => {}
        }
    }

    let mut open: BTreeMap<(String, u64), String> = BTreeMap::new();
    let mut spans = 0usize;
    let mut nodes: Vec<String> = Vec::new();
    let mut parented = 0usize;
    let mut warnings: Vec<String> = Vec::new();
    // With drops, a hard failure may be ring loss — downgrade to a
    // warning; a drop-free trace still fails loudly.
    let fail = |warnings: &mut Vec<String>, msg: String| -> Result<()> {
        if dropped == 0 {
            bail!(msg);
        }
        warnings.push(msg);
        Ok(())
    };
    for ev in events {
        if !nodes.contains(&ev.node) {
            nodes.push(ev.node.clone());
        }
        match ev.kind.as_str() {
            "span_begin" => {
                let key = (ev.node.clone(), span_id(ev)?);
                if let Some(prev) = open.insert(key, ev.name.clone()) {
                    bail!("duplicate span_begin for span already open as {prev:?}");
                }
                if let Some((pnode, pid)) = parent_ref(ev) {
                    // Parents on nodes absent from the merge are
                    // uncheckable (e.g. a worker trace inspected
                    // without the coordinator's) — skip, don't fail.
                    let Some(node_ids) = ids.get(pnode.as_str()) else {
                        continue;
                    };
                    if !node_ids.contains(&pid) {
                        fail(
                            &mut warnings,
                            format!(
                                "span {:?} (node {:?}, span {}) has unresolved parent \
                                 span {pid} on node {pnode:?}",
                                ev.name,
                                ev.node,
                                span_id(ev)?
                            ),
                        )?;
                        continue;
                    }
                    parented += 1;
                    if ev.name == "dist.job" {
                        let pname = names.get(&(pnode.as_str(), pid)).copied().unwrap_or("");
                        if pname != "dist.lease" {
                            fail(
                                &mut warnings,
                                format!(
                                    "dist.job span {} (node {:?}) parents under \
                                     {pname:?}, expected dist.lease",
                                    span_id(ev)?,
                                    ev.node
                                ),
                            )?;
                        }
                    }
                } else if ev.name == "dist.job" && has_lease {
                    // Both sides traced: a worker job span with no
                    // causal parent breaks the cross-machine contract.
                    fail(
                        &mut warnings,
                        format!(
                            "dist.job span {} (node {:?}) has no parent despite \
                             dist.lease spans in the trace",
                            span_id(ev)?,
                            ev.node
                        ),
                    )?;
                }
            }
            "span_end" => {
                spans += 1;
                let key = (ev.node.clone(), span_id(ev)?);
                if open.remove(&key).is_none() && dropped == 0 {
                    bail!(
                        "span_end {:?} (node {:?}, span {}) without begin",
                        ev.name,
                        ev.node,
                        key.1
                    );
                }
            }
            _ => {}
        }
    }
    // Ring overflow drops oldest events first, so a dropped begin with
    // a surviving end is legitimate loss, not malformed tracing —
    // unbalanced spans only fail a drop-free trace.
    if !open.is_empty() {
        let ((node, id), name) = open.iter().next().unwrap();
        fail(
            &mut warnings,
            format!(
                "{} unbalanced span(s), e.g. {name:?} (node {node:?}, span {id}) never ended",
                open.len()
            ),
        )?;
    }
    if dropped > 0 {
        warnings.push(format!(
            "{dropped} event(s) dropped to ring overflow — trace is incomplete"
        ));
    }
    nodes.sort();
    Ok(CheckReport { events: events.len(), spans, nodes, dropped, parented, warnings })
}

/// Per-job commit counts from `dist.commit` counter events — the
/// merged-trace accounting view (`tests/obs_determinism.rs` pins that
/// a distributed run commits every job exactly once).
pub fn commit_counts(events: &[Event]) -> BTreeMap<u64, u64> {
    let mut counts = BTreeMap::new();
    for ev in events {
        if ev.kind == "counter" && ev.name == "dist.commit" {
            if let Some(job) = ev.fields.get("job").and_then(Json::as_u64) {
                *counts.entry(job).or_insert(0) += 1;
            }
        }
    }
    counts
}

fn fmt_us(us: u64) -> String {
    if us >= 1_000_000 {
        format!("{:.2}s", us as f64 / 1e6)
    } else if us >= 1_000 {
        format!("{:.2}ms", us as f64 / 1e3)
    } else {
        format!("{us}us")
    }
}

/// A compact label for a span's identity fields (bench/method/et/cell
/// when present), for the slowest-spans table.
fn span_label(ev: &Event) -> String {
    let mut parts = Vec::new();
    for key in ["bench", "method", "et", "cell_a", "cell_b", "job", "status"] {
        if let Some(v) = ev.fields.get(key) {
            let txt = match v {
                Json::Str(s) => s.clone(),
                other => other.render(),
            };
            parts.push(format!("{key}={txt}"));
        }
    }
    parts.join(" ")
}

/// Render the human report over (possibly multi-node) events:
/// per-phase aggregates, the `top` slowest spans, and — when the trace
/// came from a distributed run — per-node event counts and commit
/// accounting.
pub fn render_report(events: &[Event], top: usize) -> String {
    let mut out = String::new();
    let report = match check(events) {
        Ok(r) => r,
        Err(e) => {
            let _ = writeln!(out, "warning: trace failed validation: {e:#}");
            CheckReport {
                events: events.len(),
                spans: 0,
                nodes: Vec::new(),
                dropped: 0,
                parented: 0,
                warnings: Vec::new(),
            }
        }
    };
    let _ = writeln!(
        out,
        "{} event(s), {} span(s), {} node(s){}",
        report.events,
        report.spans,
        report.nodes.len().max(1),
        if report.dropped > 0 {
            format!(" — {} event(s) dropped to ring overflow", report.dropped)
        } else {
            String::new()
        }
    );
    for w in &report.warnings {
        let _ = writeln!(out, "warning: {w}");
    }

    // Per-phase timeline: aggregate span_end durations by span name.
    let mut phases: BTreeMap<&str, (u64, u64, u64)> = BTreeMap::new();
    let mut ends: Vec<&Event> = Vec::new();
    for ev in events {
        if ev.kind == "span_end" {
            let dur = ev.fields.get("dur_us").and_then(Json::as_u64).unwrap_or(0);
            let e = phases.entry(ev.name.as_str()).or_insert((0, 0, 0));
            e.0 += 1;
            e.1 += dur;
            e.2 = e.2.max(dur);
            ends.push(ev);
        }
    }
    if !phases.is_empty() {
        let _ = writeln!(out, "\nphases:");
        let _ = writeln!(
            out,
            "  {:<24} {:>7} {:>12} {:>12} {:>12}",
            "span", "count", "total", "mean", "max"
        );
        for (name, (count, total, max)) in &phases {
            let _ = writeln!(
                out,
                "  {:<24} {:>7} {:>12} {:>12} {:>12}",
                name,
                count,
                fmt_us(*total),
                fmt_us(total / count.max(&1)),
                fmt_us(*max)
            );
        }
    }

    // Top-N slowest spans. Ties break on (node, seq) for determinism.
    ends.sort_by(|a, b| {
        let da = a.fields.get("dur_us").and_then(Json::as_u64).unwrap_or(0);
        let db = b.fields.get("dur_us").and_then(Json::as_u64).unwrap_or(0);
        db.cmp(&da).then_with(|| (&a.node, a.seq).cmp(&(&b.node, b.seq)))
    });
    if !ends.is_empty() {
        let _ = writeln!(out, "\nslowest {} span(s):", top.min(ends.len()));
        for ev in ends.iter().take(top) {
            let dur = ev.fields.get("dur_us").and_then(Json::as_u64).unwrap_or(0);
            let label = span_label(ev);
            let _ = writeln!(
                out,
                "  {:>12}  {:<24} [{}]{}{}",
                fmt_us(dur),
                ev.name,
                ev.node,
                if label.is_empty() { "" } else { " " },
                label
            );
        }
    }

    // Merged multi-node view: per-node event counts, plus commit
    // accounting when coordinator events are present.
    if report.nodes.len() > 1 {
        let _ = writeln!(out, "\nnodes:");
        for node in &report.nodes {
            let n = events.iter().filter(|e| &e.node == node).count();
            let _ = writeln!(out, "  {node:<24} {n:>7} event(s)");
        }
    }
    let commits = commit_counts(events);
    if !commits.is_empty() {
        let dups: Vec<u64> = commits
            .iter()
            .filter(|(_, &c)| c > 1)
            .map(|(&j, _)| j)
            .collect();
        let _ = writeln!(
            out,
            "\ncommits: {} job(s) committed{}",
            commits.len(),
            if dups.is_empty() {
                ", each exactly once".to_string()
            } else {
                format!("; DUPLICATES: {dups:?}")
            }
        );
    }
    out
}

/// A span reconstructed from its begin/end pair, ready for causal
/// assembly: identity, timing, and the resolved parent key.
struct SpanRec<'a> {
    node: &'a str,
    id: u64,
    name: &'a str,
    start: u64,
    seq: u64,
    dur: u64,
    parent: Option<(String, u64)>,
    end: &'a Event,
}

/// Pair every `span_end` with its `span_begin` (dropping orphans —
/// ring overflow may have eaten either half) and carry the begin's
/// parent reference over.
fn build_spans(events: &[Event]) -> Vec<SpanRec<'_>> {
    let mut begins: BTreeMap<(&str, u64), &Event> = BTreeMap::new();
    for ev in events {
        if ev.kind == "span_begin" {
            if let Some(id) = ev.fields.get("span").and_then(Json::as_u64) {
                begins.insert((&ev.node, id), ev);
            }
        }
    }
    let mut spans = Vec::new();
    for ev in events {
        if ev.kind != "span_end" {
            continue;
        }
        let Some(id) = ev.fields.get("span").and_then(Json::as_u64) else {
            continue;
        };
        let Some(begin) = begins.get(&(ev.node.as_str(), id)) else {
            continue;
        };
        spans.push(SpanRec {
            node: &ev.node,
            id,
            name: &ev.name,
            start: begin.ts_us,
            seq: begin.seq,
            dur: ev.fields.get("dur_us").and_then(Json::as_u64).unwrap_or(0),
            parent: parent_ref(begin),
            end: ev,
        });
    }
    spans
}

/// The causal forest over reconstructed spans: an index by
/// `(node, id)`, per-span child lists (sorted by start time for
/// waterfall order), and the roots (no parent, or a parent outside
/// the merged trace) sorted slowest-first.
struct Forest<'a> {
    spans: Vec<SpanRec<'a>>,
    children: Vec<Vec<usize>>,
    roots: Vec<usize>,
}

fn build_forest(events: &[Event]) -> Forest<'_> {
    let spans = build_spans(events);
    let index: BTreeMap<(&str, u64), usize> = spans
        .iter()
        .enumerate()
        .map(|(i, s)| ((s.node, s.id), i))
        .collect();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); spans.len()];
    let mut roots: Vec<usize> = Vec::new();
    for (i, s) in spans.iter().enumerate() {
        match s
            .parent
            .as_ref()
            .and_then(|(n, id)| index.get(&(n.as_str(), *id)))
        {
            Some(&p) if p != i => children[p].push(i),
            _ => roots.push(i),
        }
    }
    for kids in &mut children {
        kids.sort_by_key(|&i| (spans[i].start, spans[i].seq, spans[i].node));
    }
    roots.sort_by(|&a, &b| {
        spans[b]
            .dur
            .cmp(&spans[a].dur)
            .then_with(|| (spans[a].node, spans[a].seq).cmp(&(spans[b].node, spans[b].seq)))
    });
    Forest { spans, children, roots }
}

/// A span's *self time*: its duration minus the time attributed to
/// its direct children (saturating — children can overlap the parent
/// boundary when clocks come from different nodes).
fn self_us(f: &Forest<'_>, i: usize) -> u64 {
    let child_total: u64 = f.children[i].iter().map(|&c| f.spans[c].dur).sum();
    f.spans[i].dur.saturating_sub(child_total)
}

/// `trace --tree`: per-request/per-job waterfalls. The `top` slowest
/// roots each render as an indented causal tree, children in start
/// order, every line showing total and self time.
pub fn render_tree(events: &[Event], top: usize) -> String {
    let f = build_forest(events);
    let mut out = String::new();
    if f.roots.is_empty() {
        let _ = writeln!(out, "no completed spans");
        return out;
    }
    let _ = writeln!(
        out,
        "causal tree: {} span(s), {} root(s), showing slowest {}",
        f.spans.len(),
        f.roots.len(),
        top.min(f.roots.len())
    );
    fn render_node(out: &mut String, f: &Forest<'_>, i: usize, depth: usize) {
        let s = &f.spans[i];
        let label = span_label(s.end);
        let _ = writeln!(
            out,
            "{:indent$}{} [{}] {} (self {}){}{}",
            "",
            s.name,
            s.node,
            fmt_us(s.dur),
            fmt_us(self_us(f, i)),
            if label.is_empty() { "" } else { " " },
            label,
            indent = depth * 2
        );
        for &c in &f.children[i] {
            render_node(out, f, c, depth + 1);
        }
    }
    for &root in f.roots.iter().take(top) {
        let _ = writeln!(out);
        render_node(&mut out, &f, root, 0);
    }
    out
}

/// `trace --critical-path`: for each of the `top` slowest roots, the
/// chain built by greedily descending into the slowest child — where
/// the time actually went, one line per hop with the hop's self time.
pub fn render_critical_path(events: &[Event], top: usize) -> String {
    let f = build_forest(events);
    let mut out = String::new();
    if f.roots.is_empty() {
        let _ = writeln!(out, "no completed spans");
        return out;
    }
    for &root in f.roots.iter().take(top) {
        let s = &f.spans[root];
        let label = span_label(s.end);
        let _ = writeln!(
            out,
            "critical path of {} [{}] {}{}{}:",
            s.name,
            s.node,
            fmt_us(s.dur),
            if label.is_empty() { "" } else { " " },
            label
        );
        let mut i = root;
        loop {
            let s = &f.spans[i];
            let _ = writeln!(
                out,
                "  {:>12} total {:>12} self  {} [{}]",
                fmt_us(s.dur),
                fmt_us(self_us(&f, i)),
                s.name,
                s.node
            );
            match f.children[i].iter().copied().max_by_key(|&c| {
                // Slowest child wins; ties break earliest-started for
                // determinism.
                (f.spans[c].dur, std::cmp::Reverse((f.spans[c].start, f.spans[c].seq)))
            }) {
                Some(next) => i = next,
                None => break,
            }
        }
    }
    out
}

/// `trace --flame`: folded-stack output, one line per distinct causal
/// stack — `node;root;child;... self_us` — directly consumable by
/// inferno / flamegraph.pl. Self time (not total) is attributed to
/// each frame so the flamegraph's widths add up exactly once.
pub fn render_flame(events: &[Event]) -> String {
    let f = build_forest(events);
    let mut folded: BTreeMap<String, u64> = BTreeMap::new();
    fn walk(f: &Forest<'_>, i: usize, prefix: &str, folded: &mut BTreeMap<String, u64>) {
        let s = &f.spans[i];
        let stack = format!("{prefix};{}", s.name);
        *folded.entry(stack.clone()).or_insert(0) += self_us(f, i);
        for &c in &f.children[i] {
            walk(f, c, &stack, folded);
        }
    }
    for &root in &f.roots {
        let node = f.spans[root].node.to_string();
        walk(&f, root, &node, &mut folded);
    }
    let mut out = String::new();
    for (stack, us) in &folded {
        let _ = writeln!(out, "{stack} {us}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::fields;

    fn ev(seq: u64, kind: &str, name: &str, node: &str, kvs: &[(&str, Json)]) -> Event {
        Event {
            seq,
            ts_us: seq * 10,
            kind: kind.to_string(),
            name: name.to_string(),
            node: node.to_string(),
            fields: fields(kvs),
        }
    }

    #[test]
    fn balanced_spans_pass_check() {
        let events = vec![
            ev(0, "span_begin", "sweep.job", "local", &[("span", Json::Num(1.0))]),
            ev(
                1,
                "span_end",
                "sweep.job",
                "local",
                &[("span", Json::Num(1.0)), ("dur_us", Json::Num(500.0))],
            ),
            ev(2, "counter", "dist.commit", "local", &[("job", Json::Num(0.0))]),
        ];
        let r = check(&events).unwrap();
        assert_eq!(r.events, 3);
        assert_eq!(r.spans, 1);
        assert_eq!(r.nodes, vec!["local".to_string()]);
    }

    #[test]
    fn unbalanced_spans_fail_check() {
        let open = vec![ev(
            0,
            "span_begin",
            "sweep.job",
            "local",
            &[("span", Json::Num(1.0))],
        )];
        assert!(check(&open).is_err());
        let stray = vec![ev(
            0,
            "span_end",
            "sweep.job",
            "local",
            &[("span", Json::Num(1.0))],
        )];
        assert!(check(&stray).is_err());
    }

    #[test]
    fn same_span_id_on_different_nodes_is_balanced() {
        let events = vec![
            ev(0, "span_begin", "a", "w1", &[("span", Json::Num(1.0))]),
            ev(0, "span_begin", "b", "w2", &[("span", Json::Num(1.0))]),
            ev(1, "span_end", "a", "w1", &[("span", Json::Num(1.0))]),
            ev(1, "span_end", "b", "w2", &[("span", Json::Num(1.0))]),
        ];
        let r = check(&events).unwrap();
        assert_eq!(r.spans, 2);
        assert_eq!(r.nodes.len(), 2);
    }

    #[test]
    fn commit_accounting_counts_per_job() {
        let events = vec![
            ev(0, "counter", "dist.commit", "coord", &[("job", Json::Num(0.0))]),
            ev(1, "counter", "dist.commit", "coord", &[("job", Json::Num(1.0))]),
            ev(2, "counter", "dist.commit", "coord", &[("job", Json::Num(1.0))]),
        ];
        let counts = commit_counts(&events);
        assert_eq!(counts.get(&0), Some(&1));
        assert_eq!(counts.get(&1), Some(&2));
        let report = render_report(&events, 5);
        assert!(report.contains("DUPLICATES"));
    }

    /// A two-node causal fixture: coord lease span 1 -> worker
    /// dist.job span 1 -> worker sweep.cell span 2.
    fn causal_fixture() -> Vec<Event> {
        vec![
            ev(0, "span_begin", "dist.lease", "coord", &[("span", Json::Num(1.0))]),
            ev(
                0,
                "span_begin",
                "dist.job",
                "w0",
                &[
                    ("span", Json::Num(1.0)),
                    ("parent", Json::Num(1.0)),
                    ("parent_node", Json::Str("coord".into())),
                ],
            ),
            ev(
                1,
                "span_begin",
                "sweep.cell",
                "w0",
                &[("span", Json::Num(2.0)), ("parent", Json::Num(1.0))],
            ),
            ev(
                2,
                "span_end",
                "sweep.cell",
                "w0",
                &[("span", Json::Num(2.0)), ("dur_us", Json::Num(300.0))],
            ),
            ev(
                3,
                "span_end",
                "dist.job",
                "w0",
                &[("span", Json::Num(1.0)), ("dur_us", Json::Num(1000.0))],
            ),
            ev(
                4,
                "span_end",
                "dist.lease",
                "coord",
                &[("span", Json::Num(1.0)), ("dur_us", Json::Num(1200.0))],
            ),
        ]
    }

    #[test]
    fn parent_references_resolve_across_nodes() {
        let r = check(&causal_fixture()).unwrap();
        assert_eq!(r.spans, 3);
        assert_eq!(r.parented, 2, "dist.job and sweep.cell both parented");
        assert!(r.warnings.is_empty());
    }

    #[test]
    fn unresolved_parent_fails_drop_free_check() {
        let events = vec![
            ev(
                0,
                "span_begin",
                "serve.batch",
                "serve",
                &[("span", Json::Num(5.0)), ("parent", Json::Num(99.0))],
            ),
            ev(
                1,
                "span_end",
                "serve.batch",
                "serve",
                &[("span", Json::Num(5.0)), ("dur_us", Json::Num(10.0))],
            ),
        ];
        let err = check(&events).unwrap_err().to_string();
        assert!(err.contains("unresolved parent"), "{err}");
    }

    #[test]
    fn parent_on_absent_node_is_skipped() {
        // Worker trace inspected without the coordinator's: the
        // cross-node parent is uncheckable, not an error. And with no
        // dist.lease span in the merge, no orphan enforcement either.
        let events = vec![
            ev(
                0,
                "span_begin",
                "dist.job",
                "w0",
                &[
                    ("span", Json::Num(1.0)),
                    ("parent", Json::Num(7.0)),
                    ("parent_node", Json::Str("coord".into())),
                ],
            ),
            ev(
                1,
                "span_end",
                "dist.job",
                "w0",
                &[("span", Json::Num(1.0)), ("dur_us", Json::Num(10.0))],
            ),
        ];
        let r = check(&events).unwrap();
        assert_eq!(r.parented, 0);
        assert!(r.warnings.is_empty());
    }

    #[test]
    fn unparented_dist_job_fails_when_leases_present() {
        let mut events = causal_fixture();
        // Second worker job with no parent at all.
        events.push(ev(5, "span_begin", "dist.job", "w1", &[("span", Json::Num(1.0))]));
        events.push(ev(
            6,
            "span_end",
            "dist.job",
            "w1",
            &[("span", Json::Num(1.0)), ("dur_us", Json::Num(10.0))],
        ));
        let err = check(&events).unwrap_err().to_string();
        assert!(err.contains("no parent despite dist.lease"), "{err}");
    }

    #[test]
    fn drops_relax_parent_failures_to_warnings() {
        let mut events = causal_fixture();
        events.push(ev(5, "span_begin", "dist.job", "w1", &[("span", Json::Num(1.0))]));
        events.push(ev(
            6,
            "span_end",
            "dist.job",
            "w1",
            &[("span", Json::Num(1.0)), ("dur_us", Json::Num(10.0))],
        ));
        events.push(ev(7, "meta", "obs.flush", "w1", &[("dropped", Json::Num(3.0))]));
        let r = check(&events).unwrap();
        assert_eq!(r.dropped, 3);
        assert!(r.warnings.iter().any(|w| w.contains("no parent")), "{:?}", r.warnings);
        assert!(r.warnings.iter().any(|w| w.contains("dropped")), "{:?}", r.warnings);
    }

    #[test]
    fn tree_renders_causal_waterfall_with_self_time() {
        let tree = render_tree(&causal_fixture(), 3);
        let lines: Vec<&str> = tree.lines().collect();
        let lease = lines.iter().position(|l| l.contains("dist.lease")).unwrap();
        let job = lines.iter().position(|l| l.contains("dist.job")).unwrap();
        let cell = lines.iter().position(|l| l.contains("sweep.cell")).unwrap();
        assert!(lease < job && job < cell, "waterfall order:\n{tree}");
        // Indentation deepens along the causal chain.
        assert!(lines[job].starts_with("  dist.job"), "{tree}");
        assert!(lines[cell].starts_with("    sweep.cell"), "{tree}");
        // Self time subtracts the child: 1000 - 300 = 700us.
        assert!(lines[job].contains("(self 700us)"), "{tree}");
        assert!(lines[cell].contains("(self 300us)"), "{tree}");
    }

    #[test]
    fn critical_path_descends_slowest_chain() {
        let out = render_critical_path(&causal_fixture(), 1);
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[0].contains("dist.lease"), "{out}");
        assert!(lines[1].contains("dist.lease"), "{out}");
        assert!(lines[2].contains("dist.job"), "{out}");
        assert!(lines[3].contains("sweep.cell"), "{out}");
        assert_eq!(lines.len(), 4, "{out}");
    }

    #[test]
    fn flame_emits_folded_stacks_of_self_time() {
        let out = render_flame(&causal_fixture());
        assert!(out.contains("coord;dist.lease 200\n"), "{out}");
        assert!(out.contains("coord;dist.lease;dist.job 700\n"), "{out}");
        assert!(out.contains("coord;dist.lease;dist.job;sweep.cell 300\n"), "{out}");
        // Every line matches the folded-stack schema.
        for line in out.lines() {
            let (stack, n) = line.rsplit_once(' ').unwrap();
            assert!(!stack.is_empty() && stack.contains(';'), "{line}");
            assert!(n.parse::<u64>().is_ok(), "{line}");
        }
    }

    #[test]
    fn report_renders_phases_and_slowest() {
        let events = vec![
            ev(0, "span_begin", "sweep.cell", "local", &[("span", Json::Num(1.0))]),
            ev(
                1,
                "span_end",
                "sweep.cell",
                "local",
                &[
                    ("span", Json::Num(1.0)),
                    ("dur_us", Json::Num(1500.0)),
                    ("cell_a", Json::Num(2.0)),
                    ("cell_b", Json::Num(3.0)),
                ],
            ),
        ];
        let report = render_report(&events, 3);
        assert!(report.contains("sweep.cell"));
        assert!(report.contains("1.50ms"));
        assert!(report.contains("cell_a=2"));
    }
}

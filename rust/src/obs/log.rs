//! Leveled structured logging with a `PALLAS_LOG` env filter.
//!
//! Replaces the ad-hoc `eprintln!` calls: every record is one JSON
//! line on stderr (`{"level":"warn","msg":...,"target":...}` plus
//! call-site fields), so operator logs are grep/jq-able and carry the
//! same structure the trace file does. The filter is read once per
//! process from `PALLAS_LOG`:
//!
//! ```text
//! PALLAS_LOG=debug                    everything at debug and above
//! PALLAS_LOG=off                      silence
//! PALLAS_LOG=warn,dist=debug          per-target override (longest
//! PALLAS_LOG=info,store.wal=off       prefix of the target wins)
//! ```
//!
//! Default (unset/unparsable): `warn` — exactly the situations the old
//! `eprintln!`s covered. Module-level [`warn`]/[`info`]/[`debug`] work
//! without an [`Obs`](super::Obs) handle; `Obs::warn` etc. route here
//! and additionally mirror the record into the trace file.

use std::collections::BTreeMap;
use std::sync::OnceLock;

use crate::util::Json;

/// Log severity, ordered: `Error < Warn < Info < Debug < Trace`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    /// `None` means "off" (a valid filter directive, not a level).
    fn parse(s: &str) -> Option<Option<Level>> {
        match s.trim() {
            "error" => Some(Some(Level::Error)),
            "warn" => Some(Some(Level::Warn)),
            "info" => Some(Some(Level::Info)),
            "debug" => Some(Some(Level::Debug)),
            "trace" => Some(Some(Level::Trace)),
            "off" => Some(None),
            _ => None,
        }
    }
}

/// A parsed `PALLAS_LOG` spec: a default max level plus per-target
/// overrides matched by longest prefix.
pub struct Filter {
    default: Option<Level>,
    targets: Vec<(String, Option<Level>)>,
}

impl Filter {
    /// Parse a spec; unknown directives are ignored (a typo'd filter
    /// must never crash the instrumented process).
    pub fn parse(spec: &str) -> Filter {
        let mut default = Some(Level::Warn);
        let mut targets = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.split_once('=') {
                None => {
                    if let Some(lv) = Level::parse(part) {
                        default = lv;
                    }
                }
                Some((target, level)) => {
                    if let Some(lv) = Level::parse(level) {
                        targets.push((target.trim().to_string(), lv));
                    }
                }
            }
        }
        // Longest prefix first, so the first match below is the winner.
        targets.sort_by(|a, b| b.0.len().cmp(&a.0.len()));
        Filter { default, targets }
    }

    /// Would a record at `level` for `target` be emitted?
    pub fn enabled(&self, level: Level, target: &str) -> bool {
        let max = self
            .targets
            .iter()
            .find(|(prefix, _)| target.starts_with(prefix.as_str()))
            .map(|(_, lv)| *lv)
            .unwrap_or(self.default);
        match max {
            Some(max) => level <= max,
            None => false,
        }
    }
}

fn global() -> &'static Filter {
    static FILTER: OnceLock<Filter> = OnceLock::new();
    FILTER.get_or_init(|| {
        Filter::parse(&std::env::var("PALLAS_LOG").unwrap_or_default())
    })
}

/// Whether a record at `level` for `target` would be emitted — lets
/// call sites skip building expensive fields.
pub fn enabled(level: Level, target: &str) -> bool {
    global().enabled(level, target)
}

/// Emit one structured log line to stderr (if the filter allows it).
pub fn emit(level: Level, target: &str, msg: &str, kvs: &[(&str, Json)]) {
    if !enabled(level, target) {
        return;
    }
    let mut m: BTreeMap<String, Json> =
        kvs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
    m.insert("level".to_string(), Json::Str(level.name().to_string()));
    m.insert("target".to_string(), Json::Str(target.to_string()));
    m.insert("msg".to_string(), Json::Str(msg.to_string()));
    eprintln!("{}", Json::Obj(m).render());
}

pub fn error(target: &str, msg: &str, kvs: &[(&str, Json)]) {
    emit(Level::Error, target, msg, kvs);
}

pub fn warn(target: &str, msg: &str, kvs: &[(&str, Json)]) {
    emit(Level::Warn, target, msg, kvs);
}

pub fn info(target: &str, msg: &str, kvs: &[(&str, Json)]) {
    emit(Level::Info, target, msg, kvs);
}

pub fn debug(target: &str, msg: &str, kvs: &[(&str, Json)]) {
    emit(Level::Debug, target, msg, kvs);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_filter_is_warn() {
        let f = Filter::parse("");
        assert!(f.enabled(Level::Error, "dist"));
        assert!(f.enabled(Level::Warn, "dist"));
        assert!(!f.enabled(Level::Info, "dist"));
        assert!(!f.enabled(Level::Debug, "dist"));
    }

    #[test]
    fn global_level_directive() {
        let f = Filter::parse("debug");
        assert!(f.enabled(Level::Debug, "anything"));
        assert!(!f.enabled(Level::Trace, "anything"));
        let off = Filter::parse("off");
        assert!(!off.enabled(Level::Error, "anything"));
    }

    #[test]
    fn per_target_overrides_longest_prefix_wins() {
        let f = Filter::parse("warn,dist=debug,dist.worker=off");
        assert!(f.enabled(Level::Debug, "dist.coordinator"));
        assert!(!f.enabled(Level::Error, "dist.worker"));
        assert!(!f.enabled(Level::Info, "store.wal"));
        assert!(f.enabled(Level::Warn, "store.wal"));
    }

    #[test]
    fn garbage_directives_are_ignored() {
        let f = Filter::parse("loud,=,x=verbose,info");
        assert!(f.enabled(Level::Info, "t"));
        assert!(!f.enabled(Level::Debug, "t"));
    }
}

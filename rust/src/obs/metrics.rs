//! Process-wide metrics registry: named monotone counters, gauges,
//! and latency histograms.
//!
//! The hot path is one relaxed atomic op on a handle cached at setup
//! (`metrics::counter("pallas_wal_appends_total")` once, `.inc()` per
//! append) — registration takes a registry lock, incrementing never
//! does. Snapshots are point-in-time and render two ways:
//!
//! * [`snapshot`] — a [`Json`] object (sorted keys), what the serve
//!   layer's `metrics` verb returns;
//! * [`render_prometheus`] — text exposition (`# TYPE` headers,
//!   `name{labels} value` samples) for scrape-style collection.
//!
//! Names follow Prometheus conventions (`pallas_<subsystem>_<what>`,
//! `_total` suffix on counters); a `{label="value"}` suffix in the
//! registered name becomes the sample's label set. The registry is
//! process-global on purpose: counters are monotone and histograms
//! only accumulate, so concurrent subsystems (or tests) sharing it
//! only ever add.
//!
//! [`histogram`] interns a shared [`Histogram`] the same way —
//! callers cache the `Arc` handle and `record()` lock-free; snapshots
//! embed each histogram's quantile summary and the Prometheus render
//! emits `summary` expositions (quantile samples + `_sum`/`_count`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::hist::{render_prometheus_summary, Histogram};
use crate::util::Json;

/// A monotone counter handle; `Clone` shares the underlying cell.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins gauge handle.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        counters: Mutex::new(BTreeMap::new()),
        gauges: Mutex::new(BTreeMap::new()),
        histograms: Mutex::new(BTreeMap::new()),
    })
}

fn intern(map: &Mutex<BTreeMap<String, Arc<AtomicU64>>>, name: &str) -> Arc<AtomicU64> {
    let mut m = map.lock().unwrap();
    match m.get(name) {
        Some(cell) => Arc::clone(cell),
        None => {
            let cell = Arc::new(AtomicU64::new(0));
            m.insert(name.to_string(), Arc::clone(&cell));
            cell
        }
    }
}

/// Register (or re-attach to) the named counter.
pub fn counter(name: &str) -> Counter {
    Counter(intern(&registry().counters, name))
}

/// Register (or re-attach to) the named gauge.
pub fn gauge(name: &str) -> Gauge {
    Gauge(intern(&registry().gauges, name))
}

/// Register (or re-attach to) the named histogram. Cache the returned
/// `Arc` at setup; `record()` on it is lock-free.
pub fn histogram(name: &str) -> Arc<Histogram> {
    let mut m = registry().histograms.lock().unwrap();
    match m.get(name) {
        Some(h) => Arc::clone(h),
        None => {
            let h = Arc::new(Histogram::new());
            m.insert(name.to_string(), Arc::clone(&h));
            h
        }
    }
}

/// Point-in-time JSON snapshot:
/// `{"counters":{name:value,...},"gauges":{...},"histograms":
/// {name:{count,max,mean,min,p50,p90,p99,sum},...}}`.
pub fn snapshot() -> Json {
    let dump = |map: &Mutex<BTreeMap<String, Arc<AtomicU64>>>| {
        Json::Obj(
            map.lock()
                .unwrap()
                .iter()
                .map(|(k, v)| {
                    (k.clone(), Json::Num(v.load(Ordering::Relaxed) as f64))
                })
                .collect(),
        )
    };
    let mut m = BTreeMap::new();
    m.insert("counters".to_string(), dump(&registry().counters));
    m.insert("gauges".to_string(), dump(&registry().gauges));
    m.insert(
        "histograms".to_string(),
        Json::Obj(
            registry()
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, h)| (k.clone(), h.to_json()))
                .collect(),
        ),
    );
    Json::Obj(m)
}

/// All counters as `(name, value)`, sorted by name — the time-series
/// sampler's raw feed ([`obs::timeseries`]).
///
/// [`obs::timeseries`]: super::timeseries
pub fn counter_values() -> Vec<(String, u64)> {
    registry()
        .counters
        .lock()
        .unwrap()
        .iter()
        .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
        .collect()
}

/// All gauges as `(name, value)`, sorted by name.
pub fn gauge_values() -> Vec<(String, u64)> {
    registry()
        .gauges
        .lock()
        .unwrap()
        .iter()
        .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
        .collect()
}

/// All registered histograms as shared handles, sorted by name.
pub fn histogram_handles() -> Vec<(String, Arc<Histogram>)> {
    registry()
        .histograms
        .lock()
        .unwrap()
        .iter()
        .map(|(k, h)| (k.clone(), Arc::clone(h)))
        .collect()
}

/// Escape one label value per the Prometheus text exposition rules:
/// `\` -> `\\`, `"` -> `\"`, newline -> `\n`.
fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// True when the text after a candidate closing quote looks like the
/// boundary to the next `key="` pair (or the end of the label block) —
/// the disambiguation rule for raw quotes *inside* a stored value.
fn is_pair_boundary(rest: &str) -> bool {
    if rest.is_empty() {
        return true;
    }
    let Some(r) = rest.strip_prefix(',') else {
        return false;
    };
    let Some(eq) = r.find('=') else {
        return false;
    };
    let key = &r[..eq];
    !key.is_empty()
        && key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
        && r[eq + 1..].starts_with('"')
}

/// Re-escape the label block of a `name{k="v",...}` metric name.
/// Registered names store label values raw (e.g. a hostile tier name
/// containing `"` or `\`), so the exposition layer must escape them.
/// Keys come from code and are passed through; a `"` inside a value is
/// treated as content unless it sits on a pair boundary (a value that
/// literally contains `",key="` is ambiguous and splits — acceptable,
/// since the output stays well-formed exposition either way).
fn escape_labels(labels: &str) -> String {
    let Some(inner) = labels.strip_prefix('{').and_then(|s| s.strip_suffix('}')) else {
        return labels.to_string();
    };
    let mut out = String::from("{");
    let mut rest = inner;
    loop {
        // Copy `key="` verbatim (keys are code-controlled idents).
        match rest.find("=\"") {
            None => {
                out.push_str(rest);
                break;
            }
            Some(eq) => {
                out.push_str(&rest[..eq + 2]);
                rest = &rest[eq + 2..];
            }
        }
        // Scan for the quote that really closes this value.
        let val_end = rest
            .char_indices()
            .find(|&(j, c)| c == '"' && is_pair_boundary(&rest[j + 1..]))
            .map(|(j, _)| j);
        match val_end {
            None => {
                // Unterminated value: escape the remainder wholesale.
                out.push_str(&escape_label_value(rest));
                break;
            }
            Some(j) => {
                out.push_str(&escape_label_value(&rest[..j]));
                out.push('"');
                rest = &rest[j + 1..];
                if rest.is_empty() {
                    break;
                }
                out.push(',');
                rest = &rest[1..]; // is_pair_boundary guaranteed the ','
            }
        }
    }
    out.push('}');
    out
}

/// Split `name{labels}` into its base and optional label suffix, with
/// the base sanitised to the Prometheus charset and label values
/// escaped for exposition.
fn prom_parts(name: &str) -> (String, String) {
    let (base, labels) = match name.find('{') {
        Some(i) => (&name[..i], &name[i..]),
        None => (name, ""),
    };
    let base: String = base
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == ':' { c } else { '_' })
        .collect();
    (base, escape_labels(labels))
}

/// Prometheus-style text exposition of the whole registry. Sorted and
/// deterministic for a fixed set of values; `# TYPE` headers appear
/// once per metric base name.
pub fn render_prometheus() -> String {
    let mut out = String::new();
    let render = |out: &mut String,
                  map: &Mutex<BTreeMap<String, Arc<AtomicU64>>>,
                  kind: &str| {
        let mut last_base = String::new();
        for (name, cell) in map.lock().unwrap().iter() {
            let (base, labels) = prom_parts(name);
            if base != last_base {
                out.push_str(&format!("# TYPE {base} {kind}\n"));
                last_base = base.clone();
            }
            out.push_str(&format!(
                "{base}{labels} {}\n",
                cell.load(Ordering::Relaxed)
            ));
        }
    };
    render(&mut out, &registry().counters, "counter");
    render(&mut out, &registry().gauges, "gauge");
    let mut last_base = String::new();
    for (name, h) in registry().histograms.lock().unwrap().iter() {
        let (base, labels) = prom_parts(name);
        render_prometheus_summary(&mut out, &format!("{base}{labels}"), h, base != last_base);
        last_base = base;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotone_and_shared_by_name() {
        let a = counter("pallas_test_metrics_shared_total");
        let b = counter("pallas_test_metrics_shared_total");
        let before = a.get();
        a.inc();
        b.add(2);
        assert_eq!(a.get(), before + 3);
        assert_eq!(b.get(), a.get());
    }

    #[test]
    fn gauges_are_last_write_wins() {
        let g = gauge("pallas_test_metrics_gauge");
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn snapshot_is_valid_sorted_json() {
        counter("pallas_test_metrics_snap_total").inc();
        gauge("pallas_test_metrics_snap_gauge").set(5);
        let snap = snapshot();
        let text = snap.render();
        // Round-trips through the parser: valid by construction.
        assert_eq!(Json::parse(&text).unwrap(), snap);
        assert!(snap
            .get("counters")
            .and_then(|c| c.get("pallas_test_metrics_snap_total"))
            .and_then(Json::as_u64)
            .unwrap()
            >= 1);
    }

    #[test]
    fn histograms_are_shared_by_name_and_snapshot() {
        let a = histogram("pallas_test_metrics_hist_us");
        let b = histogram("pallas_test_metrics_hist_us");
        let before = a.count();
        a.record(100);
        b.record(200);
        assert_eq!(a.count(), before + 2, "same name shares one histogram");
        let snap = snapshot();
        let h = snap
            .get("histograms")
            .and_then(|h| h.get("pallas_test_metrics_hist_us"))
            .expect("histogram in snapshot");
        assert!(h.get("count").and_then(Json::as_u64).unwrap() >= 2);
        assert!(h.get("p50").is_some() && h.get("p99").is_some());
        let text = render_prometheus();
        assert!(text.contains("# TYPE pallas_test_metrics_hist_us summary"));
        assert!(text.contains("pallas_test_metrics_hist_us{quantile=\"0.5\"} "));
        assert!(text.contains("pallas_test_metrics_hist_us_count "));
    }

    /// Hostile tier names — quotes, backslashes, newlines, even an
    /// embedded `",fake="` pair — registered through the raw
    /// `{label="v"}`-suffix convention must render as well-formed,
    /// correctly escaped exposition text.
    #[test]
    fn prometheus_rendering_escapes_hostile_label_values() {
        counter("pallas_test_metrics_evil_total{tier=\"a\"b\"}").add(1);
        counter("pallas_test_metrics_evil_total{tier=\"back\\slash\"}").add(1);
        counter("pallas_test_metrics_evil_total{tier=\"two\nlines\"}").add(1);
        counter("pallas_test_metrics_evil_total{tier=\"q\",et=\"4\"}").add(1);
        let text = render_prometheus();
        assert!(
            text.contains("pallas_test_metrics_evil_total{tier=\"a\\\"b\"} 1"),
            "inner quote escaped: {text}"
        );
        assert!(
            text.contains("pallas_test_metrics_evil_total{tier=\"back\\\\slash\"} 1"),
            "backslash escaped: {text}"
        );
        assert!(
            text.contains("pallas_test_metrics_evil_total{tier=\"two\\nlines\"} 1"),
            "newline escaped: {text}"
        );
        assert!(
            text.contains("pallas_test_metrics_evil_total{tier=\"q\",et=\"4\"} 1"),
            "multi-label names pass through untouched: {text}"
        );
        // Every non-comment line is exactly `name{...} value` with no
        // raw newline smuggled into the middle of a sample.
        for line in text.lines().filter(|l| l.contains("evil")) {
            assert!(
                line.ends_with(" 1") || line.starts_with("# TYPE"),
                "well-formed exposition line: {line:?}"
            );
        }
    }

    #[test]
    fn escape_labels_handles_edge_shapes() {
        assert_eq!(escape_labels(""), "");
        assert_eq!(escape_labels("{}"), "{}");
        assert_eq!(escape_labels("{tier=\"g\"}"), "{tier=\"g\"}");
        assert_eq!(escape_labels("{tier=\"a\"b\"}"), "{tier=\"a\\\"b\"}");
        assert_eq!(
            escape_labels("{a=\"x\",b=\"y\"}"),
            "{a=\"x\",b=\"y\"}"
        );
        // Unterminated value inside a block: remainder escaped as-is.
        assert_eq!(escape_labels("{tier=\"oo\\ps}"), "{tier=\"oo\\\\ps}");
        // No braces at all: passed through verbatim.
        assert_eq!(escape_labels("{tier=\"oops"), "{tier=\"oops");
    }

    #[test]
    fn registry_accessors_expose_live_values() {
        counter("pallas_test_metrics_access_total").add(3);
        gauge("pallas_test_metrics_access_gauge").set(9);
        histogram("pallas_test_metrics_access_us").record(42);
        let c = counter_values();
        assert!(c.iter().any(|(k, v)| k == "pallas_test_metrics_access_total" && *v >= 3));
        assert!(c.windows(2).all(|w| w[0].0 < w[1].0), "sorted by name");
        assert!(gauge_values()
            .iter()
            .any(|(k, v)| k == "pallas_test_metrics_access_gauge" && *v == 9));
        assert!(histogram_handles()
            .iter()
            .any(|(k, h)| k == "pallas_test_metrics_access_us" && h.count() >= 1));
    }

    #[test]
    fn prometheus_rendering_handles_labels() {
        counter("pallas_test_metrics_prom_total{tier=\"gold\"}").add(4);
        counter("pallas_test_metrics_prom_total{tier=\"silver\"}").add(2);
        let text = render_prometheus();
        assert!(text.contains("# TYPE pallas_test_metrics_prom_total counter"));
        assert!(text.contains("pallas_test_metrics_prom_total{tier=\"gold\"} "));
        assert!(text.contains("pallas_test_metrics_prom_total{tier=\"silver\"} "));
        // One TYPE header for the two labelled samples.
        assert_eq!(
            text.matches("# TYPE pallas_test_metrics_prom_total ").count(),
            1
        );
    }
}

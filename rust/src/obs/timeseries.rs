//! Periodic time-series sampling of the metrics registry: the data
//! plane under `watch`, `monitor`, the SLO evaluator and `perfgate`.
//!
//! A [`TimeSeries`] is a fixed-capacity ring of [`Sample`]s. Each
//! sample holds **counters as deltas** since the previous sample of
//! the same node (quiet counters are omitted), **gauges as points**,
//! and **histograms as cumulative [`HistSnapshot`]s** — cumulative
//! because snapshots merge exactly ([`Histogram::absorb`]) and any
//! window's activity is recoverable as [`HistSnapshot::delta`] between
//! the window's edge samples, while per-window bucket deltas would
//! lose the running totals the monitor's cluster merge needs.
//!
//! Sampling is driven by an injectable [`Clock`] so tests get
//! byte-identical series from a [`ManualClock`] while production uses
//! the monotonic one; nothing here reads the wall clock directly.
//!
//! **Wire vs ring form.** Over the wire (serve `watch` pushes,
//! coordinator `status` replies) samples travel *cumulative* — a
//! subscriber may join mid-run, so the producer cannot know the
//! subscriber's delta baseline. [`TimeSeries::push_cumulative`]
//! converts an incoming cumulative sample into ring (delta) form using
//! per-node previous totals, which is how the monitor folds many
//! endpoints into one log.
//!
//! The JSONL export mirrors `event.rs`: one sample per line, then a
//! schema footer line carrying the sample/drop accounting
//! ([`TS_SCHEMA`]). [`load`] tolerates several concatenated segments
//! (appends from multiple endpoints or runs) by summing footers.

use std::collections::{BTreeMap, VecDeque};
use std::fs::OpenOptions;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::hist::HistSnapshot;
use super::metrics;
use crate::util::Json;

/// Time-series line-format version, written into every export footer.
pub const TS_SCHEMA: u64 = 1;

/// A time source for the sampler. Implementations must be monotone;
/// the unit is microseconds since the clock's own epoch.
pub trait Clock: Send + Sync {
    fn now_us(&self) -> u64;
}

/// Production clock: microseconds since construction, monotone by
/// `Instant`'s contract.
pub struct MonotonicClock {
    epoch: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { epoch: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

/// Test clock: time moves only when the test says so, making sampled
/// series reproducible down to the byte.
pub struct ManualClock(AtomicU64);

impl ManualClock {
    pub fn new(start_us: u64) -> ManualClock {
        ManualClock(AtomicU64::new(start_us))
    }

    pub fn advance(&self, us: u64) {
        self.0.fetch_add(us, Ordering::Relaxed);
    }

    pub fn set(&self, us: u64) {
        self.0.store(us, Ordering::Relaxed);
    }
}

impl Clock for ManualClock {
    fn now_us(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// One periodic observation of a node's metrics registry. In a ring
/// (and in exports) `counters` are deltas; on the wire they are
/// cumulative totals — see the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Producing node (`serve`, `coord`, a monitor endpoint label...).
    pub node: String,
    /// Ring-local sequence number, assigned on insertion.
    pub seq: u64,
    /// Clock timestamp, µs since the producing clock's epoch.
    pub ts_us: u64,
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl Sample {
    pub fn to_json(&self) -> Json {
        let nums = |m: &BTreeMap<String, u64>| {
            Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect())
        };
        let mut m = BTreeMap::new();
        m.insert("node".to_string(), Json::Str(self.node.clone()));
        m.insert("seq".to_string(), Json::Num(self.seq as f64));
        m.insert("ts_us".to_string(), Json::Num(self.ts_us as f64));
        m.insert("counters".to_string(), nums(&self.counters));
        m.insert("gauges".to_string(), nums(&self.gauges));
        m.insert(
            "hists".to_string(),
            Json::Obj(self.hists.iter().map(|(k, h)| (k.clone(), h.to_json())).collect()),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Sample> {
        let node = j
            .get("node")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("sample missing node"))?
            .to_string();
        let num = |k: &str| j.get(k).and_then(Json::as_u64).ok_or_else(|| anyhow!("sample missing {k}"));
        let nums = |k: &str| -> Result<BTreeMap<String, u64>> {
            let mut out = BTreeMap::new();
            if let Some(obj) = j.get(k).and_then(Json::as_obj) {
                for (name, v) in obj {
                    let v = v.as_u64().ok_or_else(|| anyhow!("{k}[{name:?}] not a u64"))?;
                    out.insert(name.clone(), v);
                }
            }
            Ok(out)
        };
        let mut hists = BTreeMap::new();
        if let Some(obj) = j.get("hists").and_then(Json::as_obj) {
            for (name, h) in obj {
                hists.insert(
                    name.clone(),
                    HistSnapshot::from_json(h).with_context(|| format!("hists[{name:?}]"))?,
                );
            }
        }
        Ok(Sample {
            node,
            seq: num("seq")?,
            ts_us: num("ts_us")?,
            counters: nums("counters")?,
            gauges: nums("gauges")?,
            hists,
        })
    }
}

/// Build one cumulative sample of the process-global metrics registry.
/// With a filter, only metric names starting with the prefix are
/// included — tests use unique prefixes to stay independent of
/// whatever else the process recorded.
pub fn cumulative_sample(node: &str, ts_us: u64, filter: Option<&str>) -> Sample {
    let keep = |name: &str| filter.map_or(true, |p| name.starts_with(p));
    let counters = metrics::counter_values().into_iter().filter(|(k, _)| keep(k)).collect();
    let gauges = metrics::gauge_values().into_iter().filter(|(k, _)| keep(k)).collect();
    let hists = metrics::histogram_handles()
        .into_iter()
        .filter(|(k, _)| keep(k))
        .filter_map(|(k, h)| {
            let snap = h.snapshot();
            if snap.count > 0 {
                Some((k, snap))
            } else {
                None
            }
        })
        .collect();
    Sample { node: node.to_string(), seq: 0, ts_us, counters, gauges, hists }
}

/// Export footer accounting, summed across segments by [`load`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TsFooter {
    pub samples: u64,
    pub dropped: u64,
    pub schema: u64,
}

/// A fixed-capacity ring of samples with per-node delta state. When
/// full, the oldest sample is evicted and counted in `dropped` — the
/// same overwrite-and-account policy as the trace recorder's ring.
pub struct TimeSeries {
    cap: usize,
    node: String,
    filter: Option<String>,
    samples: VecDeque<Sample>,
    seq: u64,
    dropped: u64,
    /// Previous cumulative counter totals, per producing node.
    prev: BTreeMap<String, BTreeMap<String, u64>>,
}

impl TimeSeries {
    pub fn new(node: &str, cap: usize) -> TimeSeries {
        TimeSeries {
            cap: cap.max(1),
            node: node.to_string(),
            filter: None,
            samples: VecDeque::new(),
            seq: 0,
            dropped: 0,
            prev: BTreeMap::new(),
        }
    }

    /// Restrict locally-taken samples to metrics whose name starts
    /// with `prefix`.
    pub fn with_filter(mut self, prefix: &str) -> TimeSeries {
        self.filter = Some(prefix.to_string());
        self
    }

    /// Sample the process-global registry now (per `clock`) and append
    /// the delta-form result to the ring.
    pub fn sample(&mut self, clock: &dyn Clock) -> &Sample {
        let cumulative = cumulative_sample(&self.node, clock.now_us(), self.filter.as_deref());
        self.push_cumulative(cumulative)
    }

    /// Fold a cumulative sample (local or from the wire) into the
    /// ring: counters become deltas against this node's previous
    /// totals, quiet counters are dropped, and the ring assigns its
    /// own `seq`.
    pub fn push_cumulative(&mut self, mut s: Sample) -> &Sample {
        let prev = self.prev.entry(s.node.clone()).or_default();
        let mut deltas = BTreeMap::new();
        for (name, total) in &s.counters {
            let d = total.saturating_sub(prev.get(name).copied().unwrap_or(0));
            if d > 0 {
                deltas.insert(name.clone(), d);
            }
        }
        *prev = std::mem::take(&mut s.counters);
        s.counters = deltas;
        self.push(s)
    }

    /// Append an already-delta-form sample (ring form) verbatim,
    /// except that the ring assigns `seq`. Evicts and counts a drop
    /// when full.
    pub fn push(&mut self, mut s: Sample) -> &Sample {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
            self.dropped += 1;
        }
        s.seq = self.seq;
        self.seq += 1;
        self.samples.push_back(s);
        self.samples.back().expect("just pushed")
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Samples in ring order (oldest first).
    pub fn samples(&self) -> impl Iterator<Item = &Sample> {
        self.samples.iter()
    }

    pub fn latest(&self) -> Option<&Sample> {
        self.samples.back()
    }

    /// Summed counter deltas over the trailing `window` samples.
    pub fn window_counter(&self, name: &str, window: usize) -> u64 {
        self.samples
            .iter()
            .rev()
            .take(window)
            .map(|s| s.counters.get(name).copied().unwrap_or(0))
            .sum()
    }

    /// Histogram activity over the trailing `window` samples: the
    /// snapshot delta between the window's edge samples (cumulative
    /// snapshots make this exact). `None` when the metric never
    /// appeared.
    pub fn window_hist(&self, name: &str, window: usize) -> Option<HistSnapshot> {
        let latest = self.samples.back()?.hists.get(name)?;
        let n = self.samples.len();
        let baseline = n
            .checked_sub(window + 1)
            .and_then(|i| self.samples[i].hists.get(name));
        match baseline {
            Some(b) => Some(latest.delta(b)),
            None => Some(latest.clone()),
        }
    }

    /// Append the whole ring plus a schema footer to `path` as JSONL.
    pub fn export(&self, path: &Path) -> Result<()> {
        let mut f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open time-series log {}", path.display()))?;
        for s in &self.samples {
            writeln!(f, "{}", s.to_json().render())?;
        }
        writeln!(f, "{}", footer_line(self.samples.len() as u64, self.dropped))?;
        f.flush()?;
        Ok(())
    }
}

/// The rendered footer line for `samples`/`dropped` accounting.
pub fn footer_line(samples: u64, dropped: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("footer".to_string(), Json::Str("timeseries".to_string()));
    m.insert("samples".to_string(), Json::Num(samples as f64));
    m.insert("dropped".to_string(), Json::Num(dropped as f64));
    m.insert("schema".to_string(), Json::Num(TS_SCHEMA as f64));
    Json::Obj(m).render()
}

/// Parse a time-series log: samples in file order plus summed footer
/// accounting. Fails on malformed lines and on samples claimed by no
/// footer only if the schema is newer than this build understands.
pub fn load(path: &Path) -> Result<(Vec<Sample>, TsFooter)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read time-series log {}", path.display()))?;
    parse(&text)
}

/// [`load`] for in-memory text (tests, perfgate reductions).
pub fn parse(text: &str) -> Result<(Vec<Sample>, TsFooter)> {
    let mut samples = Vec::new();
    let mut footer = TsFooter::default();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).with_context(|| format!("time-series line {}", i + 1))?;
        if j.get("footer").and_then(Json::as_str) == Some("timeseries") {
            let num = |k: &str| j.get(k).and_then(Json::as_u64).unwrap_or(0);
            let schema = num("schema");
            if schema > TS_SCHEMA {
                return Err(anyhow!(
                    "time-series schema {schema} is newer than supported {TS_SCHEMA}"
                ));
            }
            footer.samples += num("samples");
            footer.dropped += num("dropped");
            footer.schema = footer.schema.max(schema);
            continue;
        }
        samples.push(Sample::from_json(&j).with_context(|| format!("time-series line {}", i + 1))?);
    }
    Ok((samples, footer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics;

    /// Core satellite property: the same registry evolution observed
    /// through the same manual clock yields byte-identical samples,
    /// whichever TimeSeries instance watches it.
    #[test]
    fn manual_clock_sampling_is_deterministic() {
        let prefix = "pallas_test_ts_det_";
        let c = metrics::counter("pallas_test_ts_det_jobs_total");
        let g = metrics::gauge("pallas_test_ts_det_depth");
        let h = metrics::histogram("pallas_test_ts_det_lat_us");
        let clock = ManualClock::new(1_000);
        let mut a = TimeSeries::new("n0", 16).with_filter(prefix);
        let mut b = TimeSeries::new("n0", 16).with_filter(prefix);

        for step in 0..4u64 {
            c.add(step + 1);
            g.set(10 * step);
            h.record(100 * (step + 1));
            clock.advance(250_000);
            a.sample(&clock);
            b.sample(&clock);
        }

        let render = |ts: &TimeSeries| {
            ts.samples().map(|s| s.to_json().render()).collect::<Vec<_>>().join("\n")
        };
        assert_eq!(render(&a), render(&b));
        // Counters arrive as the per-step deltas, not running totals.
        let deltas: Vec<u64> = a
            .samples()
            .map(|s| s.counters.get("pallas_test_ts_det_jobs_total").copied().unwrap_or(0))
            .collect();
        assert_eq!(deltas, vec![1, 2, 3, 4]);
        // Timestamps come from the injected clock alone.
        let ts: Vec<u64> = a.samples().map(|s| s.ts_us).collect();
        assert_eq!(ts, vec![251_000, 501_000, 751_000, 1_001_000]);
    }

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let clock = ManualClock::new(0);
        let mut ts = TimeSeries::new("n0", 3).with_filter("pallas_test_ts_ring_");
        let c = metrics::counter("pallas_test_ts_ring_total");
        for _ in 0..5 {
            c.inc();
            clock.advance(1_000);
            ts.sample(&clock);
        }
        assert_eq!(ts.len(), 3);
        assert_eq!(ts.dropped(), 2);
        let seqs: Vec<u64> = ts.samples().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4], "oldest evicted first");
    }

    #[test]
    fn export_load_round_trips_with_footer() {
        let dir = std::env::temp_dir().join(format!("pallas_ts_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ts.jsonl");
        let _ = std::fs::remove_file(&path);

        let clock = ManualClock::new(5);
        let mut ts = TimeSeries::new("serve", 8).with_filter("pallas_test_ts_rt_");
        let h = metrics::histogram("pallas_test_ts_rt_us");
        h.record(300);
        ts.sample(&clock);
        clock.advance(100);
        h.record(900);
        ts.sample(&clock);
        ts.export(&path).unwrap();
        // A second export segment appends; load sums the footers.
        ts.export(&path).unwrap();

        let (samples, footer) = load(&path).unwrap();
        assert_eq!(samples.len(), 4);
        assert_eq!(footer, TsFooter { samples: 4, dropped: 0, schema: TS_SCHEMA });
        assert_eq!(samples[0], *ts.samples().next().unwrap());
        assert_eq!(samples[1].hists["pallas_test_ts_rt_us"].count, 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn push_cumulative_keeps_per_node_delta_state() {
        let mut ts = TimeSeries::new("monitor", 8);
        let mk = |node: &str, total: u64| Sample {
            node: node.to_string(),
            seq: 0,
            ts_us: total,
            counters: [("x_total".to_string(), total)].into_iter().collect(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
        };
        ts.push_cumulative(mk("a", 10));
        ts.push_cumulative(mk("b", 100));
        ts.push_cumulative(mk("a", 25));
        ts.push_cumulative(mk("b", 100));
        let d: Vec<Option<u64>> =
            ts.samples().map(|s| s.counters.get("x_total").copied()).collect();
        assert_eq!(d, vec![Some(10), Some(100), Some(15), None]);
    }

    #[test]
    fn window_helpers_cover_edges() {
        let mut ts = TimeSeries::new("n", 8);
        assert_eq!(ts.window_counter("c", 3), 0);
        assert!(ts.window_hist("h", 3).is_none());

        let hist_at = |vals: &[u64]| {
            let h = crate::obs::Histogram::new();
            for &v in vals {
                h.record(v);
            }
            h.snapshot()
        };
        let mk = |c: u64, hist: HistSnapshot| Sample {
            node: "n".to_string(),
            seq: 0,
            ts_us: 0,
            counters: [("c".to_string(), c)].into_iter().collect(),
            gauges: BTreeMap::new(),
            hists: [("h".to_string(), hist)].into_iter().collect(),
        };
        ts.push(mk(1, hist_at(&[100])));
        ts.push(mk(2, hist_at(&[100, 200])));
        ts.push(mk(4, hist_at(&[100, 200, 5000])));
        assert_eq!(ts.window_counter("c", 2), 6);
        assert_eq!(ts.window_counter("c", 10), 7, "window larger than ring");
        // Trailing-2 window: activity after the first sample.
        let w = ts.window_hist("h", 2).unwrap();
        assert_eq!(w.count, 2);
        assert_eq!(w.count_above(1000), 1);
        // Window covering everything: the full cumulative snapshot.
        let all = ts.window_hist("h", 10).unwrap();
        assert_eq!(all.count, 3);
    }
}

//! Structured events, the [`EventSink`] trait, and the lock-striped
//! ring-buffer [`Recorder`] behind the [`Obs`] handle.
//!
//! An event is a flat record — `seq` (process-global total order),
//! `ts_us` (microseconds on the recorder's monotonic clock), `kind`
//! (one of [`KINDS`]), `name`, `node` (which process produced it) and
//! a sorted `fields` map — rendered as one deterministic JSON line.
//! Spans are begin/end event pairs linked by a `span` id field; the
//! end event carries `dur_us` measured by the guard, so durations are
//! exact even if ring overflow drops the begin event.
//!
//! **Causality (schema 2).** A span may carry a `parent` field — the
//! span id of its causal parent — plus, when the parent lives on
//! another node, a `parent_node` field. Both are ordinary entries in
//! the `fields` map, so schema-1 traces (no parents) still parse and
//! old tooling ignores them. A parent is installed on the [`Obs`]
//! handle ([`Obs::child_of`] / [`Obs::child_of_ctx`]): every span the
//! derived handle opens nests under it, which is how a whole subtree
//! (e.g. all `sweep.cell` spans of one job) inherits its parent
//! without threading ids through call signatures. [`TraceCtx`] is the
//! wire form of a span's identity — `(node, span)` — carried by the
//! dist protocol so a worker's `dist.job` span can nest under the
//! coordinator's lease span across machines. The flush footer reports
//! `schema: 2` so tooling can tell which vocabulary a trace speaks.
//!
//! The recorder never touches the disk while recording: events land in
//! one of [`STRIPES`] mutex-protected rings selected by thread (so
//! scan workers don't contend on one lock), and [`Recorder::flush`]
//! drains, sorts by `seq` and appends to the trace file in one write.
//! Overflowing a stripe drops its oldest event and counts the drop; the
//! flush footer reports the total so `trace --check` can surface it.

use std::collections::{BTreeMap, VecDeque};
use std::fs::OpenOptions;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

use super::log::{self, Level};

/// Event stripes; scan workers hash their thread onto one.
const STRIPES: usize = 8;
/// Events retained per stripe before the ring drops its oldest.
const STRIPE_CAP: usize = 8192;

/// The closed event vocabulary. `trace --check` rejects anything else.
pub const KINDS: [&str; 6] =
    ["span_begin", "span_end", "counter", "gauge", "log", "meta"];

/// One structured event, the unit of the trace file.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub seq: u64,
    pub ts_us: u64,
    pub kind: String,
    pub name: String,
    pub node: String,
    pub fields: BTreeMap<String, Json>,
}

impl Event {
    /// Render as the canonical JSON object (sorted keys, ASCII — see
    /// `util::json`), ready for one JSONL line.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("seq".to_string(), Json::Num(self.seq as f64));
        m.insert("ts_us".to_string(), Json::Num(self.ts_us as f64));
        m.insert("kind".to_string(), Json::Str(self.kind.clone()));
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("node".to_string(), Json::Str(self.node.clone()));
        m.insert("fields".to_string(), Json::Obj(self.fields.clone()));
        Json::Obj(m)
    }

    /// Parse one trace line, validating the schema (`trace --check`'s
    /// per-line half; span balance is `trace::check`).
    pub fn from_json_line(line: &str) -> Result<Event> {
        let j = Json::parse(line).context("event line is not valid JSON")?;
        let s = |key: &str| -> Result<String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("event missing string field {key:?}"))
        };
        let n = |key: &str| -> Result<u64> {
            j.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("event missing integer field {key:?}"))
        };
        let kind = s("kind")?;
        if !KINDS.contains(&kind.as_str()) {
            bail!("unknown event kind {kind:?}");
        }
        let fields = j
            .get("fields")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("event missing object field \"fields\""))?
            .clone();
        Ok(Event {
            seq: n("seq")?,
            ts_us: n("ts_us")?,
            kind,
            name: s("name")?,
            node: s("node")?,
            fields,
        })
    }
}

/// A span's cross-process identity: the recording node's name plus the
/// span id (unique within that node's sink). This is what crosses the
/// wire — the dist protocol's `lease`/`result` verbs carry one — so a
/// span on one machine can parent a span on another.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceCtx {
    pub node: String,
    pub span: u64,
}

impl TraceCtx {
    /// Render as `{"node":...,"span":...}` (sorted keys, like every
    /// other wire object).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("node".to_string(), Json::Str(self.node.clone()));
        m.insert("span".to_string(), Json::Num(self.span as f64));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<TraceCtx> {
        Ok(TraceCtx {
            node: j
                .get("node")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("trace_ctx missing \"node\""))?
                .to_string(),
            span: j
                .get("span")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("trace_ctx missing \"span\""))?,
        })
    }
}

/// Where events go. [`Recorder`] is the shipped implementation; tests
/// can substitute an in-memory sink.
pub trait EventSink: Send + Sync {
    /// Record one event. Must be cheap: called from scan workers.
    fn record(&self, kind: &'static str, name: &str, fields: BTreeMap<String, Json>);
    /// Allocate a fresh span id (unique within this sink).
    fn next_span(&self) -> u64;
    /// Persist buffered events (append; callable more than once).
    fn flush(&self) -> Result<()>;
    /// The node name stamped onto this sink's events — a span's
    /// [`TraceCtx`] is `(node_name, span id)`. Sinks that don't care
    /// about cross-node identity keep the default.
    fn node_name(&self) -> &str {
        ""
    }
}

/// Build a fields map from a literal slice — the call-site idiom is
/// `obs.counter("dist.commit", 1, &[("job", Json::Num(3.0))])`.
pub fn fields(kvs: &[(&str, Json)]) -> BTreeMap<String, Json> {
    kvs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

fn stripe_index() -> usize {
    use std::hash::{Hash, Hasher};
    thread_local! {
        static IDX: usize = {
            let mut h = std::collections::hash_map::DefaultHasher::new();
            std::thread::current().id().hash(&mut h);
            h.finish() as usize % STRIPES
        };
    }
    IDX.with(|i| *i)
}

/// The lock-striped ring-buffer recorder: buffers events in memory,
/// appends them as JSONL on [`Recorder::flush`].
pub struct Recorder {
    node: String,
    path: PathBuf,
    epoch: Instant,
    seq: AtomicU64,
    span_ids: AtomicU64,
    dropped: AtomicU64,
    /// Registry mirror of [`Recorder::dropped`] so silent ring
    /// overflow is visible to metrics scrapes, not just flush footers.
    dropped_gauge: super::metrics::Gauge,
    stripes: Vec<Mutex<VecDeque<Event>>>,
}

impl Recorder {
    pub fn new(path: &Path, node: &str) -> Recorder {
        Recorder {
            node: node.to_string(),
            path: path.to_path_buf(),
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            span_ids: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            dropped_gauge: super::metrics::gauge("pallas_obs_ring_dropped"),
            stripes: (0..STRIPES)
                .map(|_| Mutex::new(VecDeque::new()))
                .collect(),
        }
    }

    fn push(&self, ev: Event) {
        let mut ring = self.stripes[stripe_index()].lock().unwrap();
        if ring.len() >= STRIPE_CAP {
            ring.pop_front();
            let d = self.dropped.fetch_add(1, Ordering::Relaxed) + 1;
            self.dropped_gauge.set(d);
        }
        ring.push_back(ev);
    }

    /// Events dropped to ring overflow since the last flush footer.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Trace schema version reported in the flush footer: 2 added optional
/// `parent`/`parent_node` span fields. Old (schema-1) traces still
/// parse — the fields are additive.
pub const TRACE_SCHEMA: u64 = 2;

impl EventSink for Recorder {
    fn record(&self, kind: &'static str, name: &str, fields: BTreeMap<String, Json>) {
        let ev = Event {
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            ts_us: self.epoch.elapsed().as_micros() as u64,
            kind: kind.to_string(),
            name: name.to_string(),
            node: self.node.clone(),
            fields,
        };
        self.push(ev);
    }

    fn next_span(&self) -> u64 {
        self.span_ids.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn node_name(&self) -> &str {
        &self.node
    }

    fn flush(&self) -> Result<()> {
        // The footer is an ordinary event so it drains with the rest.
        self.record(
            "meta",
            "obs.flush",
            fields(&[
                ("dropped", Json::Num(self.dropped() as f64)),
                ("schema", Json::Num(TRACE_SCHEMA as f64)),
            ]),
        );
        let mut evs: Vec<Event> = Vec::new();
        for stripe in &self.stripes {
            evs.extend(stripe.lock().unwrap().drain(..));
        }
        evs.sort_by_key(|e| e.seq);
        let mut out = String::new();
        for ev in &evs {
            out.push_str(&ev.to_json().render());
            out.push('\n');
        }
        let mut f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("open trace file {}", self.path.display()))?;
        f.write_all(out.as_bytes())
            .with_context(|| format!("write trace file {}", self.path.display()))?;
        Ok(())
    }
}

/// The handle instrumentation points hold: either off (every call is a
/// no-op beyond an `Option` check) or backed by a shared [`EventSink`].
/// `Clone` is an `Arc` bump, so it threads freely through configs and
/// worker closures.
#[derive(Clone, Default)]
pub struct Obs {
    sink: Option<Arc<dyn EventSink>>,
    /// Default parent for every span this handle opens (see
    /// [`Obs::child_of`]); `None` opens root spans.
    parent: Option<TraceCtx>,
}

impl std::fmt::Debug for Obs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Obs").field("enabled", &self.enabled()).finish()
    }
}

impl Obs {
    /// Tracing disabled: logs still reach stderr (env-filtered), but
    /// no events are recorded and `span` guards are inert.
    pub fn off() -> Obs {
        Obs { sink: None, parent: None }
    }

    /// Trace into `path` (JSONL, appended on [`Obs::flush`]); `node`
    /// names this process in merged multi-node views.
    pub fn to_file(path: &Path, node: &str) -> Obs {
        Obs { sink: Some(Arc::new(Recorder::new(path, node))), parent: None }
    }

    /// Back the handle with a custom sink (tests).
    pub fn with_sink(sink: Arc<dyn EventSink>) -> Obs {
        Obs { sink: Some(sink), parent: None }
    }

    /// Derive a handle whose spans nest under `span`: the causal
    /// threading primitive. A job opens its span, then passes
    /// `obs.child_of(&span)` down, and every span the callee opens —
    /// however deep — carries the job span as `parent`. No-op (returns
    /// a clone) when tracing is off or `span` is inert.
    pub fn child_of(&self, span: &Span) -> Obs {
        Obs { sink: self.sink.clone(), parent: span.ctx().or_else(|| self.parent.clone()) }
    }

    /// As [`Obs::child_of`] for a parent on (possibly) another node —
    /// the receiving half of a wire-carried [`TraceCtx`].
    pub fn child_of_ctx(&self, ctx: &TraceCtx) -> Obs {
        Obs { sink: self.sink.clone(), parent: Some(ctx.clone()) }
    }

    /// Whether events are being recorded. Hot paths gate their field
    /// construction on this so the disabled path does no work.
    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Record a raw event (`kind` must be one of [`KINDS`]).
    pub fn event(&self, kind: &'static str, name: &str, kvs: &[(&str, Json)]) {
        if let Some(sink) = &self.sink {
            sink.record(kind, name, fields(kvs));
        }
    }

    /// Record a counter event (a named delta, not the registry: use
    /// [`metrics`](super::metrics) for process totals).
    pub fn counter(&self, name: &str, value: u64, kvs: &[(&str, Json)]) {
        if let Some(sink) = &self.sink {
            let mut f = fields(kvs);
            f.insert("value".to_string(), Json::Num(value as f64));
            sink.record("counter", name, f);
        }
    }

    /// Open a span: records `span_begin` now, `span_end` (with
    /// `dur_us` and any fields added via [`Span::field`]) when the
    /// guard drops. Inert when tracing is off.
    pub fn span(&self, name: &'static str, kvs: &[(&str, Json)]) -> Span {
        match &self.sink {
            Some(sink) => {
                let id = sink.next_span();
                let mut f = fields(kvs);
                f.insert("span".to_string(), Json::Num(id as f64));
                if let Some(p) = &self.parent {
                    f.insert("parent".to_string(), Json::Num(p.span as f64));
                    if p.node != sink.node_name() {
                        f.insert("parent_node".to_string(), Json::Str(p.node.clone()));
                    }
                }
                sink.record("span_begin", name, f.clone());
                Span {
                    sink: Some(Arc::clone(sink)),
                    id,
                    name,
                    start: Instant::now(),
                    fields: f,
                }
            }
            None => Span {
                sink: None,
                id: 0,
                name,
                start: Instant::now(),
                fields: BTreeMap::new(),
            },
        }
    }

    /// Leveled log: env-filtered stderr line (see [`log`]) plus, when
    /// tracing, a mirrored `log` event in the trace file.
    pub fn log(&self, level: Level, target: &str, msg: &str, kvs: &[(&str, Json)]) {
        log::emit(level, target, msg, kvs);
        if let Some(sink) = &self.sink {
            let mut f = fields(kvs);
            f.insert("level".to_string(), Json::Str(level.name().to_string()));
            f.insert("msg".to_string(), Json::Str(msg.to_string()));
            sink.record("log", target, f);
        }
    }

    pub fn warn(&self, target: &str, msg: &str, kvs: &[(&str, Json)]) {
        self.log(Level::Warn, target, msg, kvs);
    }

    pub fn info(&self, target: &str, msg: &str, kvs: &[(&str, Json)]) {
        self.log(Level::Info, target, msg, kvs);
    }

    pub fn debug(&self, target: &str, msg: &str, kvs: &[(&str, Json)]) {
        self.log(Level::Debug, target, msg, kvs);
    }

    /// Persist buffered events. No-op when tracing is off.
    pub fn flush(&self) -> Result<()> {
        match &self.sink {
            Some(sink) => sink.flush(),
            None => Ok(()),
        }
    }
}

/// RAII span guard returned by [`Obs::span`].
pub struct Span {
    sink: Option<Arc<dyn EventSink>>,
    id: u64,
    name: &'static str,
    start: Instant,
    fields: BTreeMap<String, Json>,
}

impl Span {
    /// Attach a field to the eventual `span_end` (e.g. a solver-stats
    /// delta folded in after the solve).
    pub fn field(&mut self, key: &str, value: Json) {
        if self.sink.is_some() {
            self.fields.insert(key.to_string(), value);
        }
    }

    /// This span's id within its sink; `None` when tracing is off.
    pub fn id(&self) -> Option<u64> {
        self.sink.as_ref().map(|_| self.id)
    }

    /// This span's cross-node identity, ready to carry over a wire or
    /// install as a default parent ([`Obs::child_of_ctx`]). `None`
    /// when tracing is off.
    pub fn ctx(&self) -> Option<TraceCtx> {
        self.sink
            .as_ref()
            .map(|s| TraceCtx { node: s.node_name().to_string(), span: self.id })
    }

    /// End the span now (dropping does the same).
    pub fn finish(self) {}
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(sink) = self.sink.take() {
            let mut f = std::mem::take(&mut self.fields);
            f.insert(
                "dur_us".to_string(),
                Json::Num(self.start.elapsed().as_micros() as f64),
            );
            sink.record("span_end", self.name, f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// In-memory sink capturing everything, for assertions.
    #[derive(Default)]
    struct MemSink {
        events: Mutex<Vec<(String, String, BTreeMap<String, Json>)>>,
        spans: AtomicU64,
    }

    impl EventSink for MemSink {
        fn record(&self, kind: &'static str, name: &str, fields: BTreeMap<String, Json>) {
            self.events.lock().unwrap().push((
                kind.to_string(),
                name.to_string(),
                fields,
            ));
        }
        fn next_span(&self) -> u64 {
            self.spans.fetch_add(1, Ordering::Relaxed) + 1
        }
        fn flush(&self) -> Result<()> {
            Ok(())
        }
    }

    #[test]
    fn event_line_round_trip() {
        let ev = Event {
            seq: 7,
            ts_us: 1234,
            kind: "counter".to_string(),
            name: "dist.commit".to_string(),
            node: "coord".to_string(),
            fields: fields(&[("job", Json::Num(3.0))]),
        };
        let line = ev.to_json().render();
        assert_eq!(line, ev.to_json().render(), "deterministic rendering");
        assert_eq!(Event::from_json_line(&line).unwrap(), ev);
    }

    #[test]
    fn malformed_lines_are_rejected() {
        assert!(Event::from_json_line("not json").is_err());
        assert!(Event::from_json_line("{\"seq\":1}").is_err());
        let bad_kind = "{\"fields\":{},\"kind\":\"dance\",\"name\":\"x\",\
                        \"node\":\"n\",\"seq\":1,\"ts_us\":2}";
        assert!(Event::from_json_line(bad_kind).is_err());
    }

    #[test]
    fn span_guard_emits_balanced_pair_with_duration() {
        let sink = Arc::new(MemSink::default());
        let obs = Obs::with_sink(sink.clone());
        {
            let mut span = obs.span("sweep.cell", &[("a", Json::Num(1.0))]);
            span.field("conflicts", Json::Num(42.0));
        }
        let evs = sink.events.lock().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].0, "span_begin");
        assert_eq!(evs[1].0, "span_end");
        assert_eq!(evs[0].2.get("span"), evs[1].2.get("span"));
        assert_eq!(evs[1].2.get("conflicts"), Some(&Json::Num(42.0)));
        assert!(evs[1].2.contains_key("dur_us"));
        assert!(!evs[0].2.contains_key("dur_us"));
    }

    #[test]
    fn disabled_handle_records_nothing() {
        let obs = Obs::off();
        assert!(!obs.enabled());
        let mut span = obs.span("x", &[]);
        span.field("k", Json::Num(1.0));
        drop(span);
        obs.counter("c", 1, &[]);
        assert!(obs.flush().is_ok());
    }

    #[test]
    fn recorder_flushes_sorted_jsonl_with_footer() {
        let dir = std::env::temp_dir().join(format!(
            "obs_event_test_{}_{}",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").len()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let _ = std::fs::remove_file(&path);
        let obs = Obs::to_file(&path, "n1");
        obs.counter("a", 1, &[]);
        obs.counter("b", 2, &[("k", Json::Str("v".to_string()))]);
        obs.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let evs: Vec<Event> = text
            .lines()
            .map(|l| Event::from_json_line(l).unwrap())
            .collect();
        assert_eq!(evs.len(), 3, "two counters + flush footer");
        assert!(evs.windows(2).all(|w| w[0].seq < w[1].seq));
        assert_eq!(evs[2].kind, "meta");
        assert_eq!(evs[2].fields.get("dropped"), Some(&Json::Num(0.0)));
        assert_eq!(
            evs[2].fields.get("schema"),
            Some(&Json::Num(TRACE_SCHEMA as f64)),
            "footer reports the trace schema version"
        );
        assert!(evs.iter().all(|e| e.node == "n1"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trace_ctx_round_trips() {
        let ctx = TraceCtx { node: "w1".to_string(), span: 42 };
        let j = ctx.to_json();
        assert_eq!(j.render(), "{\"node\":\"w1\",\"span\":42}");
        assert_eq!(TraceCtx::from_json(&j).unwrap(), ctx);
        assert!(TraceCtx::from_json(&Json::Obj(BTreeMap::new())).is_err());
    }

    /// An in-memory sink with a node name, for cross-node assertions.
    struct NamedSink {
        inner: MemSink,
        node: String,
    }

    impl EventSink for NamedSink {
        fn record(&self, kind: &'static str, name: &str, fields: BTreeMap<String, Json>) {
            self.inner.record(kind, name, fields);
        }
        fn next_span(&self) -> u64 {
            self.inner.next_span()
        }
        fn flush(&self) -> Result<()> {
            Ok(())
        }
        fn node_name(&self) -> &str {
            &self.node
        }
    }

    #[test]
    fn child_handles_parent_their_spans() {
        let sink = Arc::new(NamedSink { inner: MemSink::default(), node: "n".to_string() });
        let obs = Obs::with_sink(sink.clone());
        let root = obs.span("job", &[]);
        let root_id = root.id().unwrap();
        assert_eq!(
            root.ctx(),
            Some(TraceCtx { node: "n".to_string(), span: root_id })
        );
        let child_obs = obs.child_of(&root);
        {
            let _cell = child_obs.span("cell", &[]);
        }
        root.finish();
        let evs = sink.inner.events.lock().unwrap();
        // [job begin, cell begin, cell end, job end]
        assert_eq!(evs.len(), 4);
        let cell_begin = &evs[1];
        assert_eq!(cell_begin.1, "cell");
        assert_eq!(cell_begin.2.get("parent"), Some(&Json::Num(root_id as f64)));
        // Same-node parent: no parent_node field.
        assert!(!cell_begin.2.contains_key("parent_node"));
        // Root span itself has no parent.
        assert!(!evs[0].2.contains_key("parent"));
        // The end event repeats the linkage (drop-tolerant traces).
        assert_eq!(evs[2].2.get("parent"), Some(&Json::Num(root_id as f64)));
    }

    #[test]
    fn cross_node_parent_records_parent_node() {
        let sink = Arc::new(NamedSink { inner: MemSink::default(), node: "w1".to_string() });
        let obs = Obs::with_sink(sink.clone());
        let remote = TraceCtx { node: "coord".to_string(), span: 7 };
        {
            let _job = obs.child_of_ctx(&remote).span("dist.job", &[]);
        }
        let evs = sink.inner.events.lock().unwrap();
        assert_eq!(evs[0].2.get("parent"), Some(&Json::Num(7.0)));
        assert_eq!(
            evs[0].2.get("parent_node"),
            Some(&Json::Str("coord".to_string()))
        );
    }

    #[test]
    fn disabled_handle_has_no_span_identity() {
        let obs = Obs::off();
        let span = obs.span("x", &[]);
        assert_eq!(span.id(), None);
        assert_eq!(span.ctx(), None);
        // child_of on an inert span keeps the handle inert.
        let child = obs.child_of(&span);
        assert!(!child.enabled());
    }
}

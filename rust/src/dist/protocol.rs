//! The distributed-sweep wire vocabulary: strict request/response,
//! line-delimited JSON over TCP, framed by the shared
//! [`util::jsonl`](crate::util::jsonl) discipline (64KB line cap,
//! structured `{"ok":false,...}` errors) — the same wire rules as the
//! serving protocol, with a different verb set.
//!
//! A **worker** connects, says hello, and then loops: request a lease,
//! run the leased job, send the result, repeat. Every worker line gets
//! exactly one coordinator line back, so neither side ever needs to
//! demultiplex:
//!
//! ```text
//! worker                                coordinator
//! {"type":"hello","name":"w1","proto":1}
//!                     {"jobs":8,"lease_ms":60000,"ok":true,"type":"welcome"}
//! {"type":"lease_request"}
//!                     {"bench":"adder_i4","et":2,"job":3,"method":"SHARED",
//!                      "ok":true,"search":{...},"type":"lease"}
//! {"type":"result","job":3,"record":{...RunRecord...}}
//!                     {"fresh":true,"job":3,"ok":true,"type":"committed"}
//! {"type":"lease_request"}
//!                     {"ms":500,"ok":true,"type":"wait"}     (nothing leasable *yet*)
//!                     {"ok":true,"type":"done"}              (sweep complete: disconnect)
//! ```
//!
//! `reject` is the worker's "I cannot run this lease" (unknown
//! benchmark after a version skew, undecodable config): the
//! coordinator requeues the job for someone else and answers
//! `requeued`. `fresh:false` on a commit means the result was a stale
//! duplicate (the lease had expired and another worker's commit won) —
//! correct behaviour, not an error.
//!
//! Requests and responses are rendered with `Json::render` (sorted
//! keys, ASCII), so every message is byte-deterministic.
//!
//! **Trace context.** When both sides trace, `lease` carries the
//! coordinator's lease-span identity as `trace_ctx` (`{node, span}`)
//! and `result` carries the worker's `dist.job` span identity back —
//! so a merged trace links each worker solve under the lease that
//! caused it, one causal tree per job across machines. The field is
//! optional and additive (an untraced peer omits it; an old peer
//! ignores it), so `PROTO_VERSION` stays unchanged.
//!
//! **Telemetry piggyback.** A `lease_request` may carry a compact
//! `telemetry` frame ([`WorkerTelemetry`]: jobs completed, wire bytes
//! each way, uptime) — the coordinator folds it into its live
//! per-worker view at zero extra round trips, since the lease loop is
//! already the worker's natural heartbeat. Like `trace_ctx` the field
//! is optional and additive. Separately, a `status` request (allowed
//! *before* `hello`, so monitoring clients need no worker identity)
//! answers one cumulative registry sample plus the per-worker view —
//! the poll half of the `monitor` subcommand (DESIGN.md §14).

use std::collections::BTreeMap;

use crate::coordinator::{Method, RunRecord};
use crate::obs::TraceCtx;
use crate::search::SearchConfig;
use crate::util::jsonl;
use crate::util::Json;

/// Wire protocol version; bumped on incompatible message changes. The
/// coordinator refuses hellos from other versions (a worker from a
/// different build could silently disagree about job identity).
pub const PROTO_VERSION: u64 = 1;

/// The compact per-worker telemetry frame piggybacked on
/// `lease_request` lines. All counters are cumulative since worker
/// start, so a frame lost with its connection costs nothing — the next
/// one carries the full totals.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerTelemetry {
    /// The worker's self-reported name (matches its `hello`).
    pub name: String,
    /// Jobs completed (results sent, whether or not they were fresh).
    pub jobs: u64,
    /// Bytes this worker has written to the coordinator.
    pub tx_bytes: u64,
    /// Bytes this worker has read from the coordinator.
    pub rx_bytes: u64,
    /// Microseconds since the worker process started its run loop.
    pub uptime_us: u64,
}

impl WorkerTelemetry {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("jobs".to_string(), Json::Num(self.jobs as f64));
        m.insert("tx_bytes".to_string(), Json::Num(self.tx_bytes as f64));
        m.insert("rx_bytes".to_string(), Json::Num(self.rx_bytes as f64));
        m.insert("uptime_us".to_string(), Json::Num(self.uptime_us as f64));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<WorkerTelemetry, String> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| "telemetry: missing \"name\"".to_string())?
            .to_string();
        let num = |key: &str| {
            j.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("telemetry: missing \"{key}\""))
        };
        Ok(WorkerTelemetry {
            name,
            jobs: num("jobs")?,
            tx_bytes: num("tx_bytes")?,
            rx_bytes: num("rx_bytes")?,
            uptime_us: num("uptime_us")?,
        })
    }
}

/// A message from a worker to the coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkerMsg {
    Hello { name: String, proto: u64 },
    LeaseRequest { telemetry: Option<WorkerTelemetry> },
    Result { job: usize, record: RunRecord, trace_ctx: Option<TraceCtx> },
    Reject { job: usize, reason: String },
    /// Telemetry poll (allowed before `hello`): answer one
    /// [`CoordMsg::Status`] sample and keep the connection open.
    Status,
}

/// A coordinator response. Exactly one per worker message.
#[derive(Debug, Clone, PartialEq)]
pub enum CoordMsg {
    Welcome { jobs: usize, lease_ms: u64 },
    Lease {
        job: usize,
        bench: String,
        method: Method,
        et: u64,
        search: SearchConfig,
        trace_ctx: Option<TraceCtx>,
    },
    Wait { ms: u64 },
    Done,
    Committed { job: usize, fresh: bool },
    Requeued { job: usize },
    /// One cumulative telemetry sample (registry metrics plus the
    /// per-worker view), shaped for
    /// [`Sample::from_json`](crate::obs::Sample) consumption on the
    /// monitor side.
    Status { sample: Json },
    Error { error: String },
}

/// Parse an optional `trace_ctx` field: absent is `None`; present but
/// malformed is an error (a peer that sends one must send it right).
fn parse_trace_ctx(j: &Json, ty: &str) -> Result<Option<TraceCtx>, String> {
    match j.get("trace_ctx") {
        None => Ok(None),
        Some(ctx) => TraceCtx::from_json(ctx)
            .map(Some)
            .map_err(|e| format!("{ty}: bad trace_ctx: {e:#}")),
    }
}

impl WorkerMsg {
    pub fn render(&self) -> String {
        let mut m = BTreeMap::new();
        match self {
            WorkerMsg::Hello { name, proto } => {
                m.insert("type".to_string(), Json::Str("hello".to_string()));
                m.insert("name".to_string(), Json::Str(name.clone()));
                m.insert("proto".to_string(), Json::Num(*proto as f64));
            }
            WorkerMsg::LeaseRequest { telemetry } => {
                m.insert("type".to_string(), Json::Str("lease_request".to_string()));
                if let Some(t) = telemetry {
                    m.insert("telemetry".to_string(), t.to_json());
                }
            }
            WorkerMsg::Status => {
                m.insert("type".to_string(), Json::Str("status".to_string()));
            }
            WorkerMsg::Result { job, record, trace_ctx } => {
                m.insert("type".to_string(), Json::Str("result".to_string()));
                m.insert("job".to_string(), Json::Num(*job as f64));
                m.insert("record".to_string(), record.to_json());
                if let Some(ctx) = trace_ctx {
                    m.insert("trace_ctx".to_string(), ctx.to_json());
                }
            }
            WorkerMsg::Reject { job, reason } => {
                m.insert("type".to_string(), Json::Str("reject".to_string()));
                m.insert("job".to_string(), Json::Num(*job as f64));
                m.insert("reason".to_string(), Json::Str(reason.clone()));
            }
        }
        Json::Obj(m).render()
    }

    /// Parse one worker line; the error string is ready to embed in a
    /// structured error response.
    pub fn parse(line: &str) -> Result<WorkerMsg, String> {
        let j = Json::parse(line).map_err(|e| format!("bad JSON: {e:#}"))?;
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing \"type\" field".to_string())?;
        let job = || {
            j.get("job")
                .and_then(Json::as_u64)
                .map(|v| v as usize)
                .ok_or_else(|| format!("{ty}: missing \"job\" index"))
        };
        match ty {
            "hello" => Ok(WorkerMsg::Hello {
                name: j
                    .get("name")
                    .and_then(Json::as_str)
                    .unwrap_or("anonymous")
                    .to_string(),
                proto: j.get("proto").and_then(Json::as_u64).unwrap_or(0),
            }),
            "lease_request" => Ok(WorkerMsg::LeaseRequest {
                // Same contract as trace_ctx: absent is fine, a peer
                // that sends telemetry must send it well-formed.
                telemetry: match j.get("telemetry") {
                    None => None,
                    Some(t) => Some(WorkerTelemetry::from_json(t)?),
                },
            }),
            "status" => Ok(WorkerMsg::Status),
            "result" => Ok(WorkerMsg::Result {
                job: job()?,
                record: RunRecord::from_json(
                    j.get("record").ok_or_else(|| "result: missing \"record\"".to_string())?,
                )
                .map_err(|e| format!("result: bad record: {e:#}"))?,
                trace_ctx: parse_trace_ctx(&j, ty)?,
            }),
            "reject" => Ok(WorkerMsg::Reject {
                job: job()?,
                reason: j
                    .get("reason")
                    .and_then(Json::as_str)
                    .unwrap_or("unspecified")
                    .to_string(),
            }),
            other => Err(format!("unknown worker message type {other:?}")),
        }
    }
}

impl CoordMsg {
    pub fn render(&self) -> String {
        let mut m = BTreeMap::new();
        m.insert("ok".to_string(), Json::Bool(true));
        match self {
            CoordMsg::Welcome { jobs, lease_ms } => {
                m.insert("type".to_string(), Json::Str("welcome".to_string()));
                m.insert("jobs".to_string(), Json::Num(*jobs as f64));
                m.insert("lease_ms".to_string(), Json::Num(*lease_ms as f64));
            }
            CoordMsg::Lease { job, bench, method, et, search, trace_ctx } => {
                m.insert("type".to_string(), Json::Str("lease".to_string()));
                m.insert("job".to_string(), Json::Num(*job as f64));
                m.insert("bench".to_string(), Json::Str(bench.clone()));
                m.insert("method".to_string(), Json::Str(method.name().to_string()));
                m.insert("et".to_string(), Json::Num(*et as f64));
                m.insert("search".to_string(), search.to_json());
                if let Some(ctx) = trace_ctx {
                    m.insert("trace_ctx".to_string(), ctx.to_json());
                }
            }
            CoordMsg::Wait { ms } => {
                m.insert("type".to_string(), Json::Str("wait".to_string()));
                m.insert("ms".to_string(), Json::Num(*ms as f64));
            }
            CoordMsg::Done => {
                m.insert("type".to_string(), Json::Str("done".to_string()));
            }
            CoordMsg::Committed { job, fresh } => {
                m.insert("type".to_string(), Json::Str("committed".to_string()));
                m.insert("job".to_string(), Json::Num(*job as f64));
                m.insert("fresh".to_string(), Json::Bool(*fresh));
            }
            CoordMsg::Requeued { job } => {
                m.insert("type".to_string(), Json::Str("requeued".to_string()));
                m.insert("job".to_string(), Json::Num(*job as f64));
            }
            CoordMsg::Status { sample } => {
                m.insert("type".to_string(), Json::Str("status".to_string()));
                m.insert("sample".to_string(), sample.clone());
            }
            CoordMsg::Error { error } => {
                // The shared structured-error shape (no request ids in
                // this strict request/response protocol: id 0).
                return jsonl::error_line(0, error);
            }
        }
        Json::Obj(m).render()
    }

    /// Parse one coordinator line — the worker/client half.
    pub fn parse(line: &str) -> Result<CoordMsg, String> {
        let j = Json::parse(line).map_err(|e| format!("bad JSON: {e:#}"))?;
        let ok = j
            .get("ok")
            .and_then(Json::as_bool)
            .ok_or_else(|| "response missing \"ok\"".to_string())?;
        if !ok {
            return Ok(CoordMsg::Error {
                error: j
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("unspecified coordinator error")
                    .to_string(),
            });
        }
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .ok_or_else(|| "response missing \"type\"".to_string())?;
        let num = |key: &str| {
            j.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("{ty}: missing \"{key}\""))
        };
        match ty {
            "welcome" => Ok(CoordMsg::Welcome {
                jobs: num("jobs")? as usize,
                lease_ms: num("lease_ms")?,
            }),
            "lease" => Ok(CoordMsg::Lease {
                job: num("job")? as usize,
                bench: j
                    .get("bench")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "lease: missing \"bench\"".to_string())?
                    .to_string(),
                method: j
                    .get("method")
                    .and_then(Json::as_str)
                    .and_then(Method::from_name)
                    .ok_or_else(|| "lease: missing/unknown \"method\"".to_string())?,
                et: num("et")?,
                search: SearchConfig::from_json(
                    j.get("search").ok_or_else(|| "lease: missing \"search\"".to_string())?,
                )
                .map_err(|e| format!("lease: {e:#}"))?,
                trace_ctx: parse_trace_ctx(&j, ty)?,
            }),
            "wait" => Ok(CoordMsg::Wait { ms: num("ms")? }),
            "done" => Ok(CoordMsg::Done),
            "committed" => Ok(CoordMsg::Committed {
                job: num("job")? as usize,
                fresh: j.get("fresh").and_then(Json::as_bool).unwrap_or(false),
            }),
            "requeued" => Ok(CoordMsg::Requeued { job: num("job")? as usize }),
            "status" => Ok(CoordMsg::Status {
                sample: j
                    .get("sample")
                    .cloned()
                    .ok_or_else(|| "status: missing \"sample\"".to_string())?,
            }),
            other => Err(format!("unknown coordinator message type {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> RunRecord {
        RunRecord {
            bench: "adder_i4",
            method: Method::Shared,
            et: 2,
            area: 12.5,
            max_err: 2,
            mean_err: 0.75,
            proxy: (3, 4),
            elapsed_ms: 17,
            cached: false,
            values: vec![0, 1, 2, 3],
            all_points: vec![(3, 4, 12.5)],
            error: None,
        }
    }

    #[test]
    fn worker_messages_round_trip() {
        let msgs = [
            WorkerMsg::Hello { name: "w1".to_string(), proto: PROTO_VERSION },
            WorkerMsg::LeaseRequest { telemetry: None },
            WorkerMsg::LeaseRequest {
                telemetry: Some(WorkerTelemetry {
                    name: "w1".to_string(),
                    jobs: 12,
                    tx_bytes: 4096,
                    rx_bytes: 8192,
                    uptime_us: 1_500_000,
                }),
            },
            WorkerMsg::Status,
            WorkerMsg::Result { job: 3, record: record(), trace_ctx: None },
            WorkerMsg::Result {
                job: 4,
                record: record(),
                trace_ctx: Some(TraceCtx { node: "w1".to_string(), span: 17 }),
            },
            WorkerMsg::Reject { job: 9, reason: "unknown benchmark".to_string() },
        ];
        for m in msgs {
            let line = m.render();
            assert_eq!(line, m.render(), "deterministic rendering");
            assert_eq!(WorkerMsg::parse(&line).unwrap(), m);
        }
    }

    #[test]
    fn coordinator_messages_round_trip() {
        let msgs = [
            CoordMsg::Welcome { jobs: 8, lease_ms: 60_000 },
            CoordMsg::Lease {
                job: 3,
                bench: "adder_i4".to_string(),
                method: Method::Xpat,
                et: 2,
                search: SearchConfig::default(),
                trace_ctx: None,
            },
            CoordMsg::Lease {
                job: 5,
                bench: "adder_i4".to_string(),
                method: Method::Shared,
                et: 4,
                search: SearchConfig::default(),
                trace_ctx: Some(TraceCtx { node: "coord".to_string(), span: 42 }),
            },
            CoordMsg::Wait { ms: 500 },
            CoordMsg::Done,
            CoordMsg::Committed { job: 3, fresh: true },
            CoordMsg::Requeued { job: 9 },
            CoordMsg::Status {
                sample: Json::parse(
                    "{\"counters\":{\"dist_jobs_total\":3},\"gauges\":{},\
                     \"hists\":{},\"node\":\"coord\",\"seq\":0,\"ts_us\":12}",
                )
                .unwrap(),
            },
        ];
        for m in msgs {
            let line = m.render();
            assert_eq!(line, m.render(), "deterministic rendering");
            assert_eq!(CoordMsg::parse(&line).unwrap(), m);
        }
    }

    #[test]
    fn errors_use_the_shared_shape() {
        let line = CoordMsg::Error { error: "no such job".to_string() }.render();
        assert_eq!(line, "{\"error\":\"no such job\",\"id\":0,\"ok\":false}");
        match CoordMsg::parse(&line).unwrap() {
            CoordMsg::Error { error } => assert!(error.contains("no such job")),
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn malformed_trace_ctx_is_an_error_but_absent_is_fine() {
        // Untraced peers omit the field entirely: parses to None.
        let lease = CoordMsg::Lease {
            job: 1,
            bench: "adder_i4".to_string(),
            method: Method::Shared,
            et: 1,
            search: SearchConfig::default(),
            trace_ctx: None,
        };
        assert!(!lease.render().contains("trace_ctx"));
        // A present-but-malformed trace_ctx is a hard parse error.
        let bad = lease.render().replace(
            "\"type\":\"lease\"",
            "\"trace_ctx\":{\"node\":\"c\"},\"type\":\"lease\"",
        );
        assert!(CoordMsg::parse(&bad).unwrap_err().contains("trace_ctx"));
    }

    #[test]
    fn malformed_telemetry_is_an_error_but_absent_is_fine() {
        let bare = WorkerMsg::LeaseRequest { telemetry: None }.render();
        assert!(!bare.contains("telemetry"));
        assert_eq!(
            WorkerMsg::parse(&bare).unwrap(),
            WorkerMsg::LeaseRequest { telemetry: None }
        );
        let bad = bare.replace(
            "\"type\":\"lease_request\"",
            "\"telemetry\":{\"jobs\":1},\"type\":\"lease_request\"",
        );
        assert!(WorkerMsg::parse(&bad).unwrap_err().contains("telemetry"));
    }

    #[test]
    fn malformed_lines_are_string_errors() {
        assert!(WorkerMsg::parse("not json").is_err());
        assert!(WorkerMsg::parse("{\"type\":\"dance\"}").unwrap_err().contains("dance"));
        assert!(WorkerMsg::parse("{\"type\":\"result\",\"job\":1}")
            .unwrap_err()
            .contains("record"));
        assert!(CoordMsg::parse("{\"ok\":true}").is_err());
        assert!(CoordMsg::parse("{\"ok\":true,\"type\":\"lease\",\"job\":1}").is_err());
    }
}

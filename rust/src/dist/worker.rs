//! The worker node: connect to a coordinator, pull leases, run jobs
//! through the exact same execution path a local sweep uses
//! ([`run_job_with`] plus a per-process [`MiterCache`], so a worker
//! that runs ten same-geometry jobs encodes the miter once), stream
//! the records back.
//!
//! Workers are deliberately stateless and trustless-by-construction:
//! they never see the store (the coordinator is the single WAL
//! writer), every record they return is re-verified against the
//! coordinator's own oracle table, and a worker that dies mid-job
//! simply lets its lease expire. A panic inside a job is caught and
//! shipped back as the standard failure record — the same shape the
//! local pool produces — so one poisoned job cannot kill the worker.
//!
//! The coordinator tearing down (sweep finished while this worker was
//! still solving a requeued-elsewhere job) surfaces as EOF mid-loop;
//! that is a graceful end, not an error.

use std::io::BufReader;
use std::net::TcpStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::circuit::generators::benchmark_by_name;
use crate::circuit::sim::TruthTables;
use crate::coordinator::{failed_record, panic_message, run_job_obs, Job};
use crate::obs::{metrics, Obs};
use crate::search::MiterCache;
use crate::util::jsonl::{self, LineRead};
use crate::util::Json;

use super::protocol::{CoordMsg, WorkerMsg, WorkerTelemetry, PROTO_VERSION};

#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Coordinator address, e.g. `127.0.0.1:7979`.
    pub addr: String,
    /// Name reported in the hello (logs only; identity is the
    /// connection).
    pub name: String,
    /// Override the leased config's `cell_workers` with this node's
    /// core budget — determinism-neutral, so heterogeneous workers
    /// still produce identical records.
    pub cell_workers: Option<usize>,
    /// Disconnect after this many completed jobs (tests, canaries).
    pub max_jobs: Option<usize>,
    /// Trace handle (observe-only; `Obs::off()` records nothing).
    pub obs: Obs,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            addr: "127.0.0.1:7979".to_string(),
            name: format!("worker-{}", std::process::id()),
            cell_workers: None,
            max_jobs: None,
            obs: Obs::off(),
        }
    }
}

/// Wire-volume counters, registered once per `run_worker` call. The
/// registry counters are process-wide (in-process test workers share
/// them), so the telemetry frames this run piggybacks on its lease
/// requests report the run-local cells instead.
struct WireCounters {
    tx: metrics::Counter,
    rx: metrics::Counter,
    tx_local: AtomicU64,
    rx_local: AtomicU64,
}

#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    /// Jobs run to completion and submitted (including failure records).
    pub completed: usize,
    /// Submissions the coordinator discarded as duplicates (our lease
    /// had expired and another worker's commit won).
    pub stale: usize,
    /// Leases this worker refused (unknown benchmark etc.).
    pub rejected: usize,
    /// `wait` backoffs served.
    pub waits: usize,
}

/// One request/response exchange. `Ok(None)` means the coordinator is
/// gone (EOF / reset) — for a worker that is a graceful end of the
/// sweep, not an error.
fn exchange(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    msg: &WorkerMsg,
    wire: &WireCounters,
) -> Result<Option<CoordMsg>> {
    let line = msg.render();
    wire.tx.add(line.len() as u64 + 1);
    wire.tx_local.fetch_add(line.len() as u64 + 1, Ordering::Relaxed);
    if jsonl::send_line(writer, &line).is_err() {
        return Ok(None);
    }
    loop {
        return match jsonl::read_line(reader) {
            LineRead::Eof => Ok(None),
            LineRead::Oversized => bail!("oversized coordinator response line"),
            LineRead::Line(l) if l.is_empty() => continue,
            LineRead::Line(l) => {
                wire.rx.add(l.len() as u64 + 1);
                wire.rx_local.fetch_add(l.len() as u64 + 1, Ordering::Relaxed);
                match CoordMsg::parse(&l) {
                    Ok(m) => Ok(Some(m)),
                    Err(e) => bail!("bad coordinator response: {e}"),
                }
            }
        };
    }
}

/// Run one worker until the coordinator reports the sweep done (or
/// disconnects, or `max_jobs` is reached).
pub fn run_worker(cfg: &WorkerConfig) -> Result<WorkerStats> {
    let stream = TcpStream::connect(&cfg.addr)
        .with_context(|| format!("connecting to coordinator {}", cfg.addr))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut writer = stream;
    let mut stats = WorkerStats::default();
    let started = Instant::now();
    let wire = WireCounters {
        tx: metrics::counter("pallas_dist_worker_tx_bytes_total"),
        rx: metrics::counter("pallas_dist_worker_rx_bytes_total"),
        tx_local: AtomicU64::new(0),
        rx_local: AtomicU64::new(0),
    };
    let jobs_completed = metrics::counter("pallas_dist_worker_jobs_completed_total");

    let hello =
        WorkerMsg::Hello { name: cfg.name.clone(), proto: PROTO_VERSION };
    match exchange(&mut writer, &mut reader, &hello, &wire)? {
        Some(CoordMsg::Welcome { .. }) => {}
        Some(CoordMsg::Error { error }) => bail!("coordinator refused hello: {error}"),
        Some(other) => bail!("unexpected hello response: {other:?}"),
        None => bail!("coordinator {} hung up during hello", cfg.addr),
    }

    // One miter-prototype cache per worker process: same-geometry
    // leases clone instead of re-encoding, exactly as in a local sweep.
    let protos = MiterCache::new();
    loop {
        if cfg.max_jobs.is_some_and(|cap| stats.completed >= cap) {
            break;
        }
        // Piggyback the live telemetry frame on the natural heartbeat:
        // every lease request carries cumulative run-local totals.
        let lease_req = WorkerMsg::LeaseRequest {
            telemetry: Some(WorkerTelemetry {
                name: cfg.name.clone(),
                jobs: stats.completed as u64,
                tx_bytes: wire.tx_local.load(Ordering::Relaxed),
                rx_bytes: wire.rx_local.load(Ordering::Relaxed),
                uptime_us: started.elapsed().as_micros() as u64,
            }),
        };
        let Some(resp) = exchange(&mut writer, &mut reader, &lease_req, &wire)? else {
            break; // coordinator gone: sweep is over for us
        };
        match resp {
            CoordMsg::Lease { job: idx, bench, method, et, search, trace_ctx } => {
                let msg = match benchmark_by_name(&bench) {
                    None => {
                        stats.rejected += 1;
                        WorkerMsg::Reject {
                            job: idx,
                            reason: format!("unknown benchmark {bench:?}"),
                        }
                    }
                    Some(b) => {
                        let mut search = search;
                        if let Some(cw) = cfg.cell_workers {
                            search.cell_workers = cw.max(1);
                        }
                        let job = Job { bench: b, method, et, search };
                        let nl = job.bench.netlist();
                        let exact = TruthTables::simulate(&nl).output_values(&nl);
                        // Parent this job's span under the
                        // coordinator's lease span when the lease
                        // carried a trace context, so the merged trace
                        // shows one causal tree per job across nodes.
                        let job_obs = match trace_ctx.as_ref() {
                            Some(ctx) => cfg.obs.child_of_ctx(ctx),
                            None => cfg.obs.clone(),
                        };
                        let mut span = job_obs.span(
                            "dist.job",
                            &[
                                ("job", Json::Num(idx as f64)),
                                ("bench", Json::Str(job.bench.name.to_string())),
                                ("method", Json::Str(job.method.name().to_string())),
                                ("et", Json::Num(job.et as f64)),
                            ],
                        );
                        let span_ctx = span.ctx();
                        let inner_obs = job_obs.child_of(&span);
                        let record =
                            catch_unwind(AssertUnwindSafe(|| {
                                run_job_obs(&job, &protos, &exact, &inner_obs)
                            }))
                            .unwrap_or_else(|p| failed_record(&job, panic_message(p)));
                        span.field("ok", Json::Bool(record.error.is_none()));
                        span.finish();
                        stats.completed += 1;
                        jobs_completed.inc();
                        let mut msg = WorkerMsg::Result {
                            job: idx,
                            record,
                            trace_ctx: span_ctx.clone(),
                        };
                        // A record too large for the wire discipline
                        // would livelock the sweep (oversized line →
                        // dropped connection → requeue → the identical
                        // line again, forever). Fail the job visibly
                        // instead; it can still run in a local sweep,
                        // whose WAL path has no line cap.
                        let line_len = msg.render().len();
                        if line_len > jsonl::MAX_LINE_BYTES {
                            let why = format!(
                                "result of {line_len} bytes exceeds the {}-byte wire \
                                 cap (huge all_points/values?); run this job locally",
                                jsonl::MAX_LINE_BYTES
                            );
                            cfg.obs.warn(
                                "dist.worker",
                                &format!("job {idx}: {why}"),
                                &[("job", Json::Num(idx as f64))],
                            );
                            msg = WorkerMsg::Result {
                                job: idx,
                                record: failed_record(&job, why),
                                trace_ctx: span_ctx,
                            };
                        }
                        msg
                    }
                };
                match exchange(&mut writer, &mut reader, &msg, &wire)? {
                    None => break,
                    Some(CoordMsg::Committed { fresh, .. }) => {
                        if !fresh {
                            stats.stale += 1;
                        }
                    }
                    Some(CoordMsg::Requeued { .. }) => {}
                    Some(CoordMsg::Error { error }) => {
                        // E.g. our record failed the coordinator's
                        // oracle re-check; the job was requeued. Keep
                        // serving — the coordinator decides our fate.
                        cfg.obs.warn(
                            "dist.worker",
                            &format!("coordinator: {error}"),
                            &[],
                        );
                    }
                    Some(other) => bail!("unexpected result response: {other:?}"),
                }
            }
            CoordMsg::Wait { ms } => {
                stats.waits += 1;
                std::thread::sleep(Duration::from_millis(ms.min(5_000)));
            }
            CoordMsg::Done => break,
            CoordMsg::Error { error } => bail!("coordinator error: {error}"),
            other => bail!("unexpected lease response: {other:?}"),
        }
    }
    if let Err(e) = cfg.obs.flush() {
        cfg.obs.warn("dist.worker", &format!("trace flush failed: {e:#}"), &[]);
    }
    Ok(stats)
}

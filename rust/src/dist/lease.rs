//! The lease scheduler: the coordinator's entire scheduling brain as a
//! pure state machine — no sockets, no threads, no clock of its own
//! (every time-dependent transition takes `now` as an argument) — so
//! each transition is unit-testable without networking.
//!
//! Job lifecycle:
//!
//! ```text
//!            park()                 grant()
//!  iterator ───────► ready ───────────────────► active lease
//!     │                ▲                        │  │      │
//!     │ cache hit      │ requeue: expire(),     │  │      └ submit() sound
//!     ▼                │ fail_conn(), reject()  │  │        ─► resolved slot
//!  commit_local()      └────────────────────────┘  └ reject() × REJECT_CAP
//!     ─► resolved slot                               ─► resolved slot (failed)
//! ```
//!
//! Invariants the tests pin:
//!
//! * **At most one active lease per job.** A requeued job's original
//!   worker may still finish; whichever *sound* result reaches
//!   [`Scheduler::submit`] first wins the slot, every later submission
//!   is [`Submission::Stale`] — and the WAL dedup
//!   (`Store::append_if_absent`) makes the same guarantee a second
//!   time at the fingerprint level.
//! * **In-order commit.** [`CommitEvent`]s are emitted by a frontier
//!   walk: events for job *i* appear only after every job *< i* holds
//!   a record, so the coordinator's WAL line order equals a
//!   single-worker local sweep's regardless of completion order —
//!   the same trick as the lattice scan's in-order cell commit.
//! * **Unsound results never commit.** A record that fails the oracle
//!   re-check (or contradicts its lease's job identity) requeues the
//!   job instead; trusting a worker's arithmetic is not required, only
//!   its liveness.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{failed_record, wal_persistable, Job, RunRecord};
use crate::store::Fingerprint;

/// Rejections (worker says "cannot run this lease") tolerated per job
/// before the coordinator fails the job locally instead of bouncing it
/// between version-skewed workers forever.
pub const REJECT_CAP: usize = 3;

/// One job fully prepared for scheduling: its sweep-order index, the
/// job itself, the exhaustive oracle table (fingerprint input and
/// soundness check), and — when a store is attached — the fingerprint
/// plus whether a stored-but-unsound record must be healed by a
/// last-writer-wins overwrite.
pub struct PreparedJob {
    pub idx: usize,
    pub job: Job,
    pub exact: Arc<Vec<u64>>,
    pub fp: Option<Fingerprint>,
    pub heal: bool,
}

/// A granted lease, ready to render as a wire message.
pub struct LeaseGrant {
    pub idx: usize,
    pub job: Job,
}

struct ActiveLease {
    prepared: PreparedJob,
    conn: u64,
    deadline: Instant,
}

/// One record the coordinator must persist now, in WAL order.
pub struct CommitEvent {
    pub idx: usize,
    pub record: RunRecord,
    pub fp: Fingerprint,
    /// `true`: overwrite last-writer-wins (healing an unsound stored
    /// record); `false`: append only if absent (duplicate dedup).
    pub heal: bool,
}

/// Outcome of a worker's result submission.
pub enum Submission {
    /// First completion of the job — the slot is filled; `.0` holds
    /// any WAL commits the frontier walk released.
    Fresh(Vec<CommitEvent>),
    /// The job was already resolved (expired lease, another worker
    /// won): correct protocol behaviour, nothing to do.
    Stale,
    /// The record failed the oracle re-check or contradicted the
    /// lease; the job has been requeued for another worker.
    Unsound(String),
}

/// Outcome of a worker's lease rejection.
pub enum Rejection {
    /// Requeued for another worker.
    Requeued,
    /// `REJECT_CAP` workers refused: failed locally, slot filled.
    FailedOut(Vec<CommitEvent>),
    /// The job is no longer this worker's to reject.
    Stale,
}

struct Slot {
    record: RunRecord,
    /// Pending persistence, consumed by the frontier walk. `None` for
    /// records that never touch the WAL (cache hits, failures,
    /// wall-clock-truncated results, storeless sweeps).
    persist: Option<(Fingerprint, bool)>,
}

pub struct Scheduler {
    lease: Duration,
    /// At most one freshly pulled job parked by the coordinator
    /// ([`Scheduler::park`]) — the pull-based iteration contract.
    ready: Option<PreparedJob>,
    /// Jobs bounced off a dead/slow/rejecting worker, ready to re-grant.
    requeue: VecDeque<PreparedJob>,
    active: HashMap<usize, ActiveLease>,
    rejects: HashMap<usize, usize>,
    slots: Vec<Option<Slot>>,
    resolved: usize,
    /// First index whose slot is still empty — the WAL commit frontier.
    frontier: usize,
}

impl Scheduler {
    pub fn new(n_jobs: usize, lease: Duration) -> Scheduler {
        Scheduler {
            lease,
            ready: None,
            requeue: VecDeque::new(),
            active: HashMap::new(),
            rejects: HashMap::new(),
            slots: (0..n_jobs).map(|_| None).collect(),
            resolved: 0,
            frontier: 0,
        }
    }

    pub fn n_jobs(&self) -> usize {
        self.slots.len()
    }

    pub fn done(&self) -> bool {
        self.resolved == self.slots.len()
    }

    pub fn resolved(&self) -> usize {
        self.resolved
    }

    /// Resolved slots the in-order commit frontier has not yet released
    /// — how far completed work is backed up behind an earlier job that
    /// is still out on lease. Observe-only: the coordinator gauges it
    /// after every scheduling step; nothing reads it back.
    pub fn frontier_lag(&self) -> usize {
        self.resolved.saturating_sub(self.frontier)
    }

    pub fn in_flight(&self) -> usize {
        self.active.len()
    }

    /// The coordinator should pull the next job off the plan iterator
    /// exactly when nothing is leasable without it.
    pub fn needs_fresh(&self) -> bool {
        self.ready.is_none() && self.requeue.is_empty()
    }

    /// Park one freshly pulled job for the next grant. At most one job
    /// is ever parked — callers pull only when [`needs_fresh`] says so.
    ///
    /// [`needs_fresh`]: Scheduler::needs_fresh
    pub fn park(&mut self, prepared: PreparedJob) {
        debug_assert!(self.ready.is_none(), "park() over an unleased parked job");
        self.ready = Some(prepared);
    }

    /// Resolve a job locally, without a lease: store cache hits and
    /// reject-capped failures. `persist` is `Some` only when a WAL
    /// line must be written once the frontier reaches the job.
    pub fn commit_local(
        &mut self,
        idx: usize,
        record: RunRecord,
        persist: Option<(Fingerprint, bool)>,
    ) -> Vec<CommitEvent> {
        debug_assert!(self.slots[idx].is_none(), "job {idx} resolved twice");
        self.slots[idx] = Some(Slot { record, persist });
        self.resolved += 1;
        self.advance_frontier()
    }

    /// Grant a lease to `conn`: requeued jobs first (they block the
    /// commit frontier, and their prepared state is already paid for),
    /// then the parked fresh job.
    pub fn grant(&mut self, conn: u64, now: Instant) -> Option<LeaseGrant> {
        let prepared = self.requeue.pop_front().or_else(|| self.ready.take())?;
        let grant = LeaseGrant { idx: prepared.idx, job: prepared.job.clone() };
        self.active.insert(
            prepared.idx,
            ActiveLease { prepared, conn, deadline: now + self.lease },
        );
        Some(grant)
    }

    /// A worker finished job `idx`. First sound submission wins the
    /// slot whether or not the submitter still holds the lease (its
    /// lease may have expired and been requeued — the work is done
    /// either way); everything later is stale.
    pub fn submit(&mut self, idx: usize, record: RunRecord, conn: u64) -> Submission {
        if idx >= self.slots.len() {
            return Submission::Unsound(format!("job index {idx} out of range"));
        }
        if self.slots[idx].is_some() {
            return Submission::Stale;
        }
        // The prepared state lives in the active lease or (after an
        // expiry) back in the requeue; a submission for a job in
        // neither place never had a lease at all.
        let prepared = if let Some(l) = self.active.get(&idx) {
            &l.prepared
        } else if let Some(p) = self.requeue.iter().find(|p| p.idx == idx) {
            p
        } else {
            return Submission::Unsound(format!("job {idx} was never leased"));
        };

        if let Err(why) = validate_record(&prepared.job, &prepared.exact, &record) {
            // A lease that produced garbage is over: bounce the job to
            // another worker — but ONLY if the garbage came from the
            // lease's current holder. A stale worker (expired lease,
            // job since re-granted) submitting junk must not yank the
            // live holder's lease and spawn duplicate grants.
            if self.active.get(&idx).is_some_and(|l| l.conn == conn) {
                let l = self.active.remove(&idx).unwrap();
                self.requeue.push_back(l.prepared);
            }
            return Submission::Unsound(why);
        }

        let persist = self
            .active
            .get(&idx)
            .map(|l| &l.prepared)
            .or_else(|| self.requeue.iter().find(|p| p.idx == idx))
            .and_then(|p| persistable(p, &record));
        self.active.remove(&idx);
        self.requeue.retain(|p| p.idx != idx);
        Submission::Fresh(self.commit_local(idx, record, persist))
    }

    /// A worker refused a lease it was granted.
    pub fn reject(&mut self, idx: usize, conn: u64, reason: &str) -> Rejection {
        match self.active.get(&idx) {
            Some(l) if l.conn == conn => {}
            // Expired/re-granted/resolved: nothing of this worker's to
            // reject any more.
            _ => return Rejection::Stale,
        }
        let l = self.active.remove(&idx).unwrap();
        let count = self.rejects.entry(idx).or_insert(0);
        *count += 1;
        if *count >= REJECT_CAP {
            let rec = failed_record(
                &l.prepared.job,
                format!("rejected by {REJECT_CAP} workers (last: {reason})"),
            );
            // Failures are never persisted: a resumed sweep retries.
            Rejection::FailedOut(self.commit_local(idx, rec, None))
        } else {
            self.requeue.push_back(l.prepared);
            Rejection::Requeued
        }
    }

    /// A connection died: every lease it held goes back to the queue.
    /// Returns the requeued job indices (for logging).
    pub fn fail_conn(&mut self, conn: u64) -> Vec<usize> {
        let idxs: Vec<usize> = self
            .active
            .iter()
            .filter(|(_, l)| l.conn == conn)
            .map(|(&idx, _)| idx)
            .collect();
        for &idx in &idxs {
            let l = self.active.remove(&idx).unwrap();
            self.requeue.push_back(l.prepared);
        }
        idxs
    }

    /// Requeue every lease whose deadline has passed (worker wedged,
    /// network black hole, job slower than the lease). Returns the
    /// expired job indices.
    pub fn expire(&mut self, now: Instant) -> Vec<usize> {
        let idxs: Vec<usize> = self
            .active
            .iter()
            .filter(|(_, l)| now >= l.deadline)
            .map(|(&idx, _)| idx)
            .collect();
        for &idx in &idxs {
            let l = self.active.remove(&idx).unwrap();
            self.requeue.push_back(l.prepared);
        }
        idxs
    }

    /// The finished record set, in job order. Callable only when
    /// [`Scheduler::done`].
    pub fn into_records(self) -> Vec<RunRecord> {
        assert!(self.resolved == self.slots.len(), "into_records before done");
        self.slots
            .into_iter()
            .map(|s| s.expect("done scheduler has every slot filled").record)
            .collect()
    }

    fn advance_frontier(&mut self) -> Vec<CommitEvent> {
        let mut out = Vec::new();
        while self.frontier < self.slots.len() {
            let Some(slot) = self.slots[self.frontier].as_mut() else { break };
            if let Some((fp, heal)) = slot.persist.take() {
                out.push(CommitEvent {
                    idx: self.frontier,
                    record: slot.record.clone(),
                    fp,
                    heal,
                });
            }
            self.frontier += 1;
        }
        out
    }
}

/// A worker-supplied record must describe the leased job and — when it
/// claims an operator — re-verify against the exhaustive oracle. The
/// coordinator trusts workers' liveness, never their arithmetic (the
/// same defence-in-depth as every other serving path in the tree).
fn validate_record(job: &Job, exact: &[u64], rec: &RunRecord) -> Result<(), String> {
    if rec.bench != job.bench.name || rec.method != job.method || rec.et != job.et {
        return Err(format!(
            "record identity ({} {} et={}) does not match the lease ({} {} et={})",
            rec.bench,
            rec.method.name(),
            rec.et,
            job.bench.name,
            job.method.name(),
            job.et
        ));
    }
    if rec.error.is_none() && rec.area.is_finite() {
        if rec.values.len() != exact.len() {
            return Err(format!(
                "operator table has {} entries, oracle has {}",
                rec.values.len(),
                exact.len()
            ));
        }
        if let Some(i) =
            (0..exact.len()).find(|&i| exact[i].abs_diff(rec.values[i]) > job.et)
        {
            return Err(format!(
                "operator unsound at input {i}: |{} - {}| > et {}",
                exact[i], rec.values[i], job.et
            ));
        }
    }
    Ok(())
}

/// Should this fresh record be written to the WAL once the frontier
/// reaches it? The rule itself is the shared
/// [`wal_persistable`](crate::coordinator::wal_persistable) — exactly
/// `run_sweep_stored`'s — plus the dist-only heal bit: a job whose
/// stored record failed oracle re-verification overwrites it
/// last-writer-wins instead of deduping on fingerprint.
fn persistable(p: &PreparedJob, rec: &RunRecord) -> Option<(Fingerprint, bool)> {
    let fp = p.fp?;
    if wal_persistable(rec, p.job.search.time_budget_ms) {
        Some((fp, p.heal))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::benchmark_by_name;
    use crate::coordinator::Method;
    use crate::search::SearchConfig;

    const LEASE: Duration = Duration::from_millis(500);

    fn prepared(idx: usize, et: u64) -> PreparedJob {
        let bench = benchmark_by_name("adder_i4").unwrap();
        PreparedJob {
            idx,
            job: Job { bench, method: Method::Shared, et, search: SearchConfig::default() },
            exact: Arc::new(vec![0, 1, 2, 3]),
            fp: Some(Fingerprint(100 + idx as u64)),
            heal: false,
        }
    }

    fn sound_record(p: &PreparedJob) -> RunRecord {
        RunRecord {
            bench: p.job.bench.name,
            method: p.job.method,
            et: p.job.et,
            area: 10.0,
            max_err: p.job.et,
            mean_err: 0.5,
            proxy: (1, 1),
            elapsed_ms: 5,
            cached: false,
            values: vec![0, 1, 2, 3],
            all_points: Vec::new(),
            error: None,
        }
    }

    fn now() -> Instant {
        Instant::now()
    }

    #[test]
    fn grant_submit_resolves_in_order() {
        let mut s = Scheduler::new(2, LEASE);
        assert!(s.needs_fresh());
        s.park(prepared(0, 2));
        assert!(!s.needs_fresh());
        let g0 = s.grant(1, now()).unwrap();
        assert_eq!(g0.idx, 0);
        assert!(s.grant(1, now()).is_none(), "nothing else leasable");
        s.park(prepared(1, 2));
        let g1 = s.grant(2, now()).unwrap();
        assert_eq!(g1.idx, 1);

        // Out-of-order completion: job 1 first — no commits released.
        let rec1 = sound_record(&prepared(1, 2));
        match s.submit(1, rec1, 2) {
            Submission::Fresh(events) => assert!(events.is_empty(), "frontier blocked"),
            _ => panic!("expected fresh"),
        }
        // Job 0 lands: both WAL commits release, in index order.
        let rec0 = sound_record(&prepared(0, 2));
        match s.submit(0, rec0, 1) {
            Submission::Fresh(events) => {
                assert_eq!(events.len(), 2);
                assert_eq!(events[0].idx, 0);
                assert_eq!(events[1].idx, 1);
                assert!(!events[0].heal);
            }
            _ => panic!("expected fresh"),
        }
        assert!(s.done());
        let recs = s.into_records();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].et, 2);
    }

    #[test]
    fn expired_lease_requeues_and_first_committed_wins() {
        let mut s = Scheduler::new(1, Duration::from_millis(0));
        s.park(prepared(0, 2));
        let t0 = now();
        s.grant(1, t0).unwrap();
        // Zero-length lease: immediately expired.
        assert_eq!(s.expire(t0 + Duration::from_millis(1)), vec![0]);
        assert_eq!(s.in_flight(), 0);
        // The original worker still finishes first — accepted.
        match s.submit(0, sound_record(&prepared(0, 2)), 1) {
            Submission::Fresh(events) => assert_eq!(events.len(), 1),
            _ => panic!("first sound submission must win"),
        }
        // The requeue entry is gone: no second grant, and the job is
        // not leasable again.
        assert!(s.grant(2, now()).is_none());
        // A late duplicate (the re-granted worker, had there been one)
        // is stale.
        assert!(matches!(
            s.submit(0, sound_record(&prepared(0, 2)), 2),
            Submission::Stale
        ));
        assert!(s.done());
    }

    #[test]
    fn dead_connection_requeues_all_its_leases() {
        let mut s = Scheduler::new(2, LEASE);
        s.park(prepared(0, 2));
        s.grant(7, now()).unwrap();
        s.park(prepared(1, 2));
        s.grant(7, now()).unwrap();
        let mut lost = s.fail_conn(7);
        lost.sort_unstable();
        assert_eq!(lost, vec![0, 1]);
        // Both jobs re-grantable to a healthy worker.
        assert!(s.grant(8, now()).is_some());
        assert!(s.grant(8, now()).is_some());
        assert!(s.grant(8, now()).is_none());
    }

    #[test]
    fn unsound_results_requeue_instead_of_committing() {
        let mut s = Scheduler::new(1, LEASE);
        s.park(prepared(0, 2));
        s.grant(1, now()).unwrap();
        // Unsound values: off by more than et at input 0.
        let mut bad = sound_record(&prepared(0, 2));
        bad.values = vec![99, 1, 2, 3];
        match s.submit(0, bad, 1) {
            Submission::Unsound(why) => assert!(why.contains("unsound"), "{why}"),
            _ => panic!("unsound record must not commit"),
        }
        assert!(!s.done());
        // Identity mismatch is also refused.
        let g = s.grant(2, now()).unwrap();
        assert_eq!(g.idx, 0);
        let mut wrong = sound_record(&prepared(0, 2));
        wrong.et = 5;
        assert!(matches!(s.submit(0, wrong, 2), Submission::Unsound(_)));
        // A sound result finally lands.
        s.grant(3, now()).unwrap();
        assert!(matches!(s.submit(0, sound_record(&prepared(0, 2)), 3), Submission::Fresh(_)));
        assert!(s.done());
    }

    #[test]
    fn stale_unsound_submission_leaves_the_live_lease_alone() {
        let mut s = Scheduler::new(1, Duration::from_millis(0));
        s.park(prepared(0, 2));
        let t0 = now();
        s.grant(1, t0).unwrap(); // worker A
        s.expire(t0 + Duration::from_millis(1)); // A's lease expires
        s.grant(2, now()).unwrap(); // re-granted to worker B
        // Stale A submits garbage: B's live lease must survive, and no
        // duplicate grant may spawn.
        let mut bad = sound_record(&prepared(0, 2));
        bad.values = vec![99, 1, 2, 3];
        assert!(matches!(s.submit(0, bad, 1), Submission::Unsound(_)));
        assert_eq!(s.in_flight(), 1, "B's live lease untouched");
        assert!(s.grant(3, now()).is_none(), "no duplicate grant spawned");
        // B still completes the job.
        assert!(matches!(
            s.submit(0, sound_record(&prepared(0, 2)), 2),
            Submission::Fresh(_)
        ));
        assert!(s.done());
    }

    #[test]
    fn reject_cap_fails_the_job_locally() {
        let mut s = Scheduler::new(1, LEASE);
        s.park(prepared(0, 2));
        for attempt in 0..REJECT_CAP {
            let g = s.grant(attempt as u64, now()).unwrap();
            assert_eq!(g.idx, 0);
            match s.reject(0, attempt as u64, "unknown benchmark") {
                Rejection::Requeued => assert!(attempt + 1 < REJECT_CAP),
                Rejection::FailedOut(events) => {
                    assert_eq!(attempt + 1, REJECT_CAP);
                    assert!(events.is_empty(), "failures are never persisted");
                }
                Rejection::Stale => panic!("live lease cannot be stale"),
            }
        }
        assert!(s.done());
        let recs = s.into_records();
        assert!(recs[0].area.is_infinite());
        assert!(recs[0].error.as_deref().unwrap().contains("rejected"));
    }

    #[test]
    fn failures_and_timeouts_are_not_persisted() {
        let p = prepared(0, 2);
        let mut failed = sound_record(&p);
        failed.error = Some("boom".to_string());
        failed.area = f64::INFINITY;
        assert!(persistable(&p, &failed).is_none());

        let mut truncated = sound_record(&p);
        truncated.elapsed_ms = p.job.search.time_budget_ms;
        assert!(persistable(&p, &truncated).is_none(), "deadline-bound template result");

        let good = sound_record(&p);
        assert_eq!(persistable(&p, &good), Some((p.fp.unwrap(), false)));

        let mut storeless = prepared(0, 2);
        storeless.fp = None;
        assert!(persistable(&storeless, &good).is_none());

        let mut healing = prepared(0, 2);
        healing.heal = true;
        assert_eq!(persistable(&healing, &good), Some((healing.fp.unwrap(), true)));
    }
}

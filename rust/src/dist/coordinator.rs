//! The coordinator node: owns the plan, the store and the scheduler;
//! serves leases to workers over TCP and is the **single WAL writer**.
//!
//! Threading model (everything under one `std::thread::scope`, so
//! `run` borrows the plan and store without `Arc`):
//!
//! * the **accept loop** takes connections and spawns one connection
//!   thread each (strict request/response: the connection thread both
//!   reads and writes, no per-connection writer thread needed);
//! * a **reaper** ticks a few times per lease period and requeues
//!   expired leases;
//! * the **main thread** parks on a condvar until every job is
//!   resolved, then tears the fabric down: connection sockets are
//!   `shutdown()` (unblocking their readers at EOF) and a throwaway
//!   self-connection unblocks the accept loop — no read timeouts, no
//!   detached threads.
//!
//! Job flow is pull-based end to end: the plan's lazy `job_iter` is
//! only advanced when the scheduler has nothing leasable, each pulled
//! job is probed against the store (cache hits commit locally and
//! never cross the wire, exactly like `run_sweep_stored`), and at most
//! one prepared miss is parked awaiting the next lease request.
//!
//! Determinism: worker records pass the same oracle re-verification as
//! local results, slots collect in job order, and WAL lines are
//! released by the scheduler's in-order commit frontier — so both the
//! record vector and the WAL are byte-identical (modulo `elapsed_ms`)
//! to a single-worker local `run_sweep_stored`, regardless of worker
//! count, completion order, worker deaths or lease expiries.

use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{probe_store, Job, RunRecord, SweepPlan};
use crate::obs::timeseries::{self, Clock, MonotonicClock};
use crate::obs::{metrics, Obs, Span, TraceCtx};
use crate::store::Store;
use crate::util::jsonl::{self, LineRead};
use crate::util::Json;

use super::lease::{CommitEvent, PreparedJob, Rejection, Scheduler, Submission};
use super::protocol::{CoordMsg, WorkerMsg, WorkerTelemetry, PROTO_VERSION};

#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Bind address; `127.0.0.1:0` picks an ephemeral port (tests).
    pub addr: String,
    /// Lease length in milliseconds; 0 = auto (twice the plan's
    /// per-job wall-clock budget plus slack, so a lease only expires
    /// on a genuinely wedged worker).
    pub lease_ms: u64,
    /// Backoff hint handed to workers when nothing is leasable yet.
    pub wait_ms: u64,
    /// Trace handle (observe-only; `Obs::off()` records nothing).
    pub obs: Obs,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            addr: "127.0.0.1:7979".to_string(),
            lease_ms: 0,
            wait_ms: 500,
            obs: Obs::off(),
        }
    }
}

/// Cached handles into the process-wide metrics registry: registration
/// takes the registry lock, so it happens once here and the hot paths
/// touch only atomics.
struct CoordMetrics {
    leases_granted: metrics::Counter,
    leases_expired: metrics::Counter,
    jobs_requeued: metrics::Counter,
    results_committed: metrics::Counter,
    results_stale: metrics::Counter,
    results_unsound: metrics::Counter,
    rx_bytes: metrics::Counter,
    tx_bytes: metrics::Counter,
    frontier_lag: metrics::Gauge,
}

impl CoordMetrics {
    fn new() -> CoordMetrics {
        CoordMetrics {
            leases_granted: metrics::counter("pallas_dist_leases_granted_total"),
            leases_expired: metrics::counter("pallas_dist_leases_expired_total"),
            jobs_requeued: metrics::counter("pallas_dist_jobs_requeued_total"),
            results_committed: metrics::counter("pallas_dist_results_committed_total"),
            results_stale: metrics::counter("pallas_dist_results_stale_total"),
            results_unsound: metrics::counter("pallas_dist_results_unsound_total"),
            rx_bytes: metrics::counter("pallas_dist_coord_rx_bytes_total"),
            tx_bytes: metrics::counter("pallas_dist_coord_tx_bytes_total"),
            frontier_lag: metrics::gauge("pallas_dist_commit_frontier_lag"),
        }
    }
}

/// A bound-but-not-yet-running coordinator. Splitting `bind` from
/// [`Coordinator::run`] lets callers (tests, the in-process bench)
/// learn the ephemeral port before blocking.
pub struct Coordinator<'a> {
    plan: &'a SweepPlan,
    store: Option<&'a Store>,
    listener: TcpListener,
    addr: SocketAddr,
    lease_ms: u64,
    wait_ms: u64,
    obs: Obs,
}

/// Scheduler plus the lazy job feed, guarded by one mutex: every
/// scheduling decision and every WAL append happens under it, which is
/// what makes the commit frontier's ordering guarantee hold.
struct SchedState<'a> {
    sched: Scheduler,
    feed: Box<dyn Iterator<Item = (usize, Job)> + Send + 'a>,
    exhausted: bool,
}

struct Shared<'a> {
    sched: Mutex<SchedState<'a>>,
    all_done: Condvar,
    shutting_down: AtomicBool,
    /// One clone per *live* connection, for teardown shutdown; each
    /// entry is removed when its connection thread exits, so churning
    /// short-lived workers cannot accumulate file descriptors.
    conns: Mutex<std::collections::HashMap<u64, TcpStream>>,
    next_conn: AtomicU64,
    store: Option<&'a Store>,
    n_jobs: usize,
    lease_ms: u64,
    wait_ms: u64,
    obs: Obs,
    /// Open `dist.lease` span per leased job (tracing only; empty when
    /// untraced). A span opens at grant and ends — with a `status`
    /// field saying how — on commit, rejection, expiry, connection
    /// death, supersession by a re-grant, or teardown. Its [`TraceCtx`]
    /// rides the `lease` verb so the worker's `dist.job` span nests
    /// under it across machines.
    lease_spans: Mutex<std::collections::HashMap<usize, Span>>,
    /// Live per-worker telemetry (name → last frame), served back out
    /// through the `status` verb.
    workers: Mutex<std::collections::BTreeMap<String, WorkerView>>,
    /// Monotonic clock for telemetry timestamps (`status` samples,
    /// worker `last_seen` ages).
    clock: MonotonicClock,
    mx: CoordMetrics,
}

/// The coordinator's live view of one worker, refreshed by the
/// telemetry frame each `lease_request` piggybacks. Keyed by the
/// worker's self-reported name; counters are cumulative, so staleness
/// is judged by `last_seen_us`, not by missing frames.
struct WorkerView {
    telemetry: WorkerTelemetry,
    /// Coordinator-clock timestamp of the last frame.
    last_seen_us: u64,
}

/// End the open lease span for `job` (if traced) with a terminal
/// `status`, optionally recording the worker job-span identity the
/// `result` verb carried back.
fn end_lease_span(
    shared: &Shared<'_>,
    job: usize,
    status: &str,
    worker: Option<&TraceCtx>,
) {
    if !shared.obs.enabled() {
        return;
    }
    if let Some(mut span) = shared.lease_spans.lock().unwrap().remove(&job) {
        span.field("status", Json::Str(status.to_string()));
        if let Some(ctx) = worker {
            span.field("worker_node", Json::Str(ctx.node.clone()));
            span.field("worker_span", Json::Num(ctx.span as f64));
        }
    }
}

impl<'a> Coordinator<'a> {
    pub fn bind(
        plan: &'a SweepPlan,
        store: Option<&'a Store>,
        cfg: &DistConfig,
    ) -> Result<Coordinator<'a>> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding coordinator on {}", cfg.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let lease_ms = if cfg.lease_ms == 0 {
            plan.search.time_budget_ms.saturating_mul(2).saturating_add(30_000)
        } else {
            cfg.lease_ms
        };
        Ok(Coordinator {
            plan,
            store,
            listener,
            addr,
            lease_ms,
            wait_ms: cfg.wait_ms,
            obs: cfg.obs.clone(),
        })
    }

    /// The actually-bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve the sweep to completion and return the records in job
    /// order. Blocks until every job is resolved — with no workers
    /// connected, cache hits still resolve locally, and the call waits
    /// for workers to show up for the rest.
    pub fn run(self) -> Result<Vec<RunRecord>> {
        let Coordinator { plan, store, listener, addr, lease_ms, wait_ms, obs } = self;
        let n_jobs = plan.n_jobs();
        let shared = Shared {
            sched: Mutex::new(SchedState {
                sched: Scheduler::new(n_jobs, Duration::from_millis(lease_ms)),
                feed: Box::new(plan.job_iter().enumerate()),
                exhausted: false,
            }),
            all_done: Condvar::new(),
            shutting_down: AtomicBool::new(false),
            conns: Mutex::new(std::collections::HashMap::new()),
            next_conn: AtomicU64::new(1),
            store,
            n_jobs,
            lease_ms,
            wait_ms,
            obs,
            lease_spans: Mutex::new(std::collections::HashMap::new()),
            workers: Mutex::new(std::collections::BTreeMap::new()),
            clock: MonotonicClock::new(),
            mx: CoordMetrics::new(),
        };
        shared.obs.info(
            "dist.coordinator",
            "serving sweep",
            &[
                ("addr", Json::Str(addr.to_string())),
                ("jobs", Json::Num(n_jobs as f64)),
                ("lease_ms", Json::Num(lease_ms as f64)),
            ],
        );

        // Pre-drain: commit every leading cache hit and park the first
        // miss before any worker connects, so an all-cached plan
        // finishes with zero workers.
        refill(&shared, &mut shared.sched.lock().unwrap());

        std::thread::scope(|s| {
            // `s` is Copy; spawned closures capture it (and plain
            // references to the locals) by value, because the accept
            // thread can outlive this closure's body — it only stops
            // at the teardown self-connection below.
            let sh = &shared;
            let listener = &listener;
            s.spawn(move || reaper(sh));
            s.spawn(move || {
                for stream in listener.incoming() {
                    if sh.shutting_down.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else {
                        // Transient accept failure (fd pressure, reset
                        // in the backlog): back off instead of spinning.
                        std::thread::sleep(Duration::from_millis(50));
                        continue;
                    };
                    let conn_id = sh.next_conn.fetch_add(1, Ordering::Relaxed);
                    if let Ok(clone) = stream.try_clone() {
                        sh.conns.lock().unwrap().insert(conn_id, clone);
                    }
                    s.spawn(move || handle_conn(sh, stream, conn_id));
                }
            });

            // Park until the last slot fills, then tear the fabric
            // down so every scoped thread joins.
            let mut g = shared.sched.lock().unwrap();
            while !g.sched.done() {
                g = shared.all_done.wait(g).unwrap();
            }
            drop(g);
            shared.shutting_down.store(true, Ordering::SeqCst);
            for c in shared.conns.lock().unwrap().values() {
                let _ = c.shutdown(Shutdown::Both);
            }
            let _ = TcpStream::connect(addr);
        });

        // Teardown: any lease span still open (e.g. a job resolved by
        // a different worker while this lease was in flight) ends now
        // so the trace stays balanced.
        for (_, mut span) in shared.lease_spans.lock().unwrap().drain() {
            span.field("status", Json::Str("shutdown".to_string()));
        }
        if let Err(e) = shared.obs.flush() {
            shared.obs.warn(
                "dist.coordinator",
                &format!("trace flush failed: {e:#}"),
                &[],
            );
        }
        let state = shared.sched.into_inner().unwrap();
        Ok(state.sched.into_records())
    }
}

/// One-call convenience: bind on `cfg.addr` and serve to completion.
pub fn run_distributed_sweep(
    plan: &SweepPlan,
    store: Option<&Store>,
    cfg: &DistConfig,
) -> Result<Vec<RunRecord>> {
    Coordinator::bind(plan, store, cfg)?.run()
}

/// What the store already knows about a job.
enum Probe {
    /// Sound stored record: serve it locally, never lease it.
    Cached(RunRecord),
    /// Miss (or unsound stored record — `heal` set): lease it out.
    Miss(PreparedJob),
}

/// Consult the store via the one shared helper
/// ([`probe_store`](crate::coordinator::probe_store)) — identical
/// serving semantics to `run_sweep_stored` by construction, which is
/// what the dist-vs-local byte-identity contract rests on.
fn probe(idx: usize, job: Job, store: Option<&Store>) -> Probe {
    let p = probe_store(&job, store);
    match p.cached {
        Some(rec) => Probe::Cached(rec),
        None => Probe::Miss(PreparedJob {
            idx,
            job,
            exact: std::sync::Arc::new(p.exact),
            fp: p.fp,
            heal: p.heal,
        }),
    }
}

/// Advance the lazy feed until something is leasable (or the feed is
/// dry): cache hits commit locally as they stream past, the first miss
/// parks. Runs under the scheduler lock — the probe's oracle
/// simulation is microseconds next to a SAT solve, and serializing it
/// keeps the cached-commit order deterministic.
fn refill(shared: &Shared<'_>, g: &mut MutexGuard<'_, SchedState<'_>>) {
    while !g.exhausted && g.sched.needs_fresh() {
        match g.feed.next() {
            None => g.exhausted = true,
            Some((idx, job)) => match probe(idx, job, shared.store) {
                Probe::Cached(rec) => {
                    let events = g.sched.commit_local(idx, rec, None);
                    persist(shared, &events);
                    shared.mx.frontier_lag.set(g.sched.frontier_lag() as u64);
                    if g.sched.done() {
                        shared.all_done.notify_all();
                    }
                }
                Probe::Miss(prepared) => g.sched.park(prepared),
            },
        }
    }
}

/// Write released commit events to the WAL, in the order the frontier
/// released them. Healing overwrites last-writer-wins; everything else
/// dedups on fingerprint (first committed wins — a requeued job
/// completed twice must not grow the WAL). Append failures are
/// reported and skipped: losing one cache line is not worth losing the
/// sweep (same policy as the local path).
///
/// Every released event also lands in the trace as a `dist.commit`
/// counter with its job index — the accounting `trace --check` and the
/// merged multi-node view rest on (each committed job exactly once).
fn persist(shared: &Shared<'_>, events: &[CommitEvent]) {
    for ev in events {
        shared.obs.counter(
            "dist.commit",
            1,
            &[
                ("job", Json::Num(ev.idx as f64)),
                ("bench", Json::Str(ev.record.bench.to_string())),
                ("method", Json::Str(ev.record.method.name().to_string())),
                ("et", Json::Num(ev.record.et as f64)),
                ("heal", Json::Bool(ev.heal)),
            ],
        );
    }
    let Some(st) = shared.store else { return };
    for ev in events {
        let res = if ev.heal {
            st.append(ev.fp, &ev.record).map(|_| true)
        } else {
            st.append_if_absent(ev.fp, &ev.record)
        };
        if let Err(e) = res {
            shared.obs.warn(
                "dist.coordinator",
                &format!(
                    "store append failed for {} {} et={}: {e:#}",
                    ev.record.bench,
                    ev.record.method.name(),
                    ev.record.et
                ),
                &[("job", Json::Num(ev.idx as f64))],
            );
        }
    }
}

fn reaper(shared: &Shared<'_>) {
    // A few ticks per lease period, bounded so tests with tiny leases
    // still expire promptly and production leases don't spin.
    let tick = Duration::from_millis((shared.lease_ms / 4).clamp(10, 250));
    while !shared.shutting_down.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        let mut g = shared.sched.lock().unwrap();
        let expired = g.sched.expire(Instant::now());
        if !expired.is_empty() {
            for &j in &expired {
                end_lease_span(shared, j, "expired", None);
            }
            shared.mx.leases_expired.add(expired.len() as u64);
            shared.mx.jobs_requeued.add(expired.len() as u64);
            shared.obs.warn(
                "dist.coordinator",
                &format!("requeued {} expired lease(s): {expired:?}", expired.len()),
                &[("expired", Json::Num(expired.len() as f64))],
            );
        }
    }
}

fn handle_conn(shared: &Shared<'_>, stream: TcpStream, conn_id: u64) {
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut hello_done = false;
    loop {
        match jsonl::read_line(&mut reader) {
            LineRead::Eof => break,
            LineRead::Oversized => {
                let resp = CoordMsg::Error {
                    error: format!(
                        "request line exceeds the {}-byte cap",
                        jsonl::MAX_LINE_BYTES
                    ),
                };
                let _ = jsonl::send_line(&mut writer, &resp.render());
                break;
            }
            LineRead::Line(line) => {
                if line.is_empty() {
                    continue;
                }
                shared.mx.rx_bytes.add(line.len() as u64 + 1);
                let resp = match WorkerMsg::parse(&line) {
                    Err(error) => CoordMsg::Error { error },
                    Ok(msg) => handle_msg(shared, conn_id, msg, &mut hello_done),
                };
                let rendered = resp.render();
                shared.mx.tx_bytes.add(rendered.len() as u64 + 1);
                if jsonl::send_line(&mut writer, &rendered).is_err() {
                    break;
                }
            }
        }
    }
    // Release this connection's teardown clone (the fd) and requeue
    // whatever the worker still held.
    shared.conns.lock().unwrap().remove(&conn_id);
    let lost = shared.sched.lock().unwrap().sched.fail_conn(conn_id);
    if !lost.is_empty() {
        for &j in &lost {
            end_lease_span(shared, j, "conn_died", None);
        }
        shared.mx.jobs_requeued.add(lost.len() as u64);
        shared.obs.warn(
            "dist.coordinator",
            &format!("worker connection {conn_id} died; requeued job(s) {lost:?}"),
            &[("conn", Json::Num(conn_id as f64))],
        );
    }
}

/// One cumulative telemetry sample for the `status` verb: the
/// process-wide `pallas_dist*` registry metrics plus sweep progress
/// and the per-worker view, all folded into the standard
/// [`Sample`](crate::obs::Sample) shape (worker facts become labelled
/// gauges) so the monitor side needs no special-case parsing.
fn status_sample(shared: &Shared<'_>) -> Json {
    let now_us = shared.clock.now_us();
    let mut s = timeseries::cumulative_sample("coord", now_us, Some("pallas_dist"));
    {
        let g = shared.sched.lock().unwrap();
        s.gauges.insert("pallas_dist_jobs_total".to_string(), shared.n_jobs as u64);
        s.gauges
            .insert("pallas_dist_jobs_resolved".to_string(), g.sched.resolved() as u64);
        s.gauges
            .insert("pallas_dist_jobs_in_flight".to_string(), g.sched.in_flight() as u64);
        s.gauges.insert(
            "pallas_dist_commit_frontier_lag".to_string(),
            g.sched.frontier_lag() as u64,
        );
    }
    let workers = shared.workers.lock().unwrap();
    s.gauges.insert("pallas_dist_workers_seen".to_string(), workers.len() as u64);
    for (name, v) in workers.iter() {
        let key = |what: &str| format!("pallas_dist_worker_{what}{{worker=\"{name}\"}}");
        s.gauges.insert(key("jobs"), v.telemetry.jobs);
        s.gauges.insert(key("tx_bytes"), v.telemetry.tx_bytes);
        s.gauges.insert(key("rx_bytes"), v.telemetry.rx_bytes);
        s.gauges.insert(key("uptime_us"), v.telemetry.uptime_us);
        // Liveness: how long since this worker's last heartbeat.
        s.gauges.insert(key("age_us"), now_us.saturating_sub(v.last_seen_us));
    }
    s.to_json()
}

fn handle_msg(
    shared: &Shared<'_>,
    conn_id: u64,
    msg: WorkerMsg,
    hello_done: &mut bool,
) -> CoordMsg {
    match msg {
        WorkerMsg::Hello { name: _, proto } => {
            if proto != PROTO_VERSION {
                return CoordMsg::Error {
                    error: format!(
                        "protocol version {proto} unsupported (coordinator speaks \
                         {PROTO_VERSION})"
                    ),
                };
            }
            *hello_done = true;
            CoordMsg::Welcome { jobs: shared.n_jobs, lease_ms: shared.lease_ms }
        }
        // Telemetry poll: read-only, so it needs no worker identity —
        // deliberately ahead of the hello gate, letting `monitor`
        // clients poll without joining the sweep.
        WorkerMsg::Status => CoordMsg::Status { sample: status_sample(shared) },
        _ if !*hello_done => {
            CoordMsg::Error { error: "hello required before anything else".to_string() }
        }
        WorkerMsg::LeaseRequest { telemetry } => {
            if let Some(t) = telemetry {
                shared.workers.lock().unwrap().insert(
                    t.name.clone(),
                    WorkerView { telemetry: t, last_seen_us: shared.clock.now_us() },
                );
            }
            let mut g = shared.sched.lock().unwrap();
            loop {
                if g.sched.done() {
                    return CoordMsg::Done;
                }
                if let Some(grant) = g.sched.grant(conn_id, Instant::now()) {
                    shared.mx.leases_granted.inc();
                    let trace_ctx = if shared.obs.enabled() {
                        // Re-granting (after expiry/rejection) ends the
                        // stale span first: one open lease span per job.
                        end_lease_span(shared, grant.idx, "superseded", None);
                        let span = shared.obs.span(
                            "dist.lease",
                            &[
                                ("job", Json::Num(grant.idx as f64)),
                                ("bench", Json::Str(grant.job.bench.name.to_string())),
                                ("method", Json::Str(grant.job.method.name().to_string())),
                                ("et", Json::Num(grant.job.et as f64)),
                                ("conn", Json::Num(conn_id as f64)),
                            ],
                        );
                        let ctx = span.ctx();
                        shared.lease_spans.lock().unwrap().insert(grant.idx, span);
                        ctx
                    } else {
                        None
                    };
                    return CoordMsg::Lease {
                        job: grant.idx,
                        bench: grant.job.bench.name.to_string(),
                        method: grant.job.method,
                        et: grant.job.et,
                        search: grant.job.search,
                        trace_ctx,
                    };
                }
                if !g.exhausted && g.sched.needs_fresh() {
                    refill(shared, &mut g);
                    continue;
                }
                // Everything is leased out or resolved; this worker
                // should ask again shortly (a lease may expire).
                return CoordMsg::Wait { ms: shared.wait_ms };
            }
        }
        WorkerMsg::Result { job, record, trace_ctx } => {
            let mut g = shared.sched.lock().unwrap();
            match g.sched.submit(job, record, conn_id) {
                Submission::Fresh(events) => {
                    end_lease_span(shared, job, "committed", trace_ctx.as_ref());
                    persist(shared, &events);
                    shared.mx.results_committed.inc();
                    shared.mx.frontier_lag.set(g.sched.frontier_lag() as u64);
                    if g.sched.done() {
                        shared.all_done.notify_all();
                    }
                    CoordMsg::Committed { job, fresh: true }
                }
                Submission::Stale => {
                    // A stale duplicate: the live lease span (if any)
                    // belongs to whoever holds the job now — untouched.
                    shared.mx.results_stale.inc();
                    CoordMsg::Committed { job, fresh: false }
                }
                Submission::Unsound(why) => {
                    shared.mx.results_unsound.inc();
                    shared.obs.warn(
                        "dist.coordinator",
                        &format!(
                            "discarding result for job {job} from connection \
                             {conn_id}: {why}"
                        ),
                        &[("job", Json::Num(job as f64))],
                    );
                    CoordMsg::Error { error: why }
                }
            }
        }
        WorkerMsg::Reject { job, reason } => {
            let mut g = shared.sched.lock().unwrap();
            match g.sched.reject(job, conn_id, &reason) {
                Rejection::Requeued => {
                    end_lease_span(shared, job, "rejected", None);
                    shared.mx.jobs_requeued.inc();
                    CoordMsg::Requeued { job }
                }
                Rejection::Stale => CoordMsg::Requeued { job },
                Rejection::FailedOut(events) => {
                    end_lease_span(shared, job, "failed_out", None);
                    persist(shared, &events);
                    shared.mx.frontier_lag.set(g.sched.frontier_lag() as u64);
                    shared.obs.warn(
                        "dist.coordinator",
                        &format!(
                            "job {job} failed out after repeated rejections \
                             (last: {reason})"
                        ),
                        &[("job", Json::Num(job as f64))],
                    );
                    if g.sched.done() {
                        shared.all_done.notify_all();
                    }
                    CoordMsg::Committed { job, fresh: true }
                }
            }
        }
    }
}

//! Distributed sweep fabric: coordinator/worker nodes over TCP with
//! lease-based scheduling and store-backed resume — the subsystem that
//! turns a one-machine sweep into a horizontally scalable synthesis
//! service (every (benchmark, method, ET) job is an independent SAT
//! search, so the methodology is embarrassingly parallel at the job
//! level).
//!
//! * [`protocol`] — the worker↔coordinator verb set over the shared
//!   line-delimited-JSON wire discipline
//!   ([`util::jsonl`](crate::util::jsonl)).
//! * [`lease`] — the scheduling state machine: leases with wall-clock
//!   expiry, requeue on worker death, first-committed-wins dedup and
//!   the in-order WAL commit frontier. Pure state, unit-tested without
//!   sockets.
//! * [`coordinator`] — the TCP server around the scheduler: pull-based
//!   job iteration, store probing (cache hits never cross the wire),
//!   single-writer WAL commits, teardown.
//! * [`worker`] — the remote executor: lease → `run_job_with` (with a
//!   per-process miter-prototype cache) → result, in a loop.
//!
//! The contract, proven end to end by `tests/dist_roundtrip.rs`: a
//! distributed sweep's record set, fig5 CSV and WAL are byte-identical
//! (modulo the `cached`/`elapsed_ms` provenance columns) to a
//! sequential `run_sweep_stored` run, regardless of worker count,
//! arrival order, worker crashes or lease expiries. See DESIGN.md §11
//! for the wire protocol, the lease state machine and the determinism
//! argument.

pub mod coordinator;
pub mod lease;
pub mod protocol;
pub mod worker;

pub use coordinator::{run_distributed_sweep, Coordinator, DistConfig};
pub use lease::{Scheduler, REJECT_CAP};
pub use protocol::WorkerTelemetry;
pub use worker::{run_worker, WorkerConfig, WorkerStats};

//! The `monitor` subcommand: a live aggregated view over any mix of
//! serve and coordinator endpoints, plus the durable time-series log
//! they feed.
//!
//! One collector thread per endpoint, each speaking that endpoint's
//! native telemetry discipline:
//!
//! * **serve** endpoints get a `watch` subscription — the server
//!   pushes one cumulative registry sample per period and the
//!   collector just reads lines;
//! * **coordinator** endpoints are polled with the `status` verb
//!   (strict request/response, allowed before `hello`, so the monitor
//!   never joins the sweep).
//!
//! Collectors feed one mpsc channel; the aggregator keeps a
//! per-endpoint [`TimeSeries`] (cumulative wire samples become ring
//! deltas via [`TimeSeries::push_cumulative`]), appends every sample
//! to the `--out` JSONL log as it arrives (footer on exit, same schema
//! `perfgate` loads), optionally judges each endpoint's series against
//! an SLO spec, and renders the cluster table at the end: per-tier
//! request/error totals and p50/p99 from *exact* histogram merges
//! across endpoints, plus the coordinator's per-worker liveness view.
//!
//! Connection failures are warnings, not errors — a monitor must
//! outlive the processes it watches, and CI smoke runs race startup.
//! Everything here is observe-only: collectors hold no locks in the
//! watched processes and the watched runs' bytes are pinned by
//! `tests/obs_determinism.rs`.

use std::collections::BTreeMap;
use std::fs::OpenOptions;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::dist::protocol::{CoordMsg, WorkerMsg};
use crate::obs::timeseries::{self, Sample, TimeSeries};
use crate::obs::{Histogram, Obs, SloEvaluator, SloSpec};
use crate::serve::protocol as serve_protocol;
use crate::util::jsonl::{self, LineRead};
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// Serve endpoints to `watch` (`host:port`).
    pub serve: Vec<String>,
    /// Coordinator endpoints to poll with `status` (`host:port`).
    pub coord: Vec<String>,
    /// Sampling period, milliseconds.
    pub interval_ms: u64,
    /// Samples to collect per endpoint; `None` runs until every
    /// endpoint hangs up (i.e. until the watched processes exit).
    pub iterations: Option<u64>,
    /// Append the collected samples (ring/delta form plus footer) to
    /// this JSONL log — `perfgate` input.
    pub out: Option<PathBuf>,
    /// Judge every endpoint's series against these targets.
    pub slo: Option<SloSpec>,
    /// Trace handle; `slo.breach` events land here.
    pub obs: Obs,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            serve: Vec::new(),
            coord: Vec::new(),
            interval_ms: 1000,
            iterations: None,
            out: None,
            slo: None,
            obs: Obs::off(),
        }
    }
}

/// What one finished monitor run saw, for callers and tests.
#[derive(Debug, Clone)]
pub struct MonitorSummary {
    /// Endpoints that delivered at least one sample.
    pub endpoints_live: usize,
    /// Endpoints configured.
    pub endpoints: usize,
    /// Samples collected across all endpoints.
    pub samples: usize,
    /// SLO breach entries observed (0 without a spec).
    pub breaches: usize,
}

/// Subscribe to one serve endpoint's `watch` stream and forward every
/// pushed sample. Returns when `count` samples arrived or the server
/// hung up.
fn collect_serve(
    addr: &str,
    interval_ms: u64,
    count: Option<u64>,
    tx: &Sender<(String, Sample)>,
    obs: &Obs,
) {
    let key = format!("serve:{addr}");
    let stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(e) => {
            obs.warn("monitor", &format!("{key}: connect failed: {e}"), &[]);
            return;
        }
    };
    let _ = stream.set_nodelay(true);
    // Generous read timeout: the server pushes every `interval_ms`, so
    // silence for many periods means the stream is dead.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(
        interval_ms.saturating_mul(20).max(5_000),
    )));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let req = serve_protocol::render_watch_request(1, Some(interval_ms), count);
    if jsonl::send_line(&mut writer, &req).is_err() {
        obs.warn("monitor", &format!("{key}: subscribe failed"), &[]);
        return;
    }
    let mut reader = BufReader::new(stream);
    loop {
        match jsonl::read_line(&mut reader) {
            LineRead::Eof | LineRead::Oversized => return,
            LineRead::Line(line) => {
                if line.is_empty() {
                    continue;
                }
                let sample = Json::parse(&line)
                    .ok()
                    .and_then(|j| j.get("sample").and_then(|s| Sample::from_json(s).ok()));
                match sample {
                    Some(s) => {
                        if tx.send((key.clone(), s)).is_err() {
                            return; // aggregator gone
                        }
                    }
                    // Interleaved non-watch responses (or a structured
                    // error) are not ours to interpret; skip.
                    None => continue,
                }
            }
        }
    }
}

/// Poll one coordinator endpoint with `status` over a single
/// connection. Returns after `count` polls or when the coordinator
/// hangs up (sweep finished).
fn collect_coord(
    addr: &str,
    interval_ms: u64,
    count: Option<u64>,
    tx: &Sender<(String, Sample)>,
    obs: &Obs,
) {
    let key = format!("coord:{addr}");
    let stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(e) => {
            obs.warn("monitor", &format!("{key}: connect failed: {e}"), &[]);
            return;
        }
    };
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(
        interval_ms.saturating_mul(20).max(5_000),
    )));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut polls = 0u64;
    loop {
        if jsonl::send_line(&mut writer, &WorkerMsg::Status.render()).is_err() {
            return;
        }
        let line = loop {
            match jsonl::read_line(&mut reader) {
                LineRead::Eof | LineRead::Oversized => return,
                LineRead::Line(l) if l.is_empty() => continue,
                LineRead::Line(l) => break l,
            }
        };
        match CoordMsg::parse(&line) {
            Ok(CoordMsg::Status { sample }) => {
                if let Ok(s) = Sample::from_json(&sample) {
                    if tx.send((key.clone(), s)).is_err() {
                        return;
                    }
                }
            }
            Ok(other) => {
                obs.warn("monitor", &format!("{key}: unexpected {other:?}"), &[]);
                return;
            }
            Err(e) => {
                obs.warn("monitor", &format!("{key}: bad status line: {e}"), &[]);
                return;
            }
        }
        polls += 1;
        if count.is_some_and(|c| polls >= c) {
            return;
        }
        std::thread::sleep(Duration::from_millis(interval_ms.max(1)));
    }
}

/// Extract a label value from the `name{label="v"}`-suffix-in-name
/// metric convention (None when the label is absent).
fn label_value<'a>(name: &'a str, label: &str) -> Option<&'a str> {
    let start = name.find(&format!("{label}=\""))? + label.len() + 2;
    let rest = &name[start..];
    Some(&rest[..rest.find('"')?])
}

/// Per-tier rollup across every endpoint's series: request/error
/// totals from summed counter deltas, latency quantiles from exact
/// merges of each endpoint's latest cumulative histogram snapshot.
fn tier_table(series: &BTreeMap<String, TimeSeries>) -> String {
    use std::fmt::Write as _;

    struct TierAgg {
        requests: u64,
        errors: u64,
        lat: Histogram,
    }
    fn agg<'m>(tiers: &'m mut BTreeMap<String, TierAgg>, tier: &str) -> &'m mut TierAgg {
        tiers.entry(tier.to_string()).or_insert_with(|| TierAgg {
            requests: 0,
            errors: 0,
            lat: Histogram::new(),
        })
    }
    let mut tiers: BTreeMap<String, TierAgg> = BTreeMap::new();
    for ts in series.values() {
        // Counter deltas over the whole retained window.
        let window = ts.len();
        let mut names: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        for s in ts.samples() {
            names.extend(s.counters.keys().cloned());
        }
        for name in &names {
            let Some(tier) = label_value(name, "tier") else { continue };
            let total = ts.window_counter(name, window);
            if name.contains("_request_errors_total") {
                agg(&mut tiers, tier).errors += total;
            } else if name.contains("_requests_total") {
                agg(&mut tiers, tier).requests += total;
            }
        }
        if let Some(latest) = ts.latest() {
            for (name, snap) in &latest.hists {
                if !name.contains("_latency_us") {
                    continue;
                }
                if let Some(tier) = label_value(name, "tier") {
                    agg(&mut tiers, tier).lat.absorb(snap);
                }
            }
        }
    }
    let mut out = String::new();
    for (tier, t) in &tiers {
        let rate = if t.requests == 0 {
            0.0
        } else {
            t.errors as f64 / t.requests as f64 * 100.0
        };
        let _ = writeln!(
            out,
            "monitor: tier {tier}: {} req, {} errors ({rate:.2}%), \
             p50 {} µs, p99 {} µs",
            t.requests,
            t.errors,
            t.lat.quantile(0.50),
            t.lat.quantile(0.99)
        );
    }
    out
}

/// The coordinator's per-worker liveness view, read off the latest
/// sample of every `coord:` series.
fn worker_table(series: &BTreeMap<String, TimeSeries>) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for (key, ts) in series {
        if !key.starts_with("coord:") {
            continue;
        }
        let Some(latest) = ts.latest() else { continue };
        for (name, &jobs) in &latest.gauges {
            if !name.starts_with("pallas_dist_worker_jobs{") {
                continue;
            }
            let Some(worker) = label_value(name, "worker") else { continue };
            let gauge = |what: &str| {
                latest
                    .gauges
                    .get(&format!("pallas_dist_worker_{what}{{worker=\"{worker}\"}}"))
                    .copied()
                    .unwrap_or(0)
            };
            let _ = writeln!(
                out,
                "monitor: worker {worker} ({key}): {jobs} jobs, \
                 tx {} B, rx {} B, last seen {:.1} s ago",
                gauge("tx_bytes"),
                gauge("rx_bytes"),
                gauge("age_us") as f64 / 1e6
            );
        }
    }
    out
}

/// Run the monitor to completion (bounded by `iterations`, or by the
/// watched processes exiting). Prints the cluster table on stdout and
/// returns the summary.
pub fn run_monitor(cfg: &MonitorConfig) -> Result<MonitorSummary> {
    let endpoints = cfg.serve.len() + cfg.coord.len();
    if endpoints == 0 {
        anyhow::bail!("monitor needs at least one --serve or --coord endpoint");
    }
    let mut log = match &cfg.out {
        Some(path) => Some(
            OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .with_context(|| format!("open time-series log {}", path.display()))?,
        ),
        None => None,
    };
    let (tx, rx) = channel::<(String, Sample)>();
    let mut collectors = Vec::new();
    for addr in &cfg.serve {
        let (addr, tx, obs) = (addr.clone(), tx.clone(), cfg.obs.clone());
        let (ms, n) = (cfg.interval_ms, cfg.iterations);
        collectors.push(std::thread::spawn(move || {
            collect_serve(&addr, ms, n, &tx, &obs);
        }));
    }
    for addr in &cfg.coord {
        let (addr, tx, obs) = (addr.clone(), tx.clone(), cfg.obs.clone());
        let (ms, n) = (cfg.interval_ms, cfg.iterations);
        collectors.push(std::thread::spawn(move || {
            collect_coord(&addr, ms, n, &tx, &obs);
        }));
    }
    // The aggregator owns no Sender: the loop below ends exactly when
    // every collector has exited.
    drop(tx);

    let mut series: BTreeMap<String, TimeSeries> = BTreeMap::new();
    let mut evals: BTreeMap<String, SloEvaluator> = BTreeMap::new();
    let mut samples = 0usize;
    let mut written = 0u64;
    let mut breaches = 0usize;
    for (key, mut sample) in rx {
        samples += 1;
        // Re-node under the endpoint key: two serve endpoints must not
        // collapse into one "serve" node in the log (perfgate reduces
        // per node).
        sample.node = key.clone();
        let ts = series
            .entry(key.clone())
            .or_insert_with(|| TimeSeries::new(&key, 65_536));
        let stored = ts.push_cumulative(sample);
        if let Some(f) = log.as_mut() {
            // Ring/delta form, one line per sample, footer on exit —
            // the `timeseries::parse` schema.
            let line = stored.to_json().render();
            jsonl::send_line(f, &line).context("append time-series log")?;
            written += 1;
        }
        if let Some(spec) = &cfg.slo {
            let ev = evals
                .entry(key.clone())
                .or_insert_with(|| SloEvaluator::new(spec.clone()));
            breaches += ev.evaluate(ts, &cfg.obs).len();
        }
    }
    for c in collectors {
        let _ = c.join();
    }
    if let Some(f) = log.as_mut() {
        jsonl::send_line(f, &timeseries::footer_line(written, 0))
            .context("append time-series footer")?;
        f.flush().context("flush time-series log")?;
    }
    if let Err(e) = cfg.obs.flush() {
        cfg.obs.warn("monitor", &format!("trace flush failed: {e:#}"), &[]);
    }

    print!("{}", tier_table(&series));
    print!("{}", worker_table(&series));
    for (key, ts) in &series {
        println!("monitor: endpoint {key}: {} sample(s)", ts.len());
    }
    if breaches > 0 {
        println!("monitor: {breaches} SLO breach(es) entered");
    }
    Ok(MonitorSummary {
        endpoints_live: series.len(),
        endpoints,
        samples,
        breaches,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_values_parse_the_suffix_convention() {
        assert_eq!(
            label_value("pallas_serve_latency_us{tier=\"gold\"}", "tier"),
            Some("gold")
        );
        assert_eq!(
            label_value("pallas_dist_worker_jobs{worker=\"w1\"}", "worker"),
            Some("w1")
        );
        assert_eq!(label_value("pallas_serve_batches_total", "tier"), None);
        // First label match wins; values with escapes still terminate
        // at the first quote (good enough for display rollups).
        assert_eq!(
            label_value("m{a=\"x\",b=\"y\"}", "b"),
            Some("y")
        );
    }

    #[test]
    fn monitor_without_endpoints_is_an_error() {
        assert!(run_monitor(&MonitorConfig::default()).is_err());
    }
}

//! Indexed max-heap over variables ordered by VSIDS activity.
//!
//! Standard MiniSat structure: `heap` is the binary heap of variables,
//! `index[v]` is the position of `v` in it (or `usize::MAX` when absent),
//! so decrease/increase-key and membership tests are O(1)/O(log n).

#[derive(Debug, Default, Clone)]
pub struct VarHeap {
    heap: Vec<u32>,
    index: Vec<usize>,
}

impl VarHeap {
    pub fn grow_to(&mut self, n_vars: usize) {
        self.index.resize(n_vars, usize::MAX);
    }

    pub fn contains(&self, v: u32) -> bool {
        self.index[v as usize] != usize::MAX
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn insert(&mut self, v: u32, activity: &[f64]) {
        if self.contains(v) {
            return;
        }
        self.index[v as usize] = self.heap.len();
        self.heap.push(v);
        self.sift_up(self.heap.len() - 1, activity);
    }

    pub fn pop_max(&mut self, activity: &[f64]) -> Option<u32> {
        let top = *self.heap.first()?;
        let last = self.heap.pop().unwrap();
        self.index[top as usize] = usize::MAX;
        if !self.heap.is_empty() {
            self.heap[0] = last;
            self.index[last as usize] = 0;
            self.sift_down(0, activity);
        }
        Some(top)
    }

    /// Restore heap order for `v` after its activity increased.
    pub fn decrease_key(&mut self, v: u32, activity: &[f64]) {
        if let Some(&pos) = self.index.get(v as usize) {
            if pos != usize::MAX {
                self.sift_up(pos, activity);
            }
        }
    }

    fn sift_up(&mut self, mut i: usize, act: &[f64]) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if act[self.heap[i] as usize] <= act[self.heap[parent] as usize] {
                break;
            }
            self.swap(i, parent);
            i = parent;
        }
    }

    fn sift_down(&mut self, mut i: usize, act: &[f64]) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut best = i;
            if l < self.heap.len() && act[self.heap[l] as usize] > act[self.heap[best] as usize]
            {
                best = l;
            }
            if r < self.heap.len() && act[self.heap[r] as usize] > act[self.heap[best] as usize]
            {
                best = r;
            }
            if best == i {
                return;
            }
            self.swap(i, best);
            i = best;
        }
    }

    fn swap(&mut self, i: usize, j: usize) {
        self.heap.swap(i, j);
        self.index[self.heap[i] as usize] = i;
        self.index[self.heap[j] as usize] = j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_activity_order() {
        let act = vec![0.5, 3.0, 1.0, 2.0];
        let mut h = VarHeap::default();
        h.grow_to(4);
        for v in 0..4 {
            h.insert(v, &act);
        }
        let mut got = Vec::new();
        while let Some(v) = h.pop_max(&act) {
            got.push(v);
        }
        assert_eq!(got, vec![1, 3, 2, 0]);
    }

    #[test]
    fn reinsert_and_membership() {
        let act = vec![1.0, 2.0];
        let mut h = VarHeap::default();
        h.grow_to(2);
        h.insert(0, &act);
        assert!(h.contains(0));
        assert!(!h.contains(1));
        assert_eq!(h.pop_max(&act), Some(0));
        assert!(!h.contains(0));
        h.insert(0, &act);
        h.insert(0, &act); // idempotent
        assert_eq!(h.pop_max(&act), Some(0));
        assert!(h.is_empty());
    }

    #[test]
    fn decrease_key_reorders() {
        let mut act = vec![1.0, 2.0, 3.0];
        let mut h = VarHeap::default();
        h.grow_to(3);
        for v in 0..3 {
            h.insert(v, &act);
        }
        act[0] = 10.0;
        h.decrease_key(0, &act);
        assert_eq!(h.pop_max(&act), Some(0));
    }
}

//! Flat clause storage: one contiguous `u32` arena for every clause.
//!
//! Replaces the former `Vec<Clause>` (a heap allocation per clause, an
//! activity `f64` and two bools of padding each). Layout per clause,
//! starting at its [`CRef`] word offset:
//!
//! ```text
//!   word 0   header: len << 3 | RELOCED << 2 | DELETED << 1 | LEARNT
//!   word 1   activity as f32 bits (learnt clauses; 0 otherwise)
//!            — or the forwarding CRef while RELOCED during compaction
//!   word 2   LBD ("glue") of learnt clauses, maintained by the solver
//!            (0 for problem clauses)
//!   word 3.. the `len` literals, one `Lit` per word
//! ```
//!
//! Why it matters here:
//! * `propagate` walks literals that sit next to their header in one
//!   cache line instead of chasing a `Vec` pointer per clause;
//! * deleting a clause is a flag write, and [`ClauseArena`] tracks the
//!   wasted words so the solver can *compact* — the old representation
//!   tombstoned deleted learnts in `clauses` forever;
//! * cloning the whole clause database is a single `memcpy` of `data`,
//!   which is what makes build-once/clone-cheap miter prototypes viable
//!   (`template::miter`).

use super::solver::Lit;

/// Word offset of a clause header inside the arena.
pub type CRef = u32;

/// Words of metadata preceding the literals of every clause.
pub const HEADER_WORDS: usize = 3;

const FLAG_LEARNT: u32 = 1;
const FLAG_DELETED: u32 = 1 << 1;
const FLAG_RELOCED: u32 = 1 << 2;
const LEN_SHIFT: u32 = 3;

#[derive(Debug, Clone, Default)]
pub struct ClauseArena {
    data: Vec<u32>,
    /// Words occupied by deleted clauses, reclaimable by [`Self::compact`].
    wasted: usize,
}

impl ClauseArena {
    pub fn new() -> Self {
        ClauseArena::default()
    }

    pub fn with_capacity(words: usize) -> Self {
        ClauseArena { data: Vec::with_capacity(words), wasted: 0 }
    }

    /// Total words in use (live + deleted-but-not-yet-compacted).
    pub fn len_words(&self) -> usize {
        self.data.len()
    }

    /// Words reclaimable by compaction.
    pub fn wasted_words(&self) -> usize {
        self.wasted
    }

    /// Append a clause; the literals stream straight into the arena with
    /// no per-clause allocation.
    pub fn alloc(&mut self, lits: &[Lit], learnt: bool) -> CRef {
        debug_assert!(lits.len() >= 2, "unit/empty clauses are not stored");
        let r = self.data.len() as CRef;
        let header = ((lits.len() as u32) << LEN_SHIFT) | u32::from(learnt);
        self.data.push(header);
        self.data.push(0); // activity
        self.data.push(0); // LBD
        self.data.extend(lits.iter().map(|l| l.0));
        r
    }

    #[inline]
    pub fn len(&self, r: CRef) -> usize {
        (self.data[r as usize] >> LEN_SHIFT) as usize
    }

    #[inline]
    pub fn is_learnt(&self, r: CRef) -> bool {
        self.data[r as usize] & FLAG_LEARNT != 0
    }

    #[inline]
    pub fn is_deleted(&self, r: CRef) -> bool {
        self.data[r as usize] & FLAG_DELETED != 0
    }

    /// Flag a clause deleted and account its words as wasted. The clause
    /// stays readable until [`Self::compact`] reclaims it.
    pub fn delete(&mut self, r: CRef) {
        debug_assert!(!self.is_deleted(r));
        self.data[r as usize] |= FLAG_DELETED;
        self.wasted += HEADER_WORDS + self.len(r);
    }

    #[inline]
    pub fn lit(&self, r: CRef, k: usize) -> Lit {
        debug_assert!(k < self.len(r));
        Lit(self.data[r as usize + HEADER_WORDS + k])
    }

    #[inline]
    pub fn swap_lits(&mut self, r: CRef, a: usize, b: usize) {
        let base = r as usize + HEADER_WORDS;
        self.data.swap(base + a, base + b);
    }

    #[inline]
    pub fn activity(&self, r: CRef) -> f32 {
        f32::from_bits(self.data[r as usize + 1])
    }

    #[inline]
    pub fn set_activity(&mut self, r: CRef, a: f32) {
        self.data[r as usize + 1] = a.to_bits();
    }

    /// Literals-block-distance recorded for a learnt clause (0 until the
    /// solver stores one).
    #[inline]
    pub fn lbd(&self, r: CRef) -> u32 {
        self.data[r as usize + 2]
    }

    #[inline]
    pub fn set_lbd(&mut self, r: CRef, lbd: u32) {
        self.data[r as usize + 2] = lbd;
    }

    /// Iterate the literals of a clause (borrow-friendly copy-out).
    pub fn lits(&self, r: CRef) -> impl Iterator<Item = Lit> + '_ {
        let base = r as usize + HEADER_WORDS;
        self.data[base..base + self.len(r)].iter().map(|&w| Lit(w))
    }

    /// Walk every clause slot in allocation order, deleted ones included.
    pub fn refs(&self) -> ArenaIter<'_> {
        ArenaIter { arena: self, next: 0 }
    }

    /// Compact: rebuild the arena with the deleted clauses squeezed out,
    /// preserving allocation order. The *old* arena is left holding a
    /// forwarding table: [`Self::forward`] maps each live old [`CRef`] to
    /// its new offset (deleted clauses map to `None`). Returns the
    /// compacted arena and the number of words reclaimed; the caller
    /// remaps its watchers / reasons / learnt list and swaps the arenas.
    pub fn compact(&mut self) -> (ClauseArena, usize) {
        let reclaimed = self.wasted;
        let mut to = ClauseArena::with_capacity(self.data.len() - self.wasted);
        let mut r = 0usize;
        while r < self.data.len() {
            let len = self.len(r as CRef);
            if !self.is_deleted(r as CRef) {
                let header = self.data[r];
                let nr = to.data.len() as CRef;
                to.data.push(header);
                to.data.extend_from_slice(&self.data[r + 1..r + HEADER_WORDS + len]);
                self.data[r] |= FLAG_RELOCED;
                self.data[r + 1] = nr;
            }
            r += HEADER_WORDS + len;
        }
        (to, reclaimed)
    }

    /// New offset of a clause after [`Self::compact`] ran on this (old)
    /// arena; `None` for deleted clauses.
    #[inline]
    pub fn forward(&self, r: CRef) -> Option<CRef> {
        if self.data[r as usize] & FLAG_RELOCED != 0 {
            Some(self.data[r as usize + 1])
        } else {
            None
        }
    }
}

pub struct ArenaIter<'a> {
    arena: &'a ClauseArena,
    next: usize,
}

impl Iterator for ArenaIter<'_> {
    type Item = CRef;

    fn next(&mut self) -> Option<CRef> {
        if self.next >= self.arena.data.len() {
            return None;
        }
        let r = self.next as CRef;
        self.next += HEADER_WORDS + self.arena.len(r);
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lits(vals: &[u32]) -> Vec<Lit> {
        vals.iter().map(|&v| Lit(v)).collect()
    }

    #[test]
    fn alloc_and_read_back() {
        let mut a = ClauseArena::new();
        let r1 = a.alloc(&lits(&[2, 5, 7]), false);
        let r2 = a.alloc(&lits(&[4, 9]), true);
        assert_eq!(a.len(r1), 3);
        assert_eq!(a.len(r2), 2);
        assert!(!a.is_learnt(r1));
        assert!(a.is_learnt(r2));
        assert_eq!(a.lits(r1).collect::<Vec<_>>(), lits(&[2, 5, 7]));
        assert_eq!(a.lit(r2, 1), Lit(9));
        assert_eq!(a.len_words(), 2 * HEADER_WORDS + 5);
    }

    #[test]
    fn swap_and_activity() {
        let mut a = ClauseArena::new();
        let r = a.alloc(&lits(&[2, 5, 7]), true);
        a.swap_lits(r, 0, 2);
        assert_eq!(a.lits(r).collect::<Vec<_>>(), lits(&[7, 5, 2]));
        a.set_activity(r, 3.5);
        assert_eq!(a.activity(r), 3.5);
    }

    #[test]
    fn delete_tracks_waste_and_compact_reclaims() {
        let mut a = ClauseArena::new();
        let r1 = a.alloc(&lits(&[2, 5, 7]), false);
        let r2 = a.alloc(&lits(&[4, 9]), true);
        let r3 = a.alloc(&lits(&[6, 11, 13, 15]), true);
        a.delete(r2);
        assert_eq!(a.wasted_words(), HEADER_WORDS + 2);
        let before = a.len_words();
        let (to, reclaimed) = a.compact();
        assert_eq!(reclaimed, HEADER_WORDS + 2);
        assert_eq!(to.len_words(), before - reclaimed);
        assert_eq!(to.wasted_words(), 0);
        // Forwarding: live clauses relocate in order, deleted ones drop.
        let n1 = a.forward(r1).unwrap();
        assert_eq!(a.forward(r2), None);
        let n3 = a.forward(r3).unwrap();
        assert_eq!(to.lits(n1).collect::<Vec<_>>(), lits(&[2, 5, 7]));
        assert_eq!(to.lits(n3).collect::<Vec<_>>(), lits(&[6, 11, 13, 15]));
        assert!(to.is_learnt(n3));
        assert_eq!(to.refs().collect::<Vec<_>>(), vec![n1, n3]);
    }

    #[test]
    fn lbd_round_trips_and_survives_compaction() {
        let mut a = ClauseArena::new();
        let r1 = a.alloc(&lits(&[2, 5, 7]), true);
        let r2 = a.alloc(&lits(&[4, 9]), true);
        assert_eq!(a.lbd(r1), 0, "fresh clauses carry no glue yet");
        a.set_lbd(r1, 7);
        a.set_lbd(r2, 2);
        a.set_activity(r1, 1.5);
        assert_eq!(a.lbd(r1), 7);
        assert_eq!(a.lbd(r2), 2);
        a.delete(r2);
        let (to, _) = a.compact();
        let n1 = a.forward(r1).unwrap();
        assert_eq!(to.lbd(n1), 7, "compaction must carry the LBD word");
        assert_eq!(to.activity(n1), 1.5);
        assert_eq!(to.lits(n1).collect::<Vec<_>>(), lits(&[2, 5, 7]));
    }

    #[test]
    fn refs_walks_allocation_order() {
        let mut a = ClauseArena::new();
        let r1 = a.alloc(&lits(&[0, 2]), false);
        let r2 = a.alloc(&lits(&[4, 6, 8]), false);
        assert_eq!(a.refs().collect::<Vec<_>>(), vec![r1, r2]);
    }
}

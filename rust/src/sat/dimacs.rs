//! DIMACS CNF reader/writer — used for differential testing and for
//! exporting miters to external solvers when debugging.

use anyhow::{bail, Result};

use super::solver::{Lit, SatResult, Solver, Stats, Var};

/// Parse DIMACS CNF into clauses (1-based DIMACS vars -> 0-based).
pub fn parse_dimacs(src: &str) -> Result<(usize, Vec<Vec<Lit>>)> {
    let mut n_vars = 0usize;
    let mut clauses = Vec::new();
    let mut cur: Vec<Lit> = Vec::new();
    for line in src.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('c') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('p') {
            let parts: Vec<&str> = rest.split_whitespace().collect();
            if parts.len() != 3 || parts[0] != "cnf" {
                bail!("bad problem line: {line}");
            }
            n_vars = parts[1].parse()?;
            continue;
        }
        for tok in line.split_whitespace() {
            let x: i64 = tok.parse()?;
            if x == 0 {
                clauses.push(std::mem::take(&mut cur));
            } else {
                let v = (x.unsigned_abs() - 1) as Var;
                if (v as usize) >= n_vars {
                    bail!("literal {x} out of range (p cnf {n_vars})");
                }
                cur.push(Lit::new(v, x > 0));
            }
        }
    }
    if !cur.is_empty() {
        clauses.push(cur);
    }
    Ok((n_vars, clauses))
}

/// Load a DIMACS instance into a fresh solver.
pub fn solver_from_dimacs(src: &str) -> Result<(Solver, bool)> {
    let (n_vars, clauses) = parse_dimacs(src)?;
    let mut s = Solver::new();
    for _ in 0..n_vars {
        s.new_var();
    }
    let mut ok = true;
    for c in &clauses {
        ok &= s.add_clause(c);
    }
    Ok((s, ok))
}

/// Solve a DIMACS instance standalone, the way `synth --solve-dimacs`
/// replays a `--dump-cnf` export: load, preprocess, solve with the
/// default (Glucose-class) heuristics, and report the final statistics.
pub fn solve_dimacs(src: &str) -> Result<(SatResult, Stats)> {
    let (mut s, ok) = solver_from_dimacs(src)?;
    if !ok {
        return Ok((SatResult::Unsat, s.stats.clone()));
    }
    s.preprocess();
    let result = s.solve(&[]);
    Ok((result, s.stats.clone()))
}

/// Render clauses as DIMACS.
pub fn to_dimacs(n_vars: usize, clauses: &[Vec<Lit>]) -> String {
    let mut s = format!("p cnf {} {}\n", n_vars, clauses.len());
    for c in clauses {
        for &l in c {
            let v = l.var() as i64 + 1;
            s.push_str(&format!("{} ", if l.is_neg() { -v } else { v }));
        }
        s.push_str("0\n");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sat::SatResult;

    #[test]
    fn parse_and_solve() {
        let src = "c tiny\np cnf 2 2\n1 2 0\n-1 0\n";
        let (mut s, ok) = solver_from_dimacs(src).unwrap();
        assert!(ok);
        assert_eq!(s.solve(&[]), SatResult::Sat);
        assert!(s.model_value(Lit::new(1, true)));
    }

    #[test]
    fn round_trip() {
        let src = "p cnf 3 3\n1 -2 0\n2 3 0\n-3 -1 0\n";
        let (n, clauses) = parse_dimacs(src).unwrap();
        let again = to_dimacs(n, &clauses);
        let (n2, clauses2) = parse_dimacs(&again).unwrap();
        assert_eq!(n, n2);
        assert_eq!(clauses, clauses2);
    }

    #[test]
    fn solve_dimacs_round_trips_a_dumped_cell() {
        // The --solve-dimacs surface: a dumped miter cell (base CNF plus
        // restriction units, exactly what --dump-cnf writes) must solve
        // standalone to the same answer the miter gives in-process.
        use crate::circuit::generators::adder;
        use crate::circuit::sim::TruthTables;
        use crate::template::SharedMiter;
        let nl = adder(2);
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let (n, m) = (nl.n_inputs(), nl.n_outputs());
        for (pit, its) in [(0usize, 0usize), (4, 12)] {
            let mut miter = SharedMiter::build(n, m, 6, &exact, 2);
            let mut clauses = miter.b.solver.export_clauses();
            clauses.extend(miter.restrict(pit, its).into_iter().map(|l| vec![l]));
            let dimacs = to_dimacs(miter.b.solver.n_vars(), &clauses);
            let (result, stats) = solve_dimacs(&dimacs).unwrap();
            let want_sat = miter.solve(pit, its).is_sat();
            assert_eq!(
                result == SatResult::Sat,
                want_sat,
                "cell ({pit}, {its}) disagrees after the DIMACS round trip"
            );
            // The standalone path preprocesses, so the stats must say so.
            assert!(stats.preprocess_probes > 0, "preprocessing must have run");
        }
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(parse_dimacs("p cnf 1 1\n2 0\n").is_err());
    }

    #[test]
    fn rejects_bad_header() {
        assert!(parse_dimacs("p dnf 1 1\n1 0\n").is_err());
    }
}

//! From-scratch CDCL SAT solver — the engine behind every "SMT" query in
//! this reproduction (the paper's Z3 usage bit-blasts to propositional
//! logic at these circuit sizes; see DESIGN.md §2).
//!
//! Features: flat-arena clause storage ([`arena`]) with compacting
//! garbage collection, two-watched-literal propagation, EVSIDS decision
//! heuristic with an indexed heap, phase saving, first-UIP conflict
//! analysis with self-subsumption minimisation, Glucose-class search
//! heuristics (per-clause LBD with glue refresh, EMA-driven dynamic
//! restarts with trail blocking, LBD-tiered learnt DB reduction — see
//! DESIGN.md §8), once-per-formula preprocessing (failed-literal probing
//! and binary-clause subsumption, amortised across miter-prototype
//! clones), incremental solving under assumptions with UNSAT-core
//! extraction, cheap whole-solver cloning (the substrate for
//! `template::miter` prototypes), and DIMACS I/O for differential
//! testing. The pre-Glucose policies (Luby restarts, activity-only
//! reduction) stay selectable via [`Heuristics::legacy`] for A/B
//! benchmarking.

pub mod arena;
pub mod dimacs;
pub mod heap;
pub mod solver;

pub use solver::{Heuristics, Lbool, Lit, SatResult, Solver, Stats, Var};

//! From-scratch CDCL SAT solver — the engine behind every "SMT" query in
//! this reproduction (the paper's Z3 usage bit-blasts to propositional
//! logic at these circuit sizes; see DESIGN.md §2).
//!
//! Features: flat-arena clause storage ([`arena`]) with compacting
//! garbage collection, two-watched-literal propagation, EVSIDS decision
//! heuristic with an indexed heap, phase saving, Luby restarts, first-UIP
//! conflict analysis with self-subsumption minimisation, activity-driven
//! learnt clause DB reduction, incremental solving under assumptions with
//! UNSAT-core extraction, cheap whole-solver cloning (the substrate for
//! `template::miter` prototypes), and DIMACS I/O for differential
//! testing.

pub mod arena;
pub mod dimacs;
pub mod heap;
pub mod solver;

pub use solver::{Lbool, Lit, SatResult, Solver, Var};

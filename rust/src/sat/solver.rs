//! The CDCL solver core.
//!
//! Clause storage is a flat `u32` arena ([`super::arena::ClauseArena`]):
//! watchers and reasons hold arena word offsets ([`CRef`]), `propagate`
//! reads literals adjacent to their header instead of chasing a heap
//! pointer per clause, `reduce_db` *compacts* the arena (deleted learnts
//! are reclaimed, not tombstoned), and the whole solver is `Clone` — a
//! handful of flat-buffer copies — which is what makes the build-once/
//! clone-cheap miter prototypes of `template::miter` viable.
//!
//! Search heuristics are Glucose-4.1-class ([`Heuristics`], on by
//! default): every learnt clause carries its LBD ("glue" — the number of
//! distinct decision levels it spans) in the arena header, refreshed
//! downward when conflict analysis reuses the clause; restarts are
//! forced dynamically when a fast EMA of conflict LBD runs above the
//! slow one (recent learnts worse than the long-run average) and blocked
//! when the trail grows far past its own EMA (the search looks close to
//! a total assignment); and `reduce_db` retains by LBD tier — core glue
//! clauses are immortal, the high-LBD local tier drains first, activity
//! only breaks ties. [`Solver::preprocess`] adds a once-per-formula
//! root-level pass (failed-literal probing + subsumption against the
//! binary clauses) intended to run on a miter prototype *before* it is
//! cloned per lattice cell. All heuristic state is plain solver fields —
//! no wall-clock, no randomness — so clones still replay byte-for-byte.

use super::arena::{CRef, ClauseArena};
use super::heap::VarHeap;

/// Variable index (0-based).
pub type Var = u32;

/// Literal: `2*var + sign`, sign bit set for the negative literal.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Lit(pub u32);

impl Lit {
    #[inline]
    pub fn pos(v: Var) -> Lit {
        Lit(v << 1)
    }

    #[inline]
    pub fn neg(v: Var) -> Lit {
        Lit((v << 1) | 1)
    }

    /// Literal of `v` with the given truth value request: `Lit::new(v,
    /// true)` is satisfied when `v` is true.
    #[inline]
    pub fn new(v: Var, positive: bool) -> Lit {
        Lit((v << 1) | (!positive) as u32)
    }

    #[inline]
    pub fn var(self) -> Var {
        self.0 >> 1
    }

    #[inline]
    pub fn is_neg(self) -> bool {
        self.0 & 1 == 1
    }

    #[inline]
    pub fn inverted(self) -> Lit {
        Lit(self.0 ^ 1)
    }

    #[inline]
    fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::ops::Not for Lit {
    type Output = Lit;
    fn not(self) -> Lit {
        self.inverted()
    }
}

/// Three-valued assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lbool {
    True,
    False,
    Undef,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SatResult {
    Sat,
    Unsat,
}

#[derive(Debug, Clone, Copy)]
struct Watcher {
    clause: CRef,
    blocker: Lit,
}

const REASON_NONE: CRef = u32::MAX;

/// Learnt clauses at or below this LBD are "core" glue: never deleted by
/// the tiered `reduce_db` and exempt from glue refreshes (they cannot
/// improve).
const CORE_LBD: u32 = 2;
/// Smoothing factors of the restart EMAs: the fast LBD average reacts
/// within ~32 conflicts, the slow LBD and trail averages track the
/// long-run behaviour of the solve.
const EMA_FAST_ALPHA: f64 = 1.0 / 32.0;
const EMA_SLOW_ALPHA: f64 = 1.0 / 4096.0;
/// Force a restart when `fast > K * slow` (recent learnt quality well
/// below the long-run average).
const RESTART_FORCE_K: f64 = 1.25;
/// Block a forced restart when the trail is this factor above its EMA.
const RESTART_BLOCK_R: f64 = 1.4;
/// Minimum conflicts between dynamic restarts (or blocked attempts).
const RESTART_MIN_CONFLICTS: u64 = 50;

/// Policy switches for the Glucose-class heuristics, all on by default.
///
/// The legacy policies stay selectable so `benches/sat_solver.rs` can
/// A/B old-vs-new on the same miter corpus. Every decision behind these
/// flags is a pure function of the conflict sequence — no wall-clock, no
/// randomness — so either setting preserves the clone-replay contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Heuristics {
    /// EMA-forced / trail-blocked dynamic restarts; `false` falls back
    /// to the fixed Luby×100 schedule.
    pub ema_restarts: bool,
    /// LBD-tiered learnt retention in `reduce_db`; `false` falls back to
    /// the pure activity sort.
    pub lbd_reduce: bool,
}

impl Default for Heuristics {
    fn default() -> Self {
        Heuristics { ema_restarts: true, lbd_reduce: true }
    }
}

impl Heuristics {
    /// The pre-Glucose policies (Luby restarts, activity-only reduce).
    pub fn legacy() -> Self {
        Heuristics { ema_restarts: false, lbd_reduce: false }
    }
}

/// Deterministic exponential moving average, seeded by its first sample
/// (no bias-correction clock, nothing time-dependent).
#[derive(Debug, Clone, Copy)]
struct Ema {
    val: f64,
    alpha: f64,
    seeded: bool,
}

impl Ema {
    fn new(alpha: f64) -> Ema {
        Ema { val: 0.0, alpha, seeded: false }
    }

    fn update(&mut self, x: f64) {
        if self.seeded {
            self.val += self.alpha * (x - self.val);
        } else {
            self.val = x;
            self.seeded = true;
        }
    }

    fn get(&self) -> f64 {
        self.val
    }
}

/// Solver statistics, exposed for the benches and EXPERIMENTS.md §Perf.
#[derive(Debug, Default, Clone)]
pub struct Stats {
    pub conflicts: u64,
    pub decisions: u64,
    pub propagations: u64,
    pub restarts: u64,
    pub learnt_literals: u64,
    pub deleted_clauses: u64,
    /// Arena compactions run by `reduce_db`.
    pub gc_runs: u64,
    /// `u32` words of clause storage reclaimed by compaction.
    pub arena_reclaimed_words: u64,
    /// Sum of learnt-clause LBDs at learn time; `lbd_sum / conflicts` is
    /// the mean glue, the quality measure the restart policy watches.
    pub lbd_sum: u64,
    /// Restarts the trail-size EMA vetoed (deep trail = likely close to
    /// a satisfying assignment, so the search was left running).
    pub restarts_blocked: u64,
    /// Failed-literal probes attempted by [`Solver::preprocess`].
    pub preprocess_probes: u64,
    /// Clauses deleted or strengthened by [`Solver::preprocess`]
    /// (root simplification + subsumption against binary clauses).
    pub preprocess_subsumed: u64,
}

impl Stats {
    /// Field-wise `self - base`, saturating at zero. The observe-only
    /// seam `obs` uses to fold per-cell solver effort into trace spans:
    /// snapshot before the solve, delta after, never mutate the solver.
    pub fn delta_since(&self, base: &Stats) -> Stats {
        Stats {
            conflicts: self.conflicts.saturating_sub(base.conflicts),
            decisions: self.decisions.saturating_sub(base.decisions),
            propagations: self.propagations.saturating_sub(base.propagations),
            restarts: self.restarts.saturating_sub(base.restarts),
            learnt_literals: self.learnt_literals.saturating_sub(base.learnt_literals),
            deleted_clauses: self.deleted_clauses.saturating_sub(base.deleted_clauses),
            gc_runs: self.gc_runs.saturating_sub(base.gc_runs),
            arena_reclaimed_words: self
                .arena_reclaimed_words
                .saturating_sub(base.arena_reclaimed_words),
            lbd_sum: self.lbd_sum.saturating_sub(base.lbd_sum),
            restarts_blocked: self.restarts_blocked.saturating_sub(base.restarts_blocked),
            preprocess_probes: self.preprocess_probes.saturating_sub(base.preprocess_probes),
            preprocess_subsumed: self
                .preprocess_subsumed
                .saturating_sub(base.preprocess_subsumed),
        }
    }
}

#[derive(Clone)]
pub struct Solver {
    arena: ClauseArena,
    learnts: Vec<CRef>,
    num_problem_clauses: usize,
    watches: Vec<Vec<Watcher>>, // indexed by Lit
    assign: Vec<Lbool>,         // indexed by Var
    level: Vec<u32>,
    reason: Vec<CRef>,
    trail: Vec<Lit>,
    trail_lim: Vec<usize>,
    qhead: usize,
    activity: Vec<f64>,
    var_inc: f64,
    cla_inc: f64,
    heap: VarHeap,
    polarity: Vec<bool>, // saved phases
    ok: bool,
    seen: Vec<bool>,
    conflict_core: Vec<Lit>,
    model: Vec<Lbool>,
    /// Scratch for `add_clause` normalisation (no per-clause allocation).
    add_tmp: Vec<Lit>,
    /// Root-level unit clauses, kept for `export_clauses` (units are
    /// enqueued directly and never reach the arena).
    root_units: Vec<Lit>,
    pub stats: Stats,
    /// Abort knob: give up (returning Unsat-as-timeout is wrong, so we
    /// surface `None` from `solve_limited`) after this many conflicts.
    pub conflict_budget: Option<u64>,
    /// Heuristic policy switches (Glucose-class defaults).
    pub heuristics: Heuristics,
    /// Stamp array for LBD computation, indexed by decision level and
    /// grown on demand (assumption levels can outrun the var count).
    lbd_seen: Vec<u64>,
    lbd_stamp: u64,
    /// Fast/slow EMAs over learnt-clause LBD. They persist across
    /// incremental solves, like the activities do, and clone with the
    /// solver — part of the replay snapshot.
    ema_lbd_fast: Ema,
    ema_lbd_slow: Ema,
    /// EMA over trail size at conflicts, for blocking restarts.
    ema_trail: Ema,
    /// [`Self::preprocess`] already ran (it is once-per-formula; clones
    /// inherit the flag, so the engine may call it unconditionally).
    preprocessed: bool,
}

impl Default for Solver {
    fn default() -> Self {
        Solver::new()
    }
}

impl Solver {
    pub fn new() -> Self {
        Solver {
            arena: ClauseArena::new(),
            learnts: Vec::new(),
            num_problem_clauses: 0,
            watches: Vec::new(),
            assign: Vec::new(),
            level: Vec::new(),
            reason: Vec::new(),
            trail: Vec::new(),
            trail_lim: Vec::new(),
            qhead: 0,
            activity: Vec::new(),
            var_inc: 1.0,
            cla_inc: 1.0,
            heap: VarHeap::default(),
            polarity: Vec::new(),
            ok: true,
            seen: Vec::new(),
            conflict_core: Vec::new(),
            model: Vec::new(),
            add_tmp: Vec::new(),
            root_units: Vec::new(),
            stats: Stats::default(),
            conflict_budget: None,
            heuristics: Heuristics::default(),
            lbd_seen: Vec::new(),
            lbd_stamp: 0,
            ema_lbd_fast: Ema::new(EMA_FAST_ALPHA),
            ema_lbd_slow: Ema::new(EMA_SLOW_ALPHA),
            ema_trail: Ema::new(EMA_SLOW_ALPHA),
            preprocessed: false,
        }
    }

    pub fn new_var(&mut self) -> Var {
        let v = self.assign.len() as Var;
        self.assign.push(Lbool::Undef);
        self.level.push(0);
        self.reason.push(REASON_NONE);
        self.activity.push(0.0);
        self.polarity.push(false);
        self.seen.push(false);
        self.watches.push(Vec::new());
        self.watches.push(Vec::new());
        self.heap.grow_to(self.assign.len());
        self.heap.insert(v, &self.activity);
        v
    }

    pub fn n_vars(&self) -> usize {
        self.assign.len()
    }

    /// Problem (non-learnt) clauses attached to the store. Root-level
    /// units are not counted (they live on the trail, not in the arena).
    pub fn n_clauses(&self) -> usize {
        self.num_problem_clauses
    }

    /// Total `u32` words of clause storage currently allocated.
    pub fn arena_len_words(&self) -> usize {
        self.arena.len_words()
    }

    /// Words flagged deleted but not yet reclaimed by compaction. Zero
    /// right after every `reduce_db` — compaction is immediate.
    pub fn arena_wasted_words(&self) -> usize {
        self.arena.wasted_words()
    }

    #[inline]
    fn value_lit(&self, l: Lit) -> Lbool {
        match self.assign[l.var() as usize] {
            Lbool::Undef => Lbool::Undef,
            Lbool::True => {
                if l.is_neg() {
                    Lbool::False
                } else {
                    Lbool::True
                }
            }
            Lbool::False => {
                if l.is_neg() {
                    Lbool::True
                } else {
                    Lbool::False
                }
            }
        }
    }

    /// Add a clause; returns `false` if the formula became trivially UNSAT.
    ///
    /// Streams straight into the clause arena: normalisation happens in a
    /// reused scratch buffer, so encoding a formula performs no per-clause
    /// heap allocation.
    pub fn add_clause(&mut self, lits: &[Lit]) -> bool {
        debug_assert_eq!(self.decision_level(), 0);
        if !self.ok {
            return false;
        }
        // Normalise: sort, dedup, drop false lits, detect tautology.
        let mut c = std::mem::take(&mut self.add_tmp);
        c.clear();
        c.extend_from_slice(lits);
        c.sort_unstable();
        c.dedup();
        // Sorted by `2*var + sign`, so complementary literals are
        // adjacent: a tautology is a same-var neighbour pair.
        let tautology = c.windows(2).any(|w| w[0].var() == w[1].var());
        let mut satisfied = false;
        let mut w = 0usize;
        if !tautology {
            for i in 0..c.len() {
                match self.value_lit(c[i]) {
                    Lbool::True => {
                        satisfied = true; // already true at level 0
                        break;
                    }
                    Lbool::False => {} // drop
                    Lbool::Undef => {
                        c[w] = c[i];
                        w += 1;
                    }
                }
            }
        }
        let result = if tautology || satisfied {
            true
        } else {
            match w {
                0 => {
                    self.ok = false;
                    false
                }
                1 => {
                    self.root_units.push(c[0]);
                    self.unchecked_enqueue(c[0], REASON_NONE);
                    self.ok = self.propagate().is_none();
                    self.ok
                }
                _ => {
                    c.truncate(w);
                    self.attach_clause(&c, false);
                    self.num_problem_clauses += 1;
                    true
                }
            }
        };
        self.add_tmp = c;
        result
    }

    fn attach_clause(&mut self, lits: &[Lit], learnt: bool) -> CRef {
        let r = self.arena.alloc(lits, learnt);
        let w0 = Watcher { clause: r, blocker: lits[1] };
        let w1 = Watcher { clause: r, blocker: lits[0] };
        self.watches[(!lits[0]).idx()].push(w0);
        self.watches[(!lits[1]).idx()].push(w1);
        if learnt {
            self.learnts.push(r);
        }
        r
    }

    /// Problem CNF currently in the store: root-level units plus every
    /// attached non-learnt clause (learnts are implied, so leaving them
    /// out keeps the export equivalent to the original formula). Used by
    /// the DIMACS dump path (`sat::dimacs`, `--dump-cnf`).
    pub fn export_clauses(&self) -> Vec<Vec<Lit>> {
        let mut out: Vec<Vec<Lit>> =
            self.root_units.iter().map(|&l| vec![l]).collect();
        for r in self.arena.refs() {
            if !self.arena.is_learnt(r) && !self.arena.is_deleted(r) {
                out.push(self.arena.lits(r).collect());
            }
        }
        if !self.ok {
            out.push(Vec::new());
        }
        out
    }

    #[inline]
    fn decision_level(&self) -> u32 {
        self.trail_lim.len() as u32
    }

    fn unchecked_enqueue(&mut self, l: Lit, reason: CRef) {
        debug_assert_eq!(self.value_lit(l), Lbool::Undef);
        self.assign[l.var() as usize] =
            if l.is_neg() { Lbool::False } else { Lbool::True };
        self.level[l.var() as usize] = self.decision_level();
        self.reason[l.var() as usize] = reason;
        self.trail.push(l);
    }

    /// Propagate; returns the conflicting clause, if any.
    fn propagate(&mut self) -> Option<CRef> {
        while self.qhead < self.trail.len() {
            let p = self.trail[self.qhead];
            self.qhead += 1;
            self.stats.propagations += 1;

            let mut i = 0usize;
            let mut j = 0usize;
            let mut ws = std::mem::take(&mut self.watches[p.idx()]);
            let mut conflict = None;
            'watchers: while i < ws.len() {
                let w = ws[i];
                i += 1;
                // Fast path: blocker already true.
                if self.value_lit(w.blocker) == Lbool::True {
                    ws[j] = w;
                    j += 1;
                    continue;
                }
                let cr = w.clause;
                // Deleted clauses are compacted away inside `reduce_db`,
                // so every watched clause is live here.
                debug_assert!(!self.arena.is_deleted(cr));
                // Make sure the false literal is at position 1.
                let false_lit = !p;
                if self.arena.lit(cr, 0) == false_lit {
                    self.arena.swap_lits(cr, 0, 1);
                }
                debug_assert_eq!(self.arena.lit(cr, 1), false_lit);
                let first = self.arena.lit(cr, 0);
                if first != w.blocker && self.value_lit(first) == Lbool::True {
                    ws[j] = Watcher { clause: cr, blocker: first };
                    j += 1;
                    continue;
                }
                // Look for a new literal to watch.
                let len = self.arena.len(cr);
                for k in 2..len {
                    let lk = self.arena.lit(cr, k);
                    if self.value_lit(lk) != Lbool::False {
                        self.arena.swap_lits(cr, 1, k);
                        self.watches[(!lk).idx()]
                            .push(Watcher { clause: cr, blocker: first });
                        continue 'watchers;
                    }
                }
                // Unit or conflicting.
                ws[j] = Watcher { clause: cr, blocker: first };
                j += 1;
                if self.value_lit(first) == Lbool::False {
                    // Conflict: copy remaining watchers back and stop.
                    while i < ws.len() {
                        ws[j] = ws[i];
                        j += 1;
                        i += 1;
                    }
                    conflict = Some(cr);
                } else {
                    self.unchecked_enqueue(first, cr);
                }
            }
            ws.truncate(j);
            self.watches[p.idx()] = ws;
            if let Some(c) = conflict {
                return Some(c);
            }
        }
        None
    }

    fn bump_var(&mut self, v: Var) {
        self.activity[v as usize] += self.var_inc;
        if self.activity[v as usize] > 1e100 {
            for a in self.activity.iter_mut() {
                *a *= 1e-100;
            }
            self.var_inc *= 1e-100;
        }
        self.heap.decrease_key(v, &self.activity);
    }

    fn bump_clause(&mut self, r: CRef) {
        let a = self.arena.activity(r) + self.cla_inc as f32;
        self.arena.set_activity(r, a);
        if a > 1e20 {
            for &lr in &self.learnts {
                let scaled = self.arena.activity(lr) * 1e-20;
                self.arena.set_activity(lr, scaled);
            }
            self.cla_inc *= 1e-20;
        }
    }

    /// Literals-block-distance of a literal set under the current
    /// assignment: the number of distinct non-root decision levels among
    /// the (assigned) literals. Glucose's clause-quality measure — a low
    /// LBD clause glues few levels together and keeps propagating across
    /// restarts.
    fn lits_lbd(&mut self, lits: &[Lit]) -> u32 {
        self.lbd_stamp += 1;
        let mut lbd = 0u32;
        for &l in lits {
            lbd += self.mark_level(l);
        }
        lbd
    }

    /// As [`Self::lits_lbd`], over an arena clause (no allocation).
    fn clause_lbd(&mut self, r: CRef) -> u32 {
        self.lbd_stamp += 1;
        let mut lbd = 0u32;
        for k in 0..self.arena.len(r) {
            let l = self.arena.lit(r, k);
            lbd += self.mark_level(l);
        }
        lbd
    }

    /// 1 if `l`'s decision level is non-root and unseen at the current
    /// stamp (marking it seen), 0 otherwise.
    #[inline]
    fn mark_level(&mut self, l: Lit) -> u32 {
        let lvl = self.level[l.var() as usize] as usize;
        if lvl == 0 {
            return 0;
        }
        if lvl >= self.lbd_seen.len() {
            self.lbd_seen.resize(lvl + 1, 0);
        }
        if self.lbd_seen[lvl] != self.lbd_stamp {
            self.lbd_seen[lvl] = self.lbd_stamp;
            1
        } else {
            0
        }
    }

    /// First-UIP conflict analysis. Returns (learnt clause, backtrack
    /// level, LBD of the learnt clause).
    fn analyze(&mut self, mut confl: CRef) -> (Vec<Lit>, u32, u32) {
        let mut learnt: Vec<Lit> = vec![Lit(0)]; // slot for the asserting lit
        let mut counter = 0u32;
        let mut p: Option<Lit> = None;
        let mut index = self.trail.len();

        loop {
            if self.arena.is_learnt(confl) {
                self.bump_clause(confl);
                // Glucose-style glue refresh: a learnt clause pulled
                // back into conflict analysis may span fewer decision
                // levels now than when it was learnt — keep the lower
                // value so the tiered reduce_db promotes it.
                if self.arena.lbd(confl) > CORE_LBD {
                    let cur = self.clause_lbd(confl);
                    if cur < self.arena.lbd(confl) {
                        self.arena.set_lbd(confl, cur);
                    }
                }
            }
            let start = if p.is_some() { 1 } else { 0 };
            for k in start..self.arena.len(confl) {
                let q = self.arena.lit(confl, k);
                let v = q.var() as usize;
                if !self.seen[v] && self.level[v] > 0 {
                    self.seen[v] = true;
                    self.bump_var(q.var());
                    if self.level[v] >= self.decision_level() {
                        counter += 1;
                    } else {
                        learnt.push(q);
                    }
                }
            }
            // Pick the next literal on the trail to resolve on.
            loop {
                index -= 1;
                if self.seen[self.trail[index].var() as usize] {
                    break;
                }
            }
            let pl = self.trail[index];
            self.seen[pl.var() as usize] = false;
            counter -= 1;
            if counter == 0 {
                learnt[0] = !pl;
                p = Some(pl);
                break;
            }
            confl = self.reason[pl.var() as usize];
            debug_assert_ne!(confl, REASON_NONE);
            p = Some(pl);
        }
        let _ = p;

        // Self-subsumption minimisation: drop lits whose reason clause is
        // fully covered by the rest of the learnt clause.
        for l in &learnt[1..] {
            self.seen[l.var() as usize] = true;
        }
        let keep: Vec<Lit> = learnt[1..]
            .iter()
            .copied()
            .filter(|&l| {
                let r = self.reason[l.var() as usize];
                if r == REASON_NONE {
                    return true;
                }
                self.arena.lits(r).any(|q| {
                    q.var() != l.var()
                        && !self.seen[q.var() as usize]
                        && self.level[q.var() as usize] > 0
                })
            })
            .collect();
        for l in &learnt[1..] {
            self.seen[l.var() as usize] = false;
        }
        let mut out = vec![learnt[0]];
        out.extend(keep);

        // Backtrack level = second-highest level in the clause.
        let bt = if out.len() == 1 {
            0
        } else {
            let mut max_i = 1;
            for k in 2..out.len() {
                if self.level[out[k].var() as usize] > self.level[out[max_i].var() as usize] {
                    max_i = k;
                }
            }
            out.swap(1, max_i);
            self.level[out[1].var() as usize]
        };
        self.stats.learnt_literals += out.len() as u64;
        // LBD is computed before backtracking, while every literal of
        // the learnt clause is still assigned.
        let lbd = self.lits_lbd(&out);
        (out, bt, lbd)
    }

    fn backtrack_to(&mut self, lvl: u32) {
        if self.decision_level() <= lvl {
            return;
        }
        let lim = self.trail_lim[lvl as usize];
        for k in (lim..self.trail.len()).rev() {
            let l = self.trail[k];
            let v = l.var() as usize;
            self.polarity[v] = !l.is_neg();
            self.assign[v] = Lbool::Undef;
            self.reason[v] = REASON_NONE;
            self.heap.insert(l.var(), &self.activity);
        }
        self.trail.truncate(lim);
        self.trail_lim.truncate(lvl as usize);
        self.qhead = self.trail.len();
    }

    fn pick_branch(&mut self) -> Option<Lit> {
        while let Some(v) = self.heap.pop_max(&self.activity) {
            if self.assign[v as usize] == Lbool::Undef {
                return Some(Lit::new(v, self.polarity[v as usize]));
            }
        }
        None
    }

    /// Halve the learnt-clause DB. The tiered policy (default) retains
    /// by glue: *core* clauses (LBD ≤ 2) are never candidates, and the
    /// rest is deleted worst-first by (LBD descending, activity
    /// ascending) — the high-LBD *local* tier drains before mid-glue
    /// *tier2* clauses, with activity only breaking ties inside an LBD
    /// band. The legacy policy is the pure activity sort.
    fn reduce_db(&mut self) {
        let tiered = self.heuristics.lbd_reduce;
        let mut order: Vec<CRef> = if tiered {
            self.learnts
                .iter()
                .copied()
                .filter(|&r| self.arena.lbd(r) > CORE_LBD)
                .collect()
        } else {
            self.learnts.clone()
        };
        // `total_cmp`, not `partial_cmp(..).unwrap()`: activities are
        // floats and the sort must never panic — a NaN/inf-poisoned
        // activity gets a defined position in the order instead of
        // aborting the whole solve.
        order.sort_by(|&a, &b| {
            let by_lbd = if tiered {
                self.arena.lbd(b).cmp(&self.arena.lbd(a))
            } else {
                std::cmp::Ordering::Equal
            };
            by_lbd.then(self.arena.activity(a).total_cmp(&self.arena.activity(b)))
        });
        let target = order.len() / 2;
        let mut removed = 0usize;
        for &r in order.iter() {
            if removed >= target {
                break;
            }
            if self.arena.len(r) <= 2 {
                continue; // keep short clauses
            }
            // Never delete a clause that is currently a reason.
            if self.reason[self.arena.lit(r, 0).var() as usize] == r {
                continue;
            }
            self.arena.delete(r);
            removed += 1;
        }
        self.stats.deleted_clauses += removed as u64;
        let arena = &self.arena;
        self.learnts.retain(|&r| !arena.is_deleted(r));
        self.garbage_collect();
    }

    /// Compact the arena, squeezing out the clauses `reduce_db` deleted,
    /// and remap every watcher / reason / learnt reference. Deleted
    /// learnts are actually reclaimed (the pre-arena representation
    /// tombstoned them in the clause list forever).
    fn garbage_collect(&mut self) {
        if self.arena.wasted_words() == 0 {
            return;
        }
        let (compacted, reclaimed) = self.arena.compact();
        let old = std::mem::replace(&mut self.arena, compacted);
        for ws in self.watches.iter_mut() {
            ws.retain_mut(|w| match old.forward(w.clause) {
                Some(nr) => {
                    w.clause = nr;
                    true
                }
                None => false, // watcher of a deleted clause
            });
        }
        for r in self.reason.iter_mut() {
            if *r != REASON_NONE {
                *r = old.forward(*r).expect("reason clauses survive reduce_db");
            }
        }
        for r in self.learnts.iter_mut() {
            *r = old.forward(*r).expect("learnt list was pruned before GC");
        }
        self.stats.gc_runs += 1;
        self.stats.arena_reclaimed_words += reclaimed as u64;
    }

    /// Once-per-formula preprocessing: root-level failed-literal probing
    /// plus subsumption / self-subsuming resolution against the binary
    /// clauses. Built for the miter-prototype workflow — run it on the
    /// prototype *before* cloning and every per-cell clone inherits the
    /// simplified formula, so the cost is amortised across the lattice.
    ///
    /// Every rewrite is model-preserving: probing only asserts units the
    /// formula already implies (unit propagation refutes the opposite
    /// phase), and strengthening/deleting a clause against a binary is
    /// plain resolution/subsumption — the set of satisfying assignments
    /// is untouched, so SAT/UNSAT answers and enumerated models cannot
    /// change feasibility. It is deterministic (fixed candidate order,
    /// bounded by a work *counter*, never by wall-clock) and idempotent
    /// (flag-guarded), so callers may invoke it unconditionally on both
    /// cold-built and cache-provided prototypes.
    pub fn preprocess(&mut self) {
        if self.preprocessed {
            return;
        }
        self.preprocessed = true;
        if !self.ok {
            return;
        }
        debug_assert_eq!(self.decision_level(), 0, "preprocess runs at root");
        // Root-level reasons are never resolved on again (analysis stops
        // at level 0), so clear them before clauses start moving — a
        // deleted clause must not be reachable through `reason`.
        self.clear_root_reasons();
        self.failed_literal_probing();
        if self.ok {
            self.subsume_with_binaries();
        }
        // Preprocessing may have deleted clauses that are satisfied by
        // *derived* root units; promote every root assignment into
        // `root_units` so `export_clauses` stays equivalent to the
        // original formula (the units are implied, so adding them is
        // always sound).
        for &l in &self.trail {
            if !self.root_units.contains(&l) {
                self.root_units.push(l);
            }
        }
        self.clear_root_reasons();
        self.garbage_collect();
    }

    /// Forget the reasons of root-level assignments. Safe at any point:
    /// level-0 variables are skipped by `analyze`, `analyze_final_conflict`
    /// and `core_from_lit`, and never unassigned by `backtrack_to`.
    fn clear_root_reasons(&mut self) {
        debug_assert_eq!(self.decision_level(), 0);
        for &l in &self.trail {
            self.reason[l.var() as usize] = REASON_NONE;
        }
    }

    /// Probe the negations of binary-clause literals (the only probes
    /// whose propagation can reach beyond one clause); a probe that unit
    /// propagates to a conflict proves the opposite literal at root.
    fn failed_literal_probing(&mut self) {
        // Deterministic candidate order: binary clauses in allocation
        // order, each contributing the negations of its two literals.
        let mut cand: Vec<Lit> = Vec::new();
        let mut is_cand = vec![false; 2 * self.n_vars()];
        for r in self.arena.refs() {
            if self.arena.is_learnt(r) || self.arena.is_deleted(r) || self.arena.len(r) != 2 {
                continue;
            }
            for k in 0..2 {
                let p = !self.arena.lit(r, k);
                if !is_cand[p.idx()] {
                    is_cand[p.idx()] = true;
                    cand.push(p);
                }
            }
        }
        for p in cand {
            if self.value_lit(p) != Lbool::Undef {
                continue;
            }
            self.stats.preprocess_probes += 1;
            self.trail_lim.push(self.trail.len());
            self.unchecked_enqueue(p, REASON_NONE);
            let failed = self.propagate().is_some();
            self.backtrack_to(0);
            if failed {
                // `p` refutes by unit propagation alone, so `!p` holds
                // in every model.
                self.unchecked_enqueue(!p, REASON_NONE);
                if self.propagate().is_some() {
                    self.ok = false;
                    return;
                }
            }
        }
    }

    /// Root simplification plus subsumption with the binary problem
    /// clauses as subsumers:
    /// * clauses satisfied at root are deleted, root-false literals are
    ///   stripped;
    /// * a binary `(x ∨ y)` deletes any other clause containing both `x`
    ///   and `y` (subsumption) and strengthens any clause containing
    ///   `¬x` alongside `y` by dropping `¬x` (self-subsuming
    ///   resolution).
    /// Bounded by a deterministic clause-visit budget, so huge miters
    /// pay a fixed, reproducible amount of work.
    fn subsume_with_binaries(&mut self) {
        // Pass 1: root cleanup under the (possibly probe-extended) root
        // assignment.
        let live: Vec<CRef> = self
            .arena
            .refs()
            .filter(|&r| !self.arena.is_learnt(r) && !self.arena.is_deleted(r))
            .collect();
        for r in live {
            if !self.ok {
                return;
            }
            let lits: Vec<Lit> = self.arena.lits(r).collect();
            if lits.iter().any(|&l| self.value_lit(l) != Lbool::Undef) {
                self.stats.preprocess_subsumed += 1;
                self.replace_problem_clause(r, &lits);
            }
        }
        // Pass 2: binary subsumption over occurrence lists, maintained
        // as strengthening rewrites clauses (new refs are appended; old
        // refs stay behind flagged deleted and are skipped).
        let mut occ: Vec<Vec<CRef>> = vec![Vec::new(); 2 * self.n_vars()];
        let mut binaries: Vec<CRef> = Vec::new();
        for r in self.arena.refs() {
            if self.arena.is_learnt(r) || self.arena.is_deleted(r) {
                continue;
            }
            for l in self.arena.lits(r) {
                occ[l.idx()].push(r);
            }
            if self.arena.len(r) == 2 {
                binaries.push(r);
            }
        }
        let mut fuel: u64 = 4_000_000; // clause visits, not wall-clock
        let mut bi = 0usize;
        while bi < binaries.len() {
            let b = binaries[bi];
            bi += 1;
            if !self.ok || fuel == 0 {
                return;
            }
            if self.arena.is_deleted(b) || self.arena.len(b) != 2 {
                continue;
            }
            let (x, y) = (self.arena.lit(b, 0), self.arena.lit(b, 1));
            // Clauses holding `x`: subsumed if they also hold `y`,
            // strengthened (drop `¬y`) if they hold `¬y`. Clauses
            // holding `¬x`: strengthened (drop `¬x`) if they hold `y`.
            for (probe, partner, drop) in [(x, y, !y), (!x, y, !x)] {
                let mut i = 0usize;
                while i < occ[probe.idx()].len() {
                    let c = occ[probe.idx()][i];
                    i += 1;
                    if c == b || self.arena.is_deleted(c) {
                        continue;
                    }
                    fuel = fuel.saturating_sub(1);
                    if fuel == 0 {
                        return;
                    }
                    let mut has_partner = false;
                    let mut has_drop = false;
                    for l in self.arena.lits(c) {
                        has_partner |= l == partner;
                        has_drop |= l == drop;
                    }
                    if probe == x && has_partner {
                        // {x, y} ⊆ c: subsumed by the binary.
                        self.stats.preprocess_subsumed += 1;
                        self.detach_clause(c);
                        self.delete_problem_clause(c);
                        continue;
                    }
                    if !has_drop || (probe != x && !has_partner) {
                        continue;
                    }
                    // Resolving c with (x ∨ y) on the dropped literal
                    // yields c \ {drop}: strengthen in place.
                    let kept: Vec<Lit> = self.arena.lits(c).filter(|&l| l != drop).collect();
                    self.stats.preprocess_subsumed += 1;
                    if let Some(nr) = self.replace_problem_clause(c, &kept) {
                        for k in 0..self.arena.len(nr) {
                            let l = self.arena.lit(nr, k);
                            occ[l.idx()].push(nr);
                        }
                        if self.arena.len(nr) == 2 {
                            binaries.push(nr);
                        }
                    }
                    if !self.ok {
                        return;
                    }
                }
            }
        }
    }

    /// Rewrite problem clause `r` as `lits`: detach and delete the old
    /// body, then re-add the replacement filtered against the root
    /// assignment exactly like `add_clause` filters (satisfied ⇒ gone,
    /// false literals ⇒ stripped, unit ⇒ enqueued and propagated, empty
    /// ⇒ UNSAT). Returns the new ref when the result is still a stored
    /// (≥ 2 literal) clause.
    fn replace_problem_clause(&mut self, r: CRef, lits: &[Lit]) -> Option<CRef> {
        self.detach_clause(r);
        self.delete_problem_clause(r);
        if lits.iter().any(|&l| self.value_lit(l) == Lbool::True) {
            return None; // satisfied at root: redundant, stays deleted
        }
        let kept: Vec<Lit> = lits
            .iter()
            .copied()
            .filter(|&l| self.value_lit(l) == Lbool::Undef)
            .collect();
        match kept.len() {
            0 => {
                self.ok = false;
                None
            }
            1 => {
                self.unchecked_enqueue(kept[0], REASON_NONE);
                if self.propagate().is_some() {
                    self.ok = false;
                }
                None
            }
            _ => {
                let nr = self.attach_clause(&kept, false);
                self.num_problem_clauses += 1;
                Some(nr)
            }
        }
    }

    fn delete_problem_clause(&mut self, r: CRef) {
        debug_assert!(!self.arena.is_learnt(r));
        self.arena.delete(r);
        self.num_problem_clauses -= 1;
    }

    /// Remove the two watcher entries of a live clause.
    fn detach_clause(&mut self, r: CRef) {
        for k in 0..2 {
            let w = !self.arena.lit(r, k);
            self.watches[w.idx()].retain(|e| e.clause != r);
        }
    }

    /// Solve under assumptions. `Some(Sat)`/`Some(Unsat)`, or `None` when
    /// the conflict budget ran out.
    pub fn solve_limited(&mut self, assumptions: &[Lit]) -> Option<SatResult> {
        if !self.ok {
            self.conflict_core.clear();
            return Some(SatResult::Unsat);
        }
        self.backtrack_to(0);
        self.model.clear();
        self.conflict_core.clear();

        let budget_start = self.stats.conflicts;
        let mut max_learnts = (self.n_clauses() as f64 * 0.4).max(1000.0);
        // Legacy restart schedule (`heuristics.ema_restarts == false`).
        let mut restart_idx = 0u64;
        let mut conflicts_until_restart = luby(restart_idx) * 100;
        // Dynamic restart schedule: conflicts since the last restart (or
        // blocked attempt) of this solve. The LBD/trail EMAs persist
        // across incremental solves, like the activities.
        let mut since_restart = 0u64;

        loop {
            if let Some(confl) = self.propagate() {
                if self.decision_level() == 0 {
                    self.ok = false;
                    return Some(SatResult::Unsat);
                }
                // Conflict inside the assumption prefix => UNSAT core.
                if self.decision_level() <= assumptions.len() as u32 {
                    self.analyze_final_conflict(confl, assumptions);
                    return Some(SatResult::Unsat);
                }
                // Budget check *before* analysis: a budget of `b`
                // processes exactly `b` conflicts — the `b+1`'th is
                // detected here and abandoned. (The old check sat after
                // the increment and used `>`, letting `b+1` through.)
                if let Some(b) = self.conflict_budget {
                    if self.stats.conflicts - budget_start >= b {
                        self.backtrack_to(0);
                        return None;
                    }
                }
                self.stats.conflicts += 1;
                since_restart += 1;
                conflicts_until_restart = conflicts_until_restart.saturating_sub(1);
                let trail_at_conflict = self.trail.len() as f64;
                let (learnt, bt, lbd) = self.analyze(confl);
                self.stats.lbd_sum += lbd as u64;
                self.ema_lbd_fast.update(lbd as f64);
                self.ema_lbd_slow.update(lbd as f64);
                self.ema_trail.update(trail_at_conflict);
                // Backjump possibly below the assumption prefix: the
                // decision loop re-asserts assumptions afterwards (and a
                // falsified assumption then yields the UNSAT core).
                self.backtrack_to(bt);
                if learnt.len() == 1 {
                    debug_assert_eq!(self.value_lit(learnt[0]), Lbool::Undef);
                    self.unchecked_enqueue(learnt[0], REASON_NONE);
                } else {
                    let r = self.attach_clause(&learnt, true);
                    self.arena.set_lbd(r, lbd);
                    let first = self.arena.lit(r, 0);
                    debug_assert_eq!(self.value_lit(first), Lbool::Undef);
                    self.unchecked_enqueue(first, r);
                }
                self.var_inc /= 0.95;
                self.cla_inc /= 0.999;
                if self.learnts.len() as f64 > max_learnts {
                    self.reduce_db();
                    max_learnts *= 1.1;
                }
            } else {
                let want_restart = if self.heuristics.ema_restarts {
                    since_restart >= RESTART_MIN_CONFLICTS
                        && self.ema_lbd_fast.get() > RESTART_FORCE_K * self.ema_lbd_slow.get()
                } else {
                    conflicts_until_restart == 0
                };
                if want_restart {
                    if self.heuristics.ema_restarts
                        && self.trail.len() as f64 > RESTART_BLOCK_R * self.ema_trail.get()
                    {
                        // Deep trail: the search looks close to a total
                        // assignment — let it run instead of restarting.
                        self.stats.restarts_blocked += 1;
                        since_restart = 0;
                    } else {
                        self.stats.restarts += 1;
                        since_restart = 0;
                        restart_idx += 1;
                        conflicts_until_restart = luby(restart_idx) * 100;
                        self.backtrack_to((assumptions.len() as u32).min(self.decision_level()));
                    }
                }
                // Assumption decisions first.
                let dl = self.decision_level() as usize;
                if dl < assumptions.len() {
                    let a = assumptions[dl];
                    match self.value_lit(a) {
                        Lbool::True => {
                            // Already implied: introduce an empty decision
                            // level so indices keep lining up.
                            self.trail_lim.push(self.trail.len());
                        }
                        Lbool::False => {
                            self.core_from_lit(!a, assumptions);
                            return Some(SatResult::Unsat);
                        }
                        Lbool::Undef => {
                            self.trail_lim.push(self.trail.len());
                            self.unchecked_enqueue(a, REASON_NONE);
                        }
                    }
                    continue;
                }
                match self.pick_branch() {
                    None => {
                        self.model = self.assign.clone();
                        self.backtrack_to(0);
                        return Some(SatResult::Sat);
                    }
                    Some(l) => {
                        self.stats.decisions += 1;
                        self.trail_lim.push(self.trail.len());
                        self.unchecked_enqueue(l, REASON_NONE);
                    }
                }
            }
        }
    }

    pub fn solve(&mut self, assumptions: &[Lit]) -> SatResult {
        self.solve_limited(assumptions).expect("no conflict budget set")
    }

    /// Walk reasons from a conflicting clause restricted to assumption
    /// levels, collecting the failed assumptions (the UNSAT core).
    fn analyze_final_conflict(&mut self, confl: CRef, assumptions: &[Lit]) {
        self.conflict_core.clear();
        let mut seen = vec![false; self.n_vars()];
        let mut stack: Vec<Lit> = self.arena.lits(confl).collect();
        while let Some(l) = stack.pop() {
            let v = l.var() as usize;
            if seen[v] || self.level[v] == 0 {
                continue;
            }
            seen[v] = true;
            let r = self.reason[v];
            if r == REASON_NONE {
                // Decision inside the assumption prefix.
                if assumptions.iter().any(|&a| a.var() == l.var()) {
                    self.conflict_core.push(!l);
                }
            } else {
                stack.extend(self.arena.lits(r));
            }
        }
        self.backtrack_to(0);
    }

    /// Core when an assumption literal is directly falsified.
    fn core_from_lit(&mut self, falsified: Lit, assumptions: &[Lit]) {
        self.conflict_core.clear();
        let mut seen = vec![false; self.n_vars()];
        let mut stack = vec![falsified];
        while let Some(l) = stack.pop() {
            let v = l.var() as usize;
            if seen[v] || self.level[v] == 0 {
                continue;
            }
            seen[v] = true;
            let r = self.reason[v];
            if r == REASON_NONE {
                if assumptions.iter().any(|&a| a.var() == l.var()) {
                    self.conflict_core.push(if assumptions.contains(&l) { l } else { !l });
                }
            } else {
                stack.extend(self.arena.lits(r));
            }
        }
        self.backtrack_to(0);
    }

    /// Failed assumptions of the last UNSAT answer.
    pub fn core(&self) -> &[Lit] {
        &self.conflict_core
    }

    /// Model value of a literal after a SAT answer.
    pub fn model_value(&self, l: Lit) -> bool {
        match self.model[l.var() as usize] {
            Lbool::True => !l.is_neg(),
            Lbool::False => l.is_neg(),
            Lbool::Undef => false, // don't-care: report false
        }
    }
}

/// Luby restart sequence (1,1,2,1,1,2,4,...), 0-indexed.
fn luby(i: u64) -> u64 {
    let mut i = i + 1;
    loop {
        let mut k = 1u64;
        while (1u64 << k) - 1 < i {
            k += 1;
        }
        if (1u64 << k) - 1 == i {
            return 1u64 << (k - 1);
        }
        i -= (1u64 << (k - 1)) - 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sat::arena::HEADER_WORDS;

    fn lit(v: Var, pos: bool) -> Lit {
        Lit::new(v, pos)
    }

    #[test]
    fn trivial_sat_and_model() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        assert!(s.add_clause(&[lit(a, true), lit(b, true)]));
        assert!(s.add_clause(&[lit(a, false)]));
        assert_eq!(s.solve(&[]), SatResult::Sat);
        assert!(!s.model_value(lit(a, true)));
        assert!(s.model_value(lit(b, true)));
    }

    #[test]
    fn trivial_unsat() {
        let mut s = Solver::new();
        let a = s.new_var();
        s.add_clause(&[lit(a, true)]);
        assert!(!s.add_clause(&[lit(a, false)]) || s.solve(&[]) == SatResult::Unsat);
    }

    #[test]
    fn empty_clause_is_unsat() {
        let mut s = Solver::new();
        let _ = s.new_var();
        assert!(!s.add_clause(&[]));
        assert_eq!(s.solve(&[]), SatResult::Unsat);
    }

    #[test]
    fn tautology_is_ignored() {
        let mut s = Solver::new();
        let a = s.new_var();
        assert!(s.add_clause(&[lit(a, true), lit(a, false)]));
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    /// Pigeonhole principle PHP(n+1, n): n+1 pigeons, n holes — UNSAT and
    /// requires real conflict analysis to close out.
    fn php(pigeons: usize, holes: usize) -> Solver {
        let mut s = Solver::new();
        let mut v = vec![vec![Lit(0); holes]; pigeons];
        for p in 0..pigeons {
            for h in 0..holes {
                v[p][h] = lit(s.new_var(), true);
            }
        }
        for p in 0..pigeons {
            s.add_clause(&v[p]);
        }
        for h in 0..holes {
            for p1 in 0..pigeons {
                for p2 in p1 + 1..pigeons {
                    s.add_clause(&[!v[p1][h], !v[p2][h]]);
                }
            }
        }
        s
    }

    #[test]
    fn pigeonhole_unsat() {
        for n in 2..=5 {
            let mut s = php(n + 1, n);
            assert_eq!(s.solve(&[]), SatResult::Unsat, "PHP({},{})", n + 1, n);
        }
    }

    #[test]
    fn pigeonhole_sat_when_enough_holes() {
        let mut s = php(4, 4);
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    #[test]
    fn assumptions_and_core() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        let c = s.new_var();
        // a & b -> c is inconsistent with assumptions a, b, !c.
        s.add_clause(&[lit(a, false), lit(b, false), lit(c, true)]);
        let assum = [lit(a, true), lit(b, true), lit(c, false)];
        assert_eq!(s.solve(&assum), SatResult::Unsat);
        let core = s.core().to_vec();
        assert!(!core.is_empty());
        assert!(core.iter().all(|l| assum.contains(l)), "core {core:?} ⊄ assumptions");
        // Without the blocking assumption it's SAT again (incremental reuse).
        assert_eq!(s.solve(&[lit(a, true), lit(b, true)]), SatResult::Sat);
        assert!(s.model_value(lit(c, true)));
    }

    #[test]
    fn incremental_solving_with_added_clauses() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        s.add_clause(&[lit(a, true), lit(b, true)]);
        assert_eq!(s.solve(&[]), SatResult::Sat);
        s.add_clause(&[lit(a, false)]);
        assert_eq!(s.solve(&[]), SatResult::Sat);
        assert!(s.model_value(lit(b, true)));
        s.add_clause(&[lit(b, false)]);
        assert_eq!(s.solve(&[]), SatResult::Unsat);
    }

    #[test]
    fn random_3sat_agrees_with_brute_force() {
        // Differential test on 10-var random instances.
        let mut state = 0x12345678u64;
        let mut rand = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u32
        };
        for _round in 0..30 {
            let n = 10usize;
            let n_clauses = 38; // near the phase transition
            let mut clauses = Vec::new();
            for _ in 0..n_clauses {
                let mut cl = Vec::new();
                for _ in 0..3 {
                    let v = rand() as usize % n;
                    cl.push(Lit::new(v as Var, rand() % 2 == 0));
                }
                clauses.push(cl);
            }
            // Brute force.
            let mut bf_sat = false;
            'outer: for m in 0..1u32 << n {
                for cl in &clauses {
                    if !cl.iter().any(|l| ((m >> l.var()) & 1 == 1) != l.is_neg() ) {
                        continue 'outer;
                    }
                }
                bf_sat = true;
                break;
            }
            let mut s = Solver::new();
            for _ in 0..n {
                s.new_var();
            }
            let mut ok = true;
            for cl in &clauses {
                ok &= s.add_clause(cl);
            }
            let got = if !ok { SatResult::Unsat } else { s.solve(&[]) };
            assert_eq!(got == SatResult::Sat, bf_sat, "instance {clauses:?}");
            if got == SatResult::Sat {
                // Verify the model actually satisfies the formula.
                for cl in &clauses {
                    assert!(cl.iter().any(|&l| s.model_value(l)));
                }
            }
        }
    }

    #[test]
    fn luby_sequence_prefix() {
        let got: Vec<u64> = (0..15).map(luby).collect();
        assert_eq!(got, vec![1, 1, 2, 1, 1, 2, 4, 1, 1, 2, 1, 1, 2, 4, 8]);
    }

    #[test]
    fn conflict_budget_returns_none_or_answer() {
        let mut s = php(7, 6); // hard-ish
        s.conflict_budget = Some(10);
        let r = s.solve_limited(&[]);
        // Either it finished fast or it gave up; both acceptable.
        if let Some(res) = r {
            assert_eq!(res, SatResult::Unsat);
        }
    }

    #[test]
    fn conflict_budget_runs_exactly_b_conflicts() {
        // A budget of `b` must process exactly `b` conflicts — the old
        // `> b` check after the increment let `b + 1` through, skewing
        // budget-parity comparisons by one conflict.
        for b in [0u64, 1, 10, 100] {
            let mut s = php(8, 7); // far out of reach for these budgets
            s.conflict_budget = Some(b);
            assert_eq!(s.solve_limited(&[]), None, "budget {b}");
            assert_eq!(s.stats.conflicts, b, "budget {b}: wrong conflict count");
        }
    }

    // ---- arena / clone / reduce_db behaviour ----

    /// Attach `count` synthetic learnt clauses with strictly increasing
    /// activities, returning their refs (test scaffolding for reduce_db).
    fn with_synthetic_learnts(count: usize) -> (Solver, Vec<CRef>) {
        let mut s = Solver::new();
        let vars: Vec<Var> = (0..20).map(|_| s.new_var()).collect();
        s.add_clause(&[Lit::pos(vars[0]), Lit::pos(vars[1])]);
        let mut refs = Vec::new();
        for i in 0..count {
            let cl = [
                Lit::pos(vars[2 + (i % 6)]),
                Lit::neg(vars[8 + (i % 6)]),
                Lit::pos(vars[14 + (i % 6)]),
            ];
            let r = s.attach_clause(&cl, true);
            s.arena.set_activity(r, i as f32);
            // Non-core glue, so the tiered policy treats them all as
            // deletion candidates and the activity tiebreak decides.
            s.arena.set_lbd(r, 7);
            refs.push(r);
        }
        (s, refs)
    }

    #[test]
    fn reduce_db_compacts_arena_and_reclaims_memory() {
        let (mut s, _) = with_synthetic_learnts(100);
        let words_before = s.arena_len_words();
        s.reduce_db();
        // Half the learnts (the low-activity ones) are gone — physically,
        // not as tombstones.
        assert_eq!(s.stats.deleted_clauses, 50);
        assert_eq!(s.learnts.len(), 50);
        assert_eq!(s.stats.gc_runs, 1);
        let clause_words = HEADER_WORDS + 3;
        assert_eq!(s.stats.arena_reclaimed_words, (50 * clause_words) as u64);
        assert_eq!(s.arena_len_words(), words_before - 50 * clause_words);
        assert_eq!(s.arena_wasted_words(), 0, "compaction must be immediate");
        // Survivors are the high-activity half and the solver still works.
        for &r in &s.learnts {
            assert!(s.arena.activity(r) >= 50.0);
        }
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    #[test]
    fn reduce_db_survives_non_finite_activities() {
        // The activity sort must not panic on NaN/inf (the pre-arena code
        // used partial_cmp().unwrap()); total_cmp gives non-finite values
        // a defined order and the solver stays sound.
        let (mut s, refs) = with_synthetic_learnts(40);
        s.arena.set_activity(refs[35], f32::NAN);
        s.arena.set_activity(refs[36], f32::INFINITY);
        s.arena.set_activity(refs[37], f32::NEG_INFINITY);
        s.reduce_db();
        assert_eq!(s.stats.deleted_clauses, 20);
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    #[test]
    fn activity_rescale_keeps_values_finite_under_heavy_search() {
        let mut s = php(8, 7);
        s.conflict_budget = Some(20_000);
        let _ = s.solve_limited(&[]);
        assert!(s.stats.conflicts > 1_000, "want a real conflict workout");
        assert!(s.var_inc.is_finite() && s.cla_inc.is_finite());
        assert!(s.activity.iter().all(|a| a.is_finite()));
        for &r in &s.learnts {
            assert!(s.arena.activity(r).is_finite());
        }
    }

    #[test]
    fn cloned_solver_replays_identically() {
        // Clone = snapshot: the copy must produce the same answer with
        // the same search trace (prototype-miter cloning relies on this).
        let orig = php(6, 5);
        let mut a = orig.clone();
        let mut b = orig.clone();
        assert_eq!(a.solve(&[]), SatResult::Unsat);
        assert_eq!(b.solve(&[]), SatResult::Unsat);
        assert_eq!(a.stats.conflicts, b.stats.conflicts);
        assert_eq!(a.stats.decisions, b.stats.decisions);
        assert_eq!(a.stats.propagations, b.stats.propagations);
        assert_eq!(a.stats.restarts, b.stats.restarts);
        assert_eq!(a.stats.restarts_blocked, b.stats.restarts_blocked);
        assert_eq!(a.stats.lbd_sum, b.stats.lbd_sum);
    }

    #[test]
    fn reduce_db_keeps_core_lbd_clauses() {
        let (mut s, refs) = with_synthetic_learnts(40);
        // Glue the four *coldest* clauses: core glue is exempt from
        // deletion no matter how low its activity is.
        for &r in &refs[..4] {
            s.arena.set_lbd(r, CORE_LBD);
        }
        s.reduce_db();
        // 36 candidates, half deleted; the four core clauses survive.
        assert_eq!(s.stats.deleted_clauses, 18);
        assert_eq!(s.learnts.len(), 22);
        let core: Vec<CRef> = s
            .learnts
            .iter()
            .copied()
            .filter(|&r| s.arena.lbd(r) <= CORE_LBD)
            .collect();
        assert_eq!(core.len(), 4);
        for &r in &core {
            assert!(s.arena.activity(r) < 4.0, "cold core clauses must survive");
        }
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    #[test]
    fn legacy_heuristics_still_solve() {
        let mut s = php(6, 5);
        s.heuristics = Heuristics::legacy();
        assert_eq!(s.solve(&[]), SatResult::Unsat);
        let mut t = php(4, 4);
        t.heuristics = Heuristics::legacy();
        assert_eq!(t.solve(&[]), SatResult::Sat);
    }

    #[test]
    fn restart_stats_are_deterministic_across_fresh_builds() {
        let mut a = php(7, 6);
        let mut b = php(7, 6);
        assert_eq!(a.solve(&[]), SatResult::Unsat);
        assert_eq!(b.solve(&[]), SatResult::Unsat);
        assert_eq!(a.stats.restarts, b.stats.restarts);
        assert_eq!(a.stats.restarts_blocked, b.stats.restarts_blocked);
        assert_eq!(a.stats.lbd_sum, b.stats.lbd_sum);
        assert!(a.stats.lbd_sum > 0, "every conflict contributes glue");
    }

    // ---- preprocessing ----

    #[test]
    fn probing_fixes_failed_literals_at_root() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        let c = s.new_var();
        // (a|b) & (a|!b): probing !a propagates b and !b into a conflict,
        // so `a` is implied and gets fixed at root.
        s.add_clause(&[lit(a, true), lit(b, true)]);
        s.add_clause(&[lit(a, true), lit(b, false)]);
        s.add_clause(&[lit(c, true), lit(b, true), lit(a, false)]);
        s.preprocess();
        assert!(s.stats.preprocess_probes > 0);
        assert_eq!(s.value_lit(lit(a, true)), Lbool::True, "a implied at root");
        assert_eq!(s.solve(&[]), SatResult::Sat);
        assert!(s.model_value(lit(a, true)));
    }

    #[test]
    fn preprocess_subsumes_and_strengthens_with_binaries() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        let c = s.new_var();
        let d = s.new_var();
        s.add_clause(&[lit(a, true), lit(b, true)]); // the subsumer
        s.add_clause(&[lit(a, true), lit(b, true), lit(c, true)]); // ⊇ {a,b}
        s.add_clause(&[lit(a, false), lit(b, true), lit(d, true)]); // → (b|d)
        assert_eq!(s.n_clauses(), 3);
        s.preprocess();
        assert_eq!(s.n_clauses(), 2, "one subsumed, one strengthened in place");
        assert!(s.stats.preprocess_subsumed >= 2);
        let exported = s.export_clauses();
        // Watch swaps during probing may reorder literals — compare sorted.
        let strengthened = exported.iter().any(|cl| {
            let mut c = cl.clone();
            c.sort_unstable();
            c == vec![lit(b, true), lit(d, true)]
        });
        assert!(strengthened, "self-subsuming resolution must drop !a: {exported:?}");
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    #[test]
    fn preprocess_is_flag_guarded_idempotent() {
        let mut s = php(6, 5);
        s.preprocess();
        let probes = s.stats.preprocess_probes;
        let subsumed = s.stats.preprocess_subsumed;
        let clauses = s.n_clauses();
        let words = s.arena_len_words();
        s.preprocess(); // second call must be a no-op
        assert_eq!(s.stats.preprocess_probes, probes);
        assert_eq!(s.stats.preprocess_subsumed, subsumed);
        assert_eq!(s.n_clauses(), clauses);
        assert_eq!(s.arena_len_words(), words);
        assert_eq!(s.solve(&[]), SatResult::Unsat);
    }

    #[test]
    fn random_3sat_with_preprocess_agrees_with_brute_force() {
        // Same differential harness as above, but every instance is
        // preprocessed first: probing + subsumption must never flip an
        // answer or produce a non-model.
        let mut state = 0x9e3779b9u64;
        let mut rand = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u32
        };
        for _round in 0..30 {
            let n = 10usize;
            let n_clauses = 38;
            let mut clauses = Vec::new();
            for _ in 0..n_clauses {
                let mut cl = Vec::new();
                for _ in 0..3 {
                    let v = rand() as usize % n;
                    cl.push(Lit::new(v as Var, rand() % 2 == 0));
                }
                clauses.push(cl);
            }
            let mut bf_sat = false;
            'outer: for m in 0..1u32 << n {
                for cl in &clauses {
                    if !cl.iter().any(|l| ((m >> l.var()) & 1 == 1) != l.is_neg()) {
                        continue 'outer;
                    }
                }
                bf_sat = true;
                break;
            }
            let mut s = Solver::new();
            for _ in 0..n {
                s.new_var();
            }
            let mut ok = true;
            for cl in &clauses {
                ok &= s.add_clause(cl);
            }
            s.preprocess();
            let got = if !ok { SatResult::Unsat } else { s.solve(&[]) };
            assert_eq!(got == SatResult::Sat, bf_sat, "instance {clauses:?}");
            if got == SatResult::Sat {
                for cl in &clauses {
                    assert!(cl.iter().any(|&l| s.model_value(l)), "broken model");
                }
            }
        }
    }

    #[test]
    fn preprocessed_export_stays_equivalent() {
        // Preprocessing rewrites the clause store; the export must still
        // describe the same formula (derived units are promoted into the
        // export so deleted-satisfied clauses stay covered).
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        let c = s.new_var();
        s.add_clause(&[lit(a, true), lit(b, true)]);
        s.add_clause(&[lit(a, true), lit(b, false)]);
        s.add_clause(&[lit(a, false), lit(c, true)]);
        s.preprocess(); // fixes a, strengthens/deletes the rest
        let exported = s.export_clauses();
        let mut t = Solver::new();
        for _ in 0..3 {
            t.new_var();
        }
        for cl in &exported {
            t.add_clause(cl);
        }
        for probe in [vec![], vec![lit(b, true)], vec![lit(c, false)], vec![lit(b, false)]] {
            assert_eq!(s.solve(&probe), t.solve(&probe), "probe {probe:?}");
        }
    }

    #[test]
    fn clone_after_solving_preserves_learnt_state() {
        let mut s = php(6, 5);
        assert_eq!(s.solve(&[]), SatResult::Unsat);
        let c = s.clone();
        assert_eq!(c.learnts.len(), s.learnts.len());
        assert_eq!(c.arena_len_words(), s.arena_len_words());
        assert_eq!(c.stats.conflicts, s.stats.conflicts);
    }

    #[test]
    fn export_clauses_round_trips_the_problem() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        let c = s.new_var();
        s.add_clause(&[lit(a, true)]); // root unit
        s.add_clause(&[lit(a, false), lit(b, true), lit(c, true)]);
        s.add_clause(&[lit(b, false), lit(c, false)]);
        let exported = s.export_clauses();
        assert_eq!(exported.len(), 3);
        assert!(exported.contains(&vec![lit(a, true)]));
        // A fresh solver over the export agrees on every assumption probe.
        let mut t = Solver::new();
        for _ in 0..3 {
            t.new_var();
        }
        for cl in &exported {
            t.add_clause(cl);
        }
        for probe in [vec![], vec![lit(b, true)], vec![lit(c, true)], vec![lit(b, false)]] {
            assert_eq!(s.solve(&probe), t.solve(&probe), "probe {probe:?}");
        }
    }

    #[test]
    fn export_excludes_learnts() {
        let mut s = php(6, 5);
        let before = s.export_clauses().len();
        assert_eq!(s.solve(&[]), SatResult::Unsat);
        assert!(s.stats.conflicts > 0, "UNSAT proof must have learnt something");
        // Solving learns clauses; the export surface must not grow (the
        // refutation adds only the empty-clause marker once `ok` drops).
        let after = s.export_clauses().iter().filter(|c| !c.is_empty()).count();
        assert_eq!(after, before);
    }
}

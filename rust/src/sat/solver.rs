//! The CDCL solver core.
//!
//! Clause storage is a flat `u32` arena ([`super::arena::ClauseArena`]):
//! watchers and reasons hold arena word offsets ([`CRef`]), `propagate`
//! reads literals adjacent to their header instead of chasing a heap
//! pointer per clause, `reduce_db` *compacts* the arena (deleted learnts
//! are reclaimed, not tombstoned), and the whole solver is `Clone` — a
//! handful of flat-buffer copies — which is what makes the build-once/
//! clone-cheap miter prototypes of `template::miter` viable.

use super::arena::{CRef, ClauseArena};
use super::heap::VarHeap;

/// Variable index (0-based).
pub type Var = u32;

/// Literal: `2*var + sign`, sign bit set for the negative literal.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Lit(pub u32);

impl Lit {
    #[inline]
    pub fn pos(v: Var) -> Lit {
        Lit(v << 1)
    }

    #[inline]
    pub fn neg(v: Var) -> Lit {
        Lit((v << 1) | 1)
    }

    /// Literal of `v` with the given truth value request: `Lit::new(v,
    /// true)` is satisfied when `v` is true.
    #[inline]
    pub fn new(v: Var, positive: bool) -> Lit {
        Lit((v << 1) | (!positive) as u32)
    }

    #[inline]
    pub fn var(self) -> Var {
        self.0 >> 1
    }

    #[inline]
    pub fn is_neg(self) -> bool {
        self.0 & 1 == 1
    }

    #[inline]
    pub fn inverted(self) -> Lit {
        Lit(self.0 ^ 1)
    }

    #[inline]
    fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::ops::Not for Lit {
    type Output = Lit;
    fn not(self) -> Lit {
        self.inverted()
    }
}

/// Three-valued assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lbool {
    True,
    False,
    Undef,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SatResult {
    Sat,
    Unsat,
}

#[derive(Debug, Clone, Copy)]
struct Watcher {
    clause: CRef,
    blocker: Lit,
}

const REASON_NONE: CRef = u32::MAX;

/// Solver statistics, exposed for the benches and EXPERIMENTS.md §Perf.
#[derive(Debug, Default, Clone)]
pub struct Stats {
    pub conflicts: u64,
    pub decisions: u64,
    pub propagations: u64,
    pub restarts: u64,
    pub learnt_literals: u64,
    pub deleted_clauses: u64,
    /// Arena compactions run by `reduce_db`.
    pub gc_runs: u64,
    /// `u32` words of clause storage reclaimed by compaction.
    pub arena_reclaimed_words: u64,
}

#[derive(Clone)]
pub struct Solver {
    arena: ClauseArena,
    learnts: Vec<CRef>,
    num_problem_clauses: usize,
    watches: Vec<Vec<Watcher>>, // indexed by Lit
    assign: Vec<Lbool>,         // indexed by Var
    level: Vec<u32>,
    reason: Vec<CRef>,
    trail: Vec<Lit>,
    trail_lim: Vec<usize>,
    qhead: usize,
    activity: Vec<f64>,
    var_inc: f64,
    cla_inc: f64,
    heap: VarHeap,
    polarity: Vec<bool>, // saved phases
    ok: bool,
    seen: Vec<bool>,
    conflict_core: Vec<Lit>,
    model: Vec<Lbool>,
    /// Scratch for `add_clause` normalisation (no per-clause allocation).
    add_tmp: Vec<Lit>,
    /// Root-level unit clauses, kept for `export_clauses` (units are
    /// enqueued directly and never reach the arena).
    root_units: Vec<Lit>,
    pub stats: Stats,
    /// Abort knob: give up (returning Unsat-as-timeout is wrong, so we
    /// surface `None` from `solve_limited`) after this many conflicts.
    pub conflict_budget: Option<u64>,
}

impl Default for Solver {
    fn default() -> Self {
        Solver::new()
    }
}

impl Solver {
    pub fn new() -> Self {
        Solver {
            arena: ClauseArena::new(),
            learnts: Vec::new(),
            num_problem_clauses: 0,
            watches: Vec::new(),
            assign: Vec::new(),
            level: Vec::new(),
            reason: Vec::new(),
            trail: Vec::new(),
            trail_lim: Vec::new(),
            qhead: 0,
            activity: Vec::new(),
            var_inc: 1.0,
            cla_inc: 1.0,
            heap: VarHeap::default(),
            polarity: Vec::new(),
            ok: true,
            seen: Vec::new(),
            conflict_core: Vec::new(),
            model: Vec::new(),
            add_tmp: Vec::new(),
            root_units: Vec::new(),
            stats: Stats::default(),
            conflict_budget: None,
        }
    }

    pub fn new_var(&mut self) -> Var {
        let v = self.assign.len() as Var;
        self.assign.push(Lbool::Undef);
        self.level.push(0);
        self.reason.push(REASON_NONE);
        self.activity.push(0.0);
        self.polarity.push(false);
        self.seen.push(false);
        self.watches.push(Vec::new());
        self.watches.push(Vec::new());
        self.heap.grow_to(self.assign.len());
        self.heap.insert(v, &self.activity);
        v
    }

    pub fn n_vars(&self) -> usize {
        self.assign.len()
    }

    /// Problem (non-learnt) clauses attached to the store. Root-level
    /// units are not counted (they live on the trail, not in the arena).
    pub fn n_clauses(&self) -> usize {
        self.num_problem_clauses
    }

    /// Total `u32` words of clause storage currently allocated.
    pub fn arena_len_words(&self) -> usize {
        self.arena.len_words()
    }

    /// Words flagged deleted but not yet reclaimed by compaction. Zero
    /// right after every `reduce_db` — compaction is immediate.
    pub fn arena_wasted_words(&self) -> usize {
        self.arena.wasted_words()
    }

    #[inline]
    fn value_lit(&self, l: Lit) -> Lbool {
        match self.assign[l.var() as usize] {
            Lbool::Undef => Lbool::Undef,
            Lbool::True => {
                if l.is_neg() {
                    Lbool::False
                } else {
                    Lbool::True
                }
            }
            Lbool::False => {
                if l.is_neg() {
                    Lbool::True
                } else {
                    Lbool::False
                }
            }
        }
    }

    /// Add a clause; returns `false` if the formula became trivially UNSAT.
    ///
    /// Streams straight into the clause arena: normalisation happens in a
    /// reused scratch buffer, so encoding a formula performs no per-clause
    /// heap allocation.
    pub fn add_clause(&mut self, lits: &[Lit]) -> bool {
        debug_assert_eq!(self.decision_level(), 0);
        if !self.ok {
            return false;
        }
        // Normalise: sort, dedup, drop false lits, detect tautology.
        let mut c = std::mem::take(&mut self.add_tmp);
        c.clear();
        c.extend_from_slice(lits);
        c.sort_unstable();
        c.dedup();
        // Sorted by `2*var + sign`, so complementary literals are
        // adjacent: a tautology is a same-var neighbour pair.
        let tautology = c.windows(2).any(|w| w[0].var() == w[1].var());
        let mut satisfied = false;
        let mut w = 0usize;
        if !tautology {
            for i in 0..c.len() {
                match self.value_lit(c[i]) {
                    Lbool::True => {
                        satisfied = true; // already true at level 0
                        break;
                    }
                    Lbool::False => {} // drop
                    Lbool::Undef => {
                        c[w] = c[i];
                        w += 1;
                    }
                }
            }
        }
        let result = if tautology || satisfied {
            true
        } else {
            match w {
                0 => {
                    self.ok = false;
                    false
                }
                1 => {
                    self.root_units.push(c[0]);
                    self.unchecked_enqueue(c[0], REASON_NONE);
                    self.ok = self.propagate().is_none();
                    self.ok
                }
                _ => {
                    c.truncate(w);
                    self.attach_clause(&c, false);
                    self.num_problem_clauses += 1;
                    true
                }
            }
        };
        self.add_tmp = c;
        result
    }

    fn attach_clause(&mut self, lits: &[Lit], learnt: bool) -> CRef {
        let r = self.arena.alloc(lits, learnt);
        let w0 = Watcher { clause: r, blocker: lits[1] };
        let w1 = Watcher { clause: r, blocker: lits[0] };
        self.watches[(!lits[0]).idx()].push(w0);
        self.watches[(!lits[1]).idx()].push(w1);
        if learnt {
            self.learnts.push(r);
        }
        r
    }

    /// Problem CNF currently in the store: root-level units plus every
    /// attached non-learnt clause (learnts are implied, so leaving them
    /// out keeps the export equivalent to the original formula). Used by
    /// the DIMACS dump path (`sat::dimacs`, `--dump-cnf`).
    pub fn export_clauses(&self) -> Vec<Vec<Lit>> {
        let mut out: Vec<Vec<Lit>> =
            self.root_units.iter().map(|&l| vec![l]).collect();
        for r in self.arena.refs() {
            if !self.arena.is_learnt(r) && !self.arena.is_deleted(r) {
                out.push(self.arena.lits(r).collect());
            }
        }
        if !self.ok {
            out.push(Vec::new());
        }
        out
    }

    #[inline]
    fn decision_level(&self) -> u32 {
        self.trail_lim.len() as u32
    }

    fn unchecked_enqueue(&mut self, l: Lit, reason: CRef) {
        debug_assert_eq!(self.value_lit(l), Lbool::Undef);
        self.assign[l.var() as usize] =
            if l.is_neg() { Lbool::False } else { Lbool::True };
        self.level[l.var() as usize] = self.decision_level();
        self.reason[l.var() as usize] = reason;
        self.trail.push(l);
    }

    /// Propagate; returns the conflicting clause, if any.
    fn propagate(&mut self) -> Option<CRef> {
        while self.qhead < self.trail.len() {
            let p = self.trail[self.qhead];
            self.qhead += 1;
            self.stats.propagations += 1;

            let mut i = 0usize;
            let mut j = 0usize;
            let mut ws = std::mem::take(&mut self.watches[p.idx()]);
            let mut conflict = None;
            'watchers: while i < ws.len() {
                let w = ws[i];
                i += 1;
                // Fast path: blocker already true.
                if self.value_lit(w.blocker) == Lbool::True {
                    ws[j] = w;
                    j += 1;
                    continue;
                }
                let cr = w.clause;
                // Deleted clauses are compacted away inside `reduce_db`,
                // so every watched clause is live here.
                debug_assert!(!self.arena.is_deleted(cr));
                // Make sure the false literal is at position 1.
                let false_lit = !p;
                if self.arena.lit(cr, 0) == false_lit {
                    self.arena.swap_lits(cr, 0, 1);
                }
                debug_assert_eq!(self.arena.lit(cr, 1), false_lit);
                let first = self.arena.lit(cr, 0);
                if first != w.blocker && self.value_lit(first) == Lbool::True {
                    ws[j] = Watcher { clause: cr, blocker: first };
                    j += 1;
                    continue;
                }
                // Look for a new literal to watch.
                let len = self.arena.len(cr);
                for k in 2..len {
                    let lk = self.arena.lit(cr, k);
                    if self.value_lit(lk) != Lbool::False {
                        self.arena.swap_lits(cr, 1, k);
                        self.watches[(!lk).idx()]
                            .push(Watcher { clause: cr, blocker: first });
                        continue 'watchers;
                    }
                }
                // Unit or conflicting.
                ws[j] = Watcher { clause: cr, blocker: first };
                j += 1;
                if self.value_lit(first) == Lbool::False {
                    // Conflict: copy remaining watchers back and stop.
                    while i < ws.len() {
                        ws[j] = ws[i];
                        j += 1;
                        i += 1;
                    }
                    conflict = Some(cr);
                } else {
                    self.unchecked_enqueue(first, cr);
                }
            }
            ws.truncate(j);
            self.watches[p.idx()] = ws;
            if let Some(c) = conflict {
                return Some(c);
            }
        }
        None
    }

    fn bump_var(&mut self, v: Var) {
        self.activity[v as usize] += self.var_inc;
        if self.activity[v as usize] > 1e100 {
            for a in self.activity.iter_mut() {
                *a *= 1e-100;
            }
            self.var_inc *= 1e-100;
        }
        self.heap.decrease_key(v, &self.activity);
    }

    fn bump_clause(&mut self, r: CRef) {
        let a = self.arena.activity(r) + self.cla_inc as f32;
        self.arena.set_activity(r, a);
        if a > 1e20 {
            for &lr in &self.learnts {
                let scaled = self.arena.activity(lr) * 1e-20;
                self.arena.set_activity(lr, scaled);
            }
            self.cla_inc *= 1e-20;
        }
    }

    /// First-UIP conflict analysis. Returns (learnt clause, backtrack level).
    fn analyze(&mut self, mut confl: CRef) -> (Vec<Lit>, u32) {
        let mut learnt: Vec<Lit> = vec![Lit(0)]; // slot for the asserting lit
        let mut counter = 0u32;
        let mut p: Option<Lit> = None;
        let mut index = self.trail.len();

        loop {
            if self.arena.is_learnt(confl) {
                self.bump_clause(confl);
            }
            let start = if p.is_some() { 1 } else { 0 };
            for k in start..self.arena.len(confl) {
                let q = self.arena.lit(confl, k);
                let v = q.var() as usize;
                if !self.seen[v] && self.level[v] > 0 {
                    self.seen[v] = true;
                    self.bump_var(q.var());
                    if self.level[v] >= self.decision_level() {
                        counter += 1;
                    } else {
                        learnt.push(q);
                    }
                }
            }
            // Pick the next literal on the trail to resolve on.
            loop {
                index -= 1;
                if self.seen[self.trail[index].var() as usize] {
                    break;
                }
            }
            let pl = self.trail[index];
            self.seen[pl.var() as usize] = false;
            counter -= 1;
            if counter == 0 {
                learnt[0] = !pl;
                p = Some(pl);
                break;
            }
            confl = self.reason[pl.var() as usize];
            debug_assert_ne!(confl, REASON_NONE);
            p = Some(pl);
        }
        let _ = p;

        // Self-subsumption minimisation: drop lits whose reason clause is
        // fully covered by the rest of the learnt clause.
        for l in &learnt[1..] {
            self.seen[l.var() as usize] = true;
        }
        let keep: Vec<Lit> = learnt[1..]
            .iter()
            .copied()
            .filter(|&l| {
                let r = self.reason[l.var() as usize];
                if r == REASON_NONE {
                    return true;
                }
                self.arena.lits(r).any(|q| {
                    q.var() != l.var()
                        && !self.seen[q.var() as usize]
                        && self.level[q.var() as usize] > 0
                })
            })
            .collect();
        for l in &learnt[1..] {
            self.seen[l.var() as usize] = false;
        }
        let mut out = vec![learnt[0]];
        out.extend(keep);

        // Backtrack level = second-highest level in the clause.
        let bt = if out.len() == 1 {
            0
        } else {
            let mut max_i = 1;
            for k in 2..out.len() {
                if self.level[out[k].var() as usize] > self.level[out[max_i].var() as usize] {
                    max_i = k;
                }
            }
            out.swap(1, max_i);
            self.level[out[1].var() as usize]
        };
        self.stats.learnt_literals += out.len() as u64;
        (out, bt)
    }

    fn backtrack_to(&mut self, lvl: u32) {
        if self.decision_level() <= lvl {
            return;
        }
        let lim = self.trail_lim[lvl as usize];
        for k in (lim..self.trail.len()).rev() {
            let l = self.trail[k];
            let v = l.var() as usize;
            self.polarity[v] = !l.is_neg();
            self.assign[v] = Lbool::Undef;
            self.reason[v] = REASON_NONE;
            self.heap.insert(l.var(), &self.activity);
        }
        self.trail.truncate(lim);
        self.trail_lim.truncate(lvl as usize);
        self.qhead = self.trail.len();
    }

    fn pick_branch(&mut self) -> Option<Lit> {
        while let Some(v) = self.heap.pop_max(&self.activity) {
            if self.assign[v as usize] == Lbool::Undef {
                return Some(Lit::new(v, self.polarity[v as usize]));
            }
        }
        None
    }

    fn reduce_db(&mut self) {
        let mut order: Vec<CRef> = self.learnts.clone();
        // `total_cmp`, not `partial_cmp(..).unwrap()`: activities are
        // floats and the sort must never panic — a NaN/inf-poisoned
        // activity gets a defined position in the order instead of
        // aborting the whole solve.
        order.sort_by(|&a, &b| {
            self.arena.activity(a).total_cmp(&self.arena.activity(b))
        });
        let target = order.len() / 2;
        let mut removed = 0usize;
        for &r in order.iter() {
            if removed >= target {
                break;
            }
            if self.arena.len(r) <= 2 {
                continue; // keep short clauses
            }
            // Never delete a clause that is currently a reason.
            if self.reason[self.arena.lit(r, 0).var() as usize] == r {
                continue;
            }
            self.arena.delete(r);
            removed += 1;
        }
        self.stats.deleted_clauses += removed as u64;
        let arena = &self.arena;
        self.learnts.retain(|&r| !arena.is_deleted(r));
        self.garbage_collect();
    }

    /// Compact the arena, squeezing out the clauses `reduce_db` deleted,
    /// and remap every watcher / reason / learnt reference. Deleted
    /// learnts are actually reclaimed (the pre-arena representation
    /// tombstoned them in the clause list forever).
    fn garbage_collect(&mut self) {
        if self.arena.wasted_words() == 0 {
            return;
        }
        let (compacted, reclaimed) = self.arena.compact();
        let old = std::mem::replace(&mut self.arena, compacted);
        for ws in self.watches.iter_mut() {
            ws.retain_mut(|w| match old.forward(w.clause) {
                Some(nr) => {
                    w.clause = nr;
                    true
                }
                None => false, // watcher of a deleted clause
            });
        }
        for r in self.reason.iter_mut() {
            if *r != REASON_NONE {
                *r = old.forward(*r).expect("reason clauses survive reduce_db");
            }
        }
        for r in self.learnts.iter_mut() {
            *r = old.forward(*r).expect("learnt list was pruned before GC");
        }
        self.stats.gc_runs += 1;
        self.stats.arena_reclaimed_words += reclaimed as u64;
    }

    /// Solve under assumptions. `Some(Sat)`/`Some(Unsat)`, or `None` when
    /// the conflict budget ran out.
    pub fn solve_limited(&mut self, assumptions: &[Lit]) -> Option<SatResult> {
        if !self.ok {
            self.conflict_core.clear();
            return Some(SatResult::Unsat);
        }
        self.backtrack_to(0);
        self.model.clear();
        self.conflict_core.clear();

        let budget_start = self.stats.conflicts;
        let mut max_learnts = (self.n_clauses() as f64 * 0.4).max(1000.0);
        let mut restart_idx = 0u64;
        let mut conflicts_until_restart = luby(restart_idx) * 100;

        loop {
            if let Some(confl) = self.propagate() {
                self.stats.conflicts += 1;
                conflicts_until_restart = conflicts_until_restart.saturating_sub(1);
                if self.decision_level() == 0 {
                    self.ok = false;
                    return Some(SatResult::Unsat);
                }
                // Conflict inside the assumption prefix => UNSAT core.
                if self.decision_level() <= assumptions.len() as u32 {
                    self.analyze_final_conflict(confl, assumptions);
                    return Some(SatResult::Unsat);
                }
                let (learnt, bt) = self.analyze(confl);
                // Backjump possibly below the assumption prefix: the
                // decision loop re-asserts assumptions afterwards (and a
                // falsified assumption then yields the UNSAT core).
                self.backtrack_to(bt);
                if learnt.len() == 1 {
                    debug_assert_eq!(self.value_lit(learnt[0]), Lbool::Undef);
                    self.unchecked_enqueue(learnt[0], REASON_NONE);
                } else {
                    let r = self.attach_clause(&learnt, true);
                    let first = self.arena.lit(r, 0);
                    debug_assert_eq!(self.value_lit(first), Lbool::Undef);
                    self.unchecked_enqueue(first, r);
                }
                self.var_inc /= 0.95;
                self.cla_inc /= 0.999;
                if self.learnts.len() as f64 > max_learnts {
                    self.reduce_db();
                    max_learnts *= 1.1;
                }
                if let Some(b) = self.conflict_budget {
                    if self.stats.conflicts - budget_start > b {
                        self.backtrack_to(0);
                        return None;
                    }
                }
            } else {
                if conflicts_until_restart == 0 {
                    self.stats.restarts += 1;
                    restart_idx += 1;
                    conflicts_until_restart = luby(restart_idx) * 100;
                    self.backtrack_to((assumptions.len() as u32).min(self.decision_level()));
                }
                // Assumption decisions first.
                let dl = self.decision_level() as usize;
                if dl < assumptions.len() {
                    let a = assumptions[dl];
                    match self.value_lit(a) {
                        Lbool::True => {
                            // Already implied: introduce an empty decision
                            // level so indices keep lining up.
                            self.trail_lim.push(self.trail.len());
                        }
                        Lbool::False => {
                            self.core_from_lit(!a, assumptions);
                            return Some(SatResult::Unsat);
                        }
                        Lbool::Undef => {
                            self.trail_lim.push(self.trail.len());
                            self.unchecked_enqueue(a, REASON_NONE);
                        }
                    }
                    continue;
                }
                match self.pick_branch() {
                    None => {
                        self.model = self.assign.clone();
                        self.backtrack_to(0);
                        return Some(SatResult::Sat);
                    }
                    Some(l) => {
                        self.stats.decisions += 1;
                        self.trail_lim.push(self.trail.len());
                        self.unchecked_enqueue(l, REASON_NONE);
                    }
                }
            }
        }
    }

    pub fn solve(&mut self, assumptions: &[Lit]) -> SatResult {
        self.solve_limited(assumptions).expect("no conflict budget set")
    }

    /// Walk reasons from a conflicting clause restricted to assumption
    /// levels, collecting the failed assumptions (the UNSAT core).
    fn analyze_final_conflict(&mut self, confl: CRef, assumptions: &[Lit]) {
        self.conflict_core.clear();
        let mut seen = vec![false; self.n_vars()];
        let mut stack: Vec<Lit> = self.arena.lits(confl).collect();
        while let Some(l) = stack.pop() {
            let v = l.var() as usize;
            if seen[v] || self.level[v] == 0 {
                continue;
            }
            seen[v] = true;
            let r = self.reason[v];
            if r == REASON_NONE {
                // Decision inside the assumption prefix.
                if assumptions.iter().any(|&a| a.var() == l.var()) {
                    self.conflict_core.push(!l);
                }
            } else {
                stack.extend(self.arena.lits(r));
            }
        }
        self.backtrack_to(0);
    }

    /// Core when an assumption literal is directly falsified.
    fn core_from_lit(&mut self, falsified: Lit, assumptions: &[Lit]) {
        self.conflict_core.clear();
        let mut seen = vec![false; self.n_vars()];
        let mut stack = vec![falsified];
        while let Some(l) = stack.pop() {
            let v = l.var() as usize;
            if seen[v] || self.level[v] == 0 {
                continue;
            }
            seen[v] = true;
            let r = self.reason[v];
            if r == REASON_NONE {
                if assumptions.iter().any(|&a| a.var() == l.var()) {
                    self.conflict_core.push(if assumptions.contains(&l) { l } else { !l });
                }
            } else {
                stack.extend(self.arena.lits(r));
            }
        }
        self.backtrack_to(0);
    }

    /// Failed assumptions of the last UNSAT answer.
    pub fn core(&self) -> &[Lit] {
        &self.conflict_core
    }

    /// Model value of a literal after a SAT answer.
    pub fn model_value(&self, l: Lit) -> bool {
        match self.model[l.var() as usize] {
            Lbool::True => !l.is_neg(),
            Lbool::False => l.is_neg(),
            Lbool::Undef => false, // don't-care: report false
        }
    }
}

/// Luby restart sequence (1,1,2,1,1,2,4,...), 0-indexed.
fn luby(i: u64) -> u64 {
    let mut i = i + 1;
    loop {
        let mut k = 1u64;
        while (1u64 << k) - 1 < i {
            k += 1;
        }
        if (1u64 << k) - 1 == i {
            return 1u64 << (k - 1);
        }
        i -= (1u64 << (k - 1)) - 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sat::arena::HEADER_WORDS;

    fn lit(v: Var, pos: bool) -> Lit {
        Lit::new(v, pos)
    }

    #[test]
    fn trivial_sat_and_model() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        assert!(s.add_clause(&[lit(a, true), lit(b, true)]));
        assert!(s.add_clause(&[lit(a, false)]));
        assert_eq!(s.solve(&[]), SatResult::Sat);
        assert!(!s.model_value(lit(a, true)));
        assert!(s.model_value(lit(b, true)));
    }

    #[test]
    fn trivial_unsat() {
        let mut s = Solver::new();
        let a = s.new_var();
        s.add_clause(&[lit(a, true)]);
        assert!(!s.add_clause(&[lit(a, false)]) || s.solve(&[]) == SatResult::Unsat);
    }

    #[test]
    fn empty_clause_is_unsat() {
        let mut s = Solver::new();
        let _ = s.new_var();
        assert!(!s.add_clause(&[]));
        assert_eq!(s.solve(&[]), SatResult::Unsat);
    }

    #[test]
    fn tautology_is_ignored() {
        let mut s = Solver::new();
        let a = s.new_var();
        assert!(s.add_clause(&[lit(a, true), lit(a, false)]));
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    /// Pigeonhole principle PHP(n+1, n): n+1 pigeons, n holes — UNSAT and
    /// requires real conflict analysis to close out.
    fn php(pigeons: usize, holes: usize) -> Solver {
        let mut s = Solver::new();
        let mut v = vec![vec![Lit(0); holes]; pigeons];
        for p in 0..pigeons {
            for h in 0..holes {
                v[p][h] = lit(s.new_var(), true);
            }
        }
        for p in 0..pigeons {
            s.add_clause(&v[p]);
        }
        for h in 0..holes {
            for p1 in 0..pigeons {
                for p2 in p1 + 1..pigeons {
                    s.add_clause(&[!v[p1][h], !v[p2][h]]);
                }
            }
        }
        s
    }

    #[test]
    fn pigeonhole_unsat() {
        for n in 2..=5 {
            let mut s = php(n + 1, n);
            assert_eq!(s.solve(&[]), SatResult::Unsat, "PHP({},{})", n + 1, n);
        }
    }

    #[test]
    fn pigeonhole_sat_when_enough_holes() {
        let mut s = php(4, 4);
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    #[test]
    fn assumptions_and_core() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        let c = s.new_var();
        // a & b -> c is inconsistent with assumptions a, b, !c.
        s.add_clause(&[lit(a, false), lit(b, false), lit(c, true)]);
        let assum = [lit(a, true), lit(b, true), lit(c, false)];
        assert_eq!(s.solve(&assum), SatResult::Unsat);
        let core = s.core().to_vec();
        assert!(!core.is_empty());
        assert!(core.iter().all(|l| assum.contains(l)), "core {core:?} ⊄ assumptions");
        // Without the blocking assumption it's SAT again (incremental reuse).
        assert_eq!(s.solve(&[lit(a, true), lit(b, true)]), SatResult::Sat);
        assert!(s.model_value(lit(c, true)));
    }

    #[test]
    fn incremental_solving_with_added_clauses() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        s.add_clause(&[lit(a, true), lit(b, true)]);
        assert_eq!(s.solve(&[]), SatResult::Sat);
        s.add_clause(&[lit(a, false)]);
        assert_eq!(s.solve(&[]), SatResult::Sat);
        assert!(s.model_value(lit(b, true)));
        s.add_clause(&[lit(b, false)]);
        assert_eq!(s.solve(&[]), SatResult::Unsat);
    }

    #[test]
    fn random_3sat_agrees_with_brute_force() {
        // Differential test on 10-var random instances.
        let mut state = 0x12345678u64;
        let mut rand = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u32
        };
        for _round in 0..30 {
            let n = 10usize;
            let n_clauses = 38; // near the phase transition
            let mut clauses = Vec::new();
            for _ in 0..n_clauses {
                let mut cl = Vec::new();
                for _ in 0..3 {
                    let v = rand() as usize % n;
                    cl.push(Lit::new(v as Var, rand() % 2 == 0));
                }
                clauses.push(cl);
            }
            // Brute force.
            let mut bf_sat = false;
            'outer: for m in 0..1u32 << n {
                for cl in &clauses {
                    if !cl.iter().any(|l| ((m >> l.var()) & 1 == 1) != l.is_neg() ) {
                        continue 'outer;
                    }
                }
                bf_sat = true;
                break;
            }
            let mut s = Solver::new();
            for _ in 0..n {
                s.new_var();
            }
            let mut ok = true;
            for cl in &clauses {
                ok &= s.add_clause(cl);
            }
            let got = if !ok { SatResult::Unsat } else { s.solve(&[]) };
            assert_eq!(got == SatResult::Sat, bf_sat, "instance {clauses:?}");
            if got == SatResult::Sat {
                // Verify the model actually satisfies the formula.
                for cl in &clauses {
                    assert!(cl.iter().any(|&l| s.model_value(l)));
                }
            }
        }
    }

    #[test]
    fn luby_sequence_prefix() {
        let got: Vec<u64> = (0..15).map(luby).collect();
        assert_eq!(got, vec![1, 1, 2, 1, 1, 2, 4, 1, 1, 2, 1, 1, 2, 4, 8]);
    }

    #[test]
    fn conflict_budget_returns_none_or_answer() {
        let mut s = php(7, 6); // hard-ish
        s.conflict_budget = Some(10);
        let r = s.solve_limited(&[]);
        // Either it finished fast or it gave up; both acceptable.
        if let Some(res) = r {
            assert_eq!(res, SatResult::Unsat);
        }
    }

    // ---- arena / clone / reduce_db behaviour ----

    /// Attach `count` synthetic learnt clauses with strictly increasing
    /// activities, returning their refs (test scaffolding for reduce_db).
    fn with_synthetic_learnts(count: usize) -> (Solver, Vec<CRef>) {
        let mut s = Solver::new();
        let vars: Vec<Var> = (0..20).map(|_| s.new_var()).collect();
        s.add_clause(&[Lit::pos(vars[0]), Lit::pos(vars[1])]);
        let mut refs = Vec::new();
        for i in 0..count {
            let cl = [
                Lit::pos(vars[2 + (i % 6)]),
                Lit::neg(vars[8 + (i % 6)]),
                Lit::pos(vars[14 + (i % 6)]),
            ];
            let r = s.attach_clause(&cl, true);
            s.arena.set_activity(r, i as f32);
            refs.push(r);
        }
        (s, refs)
    }

    #[test]
    fn reduce_db_compacts_arena_and_reclaims_memory() {
        let (mut s, _) = with_synthetic_learnts(100);
        let words_before = s.arena_len_words();
        s.reduce_db();
        // Half the learnts (the low-activity ones) are gone — physically,
        // not as tombstones.
        assert_eq!(s.stats.deleted_clauses, 50);
        assert_eq!(s.learnts.len(), 50);
        assert_eq!(s.stats.gc_runs, 1);
        let clause_words = HEADER_WORDS + 3;
        assert_eq!(s.stats.arena_reclaimed_words, (50 * clause_words) as u64);
        assert_eq!(s.arena_len_words(), words_before - 50 * clause_words);
        assert_eq!(s.arena_wasted_words(), 0, "compaction must be immediate");
        // Survivors are the high-activity half and the solver still works.
        for &r in &s.learnts {
            assert!(s.arena.activity(r) >= 50.0);
        }
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    #[test]
    fn reduce_db_survives_non_finite_activities() {
        // The activity sort must not panic on NaN/inf (the pre-arena code
        // used partial_cmp().unwrap()); total_cmp gives non-finite values
        // a defined order and the solver stays sound.
        let (mut s, refs) = with_synthetic_learnts(40);
        s.arena.set_activity(refs[35], f32::NAN);
        s.arena.set_activity(refs[36], f32::INFINITY);
        s.arena.set_activity(refs[37], f32::NEG_INFINITY);
        s.reduce_db();
        assert_eq!(s.stats.deleted_clauses, 20);
        assert_eq!(s.solve(&[]), SatResult::Sat);
    }

    #[test]
    fn activity_rescale_keeps_values_finite_under_heavy_search() {
        let mut s = php(8, 7);
        s.conflict_budget = Some(20_000);
        let _ = s.solve_limited(&[]);
        assert!(s.stats.conflicts > 1_000, "want a real conflict workout");
        assert!(s.var_inc.is_finite() && s.cla_inc.is_finite());
        assert!(s.activity.iter().all(|a| a.is_finite()));
        for &r in &s.learnts {
            assert!(s.arena.activity(r).is_finite());
        }
    }

    #[test]
    fn cloned_solver_replays_identically() {
        // Clone = snapshot: the copy must produce the same answer with
        // the same search trace (prototype-miter cloning relies on this).
        let orig = php(6, 5);
        let mut a = orig.clone();
        let mut b = orig.clone();
        assert_eq!(a.solve(&[]), SatResult::Unsat);
        assert_eq!(b.solve(&[]), SatResult::Unsat);
        assert_eq!(a.stats.conflicts, b.stats.conflicts);
        assert_eq!(a.stats.decisions, b.stats.decisions);
        assert_eq!(a.stats.propagations, b.stats.propagations);
    }

    #[test]
    fn clone_after_solving_preserves_learnt_state() {
        let mut s = php(6, 5);
        assert_eq!(s.solve(&[]), SatResult::Unsat);
        let c = s.clone();
        assert_eq!(c.learnts.len(), s.learnts.len());
        assert_eq!(c.arena_len_words(), s.arena_len_words());
        assert_eq!(c.stats.conflicts, s.stats.conflicts);
    }

    #[test]
    fn export_clauses_round_trips_the_problem() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        let c = s.new_var();
        s.add_clause(&[lit(a, true)]); // root unit
        s.add_clause(&[lit(a, false), lit(b, true), lit(c, true)]);
        s.add_clause(&[lit(b, false), lit(c, false)]);
        let exported = s.export_clauses();
        assert_eq!(exported.len(), 3);
        assert!(exported.contains(&vec![lit(a, true)]));
        // A fresh solver over the export agrees on every assumption probe.
        let mut t = Solver::new();
        for _ in 0..3 {
            t.new_var();
        }
        for cl in &exported {
            t.add_clause(cl);
        }
        for probe in [vec![], vec![lit(b, true)], vec![lit(c, true)], vec![lit(b, false)]] {
            assert_eq!(s.solve(&probe), t.solve(&probe), "probe {probe:?}");
        }
    }

    #[test]
    fn export_excludes_learnts() {
        let mut s = php(6, 5);
        let before = s.export_clauses().len();
        assert_eq!(s.solve(&[]), SatResult::Unsat);
        assert!(s.stats.conflicts > 0, "UNSAT proof must have learnt something");
        // Solving learns clauses; the export surface must not grow (the
        // refutation adds only the empty-clause marker once `ok` drops).
        let after = s.export_clauses().iter().filter(|c| !c.is_empty()).count();
        assert_eq!(after, before);
    }
}

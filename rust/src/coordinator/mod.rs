//! L3 coordinator: the experiment orchestrator that owns the process
//! event loop. Jobs (benchmark × method × ET) run on a std::thread
//! worker pool (the build environment vendors no tokio; SAT search is
//! CPU-bound, so threads + channels are the right tool anyway — see
//! Cargo.toml note), results stream back over a channel and are
//! aggregated into the figure series that `report` renders.

pub mod jobs;
pub mod sweep;

pub use jobs::{run_job, run_job_cached, run_job_obs, run_job_with, Job, Method, RunRecord};
pub use sweep::{
    failed_record, panic_message, probe_store, probe_store_obs, run_sweep, run_sweep_obs,
    run_sweep_stored, run_sweep_with, wal_persistable, StoreProbe, SweepPlan,
};

//! One experiment job: a (benchmark, method, ET) triple, producing the
//! figures' raw numbers.
//!
//! [`RunRecord`] round-trips through [`util::Json`](crate::util::Json)
//! (`to_json`/`from_json`) so the persistent store (`store::wal`) can
//! write records as JSONL and serve them back on resumed sweeps.

use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::baselines::{mecals, muscat};
use crate::circuit::generators::{benchmark_by_name, Benchmark};
use crate::circuit::sim::TruthTables;
use crate::search::{MiterCache, SearchConfig};
use crate::synth::synthesize_area;
use crate::util::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Shared,
    Xpat,
    Muscat,
    Mecals,
    Exact,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Shared => "SHARED",
            Method::Xpat => "XPAT",
            Method::Muscat => "MUSCAT",
            Method::Mecals => "MECALS",
            Method::Exact => "EXACT",
        }
    }

    /// Inverse of [`Method::name`] (the form stored in WALs and CSVs).
    pub fn from_name(name: &str) -> Option<Method> {
        match name {
            "SHARED" => Some(Method::Shared),
            "XPAT" => Some(Method::Xpat),
            "MUSCAT" => Some(Method::Muscat),
            "MECALS" => Some(Method::Mecals),
            "EXACT" => Some(Method::Exact),
            _ => None,
        }
    }

    pub fn all_compared() -> [Method; 4] {
        [Method::Shared, Method::Xpat, Method::Muscat, Method::Mecals]
    }
}

#[derive(Debug, Clone)]
pub struct Job {
    pub bench: &'static Benchmark,
    pub method: Method,
    pub et: u64,
    pub search: SearchConfig,
}

/// One figure point (Fig. 5 keeps the best per job; Fig. 4 additionally
/// uses `all_points` for the multi-solution scatter).
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    pub bench: &'static str,
    pub method: Method,
    pub et: u64,
    pub area: f64,
    pub max_err: u64,
    pub mean_err: f64,
    /// (PIT, ITS) for SHARED, (LPP, PPO) for XPAT, (0, 0) otherwise.
    pub proxy: (usize, usize),
    pub elapsed_ms: u64,
    /// Served from the persistent store instead of solved this run
    /// (`coordinator::sweep::run_sweep_stored`). Cached records report
    /// `elapsed_ms = 0`.
    pub cached: bool,
    /// The winning operator's exhaustive output table (`2^n` entries) —
    /// what `store::oplib` exports for the NN layer. Empty when the job
    /// produced no operator (failed, infeasible).
    pub values: Vec<u64>,
    /// Every enumerated solution: (proxy.0, proxy.1, area).
    pub all_points: Vec<(usize, usize, f64)>,
    /// `Some(message)` when the job crashed instead of completing (the
    /// sweep records the failure and carries on; see `sweep::run_sweep`).
    /// Failed jobs report `area = inf` so figure renderers skip them.
    pub error: Option<String>,
}

/// JSON has no ±inf/NaN: non-finite floats are stored as tagged strings.
fn f64_to_json(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        Json::Str("nan".into())
    } else if x > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

fn f64_from_json(j: &Json, what: &str) -> Result<f64> {
    match j {
        Json::Num(x) => Ok(*x),
        Json::Str(s) => match s.as_str() {
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "nan" => Ok(f64::NAN),
            other => bail!("{what}: bad float string {other:?}"),
        },
        other => bail!("{what}: expected number, got {other:?}"),
    }
}

/// `u64` travels as a JSON number. Exact for every value that occurs in
/// records (≤ 2^53), and the `u64::MAX` failure sentinel survives too:
/// it rounds to 2^64 as f64 and the saturating cast brings it back.
fn u64_from_json(j: &Json, what: &str) -> Result<u64> {
    j.as_u64().ok_or_else(|| anyhow!("{what}: expected unsigned integer"))
}

fn usize_from_json(j: &Json, what: &str) -> Result<usize> {
    Ok(u64_from_json(j, what)? as usize)
}

/// Resolve a deserialized benchmark name to a `&'static str`. Paper
/// benchmarks map to their static names; unknown names (stores written
/// against custom circuits) are interned — each distinct name leaks
/// exactly once per process, however many WAL records carry it or how
/// often the store is reopened — a deliberate trade for keeping
/// `RunRecord` borrow-free.
fn static_bench_name(name: &str) -> &'static str {
    if let Some(b) = benchmark_by_name(name) {
        return b.name;
    }
    static INTERNED: std::sync::Mutex<std::collections::BTreeSet<&'static str>> =
        std::sync::Mutex::new(std::collections::BTreeSet::new());
    let mut set = INTERNED.lock().unwrap();
    if let Some(&interned) = set.get(name) {
        return interned;
    }
    let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
    set.insert(leaked);
    leaked
}

impl RunRecord {
    /// Serialize for the store WAL. Deterministic (sorted keys, ASCII,
    /// single line) so identical records render byte-identically.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("bench".to_string(), Json::Str(self.bench.to_string()));
        m.insert("method".to_string(), Json::Str(self.method.name().to_string()));
        m.insert("et".to_string(), Json::Num(self.et as f64));
        m.insert("area".to_string(), f64_to_json(self.area));
        m.insert("max_err".to_string(), Json::Num(self.max_err as f64));
        m.insert("mean_err".to_string(), f64_to_json(self.mean_err));
        m.insert(
            "proxy".to_string(),
            Json::Arr(vec![
                Json::Num(self.proxy.0 as f64),
                Json::Num(self.proxy.1 as f64),
            ]),
        );
        m.insert("elapsed_ms".to_string(), Json::Num(self.elapsed_ms as f64));
        m.insert("cached".to_string(), Json::Bool(self.cached));
        m.insert(
            "values".to_string(),
            Json::Arr(self.values.iter().map(|&v| Json::Num(v as f64)).collect()),
        );
        m.insert(
            "all_points".to_string(),
            Json::Arr(
                self.all_points
                    .iter()
                    .map(|&(a, b, area)| {
                        Json::Arr(vec![
                            Json::Num(a as f64),
                            Json::Num(b as f64),
                            f64_to_json(area),
                        ])
                    })
                    .collect(),
            ),
        );
        m.insert(
            "error".to_string(),
            match &self.error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }

    /// Inverse of [`RunRecord::to_json`].
    pub fn from_json(j: &Json) -> Result<RunRecord> {
        let get = |key: &str| {
            j.get(key).ok_or_else(|| anyhow!("record missing field {key:?}"))
        };
        let bench_name = get("bench")?
            .as_str()
            .ok_or_else(|| anyhow!("bench: expected string"))?;
        let method_name = get("method")?
            .as_str()
            .ok_or_else(|| anyhow!("method: expected string"))?;
        let method = Method::from_name(method_name)
            .ok_or_else(|| anyhow!("unknown method {method_name:?}"))?;
        let proxy_arr = get("proxy")?
            .as_arr()
            .ok_or_else(|| anyhow!("proxy: expected array"))?;
        if proxy_arr.len() != 2 {
            bail!("proxy: expected 2 entries, got {}", proxy_arr.len());
        }
        let values = get("values")?
            .as_arr()
            .ok_or_else(|| anyhow!("values: expected array"))?
            .iter()
            .map(|v| u64_from_json(v, "values[]"))
            .collect::<Result<Vec<u64>>>()?;
        let all_points = get("all_points")?
            .as_arr()
            .ok_or_else(|| anyhow!("all_points: expected array"))?
            .iter()
            .map(|p| -> Result<(usize, usize, f64)> {
                let t = p
                    .as_arr()
                    .ok_or_else(|| anyhow!("all_points[]: expected array"))?;
                if t.len() != 3 {
                    bail!("all_points[]: expected 3 entries");
                }
                Ok((
                    usize_from_json(&t[0], "all_points[].0")?,
                    usize_from_json(&t[1], "all_points[].1")?,
                    f64_from_json(&t[2], "all_points[].2")?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let error = match get("error")? {
            Json::Null => None,
            Json::Str(s) => Some(s.clone()),
            other => bail!("error: expected string or null, got {other:?}"),
        };
        Ok(RunRecord {
            bench: static_bench_name(bench_name),
            method,
            et: u64_from_json(get("et")?, "et")?,
            area: f64_from_json(get("area")?, "area")?,
            max_err: u64_from_json(get("max_err")?, "max_err")?,
            mean_err: f64_from_json(get("mean_err")?, "mean_err")?,
            proxy: (
                usize_from_json(&proxy_arr[0], "proxy.0")?,
                usize_from_json(&proxy_arr[1], "proxy.1")?,
            ),
            elapsed_ms: u64_from_json(get("elapsed_ms")?, "elapsed_ms")?,
            cached: get("cached")?
                .as_bool()
                .ok_or_else(|| anyhow!("cached: expected bool"))?,
            values,
            all_points,
            error,
        })
    }

    /// Parse one WAL-line payload.
    pub fn parse(src: &str) -> Result<RunRecord> {
        RunRecord::from_json(&Json::parse(src).context("record JSON")?)
    }
}

/// Execute one job. Every produced circuit is re-verified against the
/// exhaustive oracle before being reported (defence in depth on top of
/// each method's own guarantee).
pub fn run_job(job: &Job) -> RunRecord {
    run_job_cached(job, &MiterCache::new())
}

/// As [`run_job`], sourcing template-method miter prototypes from a
/// shared [`MiterCache`] so a sweep encodes each geometry once. Cache
/// hits are result-invisible (prototypes are pristine); baseline methods
/// ignore the cache.
pub fn run_job_cached(job: &Job, protos: &MiterCache) -> RunRecord {
    let nl = job.bench.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    run_job_with(job, protos, &exact)
}

/// As [`run_job_cached`], with the benchmark's exhaustive truth table
/// supplied by the caller. The sweep computes `exact` once per job — it
/// is the store fingerprint input, the miter-cache geometry key, the
/// miter encoder input and the soundness oracle — and this seam keeps it
/// a single simulation instead of three. `exact` MUST be the exhaustive
/// output table of `job.bench.netlist()`.
pub fn run_job_with(job: &Job, protos: &MiterCache, exact: &[u64]) -> RunRecord {
    run_job_obs(job, protos, exact, &crate::obs::Obs::off())
}

/// As [`run_job_with`], threading an observability handle into the
/// template search so the lattice engine can emit per-cell solve spans
/// (with folded solver-stats deltas). Baseline methods ignore the
/// handle. Observe-only: the handle never influences the search.
pub fn run_job_obs(
    job: &Job,
    protos: &MiterCache,
    exact: &[u64],
    obs: &crate::obs::Obs,
) -> RunRecord {
    let nl = job.bench.netlist();
    debug_assert_eq!(exact.len(), 1usize << nl.n_inputs());
    let start = Instant::now();
    let rec = match job.method {
        Method::Exact => RunRecord {
            bench: job.bench.name,
            method: job.method,
            et: job.et,
            area: synthesize_area(&nl),
            max_err: 0,
            mean_err: 0.0,
            proxy: (0, 0),
            elapsed_ms: 0,
            cached: false,
            values: exact.to_vec(),
            all_points: Vec::new(),
            error: None,
        },
        Method::Shared | Method::Xpat => {
            let out = if job.method == Method::Shared {
                protos.search_shared_obs(&nl, job.et, &job.search, exact, obs)
            } else {
                protos.search_xpat_obs(&nl, job.et, &job.search, exact, obs)
            };
            let all_points: Vec<(usize, usize, f64)> = out
                .solutions
                .iter()
                .map(|s| (s.proxy.0, s.proxy.1, s.area))
                .collect();
            match out.best() {
                Some(best) => {
                    let vals = best.params.output_values();
                    let sound = exact
                        .iter()
                        .zip(&vals)
                        .all(|(&e, &a)| e.abs_diff(a) <= job.et);
                    assert!(sound, "unsound solution escaped the search");
                    RunRecord {
                        bench: job.bench.name,
                        method: job.method,
                        et: job.et,
                        area: best.area,
                        max_err: best.max_err,
                        mean_err: best.mean_err,
                        proxy: best.proxy,
                        elapsed_ms: 0,
                        cached: false,
                        values: vals,
                        all_points,
                        error: None,
                    }
                }
                None => RunRecord {
                    bench: job.bench.name,
                    method: job.method,
                    et: job.et,
                    area: f64::INFINITY,
                    max_err: u64::MAX,
                    mean_err: f64::INFINITY,
                    proxy: (0, 0),
                    elapsed_ms: 0,
                    cached: false,
                    values: Vec::new(),
                    all_points,
                    error: None,
                },
            }
        }
        Method::Muscat | Method::Mecals => {
            let res = if job.method == Method::Muscat {
                muscat(&nl, job.et)
            } else {
                mecals(&nl, job.et)
            };
            let vals = TruthTables::simulate(&res.netlist)
                .output_values(&res.netlist);
            assert!(
                exact.iter().zip(&vals).all(|(&e, &a)| e.abs_diff(a) <= job.et),
                "unsound baseline result"
            );
            RunRecord {
                bench: job.bench.name,
                method: job.method,
                et: job.et,
                area: res.area,
                max_err: res.max_err,
                mean_err: res.mean_err,
                proxy: (0, 0),
                elapsed_ms: 0,
                cached: false,
                values: vals,
                all_points: Vec::new(),
                error: None,
            }
        }
    };
    RunRecord { elapsed_ms: start.elapsed().as_millis() as u64, ..rec }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::benchmark_by_name;

    fn quick() -> SearchConfig {
        SearchConfig {
            pool: 6,
            solutions_per_cell: 2,
            max_sat_cells: 2,
            conflict_budget: Some(50_000),
            time_budget_ms: 20_000,
            ..Default::default()
        }
    }

    #[test]
    fn all_methods_produce_sound_records_on_adder_i4() {
        let bench = benchmark_by_name("adder_i4").unwrap();
        let exact = TruthTables::simulate(&bench.netlist())
            .output_values(&bench.netlist());
        for method in Method::all_compared() {
            let rec = run_job(&Job { bench, method, et: 2, search: quick() });
            assert!(rec.area.is_finite(), "{}", method.name());
            assert!(rec.max_err <= 2, "{}", method.name());
            assert!(!rec.cached, "{}", method.name());
            // The exported operator table must itself be sound.
            assert_eq!(rec.values.len(), exact.len(), "{}", method.name());
            assert!(
                exact.iter().zip(&rec.values).all(|(&e, &a)| e.abs_diff(a) <= 2),
                "{}: exported values unsound",
                method.name()
            );
        }
    }

    #[test]
    fn exact_method_reports_reference_area() {
        let bench = benchmark_by_name("mult_i4").unwrap();
        let rec = run_job(&Job { bench, method: Method::Exact, et: 0, search: quick() });
        let direct = synthesize_area(&bench.netlist());
        assert_eq!(rec.area, direct);
        assert_eq!(rec.max_err, 0);
    }

    #[test]
    fn template_methods_report_scatter_points() {
        let bench = benchmark_by_name("adder_i4").unwrap();
        let rec = run_job(&Job {
            bench,
            method: Method::Shared,
            et: 1,
            search: quick(),
        });
        assert!(!rec.all_points.is_empty());
        assert!(rec.all_points.iter().any(|&(_, _, a)| a == rec.area));
    }

    #[test]
    fn method_name_round_trip() {
        for m in [
            Method::Shared,
            Method::Xpat,
            Method::Muscat,
            Method::Mecals,
            Method::Exact,
        ] {
            assert_eq!(Method::from_name(m.name()), Some(m));
        }
        assert_eq!(Method::from_name("shared"), None);
    }

    #[test]
    fn record_json_round_trip() {
        let rec = RunRecord {
            bench: "adder_i4",
            method: Method::Shared,
            et: 2,
            area: 12.5,
            max_err: 2,
            mean_err: 0.75,
            proxy: (3, 4),
            elapsed_ms: 17,
            cached: false,
            values: vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15],
            all_points: vec![(3, 4, 12.5), (4, 5, 13.0)],
            error: None,
        };
        let back = RunRecord::parse(&rec.to_json().render()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn failed_record_json_round_trip() {
        // The failure shape: inf area, u64::MAX max_err, an error string
        // with characters that need escaping.
        let rec = RunRecord {
            bench: "mult_i6",
            method: Method::Xpat,
            et: 8,
            area: f64::INFINITY,
            max_err: u64::MAX,
            mean_err: f64::INFINITY,
            proxy: (0, 0),
            elapsed_ms: 3,
            cached: false,
            values: Vec::new(),
            all_points: Vec::new(),
            error: Some("panicked: \"index\\out of bounds\"\nat line 3".into()),
        };
        let text = rec.to_json().render();
        assert!(text.is_ascii());
        let back = RunRecord::parse(&text).unwrap();
        assert_eq!(back, rec);
    }
}

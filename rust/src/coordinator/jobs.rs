//! One experiment job: a (benchmark, method, ET) triple, producing the
//! figures' raw numbers.

use std::time::Instant;

use crate::baselines::{mecals, muscat};
use crate::circuit::generators::Benchmark;
use crate::circuit::sim::TruthTables;
use crate::search::{MiterCache, SearchConfig};
use crate::synth::synthesize_area;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Shared,
    Xpat,
    Muscat,
    Mecals,
    Exact,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Shared => "SHARED",
            Method::Xpat => "XPAT",
            Method::Muscat => "MUSCAT",
            Method::Mecals => "MECALS",
            Method::Exact => "EXACT",
        }
    }

    pub fn all_compared() -> [Method; 4] {
        [Method::Shared, Method::Xpat, Method::Muscat, Method::Mecals]
    }
}

#[derive(Debug, Clone)]
pub struct Job {
    pub bench: &'static Benchmark,
    pub method: Method,
    pub et: u64,
    pub search: SearchConfig,
}

/// One figure point (Fig. 5 keeps the best per job; Fig. 4 additionally
/// uses `all_points` for the multi-solution scatter).
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub bench: &'static str,
    pub method: Method,
    pub et: u64,
    pub area: f64,
    pub max_err: u64,
    pub mean_err: f64,
    /// (PIT, ITS) for SHARED, (LPP, PPO) for XPAT, (0, 0) otherwise.
    pub proxy: (usize, usize),
    pub elapsed_ms: u64,
    /// Every enumerated solution: (proxy.0, proxy.1, area).
    pub all_points: Vec<(usize, usize, f64)>,
    /// `Some(message)` when the job crashed instead of completing (the
    /// sweep records the failure and carries on; see `sweep::run_sweep`).
    /// Failed jobs report `area = inf` so figure renderers skip them.
    pub error: Option<String>,
}

/// Execute one job. Every produced circuit is re-verified against the
/// exhaustive oracle before being reported (defence in depth on top of
/// each method's own guarantee).
pub fn run_job(job: &Job) -> RunRecord {
    run_job_cached(job, &MiterCache::new())
}

/// As [`run_job`], sourcing template-method miter prototypes from a
/// shared [`MiterCache`] so a sweep encodes each geometry once. Cache
/// hits are result-invisible (prototypes are pristine); baseline methods
/// ignore the cache.
pub fn run_job_cached(job: &Job, protos: &MiterCache) -> RunRecord {
    let nl = job.bench.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    let start = Instant::now();
    let rec = match job.method {
        Method::Exact => RunRecord {
            bench: job.bench.name,
            method: job.method,
            et: job.et,
            area: synthesize_area(&nl),
            max_err: 0,
            mean_err: 0.0,
            proxy: (0, 0),
            elapsed_ms: 0,
            all_points: Vec::new(),
            error: None,
        },
        Method::Shared | Method::Xpat => {
            let out = if job.method == Method::Shared {
                protos.search_shared(&nl, job.et, &job.search)
            } else {
                protos.search_xpat(&nl, job.et, &job.search)
            };
            let all_points: Vec<(usize, usize, f64)> = out
                .solutions
                .iter()
                .map(|s| (s.proxy.0, s.proxy.1, s.area))
                .collect();
            match out.best() {
                Some(best) => {
                    let vals = best.params.output_values();
                    let sound = exact
                        .iter()
                        .zip(&vals)
                        .all(|(&e, &a)| e.abs_diff(a) <= job.et);
                    assert!(sound, "unsound solution escaped the search");
                    RunRecord {
                        bench: job.bench.name,
                        method: job.method,
                        et: job.et,
                        area: best.area,
                        max_err: best.max_err,
                        mean_err: best.mean_err,
                        proxy: best.proxy,
                        elapsed_ms: 0,
                        all_points,
                        error: None,
                    }
                }
                None => RunRecord {
                    bench: job.bench.name,
                    method: job.method,
                    et: job.et,
                    area: f64::INFINITY,
                    max_err: u64::MAX,
                    mean_err: f64::INFINITY,
                    proxy: (0, 0),
                    elapsed_ms: 0,
                    all_points,
                    error: None,
                },
            }
        }
        Method::Muscat | Method::Mecals => {
            let res = if job.method == Method::Muscat {
                muscat(&nl, job.et)
            } else {
                mecals(&nl, job.et)
            };
            let vals = TruthTables::simulate(&res.netlist)
                .output_values(&res.netlist);
            assert!(
                exact.iter().zip(&vals).all(|(&e, &a)| e.abs_diff(a) <= job.et),
                "unsound baseline result"
            );
            RunRecord {
                bench: job.bench.name,
                method: job.method,
                et: job.et,
                area: res.area,
                max_err: res.max_err,
                mean_err: res.mean_err,
                proxy: (0, 0),
                elapsed_ms: 0,
                all_points: Vec::new(),
                error: None,
            }
        }
    };
    RunRecord { elapsed_ms: start.elapsed().as_millis() as u64, ..rec }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::benchmark_by_name;

    fn quick() -> SearchConfig {
        SearchConfig {
            pool: 6,
            solutions_per_cell: 2,
            max_sat_cells: 2,
            conflict_budget: Some(50_000),
            time_budget_ms: 20_000,
            ..Default::default()
        }
    }

    #[test]
    fn all_methods_produce_sound_records_on_adder_i4() {
        let bench = benchmark_by_name("adder_i4").unwrap();
        for method in Method::all_compared() {
            let rec = run_job(&Job { bench, method, et: 2, search: quick() });
            assert!(rec.area.is_finite(), "{}", method.name());
            assert!(rec.max_err <= 2, "{}", method.name());
        }
    }

    #[test]
    fn exact_method_reports_reference_area() {
        let bench = benchmark_by_name("mult_i4").unwrap();
        let rec = run_job(&Job { bench, method: Method::Exact, et: 0, search: quick() });
        let direct = synthesize_area(&bench.netlist());
        assert_eq!(rec.area, direct);
        assert_eq!(rec.max_err, 0);
    }

    #[test]
    fn template_methods_report_scatter_points() {
        let bench = benchmark_by_name("adder_i4").unwrap();
        let rec = run_job(&Job {
            bench,
            method: Method::Shared,
            et: 1,
            search: quick(),
        });
        assert!(!rec.all_points.is_empty());
        assert!(rec.all_points.iter().any(|&(_, _, a)| a == rec.area));
    }
}

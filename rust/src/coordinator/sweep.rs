//! Parallel sweep execution: fan a job list out over a worker pool and
//! collect records in a deterministic order.
//!
//! Parallelism is nested: `SweepPlan::workers` threads run jobs, and
//! each job's lattice scan may itself use
//! `SearchConfig::cell_workers` threads (`search::engine`), so the
//! process-wide thread budget is `workers × cell_workers`. The `sweep`
//! CLI keeps that product near the machine's core count by shrinking
//! the outer pool when `--cell-workers` is raised.
//!
//! A job that panics does not take down the sweep: the panic is caught
//! on the worker, recorded as a [`RunRecord`] with
//! `error: Some(message)` and `area = inf`, and the remaining jobs run
//! to completion.
//!
//! Template-method jobs share one [`MiterCache`] per sweep: the first
//! job of a geometry (benchmark × ET × pool) encodes the miter, every
//! later same-geometry job clones the prototype instead of re-encoding.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::circuit::generators::{Benchmark, PAPER_BENCHMARKS};
use crate::circuit::sim::TruthTables;
use crate::obs::{metrics, Obs};
use crate::search::{MiterCache, SearchConfig};
use crate::store::{job_fingerprint, Fingerprint, Store};
use crate::util::Json;

use super::jobs::{run_job_obs, Job, Method, RunRecord};

/// A declarative sweep: which benchmarks, methods and ET values to run.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub benches: Vec<&'static Benchmark>,
    pub methods: Vec<Method>,
    /// `None` = each benchmark's paper ET sweep; `Some(v)` = fixed list.
    pub ets: Option<Vec<u64>>,
    pub search: SearchConfig,
    pub workers: usize,
}

impl Default for SweepPlan {
    fn default() -> Self {
        SweepPlan {
            benches: PAPER_BENCHMARKS.iter().collect(),
            methods: Method::all_compared().to_vec(),
            ets: None,
            search: SearchConfig::default(),
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        }
    }
}

impl SweepPlan {
    /// Lazy job enumeration in the canonical order (benchmark, method,
    /// ET) — the job *index* in this order is the identity the
    /// distributed coordinator leases by and the slot every record
    /// commits into, so changing the order is a wire-compatibility
    /// break. Pull-based: a million-job plan costs nothing until
    /// pulled, which is what lets the coordinator keep at most one
    /// unleased job materialized.
    pub fn job_iter(&self) -> impl Iterator<Item = Job> + '_ {
        self.benches.iter().flat_map(move |&bench| {
            let ets = self.ets.clone().unwrap_or_else(|| bench.et_sweep());
            self.methods.iter().flat_map(move |&method| {
                let search = self.search.clone();
                ets.clone().into_iter().map(move |et| Job {
                    bench,
                    method,
                    et,
                    search: search.clone(),
                })
            })
        })
    }

    /// Total job count, without materializing any job.
    pub fn n_jobs(&self) -> usize {
        let ets_for = |b: &Benchmark| match &self.ets {
            Some(v) => v.len(),
            None => b.et_sweep().len(),
        };
        self.benches.iter().map(|&b| ets_for(b) * self.methods.len()).sum()
    }

    pub fn jobs(&self) -> Vec<Job> {
        self.job_iter().collect()
    }
}

/// Record standing in for a job that crashed or was lost to a dead
/// worker: infinite area (the markdown renderer shows those as "—", and
/// the CSVs carry them verbatim alongside the error column so nothing is
/// silently dropped) plus the failure message. Shared with the
/// distributed fabric (`dist`), whose remote workers and reject-capped
/// jobs fail with exactly the same shape.
pub fn failed_record(job: &Job, message: String) -> RunRecord {
    RunRecord {
        bench: job.bench.name,
        method: job.method,
        et: job.et,
        area: f64::INFINITY,
        max_err: u64::MAX,
        mean_err: f64::INFINITY,
        proxy: (0, 0),
        elapsed_ms: 0,
        cached: false,
        values: Vec::new(),
        all_points: Vec::new(),
        error: Some(message),
    }
}

/// Human-readable text out of a panic payload (shared with `dist`'s
/// worker loop, which catches job panics the same way the local pool
/// does).
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked".to_string()
    }
}

/// Run the plan on a worker pool; records return in job order. All jobs
/// share one miter-prototype cache, so each distinct geometry is encoded
/// once per sweep.
pub fn run_sweep(plan: &SweepPlan) -> Vec<RunRecord> {
    run_sweep_stored(plan, None)
}

/// As [`run_sweep`], backed by an optional persistent [`Store`]: a job
/// whose fingerprint is already present is served from disk — no SAT
/// search — and reported with `cached: true`, `elapsed_ms: 0`; a job
/// solved fresh is appended to the store's WAL the moment it commits,
/// so a sweep killed at any point resumes where it stopped.
///
/// Failed jobs (`error: Some`), no-solution jobs (`area = inf`) and
/// wall-clock-truncated template jobs (elapsed reached
/// `time_budget_ms`) are NOT persisted: a resumed sweep retries them
/// instead of replaying the outcome forever. The latter two cases
/// matter because a deadline that binds on a loaded machine truncates
/// the lattice scan at a load-dependent point — caching the degraded
/// result would permanently replace what a complete search produces
/// (conflict-budget aborts, by contrast, are machine-independent and
/// cache fine). A store append error is reported to stderr and the
/// sweep carries on — losing one cache entry is not worth losing the
/// sweep.
///
/// The per-job exhaustive truth table is simulated once here and
/// threads through fingerprinting, the miter-prototype cache and the
/// engine ([`run_job_with`]).
pub fn run_sweep_stored(plan: &SweepPlan, store: Option<&Store>) -> Vec<RunRecord> {
    run_sweep_obs(plan, store, &Obs::off())
}

/// As [`run_sweep_stored`], with an observability handle: each solved
/// job gets a `sweep.job` span (the lattice engine nests per-cell
/// spans under it), store heals and append failures go through the
/// leveled log, and heals are counted in the metrics registry.
/// Observe-only by construction — no clock read or event feeds a
/// search or commit decision — so records/CSV/WAL bytes are identical
/// with tracing on or off (`tests/obs_determinism.rs`).
pub fn run_sweep_obs(plan: &SweepPlan, store: Option<&Store>, obs: &Obs) -> Vec<RunRecord> {
    let protos = MiterCache::new();
    let heals = metrics::counter("pallas_store_heals_total");
    run_sweep_with(plan, |job| {
        // One store consultation path for every sweep flavour (the
        // distributed coordinator uses the same helper): oracle
        // simulated once, hit re-verified, unsound record flagged for
        // a last-writer-wins heal.
        let probe = probe_store_obs(job, store, obs);
        if let Some(cached) = probe.cached {
            return cached;
        }
        let mut span = obs.span(
            "sweep.job",
            &[
                ("bench", Json::Str(job.bench.name.to_string())),
                ("method", Json::Str(job.method.name().to_string())),
                ("et", Json::Num(job.et as f64)),
            ],
        );
        let rec = run_job_obs(job, &protos, &probe.exact, &obs.child_of(&span));
        span.field("elapsed_ms", Json::Num(rec.elapsed_ms as f64));
        span.field("solved", Json::Bool(rec.area.is_finite()));
        span.finish();
        if let (Some(st), Some(fp)) = (store, probe.fp) {
            if wal_persistable(&rec, job.search.time_budget_ms) {
                match st.append(fp, &rec) {
                    Ok(()) => {
                        if probe.heal {
                            heals.inc();
                            obs.warn(
                                "store",
                                "healed unsound store record (last-writer-wins overwrite)",
                                &[("fp", Json::Str(fp.to_string()))],
                            );
                        }
                    }
                    Err(e) => obs.warn(
                        "sweep",
                        &format!(
                            "store append failed for {} {} et={}: {e:#}",
                            rec.bench,
                            rec.method.name(),
                            rec.et
                        ),
                        &[],
                    ),
                }
            }
        }
        rec
    })
}

/// Everything the store knows about one job, plus the oracle table the
/// lookup needed anyway. The single source of truth for cache-serving
/// semantics: both the local stored sweep above and the distributed
/// coordinator (`dist::coordinator`) consult the store through this
/// helper, so the two paths cannot drift — which is what makes the
/// dist-vs-local byte-identity contract (`tests/dist_roundtrip.rs`)
/// hold by construction.
pub struct StoreProbe {
    /// The job's exhaustive oracle table, simulated once here.
    pub exact: Vec<u64>,
    /// Store fingerprint (`None` when no store is attached).
    pub fp: Option<Fingerprint>,
    /// A sound stored record, rebuilt for serving (`cached: true`,
    /// `elapsed_ms: 0`, bench name re-anchored to this process).
    pub cached: Option<RunRecord>,
    /// A stored record existed but failed oracle re-verification: the
    /// fresh solve must overwrite it last-writer-wins.
    pub heal: bool,
}

/// Simulate the oracle, fingerprint the job and consult the store. A
/// hit is served only after re-verifying the stored operator table
/// against the oracle (the disk is not part of the soundness
/// argument); an unsound record is reported and flagged for healing.
pub fn probe_store(job: &Job, store: Option<&Store>) -> StoreProbe {
    probe_store_obs(job, store, &Obs::off())
}

/// As [`probe_store`], reporting re-verification failures through the
/// observability handle (structured warning carrying the unsound
/// fingerprint) instead of a bare stderr line.
pub fn probe_store_obs(job: &Job, store: Option<&Store>, obs: &Obs) -> StoreProbe {
    let nl = job.bench.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    let fp = store.map(|_| {
        job_fingerprint(nl.n_inputs(), nl.n_outputs(), &exact, job.method, job.et, &job.search)
    });
    let mut heal = false;
    if let (Some(st), Some(fp)) = (store, fp) {
        if let Some(rec) = st.get(fp) {
            let sound = rec.values.len() == exact.len()
                && exact.iter().zip(&rec.values).all(|(&e, &a)| e.abs_diff(a) <= job.et);
            if sound {
                // The fingerprint pins method/ET/config/truth table;
                // the bench pointer is re-anchored to this process's
                // static (names are not part of the fingerprint).
                let cached = RunRecord {
                    bench: job.bench.name,
                    elapsed_ms: 0,
                    cached: true,
                    ..rec
                };
                return StoreProbe { exact, fp: Some(fp), cached: Some(cached), heal: false };
            }
            obs.warn(
                "store",
                "store record failed oracle re-verification; re-solving",
                &[
                    ("fp", Json::Str(fp.to_string())),
                    ("bench", Json::Str(job.bench.name.to_string())),
                    ("method", Json::Str(job.method.name().to_string())),
                    ("et", Json::Num(job.et as f64)),
                ],
            );
            heal = true;
        }
    }
    StoreProbe { exact, fp, cached: None, heal }
}

/// Should a fresh record be written to the WAL? Failed jobs,
/// no-solution jobs and wall-clock-truncated template jobs are not
/// persisted — a resumed sweep retries them (a binding deadline
/// truncates the scan at a load-dependent point; caching that would
/// permanently replace what a complete search produces). Shared by the
/// local stored sweep and the distributed commit path.
pub fn wal_persistable(rec: &RunRecord, time_budget_ms: u64) -> bool {
    let deadline_bound = matches!(rec.method, Method::Shared | Method::Xpat)
        && rec.elapsed_ms >= time_budget_ms;
    rec.error.is_none() && rec.area.is_finite() && !deadline_bound
}

/// As [`run_sweep`] with a custom job runner (the seam the resilience
/// tests use). A panicking runner yields a `failed_record`, never a
/// missing slot or a dead sweep.
pub fn run_sweep_with<F>(plan: &SweepPlan, runner: F) -> Vec<RunRecord>
where
    F: Fn(&Job) -> RunRecord + Sync,
{
    let jobs = plan.jobs();
    let n_jobs = jobs.len();
    if n_jobs == 0 {
        return Vec::new();
    }
    // FIFO: jobs dispatch in plan order, so a 1-worker sweep runs (and
    // commits to a store's WAL) in exactly job-index order — the order
    // the distributed coordinator's in-order commit frontier reproduces
    // (`tests/dist_roundtrip.rs` pins the two WALs byte-identical).
    let queue = Arc::new(Mutex::new(
        jobs.iter().cloned().enumerate().collect::<VecDeque<(usize, Job)>>(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, RunRecord)>();
    let workers = plan.workers.clamp(1, n_jobs);
    let runner = &runner;

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            scope.spawn(move || loop {
                let next = queue.lock().unwrap().pop_front();
                match next {
                    Some((idx, job)) => {
                        let rec = catch_unwind(AssertUnwindSafe(|| runner(&job)))
                            .unwrap_or_else(|payload| {
                                failed_record(&job, panic_message(payload))
                            });
                        if tx.send((idx, rec)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<RunRecord>> = (0..n_jobs).map(|_| None).collect();
        for (idx, rec) in rx {
            slots[idx] = Some(rec);
        }
        // A slot can only still be empty if a worker died so hard the
        // catch above never ran (e.g. a panic-in-panic abort was
        // survived); record the loss instead of poisoning the sweep.
        slots
            .into_iter()
            .enumerate()
            .map(|(idx, s)| {
                s.unwrap_or_else(|| {
                    failed_record(&jobs[idx], "worker died mid-job".to_string())
                })
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::jobs::run_job;
    use crate::circuit::generators::benchmark_by_name;

    fn tiny_plan() -> SweepPlan {
        SweepPlan {
            benches: vec![benchmark_by_name("adder_i4").unwrap()],
            methods: vec![Method::Shared, Method::Muscat],
            ets: Some(vec![1, 2]),
            search: SearchConfig {
                pool: 5,
                solutions_per_cell: 1,
                max_sat_cells: 1,
                conflict_budget: Some(20_000),
                time_budget_ms: 20_000,
                ..Default::default()
            },
            workers: 2,
        }
    }

    #[test]
    fn sweep_returns_records_in_job_order() {
        let plan = tiny_plan();
        let jobs = plan.jobs();
        let recs = run_sweep(&plan);
        assert_eq!(recs.len(), jobs.len());
        for (j, r) in jobs.iter().zip(&recs) {
            assert_eq!(j.bench.name, r.bench);
            assert_eq!(j.method, r.method);
            assert_eq!(j.et, r.et);
            assert!(r.error.is_none());
        }
    }

    #[test]
    fn single_worker_matches_parallel_areas() {
        let mut p1 = tiny_plan();
        p1.workers = 1;
        let mut p4 = tiny_plan();
        p4.workers = 4;
        let a: Vec<f64> = run_sweep(&p1).iter().map(|r| r.area).collect();
        let b: Vec<f64> = run_sweep(&p4).iter().map(|r| r.area).collect();
        assert_eq!(a, b, "sweep must be deterministic across worker counts");
    }

    #[test]
    fn sweep_survives_a_panicking_job() {
        let plan = tiny_plan();
        let jobs = plan.jobs();
        let recs = run_sweep_with(&plan, |job| {
            if job.et == 2 {
                panic!("injected failure for et=2");
            }
            run_job(job)
        });
        assert_eq!(recs.len(), jobs.len(), "one bad job must not eat the sweep");
        for (j, r) in jobs.iter().zip(&recs) {
            assert_eq!(j.et, r.et);
            if j.et == 2 {
                let msg = r.error.as_deref().expect("failure must be recorded");
                assert!(msg.contains("injected failure"), "{msg}");
                assert!(r.area.is_infinite());
            } else {
                assert!(r.error.is_none());
                assert!(r.area.is_finite());
            }
        }
    }

    #[test]
    fn default_plan_covers_paper_grid() {
        let plan = SweepPlan::default();
        let jobs = plan.jobs();
        // 6 benchmarks x 4 methods x per-bench ET count.
        let expected: usize = PAPER_BENCHMARKS
            .iter()
            .map(|b| b.et_sweep().len() * 4)
            .sum();
        assert_eq!(jobs.len(), expected);
        assert_eq!(plan.n_jobs(), expected, "count must not require materializing");
    }

    #[test]
    fn job_iter_is_lazy_and_matches_jobs() {
        let plan = tiny_plan();
        let eager = plan.jobs();
        let lazy: Vec<Job> = plan.job_iter().collect();
        assert_eq!(eager.len(), lazy.len());
        assert_eq!(plan.n_jobs(), eager.len());
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.bench.name, b.bench.name);
            assert_eq!(a.method, b.method);
            assert_eq!(a.et, b.et);
        }
        // Pulling one job must not have enumerated the rest.
        let first = plan.job_iter().next().unwrap();
        assert_eq!(first.bench.name, eager[0].bench.name);
        assert_eq!(first.et, eager[0].et);
    }
}

//! Parallel sweep execution: fan a job list out over a worker pool and
//! collect records in a deterministic order.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::circuit::generators::{Benchmark, PAPER_BENCHMARKS};
use crate::search::SearchConfig;

use super::jobs::{run_job, Job, Method, RunRecord};

/// A declarative sweep: which benchmarks, methods and ET values to run.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub benches: Vec<&'static Benchmark>,
    pub methods: Vec<Method>,
    /// `None` = each benchmark's paper ET sweep; `Some(v)` = fixed list.
    pub ets: Option<Vec<u64>>,
    pub search: SearchConfig,
    pub workers: usize,
}

impl Default for SweepPlan {
    fn default() -> Self {
        SweepPlan {
            benches: PAPER_BENCHMARKS.iter().collect(),
            methods: Method::all_compared().to_vec(),
            ets: None,
            search: SearchConfig::default(),
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        }
    }
}

impl SweepPlan {
    pub fn jobs(&self) -> Vec<Job> {
        let mut jobs = Vec::new();
        for &bench in &self.benches {
            let ets = self.ets.clone().unwrap_or_else(|| bench.et_sweep());
            for &method in &self.methods {
                for &et in &ets {
                    jobs.push(Job { bench, method, et, search: self.search.clone() });
                }
            }
        }
        jobs
    }
}

/// Run the plan on a worker pool; records return in job order.
pub fn run_sweep(plan: &SweepPlan) -> Vec<RunRecord> {
    let jobs = plan.jobs();
    let n_jobs = jobs.len();
    if n_jobs == 0 {
        return Vec::new();
    }
    let queue = Arc::new(Mutex::new(
        jobs.into_iter().enumerate().collect::<Vec<(usize, Job)>>(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, RunRecord)>();
    let workers = plan.workers.clamp(1, n_jobs);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            scope.spawn(move || loop {
                let next = queue.lock().unwrap().pop();
                match next {
                    Some((idx, job)) => {
                        let rec = run_job(&job);
                        if tx.send((idx, rec)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<RunRecord>> = (0..n_jobs).map(|_| None).collect();
        for (idx, rec) in rx {
            slots[idx] = Some(rec);
        }
        slots.into_iter().map(|s| s.expect("worker died mid-job")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::benchmark_by_name;

    fn tiny_plan() -> SweepPlan {
        SweepPlan {
            benches: vec![benchmark_by_name("adder_i4").unwrap()],
            methods: vec![Method::Shared, Method::Muscat],
            ets: Some(vec![1, 2]),
            search: SearchConfig {
                pool: 5,
                solutions_per_cell: 1,
                max_sat_cells: 1,
                conflict_budget: Some(20_000),
                time_budget_ms: 20_000,
            },
            workers: 2,
        }
    }

    #[test]
    fn sweep_returns_records_in_job_order() {
        let plan = tiny_plan();
        let jobs = plan.jobs();
        let recs = run_sweep(&plan);
        assert_eq!(recs.len(), jobs.len());
        for (j, r) in jobs.iter().zip(&recs) {
            assert_eq!(j.bench.name, r.bench);
            assert_eq!(j.method, r.method);
            assert_eq!(j.et, r.et);
        }
    }

    #[test]
    fn single_worker_matches_parallel_areas() {
        let mut p1 = tiny_plan();
        p1.workers = 1;
        let mut p4 = tiny_plan();
        p4.workers = 4;
        let a: Vec<f64> = run_sweep(&p1).iter().map(|r| r.area).collect();
        let b: Vec<f64> = run_sweep(&p4).iter().map(|r| r.area).collect();
        assert_eq!(a, b, "sweep must be deterministic across worker counts");
    }

    #[test]
    fn default_plan_covers_paper_grid() {
        let plan = SweepPlan::default();
        let jobs = plan.jobs();
        // 6 benchmarks x 4 methods x per-bench ET count.
        let expected: usize = PAPER_BENCHMARKS
            .iter()
            .map(|b| b.et_sweep().len() * 4)
            .sum();
        assert_eq!(jobs.len(), expected);
    }
}

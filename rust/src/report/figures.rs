//! Renderers for the paper's evaluation artefacts.
//!
//! * Fig. 4 — area vs. proxy value at fixed ET: scatter series per
//!   method plus the exact-circuit star and the random-sound baseline.
//! * Fig. 5 — best area per method across the ET sweep.

use std::fmt::Write as _;

use crate::baselines::RandomPoint;
use crate::coordinator::{Method, RunRecord};

/// Raw record dump (one row per job) — the machine-readable log.
/// `cached` distinguishes store-served rows of a resumed sweep from
/// fresh solves (their `elapsed_ms` is 0 by construction).
pub fn records_csv(records: &[RunRecord]) -> String {
    let mut s = String::from(
        "bench,method,et,area,max_err,mean_err,proxy_a,proxy_b,elapsed_ms,cached,error\n",
    );
    for r in records {
        let _ = writeln!(
            s,
            "{},{},{},{:.4},{},{:.4},{},{},{},{},{}",
            r.bench,
            r.method.name(),
            r.et,
            r.area,
            r.max_err,
            r.mean_err,
            r.proxy.0,
            r.proxy.1,
            r.elapsed_ms,
            r.cached,
            r.error
                .as_deref()
                .unwrap_or("")
                .replace(['\n', '\r', ','], ";")
        );
    }
    s
}

/// Fig. 4 series: every enumerated solution of the template methods
/// (proxy = PIT+ITS for SHARED, LPP·PPO·m for XPAT — the paper plots
/// each method against its own proxy), single points for the baseline
/// methods and the exact star, and the random-sound cloud.
pub fn fig4_csv(
    bench: &str,
    et: u64,
    exact_area: f64,
    records: &[RunRecord],
    random: &[RandomPoint],
) -> String {
    let mut s = String::from("bench,et,series,proxy,area\n");
    let _ = writeln!(s, "{bench},{et},exact,0,{exact_area:.4}");
    for p in random {
        let _ = writeln!(s, "{bench},{et},random,{},{:.4}", p.pit + p.its, p.area);
    }
    for r in records.iter().filter(|r| r.bench == bench && r.et == et) {
        match r.method {
            Method::Shared | Method::Xpat => {
                for &(a, b, area) in &r.all_points {
                    let proxy = a + b;
                    let _ = writeln!(
                        s,
                        "{bench},{et},{},{proxy},{area:.4}",
                        r.method.name()
                    );
                }
            }
            _ => {
                let _ = writeln!(
                    s,
                    "{bench},{et},{},{},{:.4}",
                    r.method.name(),
                    r.proxy.0 + r.proxy.1,
                    r.area
                );
            }
        }
    }
    s
}

/// Fig. 5 series: per (bench, method), area across the ET sweep. The
/// trailing `cached` column marks rows served from the result store; a
/// resumed sweep's CSV is byte-identical to the fresh one modulo that
/// column (asserted by `tests/store_roundtrip.rs`).
pub fn fig5_csv(records: &[RunRecord]) -> String {
    let mut s = String::from("bench,method,et,area,cached\n");
    for r in records {
        let _ = writeln!(
            s,
            "{},{},{},{:.4},{}",
            r.bench,
            r.method.name(),
            r.et,
            r.area,
            r.cached
        );
    }
    s
}

/// Markdown rendering of the Fig. 5 grid — one table per benchmark,
/// methods as columns, ET values as rows; the winner per row is bolded.
/// Cells served from the result store carry a `†` marker (explained in
/// a footnote), so a resumed sweep is visually distinguishable.
pub fn fig5_markdown(records: &[RunRecord]) -> String {
    let mut benches: Vec<&str> = records.iter().map(|r| r.bench).collect();
    benches.sort_unstable();
    benches.dedup();
    let methods = Method::all_compared();

    let mut s = String::new();
    let mut any_cached = false;
    for bench in benches {
        let _ = writeln!(s, "\n### {bench}\n");
        let mut header = String::from("| ET |");
        for m in methods {
            let _ = write!(header, " {} |", m.name());
        }
        let _ = writeln!(s, "{header}");
        let _ = writeln!(s, "|---{}|", "|---".repeat(methods.len()));

        let mut ets: Vec<u64> = records
            .iter()
            .filter(|r| r.bench == bench)
            .map(|r| r.et)
            .collect();
        ets.sort_unstable();
        ets.dedup();
        for et in ets {
            let cells: Vec<Option<(f64, bool)>> = methods
                .iter()
                .map(|&m| {
                    records
                        .iter()
                        .find(|r| r.bench == bench && r.et == et && r.method == m)
                        .map(|r| (r.area, r.cached))
                })
                .collect();
            let best = cells
                .iter()
                .flatten()
                .fold(f64::INFINITY, |a, &(b, _)| a.min(b));
            let mut row = format!("| {et} |");
            for cell in cells {
                match cell {
                    Some((a, cached)) if a.is_finite() => {
                        let mark = if cached { "†" } else { "" };
                        if (a - best).abs() < 1e-9 {
                            let _ = write!(row, " **{a:.3}**{mark} |");
                        } else {
                            let _ = write!(row, " {a:.3}{mark} |");
                        }
                        if cached {
                            any_cached = true;
                        }
                    }
                    _ => {
                        let _ = write!(row, " — |");
                    }
                }
            }
            let _ = writeln!(s, "{row}");
        }
    }
    if any_cached {
        let _ = writeln!(s, "\n† served from the result store (resumed sweep)");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(bench: &'static str, method: Method, et: u64, area: f64) -> RunRecord {
        RunRecord {
            bench,
            method,
            et,
            area,
            max_err: et,
            mean_err: 0.5,
            proxy: (2, 3),
            elapsed_ms: 1,
            cached: false,
            values: vec![0, 1, 2, 3],
            all_points: vec![(2, 3, area), (3, 4, area + 1.0)],
            error: None,
        }
    }

    #[test]
    fn records_csv_has_row_per_record() {
        let rs = vec![
            rec("adder_i4", Method::Shared, 1, 2.0),
            rec("adder_i4", Method::Xpat, 1, 3.0),
        ];
        let csv = records_csv(&rs);
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("adder_i4,SHARED,1,2.0000"));
        assert!(csv.lines().next().unwrap().contains(",cached,"));
    }

    #[test]
    fn csvs_carry_the_cached_flag() {
        let mut cached = rec("adder_i4", Method::Shared, 1, 2.0);
        cached.cached = true;
        cached.elapsed_ms = 0;
        let rs = vec![cached, rec("adder_i4", Method::Xpat, 1, 3.0)];
        let f5 = fig5_csv(&rs);
        assert!(f5.starts_with("bench,method,et,area,cached\n"));
        assert!(f5.contains("adder_i4,SHARED,1,2.0000,true"));
        assert!(f5.contains("adder_i4,XPAT,1,3.0000,false"));
        let rc = records_csv(&rs);
        assert!(rc.contains(",0,true,"));

        // Markdown: cached cells get the dagger + footnote; a fully
        // fresh sweep renders no footnote.
        let md = fig5_markdown(&rs);
        assert!(md.contains("**2.000**†"));
        assert!(md.contains("† served from the result store"));
        let fresh = fig5_markdown(&[rec("adder_i4", Method::Shared, 1, 2.0)]);
        assert!(!fresh.contains('†'));
    }

    #[test]
    fn fig4_includes_all_series() {
        let rs = vec![
            rec("adder_i4", Method::Shared, 2, 2.0),
            rec("adder_i4", Method::Muscat, 2, 4.0),
        ];
        let random = vec![RandomPoint { pit: 3, its: 5, area: 6.0, max_err: 1, mean_err: 0.2 }];
        let csv = fig4_csv("adder_i4", 2, 9.5, &rs, &random);
        assert!(csv.contains("exact,0,9.5000"));
        assert!(csv.contains("random,8,6.0000"));
        assert!(csv.contains("SHARED,5,2.0000")); // scatter point (2+3)
        assert!(csv.contains("SHARED,7,3.0000")); // scatter point (3+4)
        assert!(csv.contains("MUSCAT,5,4.0000"));
    }

    #[test]
    fn fig5_markdown_bolds_winner() {
        let rs = vec![
            rec("mult_i4", Method::Shared, 1, 2.0),
            rec("mult_i4", Method::Xpat, 1, 3.0),
            rec("mult_i4", Method::Muscat, 1, 4.0),
            rec("mult_i4", Method::Mecals, 1, 5.0),
        ];
        let md = fig5_markdown(&rs);
        assert!(md.contains("### mult_i4"));
        assert!(md.contains("**2.000**"));
        assert!(!md.contains("**3.000**"));
    }

    #[test]
    fn fig5_markdown_handles_missing_cells() {
        let rs = vec![rec("adder_i6", Method::Shared, 4, 2.5)];
        let md = fig5_markdown(&rs);
        assert!(md.contains("—"));
    }
}

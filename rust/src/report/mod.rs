//! Figure/table emitters: CSV series for plotting plus human-readable
//! markdown tables, one emitter per paper figure.

pub mod figures;

pub use figures::{fig4_csv, fig5_csv, fig5_markdown, records_csv};

//! Bit-parallel exhaustive evaluator (the host-side oracle).

use crate::circuit::sim::input_pattern;
use crate::template::SopParams;

#[derive(Debug, Clone, PartialEq)]
pub struct EvalResult {
    pub max_err: u64,
    pub mean_err: f64,
    /// Output value per input point.
    pub values: Vec<u64>,
}

/// Input-count cap of the fast evaluator: the paper's largest geometry
/// is 8 inputs (`mult_i8`), i.e. `2^8 = 256` points = [`MAX_WORDS`]
/// 64-bit words per row.
const MAX_INPUTS: usize = 8;

/// Words per row at [`MAX_INPUTS`]: `2^MAX_INPUTS / 64`.
const MAX_WORDS: usize = (1 << MAX_INPUTS) / 64;

/// Scratch space reused across candidates of one geometry — the batch
/// path allocates it once instead of ~(t + m + n) Vecs per candidate
/// (EXPERIMENTS.md §Perf iteration 1).
struct Scratch {
    inputs: Vec<[u64; MAX_WORDS]>,
    prods: Vec<[u64; MAX_WORDS]>,
    bits: Vec<[u64; MAX_WORDS]>,
}

impl Scratch {
    fn new(n: usize, t: usize, m: usize) -> Self {
        assert!(
            n <= MAX_INPUTS,
            "fast evaluator capped at {MAX_INPUTS} inputs (paper max)"
        );
        let words = (1usize << n).div_ceil(64);
        let mut inputs = vec![[0u64; MAX_WORDS]; n];
        for (j, row) in inputs.iter_mut().enumerate() {
            for (w, word) in input_pattern(j, n, words).into_iter().enumerate() {
                row[w] = word;
            }
        }
        Scratch { inputs, prods: vec![[0; MAX_WORDS]; t], bits: vec![[0; MAX_WORDS]; m] }
    }
}

fn evaluate_with(p: &SopParams, exact: &[u64], s: &mut Scratch) -> EvalResult {
    let n = p.n;
    let words = (1usize << n).div_ceil(64);
    let mask = if n < 6 { (1u64 << (1usize << n)) - 1 } else { !0 };

    for k in 0..p.t {
        let row = &mut s.prods[k];
        row[..words].fill(mask);
        for j in 0..n {
            if !p.uses(k, j) {
                continue;
            }
            let neg = if p.negated(k, j) { !0u64 } else { 0 };
            for w in 0..words {
                row[w] &= s.inputs[j][w] ^ neg;
            }
        }
    }

    for i in 0..p.m {
        let init = if p.out_const[i] { mask } else { 0 };
        let mut acc = [init; MAX_WORDS];
        for k in 0..p.t {
            if p.selects(i, k) {
                for w in 0..words {
                    acc[w] |= s.prods[k][w];
                }
            }
        }
        s.bits[i] = acc;
    }

    let npoints = 1usize << n;
    let mut values = Vec::with_capacity(npoints);
    let mut max_err = 0u64;
    let mut sum = 0u128;
    for x in 0..npoints {
        let (w, b) = (x / 64, x % 64);
        let mut v = 0u64;
        for (i, row) in s.bits.iter().enumerate().take(p.m) {
            v |= ((row[w] >> b) & 1) << i;
        }
        let d = v.abs_diff(exact[x]);
        max_err = max_err.max(d);
        sum += d as u128;
        values.push(v);
    }
    EvalResult { max_err, mean_err: sum as f64 / npoints as f64, values }
}

/// Evaluate one instantiation against exact values.
pub fn evaluate(p: &SopParams, exact: &[u64]) -> EvalResult {
    assert_eq!(exact.len(), 1usize << p.n);
    let mut s = Scratch::new(p.n, p.t, p.m);
    evaluate_with(p, exact, &mut s)
}

/// Evaluate many instantiations (the PJRT artifact's rust twin).
/// Scratch buffers are shared across the batch.
pub fn evaluate_batch(batch: &[SopParams], exact: &[u64]) -> Vec<EvalResult> {
    let Some(first) = batch.first() else {
        return Vec::new();
    };
    assert_eq!(exact.len(), 1usize << first.n);
    let mut s = Scratch::new(first.n, first.t, first.m);
    batch
        .iter()
        .map(|p| {
            if (p.n, p.t, p.m) != (first.n, first.t, first.m) {
                evaluate(p, exact)
            } else {
                evaluate_with(p, exact, &mut s)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::PAPER_BENCHMARKS;
    use crate::circuit::sim::TruthTables;
    use crate::util::Rng;

    #[test]
    fn agrees_with_direct_semantics_on_random_params() {
        for b in &PAPER_BENCHMARKS {
            let nl = b.netlist();
            let exact = TruthTables::simulate(&nl).output_values(&nl);
            let mut rng = Rng::seed_from(0xBEEF ^ b.bits as u64);
            for _ in 0..5 {
                let p = SopParams::random(
                    &mut rng, nl.n_inputs(), nl.n_outputs(), 8, 0.35, 0.3,
                );
                let r = evaluate(&p, &exact);
                let direct = p.output_values();
                assert_eq!(r.values, direct, "{}", b.name);
                let (mx, mean) =
                    crate::circuit::sim::error_stats(&exact, &direct);
                assert_eq!(r.max_err, mx);
                assert!((r.mean_err - mean).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn perfect_params_give_zero_error() {
        // Build params computing out0 = in0 over n=2 (exact = bit0).
        let mut p = SopParams::empty(2, 1, 1);
        p.use_mask[0] = true;
        p.out_sel[0] = true;
        let exact: Vec<u64> = (0..4u64).map(|x| x & 1).collect();
        let r = evaluate(&p, &exact);
        assert_eq!(r.max_err, 0);
        assert_eq!(r.mean_err, 0.0);
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::seed_from(7);
        let exact: Vec<u64> = (0..16u64).map(|x| x % 8).collect();
        let ps: Vec<SopParams> = (0..10)
            .map(|_| SopParams::random(&mut rng, 4, 3, 6, 0.4, 0.3))
            .collect();
        let batch = evaluate_batch(&ps, &exact);
        for (p, r) in ps.iter().zip(&batch) {
            assert_eq!(*r, evaluate(p, &exact));
        }
    }
}

//! Bulk exhaustive evaluation of template instantiations.
//!
//! Two engines with identical semantics:
//! * [`rust_eval`] — bit-parallel host evaluation (64 input points per
//!   word). This is the oracle for tests and the fallback path.
//! * the PJRT artifact (see [`crate::runtime`]) — the JAX/Pallas L1
//!   kernel, AOT-lowered, batching hundreds of candidates per dispatch.
//!
//! [`pack`] converts between [`SopParams`](crate::template::SopParams)
//! and the artifact's flat f32 tensor layout.

pub mod pack;
pub mod rust_eval;

pub use pack::{pack_batch, PackedBatch};
pub use rust_eval::{evaluate, evaluate_batch, EvalResult};

//! Packing between [`SopParams`] and the PJRT artifact's tensor layout.
//!
//! The artifact's shape contract (see `python/compile/model.py`) is
//! `use_mask [B,T,n], neg_mask [B,T,n], out_sel [B,m,T], out_const [B,m],
//! exact [2^n]`, all f32 {0,1}, with a fixed batch B. Short batches are
//! padded with empty instantiations (harmless: they evaluate to constant
//! 0 and are sliced away on return).

use crate::template::SopParams;

#[derive(Debug, Clone)]
pub struct PackedBatch {
    pub n: usize,
    pub m: usize,
    pub t: usize,
    pub b: usize,
    /// Real (unpadded) batch entries.
    pub len: usize,
    pub use_mask: Vec<f32>,
    pub neg_mask: Vec<f32>,
    pub out_sel: Vec<f32>,
    pub out_const: Vec<f32>,
}

/// Pack up to `b` instantiations; `params.len() <= b` is required and all
/// entries must share the artifact geometry.
pub fn pack_batch(params: &[SopParams], n: usize, m: usize, t: usize, b: usize)
                  -> PackedBatch {
    assert!(params.len() <= b, "batch overflow: {} > {b}", params.len());
    let mut out = PackedBatch {
        n,
        m,
        t,
        b,
        len: params.len(),
        use_mask: vec![0.0; b * t * n],
        neg_mask: vec![0.0; b * t * n],
        out_sel: vec![0.0; b * m * t],
        out_const: vec![0.0; b * m],
    };
    for (bi, p) in params.iter().enumerate() {
        assert_eq!((p.n, p.m, p.t), (n, m, t), "geometry mismatch");
        for k in 0..t {
            for j in 0..n {
                out.use_mask[bi * t * n + k * n + j] = p.uses(k, j) as u8 as f32;
                out.neg_mask[bi * t * n + k * n + j] =
                    p.negated(k, j) as u8 as f32;
            }
        }
        for i in 0..m {
            for k in 0..t {
                out.out_sel[bi * m * t + i * t + k] = p.selects(i, k) as u8 as f32;
            }
            out.out_const[bi * m + i] = p.out_const[i] as u8 as f32;
        }
    }
    out
}

/// Widen (or check) an instantiation to the artifact's pool size `t` by
/// appending unused products.
pub fn widen_to_pool(p: &SopParams, t: usize) -> SopParams {
    assert!(p.t <= t, "pool too small: {} > {t}", p.t);
    if p.t == t {
        return p.clone();
    }
    let mut q = SopParams::empty(p.n, p.m, t);
    for k in 0..p.t {
        for j in 0..p.n {
            q.use_mask[k * p.n + j] = p.use_mask[k * p.n + j];
            q.neg_mask[k * p.n + j] = p.neg_mask[k * p.n + j];
        }
    }
    for i in 0..p.m {
        for k in 0..p.t {
            q.out_sel[i * t + k] = p.out_sel[i * p.t + k];
        }
        q.out_const[i] = p.out_const[i];
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pack_layout_roundtrip() {
        let mut rng = Rng::seed_from(3);
        let p = SopParams::random(&mut rng, 3, 2, 4, 0.5, 0.5);
        let packed = pack_batch(&[p.clone()], 3, 2, 4, 2);
        assert_eq!(packed.len, 1);
        for k in 0..4 {
            for j in 0..3 {
                assert_eq!(
                    packed.use_mask[k * 3 + j] > 0.5,
                    p.uses(k, j)
                );
                assert_eq!(packed.neg_mask[k * 3 + j] > 0.5, p.negated(k, j));
            }
        }
        for i in 0..2 {
            for k in 0..4 {
                assert_eq!(packed.out_sel[i * 4 + k] > 0.5, p.selects(i, k));
            }
        }
        // Padding slot stays all-zero.
        assert!(packed.use_mask[12..].iter().all(|&v| v == 0.0));
        assert!(packed.out_sel[8..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn widen_preserves_function() {
        let mut rng = Rng::seed_from(11);
        let p = SopParams::random(&mut rng, 4, 3, 5, 0.4, 0.4);
        let q = widen_to_pool(&p, 9);
        assert_eq!(q.t, 9);
        assert_eq!(p.output_values(), q.output_values());
        assert_eq!(p.pit(), q.pit());
        assert_eq!(p.its(), q.its());
    }

    #[test]
    #[should_panic(expected = "batch overflow")]
    fn overflow_panics() {
        let p = SopParams::empty(2, 1, 2);
        pack_batch(&[p.clone(), p.clone(), p], 2, 1, 2, 2);
    }

    #[test]
    #[should_panic(expected = "geometry mismatch")]
    fn geometry_mismatch_panics() {
        let p = SopParams::empty(2, 1, 2);
        pack_batch(&[p], 3, 1, 2, 2);
    }
}

//! Batched QoS-aware inference serving on top of the operator library
//! — the deployment layer that turns synthesis results into a running
//! service (the QoS-Nets-style adaptive-approximation flow; see
//! PAPERS.md).
//!
//! A request is a digit image plus a QoS tier (a named error budget
//! `et`); the server answers with the MLP's label computed through the
//! cheapest *verified* approximate multiplier on the store's Pareto
//! frontier for that budget. Pieces:
//!
//! - [`protocol`] — the request/response vocabulary, framed by the
//!   shared line-delimited-JSON wire discipline
//!   ([`util::jsonl`](crate::util::jsonl); `std::net` + `util::Json`
//!   only, no external dependencies).
//! - [`registry`] — QoS tier → verified min-area `MultLut`, resolved
//!   from the operator library at startup, atomically hot-swappable
//!   via `reload` after new sweeps land in the store; each tier's LUT
//!   is additionally folded into a compiled branchless batch kernel
//!   ([`CompiledMlp`](crate::nn::CompiledMlp)) at resolve/reload time,
//!   with the scalar path kept as the differential-testing oracle
//!   (`serve --scalar-path`). See DESIGN.md §12.
//! - [`batcher`] — bounded sharded queue with micro-batching (flush at
//!   `--batch` requests or a deadline).
//! - [`server`] — accept loop, worker pool, per-tier metrics, `watch`
//!   telemetry subscriptions, graceful shutdown.
//! - [`loadgen`] — load generator (the serve bench's client half):
//!   closed-loop by default, open-loop with `--rate` (latency charged
//!   from intended send times, avoiding coordinated omission), with
//!   optional in-run SLO judging.
//!
//! See DESIGN.md §10 for the architecture and the determinism
//! argument.

pub mod batcher;
pub mod loadgen;
pub mod protocol;
pub mod registry;
pub mod server;

// Latency percentiles (server per-tier metrics and the load
// generator's client side alike) come from fixed-size log2-bucketed
// histograms — `obs::Histogram::quantile`, the same nearest-rank
// convention the old sort-based `percentile` helper used, but bounded
// in memory and mergeable across clients. The two halves of
// `BENCH_serve.json` share one implementation so they cannot drift.

pub use loadgen::{run_loadgen, LoadgenConfig, LoadgenStats};
pub use registry::{parse_tiers, Registry, ResolvedTier, TierSource, TierSpec, DEFAULT_TIERS};
pub use server::{serving_mlp, ServeConfig, Server};

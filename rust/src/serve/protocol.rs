//! The serving wire protocol: line-delimited JSON over TCP, one
//! request or response per line, reusing [`util::Json`](crate::util::Json)
//! (std::net only — no external dependencies).
//!
//! Requests (`"id"` is an opaque client token echoed back, so clients
//! may pipeline and match responses out of order; like every JSON
//! number it travels as an f64, so ids must stay below 2^53 to be
//! echoed exactly — the same interop bound JS clients live with):
//!
//! ```text
//! {"type":"infer","id":7,"tier":"silver","pixels":[0,...,15]}   64 4-bit pixels
//! {"type":"stats","id":8}                                       metrics snapshot
//!                                                               (incl. per-tier
//!                                                               "tier.NAME.path":
//!                                                               "compiled"/"scalar")
//! {"type":"watch","id":9,"sample_ms":500,"count":10}            subscribe to pushed
//!                                                               registry samples
//! {"type":"reload","id":10}                                     re-resolve tiers from the store
//! {"type":"shutdown","id":11}                                   graceful shutdown
//! ```
//!
//! An `infer` request may also name a `"bench"`; the server answers
//! with a structured error unless it matches the served benchmark.
//!
//! Responses always carry `"id"` and `"ok"`. Successful inference adds
//! the label and the serving operator's provenance (`tier`, achieved
//! `max_err`, `area`, `source`); the provenance fields are exactly the
//! registry's resolution, so a response line is a *deterministic*
//! function of (request, store contents) — the worker-count/batch-size
//! invariance test compares raw response bytes across server
//! configurations. Failures render as `{"id":..,"ok":false,"error":..}`
//! and never kill the connection or a worker.

use std::collections::BTreeMap;

use crate::util::Json;

/// The line cap is the shared wire discipline's
/// ([`util::jsonl`](crate::util::jsonl)), re-exported so protocol
/// users need not know where framing lives.
pub use crate::util::jsonl::MAX_LINE_BYTES;

/// 4-bit pixels: the LUT datapath's operand range.
pub const MAX_PIXEL: u64 = 15;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    Infer {
        id: u64,
        tier: String,
        /// Optional benchmark name; must match the served bench.
        bench: Option<String>,
        pixels: Vec<u8>,
    },
    Stats { id: u64 },
    /// Process-wide metrics-registry snapshot (`obs::metrics`), as
    /// opposed to `stats`, which reports this server's own counters.
    Metrics { id: u64 },
    /// Subscribe to pushed registry samples: the server streams one
    /// `{"id":..,"ok":true,"sample":{..}}` line per period onto this
    /// connection (cumulative counters — the subscriber deltas them).
    /// `sample_ms` overrides the server's `--sample-ms`; `count` bounds
    /// the stream, else it runs until disconnect or shutdown.
    Watch {
        id: u64,
        sample_ms: Option<u64>,
        count: Option<u64>,
    },
    Reload { id: u64 },
    Shutdown { id: u64 },
}

/// Parse one request line. The error string is ready to embed in a
/// structured error response (the caller recovers the id separately
/// via [`request_id`] when possible).
pub fn parse_request(line: &str) -> Result<Request, String> {
    if line.len() > MAX_LINE_BYTES {
        return Err(format!(
            "request line of {} bytes exceeds the {MAX_LINE_BYTES}-byte cap",
            line.len()
        ));
    }
    let j = Json::parse(line).map_err(|e| format!("bad JSON: {e:#}"))?;
    let id = j.get("id").and_then(Json::as_u64).unwrap_or(0);
    let ty = j
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| "missing \"type\" field".to_string())?;
    match ty {
        "stats" => Ok(Request::Stats { id }),
        "metrics" => Ok(Request::Metrics { id }),
        "watch" => Ok(Request::Watch {
            id,
            sample_ms: j.get("sample_ms").and_then(Json::as_u64),
            count: j.get("count").and_then(Json::as_u64),
        }),
        "reload" => Ok(Request::Reload { id }),
        "shutdown" => Ok(Request::Shutdown { id }),
        "infer" => {
            let tier = j
                .get("tier")
                .and_then(Json::as_str)
                .ok_or_else(|| "infer: missing \"tier\" field".to_string())?
                .to_string();
            let bench = j.get("bench").and_then(Json::as_str).map(str::to_string);
            let arr = j
                .get("pixels")
                .and_then(Json::as_arr)
                .ok_or_else(|| "infer: missing \"pixels\" array".to_string())?;
            let mut pixels = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                let x = v
                    .as_u64()
                    .ok_or_else(|| format!("pixels[{i}]: expected an integer"))?;
                if x > MAX_PIXEL {
                    return Err(format!("pixels[{i}] = {x} outside the 4-bit range"));
                }
                pixels.push(x as u8);
            }
            Ok(Request::Infer { id, tier, bench, pixels })
        }
        other => Err(format!("unknown request type {other:?}")),
    }
}

/// Best-effort id recovery from a line that failed full parsing, so
/// even malformed-request errors can be matched by pipelined clients
/// (the shared [`jsonl::recover_id`](crate::util::jsonl::recover_id)).
pub fn request_id(line: &str) -> u64 {
    crate::util::jsonl::recover_id(line)
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Infer {
        id: u64,
        label: usize,
        tier: String,
        /// The serving operator's achieved worst-case error.
        max_err: u64,
        /// The serving operator's area (µm²).
        area: f64,
        /// Provenance: `oplib:<METHOD>:<fingerprint>` or `exact`.
        source: String,
    },
    Stats { id: u64, stats: Json },
    /// The process-wide metrics-registry snapshot.
    Metrics { id: u64, metrics: Json },
    /// One pushed time-series sample on a `watch` subscription
    /// (`obs::timeseries::Sample`, cumulative counters).
    Watch { id: u64, sample: Json },
    /// Acknowledgement for `reload` / `shutdown`.
    Ack { id: u64, info: String },
    Error { id: u64, error: String },
}

impl Response {
    /// Render as one deterministic JSON line (no trailing newline):
    /// `Json::render` sorts keys and escapes to ASCII.
    pub fn render(&self) -> String {
        let mut m = BTreeMap::new();
        match self {
            Response::Infer { id, label, tier, max_err, area, source } => {
                m.insert("id".to_string(), Json::Num(*id as f64));
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("label".to_string(), Json::Num(*label as f64));
                m.insert("tier".to_string(), Json::Str(tier.clone()));
                m.insert("max_err".to_string(), Json::Num(*max_err as f64));
                m.insert("area".to_string(), Json::Num(*area));
                m.insert("source".to_string(), Json::Str(source.clone()));
            }
            Response::Stats { id, stats } => {
                m.insert("id".to_string(), Json::Num(*id as f64));
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("stats".to_string(), stats.clone());
            }
            Response::Metrics { id, metrics } => {
                m.insert("id".to_string(), Json::Num(*id as f64));
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("metrics".to_string(), metrics.clone());
            }
            Response::Watch { id, sample } => {
                m.insert("id".to_string(), Json::Num(*id as f64));
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("sample".to_string(), sample.clone());
            }
            Response::Ack { id, info } => {
                m.insert("id".to_string(), Json::Num(*id as f64));
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("info".to_string(), Json::Str(info.clone()));
            }
            Response::Error { id, error } => {
                // The shared structured-error shape, byte for byte.
                return crate::util::jsonl::error_line(*id, error);
            }
        }
        Json::Obj(m).render()
    }
}

/// Render an `infer` request line (no trailing newline) — the client
/// half used by the load generator and the integration tests.
pub fn render_infer_request(id: u64, tier: &str, pixels: &[u8]) -> String {
    let mut m = BTreeMap::new();
    m.insert("type".to_string(), Json::Str("infer".to_string()));
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert("tier".to_string(), Json::Str(tier.to_string()));
    m.insert(
        "pixels".to_string(),
        Json::Arr(pixels.iter().map(|&p| Json::Num(f64::from(p))).collect()),
    );
    Json::Obj(m).render()
}

/// Render a control request line (`stats` / `reload` / `shutdown`).
pub fn render_control_request(ty: &str, id: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("type".to_string(), Json::Str(ty.to_string()));
    m.insert("id".to_string(), Json::Num(id as f64));
    Json::Obj(m).render()
}

/// Render a `watch` subscription request line — the monitor's client
/// half.
pub fn render_watch_request(id: u64, sample_ms: Option<u64>, count: Option<u64>) -> String {
    let mut m = BTreeMap::new();
    m.insert("type".to_string(), Json::Str("watch".to_string()));
    m.insert("id".to_string(), Json::Num(id as f64));
    if let Some(ms) = sample_ms {
        m.insert("sample_ms".to_string(), Json::Num(ms as f64));
    }
    if let Some(n) = count {
        m.insert("count".to_string(), Json::Num(n as f64));
    }
    Json::Obj(m).render()
}

/// Client-side view of one response line.
#[derive(Debug, Clone)]
pub struct ParsedResponse {
    pub id: u64,
    pub ok: bool,
    /// Present on successful `infer` responses.
    pub label: Option<u64>,
    /// Present on error responses.
    pub error: Option<String>,
    /// The whole payload, for provenance fields (`area`, `source`, ...).
    pub raw: Json,
}

pub fn parse_response(line: &str) -> Result<ParsedResponse, String> {
    let j = Json::parse(line).map_err(|e| format!("bad response JSON: {e:#}"))?;
    let id = j
        .get("id")
        .and_then(Json::as_u64)
        .ok_or_else(|| "response missing \"id\"".to_string())?;
    let ok = j
        .get("ok")
        .and_then(Json::as_bool)
        .ok_or_else(|| "response missing \"ok\"".to_string())?;
    Ok(ParsedResponse {
        id,
        ok,
        label: j.get("label").and_then(Json::as_u64),
        error: j.get("error").and_then(Json::as_str).map(str::to_string),
        raw: j,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_request_round_trip() {
        let pixels: Vec<u8> = (0..64).map(|i| (i % 16) as u8).collect();
        let line = render_infer_request(42, "silver", &pixels);
        match parse_request(&line).unwrap() {
            Request::Infer { id, tier, bench, pixels: got } => {
                assert_eq!(id, 42);
                assert_eq!(tier, "silver");
                assert_eq!(bench, None);
                assert_eq!(got, pixels);
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn control_requests_round_trip() {
        for ty in ["stats", "metrics", "reload", "shutdown"] {
            let line = render_control_request(ty, 9);
            let req = parse_request(&line).unwrap();
            let id = match (ty, &req) {
                ("stats", Request::Stats { id }) => *id,
                ("metrics", Request::Metrics { id }) => *id,
                ("reload", Request::Reload { id }) => *id,
                ("shutdown", Request::Shutdown { id }) => *id,
                _ => panic!("{ty}: wrong request {req:?}"),
            };
            assert_eq!(id, 9);
        }
    }

    #[test]
    fn watch_requests_round_trip() {
        let line = render_watch_request(11, Some(250), Some(4));
        match parse_request(&line).unwrap() {
            Request::Watch { id, sample_ms, count } => {
                assert_eq!((id, sample_ms, count), (11, Some(250), Some(4)));
            }
            other => panic!("wrong request: {other:?}"),
        }
        // Both knobs optional: server defaults apply, stream unbounded.
        match parse_request(&render_watch_request(12, None, None)).unwrap() {
            Request::Watch { id, sample_ms, count } => {
                assert_eq!((id, sample_ms, count), (12, None, None));
            }
            other => panic!("wrong request: {other:?}"),
        }
        // Pushed samples parse as ordinary ok-responses with a payload.
        let push = Response::Watch {
            id: 11,
            sample: Json::parse("{\"counters\":{},\"node\":\"serve\"}").unwrap(),
        };
        let parsed = parse_response(&push.render()).unwrap();
        assert!(parsed.ok);
        assert_eq!(parsed.id, 11);
        assert_eq!(
            parsed.raw.get("sample").and_then(|s| s.get("node")).and_then(Json::as_str),
            Some("serve")
        );
    }

    #[test]
    fn malformed_requests_are_structured_errors() {
        assert!(parse_request("not json at all").is_err());
        assert!(parse_request("{\"id\":1}").unwrap_err().contains("type"));
        assert!(parse_request("{\"type\":\"dance\",\"id\":1}")
            .unwrap_err()
            .contains("dance"));
        // Pixels outside the 4-bit operand range.
        let err = parse_request(
            "{\"type\":\"infer\",\"id\":1,\"tier\":\"t\",\"pixels\":[1,99]}",
        )
        .unwrap_err();
        assert!(err.contains("4-bit"), "{err}");
        // id is still recoverable from partially valid lines.
        assert_eq!(request_id("{\"id\":7,\"type\":\"dance\"}"), 7);
        assert_eq!(request_id("garbage"), 0);
    }

    #[test]
    fn oversized_line_is_rejected() {
        let huge = format!("{{\"type\":\"stats\",\"pad\":\"{}\"}}", "x".repeat(MAX_LINE_BYTES));
        assert!(parse_request(&huge).unwrap_err().contains("cap"));
    }

    #[test]
    fn responses_render_deterministically() {
        let r = Response::Infer {
            id: 3,
            label: 7,
            tier: "gold".to_string(),
            max_err: 2,
            area: 54.25,
            source: "oplib:SHARED:00000000deadbeef".to_string(),
        };
        let line = r.render();
        assert_eq!(line, r.render());
        let parsed = parse_response(&line).unwrap();
        assert!(parsed.ok);
        assert_eq!(parsed.id, 3);
        assert_eq!(parsed.label, Some(7));
        assert_eq!(parsed.raw.get("area"), Some(&Json::Num(54.25)));

        let e = Response::Error { id: 5, error: "unknown tier \"x\"".to_string() };
        let parsed = parse_response(&e.render()).unwrap();
        assert!(!parsed.ok);
        assert!(parsed.error.unwrap().contains("unknown tier"));
    }
}

//! Bounded, sharded micro-batching queue.
//!
//! Connection threads `push` work items; each serving worker owns one
//! shard and `pop_batch`es from it. A batch flushes when it reaches
//! `batch` items or when `max_wait` has elapsed since the worker saw
//! the first queued item — the classic latency/throughput micro-batch
//! knob. Pushes are round-robin across shards with failover to the
//! next non-full shard; when every shard is at capacity the push fails
//! and the caller turns that into a structured backpressure error
//! response instead of buffering unboundedly.
//!
//! [`Batcher::close`] begins graceful shutdown: further pushes fail
//! with [`PushError::Closed`], while `pop_batch` keeps draining queued
//! items and returns `None` only once its shard is empty — so every
//! request accepted before shutdown is answered.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

pub struct BatcherConfig {
    /// One shard per serving worker.
    pub shards: usize,
    /// Flush threshold: a batch never exceeds this many items.
    pub batch: usize,
    /// Flush deadline measured from when a worker observes the first
    /// item of a forming batch.
    pub max_wait: Duration,
    /// Bound on queued items per shard (backpressure).
    pub capacity_per_shard: usize,
}

struct Shard<T> {
    q: Mutex<VecDeque<T>>,
    cv: Condvar,
}

pub struct Batcher<T> {
    shards: Vec<Shard<T>>,
    batch: usize,
    max_wait: Duration,
    capacity: usize,
    next: AtomicUsize,
    closed: AtomicBool,
}

#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Every shard is at capacity; the item is handed back.
    Full(T),
    /// The batcher is shutting down; the item is handed back.
    Closed(T),
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Batcher<T> {
        let shards = cfg.shards.max(1);
        let batch = cfg.batch.max(1);
        Batcher {
            shards: (0..shards)
                .map(|_| Shard { q: Mutex::new(VecDeque::new()), cv: Condvar::new() })
                .collect(),
            batch,
            max_wait: cfg.max_wait,
            capacity: cfg.capacity_per_shard.max(batch),
            next: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Enqueue one item: round-robin over shards, failing over past
    /// full ones. O(1) in the common case, O(shards) under saturation.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        let n = self.shards.len();
        for k in 0..n {
            let shard = &self.shards[(start + k) % n];
            let mut q = shard.q.lock().unwrap();
            // The closed check must happen *under the shard lock*: the
            // mutex serializes it against the worker's final
            // empty-and-closed observation, so an item can never land
            // in a shard whose worker has already exited (it would be
            // stranded forever, never answered).
            if self.is_closed() {
                drop(q);
                return Err(PushError::Closed(item));
            }
            if q.len() < self.capacity {
                q.push_back(item);
                drop(q);
                shard.cv.notify_one();
                return Ok(());
            }
        }
        Err(PushError::Full(item))
    }

    /// Block until shard `shard_idx` has work, then drain up to `batch`
    /// items, waiting at most `max_wait` past the first observed item
    /// for the batch to fill. Returns `None` once the batcher is closed
    /// and the shard drained — the worker's exit signal.
    pub fn pop_batch(&self, shard_idx: usize) -> Option<Vec<T>> {
        let shard = &self.shards[shard_idx];
        let mut q = shard.q.lock().unwrap();
        loop {
            if !q.is_empty() {
                break;
            }
            if self.is_closed() {
                return None;
            }
            q = shard.cv.wait(q).unwrap();
        }
        let deadline = Instant::now() + self.max_wait;
        while q.len() < self.batch && !self.is_closed() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, res) = shard.cv.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            if res.timed_out() {
                break;
            }
        }
        let n = q.len().min(self.batch);
        Some(q.drain(..n).collect())
    }

    /// Begin graceful shutdown. Locking each shard before notifying
    /// closes the check-then-wait race, so no worker sleeps through it.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        for shard in &self.shards {
            let _guard = shard.q.lock().unwrap();
            shard.cv.notify_all();
        }
    }

    /// Total queued items right now (racy; telemetry only).
    pub fn queued(&self) -> usize {
        self.shards.iter().map(|s| s.q.lock().unwrap().len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher(shards: usize, batch: usize, wait_ms: u64, cap: usize) -> Batcher<u32> {
        Batcher::new(BatcherConfig {
            shards,
            batch,
            max_wait: Duration::from_millis(wait_ms),
            capacity_per_shard: cap,
        })
    }

    #[test]
    fn flushes_at_batch_size_without_waiting() {
        // max_wait is far beyond the test timeout: a full batch must
        // flush immediately.
        let b = batcher(1, 4, 60_000, 100);
        for i in 0..4 {
            b.push(i).unwrap();
        }
        let start = Instant::now();
        assert_eq!(b.pop_batch(0).unwrap(), vec![0, 1, 2, 3]);
        assert!(start.elapsed() < Duration::from_secs(10));
    }

    #[test]
    fn partial_batch_flushes_at_the_deadline() {
        let b = batcher(1, 8, 30, 100);
        b.push(7).unwrap();
        b.push(8).unwrap();
        let got = b.pop_batch(0).unwrap();
        assert_eq!(got, vec![7, 8], "deadline flush delivers the partial batch");
    }

    #[test]
    fn oversize_backlog_drains_in_batch_sized_chunks() {
        let b = batcher(1, 3, 1, 100);
        for i in 0..7 {
            b.push(i).unwrap();
        }
        assert_eq!(b.pop_batch(0).unwrap().len(), 3);
        assert_eq!(b.pop_batch(0).unwrap().len(), 3);
        assert_eq!(b.pop_batch(0).unwrap(), vec![6]);
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let b = batcher(1, 2, 1, 100);
        for i in 0..3 {
            b.push(i).unwrap();
        }
        b.close();
        assert_eq!(b.push(9), Err(PushError::Closed(9)));
        assert_eq!(b.pop_batch(0).unwrap(), vec![0, 1]);
        assert_eq!(b.pop_batch(0).unwrap(), vec![2]);
        assert_eq!(b.pop_batch(0), None, "closed and drained");
    }

    #[test]
    fn full_queue_rejects_with_backpressure() {
        let b = batcher(2, 1, 1, 2);
        for i in 0..4 {
            b.push(i).unwrap(); // 2 per shard
        }
        match b.push(99) {
            Err(PushError::Full(99)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(b.queued(), 4);
    }

    #[test]
    fn round_robin_spreads_across_shards() {
        let b = batcher(2, 10, 1, 100);
        for i in 0..6 {
            b.push(i).unwrap();
        }
        let a = b.pop_batch(0).unwrap();
        let c = b.pop_batch(1).unwrap();
        assert_eq!(a.len() + c.len(), 6);
        assert_eq!(a.len(), 3, "round-robin balance");
    }

    #[test]
    fn blocked_worker_wakes_on_close() {
        let b = std::sync::Arc::new(batcher(1, 4, 1000, 100));
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.pop_batch(0));
        std::thread::sleep(Duration::from_millis(50));
        b.close();
        assert_eq!(t.join().unwrap(), None);
    }
}

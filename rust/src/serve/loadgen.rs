//! Load generator with two loop disciplines:
//!
//! * **Closed-loop** (default): N client threads, each holding one TCP
//!   connection and issuing one request at a time (send, wait for the
//!   response, repeat) over the synthetic-digits workload with a
//!   round-robin QoS-tier rotation. Closed-loop clients measure the
//!   latency a real caller would see — including micro-batching delay —
//!   and requests/sec at a fixed concurrency, the serve bench's
//!   headline number.
//! * **Open-loop** (`--rate RPS`): each client paces request `k` to an
//!   *intended* send time `start + k * interval` regardless of how the
//!   server is doing, and latency is measured **from the intended send
//!   time**, not the actual one. This avoids coordinated omission: a
//!   closed-loop client that stalls (or a sender that falls behind)
//!   silently stops sampling exactly when the server is slowest, so a
//!   server-side pause shows up in at most one closed-loop sample —
//!   the open-loop numbers charge the whole queue of delayed requests
//!   for it. `--spike-after K --spike-ms M` injects a sender stall for
//!   exactly this demonstration: closed-loop latency barely moves,
//!   open-loop p99 eats the full stall.
//!
//! Latency aggregation uses fixed-size log2-bucketed histograms
//! ([`obs::hist`](crate::obs::hist)) — per-client histograms merge
//! exactly into global and per-tier rollups, so memory stays bounded
//! no matter how many requests a run issues. Every outcome is also
//! mirrored into the process-wide registry
//! (`pallas_loadgen_{requests_total,request_errors_total,latency_us}`
//! labelled by tier), which is what the `--slo` sampler and any
//! `monitor` watching this process judge. With `loadgen --trace` each
//! client runs under a `loadgen.client` span; closed-loop round trips
//! additionally get `loadgen.request` child spans (open-loop readers
//! decouple send from receive, so per-request spans would have no
//! single thread to live on — the client span plus the registry mirror
//! carry the signal instead).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::nn::synthetic_digits;
use crate::obs::timeseries::{MonotonicClock, TimeSeries};
use crate::obs::{metrics, Histogram, Obs, SloEvaluator, SloSpec};
use crate::util::Json;

use super::protocol::{self, ParsedResponse};

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Concurrent clients.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// Tier rotation (client `c`'s request `k` uses
    /// `tiers[(c + k) % len]`).
    pub tiers: Vec<String>,
    /// Seed for the image workload.
    pub seed: u64,
    /// `Some(rps)` switches to open-loop mode: the target *total*
    /// arrival rate, split evenly across clients, with latency charged
    /// from intended send times (no coordinated omission).
    pub rate: Option<f64>,
    /// Stall the sender for [`spike_ms`](Self::spike_ms) just before
    /// each client's request with this index — the injected incident
    /// the SLO watcher should catch.
    pub spike_after: Option<usize>,
    /// Injected stall length, milliseconds.
    pub spike_ms: u64,
    /// Judge the run's own registry mirror against these targets while
    /// it runs, counting breach entries into the stats.
    pub slo: Option<SloSpec>,
    /// SLO sampling period, milliseconds.
    pub sample_ms: u64,
    /// Tracing handle (`loadgen --trace`); [`Obs::off`] runs untraced.
    pub obs: Obs,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7878".to_string(),
            clients: 4,
            requests_per_client: 200,
            tiers: vec!["gold".to_string(), "silver".to_string(), "bronze".to_string()],
            seed: 7,
            rate: None,
            spike_after: None,
            spike_ms: 0,
            slo: None,
            sample_ms: 200,
            obs: Obs::off(),
        }
    }
}

/// Aggregates for one QoS tier: a client answers for the tier it
/// asked, so per-tier rollups need no server cooperation.
#[derive(Debug, Clone, Default)]
pub struct TierLoadStats {
    pub ok: usize,
    pub errors: usize,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

#[derive(Debug, Clone)]
pub struct LoadgenStats {
    pub sent: usize,
    pub ok: usize,
    pub errors: usize,
    pub elapsed_ms: f64,
    /// Completed requests per second across all clients.
    pub rps: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// SLO breach *entries* observed by the `--slo` sampler (0 when no
    /// spec was given).
    pub breaches: usize,
    /// Per-tier rollups, sorted by tier name.
    pub tiers: BTreeMap<String, TierLoadStats>,
}

impl LoadgenStats {
    pub fn report(&self) {
        println!(
            "loadgen: {} requests ({} ok, {} errors) in {:.1} ms -> {:.0} req/s, \
             latency p50 {} µs, p99 {} µs, max {} µs",
            self.sent, self.ok, self.errors, self.elapsed_ms, self.rps, self.p50_us,
            self.p99_us, self.max_us
        );
        for (tier, t) in &self.tiers {
            println!(
                "loadgen: tier {tier}: {} ok, {} errors, p50 {} µs, p99 {} µs, \
                 max {} µs",
                t.ok, t.errors, t.p50_us, t.p99_us, t.max_us
            );
        }
        if self.breaches > 0 {
            println!("loadgen: {} SLO breach(es) entered during the run", self.breaches);
        }
    }
}

struct ClientStats {
    ok: usize,
    errors: usize,
    lat: Histogram,
    /// (ok, errors, latency histogram) per tier this client exercised.
    tiers: BTreeMap<String, (usize, usize, Histogram)>,
}

impl ClientStats {
    fn new() -> ClientStats {
        ClientStats { ok: 0, errors: 0, lat: Histogram::new(), tiers: BTreeMap::new() }
    }
}

/// Cached registry handles for one tier's mirror metrics — the hot
/// path stays a few relaxed atomic ops per response.
struct TierMirror {
    requests: metrics::Counter,
    errors: metrics::Counter,
    lat: Arc<Histogram>,
}

fn tier_mirrors(tiers: &[String]) -> BTreeMap<String, TierMirror> {
    tiers
        .iter()
        .map(|t| {
            (
                t.clone(),
                TierMirror {
                    requests: metrics::counter(&format!(
                        "pallas_loadgen_requests_total{{tier=\"{t}\"}}"
                    )),
                    errors: metrics::counter(&format!(
                        "pallas_loadgen_request_errors_total{{tier=\"{t}\"}}"
                    )),
                    lat: metrics::histogram(&format!(
                        "pallas_loadgen_latency_us{{tier=\"{t}\"}}"
                    )),
                },
            )
        })
        .collect()
}

/// Fold one response into the client-local stats and the registry
/// mirror (both loop modes go through here).
fn record_outcome(
    stats: &mut ClientStats,
    mirrors: &BTreeMap<String, TierMirror>,
    tier: &str,
    ok: bool,
    us: u64,
) {
    stats.lat.record(us);
    let per_tier = stats.tiers.entry(tier.to_string()).or_default();
    per_tier.2.record(us);
    if let Some(m) = mirrors.get(tier) {
        m.requests.inc();
        m.lat.record(us);
        if !ok {
            m.errors.inc();
        }
    }
    if ok {
        stats.ok += 1;
        per_tier.0 += 1;
    } else {
        stats.errors += 1;
        per_tier.1 += 1;
    }
}

fn connect(addr: &str, client: usize) -> Result<(TcpStream, BufReader<TcpStream>)> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("client {client}: connecting {addr}"))?;
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .context("setting read timeout")?;
    let writer = stream.try_clone().context("cloning stream")?;
    Ok((writer, BufReader::new(stream)))
}

fn run_client(cfg: &LoadgenConfig, client: usize) -> Result<ClientStats> {
    let span = cfg.obs.span("loadgen.client", &[("client", Json::Num(client as f64))]);
    let obs = cfg.obs.child_of(&span);
    let (mut writer, mut reader) = connect(&cfg.addr, client)?;
    // Per-client image pool; different seeds keep clients from sending
    // identical byte streams.
    let pool = synthetic_digits(64, cfg.seed.wrapping_add(client as u64));
    let mirrors = tier_mirrors(&cfg.tiers);
    let mut stats = ClientStats::new();
    let mut line = String::new();
    for k in 0..cfg.requests_per_client {
        let tier = &cfg.tiers[(client + k) % cfg.tiers.len()];
        let img = &pool[k % pool.len()];
        let id = ((client as u64) << 32) | k as u64;
        let req = protocol::render_infer_request(id, tier, &img.pixels);
        let mut req_span = if obs.enabled() {
            Some(obs.span(
                "loadgen.request",
                &[("req", Json::Num(id as f64)), ("tier", Json::Str(tier.clone()))],
            ))
        } else {
            None
        };
        if cfg.spike_after == Some(k) && cfg.spike_ms > 0 {
            // Closed-loop spike: the stall happens *before* the clock
            // starts, so the measurement omits it — the coordinated
            // omission the open-loop mode exists to avoid.
            std::thread::sleep(Duration::from_millis(cfg.spike_ms));
        }
        let start = Instant::now();
        writer.write_all(req.as_bytes()).context("sending request")?;
        writer.write_all(b"\n").context("sending request")?;
        line.clear();
        let n = reader.read_line(&mut line).context("reading response")?;
        if n == 0 {
            bail!("client {client}: server closed the connection");
        }
        let resp: ParsedResponse = protocol::parse_response(line.trim())
            .map_err(|e| anyhow::anyhow!("client {client}: {e}"))?;
        if resp.id != id {
            bail!("client {client}: response id {} for request {id}", resp.id);
        }
        let us = start.elapsed().as_micros() as u64;
        if let Some(s) = req_span.as_mut() {
            s.field("status", Json::Str(if resp.ok { "ok" } else { "error" }.to_string()));
        }
        drop(req_span);
        record_outcome(&mut stats, &mirrors, tier, resp.ok, us);
    }
    span.finish();
    Ok(stats)
}

/// Open-loop client: a sender thread paces requests to their intended
/// times while this thread drains responses, charging each one from
/// its *intended* send time. The request id encodes `k`
/// (`(client << 32) | k`), so the reader recovers the intended time
/// and tier for any response without shared mutable state — responses
/// may arrive out of order (micro-batching reorders across tiers) and
/// still charge the right schedule slot.
fn run_client_open(cfg: &LoadgenConfig, client: usize, rate: f64) -> Result<ClientStats> {
    let span = cfg.obs.span(
        "loadgen.client",
        &[
            ("client", Json::Num(client as f64)),
            ("mode", Json::Str("open".to_string())),
        ],
    );
    let (mut writer, mut reader) = connect(&cfg.addr, client)?;
    let pool = synthetic_digits(64, cfg.seed.wrapping_add(client as u64));
    // The total target rate splits evenly: each of C clients sends
    // every C/rate seconds.
    let interval_s = cfg.clients as f64 / rate;
    let n = cfg.requests_per_client;
    let tiers = cfg.tiers.clone();
    let start = Instant::now();
    let read_side = std::thread::spawn(move || -> Result<ClientStats> {
        let mirrors = tier_mirrors(&tiers);
        let mut stats = ClientStats::new();
        let mut line = String::new();
        for _ in 0..n {
            line.clear();
            let got = reader.read_line(&mut line).context("reading response")?;
            if got == 0 {
                bail!("client {client}: server closed the connection");
            }
            let resp: ParsedResponse = protocol::parse_response(line.trim())
                .map_err(|e| anyhow::anyhow!("client {client}: {e}"))?;
            let k = (resp.id & 0xffff_ffff) as usize;
            let intended = start + Duration::from_secs_f64(interval_s * k as f64);
            let us = Instant::now().saturating_duration_since(intended).as_micros() as u64;
            let tier = &tiers[(client + k) % tiers.len()];
            record_outcome(&mut stats, &mirrors, tier, resp.ok, us);
        }
        Ok(stats)
    });
    let mut send_err: Option<anyhow::Error> = None;
    for k in 0..n {
        let intended = start + Duration::from_secs_f64(interval_s * k as f64);
        if let Some(wait) = intended.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        if cfg.spike_after == Some(k) && cfg.spike_ms > 0 {
            // Open-loop spike: the schedule does not move, so every
            // request delayed behind this stall is charged for it.
            std::thread::sleep(Duration::from_millis(cfg.spike_ms));
        }
        let tier = &cfg.tiers[(client + k) % cfg.tiers.len()];
        let img = &pool[k % pool.len()];
        let id = ((client as u64) << 32) | k as u64;
        let req = protocol::render_infer_request(id, tier, &img.pixels);
        if let Err(e) = writer
            .write_all(req.as_bytes())
            .and_then(|()| writer.write_all(b"\n"))
        {
            send_err = Some(anyhow::Error::from(e).context("sending request"));
            break;
        }
    }
    let stats = read_side
        .join()
        .map_err(|_| anyhow::anyhow!("client {client}: reader panicked"));
    span.finish();
    // A send failure explains the reader's failure; report it first.
    if let Some(e) = send_err {
        return Err(e);
    }
    stats?
}

/// Quantile rollup of a latency histogram into the stats shape
/// (`p50_us`/`p99_us`/`max_us` — `BENCH_serve.json` field names are
/// load-bearing).
fn rollup(h: &Histogram) -> (u64, u64, u64) {
    (h.quantile(0.50), h.quantile(0.99), h.max())
}

/// While clients run, sample the registry's `{prefix}_*` mirror into a
/// private [`TimeSeries`] and judge it against the spec; returns the
/// count of breach entries when stopped.
fn slo_watch(
    spec: SloSpec,
    sample_ms: u64,
    obs: Obs,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<usize> {
    std::thread::spawn(move || {
        let clock = MonotonicClock::default();
        let mut ts = TimeSeries::new("loadgen", 4096).with_filter(&spec.prefix);
        let mut ev = SloEvaluator::new(spec);
        let period = Duration::from_millis(sample_ms.max(1));
        let mut breaches = 0usize;
        loop {
            // Check-then-sample so the pass after `stop` still judges
            // the final state of the run.
            let stopping = stop.load(Ordering::SeqCst);
            ts.sample(&clock);
            breaches += ev.evaluate(&ts, &obs).len();
            if stopping {
                return breaches;
            }
            std::thread::sleep(period);
        }
    })
}

/// Run the workload (closed-loop, or open-loop when `rate` is set);
/// blocks until every client finishes.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadgenStats> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 || cfg.tiers.is_empty() {
        bail!("loadgen needs at least one client, one request and one tier");
    }
    if cfg.rate.is_some_and(|r| !(r > 0.0)) {
        bail!("loadgen --rate must be > 0");
    }
    let slo = cfg.slo.clone().map(|spec| {
        let stop = Arc::new(AtomicBool::new(false));
        let handle = slo_watch(spec, cfg.sample_ms, cfg.obs.clone(), stop.clone());
        (stop, handle)
    });
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.clients)
        .map(|c| {
            let cfg = cfg.clone();
            std::thread::spawn(move || match cfg.rate {
                Some(rate) => run_client_open(&cfg, c, rate),
                None => run_client(&cfg, c),
            })
        })
        .collect();
    let mut ok = 0usize;
    let mut errors = 0usize;
    // Exact merges: per-client histograms fold into one global and one
    // per-tier distribution, order-independent.
    let lat = Histogram::new();
    let mut tier_raw: BTreeMap<String, (usize, usize, Histogram)> = BTreeMap::new();
    for h in handles {
        let cs = h.join().map_err(|_| anyhow::anyhow!("loadgen client panicked"))??;
        ok += cs.ok;
        errors += cs.errors;
        lat.merge(&cs.lat);
        for (tier, (t_ok, t_err, t_lat)) in cs.tiers {
            let agg = tier_raw.entry(tier).or_default();
            agg.0 += t_ok;
            agg.1 += t_err;
            agg.2.merge(&t_lat);
        }
    }
    let elapsed = start.elapsed().as_secs_f64();
    let breaches = match slo {
        Some((stop, handle)) => {
            stop.store(true, Ordering::SeqCst);
            handle.join().unwrap_or(0)
        }
        None => 0,
    };
    if let Err(e) = cfg.obs.flush() {
        cfg.obs.warn("loadgen", &format!("trace flush failed: {e:#}"), &[]);
    }
    let tiers = tier_raw
        .into_iter()
        .map(|(tier, (t_ok, t_err, t_lat))| {
            let (p50_us, p99_us, max_us) = rollup(&t_lat);
            (tier, TierLoadStats { ok: t_ok, errors: t_err, p50_us, p99_us, max_us })
        })
        .collect();
    let (p50_us, p99_us, max_us) = rollup(&lat);
    Ok(LoadgenStats {
        sent: ok + errors,
        ok,
        errors,
        elapsed_ms: elapsed * 1e3,
        rps: (ok + errors) as f64 / elapsed.max(1e-9),
        p50_us,
        p99_us,
        max_us,
        breaches,
        tiers,
    })
}

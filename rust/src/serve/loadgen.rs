//! Closed-loop load generator: N client threads, each holding one TCP
//! connection and issuing one request at a time (send, wait for the
//! response, repeat) over the synthetic-digits workload with a
//! round-robin QoS-tier rotation. Closed-loop clients measure the
//! latency a real caller would see — including micro-batching delay —
//! and requests/sec at a fixed concurrency, the serve bench's headline
//! number.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::nn::synthetic_digits;

use super::percentile;
use super::protocol::{self, ParsedResponse};

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// Tier rotation (client `c`'s request `k` uses
    /// `tiers[(c + k) % len]`).
    pub tiers: Vec<String>,
    /// Seed for the image workload.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7878".to_string(),
            clients: 4,
            requests_per_client: 200,
            tiers: vec!["gold".to_string(), "silver".to_string(), "bronze".to_string()],
            seed: 7,
        }
    }
}

/// Aggregates for one QoS tier: a closed-loop client answers for the
/// tier it asked, so per-tier rollups need no server cooperation.
#[derive(Debug, Clone, Default)]
pub struct TierLoadStats {
    pub ok: usize,
    pub errors: usize,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

#[derive(Debug, Clone)]
pub struct LoadgenStats {
    pub sent: usize,
    pub ok: usize,
    pub errors: usize,
    pub elapsed_ms: f64,
    /// Completed requests per second across all clients.
    pub rps: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Per-tier rollups, sorted by tier name.
    pub tiers: BTreeMap<String, TierLoadStats>,
}

impl LoadgenStats {
    pub fn report(&self) {
        println!(
            "loadgen: {} requests ({} ok, {} errors) in {:.1} ms -> {:.0} req/s, \
             latency p50 {} µs, p99 {} µs, max {} µs",
            self.sent, self.ok, self.errors, self.elapsed_ms, self.rps, self.p50_us,
            self.p99_us, self.max_us
        );
        for (tier, t) in &self.tiers {
            println!(
                "loadgen: tier {tier}: {} ok, {} errors, p50 {} µs, p99 {} µs, \
                 max {} µs",
                t.ok, t.errors, t.p50_us, t.p99_us, t.max_us
            );
        }
    }
}

struct ClientStats {
    ok: usize,
    errors: usize,
    lat_us: Vec<u64>,
    /// (ok, errors, latencies) per tier this client exercised.
    tiers: BTreeMap<String, (usize, usize, Vec<u64>)>,
}

fn run_client(cfg: &LoadgenConfig, client: usize) -> Result<ClientStats> {
    let stream = TcpStream::connect(&cfg.addr)
        .with_context(|| format!("client {client}: connecting {}", cfg.addr))?;
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .context("setting read timeout")?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    // Per-client image pool; different seeds keep clients from sending
    // identical byte streams.
    let pool = synthetic_digits(64, cfg.seed.wrapping_add(client as u64));
    let mut stats = ClientStats {
        ok: 0,
        errors: 0,
        lat_us: Vec::new(),
        tiers: BTreeMap::new(),
    };
    let mut line = String::new();
    for k in 0..cfg.requests_per_client {
        let tier = &cfg.tiers[(client + k) % cfg.tiers.len()];
        let img = &pool[k % pool.len()];
        let id = ((client as u64) << 32) | k as u64;
        let req = protocol::render_infer_request(id, tier, &img.pixels);
        let start = Instant::now();
        writer.write_all(req.as_bytes()).context("sending request")?;
        writer.write_all(b"\n").context("sending request")?;
        line.clear();
        let n = reader.read_line(&mut line).context("reading response")?;
        if n == 0 {
            bail!("client {client}: server closed the connection");
        }
        let resp: ParsedResponse = protocol::parse_response(line.trim())
            .map_err(|e| anyhow::anyhow!("client {client}: {e}"))?;
        if resp.id != id {
            bail!("client {client}: response id {} for request {id}", resp.id);
        }
        let us = start.elapsed().as_micros() as u64;
        stats.lat_us.push(us);
        let per_tier = stats.tiers.entry(tier.clone()).or_default();
        per_tier.2.push(us);
        if resp.ok {
            stats.ok += 1;
            per_tier.0 += 1;
        } else {
            stats.errors += 1;
            per_tier.1 += 1;
        }
    }
    Ok(stats)
}

/// Run the closed-loop workload; blocks until every client finishes.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadgenStats> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 || cfg.tiers.is_empty() {
        bail!("loadgen needs at least one client, one request and one tier");
    }
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.clients)
        .map(|c| {
            let cfg = cfg.clone();
            std::thread::spawn(move || run_client(&cfg, c))
        })
        .collect();
    let mut ok = 0usize;
    let mut errors = 0usize;
    let mut lat_us: Vec<u64> = Vec::new();
    let mut tier_raw: BTreeMap<String, (usize, usize, Vec<u64>)> = BTreeMap::new();
    for h in handles {
        let cs = h.join().map_err(|_| anyhow::anyhow!("loadgen client panicked"))??;
        ok += cs.ok;
        errors += cs.errors;
        lat_us.extend(cs.lat_us);
        for (tier, (t_ok, t_err, t_lat)) in cs.tiers {
            let agg = tier_raw.entry(tier).or_default();
            agg.0 += t_ok;
            agg.1 += t_err;
            agg.2.extend(t_lat);
        }
    }
    let elapsed = start.elapsed().as_secs_f64();
    lat_us.sort_unstable();
    let tiers = tier_raw
        .into_iter()
        .map(|(tier, (t_ok, t_err, mut t_lat))| {
            t_lat.sort_unstable();
            (
                tier,
                TierLoadStats {
                    ok: t_ok,
                    errors: t_err,
                    p50_us: percentile(&t_lat, 0.50),
                    p99_us: percentile(&t_lat, 0.99),
                    max_us: t_lat.last().copied().unwrap_or(0),
                },
            )
        })
        .collect();
    Ok(LoadgenStats {
        sent: ok + errors,
        ok,
        errors,
        elapsed_ms: elapsed * 1e3,
        rps: (ok + errors) as f64 / elapsed.max(1e-9),
        p50_us: percentile(&lat_us, 0.50),
        p99_us: percentile(&lat_us, 0.99),
        max_us: lat_us.last().copied().unwrap_or(0),
        tiers,
    })
}

//! Closed-loop load generator: N client threads, each holding one TCP
//! connection and issuing one request at a time (send, wait for the
//! response, repeat) over the synthetic-digits workload with a
//! round-robin QoS-tier rotation. Closed-loop clients measure the
//! latency a real caller would see — including micro-batching delay —
//! and requests/sec at a fixed concurrency, the serve bench's headline
//! number.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::nn::synthetic_digits;

use super::percentile;
use super::protocol::{self, ParsedResponse};

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// Tier rotation (client `c`'s request `k` uses
    /// `tiers[(c + k) % len]`).
    pub tiers: Vec<String>,
    /// Seed for the image workload.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7878".to_string(),
            clients: 4,
            requests_per_client: 200,
            tiers: vec!["gold".to_string(), "silver".to_string(), "bronze".to_string()],
            seed: 7,
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoadgenStats {
    pub sent: usize,
    pub ok: usize,
    pub errors: usize,
    pub elapsed_ms: f64,
    /// Completed requests per second across all clients.
    pub rps: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl LoadgenStats {
    pub fn report(&self) {
        println!(
            "loadgen: {} requests ({} ok, {} errors) in {:.1} ms -> {:.0} req/s, \
             latency p50 {} µs, p99 {} µs, max {} µs",
            self.sent, self.ok, self.errors, self.elapsed_ms, self.rps, self.p50_us,
            self.p99_us, self.max_us
        );
    }
}

struct ClientStats {
    ok: usize,
    errors: usize,
    lat_us: Vec<u64>,
}

fn run_client(cfg: &LoadgenConfig, client: usize) -> Result<ClientStats> {
    let stream = TcpStream::connect(&cfg.addr)
        .with_context(|| format!("client {client}: connecting {}", cfg.addr))?;
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .context("setting read timeout")?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    // Per-client image pool; different seeds keep clients from sending
    // identical byte streams.
    let pool = synthetic_digits(64, cfg.seed.wrapping_add(client as u64));
    let mut stats = ClientStats { ok: 0, errors: 0, lat_us: Vec::new() };
    let mut line = String::new();
    for k in 0..cfg.requests_per_client {
        let tier = &cfg.tiers[(client + k) % cfg.tiers.len()];
        let img = &pool[k % pool.len()];
        let id = ((client as u64) << 32) | k as u64;
        let req = protocol::render_infer_request(id, tier, &img.pixels);
        let start = Instant::now();
        writer.write_all(req.as_bytes()).context("sending request")?;
        writer.write_all(b"\n").context("sending request")?;
        line.clear();
        let n = reader.read_line(&mut line).context("reading response")?;
        if n == 0 {
            bail!("client {client}: server closed the connection");
        }
        let resp: ParsedResponse = protocol::parse_response(line.trim())
            .map_err(|e| anyhow::anyhow!("client {client}: {e}"))?;
        if resp.id != id {
            bail!("client {client}: response id {} for request {id}", resp.id);
        }
        stats.lat_us.push(start.elapsed().as_micros() as u64);
        if resp.ok {
            stats.ok += 1;
        } else {
            stats.errors += 1;
        }
    }
    Ok(stats)
}

/// Run the closed-loop workload; blocks until every client finishes.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadgenStats> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 || cfg.tiers.is_empty() {
        bail!("loadgen needs at least one client, one request and one tier");
    }
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.clients)
        .map(|c| {
            let cfg = cfg.clone();
            std::thread::spawn(move || run_client(&cfg, c))
        })
        .collect();
    let mut ok = 0usize;
    let mut errors = 0usize;
    let mut lat_us: Vec<u64> = Vec::new();
    for h in handles {
        let cs = h.join().map_err(|_| anyhow::anyhow!("loadgen client panicked"))??;
        ok += cs.ok;
        errors += cs.errors;
        lat_us.extend(cs.lat_us);
    }
    let elapsed = start.elapsed().as_secs_f64();
    lat_us.sort_unstable();
    Ok(LoadgenStats {
        sent: ok + errors,
        ok,
        errors,
        elapsed_ms: elapsed * 1e3,
        rps: (ok + errors) as f64 / elapsed.max(1e-9),
        p50_us: percentile(&lat_us, 0.50),
        p99_us: percentile(&lat_us, 0.99),
        max_us: lat_us.last().copied().unwrap_or(0),
    })
}

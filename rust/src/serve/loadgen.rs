//! Closed-loop load generator: N client threads, each holding one TCP
//! connection and issuing one request at a time (send, wait for the
//! response, repeat) over the synthetic-digits workload with a
//! round-robin QoS-tier rotation. Closed-loop clients measure the
//! latency a real caller would see — including micro-batching delay —
//! and requests/sec at a fixed concurrency, the serve bench's headline
//! number.
//!
//! Latency aggregation uses fixed-size log2-bucketed histograms
//! ([`obs::hist`](crate::obs::hist)) — per-client histograms merge
//! exactly into global and per-tier rollups, so memory stays bounded
//! no matter how many requests a run issues. With `loadgen --trace`
//! each client runs under a `loadgen.client` span whose
//! `loadgen.request` children time individual round trips.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::nn::synthetic_digits;
use crate::obs::{Histogram, Obs};
use crate::util::Json;

use super::protocol::{self, ParsedResponse};

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// Tier rotation (client `c`'s request `k` uses
    /// `tiers[(c + k) % len]`).
    pub tiers: Vec<String>,
    /// Seed for the image workload.
    pub seed: u64,
    /// Tracing handle (`loadgen --trace`); [`Obs::off`] runs untraced.
    pub obs: Obs,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7878".to_string(),
            clients: 4,
            requests_per_client: 200,
            tiers: vec!["gold".to_string(), "silver".to_string(), "bronze".to_string()],
            seed: 7,
            obs: Obs::off(),
        }
    }
}

/// Aggregates for one QoS tier: a closed-loop client answers for the
/// tier it asked, so per-tier rollups need no server cooperation.
#[derive(Debug, Clone, Default)]
pub struct TierLoadStats {
    pub ok: usize,
    pub errors: usize,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

#[derive(Debug, Clone)]
pub struct LoadgenStats {
    pub sent: usize,
    pub ok: usize,
    pub errors: usize,
    pub elapsed_ms: f64,
    /// Completed requests per second across all clients.
    pub rps: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Per-tier rollups, sorted by tier name.
    pub tiers: BTreeMap<String, TierLoadStats>,
}

impl LoadgenStats {
    pub fn report(&self) {
        println!(
            "loadgen: {} requests ({} ok, {} errors) in {:.1} ms -> {:.0} req/s, \
             latency p50 {} µs, p99 {} µs, max {} µs",
            self.sent, self.ok, self.errors, self.elapsed_ms, self.rps, self.p50_us,
            self.p99_us, self.max_us
        );
        for (tier, t) in &self.tiers {
            println!(
                "loadgen: tier {tier}: {} ok, {} errors, p50 {} µs, p99 {} µs, \
                 max {} µs",
                t.ok, t.errors, t.p50_us, t.p99_us, t.max_us
            );
        }
    }
}

struct ClientStats {
    ok: usize,
    errors: usize,
    lat: Histogram,
    /// (ok, errors, latency histogram) per tier this client exercised.
    tiers: BTreeMap<String, (usize, usize, Histogram)>,
}

fn run_client(cfg: &LoadgenConfig, client: usize) -> Result<ClientStats> {
    let span = cfg.obs.span("loadgen.client", &[("client", Json::Num(client as f64))]);
    let obs = cfg.obs.child_of(&span);
    let stream = TcpStream::connect(&cfg.addr)
        .with_context(|| format!("client {client}: connecting {}", cfg.addr))?;
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .context("setting read timeout")?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    // Per-client image pool; different seeds keep clients from sending
    // identical byte streams.
    let pool = synthetic_digits(64, cfg.seed.wrapping_add(client as u64));
    let mut stats = ClientStats {
        ok: 0,
        errors: 0,
        lat: Histogram::new(),
        tiers: BTreeMap::new(),
    };
    let mut line = String::new();
    for k in 0..cfg.requests_per_client {
        let tier = &cfg.tiers[(client + k) % cfg.tiers.len()];
        let img = &pool[k % pool.len()];
        let id = ((client as u64) << 32) | k as u64;
        let req = protocol::render_infer_request(id, tier, &img.pixels);
        let mut req_span = if obs.enabled() {
            Some(obs.span(
                "loadgen.request",
                &[("req", Json::Num(id as f64)), ("tier", Json::Str(tier.clone()))],
            ))
        } else {
            None
        };
        let start = Instant::now();
        writer.write_all(req.as_bytes()).context("sending request")?;
        writer.write_all(b"\n").context("sending request")?;
        line.clear();
        let n = reader.read_line(&mut line).context("reading response")?;
        if n == 0 {
            bail!("client {client}: server closed the connection");
        }
        let resp: ParsedResponse = protocol::parse_response(line.trim())
            .map_err(|e| anyhow::anyhow!("client {client}: {e}"))?;
        if resp.id != id {
            bail!("client {client}: response id {} for request {id}", resp.id);
        }
        let us = start.elapsed().as_micros() as u64;
        if let Some(s) = req_span.as_mut() {
            s.field("status", Json::Str(if resp.ok { "ok" } else { "error" }.to_string()));
        }
        drop(req_span);
        stats.lat.record(us);
        let per_tier = stats.tiers.entry(tier.clone()).or_default();
        per_tier.2.record(us);
        if resp.ok {
            stats.ok += 1;
            per_tier.0 += 1;
        } else {
            stats.errors += 1;
            per_tier.1 += 1;
        }
    }
    span.finish();
    Ok(stats)
}

/// Quantile rollup of a latency histogram into the stats shape
/// (`p50_us`/`p99_us`/`max_us` — `BENCH_serve.json` field names are
/// load-bearing).
fn rollup(h: &Histogram) -> (u64, u64, u64) {
    (h.quantile(0.50), h.quantile(0.99), h.max())
}

/// Run the closed-loop workload; blocks until every client finishes.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadgenStats> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 || cfg.tiers.is_empty() {
        bail!("loadgen needs at least one client, one request and one tier");
    }
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.clients)
        .map(|c| {
            let cfg = cfg.clone();
            std::thread::spawn(move || run_client(&cfg, c))
        })
        .collect();
    let mut ok = 0usize;
    let mut errors = 0usize;
    // Exact merges: per-client histograms fold into one global and one
    // per-tier distribution, order-independent.
    let lat = Histogram::new();
    let mut tier_raw: BTreeMap<String, (usize, usize, Histogram)> = BTreeMap::new();
    for h in handles {
        let cs = h.join().map_err(|_| anyhow::anyhow!("loadgen client panicked"))??;
        ok += cs.ok;
        errors += cs.errors;
        lat.merge(&cs.lat);
        for (tier, (t_ok, t_err, t_lat)) in cs.tiers {
            let agg = tier_raw.entry(tier).or_default();
            agg.0 += t_ok;
            agg.1 += t_err;
            agg.2.merge(&t_lat);
        }
    }
    let elapsed = start.elapsed().as_secs_f64();
    if let Err(e) = cfg.obs.flush() {
        cfg.obs.warn("loadgen", &format!("trace flush failed: {e:#}"), &[]);
    }
    let tiers = tier_raw
        .into_iter()
        .map(|(tier, (t_ok, t_err, t_lat))| {
            let (p50_us, p99_us, max_us) = rollup(&t_lat);
            (tier, TierLoadStats { ok: t_ok, errors: t_err, p50_us, p99_us, max_us })
        })
        .collect();
    let (p50_us, p99_us, max_us) = rollup(&lat);
    Ok(LoadgenStats {
        sent: ok + errors,
        ok,
        errors,
        elapsed_ms: elapsed * 1e3,
        rps: (ok + errors) as f64 / elapsed.max(1e-9),
        p50_us,
        p99_us,
        max_us,
        tiers,
    })
}

//! The inference server: accept loop, per-connection reader/writer
//! threads, a sharded micro-batching worker pool, per-tier metrics and
//! graceful shutdown.
//!
//! Data flow: a connection reader parses each request line; control
//! requests (`stats`/`reload`/`shutdown`) are handled inline, `infer`
//! requests become [`WorkItem`]s pushed onto the [`Batcher`]. Each
//! worker owns one shard: it pops a micro-batch, groups it by tier,
//! resolves each tier once through the [`Registry`] (one `Arc` held
//! across the whole group, so a concurrent `reload` cannot swap an
//! operator *or its compiled kernel* mid-batch) and answers the group
//! with a single batched dispatch: the tier's [`CompiledMlp`] kernel
//! when one was compiled, the scalar
//! [`QuantMlp::classify_batch`] oracle otherwise (`serve
//! --scalar-path`, or an operator whose products overflow the kernel's
//! `i16` rows). Responses flow back through a per-connection mpsc
//! channel drained by a writer thread, so worker threads never
//! interleave bytes on a shared socket.
//!
//! Determinism: a response line is a pure function of (request line,
//! store contents) — inference is integer-exact, the compiled kernel
//! and `classify_batch` are byte-identical to the sequential path, and
//! the response renderer is deterministic — so worker count, batch
//! size, arrival order *and path choice* change only the *order* lines
//! appear on the wire, never their bytes. Clients match by `id`.
//!
//! Robustness: malformed lines, unknown tiers/benches, oversized
//! requests and queue-full backpressure all produce structured error
//! responses; a panic while processing a batch is caught and turned
//! into error responses for that batch — serving workers never die.
//!
//! Tracing (`serve --trace`): every `infer` request opens a
//! `serve.request` root span with a `serve.queue` child measuring
//! queue wait; both ride inside the [`WorkItem`] through the batcher.
//! Each popped micro-batch runs under a `serve.batch` span with one
//! `serve.compute` child per tier group (kernel vs scalar recorded as
//! a field); a batch serves many requests, so request spans link to it
//! via a `batch` field rather than a parent edge (spans have one
//! parent). Span guards are RAII — a panicking batch still ends every
//! span — and all of it is observe-only: response bytes are pinned
//! identical with tracing on vs off (`tests/obs_determinism.rs`).
//! Per-tier latency lives in fixed-size log2-bucketed histograms
//! ([`obs::hist`](crate::obs::hist)) — bounded memory on arbitrarily
//! long runs, with a registry mirror for metrics scrapes.
//!
//! Live telemetry (`watch`): a subscription spawns a sampler thread
//! that pushes one cumulative registry sample per period through the
//! connection's writer channel (`Response::Watch` lines interleaved
//! with ordinary responses — clients match by `id`). Teardown rides
//! the jsonl writer contract: when the subscriber disconnects the
//! writer thread exits, the sampler's `send` fails, and the sampler
//! stops — no leaked threads, no dead-socket spins. See DESIGN.md §14.

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::bench_support::JsonReport;
use crate::nn::digits::IMG;
#[allow(unused_imports)] // CompiledMlp: doc link target
use crate::nn::{synthetic_digits, CompiledMlp, QuantMlp};
use crate::obs::timeseries::{self, Clock, MonotonicClock};
use crate::obs::{metrics, Histogram, Obs, Span};
use crate::util::jsonl::{self, LineRead};
use crate::util::Json;

use super::batcher::{Batcher, BatcherConfig, PushError};
use super::protocol::{self, Request, Response};
use super::registry::Registry;

/// The canonical served model: the server, the integration tests, the
/// NN example and the load generator all train this exact MLP (same
/// data, geometry, seed), so server responses are reproducible against
/// direct local inference.
pub fn serving_mlp() -> QuantMlp {
    QuantMlp::train(&synthetic_digits(300, 11), 12, 15, 5)
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; `127.0.0.1:0` picks an ephemeral port (tests).
    pub addr: String,
    /// Serving workers (= batcher shards).
    pub workers: usize,
    /// Micro-batch flush threshold.
    pub batch: usize,
    /// Micro-batch flush deadline in milliseconds.
    pub batch_wait_ms: u64,
    /// Queued-request bound per worker shard (backpressure).
    pub queue_cap: usize,
    /// Default period for `watch` subscriptions (`serve --sample-ms`);
    /// a watch request may override it per subscription.
    pub sample_ms: u64,
    /// Tracing handle (`serve --trace`); [`Obs::off`] serves untraced.
    pub obs: Obs,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers: 4,
            batch: 8,
            batch_wait_ms: 2,
            queue_cap: 1024,
            sample_ms: 1000,
            obs: Obs::off(),
        }
    }
}

struct WorkItem {
    id: u64,
    tier: String,
    pixels: Vec<u8>,
    resp: Sender<String>,
    enqueued: Instant,
    /// `serve.request` root span — ends when the response is handed to
    /// the connection writer (or the item is rejected). Inert-free:
    /// absent entirely when tracing is off.
    span: Option<Span>,
    /// `serve.queue` child span — ends when a worker pops the batch.
    queue: Option<Span>,
}

struct TierStats {
    requests: u64,
    /// Per-server latency distribution: fixed 8 KiB however long the
    /// server runs, quantiles with bounded relative error.
    hist: Histogram,
    /// Mirrors in the process-wide registry (`obs::metrics`), labelled
    /// by tier; handles are cached here so the hot path stays a few
    /// relaxed atomic ops. (The histogram is mirrored rather than
    /// shared because benches run many servers per process — each
    /// server's `stats` must cover its own traffic only.)
    global: metrics::Counter,
    global_lat: Arc<Histogram>,
}

impl TierStats {
    fn new(tier: &str) -> TierStats {
        TierStats {
            requests: 0,
            hist: Histogram::new(),
            global: metrics::counter(&format!(
                "pallas_serve_requests_total{{tier=\"{tier}\"}}"
            )),
            global_lat: metrics::histogram(&format!(
                "pallas_serve_latency_us{{tier=\"{tier}\"}}"
            )),
        }
    }

    fn record(&mut self, us: u64) {
        self.hist.record(us);
        self.global_lat.record(us);
        self.requests += 1;
        self.global.inc();
    }
}

#[derive(Default)]
struct Metrics {
    tiers: Mutex<BTreeMap<String, TierStats>>,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    max_batch: AtomicU64,
    rejected: AtomicU64,
    request_errors: AtomicU64,
    connections: AtomicU64,
}

impl Metrics {
    fn record_infer(&self, tier: &str, lat_us: u64) {
        let mut tiers = self.tiers.lock().unwrap();
        tiers
            .entry(tier.to_string())
            .or_insert_with(|| TierStats::new(tier))
            .record(lat_us);
    }

    fn note_batch(&self, occupancy: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(occupancy as u64, Ordering::Relaxed);
        self.max_batch.fetch_max(occupancy as u64, Ordering::Relaxed);
        metrics::counter("pallas_serve_batches_total").inc();
        metrics::counter("pallas_serve_batched_requests_total").add(occupancy as u64);
    }

    fn note_errors(&self, n: usize) {
        self.request_errors.fetch_add(n as u64, Ordering::Relaxed);
        metrics::counter("pallas_serve_request_errors_total").add(n as u64);
    }

    /// An error attributable to a specific tier also bumps the
    /// per-tier labelled counter, so SLO error-rate targets can judge
    /// tiers independently (DESIGN.md §14).
    fn note_tier_errors(&self, tier: &str, n: usize) {
        self.note_errors(n);
        metrics::counter(&format!(
            "pallas_serve_request_errors_total{{tier=\"{tier}\"}}"
        ))
        .add(n as u64);
    }

    /// (requests, p50_us, p99_us) per tier, sorted by tier name.
    fn tier_rows(&self) -> Vec<(String, u64, u64, u64)> {
        let tiers = self.tiers.lock().unwrap();
        tiers
            .iter()
            .map(|(name, t)| {
                (name.clone(), t.requests, t.hist.quantile(0.50), t.hist.quantile(0.99))
            })
            .collect()
    }

    /// The machine-readable metrics block (`BENCH_serve.json` shape).
    fn fill_report(&self, registry: &Registry, report: &mut JsonReport) {
        for (name, requests, p50, p99) in self.tier_rows() {
            report.push(&format!("tier.{name}.requests"), requests as f64);
            report.push(&format!("tier.{name}.p50_us"), p50 as f64);
            report.push(&format!("tier.{name}.p99_us"), p99 as f64);
            if let Some(t) = registry.resolve(&name) {
                report.push(&format!("tier.{name}.area"), t.area);
                report.push(&format!("tier.{name}.max_err"), t.max_err as f64);
                report.push(
                    &format!("tier.{name}.compiled"),
                    if t.kernel.is_some() { 1.0 } else { 0.0 },
                );
            }
        }
        let batches = self.batches.load(Ordering::Relaxed);
        let batched = self.batched_requests.load(Ordering::Relaxed);
        report.push("batches", batches as f64);
        report.push("batched_requests", batched as f64);
        report.push(
            "mean_batch_occupancy",
            if batches == 0 { 0.0 } else { batched as f64 / batches as f64 },
        );
        report.push("max_batch_occupancy", self.max_batch.load(Ordering::Relaxed) as f64);
        report.push("rejected", self.rejected.load(Ordering::Relaxed) as f64);
        report.push("request_errors", self.request_errors.load(Ordering::Relaxed) as f64);
        report.push("connections", self.connections.load(Ordering::Relaxed) as f64);
    }
}

struct Shared {
    registry: Registry,
    batcher: Batcher<WorkItem>,
    metrics: Metrics,
    shutting_down: AtomicBool,
    addr: SocketAddr,
    sample_ms: u64,
    obs: Obs,
}

impl Shared {
    fn initiate_shutdown(&self) {
        if self.shutting_down.swap(true, Ordering::SeqCst) {
            return;
        }
        // Stop accepting new work; queued items still drain.
        self.batcher.close();
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }
}

pub struct Server {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind, spawn the worker pool and the accept loop, return
    /// immediately. The server runs until a `shutdown` request arrives
    /// or [`Server::shutdown`] is called. The served model (and its
    /// per-tier compiled kernels) comes from the registry, which owns
    /// it — see [`Registry::mlp`].
    pub fn start(cfg: &ServeConfig, registry: Registry) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let workers_n = cfg.workers.max(1);
        let shared = Arc::new(Shared {
            registry,
            batcher: Batcher::new(BatcherConfig {
                shards: workers_n,
                batch: cfg.batch,
                max_wait: Duration::from_millis(cfg.batch_wait_ms),
                capacity_per_shard: cfg.queue_cap,
            }),
            metrics: Metrics::default(),
            shutting_down: AtomicBool::new(false),
            addr,
            sample_ms: cfg.sample_ms.max(1),
            obs: cfg.obs.clone(),
        });
        let workers = (0..workers_n)
            .map(|w| {
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(sh, w))
            })
            .collect();
        let accept = {
            let sh = shared.clone();
            std::thread::spawn(move || accept_loop(sh, listener))
        };
        Ok(Server { shared, accept: Some(accept), workers })
    }

    /// The actually-bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Programmatic graceful shutdown (the TCP `shutdown` request is
    /// the remote spelling of this).
    pub fn shutdown(&self) {
        self.shared.initiate_shutdown();
    }

    /// Block until the accept loop and every worker exit (i.e. until
    /// shutdown completes), then return the final metrics as a
    /// [`JsonReport`] ready for `BENCH_serve.json`.
    pub fn join(mut self) -> JsonReport {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Err(e) = self.shared.obs.flush() {
            self.shared.obs.warn("serve", &format!("trace flush failed: {e:#}"), &[]);
        }
        let mut report = JsonReport::new();
        self.shared.metrics.fill_report(&self.shared.registry, &mut report);
        report
    }
}

fn accept_loop(shared: Arc<Shared>, listener: TcpListener) {
    for stream in listener.incoming() {
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        if let Ok(stream) = stream {
            shared.metrics.connections.fetch_add(1, Ordering::Relaxed);
            metrics::counter("pallas_serve_connections_total").inc();
            let sh = shared.clone();
            std::thread::spawn(move || handle_conn(sh, stream));
        }
    }
}

fn handle_conn(shared: Arc<Shared>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = channel::<String>();
    // Shared wire discipline (util::jsonl): one writer thread per
    // connection, capped line reads, structured errors.
    let writer = jsonl::spawn_writer(stream, rx);

    let mut reader = BufReader::new(read_half);
    loop {
        match jsonl::read_line(&mut reader) {
            LineRead::Eof => break,
            LineRead::Oversized => {
                // An over-cap line without a newline cannot be
                // re-framed, so it ends the connection after a
                // structured error.
                let _ = tx.send(
                    Response::Error {
                        id: 0,
                        error: format!(
                            "request line exceeds the {}-byte cap",
                            protocol::MAX_LINE_BYTES
                        ),
                    }
                    .render(),
                );
                break;
            }
            LineRead::Line(line) => {
                if line.is_empty() {
                    continue;
                }
                handle_request(&shared, &line, &tx);
            }
        }
    }
    drop(tx);
    let _ = writer.join();
}

fn send(tx: &Sender<String>, resp: Response) {
    let _ = tx.send(resp.render());
}

fn handle_request(shared: &Arc<Shared>, line: &str, tx: &Sender<String>) {
    let req = match protocol::parse_request(line) {
        Ok(r) => r,
        Err(error) => {
            shared.metrics.note_errors(1);
            send(tx, Response::Error { id: protocol::request_id(line), error });
            return;
        }
    };
    match req {
        Request::Stats { id } => {
            send(tx, Response::Stats { id, stats: stats_snapshot(shared) });
        }
        Request::Metrics { id } => {
            send(tx, Response::Metrics { id, metrics: metrics::snapshot() });
        }
        Request::Reload { id } => {
            let resp = match shared.registry.reload() {
                Ok(info) => Response::Ack { id, info },
                Err(e) => Response::Error { id, error: format!("reload failed: {e:#}") },
            };
            send(tx, resp);
        }
        Request::Shutdown { id } => {
            send(tx, Response::Ack { id, info: "shutting down".to_string() });
            shared.initiate_shutdown();
        }
        Request::Watch { id, sample_ms, count } => {
            // Subscription: a sampler thread pushes registry samples
            // through the connection's writer channel until the
            // subscriber disconnects (the writer thread dies, so
            // `tx.send` starts failing — the jsonl teardown contract),
            // the server shuts down, or `count` samples were pushed.
            let period =
                Duration::from_millis(sample_ms.unwrap_or(shared.sample_ms).max(1));
            let sub_tx = tx.clone();
            let sh = shared.clone();
            std::thread::spawn(move || watch_loop(sh, sub_tx, id, period, count));
        }
        Request::Infer { id, tier, bench, pixels } => {
            // Errors are attributed to the tier's labelled counter only
            // when the tier actually exists — labelling by arbitrary
            // client-supplied names would let a hostile client grow the
            // registry without bound.
            let known_tier = shared.registry.resolve(&tier).is_some();
            if let Some(b) = &bench {
                if b != shared.registry.bench() {
                    if known_tier {
                        shared.metrics.note_tier_errors(&tier, 1);
                    } else {
                        shared.metrics.note_errors(1);
                    }
                    send(
                        tx,
                        Response::Error {
                            id,
                            error: format!(
                                "unknown bench {b:?} (this server serves {})",
                                shared.registry.bench()
                            ),
                        },
                    );
                    return;
                }
            }
            if pixels.len() != IMG * IMG {
                if known_tier {
                    shared.metrics.note_tier_errors(&tier, 1);
                } else {
                    shared.metrics.note_errors(1);
                }
                send(
                    tx,
                    Response::Error {
                        id,
                        error: format!(
                            "expected {} pixels, got {}",
                            IMG * IMG,
                            pixels.len()
                        ),
                    },
                );
                return;
            }
            if !known_tier {
                shared.metrics.note_errors(1);
                send(
                    tx,
                    Response::Error {
                        id,
                        error: format!(
                            "unknown tier {tier:?}; have: {}",
                            shared.registry.tier_names().join(", ")
                        ),
                    },
                );
                return;
            }
            // Request-scoped span tree (only when tracing): the root
            // `serve.request` lives until the response is enqueued to
            // the writer; its `serve.queue` child measures queue wait.
            let (span, queue) = if shared.obs.enabled() {
                let span = shared.obs.span(
                    "serve.request",
                    &[
                        ("req", Json::Num(id as f64)),
                        ("tier", Json::Str(tier.clone())),
                    ],
                );
                let queue = shared.obs.child_of(&span).span("serve.queue", &[]);
                (Some(span), Some(queue))
            } else {
                (None, None)
            };
            let item = WorkItem {
                id,
                tier,
                pixels,
                resp: tx.clone(),
                enqueued: Instant::now(),
                span,
                queue,
            };
            match shared.batcher.push(item) {
                Ok(()) => {}
                Err(PushError::Full(mut item)) => {
                    shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    if let Some(s) = item.span.as_mut() {
                        s.field("status", Json::Str("rejected".to_string()));
                    }
                    send(
                        tx,
                        Response::Error {
                            id: item.id,
                            error: "server overloaded: request queue full".to_string(),
                        },
                    );
                }
                Err(PushError::Closed(mut item)) => {
                    if let Some(s) = item.span.as_mut() {
                        s.field("status", Json::Str("shutdown".to_string()));
                    }
                    send(
                        tx,
                        Response::Error {
                            id: item.id,
                            error: "server shutting down".to_string(),
                        },
                    );
                }
            }
        }
    }
}

/// The `watch` sampler: one thread per subscription, pushing one
/// cumulative registry sample per period as a `Response::Watch` line.
/// Cumulative (not delta) so a subscriber joining mid-run sees full
/// totals immediately; the receiving side ([`TimeSeries::
/// push_cumulative`](crate::obs::TimeSeries::push_cumulative)) turns
/// consecutive pushes into window deltas. Observe-only by
/// construction: it reads atomics the hot path was already bumping
/// and never touches the registry, batcher or sockets directly.
fn watch_loop(
    shared: Arc<Shared>,
    tx: Sender<String>,
    id: u64,
    period: Duration,
    count: Option<u64>,
) {
    let clock = MonotonicClock::default();
    let mut sent = 0u64;
    loop {
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let sample = timeseries::cumulative_sample("serve", clock.now_us(), None);
        if tx.send(Response::Watch { id, sample: sample.to_json() }.render()).is_err() {
            break; // subscriber gone: the writer thread dropped `rx`.
        }
        sent += 1;
        if count.is_some_and(|c| sent >= c) {
            break;
        }
        std::thread::sleep(period);
    }
}

fn worker_loop(shared: Arc<Shared>, shard: usize) {
    while let Some(mut batch) = shared.batcher.pop_batch(shard) {
        if batch.is_empty() {
            continue;
        }
        shared.metrics.note_batch(batch.len());
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            process_batch(&shared, &mut batch)
        }));
        if outcome.is_err() {
            // A worker must never die. Every item gets an error
            // response; items already answered before the panic may see
            // a duplicate id, which beats a silent drop. Any spans the
            // panicking half left in place end when `batch` drops — the
            // trace stays balanced.
            for item in &mut batch {
                shared.metrics.note_tier_errors(&item.tier, 1);
                if let Some(s) = item.span.as_mut() {
                    s.field("status", Json::Str("panic".to_string()));
                }
                let _ = item.resp.send(
                    Response::Error {
                        id: item.id,
                        error: "internal error while processing batch".to_string(),
                    }
                    .render(),
                );
            }
        }
    }
}

/// Answer one request and end its span: the response is rendered and
/// handed to the connection writer, which is where the server's
/// accounting of the request stops (the write itself is asynchronous).
fn respond(item: &mut WorkItem, status: &str, resp: Response) {
    if let Some(mut s) = item.span.take() {
        s.field("status", Json::Str(status.to_string()));
    }
    let _ = item.resp.send(resp.render());
}

fn process_batch(shared: &Shared, batch: &mut [WorkItem]) {
    // The whole micro-batch runs under one `serve.batch` span. A batch
    // serves many requests, so request spans can't parent it (spans
    // have exactly one parent) — instead each request span records the
    // batch span's id as a `batch` field, and queue-wait children end
    // here, where the batch was popped.
    let batch_span = if shared.obs.enabled() {
        let span = shared
            .obs
            .span("serve.batch", &[("occupancy", Json::Num(batch.len() as f64))]);
        let link = span.id().map(|id| Json::Num(id as f64));
        for item in batch.iter_mut() {
            item.queue.take();
            if let (Some(s), Some(link)) = (item.span.as_mut(), &link) {
                s.field("batch", link.clone());
            }
        }
        Some(span)
    } else {
        None
    };
    // Group by tier so each tier costs one registry resolution and one
    // batched LUT dispatch; the Arc pins the operator across the group
    // even if a reload swaps the registry mid-batch.
    let mut groups: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (i, item) in batch.iter().enumerate() {
        groups.entry(item.tier.clone()).or_default().push(i);
    }
    for (tier, idxs) in groups {
        let tier = tier.as_str();
        let Some(resolved) = shared.registry.resolve(tier) else {
            // Tier sets are fixed per registry, so this is unreachable
            // in practice — but a missing tier must degrade, not panic.
            shared.metrics.note_errors(idxs.len());
            for &i in &idxs {
                let item = &mut batch[i];
                let resp =
                    Response::Error { id: item.id, error: format!("unknown tier {tier:?}") };
                respond(item, "error", resp);
            }
            continue;
        };
        let images: Vec<&[u8]> = idxs.iter().map(|&i| batch[i].pixels.as_slice()).collect();
        // Compiled kernel when the tier has one, scalar oracle
        // otherwise — byte-identical either way. Shape/range errors
        // are checked on this path (a bad image must never panic a
        // worker or poison its batch-mates).
        let mut compute = batch_span.as_ref().map(|bs| {
            shared.obs.child_of(bs).span(
                "serve.compute",
                &[
                    ("tier", Json::Str(tier.to_string())),
                    ("n", Json::Num(idxs.len() as f64)),
                    (
                        "path",
                        Json::Str(
                            if resolved.kernel.is_some() { "kernel" } else { "scalar" }
                                .to_string(),
                        ),
                    ),
                ],
            )
        });
        let labels = match &resolved.kernel {
            Some(kernel) => kernel.try_classify_batch(&images),
            None => shared.registry.mlp().try_classify_batch(&images, &resolved.lut),
        };
        compute.take();
        let labels = match labels {
            Ok(labels) => labels,
            Err(e) => {
                shared.metrics.note_tier_errors(tier, idxs.len());
                for &i in &idxs {
                    let item = &mut batch[i];
                    let resp = Response::Error {
                        id: item.id,
                        error: format!("inference failed: {e}"),
                    };
                    respond(item, "error", resp);
                }
                continue;
            }
        };
        let source = resolved.source_str();
        for (&i, label) in idxs.iter().zip(labels) {
            let item = &mut batch[i];
            shared
                .metrics
                .record_infer(tier, item.enqueued.elapsed().as_micros() as u64);
            let resp = Response::Infer {
                id: item.id,
                label,
                tier: tier.to_string(),
                max_err: resolved.max_err,
                area: resolved.area,
                source: source.clone(),
            };
            respond(item, "ok", resp);
        }
    }
}

/// The `stats` response payload: a flat object mirroring
/// `BENCH_serve.json` plus per-tier registry provenance.
fn stats_snapshot(shared: &Shared) -> Json {
    let mut m = BTreeMap::new();
    m.insert("bench".to_string(), Json::Str(shared.registry.bench().to_string()));
    m.insert(
        "queued".to_string(),
        Json::Num(shared.batcher.queued() as f64),
    );
    let mut report = JsonReport::new();
    shared.metrics.fill_report(&shared.registry, &mut report);
    for (k, v) in report.entries() {
        m.insert(
            k.clone(),
            if v.is_finite() { Json::Num(*v) } else { Json::Null },
        );
    }
    for (name, tier) in shared.registry.snapshot().iter() {
        m.insert(format!("tier.{name}.et"), Json::Num(tier.et as f64));
        m.insert(format!("tier.{name}.source"), Json::Str(tier.source_str()));
        m.insert(format!("tier.{name}.path"), Json::Str(tier.path_str().to_string()));
    }
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The old sort-based `percentile` helper's rank-selection cases,
    /// kept as accuracy tests for its histogram replacement: exact in
    /// the sub-64 unit-bucket range and at the min/max edges, within
    /// the documented 1/64 relative bound elsewhere.
    #[test]
    fn histogram_quantiles_pick_expected_ranks() {
        assert_eq!(Histogram::new().quantile(0.5), 0, "empty -> 0");
        let single = Histogram::new();
        single.record(7);
        assert_eq!(single.quantile(0.99), 7);
        let h = Histogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(0.5), 51); // round((99)*0.5)=50 -> 51st value
        assert_eq!(h.quantile(1.0), 100);
        let p99 = h.quantile(0.99); // exact rank value is 99
        assert!(p99.abs_diff(99) <= 99 / 64 + 1, "p99 estimate {p99}");
    }

    #[test]
    fn tier_stats_stay_bounded_past_any_cap() {
        let mut t = TierStats::new("hist_test");
        let n = 10_000u64;
        for i in 0..n {
            t.record(i);
        }
        assert_eq!(t.requests, n);
        assert_eq!(t.hist.count(), n, "every sample recorded, none evicted");
        // Memory is fixed by construction (no Vec to grow); quantiles
        // still track the full distribution within the error bound.
        let p50 = t.hist.quantile(0.50);
        let exact = n / 2;
        assert!(p50.abs_diff(exact) <= exact / 32 + 1, "p50 {p50} vs {exact}");
        assert_eq!(t.hist.min(), 0);
        assert_eq!(t.hist.max(), n - 1);
    }
}

//! The tiered operator registry: QoS tier name → verified min-area
//! multiplier LUT.
//!
//! A tier is a named error budget (`gold=0,silver=4,bronze=16`). At
//! startup every tier is resolved against the operator library's
//! Pareto frontier: the min-area stored operator whose *achieved*
//! worst-case error fits the budget ([`OpLib::best_verified`] — the
//! entry is re-verified against the exhaustive oracle exactly as
//! `oplib best` does), falling back to the exact multiplier when the
//! library has nothing within budget (the exact LUT is sound for every
//! budget; it just saves no area). A malformed or tampered store entry
//! therefore surfaces as a resolution *error*, never as a panic inside
//! a serving worker.
//!
//! [`Registry::reload`] re-resolves every tier from the store
//! *directory* (reopened, so operators appended by a sweep in another
//! process since startup are picked up) and atomically swaps the tier
//! map. In-flight requests keep the `Arc<ResolvedTier>` they already
//! resolved, so a reload never drops or corrupts requests mid-batch; a
//! failed reload (store unreadable, best entry fails re-verification)
//! leaves the current map serving untouched.
//!
//! Kernel compile lifecycle (DESIGN.md §12): the registry owns the
//! serving [`QuantMlp`], and resolution folds each tier's LUT into a
//! [`CompiledMlp`] right after the operator verifies — so a tier's
//! kernel is recompiled atomically with its operator on every reload,
//! and an in-flight batch's pinned `Arc<ResolvedTier>` keeps both the
//! LUT *and* the kernel it resolved. A LUT whose products don't fit
//! the kernel's `i16` rows (legal on the 16-bit bus) degrades that
//! tier to the scalar path (`kernel = None`) instead of failing
//! resolution; `serve --scalar-path` forces `kernel = None` everywhere
//! for differential testing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, bail, Context, Result};

use crate::circuit::generators::benchmark_by_name;
use crate::nn::{CompiledMlp, MultLut, QuantMlp};
use crate::store::{OpLib, Store};
use crate::synth::synthesize_area;

/// The default QoS ladder: tier name = quality class, value = error
/// budget `et` for the served 4x4 multiplier.
pub const DEFAULT_TIERS: &str = "gold=0,silver=4,bronze=16";

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TierSpec {
    pub name: String,
    pub et: u64,
}

/// Parse a `name=et,name=et,...` tier specification.
pub fn parse_tiers(spec: &str) -> Result<Vec<TierSpec>> {
    let mut out: Vec<TierSpec> = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (name, et) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("tier {part:?}: expected name=et"))?;
        let name = name.trim();
        let et: u64 = et
            .trim()
            .parse()
            .map_err(|_| anyhow!("tier {part:?}: bad error budget"))?;
        if name.is_empty() {
            bail!("tier {part:?}: empty name");
        }
        if out.iter().any(|t| t.name == name) {
            bail!("duplicate tier {name:?}");
        }
        out.push(TierSpec { name: name.to_string(), et });
    }
    if out.is_empty() {
        bail!("no tiers in {spec:?}");
    }
    Ok(out)
}

/// Where a tier's operator came from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TierSource {
    /// Min-area hit on the library's Pareto frontier.
    OpLib { method: &'static str, fingerprint: String },
    /// Nothing stored within budget: the exact multiplier (sound for
    /// every budget, zero area saving).
    ExactFallback,
}

/// One resolved tier, immutable once published; workers hold it via
/// `Arc` across a whole micro-batch.
#[derive(Debug, Clone)]
pub struct ResolvedTier {
    pub name: String,
    pub et: u64,
    /// The serving operator's achieved worst-case error (0 for exact).
    pub max_err: u64,
    pub area: f64,
    pub source: TierSource,
    pub lut: MultLut,
    /// The tier's LUT folded into the serving model's weights —
    /// compiled at resolve/reload time, pinned with the tier by
    /// in-flight batches. `None` when kernels are disabled
    /// (`serve --scalar-path`) or the LUT's products overflow the
    /// kernel's `i16` rows; workers then fall back to the scalar
    /// `classify_batch` oracle.
    pub kernel: Option<Arc<CompiledMlp>>,
}

impl ResolvedTier {
    /// Provenance string for responses: `oplib:<METHOD>:<fp>` / `exact`.
    pub fn source_str(&self) -> String {
        match &self.source {
            TierSource::OpLib { method, fingerprint } => {
                format!("oplib:{method}:{fingerprint}")
            }
            TierSource::ExactFallback => "exact".to_string(),
        }
    }

    /// Which inference path this tier runs (`stats` reporting).
    pub fn path_str(&self) -> &'static str {
        if self.kernel.is_some() {
            "compiled"
        } else {
            "scalar"
        }
    }
}

type TierMap = BTreeMap<String, Arc<ResolvedTier>>;

pub struct Registry {
    bench: &'static str,
    tiers: Vec<TierSpec>,
    store_dir: Option<PathBuf>,
    /// The model every tier serves; owned here so kernel compilation
    /// and the scalar fallback can never disagree about the weights.
    mlp: Arc<QuantMlp>,
    /// `false` = `serve --scalar-path`: resolution skips kernel
    /// compilation and every tier runs the scalar oracle.
    compile_kernels: bool,
    current: RwLock<Arc<TierMap>>,
    /// Serializes whole reloads (resolve + publish): without it, two
    /// concurrent reloads could publish their maps in the opposite
    /// order of their store reads, leaving the *older* snapshot live.
    reload_lock: Mutex<()>,
}

impl Registry {
    /// Resolve every tier once at startup. `store_dir = None` is the
    /// degenerate no-library mode: every tier serves the exact LUT.
    pub fn open(
        bench: &'static str,
        tiers: Vec<TierSpec>,
        store_dir: Option<&Path>,
        mlp: Arc<QuantMlp>,
        compile_kernels: bool,
    ) -> Result<Registry> {
        let b = benchmark_by_name(bench)
            .ok_or_else(|| anyhow!("unknown benchmark {bench:?}"))?;
        if b.netlist().n_inputs() != 8 {
            bail!(
                "serving needs a 4x4 multiplier benchmark (8 inputs); {bench} has {}",
                b.netlist().n_inputs()
            );
        }
        if tiers.is_empty() {
            bail!("at least one QoS tier required");
        }
        let map = resolve_all(bench, &tiers, store_dir, &mlp, compile_kernels)?;
        Ok(Registry {
            bench,
            tiers,
            store_dir: store_dir.map(Path::to_path_buf),
            mlp,
            compile_kernels,
            current: RwLock::new(Arc::new(map)),
            reload_lock: Mutex::new(()),
        })
    }

    pub fn bench(&self) -> &'static str {
        self.bench
    }

    /// The model every tier serves (scalar-oracle dispatch and stats).
    pub fn mlp(&self) -> &Arc<QuantMlp> {
        &self.mlp
    }

    /// The current resolution of one tier. `None` = unknown tier name
    /// (the tier *set* is fixed for the registry's lifetime; reloads
    /// only change what each tier resolves to).
    pub fn resolve(&self, tier: &str) -> Option<Arc<ResolvedTier>> {
        self.current.read().unwrap().get(tier).cloned()
    }

    /// Snapshot of the whole tier map (stats reporting).
    pub fn snapshot(&self) -> Arc<TierMap> {
        self.current.read().unwrap().clone()
    }

    /// Known tier names, for error messages.
    pub fn tier_names(&self) -> Vec<String> {
        self.tiers.iter().map(|t| t.name.clone()).collect()
    }

    /// Re-resolve every tier from the store directory and atomically
    /// publish the new map. Returns a human-readable summary. On error
    /// the previous map keeps serving.
    pub fn reload(&self) -> Result<String> {
        // One reload at a time: the store read and the publish must not
        // interleave with another reload's, or a stale snapshot could
        // be published last.
        let _serialized = self.reload_lock.lock().unwrap();
        let map = resolve_all(
            self.bench,
            &self.tiers,
            self.store_dir.as_deref(),
            &self.mlp,
            self.compile_kernels,
        )?;
        let from_lib = map
            .values()
            .filter(|t| matches!(t.source, TierSource::OpLib { .. }))
            .count();
        let summary = format!(
            "reloaded {} tiers for {} ({from_lib} from the library, {} exact fallback)",
            map.len(),
            self.bench,
            map.len() - from_lib
        );
        *self.current.write().unwrap() = Arc::new(map);
        crate::obs::metrics::counter("pallas_serve_reloads_total").inc();
        crate::obs::log::info("serve.registry", &summary, &[]);
        Ok(summary)
    }
}

fn resolve_all(
    bench: &'static str,
    tiers: &[TierSpec],
    store_dir: Option<&Path>,
    mlp: &Arc<QuantMlp>,
    compile_kernels: bool,
) -> Result<TierMap> {
    let lib = match store_dir {
        Some(d) => {
            // Read-only: tier resolution must work (and reload must
            // keep working) while a sweep process holds the store's
            // writer lock.
            let store = Store::open_read_only(d)
                .with_context(|| format!("opening operator store {}", d.display()))?;
            Some(OpLib::from_store(&store))
        }
        None => None,
    };
    let exact_area = synthesize_area(&benchmark_by_name(bench).unwrap().netlist());
    let mut map = TierMap::new();
    for t in tiers {
        let entry = match &lib {
            Some(l) => l
                .best_verified(bench, t.et)
                .with_context(|| format!("resolving tier {:?} (et<={})", t.name, t.et))?,
            None => None,
        };
        let mut resolved = match entry {
            Some(e) => ResolvedTier {
                name: t.name.clone(),
                et: t.et,
                max_err: e.max_err,
                area: e.area,
                source: TierSource::OpLib {
                    method: e.method.name(),
                    fingerprint: e.fingerprint.to_string(),
                },
                lut: MultLut::try_from_values(&e.values).map_err(|m| {
                    anyhow!("tier {:?}: stored operator {}: {m}", t.name, e.fingerprint)
                })?,
                kernel: None,
            },
            None => ResolvedTier {
                name: t.name.clone(),
                et: t.et,
                max_err: 0,
                area: exact_area,
                source: TierSource::ExactFallback,
                lut: MultLut::exact(),
                kernel: None,
            },
        };
        if compile_kernels {
            // A non-compilable operator (i16 product overflow) is a
            // *degradation* to the scalar path, not a resolution
            // failure: the tier still serves, stats show path=scalar.
            resolved.kernel =
                CompiledMlp::try_compile(mlp, &resolved.lut).ok().map(Arc::new);
        }
        map.insert(t.name.clone(), Arc::new(resolved));
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Method, RunRecord};
    use crate::nn::synthetic_digits;
    use crate::store::Fingerprint;

    /// A small but real model — kernel compilation is geometry-generic.
    fn tiny_mlp() -> Arc<QuantMlp> {
        Arc::new(QuantMlp::train(&synthetic_digits(40, 3), 4, 2, 1))
    }

    fn tmp_store(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("sxpat_registry_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// A sound mult_i8 record: exact products with the low `mask_bits`
    /// output bits cleared, max_err recorded honestly.
    fn masked_mult_record(mask_bits: u32, area: f64) -> RunRecord {
        let mask = !((1u64 << mask_bits) - 1);
        let values: Vec<u64> =
            (0..256u64).map(|x| ((x & 15) * (x >> 4)) & mask).collect();
        let max_err = (0..256u64)
            .map(|x| ((x & 15) * (x >> 4)).abs_diff(((x & 15) * (x >> 4)) & mask))
            .max()
            .unwrap();
        RunRecord {
            bench: "mult_i8",
            method: Method::Shared,
            et: max_err,
            area,
            max_err,
            mean_err: 0.5,
            proxy: (0, 0),
            elapsed_ms: 1,
            cached: false,
            values,
            all_points: Vec::new(),
            error: None,
        }
    }

    #[test]
    fn parse_tiers_accepts_and_rejects() {
        let tiers = parse_tiers(" gold=0, silver=4 ,bronze=16").unwrap();
        assert_eq!(tiers.len(), 3);
        assert_eq!(tiers[1], TierSpec { name: "silver".to_string(), et: 4 });
        assert!(parse_tiers("").is_err());
        assert!(parse_tiers("gold").is_err());
        assert!(parse_tiers("gold=x").is_err());
        assert!(parse_tiers("=3").is_err());
        assert!(parse_tiers("a=1,a=2").is_err());
        parse_tiers(DEFAULT_TIERS).unwrap();
    }

    #[test]
    fn no_store_registry_serves_exact_everywhere() {
        let mlp = tiny_mlp();
        let reg = Registry::open(
            "mult_i8",
            parse_tiers(DEFAULT_TIERS).unwrap(),
            None,
            mlp.clone(),
            true,
        )
        .unwrap();
        for name in reg.tier_names() {
            let t = reg.resolve(&name).unwrap();
            assert_eq!(t.source, TierSource::ExactFallback);
            assert_eq!(t.max_err, 0);
            assert_eq!(t.lut.max_error(), 0);
            // Exact products always fit i16 rows: every tier compiles.
            let kernel = t.kernel.as_ref().expect("exact LUT must compile");
            assert_eq!(t.path_str(), "compiled");
            assert_eq!(kernel.n_in(), mlp.n_in());
        }
        assert!(reg.resolve("platinum").is_none());
        // Non-multiplier geometry is rejected up front.
        assert!(Registry::open(
            "adder_i4",
            parse_tiers(DEFAULT_TIERS).unwrap(),
            None,
            tiny_mlp(),
            true
        )
        .is_err());
    }

    #[test]
    fn scalar_mode_skips_kernel_compilation() {
        let reg = Registry::open(
            "mult_i8",
            parse_tiers(DEFAULT_TIERS).unwrap(),
            None,
            tiny_mlp(),
            false,
        )
        .unwrap();
        for name in reg.tier_names() {
            let t = reg.resolve(&name).unwrap();
            assert!(t.kernel.is_none());
            assert_eq!(t.path_str(), "scalar");
        }
    }

    #[test]
    fn compiled_kernel_matches_the_tier_lut() {
        let dir = tmp_store("kernelparity");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &masked_mult_record(2, 40.0)).unwrap();
        }
        let mlp = tiny_mlp();
        let reg = Registry::open(
            "mult_i8",
            parse_tiers("silver=4").unwrap(),
            Some(dir.as_path()),
            mlp.clone(),
            true,
        )
        .unwrap();
        let silver = reg.resolve("silver").unwrap();
        assert!(matches!(silver.source, TierSource::OpLib { .. }));
        let kernel = silver.kernel.as_ref().expect("masked LUT fits i16 rows");
        let data = synthetic_digits(30, 9);
        let images: Vec<&[u8]> = data.iter().map(|s| s.pixels.as_slice()).collect();
        assert_eq!(
            kernel.classify_batch(&images),
            mlp.classify_batch(&images, &silver.lut)
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reload_swaps_in_better_operators_atomically() {
        let dir = tmp_store("reload");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &masked_mult_record(2, 40.0)).unwrap();
        }
        let reg = Registry::open(
            "mult_i8",
            parse_tiers("silver=4,gold=0").unwrap(),
            Some(dir.as_path()),
            tiny_mlp(),
            true,
        )
        .unwrap();
        let silver = reg.resolve("silver").unwrap();
        assert_eq!(silver.area, 40.0);
        assert!(matches!(silver.source, TierSource::OpLib { .. }));
        assert_eq!(silver.path_str(), "compiled");
        // gold (et=0) has no stored operator -> exact fallback.
        assert_eq!(reg.resolve("gold").unwrap().source, TierSource::ExactFallback);

        // A strictly better operator lands in the WAL (another sweep).
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(2), &masked_mult_record(1, 9.5)).unwrap();
        }
        // Not visible until reload...
        assert_eq!(reg.resolve("silver").unwrap().area, 40.0);
        let summary = reg.reload().unwrap();
        assert!(summary.contains("2 tiers"), "{summary}");
        assert_eq!(reg.resolve("silver").unwrap().area, 9.5);
        // ...and the Arc held across the swap stays valid (in-flight
        // requests keep their operator).
        assert_eq!(silver.area, 40.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn failed_reload_keeps_serving_the_old_map() {
        let dir = tmp_store("badreload");
        {
            let st = Store::open(&dir).unwrap();
            st.append(Fingerprint(1), &masked_mult_record(2, 40.0)).unwrap();
        }
        let reg = Registry::open(
            "mult_i8",
            parse_tiers("silver=4").unwrap(),
            Some(dir.as_path()),
            tiny_mlp(),
            true,
        )
        .unwrap();
        // A tampered "better" record: smaller area but an unsound table
        // (claims max_err 0 with wrong values) — re-verification on the
        // resolve path must reject it.
        {
            let st = Store::open(&dir).unwrap();
            let mut bad = masked_mult_record(0, 1.0);
            bad.values[10] += 100;
            st.append(Fingerprint(3), &bad).unwrap();
        }
        assert!(reg.reload().is_err());
        let silver = reg.resolve("silver").unwrap();
        assert_eq!(silver.area, 40.0, "old map must keep serving");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

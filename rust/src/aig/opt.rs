//! AIG optimisation: exhaustive-simulation functional reduction.
//!
//! For circuits with <= 16 inputs, simulating every input point is exact,
//! so equivalence-up-to-complement merging here is *complete* (a
//! "fraig" whose SAT oracle never gets consulted). Combined with the
//! structural hashing performed on reconstruction, this subsumes constant
//! propagation, duplicate-cone sharing and inverter push-through — the
//! bulk of what `abc`'s light scripts buy on circuits of this size.

use std::collections::HashMap;

use super::graph::{self, Aig, Lit};

/// Functionally reduce `g`: merge every pair of nodes whose exhaustive
/// truth tables agree (possibly complemented), then rebuild and sweep.
/// Iterates to a fixpoint on the live AND count.
pub fn optimize(g: &Aig) -> Aig {
    let mut cur = reduce_once(g);
    loop {
        let next = reduce_once(&cur);
        if next.live_and_count() >= cur.live_and_count() {
            return cur;
        }
        cur = next;
    }
}

fn reduce_once(g: &Aig) -> Aig {
    let rows = g.simulate_all();
    let mut out = Aig::new(g.n_inputs);

    // Canonical key per truth table: complement so the bit at input point
    // 0 is 0; `phase` records whether we complemented.
    let canon = |row: &[u64]| -> (Vec<u64>, bool) {
        if row[0] & 1 == 1 {
            (row.iter().map(|w| !w).collect(), true)
        } else {
            (row.to_vec(), false)
        }
    };
    let mask = if g.n_inputs < 6 { (1u64 << (1usize << g.n_inputs)) - 1 } else { !0 };
    let canon_masked = |row: &[u64]| -> (Vec<u64>, bool) {
        let (mut key, ph) = canon(row);
        if let Some(w0) = key.first_mut() {
            *w0 &= mask;
        }
        for w in key.iter_mut().skip(1) {
            // already full words
            let _ = w;
        }
        (key, ph)
    };

    // class: canonical truth table -> NEW-graph literal computing it.
    let mut class: HashMap<Vec<u64>, Lit> = HashMap::new();
    class.insert(vec![0u64; rows[0].len()], graph::FALSE);

    // map: old variable -> new-graph literal with the variable's function.
    let mut map: Vec<Lit> = vec![graph::FALSE; g.n_vars()];
    for j in 0..g.n_inputs {
        let l = out.input(j);
        map[1 + j] = l;
        let (key, ph) = canon_masked(&rows[1 + j]);
        debug_assert!(!ph, "input pattern has bit 0 set");
        class.entry(key).or_insert(l);
    }

    for (i, nd) in g.ands.iter().enumerate() {
        let v = 1 + g.n_inputs + i;
        let (key, phase) = canon_masked(&rows[v]);
        if let Some(&canon_lit) = class.get(&key) {
            // Function (up to complement) already built: reuse it.
            map[v] = if phase { graph::not(canon_lit) } else { canon_lit };
            continue;
        }
        let a = translate(&map, nd.0);
        let b = translate(&map, nd.1);
        let l = out.and(a, b);
        map[v] = l;
        class.insert(key, if phase { graph::not(l) } else { l });
    }
    out.outputs = g.outputs.iter().map(|&l| translate(&map, l)).collect();
    out
}

/// Apply the variable map to a literal from the *old* graph.
fn translate(map: &[Lit], l: Lit) -> Lit {
    let base = map[graph::var(l) as usize];
    if graph::is_compl(l) {
        graph::not(base)
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::build::netlist_to_aig;
    use crate::circuit::generators::PAPER_BENCHMARKS;
    use crate::circuit::netlist::{GateKind, Netlist};

    #[test]
    fn optimize_preserves_function_on_benchmarks() {
        for b in &PAPER_BENCHMARKS {
            let g = netlist_to_aig(&b.netlist());
            let opt = optimize(&g);
            assert_eq!(g.output_values(), opt.output_values(), "{}", b.name);
            assert!(
                opt.live_and_count() <= g.live_and_count(),
                "{}: optimisation grew the graph",
                b.name
            );
        }
    }

    #[test]
    fn merges_functionally_equal_cones() {
        // x = a AND b built twice through different structures:
        // (a & b) vs NOT(NOT a OR NOT b) — strash alone won't merge the
        // intermediate nodes, functional reduction must.
        let mut nl = Netlist::new("fr");
        let a = nl.add_input();
        let b = nl.add_input();
        let x1 = nl.push(GateKind::And, vec![a, b]);
        let na = nl.push(GateKind::Not, vec![a]);
        let nb = nl.push(GateKind::Not, vec![b]);
        let or = nl.push(GateKind::Or, vec![na, nb]);
        let x2 = nl.push(GateKind::Not, vec![or]);
        let y = nl.push(GateKind::Xor, vec![x1, x2]); // == 0
        nl.set_outputs(vec![y]);
        let g = netlist_to_aig(&nl);
        let opt = optimize(&g);
        assert_eq!(opt.output_values(), vec![0, 0, 0, 0]);
        assert_eq!(opt.live_and_count(), 0, "xor of equal cones must fold to const");
    }

    #[test]
    fn detects_complement_equivalence() {
        // out0 = a NAND b, out1 = a AND b: one node suffices.
        let mut nl = Netlist::new("compl");
        let a = nl.add_input();
        let b = nl.add_input();
        let x = nl.push(GateKind::And, vec![a, b]);
        let y = nl.push(GateKind::Nand, vec![a, b]);
        nl.set_outputs(vec![x, y]);
        let opt = optimize(&netlist_to_aig(&nl));
        assert_eq!(opt.live_and_count(), 1);
        assert_eq!(opt.output_values(), vec![2, 2, 2, 1]);
    }

    #[test]
    fn constant_cones_fold() {
        // (a OR NOT a) AND b == b.
        let mut nl = Netlist::new("taut");
        let a = nl.add_input();
        let b = nl.add_input();
        let na = nl.push(GateKind::Not, vec![a]);
        let t = nl.push(GateKind::Or, vec![a, na]);
        let y = nl.push(GateKind::And, vec![t, b]);
        nl.set_outputs(vec![y]);
        let opt = optimize(&netlist_to_aig(&nl));
        assert_eq!(opt.live_and_count(), 0);
        assert_eq!(opt.output_values(), vec![0, 0, 1, 1]);
    }
}

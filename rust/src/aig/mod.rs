//! And-inverter-graph substrate: the optimisation IR between extracted
//! template netlists and the technology mapper (our stand-in for the
//! Yosys flow the paper uses — see DESIGN.md §2).
//!
//! Passes: structural hashing with local simplification rules (on
//! construction), exhaustive-simulation functional reduction (complete
//! equivalence merging for the paper's <=8-input circuits), and dead-node
//! sweeping.

pub mod build;
pub mod graph;
pub mod opt;

pub use build::{aig_to_netlist, netlist_to_aig};
pub use graph::{Aig, Lit};
pub use opt::optimize;

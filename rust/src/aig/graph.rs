//! Structurally-hashed and-inverter graph.
//!
//! Literal encoding: `lit = 2*var + complemented`. Variable 0 is the
//! constant FALSE, so literal 0 is `false` and literal 1 is `true`.
//! Variables `1..=n_inputs` are primary inputs; higher variables are
//! two-input AND nodes created through [`Aig::and`], which structurally
//! hashes and applies the standard local simplifications
//! (`a&0=0, a&1=a, a&a=a, a&!a=0`).

use std::collections::HashMap;

/// An AIG literal: variable index shifted left once, LSB = complement.
pub type Lit = u32;

pub const FALSE: Lit = 0;
pub const TRUE: Lit = 1;

#[inline]
pub fn var(l: Lit) -> u32 {
    l >> 1
}

#[inline]
pub fn is_compl(l: Lit) -> bool {
    l & 1 == 1
}

#[inline]
pub fn not(l: Lit) -> Lit {
    l ^ 1
}

#[inline]
pub fn lit(v: u32, compl: bool) -> Lit {
    (v << 1) | compl as Lit
}

/// Fanins of an AND node, normalised so `fanin0 <= fanin1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AndNode(pub Lit, pub Lit);

#[derive(Debug, Clone, Default)]
pub struct Aig {
    pub n_inputs: usize,
    /// AND node i (variable `1 + n_inputs + i`) and its two fanin literals.
    pub ands: Vec<AndNode>,
    pub outputs: Vec<Lit>,
    strash: HashMap<AndNode, Lit>,
}

impl Aig {
    pub fn new(n_inputs: usize) -> Self {
        Aig { n_inputs, ..Default::default() }
    }

    /// Literal of primary input `j` (0-based).
    pub fn input(&self, j: usize) -> Lit {
        assert!(j < self.n_inputs);
        lit(1 + j as u32, false)
    }

    pub fn n_vars(&self) -> usize {
        1 + self.n_inputs + self.ands.len()
    }

    fn and_var(&self, idx: usize) -> u32 {
        (1 + self.n_inputs + idx) as u32
    }

    /// Index into `ands` for an AND variable, if it is one.
    pub fn and_index(&self, v: u32) -> Option<usize> {
        let base = 1 + self.n_inputs as u32;
        (v >= base).then(|| (v - base) as usize)
    }

    /// Create (or reuse) the AND of two literals.
    pub fn and(&mut self, a: Lit, b: Lit) -> Lit {
        // Local simplification rules.
        if a == FALSE || b == FALSE || a == not(b) {
            return FALSE;
        }
        if a == TRUE {
            return b;
        }
        if b == TRUE || a == b {
            return a;
        }
        let key = if a <= b { AndNode(a, b) } else { AndNode(b, a) };
        if let Some(&l) = self.strash.get(&key) {
            return l;
        }
        let v = self.and_var(self.ands.len());
        self.ands.push(key);
        let l = lit(v, false);
        self.strash.insert(key, l);
        l
    }

    pub fn or(&mut self, a: Lit, b: Lit) -> Lit {
        not(self.and(not(a), not(b)))
    }

    pub fn xor(&mut self, a: Lit, b: Lit) -> Lit {
        let n_ab = self.and(a, not(b));
        let n_ba = self.and(not(a), b);
        self.or(n_ab, n_ba)
    }

    pub fn mux(&mut self, sel: Lit, t: Lit, e: Lit) -> Lit {
        let st = self.and(sel, t);
        let se = self.and(not(sel), e);
        self.or(st, se)
    }

    pub fn and_many(&mut self, lits: &[Lit]) -> Lit {
        lits.iter().fold(TRUE, |acc, &l| self.and(acc, l))
    }

    pub fn or_many(&mut self, lits: &[Lit]) -> Lit {
        lits.iter().fold(FALSE, |acc, &l| self.or(acc, l))
    }

    /// Number of AND nodes reachable from the outputs.
    pub fn live_and_count(&self) -> usize {
        self.live_vars().iter().filter(|&&v| self.and_index(v).is_some()).count()
    }

    /// Variables reachable from the outputs (excluding the constant).
    pub fn live_vars(&self) -> Vec<u32> {
        let mut seen = vec![false; self.n_vars()];
        let mut stack: Vec<u32> = self.outputs.iter().map(|&l| var(l)).collect();
        let mut live = Vec::new();
        while let Some(v) = stack.pop() {
            if v == 0 || std::mem::replace(&mut seen[v as usize], true) {
                continue;
            }
            live.push(v);
            if let Some(i) = self.and_index(v) {
                stack.push(var(self.ands[i].0));
                stack.push(var(self.ands[i].1));
            }
        }
        live
    }

    /// Exhaustively simulate every variable over all `2^n_inputs` points.
    /// Returns one bit-parallel row per variable (row 0 = constant FALSE).
    pub fn simulate_all(&self) -> Vec<Vec<u64>> {
        let n = self.n_inputs;
        assert!(n <= 16, "exhaustive AIG simulation capped at 16 inputs");
        let words = (1usize << n).div_ceil(64);
        let mask = if n < 6 { (1u64 << (1usize << n)) - 1 } else { !0 };
        let mut rows: Vec<Vec<u64>> = Vec::with_capacity(self.n_vars());
        rows.push(vec![0u64; words]); // constant FALSE
        for j in 0..n {
            rows.push(crate::circuit::sim::input_pattern(j, n, words));
        }
        for nd in &self.ands {
            let mut row = vec![0u64; words];
            for w in 0..words {
                let a = rows[var(nd.0) as usize][w] ^ if is_compl(nd.0) { !0 } else { 0 };
                let b = rows[var(nd.1) as usize][w] ^ if is_compl(nd.1) { !0 } else { 0 };
                row[w] = (a & b) & mask;
            }
            rows.push(row);
        }
        rows
    }

    /// Output values (LSB-first bus) at every input point.
    pub fn output_values(&self) -> Vec<u64> {
        let rows = self.simulate_all();
        let n = self.n_inputs;
        (0..1usize << n)
            .map(|x| {
                self.outputs.iter().enumerate().fold(0u64, |acc, (i, &l)| {
                    let bit =
                        ((rows[var(l) as usize][x / 64] >> (x % 64)) & 1) ^ is_compl(l) as u64;
                    acc | (bit << i)
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_helpers() {
        assert_eq!(var(7), 3);
        assert!(is_compl(7));
        assert_eq!(not(6), 7);
        assert_eq!(lit(3, true), 7);
    }

    #[test]
    fn simplification_rules() {
        let mut g = Aig::new(2);
        let a = g.input(0);
        assert_eq!(g.and(a, FALSE), FALSE);
        assert_eq!(g.and(a, TRUE), a);
        assert_eq!(g.and(a, a), a);
        assert_eq!(g.and(a, not(a)), FALSE);
        assert_eq!(g.ands.len(), 0);
    }

    #[test]
    fn strash_reuses_nodes() {
        let mut g = Aig::new(2);
        let (a, b) = (g.input(0), g.input(1));
        let x = g.and(a, b);
        let y = g.and(b, a);
        assert_eq!(x, y);
        assert_eq!(g.ands.len(), 1);
    }

    #[test]
    fn xor_truth_table() {
        let mut g = Aig::new(2);
        let (a, b) = (g.input(0), g.input(1));
        let x = g.xor(a, b);
        g.outputs = vec![x];
        assert_eq!(g.output_values(), vec![0, 1, 1, 0]);
    }

    #[test]
    fn mux_truth_table() {
        let mut g = Aig::new(3); // in0 = sel, in1 = t, in2 = e
        let (s, t, e) = (g.input(0), g.input(1), g.input(2));
        let m = g.mux(s, t, e);
        g.outputs = vec![m];
        let vals = g.output_values();
        for x in 0..8usize {
            let (s, t, e) = (x & 1, (x >> 1) & 1, (x >> 2) & 1);
            let want = if s == 1 { t } else { e } as u64;
            assert_eq!(vals[x], want, "x={x}");
        }
    }

    #[test]
    fn or_and_many() {
        let mut g = Aig::new(3);
        let ins: Vec<Lit> = (0..3).map(|j| g.input(j)).collect();
        let all = g.and_many(&ins);
        let any = g.or_many(&ins);
        g.outputs = vec![all, any];
        let vals = g.output_values();
        assert_eq!(vals[0], 0);
        assert_eq!(vals[7], 3);
        assert_eq!(vals[3], 2);
    }

    #[test]
    fn live_count_ignores_dead_nodes() {
        let mut g = Aig::new(2);
        let (a, b) = (g.input(0), g.input(1));
        let x = g.and(a, b);
        let _dead = g.and(not(a), b);
        g.outputs = vec![x];
        assert_eq!(g.live_and_count(), 1);
        assert_eq!(g.ands.len(), 2);
    }

    #[test]
    fn complemented_output() {
        let mut g = Aig::new(1);
        let a = g.input(0);
        g.outputs = vec![not(a)];
        assert_eq!(g.output_values(), vec![1, 0]);
    }
}

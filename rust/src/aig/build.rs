//! Netlist <-> AIG conversion.

use crate::circuit::netlist::{GateKind, Netlist, NodeId};

use super::graph::{self, Aig, Lit};

/// Lower a gate-level netlist into a structurally-hashed AIG.
pub fn netlist_to_aig(nl: &Netlist) -> Aig {
    let mut g = Aig::new(nl.n_inputs());
    let mut lit_of: Vec<Lit> = Vec::with_capacity(nl.gates.len());
    let mut input_idx = 0usize;
    for gate in &nl.gates {
        let fanins: Vec<Lit> = gate.fanins.iter().map(|&f| lit_of[f as usize]).collect();
        let l = match gate.kind {
            GateKind::Input => {
                let l = g.input(input_idx);
                input_idx += 1;
                l
            }
            GateKind::Const0 => graph::FALSE,
            GateKind::Const1 => graph::TRUE,
            GateKind::Buf => fanins[0],
            GateKind::Not => graph::not(fanins[0]),
            GateKind::And => g.and_many(&fanins),
            GateKind::Nand => graph::not(g.and_many(&fanins)),
            GateKind::Or => g.or_many(&fanins),
            GateKind::Nor => graph::not(g.or_many(&fanins)),
            GateKind::Xor => fanins.iter().fold(graph::FALSE, |acc, &l| g.xor(acc, l)),
            GateKind::Xnor => {
                graph::not(fanins.iter().fold(graph::FALSE, |acc, &l| g.xor(acc, l)))
            }
        };
        lit_of.push(l);
    }
    g.outputs = nl.outputs.iter().map(|&o| lit_of[o as usize]).collect();
    g
}

/// Raise an AIG back to a netlist of `And`/`Not` gates (plus constants).
/// Inverters are cached so each literal materialises at most once.
pub fn aig_to_netlist(g: &Aig, name: &str) -> Netlist {
    let mut nl = Netlist::new(name);
    // node id of the *positive* phase of each variable; u32::MAX = unset.
    let mut pos: Vec<NodeId> = vec![u32::MAX; g.n_vars()];
    let mut neg: Vec<NodeId> = vec![u32::MAX; g.n_vars()];
    for j in 0..g.n_inputs {
        pos[graph::var(g.input(j)) as usize] = nl.add_input();
    }

    let mut live = vec![false; g.n_vars()];
    for v in g.live_vars() {
        live[v as usize] = true;
    }

    // Lazily-created constants.
    let mut const0: Option<NodeId> = None;
    let mut const1: Option<NodeId> = None;

    // Materialise AND nodes in creation (= topological) order.
    for (i, nd) in g.ands.iter().enumerate() {
        let v = 1 + g.n_inputs + i;
        if !live[v] {
            continue;
        }
        let a = resolve(&mut nl, &mut pos, &mut neg, &mut const0, &mut const1, nd.0);
        let b = resolve(&mut nl, &mut pos, &mut neg, &mut const0, &mut const1, nd.1);
        pos[v] = nl.push(GateKind::And, vec![a, b]);
    }

    let outs: Vec<NodeId> = g
        .outputs
        .clone()
        .iter()
        .map(|&l| resolve(&mut nl, &mut pos, &mut neg, &mut const0, &mut const1, l))
        .collect();
    nl.set_outputs(outs);
    nl
}

fn resolve(
    nl: &mut Netlist,
    pos: &mut [NodeId],
    neg: &mut [NodeId],
    const0: &mut Option<NodeId>,
    const1: &mut Option<NodeId>,
    l: Lit,
) -> NodeId {
    let v = graph::var(l) as usize;
    if v == 0 {
        return if graph::is_compl(l) {
            *const1.get_or_insert_with(|| nl.push(GateKind::Const1, vec![]))
        } else {
            *const0.get_or_insert_with(|| nl.push(GateKind::Const0, vec![]))
        };
    }
    if !graph::is_compl(l) {
        assert_ne!(pos[v], u32::MAX, "fanin materialised before its node");
        return pos[v];
    }
    if neg[v] == u32::MAX {
        let p = pos[v];
        assert_ne!(p, u32::MAX);
        neg[v] = nl.push(GateKind::Not, vec![p]);
    }
    neg[v]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::PAPER_BENCHMARKS;
    use crate::circuit::sim::TruthTables;

    #[test]
    fn netlist_aig_round_trip_preserves_function() {
        for b in &PAPER_BENCHMARKS {
            let nl = b.netlist();
            let g = netlist_to_aig(&nl);
            let tt = TruthTables::simulate(&nl);
            assert_eq!(
                g.output_values(),
                tt.output_values(&nl),
                "netlist->aig mismatch for {}",
                b.name
            );
            let back = aig_to_netlist(&g, b.name);
            assert!(back.validate().is_ok());
            let tt2 = TruthTables::simulate(&back);
            assert_eq!(
                tt2.output_values(&back),
                tt.output_values(&nl),
                "aig->netlist mismatch for {}",
                b.name
            );
        }
    }

    #[test]
    fn constants_materialise_once() {
        use crate::circuit::netlist::Netlist;
        let mut nl = Netlist::new("consts");
        let _a = nl.add_input();
        let c0 = nl.push(GateKind::Const0, vec![]);
        let c1 = nl.push(GateKind::Const1, vec![]);
        nl.set_outputs(vec![c0, c1, c0]);
        let g = netlist_to_aig(&nl);
        assert_eq!(g.output_values(), vec![2, 2]);
        let back = aig_to_netlist(&g, "consts");
        let kinds: Vec<_> = back.gates.iter().map(|x| x.kind).collect();
        let n0 = kinds.iter().filter(|k| **k == GateKind::Const0).count();
        let n1 = kinds.iter().filter(|k| **k == GateKind::Const1).count();
        assert_eq!((n0, n1), (1, 1));
    }

    #[test]
    fn strash_shrinks_redundant_netlist() {
        use crate::circuit::netlist::Netlist;
        let mut nl = Netlist::new("dup");
        let a = nl.add_input();
        let b = nl.add_input();
        let x1 = nl.push(GateKind::And, vec![a, b]);
        let x2 = nl.push(GateKind::And, vec![a, b]); // duplicate
        let o = nl.push(GateKind::Or, vec![x1, x2]); // = x1
        nl.set_outputs(vec![o]);
        let g = netlist_to_aig(&nl);
        assert_eq!(g.live_and_count(), 1);
    }
}

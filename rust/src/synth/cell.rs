//! Standard-cell library and the 3-input minimal-area function table.
//!
//! Cell areas follow the Nangate 45nm Open Cell Library X1 drive
//! strengths (µm²). The [`FunctionTable`] assigns to every boolean
//! function of up to three variables the minimum *tree* area over
//! compositions of library cells, computed once by fixpoint relaxation —
//! a miniature exact synthesis that the cut mapper then reuses for every
//! cut match. Shared subtrees inside a cut are not discounted (tree
//! costing), which is the standard conservative choice in cut mappers.

use std::sync::OnceLock;

/// Truth table over (a, b, c) packed into a u8: bit `x` is f(x) with
/// a = bit0 of x, b = bit1, c = bit2 — the same LSB-first order used
/// everywhere in this repo.
pub type Tt3 = u8;

pub const VAR_A: Tt3 = 0xAA;
pub const VAR_B: Tt3 = 0xCC;
pub const VAR_C: Tt3 = 0xF0;

#[derive(Debug, Clone)]
pub struct Cell {
    pub name: &'static str,
    pub area: f64,
    pub arity: usize,
    /// Function as a combinator over operand truth tables.
    pub eval: fn(&[Tt3]) -> Tt3,
}

/// The library: Nangate 45nm X1-ish cells.
#[derive(Debug, Clone)]
pub struct CellLibrary {
    pub cells: Vec<Cell>,
    pub inv_area: f64,
}

fn f_inv(x: &[Tt3]) -> Tt3 {
    !x[0]
}
fn f_nand2(x: &[Tt3]) -> Tt3 {
    !(x[0] & x[1])
}
fn f_nor2(x: &[Tt3]) -> Tt3 {
    !(x[0] | x[1])
}
fn f_and2(x: &[Tt3]) -> Tt3 {
    x[0] & x[1]
}
fn f_or2(x: &[Tt3]) -> Tt3 {
    x[0] | x[1]
}
fn f_xor2(x: &[Tt3]) -> Tt3 {
    x[0] ^ x[1]
}
fn f_xnor2(x: &[Tt3]) -> Tt3 {
    !(x[0] ^ x[1])
}
fn f_nand3(x: &[Tt3]) -> Tt3 {
    !(x[0] & x[1] & x[2])
}
fn f_nor3(x: &[Tt3]) -> Tt3 {
    !(x[0] | x[1] | x[2])
}
fn f_aoi21(x: &[Tt3]) -> Tt3 {
    !((x[0] & x[1]) | x[2])
}
fn f_oai21(x: &[Tt3]) -> Tt3 {
    !((x[0] | x[1]) & x[2])
}
fn f_mux2(x: &[Tt3]) -> Tt3 {
    // MUX2(a, b, sel) = sel ? b : a
    (x[2] & x[1]) | (!x[2] & x[0])
}

impl CellLibrary {
    pub fn nangate45() -> Self {
        CellLibrary {
            inv_area: 0.532,
            cells: vec![
                Cell { name: "INV_X1", area: 0.532, arity: 1, eval: f_inv },
                Cell { name: "NAND2_X1", area: 0.798, arity: 2, eval: f_nand2 },
                Cell { name: "NOR2_X1", area: 0.798, arity: 2, eval: f_nor2 },
                Cell { name: "AND2_X1", area: 1.064, arity: 2, eval: f_and2 },
                Cell { name: "OR2_X1", area: 1.064, arity: 2, eval: f_or2 },
                Cell { name: "XOR2_X1", area: 1.596, arity: 2, eval: f_xor2 },
                Cell { name: "XNOR2_X1", area: 1.596, arity: 2, eval: f_xnor2 },
                Cell { name: "NAND3_X1", area: 1.064, arity: 3, eval: f_nand3 },
                Cell { name: "NOR3_X1", area: 1.064, arity: 3, eval: f_nor3 },
                Cell { name: "AOI21_X1", area: 1.064, arity: 3, eval: f_aoi21 },
                Cell { name: "OAI21_X1", area: 1.064, arity: 3, eval: f_oai21 },
                Cell { name: "MUX2_X1", area: 1.862, arity: 3, eval: f_mux2 },
            ],
        }
    }
}

/// Minimal tree-area per 3-input function, plus the cell chosen at the
/// root (for reporting).
#[derive(Debug, Clone)]
pub struct FunctionTable {
    pub cost: [f64; 256],
    pub root_cell: [&'static str; 256],
    pub inv_area: f64,
}

impl FunctionTable {
    /// The singleton Nangate-45nm table (built on first use).
    pub fn nangate45() -> &'static FunctionTable {
        static TABLE: OnceLock<FunctionTable> = OnceLock::new();
        TABLE.get_or_init(|| FunctionTable::build(&CellLibrary::nangate45()))
    }

    /// Fixpoint relaxation over cell compositions.
    ///
    /// Binary/unary cells relax over all pairs of reached functions.
    /// Ternary cells are seeded over *leaf arrangements* (permutations of
    /// the three variables, each possibly inverted) and then participate
    /// in further relaxation through the general pass below — leaf-level
    /// AOI/OAI/MUX matches are what a cut of size 3 can use directly.
    pub fn build(lib: &CellLibrary) -> FunctionTable {
        let mut cost = [f64::INFINITY; 256];
        let mut root: [&'static str; 256] = ["-"; 256];
        // Free starting points: projections and constants (wires).
        for (tt, name) in [
            (VAR_A, "wire"),
            (VAR_B, "wire"),
            (VAR_C, "wire"),
            (0x00u8, "tie0"),
            (0xFFu8, "tie1"),
        ] {
            cost[tt as usize] = 0.0;
            root[tt as usize] = name;
        }

        // Ternary seeding over leaf arrangements with input inverters.
        let perms: [[Tt3; 3]; 6] = [
            [VAR_A, VAR_B, VAR_C],
            [VAR_A, VAR_C, VAR_B],
            [VAR_B, VAR_A, VAR_C],
            [VAR_B, VAR_C, VAR_A],
            [VAR_C, VAR_A, VAR_B],
            [VAR_C, VAR_B, VAR_A],
        ];
        for cell in lib.cells.iter().filter(|c| c.arity == 3) {
            for perm in &perms {
                for mask in 0..8u8 {
                    let ops: Vec<Tt3> = perm
                        .iter()
                        .enumerate()
                        .map(|(i, &v)| if (mask >> i) & 1 == 1 { !v } else { v })
                        .collect();
                    let tt = (cell.eval)(&ops) as usize;
                    let c = cell.area + mask.count_ones() as f64 * lib.inv_area;
                    if c < cost[tt] {
                        cost[tt] = c;
                        root[tt] = cell.name;
                    }
                }
            }
        }

        // General relaxation with unary/binary cells until fixpoint.
        loop {
            let mut changed = false;
            for cell in lib.cells.iter().filter(|c| c.arity <= 2) {
                if cell.arity == 1 {
                    for x in 0..256usize {
                        if cost[x].is_infinite() {
                            continue;
                        }
                        let tt = (cell.eval)(&[x as Tt3]) as usize;
                        let c = cost[x] + cell.area;
                        if c + 1e-9 < cost[tt] {
                            cost[tt] = c;
                            root[tt] = cell.name;
                            changed = true;
                        }
                    }
                } else {
                    for x in 0..256usize {
                        if cost[x].is_infinite() {
                            continue;
                        }
                        for y in x..256usize {
                            if cost[y].is_infinite() {
                                continue;
                            }
                            let tt = (cell.eval)(&[x as Tt3, y as Tt3]) as usize;
                            let c = cost[x] + cost[y] + cell.area;
                            if c + 1e-9 < cost[tt] {
                                cost[tt] = c;
                                root[tt] = cell.name;
                                changed = true;
                            }
                        }
                    }
                }
            }
            if !changed {
                break;
            }
        }
        FunctionTable { cost, root_cell: root, inv_area: lib.inv_area }
    }

    pub fn area_of(&self, tt: Tt3) -> f64 {
        self.cost[tt as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_total_and_finite() {
        let t = FunctionTable::nangate45();
        for f in 0..256usize {
            assert!(t.cost[f].is_finite(), "function {f:#04x} unreachable");
        }
    }

    #[test]
    fn projections_and_constants_are_free() {
        let t = FunctionTable::nangate45();
        for f in [VAR_A, VAR_B, VAR_C, 0x00, 0xFF] {
            assert_eq!(t.area_of(f), 0.0);
        }
    }

    #[test]
    fn single_cells_cost_their_area() {
        let t = FunctionTable::nangate45();
        assert_eq!(t.area_of(!VAR_A), 0.532); // INV
        assert_eq!(t.area_of(!(VAR_A & VAR_B)), 0.798); // NAND2
        assert_eq!(t.area_of(VAR_A & VAR_B), 1.064); // AND2 beats NAND2+INV (1.33)
        assert_eq!(t.area_of(VAR_A ^ VAR_B), 1.596); // XOR2
        assert_eq!(t.area_of(!((VAR_A & VAR_B) | VAR_C)), 1.064); // AOI21
    }

    #[test]
    fn table_respects_symmetry() {
        // Cost must be invariant under permuting input variables.
        let t = FunctionTable::nangate45();
        let maj_abc = (VAR_A & VAR_B) | (VAR_A & VAR_C) | (VAR_B & VAR_C);
        let maj_bca = (VAR_B & VAR_C) | (VAR_B & VAR_A) | (VAR_C & VAR_A);
        assert_eq!(t.area_of(maj_abc), t.area_of(maj_bca));
    }

    #[test]
    fn inverter_duality() {
        // f and !f differ by at most one inverter.
        let t = FunctionTable::nangate45();
        for f in 0..=255u8 {
            let d = (t.area_of(f) - t.area_of(!f)).abs();
            assert!(d <= t.inv_area + 1e-9, "f={f:#04x} delta={d}");
        }
    }

    #[test]
    fn costs_are_sane_upper_bound() {
        // Nothing should exceed a naive 2-level bound for 3 vars.
        let t = FunctionTable::nangate45();
        for f in 0..=255u8 {
            assert!(t.area_of(f) < 12.0, "f={f:#04x} cost={}", t.area_of(f));
        }
    }
}

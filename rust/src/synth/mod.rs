//! Technology mapping and the synthesised-area metric — our stand-in for
//! the paper's Yosys + Nangate 45nm flow (DESIGN.md §2).
//!
//! `cell` builds, once, a minimal-area implementation table for all 256
//! three-input boolean functions over a Nangate-45nm-like standard-cell
//! library (fixpoint relaxation over cell compositions). `mapper` then
//! performs 3-feasible-cut covering of the optimised AIG with that table,
//! which is exactly the shape of an area-oriented LUT/cell mapper.
//!
//! The resulting metric is deterministic and monotone in circuit
//! structure; the paper's claims rest on *relative* areas (who wins, by
//! how much), which this preserves.

pub mod cell;
pub mod mapper;

pub use cell::{CellLibrary, FunctionTable};
pub use mapper::{map_aig, MappedNetlist};

use crate::aig::{netlist_to_aig, optimize};
use crate::circuit::Netlist;

/// End-to-end "synthesis": optimise the netlist and map it, returning the
/// synthesised area in µm² (Nangate-45nm-like cell areas).
pub fn synthesize_area(nl: &Netlist) -> f64 {
    let aig = optimize(&netlist_to_aig(nl));
    map_aig(&aig, FunctionTable::nangate45()).area
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::PAPER_BENCHMARKS;

    #[test]
    fn exact_benchmark_areas_are_positive_and_monotone() {
        let mut adder_area = Vec::new();
        let mut mult_area = Vec::new();
        for b in &PAPER_BENCHMARKS {
            let area = synthesize_area(&b.netlist());
            assert!(area > 0.0, "{}", b.name);
            if b.is_adder {
                adder_area.push(area);
            } else {
                mult_area.push(area);
            }
        }
        // Wider circuits must synthesise larger.
        assert!(adder_area[0] < adder_area[1] && adder_area[1] < adder_area[2]);
        assert!(mult_area[0] < mult_area[1] && mult_area[1] < mult_area[2]);
        // A multiplier dwarfs the same-width adder.
        assert!(mult_area[2] > adder_area[2]);
    }

    #[test]
    fn constant_circuit_has_zero_area() {
        use crate::circuit::netlist::{GateKind, Netlist};
        let mut nl = Netlist::new("const");
        let _a = nl.add_input();
        let c = nl.push(GateKind::Const1, vec![]);
        nl.set_outputs(vec![c]);
        assert_eq!(synthesize_area(&nl), 0.0);
    }
}

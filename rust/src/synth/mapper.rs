//! Area-oriented 3-feasible-cut covering of an AIG.
//!
//! Per AIG node we enumerate cuts with at most three leaves (merging
//! fanin cut sets, pruned by area flow to a small priority list), compute
//! each cut's local function, and price it with the
//! [`FunctionTable`](super::cell::FunctionTable). A reverse pass from the
//! outputs extracts the chosen cover and sums distinct cell areas;
//! complemented output edges pay one inverter unless the complemented
//! function is itself the mapped one.

use std::collections::{HashMap, HashSet};

use crate::aig::graph::{self, Aig, Lit};

use super::cell::{FunctionTable, Tt3, VAR_A, VAR_B, VAR_C};

const MAX_CUTS_PER_NODE: usize = 12;

/// A cut: up to three leaf variables plus its local function.
#[derive(Debug, Clone, PartialEq)]
pub struct Cut {
    pub leaves: Vec<u32>, // sorted variable indices
    pub tt: Tt3,
    pub cost: f64, // area-flow estimate used for pruning & DP
}

/// Result of mapping.
#[derive(Debug, Clone)]
pub struct MappedNetlist {
    pub area: f64,
    /// (root variable, chosen cut leaves, root cell name) per mapped node.
    pub cells: Vec<(u32, Vec<u32>, &'static str)>,
    pub inverters: usize,
}

fn tt_of_leaf(pos: usize) -> Tt3 {
    [VAR_A, VAR_B, VAR_C][pos]
}

/// Express literal `l`'s function over `leaves`, where `funcs[var]` holds
/// each already-expressed variable's tt (populated for cut internals).
fn lit_tt(funcs: &HashMap<u32, Tt3>, l: Lit) -> Tt3 {
    let t = funcs[&graph::var(l)];
    if graph::is_compl(l) {
        !t
    } else {
        t
    }
}

/// Compute the function of `root`'s cone over the cut leaves.
fn cut_function(aig: &Aig, root: u32, leaves: &[u32]) -> Tt3 {
    let mut funcs: HashMap<u32, Tt3> = HashMap::new();
    for (i, &v) in leaves.iter().enumerate() {
        funcs.insert(v, tt_of_leaf(i));
    }
    fill(aig, root, &mut funcs);
    funcs[&root]
}

fn fill(aig: &Aig, v: u32, funcs: &mut HashMap<u32, Tt3>) {
    if funcs.contains_key(&v) {
        return;
    }
    if v == 0 {
        funcs.insert(0, 0x00);
        return;
    }
    let idx = aig
        .and_index(v)
        .expect("cut leaf set must cover all non-AND fanins");
    let (f0, f1) = (aig.ands[idx].0, aig.ands[idx].1);
    fill(aig, graph::var(f0), funcs);
    fill(aig, graph::var(f1), funcs);
    let tt = lit_tt(funcs, f0) & lit_tt(funcs, f1);
    funcs.insert(v, tt);
}

fn merge_leaves(a: &[u32], b: &[u32]) -> Option<Vec<u32>> {
    let mut set: Vec<u32> = a.to_vec();
    for &x in b {
        if !set.contains(&x) {
            set.push(x);
        }
    }
    if set.len() > 3 {
        return None;
    }
    set.sort_unstable();
    Some(set)
}

/// Map the AIG; returns total area plus the chosen cover.
pub fn map_aig(aig: &Aig, table: &FunctionTable) -> MappedNetlist {
    let n_vars = aig.n_vars();
    let mut cuts: Vec<Vec<Cut>> = vec![Vec::new(); n_vars];
    let mut best: Vec<f64> = vec![0.0; n_vars]; // area-flow of best cut
    let mut fanout: Vec<u32> = vec![0; n_vars];
    for nd in &aig.ands {
        fanout[graph::var(nd.0) as usize] += 1;
        fanout[graph::var(nd.1) as usize] += 1;
    }
    for &o in &aig.outputs {
        fanout[graph::var(o) as usize] += 1;
    }

    // Inputs: the trivial cut.
    for j in 0..aig.n_inputs {
        let v = graph::var(aig.input(j));
        cuts[v as usize] =
            vec![Cut { leaves: vec![v], tt: VAR_A, cost: 0.0 }];
    }

    // Forward DP in topological (creation) order.
    for (i, nd) in aig.ands.iter().enumerate() {
        let v = (1 + aig.n_inputs + i) as u32;
        let (v0, v1) = (graph::var(nd.0), graph::var(nd.1));
        let mut cand: Vec<Cut> = Vec::new();

        let left: Vec<Cut> = cut_sets(&cuts, v0);
        let right: Vec<Cut> = cut_sets(&cuts, v1);
        for lc in &left {
            for rc in &right {
                let Some(leaves) = merge_leaves(&lc.leaves, &rc.leaves) else {
                    continue;
                };
                let tt = cut_function(aig, v, &leaves);
                let mut cost = table.area_of(tt);
                for &leaf in &leaves {
                    cost += best[leaf as usize] / fanout[leaf as usize].max(1) as f64;
                }
                cand.push(Cut { leaves, tt, cost });
            }
        }
        // Always include the structural 2-cut (its leaves are the fanins),
        // already generated above via trivial fanin cuts; dedup and prune.
        cand.sort_by(|a, b| a.cost.partial_cmp(&b.cost).unwrap());
        cand.dedup_by(|a, b| a.leaves == b.leaves && a.tt == b.tt);
        cand.truncate(MAX_CUTS_PER_NODE);
        best[v as usize] = cand.first().map(|c| c.cost).unwrap_or(0.0);
        cuts[v as usize] = cand;
    }

    // Reverse extraction from the outputs: first fix the cover, then do
    // phase assignment (a root used only in complemented phase is mapped
    // as its complement — NAND-style — instead of paying an inverter).
    let mut mapped: HashSet<u32> = HashSet::new();
    let mut chosen: HashMap<u32, Cut> = HashMap::new();
    let mut leaf_uses: HashSet<u32> = HashSet::new();
    let mut stack: Vec<u32> = Vec::new();
    for &o in &aig.outputs {
        if aig.and_index(graph::var(o)).is_some() {
            stack.push(graph::var(o));
        }
    }
    while let Some(v) = stack.pop() {
        if !mapped.insert(v) {
            continue;
        }
        let cut = cuts[v as usize]
            .first()
            .unwrap_or_else(|| panic!("no cut for node {v}"))
            .clone();
        for &leaf in &cut.leaves {
            if aig.and_index(leaf).is_some() {
                stack.push(leaf);
                leaf_uses.insert(leaf);
            }
        }
        chosen.insert(v, cut);
    }

    let mut area = 0.0f64;
    let mut cells: Vec<(u32, Vec<u32>, &'static str)> = Vec::new();
    let mut invs: HashSet<Lit> = HashSet::new();

    // Output-edge phase census per variable.
    let mut pos_out: HashSet<u32> = HashSet::new();
    let mut neg_out: HashSet<u32> = HashSet::new();
    for &o in &aig.outputs {
        if graph::is_compl(o) {
            neg_out.insert(graph::var(o));
        } else {
            pos_out.insert(graph::var(o));
        }
    }

    for (&v, cut) in &chosen {
        let flip = neg_out.contains(&v) && !pos_out.contains(&v) && !leaf_uses.contains(&v);
        let tt = if flip { !cut.tt } else { cut.tt };
        area += table.area_of(tt);
        cells.push((v, cut.leaves.clone(), table.root_cell[tt as usize]));
        // A flipped root serves its complemented outputs directly.
        if flip {
            invs.insert(graph::lit(v, true)); // mark as served
        }
    }

    // Inverters: distinct complemented output literals not served by a
    // flipped root; complemented PIs always need one; constants never.
    for &o in &aig.outputs {
        let v = graph::var(o);
        if !graph::is_compl(o) || v == 0 || invs.contains(&o) {
            continue;
        }
        let flipped = neg_out.contains(&v) && !pos_out.contains(&v) && !leaf_uses.contains(&v);
        if aig.and_index(v).is_some() && flipped {
            continue;
        }
        invs.insert(o);
        area += table.inv_area;
    }
    let n_inv = invs.iter().filter(|&&l| {
        let v = graph::var(l);
        !(aig.and_index(v).is_some()
            && neg_out.contains(&v)
            && !pos_out.contains(&v)
            && !leaf_uses.contains(&v))
    }).count();

    MappedNetlist { area, cells, inverters: n_inv }
}

/// Cut set of a variable; constants contribute an empty-leaf constant cut.
fn cut_sets(cuts: &[Vec<Cut>], v: u32) -> Vec<Cut> {
    if v == 0 {
        return vec![Cut { leaves: vec![], tt: 0x00, cost: 0.0 }];
    }
    let mut cs = cuts[v as usize].clone();
    // The trivial self-cut lets parents treat this node as a leaf.
    if !cs.iter().any(|c| c.leaves == vec![v]) {
        cs.push(Cut { leaves: vec![v], tt: VAR_A, cost: 0.0 });
    }
    cs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::build::netlist_to_aig;
    use crate::aig::optimize;
    use crate::circuit::netlist::{GateKind, Netlist};
    use crate::synth::cell::FunctionTable;

    fn area_of(nl: &Netlist) -> f64 {
        map_aig(&optimize(&netlist_to_aig(nl)), FunctionTable::nangate45()).area
    }

    #[test]
    fn single_and_gate_costs_and2() {
        let mut nl = Netlist::new("and2");
        let a = nl.add_input();
        let b = nl.add_input();
        let g = nl.push(GateKind::And, vec![a, b]);
        nl.set_outputs(vec![g]);
        assert!((area_of(&nl) - 1.064).abs() < 1e-9);
    }

    #[test]
    fn nand_is_cheaper_than_and_plus_inv() {
        let mut nl = Netlist::new("nand2");
        let a = nl.add_input();
        let b = nl.add_input();
        let g = nl.push(GateKind::Nand, vec![a, b]);
        nl.set_outputs(vec![g]);
        assert!((area_of(&nl) - 0.798).abs() < 1e-9);
    }

    #[test]
    fn xor_maps_to_single_cell() {
        let mut nl = Netlist::new("xor2");
        let a = nl.add_input();
        let b = nl.add_input();
        let g = nl.push(GateKind::Xor, vec![a, b]);
        nl.set_outputs(vec![g]);
        // One XOR2 cell, not the 3-AND AIG decomposition.
        assert!((area_of(&nl) - 1.596).abs() < 1e-9);
    }

    #[test]
    fn full_adder_is_compact() {
        // sum = a^b^cin, cout = ab + cin(a^b): cut mapping should find
        // two XOR2 plus an AOI/OAI-class cone, well under naive AND cover.
        let mut nl = Netlist::new("fa");
        let a = nl.add_input();
        let b = nl.add_input();
        let c = nl.add_input();
        let axb = nl.push(GateKind::Xor, vec![a, b]);
        let sum = nl.push(GateKind::Xor, vec![axb, c]);
        let ab = nl.push(GateKind::And, vec![a, b]);
        let cx = nl.push(GateKind::And, vec![axb, c]);
        let cout = nl.push(GateKind::Or, vec![ab, cx]);
        nl.set_outputs(vec![sum, cout]);
        let area = area_of(&nl);
        assert!(area <= 6.5, "full adder mapped to {area}");
        assert!(area >= 3.0);
    }

    #[test]
    fn output_inverter_is_charged_once() {
        let mut nl = Netlist::new("invout");
        let a = nl.add_input();
        let b = nl.add_input();
        let g = nl.push(GateKind::And, vec![a, b]);
        let n = nl.push(GateKind::Not, vec![g]);
        nl.set_outputs(vec![n, n]);
        // NAND2 alone: complemented output function is itself one cell.
        let area = area_of(&nl);
        assert!((area - 0.798).abs() < 1e-9, "got {area}");
    }

    #[test]
    fn pi_passthrough_costs_nothing() {
        let mut nl = Netlist::new("wire");
        let a = nl.add_input();
        nl.set_outputs(vec![a]);
        assert_eq!(area_of(&nl), 0.0);
    }

    #[test]
    fn inverted_pi_costs_inverter() {
        let mut nl = Netlist::new("inv");
        let a = nl.add_input();
        let n = nl.push(GateKind::Not, vec![a]);
        nl.set_outputs(vec![n]);
        assert!((area_of(&nl) - 0.532).abs() < 1e-9);
    }

    #[test]
    fn shared_logic_counted_once() {
        // Two outputs sharing one AND cone: area must not double.
        let mut nl = Netlist::new("share");
        let a = nl.add_input();
        let b = nl.add_input();
        let c = nl.add_input();
        let g = nl.push(GateKind::And, vec![a, b]);
        let o1 = nl.push(GateKind::And, vec![g, c]);
        let o2 = nl.push(GateKind::Or, vec![g, c]);
        nl.set_outputs(vec![o1, o2]);
        let area = area_of(&nl);
        // AND3 cone + (ab|c) cone <= two 3-cut cells; sharing makes this
        // at most ~2 cells plus change.
        assert!(area <= 3.2, "got {area}");
    }
}

//! Integer range comparators over bit vectors.
//!
//! In the ∀-expanded miter (DESIGN.md §2) the exact circuit's value `E`
//! at each input point is a *constant*, so the distance constraint
//! `|V - E| <= ET` collapses to the interval check
//! `V ∈ [max(0, E-ET), min(2^m - 1, E+ET)]` over the approximate output
//! bits `V` — two lexicographic comparisons against constants.

use crate::sat::Lit;

use super::cnf::CnfBuilder;

/// Constrain `value(bits) <= c` where `bits` is LSB-first.
///
/// Classic constant comparison: for every position `k` where `c` has a 0
/// bit, either some higher position with a 1 in `c` is 0 in the value, or
/// `bits[k]` must be 0. Encoded MSB-down with a prefix-equality chain.
pub fn value_le_const(b: &mut CnfBuilder, bits: &[Lit], c: u64) {
    let m = bits.len();
    if m == 0 || c >= (1u64 << m) - 1 {
        return; // trivially satisfied
    }
    // eq[k]: value bits above position k all equal c's bits above k.
    // Chain from MSB; when prefix equal and c_k = 0, forbid bits[k] = 1.
    let mut prefix_eq: Option<Lit> = None; // None = vacuously true
    for k in (0..m).rev() {
        let ck = (c >> k) & 1 == 1;
        match prefix_eq {
            None => {
                if !ck {
                    // No prefix condition: bits[k] -> value > c unless a
                    // higher... there is no higher; forbid directly only
                    // while prefix is vacuous.
                    b.add_clause(&[!bits[k]]);
                    continue; // prefix stays vacuous (bits[k]=0=c_k)
                }
                // c_k = 1: prefix equality now depends on bits[k].
                prefix_eq = Some(bits[k]);
            }
            Some(eq) => {
                if !ck {
                    // eq & bits[k] would make value > c.
                    b.add_clause(&[!eq, !bits[k]]);
                    // prefix remains eq ∧ !bits[k]; fold into a new lit.
                    let eq2 = b.new_lit();
                    b.add_clause(&[!eq2, eq]);
                    b.add_clause(&[!eq2, !bits[k]]);
                    b.add_clause(&[eq2, !eq, bits[k]]);
                    prefix_eq = Some(eq2);
                } else {
                    let eq2 = b.new_lit();
                    b.add_clause(&[!eq2, eq]);
                    b.add_clause(&[!eq2, bits[k]]);
                    b.add_clause(&[eq2, !eq, !bits[k]]);
                    prefix_eq = Some(eq2);
                }
            }
        }
    }
}

/// Constrain `value(bits) >= c` (LSB-first) by comparing the complemented
/// bits against the complemented constant: `V >= c  <=>  ~V <= ~c`.
pub fn value_ge_const(b: &mut CnfBuilder, bits: &[Lit], c: u64) {
    let m = bits.len();
    if c == 0 {
        return;
    }
    assert!(c < (1u64 << m) + 1, "bound exceeds bus range");
    let inv: Vec<Lit> = bits.iter().map(|&l| !l).collect();
    let mask = (1u64 << m) - 1;
    value_le_const(b, &inv, !c & mask);
}

/// Constrain `lo <= value(bits) <= hi`.
pub fn value_in_range(b: &mut CnfBuilder, bits: &[Lit], lo: u64, hi: u64) {
    assert!(lo <= hi);
    value_ge_const(b, bits, lo);
    value_le_const(b, bits, hi);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sat::{Lit, SatResult};

    fn models_in_range(m: usize, lo: u64, hi: u64) -> Vec<u64> {
        let mut b = CnfBuilder::new();
        let bits: Vec<Lit> = (0..m).map(|_| b.new_lit()).collect();
        value_in_range(&mut b, &bits, lo, hi);
        let mut sats = Vec::new();
        for v in 0..1u64 << m {
            let assum: Vec<Lit> = bits
                .iter()
                .enumerate()
                .map(|(i, &l)| if (v >> i) & 1 == 1 { l } else { !l })
                .collect();
            if b.solver.solve(&assum) == SatResult::Sat {
                sats.push(v);
            }
        }
        sats
    }

    #[test]
    fn exhaustive_range_check() {
        for m in 1..=4 {
            let top = (1u64 << m) - 1;
            for lo in 0..=top {
                for hi in lo..=top {
                    let got = models_in_range(m, lo, hi);
                    let want: Vec<u64> = (lo..=hi).collect();
                    assert_eq!(got, want, "m={m} lo={lo} hi={hi}");
                }
            }
        }
    }

    #[test]
    fn le_only() {
        let mut b = CnfBuilder::new();
        let bits: Vec<Lit> = (0..3).map(|_| b.new_lit()).collect();
        value_le_const(&mut b, &bits, 5);
        for v in 0..8u64 {
            let assum: Vec<Lit> = bits
                .iter()
                .enumerate()
                .map(|(i, &l)| if (v >> i) & 1 == 1 { l } else { !l })
                .collect();
            let want = if v <= 5 { SatResult::Sat } else { SatResult::Unsat };
            assert_eq!(b.solver.solve(&assum), want, "v={v}");
        }
    }

    #[test]
    fn ge_only() {
        let mut b = CnfBuilder::new();
        let bits: Vec<Lit> = (0..3).map(|_| b.new_lit()).collect();
        value_ge_const(&mut b, &bits, 3);
        for v in 0..8u64 {
            let assum: Vec<Lit> = bits
                .iter()
                .enumerate()
                .map(|(i, &l)| if (v >> i) & 1 == 1 { l } else { !l })
                .collect();
            let want = if v >= 3 { SatResult::Sat } else { SatResult::Unsat };
            assert_eq!(b.solver.solve(&assum), want, "v={v}");
        }
    }

    #[test]
    fn trivial_bounds_add_nothing() {
        let mut b = CnfBuilder::new();
        let bits: Vec<Lit> = (0..3).map(|_| b.new_lit()).collect();
        let before = b.solver.n_clauses();
        value_le_const(&mut b, &bits, 7);
        value_ge_const(&mut b, &bits, 0);
        assert_eq!(b.solver.n_clauses(), before);
    }
}

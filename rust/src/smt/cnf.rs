//! Tseitin-style CNF construction helpers over a [`Solver`].

use crate::sat::{Lit, Solver};

/// Thin wrapper owning a solver while formulas are being built.
///
/// Clauses stream straight into the solver's clause arena — there is no
/// intermediate `Vec<Vec<Lit>>` stage. The builder is `Clone` (the
/// solver's clause store is a flat buffer), which is what miter
/// *prototypes* rely on: encode once, clone per lattice cell.
/// [`Self::clauses_added`] counts every encoded clause so tests can
/// assert that cloning performs no re-encoding.
#[derive(Clone)]
pub struct CnfBuilder {
    pub solver: Solver,
    /// Lazily-created literal that is constrained true.
    true_lit: Option<Lit>,
    /// Clauses routed through [`Self::add_clause`] since construction.
    clauses_added: u64,
}

impl Default for CnfBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl CnfBuilder {
    pub fn new() -> Self {
        CnfBuilder { solver: Solver::new(), true_lit: None, clauses_added: 0 }
    }

    /// How many clauses this builder has encoded (clones inherit the
    /// count — a clone that re-encoded anything would show a delta).
    pub fn clauses_added(&self) -> u64 {
        self.clauses_added
    }

    pub fn new_lit(&mut self) -> Lit {
        Lit::pos(self.solver.new_var())
    }

    /// A literal fixed to true (created on first use).
    pub fn true_lit(&mut self) -> Lit {
        match self.true_lit {
            Some(l) => l,
            None => {
                let l = self.new_lit();
                self.solver.add_clause(&[l]);
                self.true_lit = Some(l);
                l
            }
        }
    }

    pub fn false_lit(&mut self) -> Lit {
        !self.true_lit()
    }

    pub fn add_clause(&mut self, lits: &[Lit]) {
        self.clauses_added += 1;
        self.solver.add_clause(lits);
    }

    /// y <-> AND(xs). Empty conjunction is true.
    pub fn and(&mut self, xs: &[Lit]) -> Lit {
        match xs {
            [] => self.true_lit(),
            [x] => *x,
            _ => {
                let y = self.new_lit();
                for &x in xs {
                    self.add_clause(&[!y, x]);
                }
                let mut long: Vec<Lit> = xs.iter().map(|&x| !x).collect();
                long.push(y);
                self.add_clause(&long);
                y
            }
        }
    }

    /// y <-> OR(xs). Empty disjunction is false.
    pub fn or(&mut self, xs: &[Lit]) -> Lit {
        match xs {
            [] => self.false_lit(),
            [x] => *x,
            _ => {
                let y = self.new_lit();
                for &x in xs {
                    self.add_clause(&[y, !x]);
                }
                let mut long: Vec<Lit> = xs.to_vec();
                long.push(!y);
                self.add_clause(&long);
                y
            }
        }
    }

    /// y <-> (a XOR b).
    pub fn xor(&mut self, a: Lit, b: Lit) -> Lit {
        let y = self.new_lit();
        self.add_clause(&[!y, a, b]);
        self.add_clause(&[!y, !a, !b]);
        self.add_clause(&[y, !a, b]);
        self.add_clause(&[y, a, !b]);
        y
    }

    /// y <-> (sel ? t : e).
    pub fn ite(&mut self, sel: Lit, t: Lit, e: Lit) -> Lit {
        let y = self.new_lit();
        self.add_clause(&[!y, !sel, t]);
        self.add_clause(&[!y, sel, e]);
        self.add_clause(&[y, !sel, !t]);
        self.add_clause(&[y, sel, !e]);
        // Redundant but propagation-strengthening: y true if both branches.
        self.add_clause(&[!t, !e, y]);
        self.add_clause(&[t, e, !y]);
        y
    }

    /// Constrain a -> b.
    pub fn implies(&mut self, a: Lit, b: Lit) {
        self.add_clause(&[!a, b]);
    }

    /// Constrain y <-> (a OR b) for an existing y.
    pub fn define_or2(&mut self, y: Lit, a: Lit, b: Lit) {
        self.add_clause(&[!a, y]);
        self.add_clause(&[!b, y]);
        self.add_clause(&[a, b, !y]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sat::SatResult;

    fn all_models_agree<F>(builder: &mut CnfBuilder, ins: &[Lit], y: Lit, f: F)
    where
        F: Fn(&[bool]) -> bool,
    {
        // Enumerate all input assignments via assumptions; check y's value.
        let n = ins.len();
        for m in 0..1usize << n {
            let assum: Vec<Lit> = ins
                .iter()
                .enumerate()
                .map(|(i, &l)| if (m >> i) & 1 == 1 { l } else { !l })
                .collect();
            assert_eq!(builder.solver.solve(&assum), SatResult::Sat);
            let bits: Vec<bool> = (0..n).map(|i| (m >> i) & 1 == 1).collect();
            assert_eq!(
                builder.solver.model_value(y),
                f(&bits),
                "inputs {bits:?}"
            );
        }
    }

    #[test]
    fn and_gate_semantics() {
        let mut b = CnfBuilder::new();
        let ins: Vec<Lit> = (0..3).map(|_| b.new_lit()).collect();
        let y = b.and(&ins);
        all_models_agree(&mut b, &ins.clone(), y, |bits| bits.iter().all(|&x| x));
    }

    #[test]
    fn or_gate_semantics() {
        let mut b = CnfBuilder::new();
        let ins: Vec<Lit> = (0..3).map(|_| b.new_lit()).collect();
        let y = b.or(&ins);
        all_models_agree(&mut b, &ins.clone(), y, |bits| bits.iter().any(|&x| x));
    }

    #[test]
    fn xor_gate_semantics() {
        let mut b = CnfBuilder::new();
        let ins: Vec<Lit> = (0..2).map(|_| b.new_lit()).collect();
        let y = b.xor(ins[0], ins[1]);
        all_models_agree(&mut b, &ins.clone(), y, |bits| bits[0] ^ bits[1]);
    }

    #[test]
    fn ite_semantics() {
        let mut b = CnfBuilder::new();
        let ins: Vec<Lit> = (0..3).map(|_| b.new_lit()).collect();
        let y = b.ite(ins[0], ins[1], ins[2]);
        all_models_agree(&mut b, &ins.clone(), y, |bits| {
            if bits[0] {
                bits[1]
            } else {
                bits[2]
            }
        });
    }

    #[test]
    fn clone_carries_state_without_reencoding() {
        let mut b = CnfBuilder::new();
        let ins: Vec<Lit> = (0..3).map(|_| b.new_lit()).collect();
        let y = b.and(&ins);
        let encoded = b.clauses_added();
        assert!(encoded > 0);
        let mut c = b.clone();
        // The clone starts at the same count (nothing re-encoded) and
        // answers queries identically.
        assert_eq!(c.clauses_added(), encoded);
        assert_eq!(c.solver.solve(&[ins[0], ins[1], ins[2]]), SatResult::Sat);
        assert!(c.solver.model_value(y));
        // Divergence after the clone stays local to each copy.
        c.add_clause(&[!y]);
        assert_eq!(c.clauses_added(), encoded + 1);
        assert_eq!(b.clauses_added(), encoded);
        assert_eq!(c.solver.solve(&[ins[0], ins[1], ins[2]]), SatResult::Unsat);
        assert_eq!(b.solver.solve(&[ins[0], ins[1], ins[2]]), SatResult::Sat);
    }

    #[test]
    fn empty_and_or() {
        let mut b = CnfBuilder::new();
        let t = b.and(&[]);
        let f = b.or(&[]);
        assert_eq!(b.solver.solve(&[]), SatResult::Sat);
        assert!(b.solver.model_value(t));
        assert!(!b.solver.model_value(f));
    }
}

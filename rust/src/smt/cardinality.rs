//! Totalizer cardinality constraints (Bailleux & Boufkhad).
//!
//! `at_most_k(builder, xs, k)` constrains `sum(xs) <= k` by building a
//! balanced merge tree of unary counters. The totalizer is incremental-
//! friendly: the count outputs are ordinary literals, so the search can
//! also *assume* tighter bounds without re-encoding (used by the
//! progressive weakening in `search::lattice`).

use crate::sat::Lit;

use super::cnf::CnfBuilder;

/// Unary counter node: `out[i]` true iff at least `i+1` of the leaves
/// below it are true.
fn merge(b: &mut CnfBuilder, left: &[Lit], right: &[Lit], cap: usize) -> Vec<Lit> {
    let n = (left.len() + right.len()).min(cap);
    let out: Vec<Lit> = (0..n).map(|_| b.new_lit()).collect();
    // sum >= i+j  =>  out[i+j-1]; encode for all splits.
    for i in 0..=left.len().min(n) {
        for j in 0..=right.len().min(n) {
            if i + j == 0 || i + j > n {
                continue;
            }
            let mut clause: Vec<Lit> = Vec::with_capacity(3);
            if i > 0 {
                clause.push(!left[i - 1]);
            }
            if j > 0 {
                clause.push(!right[j - 1]);
            }
            clause.push(out[i + j - 1]);
            b.add_clause(&clause);
        }
    }
    // Only the "count >= i+j -> out" direction is required: enforcing
    // `sum <= k` (hard or assumed as !out[k]) only ever *reads* that
    // direction. At-least constraints would need the converse; the search
    // never uses them.
    out
}

/// Build the totalizer count outputs for `xs`, capped at `cap` counts.
/// `result[i]` is true iff at least `i+1` inputs are true (for i < cap).
pub fn totalizer_outputs(b: &mut CnfBuilder, xs: &[Lit], cap: usize) -> Vec<Lit> {
    match xs.len() {
        0 => Vec::new(),
        1 => vec![xs[0]],
        _ => {
            let mid = xs.len() / 2;
            let left = totalizer_outputs(b, &xs[..mid], cap);
            let right = totalizer_outputs(b, &xs[mid..], cap);
            merge(b, &left, &right, cap)
        }
    }
}

/// Constrain `sum(xs) <= k` (hard clauses).
pub fn at_most_k(b: &mut CnfBuilder, xs: &[Lit], k: usize) {
    if k >= xs.len() {
        return;
    }
    if k == 0 {
        for &x in xs {
            b.add_clause(&[!x]);
        }
        return;
    }
    let outs = totalizer_outputs(b, xs, k + 1);
    // Forbid count >= k+1.
    if outs.len() > k {
        b.add_clause(&[!outs[k]]);
    }
}

/// Build count outputs once and return the *assumption literal* that
/// enforces `sum(xs) <= k` when assumed. Used for progressive weakening
/// without re-encoding the formula. `Clone` so an encoded miter can be
/// cloned wholesale (the outputs are plain literals into the cloned
/// solver).
#[derive(Clone)]
pub struct BoundedCounter {
    outs: Vec<Lit>,
    n_inputs: usize,
}

impl BoundedCounter {
    pub fn new(b: &mut CnfBuilder, xs: &[Lit]) -> Self {
        let outs = totalizer_outputs(b, xs, xs.len());
        BoundedCounter { outs, n_inputs: xs.len() }
    }

    /// Literal that is *false* iff the count exceeds `k`; assume it to
    /// enforce `sum <= k`. Returns `None` when the bound is trivial.
    pub fn at_most(&self, k: usize) -> Option<Lit> {
        if k >= self.n_inputs {
            None
        } else {
            Some(!self.outs[k])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sat::SatResult;

    fn popcount_models(n: usize, k: usize) -> (usize, usize) {
        // Returns (#models found satisfying at_most_k, expected count).
        let mut b = CnfBuilder::new();
        let xs: Vec<Lit> = (0..n).map(|_| b.new_lit()).collect();
        at_most_k(&mut b, &xs, k);
        let mut sat_count = 0usize;
        for m in 0..1usize << n {
            let assum: Vec<Lit> = xs
                .iter()
                .enumerate()
                .map(|(i, &l)| if (m >> i) & 1 == 1 { l } else { !l })
                .collect();
            if b.solver.solve(&assum) == SatResult::Sat {
                sat_count += 1;
            }
        }
        let expected = (0..1usize << n).filter(|m| m.count_ones() as usize <= k).count();
        (sat_count, expected)
    }

    #[test]
    fn at_most_k_exact_model_count() {
        for n in 1..=6 {
            for k in 0..=n {
                let (got, want) = popcount_models(n, k);
                assert_eq!(got, want, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn at_most_zero_forces_all_false() {
        let mut b = CnfBuilder::new();
        let xs: Vec<Lit> = (0..4).map(|_| b.new_lit()).collect();
        at_most_k(&mut b, &xs, 0);
        assert_eq!(b.solver.solve(&[]), SatResult::Sat);
        for &x in &xs {
            assert!(!b.solver.model_value(x));
        }
        assert_eq!(b.solver.solve(&[xs[2]]), SatResult::Unsat);
    }

    #[test]
    fn bounded_counter_assumption_tightening() {
        let mut b = CnfBuilder::new();
        let xs: Vec<Lit> = (0..5).map(|_| b.new_lit()).collect();
        let counter = BoundedCounter::new(&mut b, &xs);
        // Force exactly 3 inputs true.
        b.solver.add_clause(&[xs[0]]);
        b.solver.add_clause(&[xs[1]]);
        b.solver.add_clause(&[xs[2]]);
        b.solver.add_clause(&[!xs[3]]);
        b.solver.add_clause(&[!xs[4]]);
        for k in 0..5 {
            let mut assum = Vec::new();
            if let Some(l) = counter.at_most(k) {
                assum.push(l);
            }
            let want = if k >= 3 { SatResult::Sat } else { SatResult::Unsat };
            assert_eq!(b.solver.solve(&assum), want, "k={k}");
        }
    }

    #[test]
    fn trivial_bound_is_none() {
        let mut b = CnfBuilder::new();
        let xs: Vec<Lit> = (0..3).map(|_| b.new_lit()).collect();
        let counter = BoundedCounter::new(&mut b, &xs);
        assert!(counter.at_most(3).is_none());
        assert!(counter.at_most(2).is_some());
    }
}

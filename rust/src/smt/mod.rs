//! Propositional encodings on top of the SAT core — the "bit-blasting"
//! layer standing in for the paper's use of Z3 over the error miter
//! (DESIGN.md §2): Tseitin gates, totalizer cardinality constraints for
//! the LPP/PPO/PIT/ITS restrictions, and integer range comparators for
//! the `|exact - approx| <= ET` distance check.

pub mod cardinality;
pub mod cnf;
pub mod compare;

pub use cardinality::at_most_k;
pub use cnf::CnfBuilder;
pub use compare::{value_in_range, value_le_const, value_ge_const};
